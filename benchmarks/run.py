"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (units in ``derived`` where the
quantity is a model count rather than wall time) and writes the
``BENCH_dprt.json`` artifact (method x N x batch rows from the DPRT
implementation shoot-out) at the repo root so subsequent PRs have a
structured perf baseline to regress against.
"""
import sys
import traceback


def main() -> None:
    from . import (table1_forward_cycles, table2_inverse_cycles,
                   table3_resources, fig17_runtime_vs_n, fig19_20_pareto,
                   bench_conv, bench_dprt_impl, bench_lm_step,
                   roofline_report, common)

    print("name,us_per_call,derived")
    failed = []
    for mod in [table1_forward_cycles, table2_inverse_cycles,
                table3_resources, fig17_runtime_vs_n, fig19_20_pareto,
                bench_conv, bench_dprt_impl, bench_lm_step,
                roofline_report]:
        try:
            mod.main()
        except Exception:
            failed.append(mod)
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
    if bench_dprt_impl not in failed:
        # never clobber the committed perf baseline with partial rows
        common.dump_json(common.BENCH_DPRT_PATH, prefix="dprt_impl/")
    else:
        print("# BENCH_dprt.json NOT written (bench_dprt_impl failed)",
              file=sys.stderr)
    if failed:
        raise SystemExit(f"{len(failed)} benchmark modules failed")


if __name__ == "__main__":
    main()
