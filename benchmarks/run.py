"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (units in ``derived`` where the
quantity is a model count rather than wall time) and writes the
``BENCH_dprt.json`` artifact (method x N x batch rows from the DPRT
implementation shoot-out) at the repo root so subsequent PRs have a
structured perf baseline to regress against.

Regression workflow (see ``benchmarks/check_regression.py``):

    python -m benchmarks.run             # full run, REWRITES the baseline
    python -m benchmarks.run --check     # full run, COMPARES against the
                                         # committed baseline instead of
                                         # rewriting; exit 1 on slowdown
    python -m benchmarks.check_regression  # guarded rows only (DPRT
                                         # shoot-out + conv/DFT pipelines
                                         # + sharded where available) and
                                         # compare
"""
import sys
import traceback


def main(argv=None) -> None:
    if argv is None:
        argv = sys.argv[1:]
    check = "--check" in argv
    from . import (table1_forward_cycles, table2_inverse_cycles,
                   table3_resources, fig17_runtime_vs_n, fig19_20_pareto,
                   bench_conv, bench_dprt_impl, bench_dprt_sharded,
                   bench_stream, bench_lm_step, roofline_report,
                   check_regression, common)

    print("name,us_per_call,derived")
    failed = []
    for mod in [table1_forward_cycles, table2_inverse_cycles,
                table3_resources, fig17_runtime_vs_n, fig19_20_pareto,
                bench_conv, bench_dprt_impl, bench_dprt_sharded,
                bench_stream, bench_lm_step, roofline_report]:
        try:
            mod.main()
        except Exception:
            failed.append(mod)
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
    if bench_dprt_impl in failed or bench_conv in failed:
        print("# BENCH_dprt.json NOT written (DPRT/conv bench failed)",
              file=sys.stderr)
    elif check:
        # guard mode: gate perf against the committed baseline AND the
        # public-API health smoke together (neither touches the baseline)
        fresh = [r for r in common.ROWS
                 if r["name"].startswith(common.BENCH_PREFIXES)]
        guard_failed = check_regression.run_guard(fresh) != 0
        import contextlib
        from repro.radon import selfcheck
        with contextlib.redirect_stdout(sys.stderr):  # keep stdout CSV-pure
            selfcheck_failed = selfcheck.run(run_bench=False) != 0
        if selfcheck_failed:
            print("# FAIL: repro.radon.selfcheck", file=sys.stderr)
            guard_failed = True
        if guard_failed:
            raise SystemExit(1)
    else:
        # never clobber the committed perf baseline with partial rows
        common.dump_json(common.BENCH_DPRT_PATH,
                         prefix=common.BENCH_PREFIXES)
    if failed:
        raise SystemExit(f"{len(failed)} benchmark modules failed")


if __name__ == "__main__":
    main(sys.argv[1:])
