"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (units in ``derived`` where the
quantity is a model count rather than wall time).
"""
import sys
import traceback


def main() -> None:
    from . import (table1_forward_cycles, table2_inverse_cycles,
                   table3_resources, fig17_runtime_vs_n, fig19_20_pareto,
                   bench_conv, bench_dprt_impl, bench_lm_step,
                   roofline_report)

    print("name,us_per_call,derived")
    failures = 0
    for mod in [table1_forward_cycles, table2_inverse_cycles,
                table3_resources, fig17_runtime_vs_n, fig19_20_pareto,
                bench_conv, bench_dprt_impl, bench_lm_step,
                roofline_report]:
        try:
            mod.main()
        except Exception:
            failures += 1
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
