"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (units in ``derived`` where the
quantity is a model count rather than wall time) and writes the
``BENCH_dprt.json`` artifact (method x N x batch rows from the DPRT
implementation shoot-out) at the repo root so subsequent PRs have a
structured perf baseline to regress against.

Regression workflow (see ``benchmarks/check_regression.py``):

    python -m benchmarks.run             # full run, REWRITES the baseline
    python -m benchmarks.run --check     # full run, COMPARES against the
                                         # committed baseline instead of
                                         # rewriting; exit 1 on slowdown
    python -m benchmarks.run --only serve  # just the serve/* modules;
                                         # without --check this MERGES the
                                         # fresh rows into the baseline
                                         # (other rows kept verbatim)
    python -m benchmarks.check_regression  # guarded rows only (DPRT
                                         # shoot-out + conv/DFT pipelines
                                         # + sharded/stream/serve rows)
"""
import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="compare against the committed baseline instead "
                         "of rewriting it; exit 1 on regression")
    ap.add_argument("--only", default=None, metavar="PREFIX",
                    help="run only the modules producing rows under this "
                         "baseline prefix (e.g. serve, conv, dprt_impl)")
    args = ap.parse_args(argv)
    from . import (table1_forward_cycles, table2_inverse_cycles,
                   table3_resources, fig17_runtime_vs_n, fig19_20_pareto,
                   bench_conv, bench_dprt_impl, bench_dprt_sharded,
                   bench_recon, bench_serve, bench_stream, bench_lm_step,
                   roofline_report, check_regression, common)

    # guarded-prefix -> producing module; --only selects through this
    prefix_modules = {
        "dprt_impl/": bench_dprt_impl,
        "conv/": bench_conv,
        "dft/": bench_conv,
        "stream/": bench_stream,
        "sharded_stream/": bench_stream,
        "serve/": bench_serve,
        "recon/": bench_recon,
    }
    all_modules = [table1_forward_cycles, table2_inverse_cycles,
                   table3_resources, fig17_runtime_vs_n, fig19_20_pareto,
                   bench_conv, bench_dprt_impl, bench_dprt_sharded,
                   bench_recon, bench_serve, bench_stream, bench_lm_step,
                   roofline_report]
    if args.only is None:
        modules, prefixes = all_modules, common.BENCH_PREFIXES
    else:
        prefixes = tuple(p for p in prefix_modules
                         if p.startswith(args.only))
        if not prefixes:
            raise SystemExit(
                f"--only {args.only!r} matches no guarded prefix "
                f"(choose from {sorted(prefix_modules)})")
        modules = list(dict.fromkeys(prefix_modules[p] for p in prefixes))

    print("name,us_per_call,derived")
    failed = []
    for mod in modules:
        try:
            mod.main()
        except Exception:
            failed.append(mod)
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
    if any(prefix_modules[p] in failed for p in prefixes):
        print("# baseline NOT touched (a guarded-row module failed)",
              file=sys.stderr)
    elif args.check:
        # guard mode: gate perf against the committed baseline -- and,
        # on full runs, the public-API health smoke with it (a partial
        # --only run keeps the quick path quick; scripts/ci.sh runs
        # selfcheck as its own step)
        fresh = [r for r in common.ROWS if r["name"].startswith(prefixes)]
        guard_failed = check_regression.run_guard(
            fresh, prefixes=None if args.only is None else prefixes) != 0
        if args.only is None:
            import contextlib
            from repro.radon import selfcheck
            with contextlib.redirect_stdout(sys.stderr):  # stdout CSV-pure
                if selfcheck.run(run_bench=False) != 0:
                    print("# FAIL: repro.radon.selfcheck", file=sys.stderr)
                    guard_failed = True
        if guard_failed:
            raise SystemExit(1)
    elif args.only is not None:
        # partial rerun: refresh ONLY the measured prefixes in the
        # artifact, keep every other committed row byte-identical
        common.merge_json(common.BENCH_DPRT_PATH, prefixes)
    else:
        # never clobber the committed perf baseline with partial rows
        common.dump_json(common.BENCH_DPRT_PATH,
                         prefix=common.BENCH_PREFIXES)
    if failed:
        raise SystemExit(f"{len(failed)} benchmark modules failed")


if __name__ == "__main__":
    main(sys.argv[1:])
