"""Shared benchmark helpers: timing + CSV emission + JSON artifacts."""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Callable, Optional

import jax

ROWS: list[dict] = []

# Repo-root perf-baseline artifact, shared by benchmarks.run and the
# standalone `python -m benchmarks.bench_dprt_impl` entry point.
BENCH_DPRT_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_dprt.json")

#: row-name prefixes folded into (and regressed against) the baseline
#: artifact: the DPRT implementation shoot-out, the projection-pipeline
#: conv/DFT rows, the streamed-strip / direction-sharded rows, the
#: dynamic-batching serve tier, and the reconstruction solvers.
BENCH_PREFIXES = ("dprt_impl/", "conv/", "dft/", "stream/",
                  "sharded_stream/", "serve/", "recon/")


def emit(name: str, us_per_call: float, derived: str = "", **extra) -> None:
    """Record one measurement row.

    ``extra`` keys (e.g. method=, n=, batch=) are carried into the JSON
    artifact written by :func:`dump_json` so downstream PRs can regress
    against structured numbers instead of parsing row names.
    """
    ROWS.append({"name": name, "us_per_call": us_per_call,
                 "derived": derived, **extra})
    print(f"{name},{us_per_call:.2f},{derived}")


def dump_json(path: str, prefix=None) -> dict:
    """Write recorded rows (optionally filtered by name prefix(es)) to
    ``path``.

    Returns the artifact dict: {"backend", "rows": [...]} with each row's
    structured fields intact.
    """
    rows = [r for r in ROWS
            if prefix is None or r["name"].startswith(prefix)]
    artifact = {"backend": jax.default_backend(), "rows": rows}
    with open(path, "w") as fh:
        json.dump(artifact, fh, indent=2, sort_keys=True)
    # status to stderr: stdout is the name,us_per_call,derived CSV stream
    print(f"# wrote {len(rows)} rows -> {path}", file=sys.stderr)
    return artifact


def merge_json(path: str, prefixes) -> dict:
    """Update the artifact at ``path`` in place for ``prefixes`` only:
    recorded rows under those prefixes replace the baseline's, every
    other baseline row is kept verbatim.  The partial-rerun writer
    behind ``benchmarks.run --only`` -- a single-prefix rerun must
    never clobber the rest of the committed baseline.
    """
    fresh = [r for r in ROWS if r["name"].startswith(tuple(prefixes))]
    try:
        with open(path) as fh:
            artifact = json.load(fh)
    except (OSError, json.JSONDecodeError):
        artifact = {}
    kept = [r for r in artifact.get("rows", [])
            if not r["name"].startswith(tuple(prefixes))]
    artifact = {"backend": artifact.get("backend") or jax.default_backend(),
                "rows": sorted(kept + fresh, key=lambda r: r["name"])}
    with open(path, "w") as fh:
        json.dump(artifact, fh, indent=2, sort_keys=True)
    print(f"# merged {len(fresh)} rows under {tuple(prefixes)} -> {path} "
          f"({len(kept)} rows kept)", file=sys.stderr)
    return artifact


def time_jax(fn: Callable, *args, warmup: int = 1, iters: int = 5,
             stat: str = "median") -> float:
    """Wall-time (us) of a jitted callable on current devices.

    ``stat="median"`` (default) suits quick sweeps; ``stat="min"`` with
    more iters is the noise-robust statistic the conv/pipeline rows use
    (min-of-20 per the projection-pipeline acceptance methodology).
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    if stat == "min":
        return times[0] * 1e6
    return times[len(times) // 2] * 1e6
