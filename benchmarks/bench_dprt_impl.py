"""Implementation shoot-out at the paper's N=251: gather (systolic analog)
vs Horner shift-add (paper dataflow) vs strip decomposition (H sweep) vs
the fused Pallas kernel family (interpret mode on CPU), single-image AND
batched (the Sec. V-B coprocessor throughput scenario).

Every pallas row also reports the hoisted-ladder work model: the
roll-select masks and alignment rolls cost <= ceil(log2 N) rotate+select
pairs of *setup* per m-block (amortized over all H Horner steps of a
strip -- NOT re-derived per step), plus the useful-row fraction of the
final m-block so masked padding rows are never counted as throughput.
This is the measurement harness the §Perf hillclimb of the DPRT cell
iterates with; ``python -m benchmarks.run`` folds these rows into
``BENCH_dprt.json``.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import radon
from repro.kernels import (dprt_pallas, pallas_block_spec,
                           roll_rows_ladder_spec)
from repro.kernels.tuning import wasted_direction_rows

from .common import BENCH_DPRT_PATH, dump_json, emit, time_jax

N = 251
BATCH = 16


def _ladder_note(n: int, m_block: int) -> str:
    """Work model of the hoisted ladder for the derived column."""
    setup = roll_rows_ladder_spec(n)
    waste = wasted_direction_rows(n, m_block)
    useful = (n + 1) / (n + 1 + waste)
    return (f"ladder_setup_rot_sel_per_mblock<={setup} "
            f"useful_row_frac={useful:.3f} masked_rows={waste}")


def main() -> None:
    n = N
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)

    # operator API: one cached, AOT-able operator per (geometry, knobs)
    base = time_jax(radon.DPRT((n, n), jnp.int32, "gather"), f)
    emit(f"dprt_impl/gather/N{n}", base, "systolic-analog baseline",
         method="gather", n=n, batch=1)
    horner = time_jax(radon.DPRT((n, n), jnp.int32, "horner"), f)
    emit(f"dprt_impl/horner/N{n}", horner,
         f"speedup_vs_gather={base / horner:.2f}",
         method="horner", n=n, batch=1)
    for h in [2, 16, 64, 128]:
        us = time_jax(radon.DPRT((n, n), jnp.int32, "strips",
                                 strip_rows=h), f)
        emit(f"dprt_impl/strips_H{h}/N{n}", us,
             f"speedup_vs_gather={base / us:.2f}",
             method="strips", n=n, batch=1, strip_rows=h)

    th, tm = pallas_block_spec(n)
    us = time_jax(radon.DPRT((n, n), jnp.int32, "pallas"), f, iters=3)
    emit(f"dprt_impl/pallas_fused/N{n}", us,
         f"H={th} M={tm} speedup_vs_horner={horner / us:.2f} "
         + _ladder_note(n, tm),
         method="pallas", n=n, batch=1, strip_rows=th, m_block=tm)

    # the plan layer's auto pick (resolves to the fused pallas backend for
    # prime images); the regression guard gates it against pallas_fused
    us_a = time_jax(radon.DPRT((n, n), jnp.int32, "auto"), f, iters=3)
    emit(f"dprt_impl/auto/N{n}", us_a,
         f"resolved=pallas dispatch_overhead_x={us_a / us:.2f}",
         method="auto", n=n, batch=1, strip_rows=th, m_block=tm)

    # batched service throughput (the FPGA-coprocessor comparison point,
    # Sec. V-B: CPU ~1.48ms/image for the adds alone)
    fb = jnp.asarray(rng.integers(0, 256, (BATCH, n, n)), jnp.int32)
    us_h = time_jax(radon.DPRT((BATCH, n, n), jnp.int32, "horner"), fb,
                    iters=3)
    emit(f"dprt_impl/batched{BATCH}_horner/N{n}", us_h,
         f"imgs_per_s={BATCH / (us_h / 1e6):.1f}",
         method="horner", n=n, batch=BATCH)
    us_s = time_jax(radon.DPRT((BATCH, n, n), jnp.int32, "strips",
                               strip_rows=64), fb, iters=3)
    emit(f"dprt_impl/batched{BATCH}_strips_H64/N{n}", us_s,
         f"imgs_per_s={BATCH / (us_s / 1e6):.1f}",
         method="strips", n=n, batch=BATCH, strip_rows=64)
    us_p = time_jax(radon.DPRT((BATCH, n, n), jnp.int32, "pallas"), fb,
                    iters=3)
    emit(f"dprt_impl/batched{BATCH}_pallas_fused/N{n}", us_p,
         f"imgs_per_s={BATCH / (us_p / 1e6):.1f} one_pallas_call "
         f"speedup_vs_batched_horner={us_h / us_p:.2f} "
         + _ladder_note(n, tm),
         method="pallas", n=n, batch=BATCH, strip_rows=th, m_block=tm)

    # bounded-memory streaming (Sec. III-C resource fitting): the same
    # stack in block_batch-sized chunks through the fused kernel
    us_b = time_jax(radon.DPRT((BATCH, n, n), jnp.int32, "pallas",
                               block_batch=4), fb, iters=3)
    emit(f"dprt_impl/batched{BATCH}_pallas_blockbatch4/N{n}", us_b,
         f"imgs_per_s={BATCH / (us_b / 1e6):.1f} chunks_of_4 "
         f"overhead_vs_one_call_x={us_b / us_p:.2f}",
         method="pallas", n=n, batch=BATCH, strip_rows=th, m_block=tm,
         block_batch=4)

    # direct single-image pallas kernel call (bypassing dispatch), for
    # continuity with the seed trajectory's pallas_interp row
    us = time_jax(jax.jit(
        lambda x: dprt_pallas(x, strip_rows=16, m_block=32)), f, iters=3)
    emit(f"dprt_impl/pallas_interp/N{n}", us,
         "python-interpret mode (correctness path; perf on real TPU)",
         method="pallas", n=n, batch=1, strip_rows=16, m_block=32)


if __name__ == "__main__":
    main()
    dump_json(BENCH_DPRT_PATH, prefix="dprt_impl/")
