"""Implementation shoot-out at the paper's N=251: gather (systolic analog)
vs Horner shift-add (paper dataflow) vs strip decomposition (H sweep) vs
the Pallas kernel (interpret mode).  This is the measurement harness the
§Perf hillclimb of the DPRT cell iterates with."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dprt import dprt
from repro.kernels import dprt_pallas

from .common import emit, time_jax


def main() -> None:
    n = 251
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)

    base = time_jax(jax.jit(lambda x: dprt(x, method="gather")), f)
    emit("dprt_impl/gather/N251", base, "systolic-analog baseline")
    horner = time_jax(jax.jit(lambda x: dprt(x, method="horner")), f)
    emit("dprt_impl/horner/N251", horner,
         f"speedup_vs_gather={base / horner:.2f}")
    for h in [2, 16, 64, 128]:
        us = time_jax(jax.jit(
            lambda x, hh=h: dprt(x, method="strips", strip_rows=hh)), f)
        emit(f"dprt_impl/strips_H{h}/N251", us,
             f"speedup_vs_gather={base / us:.2f}")
    us = time_jax(jax.jit(
        lambda x: dprt_pallas(x, strip_rows=16, m_block=32)), f, iters=3)
    emit("dprt_impl/pallas_interp/N251", us,
         "python-interpret mode (correctness path; perf on real TPU)")

    # batched service throughput (the FPGA-coprocessor comparison point,
    # Sec. V-B: CPU ~1.48ms/image for the adds alone)
    fb = jnp.asarray(rng.integers(0, 256, (16, n, n)), jnp.int32)
    from repro.core.dprt import dprt_batched
    us = time_jax(jax.jit(lambda x: dprt_batched(x, method="horner")), fb,
                  iters=3)
    emit("dprt_impl/batched16/N251", us,
         f"imgs_per_s={16 / (us / 1e6):.1f}")


if __name__ == "__main__":
    main()
