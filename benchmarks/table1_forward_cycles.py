"""Paper Table I: forward-DPRT clock-cycle models, validated against the
quoted N=251 values, plus the measured cycle-model speedup ratios."""
from repro.core import pareto as P

from .common import emit


def main() -> None:
    for n in [31, 127, 251]:
        serial = P.cycles_serial(n)
        systolic = P.cycles_systolic(n)
        fd = P.cycles_fdprt(n)
        emit(f"table1/serial/N{n}", serial, "cycles")
        emit(f"table1/systolic/N{n}", systolic, "cycles")
        for h in [2, 16, 84]:
            if h <= (n - 1) // 2:
                c = P.cycles_sfdprt(n, h)
                emit(f"table1/sfdprt_H{h}/N{n}", c,
                     f"speedup_vs_systolic={systolic / c:.2f}")
        emit(f"table1/fdprt/N{n}", fd,
             f"speedup_vs_systolic={systolic / fd:.2f}")
    # paper-quoted pins
    assert P.cycles_fdprt(251) == 511
    assert P.cycles_systolic(251) == 63253
    emit("table1/pin/fdprt_251", 511, "matches_paper=true")


if __name__ == "__main__":
    main()
