"""Streamed-strip SFDPRT kernels and the direction-sharded collectives.

Three questions, answered with committed rows:

1. What does in-launch streaming cost when you DON'T need it?
   ``stream/dprt_n1021_stream`` vs ``stream/dprt_n1021_whole``: the
   N=1021 single image fits the whole-image kernel, so the streamed
   kernel's VMEM-scratch accumulation + final flush is pure overhead --
   the acceptance bound is 1.15x.
2. Does the giant-N geometry actually run?  ``stream/roundtrip_n2053_
   stream``: N=2053 forward + inverse through ONE pallas_call each
   (min-of-1: a multi-second deterministic row, noise is compile-shaped
   not scheduler-shaped).
3. Do the direction-sharded collectives beat the all-directions psum
   assembly?  ``sharded_stream/assembly_{psum8,dirsharded8}``: the
   assembly collective itself, isolated on realistic per-shard
   ``(B, N+1, N)`` int32 partials through the production
   ``_reduce_partial`` helper -- old layout (psum replicates the full
   output to every device, 8x the bytes written) vs new (psum_scatter,
   each device keeps only its direction shard).  The full forced-host
   round trip is compute-dominated (the per-shard kernels dwarf either
   collective, so psum-vs-scatter is a coin flip end to end on shared
   memory); the isolated collective is where the layout's byte savings
   are measurable on this host, and the committed speedup is what real
   multi-host wires amplify.  ``sharded_stream/roundtrip_dirsharded8``
   additionally times the default-layout round trip end to end, and
   the subprocess asserts BOTH layouts round-trip bit-exactly first.

The sharded rows run in a fresh ``--xla_force_host_platform_device_
count=8`` subprocess (same pattern and SKIP semantics as
``bench_dprt_sharded``; rows carry ``devices=8`` so the guard skips
them where the mesh cannot be reproduced).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from .common import emit, time_jax

N_STREAM = 1021
N_GIANT = 2053
# N+1 = 312 = 8*39: the direction shards divide the 8-device axis with
# no padding, so the assembly comparison is pure collective, not pad copy
N_SHARDED = 311
BATCH = 16
DEVICES = 8

_SUBPROC = r"""
import json, time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.distributed import (_reduce_partial, _shard_map,
                                    dprt_sharded_pallas,
                                    idprt_sharded_pallas)

n, batch, devs = %(n)d, %(batch)d, 8
mesh = jax.make_mesh((devs,), ("model",))
rng = np.random.default_rng(0)
fb = jnp.asarray(rng.integers(0, 256, (batch, n, n)), jnp.int32)

def roundtrip(reduce):
    def rt(x):
        r = dprt_sharded_pallas(x, mesh, reduce=reduce)
        return idprt_sharded_pallas(r, mesh, reduce=reduce)
    return jax.jit(rt)

# functional gate: BOTH layouts must round-trip bit-exactly
dirsharded = roundtrip("psum_scatter")
assert (np.asarray(roundtrip("psum")(fb)) == np.asarray(fb)).all()
assert (np.asarray(dirsharded(fb)) == np.asarray(fb)).all()

# the assembly collective, isolated: realistic (B, N+1, N) int32
# per-shard partials through the production _reduce_partial helper
part = jnp.asarray(rng.integers(0, 1 << 20, (batch, n + 1, n)), jnp.int32)

def assembly(reduce):
    def local(p):
        p = p + jax.lax.axis_index("model")  # distinct per-device partials
        return _reduce_partial(p, "model", devs, n + 1, n + 1, reduce)
    row = None if reduce == "psum" else "model"
    return jax.jit(_shard_map(local, mesh, in_specs=P(None, None, None),
                              out_specs=P(None, row, None)))

fns = {"psum": assembly("psum"), "dirsharded": assembly("psum_scatter")}

def percall_min(fn, *args, iters=20):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6

# alternate the two layouts (3 rounds) so load noise hits both equally
rows = {"psum": [], "dirsharded": []}
for _ in range(3):
    for k, f in fns.items():
        rows[k].append(percall_min(f, part, iters=30))
rows = {k: min(v) for k, v in rows.items()}
rows["roundtrip"] = percall_min(dirsharded, fb, iters=10)
print("BENCH_JSON:" + json.dumps(rows))
"""


def _local_rows() -> None:
    import jax.numpy as jnp
    from repro.core.plan import get_plan

    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.integers(0, 256, (N_STREAM, N_STREAM)), jnp.int32)
    whole = get_plan(f.shape, f.dtype, "pallas")
    stream = get_plan(f.shape, f.dtype, "pallas", stream_rows=256)
    fw = jax.jit(whole.forward)
    fs = jax.jit(stream.forward)
    assert (np.asarray(fw(f)) == np.asarray(fs(f))).all()
    # alternate so load noise hits both kernels equally
    tw = time_jax(fw, f, iters=10, stat="min")
    ts = time_jax(fs, f, iters=10, stat="min")
    tw = min(tw, time_jax(fw, f, iters=10, stat="min"))
    ts = min(ts, time_jax(fs, f, iters=10, stat="min"))
    emit(f"stream/dprt_n{N_STREAM}_whole", tw,
         "whole-image fused kernel (single pallas_call)",
         method="pallas", n=N_STREAM, batch=1)
    emit(f"stream/dprt_n{N_STREAM}_stream", ts,
         f"streamed strips, ONE launch; vs_whole=x{ts / tw:.2f} "
         f"(acceptance <= 1.15)",
         method="pallas", n=N_STREAM, batch=1)

    g = jnp.asarray(rng.integers(0, 256, (N_GIANT, N_GIANT)), jnp.int32)
    plan = get_plan(g.shape, g.dtype, "pallas", stream_rows=256)

    def roundtrip(x):
        return plan.inverse(plan.forward(x))

    rt = jax.jit(roundtrip)
    assert (np.asarray(rt(g)) == np.asarray(g)).all()  # also the warmup
    t0 = time.perf_counter()
    jax.block_until_ready(rt(g))
    emit(f"stream/roundtrip_n{N_GIANT}_stream",
         (time.perf_counter() - t0) * 1e6,
         "giant-N streamed forward+inverse, one pallas_call each "
         "(min-of-1: deterministic multi-second row)",
         method="pallas", n=N_GIANT, batch=1, guard_tol=2.0)


def _sharded_rows() -> None:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    code = _SUBPROC % {"n": N_SHARDED, "batch": BATCH}
    try:
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, cwd=repo,
                           timeout=1800, env=env)
    except (OSError, subprocess.TimeoutExpired) as e:
        print(f"# skip sharded_stream rows: subprocess failed ({e})",
              file=sys.stderr)
        return
    if r.returncode != 0:
        print(f"# skip sharded_stream rows: subprocess exited "
              f"{r.returncode}\n"
              f"# {r.stderr.strip().splitlines()[-1] if r.stderr else ''}",
              file=sys.stderr)
        return
    payload = next((line[len("BENCH_JSON:"):]
                    for line in r.stdout.splitlines()
                    if line.startswith("BENCH_JSON:")), None)
    if payload is None:
        print("# skip sharded_stream rows: no payload from subprocess",
              file=sys.stderr)
        return
    t = json.loads(payload)
    psum, dirs = t["psum"], t["dirsharded"]
    emit(f"sharded_stream/assembly_psum{DEVICES}/N{N_SHARDED}", psum,
         f"B={BATCH} all-directions psum assembly: full (N+1,N) output "
         f"replicated to every device (old layout)",
         method="sharded_pallas", n=N_SHARDED, batch=BATCH, devices=DEVICES)
    emit(f"sharded_stream/assembly_dirsharded{DEVICES}/N{N_SHARDED}", dirs,
         f"B={BATCH} direction-sharded psum_scatter: each device keeps "
         f"its shard; speedup_vs_psum={psum / dirs:.2f}",
         method="sharded_pallas", n=N_SHARDED, batch=BATCH, devices=DEVICES)
    emit(f"sharded_stream/roundtrip_dirsharded{DEVICES}/N{N_SHARDED}",
         t["roundtrip"],
         f"B={BATCH} default-layout round trip (direction-sharded forward, "
         f"inverse consuming shards in place; both layouts asserted exact)",
         method="sharded_pallas", n=N_SHARDED, batch=BATCH, devices=DEVICES)


def main() -> None:
    _local_rows()
    if jax.default_backend() != "cpu":
        print("# skip sharded_stream rows: forced-host mesh bench is "
              f"CPU-only (current backend: {jax.default_backend()})",
              file=sys.stderr)
        return
    _sharded_rows()


if __name__ == "__main__":
    main()
