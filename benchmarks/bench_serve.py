"""Serving-tier rows: dynamic batching + the persistent AOT cache.

Two claims gate here (``serve/*`` rows in ``BENCH_dprt.json``):

* **Coalescing.**  ``serve/coalesced`` drives the async service
  (:class:`repro.launch.service.DPRTService`) with concurrent
  single-image requests that the batcher coalesces into the fused
  batched kernel; ``serve/seq_per_request`` is the same traffic served
  one image at a time (what a front-end without dynamic batching
  does).  At small geometries the per-call dispatch overhead dominates
  the kernel, which is exactly where a high-QPS image service lives --
  the coalesced path amortizes it across the batch.
* **Routing.**  ``serve/router_mixed`` drives the fault-tolerant
  multiplexer (:class:`repro.launch.router.ServiceRouter`) with traffic
  interleaving two geometries -- the production shape where one
  front-end owns every geometry -- and ``serve/router_overhead`` sends
  the exact single-geometry traffic of ``serve/coalesced`` through the
  router, so their ratio isolates what admission, deadline tracking and
  the retry seam cost on the happy path.
* **Process isolation.**  ``serve/pool_workers2`` serves the N=31
  traffic through a :class:`repro.launch.supervisor.WorkerPool` of two
  ``serve --jsonl`` subprocesses -- pricing the pipe transport, JSON
  payload codec and supervision protocol against the in-process
  ``serve/router_overhead`` row (on a single-core host the pool cannot
  win; the row exists so regressions in the wire path are caught).
* **Warm restarts.**  ``serve/aot_cold_compile`` times XLA compilation
  of a warm-size executable; ``serve/aot_warm_restore`` times
  restoring the same executable from its serialized blob
  (``import_executable``) -- the path a process restart takes through
  :class:`repro.radon.PersistentAOTCache`, skipping XLA entirely.

Wall-clock service numbers on shared single-core hosts are the
noisiest in the suite: every row is a min over several full passes
(the passes share one event loop via ``run_requests(repeats=)``, as a
real deployment would), responses are checked bit-exact against the
sequential baseline before anything is timed, and the rows carry loose
``guard_tol`` values -- the guard is here to catch a lost batching
path or a broken restore, not scheduler jitter.
"""
import json
import os
import subprocess
import sys
import tempfile
import textwrap
import time

import jax.numpy as jnp
import numpy as np

from repro import radon
from repro.checkpoint.store import save_blob
from repro.launch.router import ServiceRouter
from repro.launch.service import DPRTService
from repro.launch.supervisor import WorkerPool

from .common import emit

N = 31           # dispatch-overhead-bound geometry: where coalescing wins
N_SMALL = 13     # second routed geometry for the multiplexing row
MAX_BATCH = 16   # the B=16-equivalent load of the acceptance criterion
REQUESTS = 64
PASSES = 9


def main() -> None:
    svc = DPRTService((N, N), jnp.int32, max_batch=MAX_BATCH)
    svc.warmup()
    rng = np.random.default_rng(0)
    imgs = [rng.integers(0, 256, (N, N), dtype=np.int32)
            for _ in range(REQUESTS)]

    # correctness first: every coalesced response must equal the
    # per-request baseline bit-for-bit (this pass also warms both paths)
    ref, _ = svc.run_sequential(imgs)
    for got, want in zip(svc.run_requests(imgs, repeats=2), ref):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    seq_walls = []
    for _ in range(PASSES):
        seq_walls.append(sum(svc.run_sequential(imgs)[1]))
    svc.run_requests(imgs, repeats=PASSES)
    coal = min(svc.last_pass_walls) / REQUESTS
    seq = min(seq_walls) / REQUESTS
    emit(f"serve/coalesced/N{N}/b{MAX_BATCH}", 1e6 * coal,
         f"x_vs_seq={seq / coal:.2f} imgs_per_s={1 / coal:.0f}",
         kind="serve", variant="coalesced", method="auto", n=N,
         batch=MAX_BATCH, requests=REQUESTS, guard_tol=2.0)
    emit(f"serve/seq_per_request/N{N}/b{MAX_BATCH}", 1e6 * seq,
         "per-request baseline, no coalescing", kind="serve",
         variant="seq_per_request", method="auto", n=N, batch=MAX_BATCH,
         requests=REQUESTS, guard_tol=2.5)

    # the fault-tolerant router: mixed-geometry multiplexing, plus the
    # single-geometry overhead row against the direct service above
    router = ServiceRouter(max_batch=MAX_BATCH, queue_cap=REQUESTS,
                           max_inflight=2 * REQUESTS)
    router.prefill([{"n": N}, {"n": N_SMALL}])
    small = [rng.integers(0, 256, (N_SMALL, N_SMALL), dtype=np.int32)
             for _ in range(REQUESTS // 2)]
    mixed, want = [], []
    oracle = radon.DPRT((1, N_SMALL, N_SMALL), jnp.int32)
    for i in range(REQUESTS):
        if i % 2:
            mixed.append(({"n": N}, imgs[i]))
            want.append(np.asarray(ref[i]))
        else:
            img = small[i // 2]
            mixed.append(({"n": N_SMALL}, img))
            want.append(np.asarray(oracle(jnp.asarray(img[None])))[0])
    for got, exp in zip(router.run_requests(mixed, repeats=2), want):
        np.testing.assert_array_equal(np.asarray(got), exp)
    router.run_requests(mixed, repeats=PASSES)
    rmixed = min(router.last_pass_walls) / REQUESTS
    router.run_requests([({"n": N}, img) for img in imgs],
                        repeats=PASSES)
    rover = min(router.last_pass_walls) / REQUESTS
    assert router.verdict() == "OK", router.healthz()   # clean happy path
    emit(f"serve/router_mixed/N{N_SMALL}_{N}/b{MAX_BATCH}", 1e6 * rmixed,
         f"imgs_per_s={1 / rmixed:.0f} routes=2", kind="serve",
         variant="router_mixed", method="auto", n=N, batch=MAX_BATCH,
         requests=REQUESTS, guard_tol=2.5)
    emit(f"serve/router_overhead/N{N}/b{MAX_BATCH}", 1e6 * rover,
         f"x_vs_direct={rover / coal:.2f}", kind="serve",
         variant="router_overhead", method="auto", n=N, batch=MAX_BATCH,
         requests=REQUESTS, guard_tol=2.5)

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(repo, "src")}

    # the supervised multi-process pool: the same N=31 traffic served
    # by two router subprocesses over pipes.  On a single-core host the
    # pool cannot beat the in-process router (same silicon plus
    # serialize/fork overhead) -- the row prices process isolation and
    # the supervision protocol, it does not claim a speedup here.
    with tempfile.TemporaryDirectory() as d:
        pool = WorkerPool(2, aot_dir=d, manifest=[{"n": N}],
                          max_batch=MAX_BATCH,
                          pending_cap=4 * REQUESTS, env=env)
        try:
            pool.start()
            if not pool.wait_ready(600.0):
                raise TimeoutError("pool workers never became ready")
            futs = [pool.submit({"n": N}, img) for img in imgs]
            for fut, want in zip(futs, ref):          # bit-exact first
                np.testing.assert_array_equal(
                    np.asarray(fut.result(timeout=300)),
                    np.asarray(want))
            pool_walls = []
            for _ in range(3):
                t0 = time.perf_counter()
                futs = [pool.submit({"n": N}, img) for img in imgs]
                for fut in futs:
                    fut.result(timeout=300)
                pool_walls.append(time.perf_counter() - t0)
            ppool = min(pool_walls) / REQUESTS
            assert pool.verdict() == "OK", pool.healthz()
            emit(f"serve/pool_workers2/N{N}/b{MAX_BATCH}", 1e6 * ppool,
                 f"x_vs_router={ppool / rover:.2f} workers=2 "
                 f"imgs_per_s={1 / ppool:.0f}", kind="serve",
                 variant="pool_workers2", method="auto", n=N,
                 batch=MAX_BATCH, requests=REQUESTS, guard_tol=3.0)
        except Exception as e:
            print(f"# serve/pool_workers2: skipped: {e}",
                  file=sys.stderr)
        finally:
            pool.drain()

    # persistent AOT: cold start vs warm restart, each in a FRESH
    # process -- in-process re-compiles hit jax's lowering caches and
    # would flatter the "cold" number.  The warm child also asserts the
    # compile counters: a restore must take ZERO traces.
    with tempfile.TemporaryDirectory() as d:
        op = radon.DPRT((MAX_BATCH, N, N), jnp.int32)
        save_blob(d, op.cache_token(), op.export_executable(),
                  meta={"fingerprint": radon.aot_fingerprint()})
        child = textwrap.dedent(f"""
            import json, sys, time
            import jax.numpy as jnp
            from repro import radon
            op = radon.DPRT(({MAX_BATCH}, {N}, {N}), jnp.int32)
            mode = sys.argv[1]
            t0 = time.perf_counter()
            if mode == "cold":
                op.compile()
            else:
                cache = radon.PersistentAOTCache({d!r})
                cache.get_or_compile(op)
                assert cache.hits == 1, cache.stats()
            dt = time.perf_counter() - t0
            want = 1 if mode == "cold" else 0
            assert radon.trace_count() == want, radon.trace_counts()
            print(json.dumps({{"s": dt}}))
        """)

        def restart(mode):
            out = subprocess.run([sys.executable, "-c", child, mode],
                                 env=env, capture_output=True, text=True,
                                 timeout=300)
            if out.returncode != 0:
                print(f"# serve/aot_{mode}: subprocess failed: "
                      f"{out.stderr.strip()[-200:]}", file=sys.stderr)
                return None
            return json.loads(out.stdout.strip().splitlines()[-1])["s"]

        cold, warm = restart("cold"), restart("warm")
    if cold is not None and warm is not None:
        emit(f"serve/aot_cold_compile/N{N}/b{MAX_BATCH}", 1e6 * cold,
             f"x_vs_restore={cold / warm:.1f}", kind="serve",
             variant="aot_cold_compile", method="auto", n=N,
             batch=MAX_BATCH, guard_tol=2.5)
        emit(f"serve/aot_warm_restore/N{N}/b{MAX_BATCH}", 1e6 * warm,
             "fresh-process restore: deserialize only, zero traces, "
             "no XLA compilation", kind="serve",
             variant="aot_warm_restore", method="auto", n=N,
             batch=MAX_BATCH, guard_tol=2.5)


if __name__ == "__main__":
    main()
