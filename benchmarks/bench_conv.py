"""Projection-domain pipeline shoot-out: staged vs fused conv/DFT.

The paper's application claim (Sec. I/VI) is exact fixed-point
convolution *through* the DPRT.  These rows gate this repo's fused
projection-domain pipeline -- ``transform -> per-direction 1-D conv ->
inverse`` as ONE kernel launch with the projections resident in
VMEM/registers -- against the staged path (separate forward, circulant
1-D stage, inverse launches):

* ``conv/circ_staged``        -- the pre-pipeline default: staged stages
  on the ``horner`` backend (what ``circ_conv2d_dprt`` dispatched before
  the pipeline landed).
* ``conv/circ_staged_pallas`` -- the strongest staged configuration:
  separate fused-kernel launches + the XLA circulant einsum.
* ``conv/circ_fused``         -- today's default: the fused pipeline
  (``method="auto"`` resolves the pipeline-capable Pallas backend).
* ``dft/dft2_*``              -- the slice-theorem 2-D DFT with its
  exact integer stage staged (horner) vs fused (one kernel launch).

All timings are min-of-20 (CPU-interpret numbers on shared hosts are
noisy; the min is the robust statistic the acceptance gates use), and
every variant is checked bit-exact against the staged path before it is
timed.  ``python -m benchmarks.run`` folds these rows into
``BENCH_dprt.json``; ``--check`` regresses against them.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.conv import (circ_conv2d_dprt, circ_conv2d_fft,
                             prime_vs_pow2_padding)
from repro.core.dft import dft2_via_dprt, dft2_via_dprt_batched

from .common import emit, time_jax

SIZES = (61, 251)
BATCH = 16
ITERS = 20


def _conv_rows(n: int, batch: int, f, g, tag: str = None) -> None:
    tag = tag or f"N{n}/b{batch}"
    variants = [
        ("circ_staged", dict(method="horner", fuse=False), "horner"),
        ("circ_staged_pallas", dict(fuse=False), "auto"),
        ("circ_fused", dict(), "auto"),
    ]
    fns = {name: jax.jit(lambda x, y, kw=kw: circ_conv2d_dprt(x, y, **kw))
           for name, kw, _ in variants}
    base = np.asarray(fns["circ_staged"](f, g))
    times = {}
    for name, _, _ in variants:
        np.testing.assert_array_equal(np.asarray(fns[name](f, g)), base)
        times[name] = time_jax(fns[name], f, g, iters=ITERS, stat="min")
    for name, _, method in variants:
        us = times[name]
        speed = times["circ_staged"] / us
        note = (f"exact_int=True x_vs_staged={speed:.2f}"
                + (f" imgs_per_s={batch / (us / 1e6):.1f}"
                   if batch > 1 else ""))
        # comparison anchors (staged rows) gate looser than the fused
        # hot path: the minute-long staged runs swing hardest with host
        # load, and the guard's job is protecting the FUSED rows
        tol = None if n < 251 else (2.0 if name == "circ_fused" else 2.5)
        emit(f"conv/{name}/{tag}", us, note, kind="circ",
             variant=name.replace("circ_", ""), method=method,
             n=n, batch=batch, fused=name == "circ_fused",
             **({"guard_tol": tol} if tol else {}))


def _dft_rows(n: int, batch: int, f) -> None:
    tag = f"N{n}/b{batch}"
    if batch == 1:
        fns = {
            "dft2_staged": jax.jit(lambda x: dft2_via_dprt(
                x, method="horner")),
            "dft2_fused": jax.jit(lambda x: dft2_via_dprt(x)),
        }
    else:
        fns = {
            "dft2_staged": jax.jit(lambda x: dft2_via_dprt_batched(
                x, method="horner")),
            "dft2_fused": jax.jit(lambda x: dft2_via_dprt_batched(x)),
        }
    # the exact integer stage must be bit-identical across backends, so
    # the float spectra match exactly too
    np.testing.assert_array_equal(np.asarray(fns["dft2_staged"](f)),
                                  np.asarray(fns["dft2_fused"](f)))
    t_staged = time_jax(fns["dft2_staged"], f, iters=ITERS, stat="min")
    t_fused = time_jax(fns["dft2_fused"], f, iters=ITERS, stat="min")
    anchor = {"guard_tol": 2.5} if n >= 251 else {}
    hot = {"guard_tol": 2.0} if n >= 251 else {}
    emit(f"dft/dft2_staged/{tag}", t_staged, "integer stage on horner",
         kind="dft2", variant="staged", method="horner", n=n, batch=batch,
         fused=False, **anchor)
    emit(f"dft/dft2_fused/{tag}", t_fused,
         f"one-launch integer stage x_vs_staged={t_staged / t_fused:.2f}",
         kind="dft2", variant="fused", method="auto", n=n, batch=batch,
         fused=True, **hot)


def main() -> None:
    rng = np.random.default_rng(0)
    for n in SIZES:
        f = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)
        g = jnp.asarray(rng.integers(0, 16, (n, n)), jnp.int32)
        fb = jnp.asarray(rng.integers(0, 256, (BATCH, n, n)), jnp.int32)
        _conv_rows(n, 1, f, g)
        _conv_rows(n, BATCH, fb, g)
        # per-image kernels (e.g. spatially varying PSFs): the staged
        # path cannot amortize its circulants across the batch here, so
        # this is the batched workload fusion wins outright
        gb = jnp.asarray(rng.integers(0, 16, (BATCH, n, n)), jnp.int32)
        _conv_rows(n, BATCH, fb, gb, tag=f"N{n}/b{BATCH}x{BATCH}")
        _dft_rows(n, 1, f)
        _dft_rows(n, BATCH, fb)

    # the float-FFT contrast row (the approach the paper's hardware
    # avoids) and the padding-overhead quantification, as before
    n = 251
    f = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 16, (n, n)), jnp.int32)
    ff = jax.jit(circ_conv2d_fft)
    emit(f"conv/fft/N{n}", time_jax(ff, f, g),
         "float path; DPRT route is exact by construction")
    pad = prime_vs_pow2_padding(251, 16)
    emit("conv/pad/prime_overhead_pct",
         100 * (pad["prime_overhead"] - 1), f"pow2={pad['pow2_pad']}")


if __name__ == "__main__":
    main()
