"""The paper's application claim: exact fixed-point convolution via DPRT
vs floating-point FFT -- wall time and exactness on this host."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.conv import (circ_conv2d_dprt, circ_conv2d_fft,
                             prime_vs_pow2_padding)

from .common import emit, time_jax


def main() -> None:
    rng = np.random.default_rng(0)
    for n in [31, 127, 251]:
        f = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)
        g = jnp.asarray(rng.integers(0, 16, (n, n)), jnp.int32)
        dp = jax.jit(circ_conv2d_dprt)
        ff = jax.jit(circ_conv2d_fft)
        us_d = time_jax(dp, f, g)
        us_f = time_jax(ff, f, g)
        exact = bool(np.allclose(np.asarray(dp(f, g), dtype=np.float64),
                                 np.asarray(ff(f, g), dtype=np.float64),
                                 atol=0.5))
        emit(f"conv/dprt/N{n}", us_d, f"exact_int=True")
        emit(f"conv/fft/N{n}", us_f, f"matches_after_round={exact}")
    pad = prime_vs_pow2_padding(251, 16)
    emit("conv/pad/prime_overhead_pct",
         100 * (pad["prime_overhead"] - 1), f"pow2={pad['pow2_pad']}")


if __name__ == "__main__":
    main()
