"""Paper Figs. 19/20: the Pareto front of running time vs resources over
strip height H, plus our TPU-analog front (VMEM bytes / VPU ops)."""
from repro.core import pareto as P

from .common import emit


def main() -> None:
    n, b = 251, 8
    front = P.pareto_front(n)
    emit("fig19/front_size", len(front), f"H=2..{(n - 1) // 2}")
    pts = P.pareto_points(n, b)
    for p in pts[:: max(1, len(pts) // 12)]:
        emit(f"fig19/H{p['h']}/cycles", p["cycles"], f"ff={p['ff']}")
        emit(f"fig20/H{p['h']}/cycles", p["cycles"], f"fa={p['fa']}")
    # dominance check: every listed point beats the systolic reference in
    # cycles once its resources pass the systolic point (paper Sec. V-B)
    systolic_c = P.cycles_systolic(n)
    faster = [p for p in pts if p["cycles"] * 36 <= systolic_c]
    emit("fig19/first_36x_H", faster[0]["h"] if faster else -1,
         "paper quotes H=84 at ~36x")

    # TPU-analog Pareto: (H, M) -> VMEM bytes vs total VPU ops
    for h in [2, 4, 8, 16, 32, 64, 128, 251]:
        c = P.tpu_strip_cost(n, h, 8)
        emit(f"fig19/tpu_H{h}_M8/vmem", c.vmem_bytes,
             f"vpu_ops={c.vpu_ops}")


if __name__ == "__main__":
    main()
