"""Paper Fig. 17: running time vs N.  Cycle models for the hardware
variants + *measured* wall-times of our JAX implementations on this host
(the shape of the curves is the reproduction; absolute units differ)."""
import jax.numpy as jnp
import numpy as np

from repro import radon
from repro.core import pareto as P

from .common import emit, time_jax


def main() -> None:
    for n in [31, 61, 127, 251]:
        emit(f"fig17/model/serial/N{n}", P.cycles_serial(n), "cycles")
        emit(f"fig17/model/systolic/N{n}", P.cycles_systolic(n), "cycles")
        emit(f"fig17/model/sfdprt_H2/N{n}", P.cycles_sfdprt(n, 2), "cycles")
        emit(f"fig17/model/sfdprt_H16/N{n}", P.cycles_sfdprt(n, 16),
             "cycles")
        emit(f"fig17/model/fdprt/N{n}", P.cycles_fdprt(n), "cycles")

    rng = np.random.default_rng(0)
    for n in [31, 127, 251]:
        f = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)
        for method, kw in [("gather", {}), ("horner", {}),
                           ("strips", {"strip_rows": 16})]:
            op = radon.DPRT((n, n), jnp.int32, method, **kw)
            us = time_jax(op, f)
            emit(f"fig17/measured/{method}/N{n}", us, "us_wall_cpu")


if __name__ == "__main__":
    main()
