"""Perf regression guard: fresh DPRT benchmark vs the committed baseline.

Compares a fresh ``bench_dprt_impl`` run against the repo-root
``BENCH_dprt.json`` artifact (written by ``python -m benchmarks.run``)
and exits nonzero when any matched row slows down by more than the
tolerance.  Workflow:

    python -m benchmarks.check_regression            # guard only
    python -m benchmarks.check_regression --tol 1.3  # tighter gate
    python -m benchmarks.run --check                 # full suite, compare
                                                     # INSTEAD of rewriting
    python -m benchmarks.run                         # rewrite the baseline
                                                     # (after accepting perf)

Rows are matched by their ``name`` field.  Rows new in this run (e.g.
``dprt_impl/auto/...`` before the baseline was regenerated) fall back to
the equivalent baseline row when one exists (``auto`` resolves to the
fused pallas backend, so it is gated against ``pallas_fused``) and are
otherwise reported as NEW without failing the guard.  A baseline
recorded on a different jax backend (cpu vs tpu) is incomparable: the
guard reports SKIPPED and passes.  Likewise, a baseline row whose
backend is unavailable in the current process -- e.g. the sharded mesh
rows (``devices`` metadata) on a host that cannot spawn the forced-host
8-device subprocess -- is SKIPPED with a warning, never failed.

The default tolerance is deliberately loose (1.5x): CPU-interpret
timings on shared machines are noisy, and the guard's job is to catch
real regressions (an accidental de-fusing, a lost batching path), not
scheduler jitter.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import common

DEFAULT_TOL = 1.5

# fresh-row name -> baseline-row name, used when the fresh name is not
# in the baseline yet.  "auto" resolves to the fused pallas backend for
# prime images, so its gate is the pallas_fused baseline row.
ALIASES = [("/auto/", "/pallas_fused/")]


def load_baseline(path: str) -> dict:
    with open(path) as fh:
        artifact = json.load(fh)
    rows = {r["name"]: r for r in artifact.get("rows", [])}
    return {"backend": artifact.get("backend"), "rows": rows}


def _baseline_row(baseline_rows: dict, name: str):
    if name in baseline_rows:
        return baseline_rows[name], name
    for frag, repl in ALIASES:
        alias = name.replace(frag, repl)
        if alias != name and alias in baseline_rows:
            return baseline_rows[alias], alias
    return None, None


def _unavailable_reason(row: dict):
    """Why a baseline row cannot be (re)measured in this process, or
    ``None`` if it should have been.  Mesh rows (``devices`` metadata)
    need either enough visible devices or a CPU host that can force
    them in a subprocess; rows naming an unregistered backend cannot
    run at all."""
    import jax
    method = row.get("method")
    if method and method != "auto":
        try:
            from repro.core.plan import available_backends
            if method not in available_backends():
                return f"backend {method!r} not registered"
        except ImportError:  # guard must stay runnable standalone
            pass
    devices = int(row.get("devices", 1))
    if devices > len(jax.devices()) and jax.default_backend() != "cpu":
        return (f"needs {devices} devices, {len(jax.devices())} visible "
                f"(non-CPU backend cannot force host devices)")
    if devices > 1:
        # measurable via the forced-host subprocess bench -- but that
        # bench warns and emits nothing when the subprocess fails, so a
        # missing mesh row is an environment limitation, not a perf
        # regression
        return f"forced-host {devices}-device subprocess unavailable here"
    return None


def compare(baseline: dict, fresh_rows: list, tol: float) -> tuple:
    """Returns (report_lines, regressions).  A regression is a matched
    row whose fresh/baseline time ratio exceeds ``tol``.  Baseline rows
    that were not measured AND cannot run in the current process (e.g.
    sharded mesh rows on a host without the forced-device subprocess)
    are reported as SKIPPED -- a warning, never a failure."""
    lines, regressions = [], []
    seen = set()
    for row in fresh_rows:
        base, matched_name = _baseline_row(baseline["rows"], row["name"])
        if base is None:
            lines.append(f"NEW      {row['name']}: "
                         f"{row['us_per_call']:.0f}us (no baseline row)")
            continue
        seen.add(matched_name)
        ratio = row["us_per_call"] / base["us_per_call"]
        # a baseline row may carry its own tolerance (guard_tol): the
        # minute-long staged comparison anchors swing +/-50% with host
        # load on shared CPU machines, so they gate looser than the
        # fused hot-path rows the guard exists to protect
        row_tol = float(base.get("guard_tol") or tol)
        status = "REGRESS" if ratio > row_tol else "ok"
        via = "" if matched_name == row["name"] else f" (vs {matched_name})"
        lines.append(f"{status:8s} {row['name']}{via}: "
                     f"{row['us_per_call']:.0f}us vs "
                     f"{base['us_per_call']:.0f}us  x{ratio:.2f}"
                     + (f" (tol x{row_tol})" if row_tol != tol else ""))
        if ratio > row_tol:
            regressions.append((row["name"], ratio))
    for name in sorted(set(baseline["rows"]) - seen):
        reason = _unavailable_reason(baseline["rows"][name])
        if reason is not None:
            lines.append(f"SKIPPED  {name}: {reason}")
        else:
            lines.append(f"MISSING  {name}: baseline row not measured "
                         f"this run")
    return lines, regressions


def run_guard(fresh_rows: list, baseline_path: str = None,
              tol: float = DEFAULT_TOL, prefixes=None) -> int:
    """Compare ``fresh_rows`` against the committed baseline; 0 = pass.

    ``prefixes`` restricts the comparison scope (a ``run --only`` pass
    measures one prefix family; out-of-scope baseline rows must not be
    reported MISSING)."""
    import jax
    baseline_path = baseline_path or common.BENCH_DPRT_PATH
    try:
        baseline = load_baseline(baseline_path)
    except (OSError, json.JSONDecodeError) as e:
        print(f"# no usable baseline at {baseline_path}: {e}",
              file=sys.stderr)
        return 0
    if prefixes is not None:
        baseline["rows"] = {k: v for k, v in baseline["rows"].items()
                            if k.startswith(tuple(prefixes))}
    if baseline["backend"] != jax.default_backend():
        print(f"# SKIPPED: baseline backend {baseline['backend']!r} != "
              f"current {jax.default_backend()!r} (incomparable timings)",
              file=sys.stderr)
        return 0
    lines, regressions = compare(baseline, fresh_rows, tol)
    for line in lines:
        print(f"# {line}", file=sys.stderr)
    if regressions:
        worst = max(regressions, key=lambda x: x[1])
        print(f"# FAIL: {len(regressions)} row(s) beyond x{tol} tolerance; "
              f"worst {worst[0]} at x{worst[1]:.2f}", file=sys.stderr)
        return 1
    print(f"# PASS: {sum(1 for l in lines if l.startswith('ok'))} rows "
          f"within x{tol} of baseline", file=sys.stderr)
    return 0


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL,
                    help=f"max fresh/baseline ratio (default {DEFAULT_TOL})")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (default: repo BENCH_dprt.json)")
    args = ap.parse_args(argv)

    from . import (bench_conv, bench_dprt_impl, bench_dprt_sharded,
                   bench_recon, bench_serve, bench_stream)
    start = len(common.ROWS)
    print("name,us_per_call,derived")
    bench_dprt_impl.main()
    bench_conv.main()           # staged-vs-fused projection pipelines
    bench_dprt_sharded.main()   # warns + emits nothing where unavailable
    bench_stream.main()         # streamed-strip + direction-sharded rows
    bench_serve.main()          # dynamic batching + persistent AOT rows
    bench_recon.main()          # oracle-gated reconstruction solver rows
    fresh = [r for r in common.ROWS[start:]
             if r["name"].startswith(common.BENCH_PREFIXES)]
    raise SystemExit(run_guard(fresh, args.baseline, args.tol))


if __name__ == "__main__":
    main()
