"""Mesh-distributed DPRT shoot-out: legacy ``sharded`` (per-device
Horner scan + alignment gather) vs ``sharded_pallas`` (per-device fused
SFDPRT Pallas kernel, one pallas_call + one psum) at the paper's N=251.

Runs in a fresh subprocess with ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` (the main bench process must keep its single default
device), so the rows are measurable on any CPU host -- including CI and
1-device laptops.  Emitted rows carry ``devices=8`` so the regression
guard can SKIP them (with a warning, not a failure) in processes where
the mesh cannot be reproduced; see ``check_regression.py``.

Per-call times are the MIN over many alternating iterations: the mesh
path is collective-dominated and forced-host CPU timing noise is large,
so the minimum -- the deterministic floor -- is the robust estimator
for regression gating.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax

from .common import emit

N = 251
BATCH = 16
DEVICES = 8

_SUBPROC = r"""
import json, time
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import (dprt_sharded, dprt_sharded_pallas,
                                    dprt_batch_sharded)
from repro.core.plan import get_plan

n, batch = %(n)d, %(batch)d
mesh1 = jax.make_mesh((8,), ("model",))
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
f = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)
fb = jnp.asarray(rng.integers(0, 256, (batch, n, n)), jnp.int32)

def percall_min(fn, *args, iters=20):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6

rows = {}
legacy = jax.jit(lambda x: dprt_sharded(x, mesh1))
pallas = jax.jit(lambda x: dprt_sharded_pallas(x, mesh1))
assert (np.asarray(legacy(f)) == np.asarray(pallas(f))).all()
# alternate the two so load noise hits both equally
rows["sharded"] = percall_min(legacy, f)
rows["sharded_pallas"] = percall_min(pallas, f)
rows["sharded_2nd"] = percall_min(legacy, f)
rows["sharded_pallas_2nd"] = percall_min(pallas, f)

# batched: legacy = batch-only sharding (per-device horner lax.map);
# pallas = 2-D mesh, batch over data AND row strips over model, one
# fused kernel call per device shard
blegacy = jax.jit(lambda x: dprt_batch_sharded(x, mesh2))
bplan = get_plan(fb.shape, fb.dtype, "auto", mesh=mesh2)
assert bplan.method == "sharded_pallas", bplan.method
bpallas = jax.jit(bplan.forward)
assert (np.asarray(blegacy(fb)) == np.asarray(bpallas(fb))).all()
rows["batched_sharded"] = percall_min(blegacy, fb, iters=10)
rows["batched_sharded_pallas"] = percall_min(bpallas, fb, iters=10)
print("BENCH_JSON:" + json.dumps(rows))
"""


def main() -> None:
    if jax.default_backend() != "cpu":
        print("# skip sharded rows: forced-host mesh bench is CPU-only "
              f"(current backend: {jax.default_backend()})",
              file=sys.stderr)
        return
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    code = _SUBPROC % {"n": N, "batch": BATCH}
    try:
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, cwd=repo,
                           timeout=1800, env=env)
    except (OSError, subprocess.TimeoutExpired) as e:
        print(f"# skip sharded rows: subprocess failed ({e})",
              file=sys.stderr)
        return
    if r.returncode != 0:
        print(f"# skip sharded rows: subprocess exited {r.returncode}\n"
              f"# {r.stderr.strip().splitlines()[-1] if r.stderr else ''}",
              file=sys.stderr)
        return
    payload = next((line[len("BENCH_JSON:"):]
                    for line in r.stdout.splitlines()
                    if line.startswith("BENCH_JSON:")), None)
    if payload is None:
        print("# skip sharded rows: no payload from subprocess",
              file=sys.stderr)
        return
    t = json.loads(payload)
    # the alternating pairs guard against one-sided load spikes: keep
    # the min of the two passes per backend
    leg = min(t["sharded"], t["sharded_2nd"])
    pal = min(t["sharded_pallas"], t["sharded_pallas_2nd"])
    emit(f"dprt_impl/sharded{DEVICES}/N{N}", leg,
         "legacy per-device horner + psum (forced-host 8-device mesh)",
         method="sharded", n=N, batch=1, devices=DEVICES)
    emit(f"dprt_impl/sharded_pallas{DEVICES}/N{N}", pal,
         f"per-shard fused kernel + psum speedup_vs_sharded={leg/pal:.2f}",
         method="sharded_pallas", n=N, batch=1, devices=DEVICES)
    bleg, bpal = t["batched_sharded"], t["batched_sharded_pallas"]
    emit(f"dprt_impl/batched{BATCH}_sharded{DEVICES}/N{N}", bleg,
         f"imgs_per_s={BATCH / (bleg / 1e6):.1f} batch-only data sharding",
         method="sharded", n=N, batch=BATCH, devices=DEVICES)
    emit(f"dprt_impl/batched{BATCH}_sharded_pallas{DEVICES}/N{N}", bpal,
         f"imgs_per_s={BATCH / (bpal / 1e6):.1f} 2-D mesh data x model "
         f"speedup_vs_sharded={bleg/bpal:.2f}",
         method="sharded_pallas", n=N, batch=BATCH, devices=DEVICES)


if __name__ == "__main__":
    main()
