"""Reconstruction-subsystem rows: ``recon/*`` in ``BENCH_dprt.json``.

Three claims gate here:

* **Exactness before speed.**  At N=13 the masked-direction CG solution
  is asserted against the dense least-squares oracle (and the unmasked
  Sherman-Morrison path against the exact inverse) before anything is
  timed -- a fast wrong solver must fail the bench, not set a baseline.
* **The closed form is transform-rate.**  ``recon/sherman/n251`` times
  the non-iterative unmasked solve: one exact inverse plus a rank-1
  correction, so it must stay within a small factor of the raw inverse
  transform.
* **Iterative cost = launches x iterations.**  ``recon/cg_masked/*``
  rows run a FIXED iteration count (``tol=0`` never converges early),
  so the timing measures the fused normal-equation launch path --
  single-image and B=4 batched -- deterministically, not a
  convergence-dependent iteration count.

Wall-clock noise policy matches the serve rows: ``time_jax`` min-of-N
statistic plus loose per-row ``guard_tol`` -- the guard catches a lost
fused pipeline (CG falling back to staged launches), not scheduler
jitter.
"""
import jax.numpy as jnp
import numpy as np

from repro import radon

from .common import emit, time_jax

N_SMALL = 13      # oracle-checkable geometry
N_BIG = 251       # prime serving geometry for the timing rows
BATCH = 4
MAXITER = 10      # fixed CG iteration count for deterministic timing


def _oracle_gate() -> None:
    """Fail loudly (raise) if the solvers stop matching the oracles."""
    rng = np.random.default_rng(0)
    x = rng.integers(0, 64, (N_SMALL, N_SMALL)).astype(np.int32)
    op = radon.DPRT((N_SMALL, N_SMALL), jnp.int32)

    res = radon.solve(op, op(jnp.asarray(x)))
    assert int(res.iterations) == 0, "sherman path must not iterate"
    np.testing.assert_allclose(np.asarray(res.image), x, atol=1e-3)

    m = radon.MaskedDPRT(op, mask=radon.direction_mask(N_SMALL, [2, 7]))
    b = m(jnp.asarray(x, jnp.float32))
    A = np.asarray(m.as_matrix()).astype(np.float64)
    want, *_ = np.linalg.lstsq(A, np.asarray(b).ravel(), rcond=None)
    got = np.asarray(radon.solve(m, b, "cg", tol=1e-7,
                                 maxiter=300).image).ravel()
    np.testing.assert_allclose(got, want, rtol=1e-4,
                               atol=1e-4 * max(1.0, np.abs(want).max()))


def main() -> None:
    _oracle_gate()
    rng = np.random.default_rng(1)

    # -- recon/cg_masked/n13: the oracle-gated geometry ---------------------
    m13 = radon.MaskedDPRT(radon.DPRT((N_SMALL, N_SMALL), jnp.int32),
                           mask=radon.direction_mask(N_SMALL, [2, 7]))
    b13 = m13(jnp.asarray(rng.integers(0, 64, (N_SMALL, N_SMALL)),
                          jnp.float32))
    us = time_jax(lambda b: radon.solve(m13, b, "cg", tol=0.0,
                                        maxiter=MAXITER).image,
                  b13, warmup=2, iters=20, stat="min")
    emit(f"recon/cg_masked/n{N_SMALL}", us,
         f"{MAXITER} fixed CG iterations, oracle-gated", kind="recon",
         variant="cg_masked", method="auto", n=N_SMALL, batch=1,
         maxiter=MAXITER, guard_tol=2.0)

    # -- recon/sherman/n251: the non-iterative closed form ------------------
    op = radon.DPRT((N_BIG, N_BIG), jnp.int32)
    xb = jnp.asarray(rng.integers(0, 64, (N_BIG, N_BIG)), jnp.int32)
    rb = op(xb)
    inv_us = time_jax(lambda r: op.inverse(r), rb, warmup=2, iters=10,
                      stat="min")
    sh_us = time_jax(lambda r: radon.solve(op, r.astype(jnp.float32)).image,
                     rb, warmup=2, iters=10, stat="min")
    emit(f"recon/sherman/n{N_BIG}", sh_us,
         f"x_vs_inverse={sh_us / inv_us:.2f} (direct, 0 iterations)",
         kind="recon", variant="sherman", method="auto", n=N_BIG, batch=1,
         guard_tol=2.0)

    # -- recon/cg_masked/n251_b4: the batched fused normal launch -----------
    mb = radon.MaskedDPRT(radon.DPRT((BATCH, N_BIG, N_BIG), jnp.int32),
                          mask=radon.direction_mask(N_BIG, [5]))
    bb = mb(jnp.asarray(rng.integers(0, 64, (BATCH, N_BIG, N_BIG)),
                        jnp.float32))
    us = time_jax(lambda b: radon.solve(mb, b, "cg", tol=0.0,
                                        maxiter=MAXITER).image,
                  bb, warmup=2, iters=10, stat="min")
    emit(f"recon/cg_masked/n{N_BIG}_b{BATCH}", us,
         f"{MAXITER} fixed CG iterations, per-image "
         f"{us / BATCH:.0f}us", kind="recon", variant="cg_masked",
         method="auto", n=N_BIG, batch=BATCH, maxiter=MAXITER,
         guard_tol=2.0)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
