"""System-level harness: smoke-scale train-step and decode throughput for
representative architectures on this host (framework sanity, not TPU perf)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import Model
from repro.parallel.sharding import init_params

from .common import emit, time_jax


def main() -> None:
    rng = np.random.default_rng(0)
    for arch in ["tinyllama_1_1b", "qwen3_moe_235b_a22b", "mamba2_2_7b"]:
        cfg = get_smoke_config(arch)
        model = Model(cfg)
        params = init_params(model.specs(), jax.random.key(0), jnp.float32)
        b, s = 4, 64
        batch = {"tokens": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (b, s))),
                 "labels": jnp.asarray(
                     rng.integers(0, cfg.vocab_size, (b, s)))}
        step = jax.jit(jax.value_and_grad(
            lambda p: model.loss(p, batch)[0]))
        us = time_jax(lambda p: step(p)[0], params, iters=3)
        emit(f"lm/train_step/{arch}", us,
             f"tokens_per_s={b * s / (us / 1e6):.0f}")

        logits, cache = jax.jit(
            lambda p, bb: model.prefill(p, bb, max_len=s + 8))(
                params, {"tokens": batch["tokens"]})
        dec = jax.jit(model.decode_step)
        us = time_jax(lambda p: dec(p, cache, batch["tokens"][:, :1],
                                    jnp.int32(s))[0], params, iters=3)
        emit(f"lm/decode_step/{arch}", us,
             f"tok_per_s={b / (us / 1e6):.0f}")


if __name__ == "__main__":
    main()
