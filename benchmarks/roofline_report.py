"""§Roofline report: aggregates experiments/dryrun/*.json into the
per-(arch x shape x mesh) three-term table used in EXPERIMENTS.md."""
import glob
import json
import os

from .common import emit

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_OPT = os.path.join(_ROOT, "experiments", "dryrun_opt")
DRYRUN_DIR = _OPT if os.path.isdir(_OPT) and os.listdir(_OPT) else \
    os.path.join(_ROOT, "experiments", "dryrun")


def load_cells(mesh_filter=None):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    if mesh_filter:
        cells = [c for c in cells if c["mesh"] == mesh_filter]
    return cells


def main() -> None:
    cells = load_cells()
    if not cells:
        emit("roofline/no_dryrun_artifacts", 0,
             "run repro.launch.dryrun first")
        return
    ok = [c for c in cells if c["status"] == "ok"]
    err = [c for c in cells if c["status"] == "error"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    emit("roofline/cells_ok", len(ok), f"err={len(err)},skip={len(skipped)}")
    for c in ok:
        if c["mesh"] != "16x16":
            continue  # the roofline table is single-pod per assignment
        t = c["roofline"]
        name = f"roofline/{c['arch']}/{c['shape']}"
        emit(name, t["step_s_lower_bound"] * 1e6,
             f"dom={t['dominant']},comp={t['compute_s']:.2e},"
             f"mem={t['memory_s']:.2e},coll={t['collective_s']:.2e},"
             f"useful_ratio={c.get('useful_flops_ratio') and round(c['useful_flops_ratio'], 3)}")
    for c in err:
        emit(f"roofline/ERROR/{c['arch']}/{c['shape']}/{c['mesh']}", -1,
             c.get("error", "?")[:80])


if __name__ == "__main__":
    main()
