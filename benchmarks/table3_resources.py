"""Paper Tables III/IV: resource models (flip-flops, 1-bit adders, MUX,
RAM bits) at N=251, B=8, plus the TPU-analog VMEM/ops cost model."""
from repro.core import pareto as P

from .common import emit


def main() -> None:
    n, b = 251, 8
    emit("table3/systolic/ff", P.flipflops_systolic(n, b), "N=251,B=8")
    emit("table3/systolic/fa", P.adders_systolic(n, b), "")
    emit("table3/serial/fa", P.adders_serial(n, b), "single adder path")
    for h in [2, 16, 84]:
        emit(f"table3/sfdprt_H{h}/ff", P.flipflops_sfdprt(n, h, b), "")
        emit(f"table3/sfdprt_H{h}/fa", P.adders_sfdprt(n, h, b), "")
    emit("table3/fdprt/ff", P.flipflops_fdprt(n, b), "")
    emit("table3/fdprt/fa", P.adders_fdprt(n, b), "")
    # Table IV RAM totals
    ram_serial = n * n * b
    ram_systolic = n * (n + 1) * (b + 8)
    emit("table4/serial/ram_bits", ram_serial + 0, "paper=504,008+adders")
    emit("table4/systolic/ram_bits", ram_systolic, "paper cites 1,012,032"
         " incl. IO buffers")
    # paper pin: systolic total flip-flops = 516,096 (Fig. 19 square dot)
    assert P.flipflops_systolic(251, 8) == 516096
    emit("table3/pin/systolic_ff", 516096, "matches_paper=true")

    # TPU analog: VMEM working set + VPU ops for strip kernel tilings
    for h, m in [(8, 8), (16, 8), (16, 32), (32, 32)]:
        c = P.tpu_strip_cost(n, h, m)
        emit(f"table3/tpu_strip_H{h}_M{m}/vmem_bytes", c.vmem_bytes,
             f"vpu_ops={c.vpu_ops},ai={c.ai:.1f}")


if __name__ == "__main__":
    main()
