"""Paper Table II: inverse-DPRT clock-cycle models (B = 8 bits)."""
from repro.core import pareto as P

from .common import emit


def main() -> None:
    b = 8
    for n in [31, 127, 251]:
        emit(f"table2/isfdprt_H2/N{n}", P.cycles_isfdprt(n, 2, b), "cycles")
        emit(f"table2/isfdprt_H16/N{n}", P.cycles_isfdprt(n, 16, b),
             "cycles")
        emit(f"table2/isfdprt_HN/N{n}", P.cycles_isfdprt(n, n, b), "cycles")
        emit(f"table2/ifdprt/N{n}", P.cycles_ifdprt(n, b), "cycles")
    # iFDPRT(251): 2N + 3*ceil(log2 N) + B + 2 = 502 + 24 + 10 = 536
    assert P.cycles_ifdprt(251, 8) == 2 * 251 + 3 * 8 + 8 + 2
    emit("table2/pin/ifdprt_251", P.cycles_ifdprt(251, 8),
         "matches_formula=true")


if __name__ == "__main__":
    main()
