"""Production mesh construction (function, not constant: importing this
module must never touch jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 2, model: int = 4):
    """Small mesh over however many (host) devices are available."""
    n = len(jax.devices())
    if data * model > n:
        if n % 2 == 0 and n >= 4:
            data, model = 2, n // 2
        else:
            data, model = 1, n
    return jax.make_mesh((data, model), ("data", "model"))
