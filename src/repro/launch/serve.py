"""Serving launcher: batched LM decode or the DPRT image service.

``--mode lm``      prefill a batch of prompts then greedy-decode N tokens.
``--mode radon``   the paper's FPGA-coprocessor pattern as a TPU service:
                   batches of images in, DPRT (or DPRT-domain
                   convolution) out, batch sharded across the mesh.
``--mode service`` the async dynamic-batching front-end
                   (:mod:`repro.launch.service`): concurrent
                   single-image requests coalesced into the fused
                   batched kernel, with an optional persistent AOT
                   executable cache (``--aot-dir``) so restarts skip
                   XLA compilation, and a ``/healthz``-style stats
                   report (latency percentiles, batch occupancy, cache
                   and trace counters).

The radon service is built on the :mod:`repro.radon` operator API:
``--method`` resolves through the backend registry (any registered
backend plus ``auto``), arbitrary ``--n`` is accepted (non-prime sizes
are zero-embedded into the next prime and cropped back by the operator,
so the round trip stays bit-exact), and ``--warmup`` AOT-compiles the
forward/inverse executables before the timing loop (``op.compile()``,
cached per geometry), which together with the zero-leaf pytree plans
gives the zero-retrace steady state -- asserted by a retrace guard
around the timed section.  ``--strip-rows`` / ``--m-block`` /
``--stream-rows`` / ``--batch-impl`` / ``--block-batch`` plumb straight
into the operator.
``--mesh-shape D,M`` serves through a (data, model) device mesh:
``method=auto`` then resolves to the ``sharded_pallas`` backend (batch
shards over ``data``, row super-strips over ``model``; one fused kernel
call + one collective per device) and ``--warmup`` AOT-compiles the
sharded executables before the timing loop.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import radon
from repro.configs import get_config, get_smoke_config
from repro.configs.radon_251 import config as radon_config, \
    smoke_config as radon_smoke
from repro.core.plan import available_backends, backend_capabilities, \
    get_backend
from repro.data.synthetic import TokenStream, radon_images
from repro.launch.mesh import make_local_mesh
from repro.launch.service import (DPRTService, format_latency,
                                  latency_summary)
from repro.models import Model
from repro.parallel.sharding import init_params


def serve_lm(args):
    mcfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    model = Model(mcfg)
    params = init_params(model.specs(), jax.random.key(0), jnp.float32)
    stream = TokenStream(mcfg.vocab_size, args.prompt_len, args.batch)
    prompts = jnp.asarray(stream.batch(0)["tokens"])
    batch = {"tokens": prompts}
    if mcfg.frontend == "audio_stub":
        batch["audio_embed"] = jnp.zeros(
            (args.batch, mcfg.encoder_seq, mcfg.d_model), jnp.float32)
    if mcfg.frontend == "patch_stub":
        batch["patch_embed"] = jnp.zeros(
            (args.batch, mcfg.prefix_len, mcfg.d_model), jnp.float32)

    max_len = args.prompt_len + args.gen_tokens
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    for i in range(args.gen_tokens - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    tps = args.batch * args.gen_tokens / dt
    print(f"[serve-lm] {mcfg.name}: batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen_tokens} "
          f"-> {tps:.1f} tok/s  ({dt:.2f}s)")
    print("  sample:", gen[0, :16].tolist())
    return gen


def _parse_mesh_shape(spec):
    """``--mesh-shape D,M`` -> a (data, model) mesh (or 1-D for 'D' /
    'D,1'-style shapes); validated against the visible devices."""
    if spec is None:
        return None
    try:
        dims = tuple(int(s) for s in spec.split(","))
    except ValueError:
        raise SystemExit(f"--mesh-shape must be ints like '2,4': {spec!r}")
    if not dims or any(d < 1 for d in dims) or len(dims) > 2:
        raise SystemExit(f"--mesh-shape must be 'D' or 'D,M', got {spec!r}")
    need = 1
    for d in dims:
        need *= d
    have = len(jax.devices())
    if need > have:
        raise SystemExit(
            f"--mesh-shape {spec} needs {need} devices, {have} visible "
            f"(hint: XLA_FLAGS=--xla_force_host_platform_device_count={need}"
            f" for a CPU smoke run)")
    axes = ("data", "model")[:len(dims)] if len(dims) > 1 else ("model",)
    return jax.make_mesh(dims, axes)


def serve_radon(args):
    rcfg = radon_smoke() if args.smoke else radon_config()
    n = args.n or rcfg.n                       # any size; operator embeds
    mesh = _parse_mesh_shape(args.mesh_shape)
    if (args.method != "auto" and mesh is None
            and get_backend(args.method).mesh_aware):
        raise SystemExit(f"--method {args.method} needs --mesh-shape")
    imgs = jnp.asarray(radon_images(n, args.batch or rcfg.batch,
                                    kind="phantom"))
    op = radon.DPRT(imgs.shape, imgs.dtype, args.method,
                    strip_rows=args.strip_rows, m_block=args.m_block,
                    batch_impl=args.batch_impl,
                    stream_rows=args.stream_rows,
                    block_batch=args.block_batch, mesh=mesh)
    inv = op.inverse
    if op.input_sharding is not None:
        # place traffic at the operator's mesh-natural sharding (batch
        # scattered over the data axes) so AOT executables accept it and
        # forward -> inverse chain without any resharding
        imgs = jax.device_put(imgs, op.input_sharding)
    if args.warmup:
        # AOT: build + compile both executables before any traffic; the
        # compiled calls bypass tracing entirely (cached per geometry)
        tw = time.perf_counter()
        fwd_call, inv_call = op.compile(), inv.compile()
        print(f"[serve-radon] warmup: AOT-compiled forward+inverse for "
              f"{op.shape_in} in {1e3*(time.perf_counter()-tw):.0f}ms")
    else:
        fwd_call, inv_call = op, inv
        # warm BOTH datapaths so the timed section measures steady
        # state, not the inverse's first trace+compile
        inv_call(fwd_call(imgs)).block_until_ready()
    # steady state must not retrace: one geometry, one executable.  The
    # timing loop samples each datapath --iters times so the report is a
    # latency DISTRIBUTION (p50/p95/p99, same formatter as the service
    # healthz), not a single-shot number dominated by dispatch jitter.
    iters = max(1, args.iters)
    fwd_lat, inv_lat = [], []
    with radon.retrace_guard(max_traces=0):
        for _ in range(iters):
            t0 = time.perf_counter()
            r = fwd_call(imgs)
            r.block_until_ready()
            fwd_lat.append(time.perf_counter() - t0)
            t1 = time.perf_counter()
            back = inv_call(r)
            back.block_until_ready()
            inv_lat.append(time.perf_counter() - t1)
    exact = bool((back == imgs).all())         # operator crops the embedding
    b = imgs.shape[0]
    mesh_note = "" if mesh is None else \
        f" mesh={dict(mesh.shape)}"
    print(f"[serve-radon] N={n} (prime P={op.plan.geometry.prime}) batch={b} "
          f"method={args.method}->{op.plan.method}{mesh_note}: "
          f"round-trip exact={exact}, traces={op.trace_count}")
    print("[serve-radon] forward "
          + format_latency(latency_summary(fwd_lat),
                           b * iters / sum(fwd_lat)))
    print("[serve-radon] inverse "
          + format_latency(latency_summary(inv_lat),
                           b * iters / sum(inv_lat)))
    assert exact, "DPRT round trip must be bit-exact"
    return r


def serve_service(args):
    """The dynamic-batching service: warm up (optionally through the
    persistent executable cache), run a sequential per-request baseline,
    then the same traffic coalesced, and print the healthz report."""
    rcfg = radon_smoke() if args.smoke else radon_config()
    n = args.n or rcfg.n
    mesh = _parse_mesh_shape(args.mesh_shape)
    if (args.method != "auto" and mesh is None
            and get_backend(args.method).mesh_aware):
        raise SystemExit(f"--method {args.method} needs --mesh-shape")
    max_batch = args.batch or rcfg.batch
    requests = args.requests or (2 * max_batch if args.smoke else 64)
    kernel = jnp.ones((3, 3), jnp.int32) if args.datapath == "conv" else None
    svc = DPRTService((n, n), jnp.int32, max_batch=max_batch,
                      max_wait_us=args.max_wait_us,
                      datapath=args.datapath, method=args.method,
                      conv_kernel=kernel, aot_dir=args.aot_dir,
                      strip_rows=args.strip_rows, m_block=args.m_block,
                      batch_impl=args.batch_impl,
                      stream_rows=args.stream_rows,
                      block_batch=args.block_batch, mesh=mesh)
    imgs = [np.asarray(x) for x in
            np.asarray(radon_images(n, requests, kind="phantom"))]
    if args.datapath == "solve":
        # solve requests are sinograms: forward-project the phantoms
        # into the service's (P+1, P) float contract -- BEFORE warmup,
        # so the projection's own trace doesn't read as a post-warmup
        # retrace in the healthz verdict (the counter is process-wide)
        fwd = radon.DPRT((n, n), jnp.int32)
        imgs = [np.asarray(fwd(jnp.asarray(im))).astype(
                    svc.request_dtype.name) for im in imgs]
    winfo = svc.warmup()
    cache_note = ""
    if "persistent" in winfo:
        p = winfo["persistent"]
        cache_note = (f" (persistent: {p['hits']} restored, "
                      f"{p['misses']} compiled, dir={p['directory']})")
    print(f"[serve-service] warmup: {winfo['executables']} executables "
          f"for warm_sizes={winfo['warm_sizes']} in "
          f"{1e3 * winfo['warmup_s']:.0f}ms{cache_note}")
    # warm both serving paths (thread pool, transfer paths), then
    # measure --iters full passes so single-core scheduling noise
    # averages out of the comparison
    ref, _ = svc.run_sequential(imgs)
    results = svc.run_requests(imgs, arrival_us=args.arrival_us)
    exact = all(bool((np.asarray(a) == np.asarray(b)).all())
                for a, b in zip(results, ref))
    # best-of-iters throughput on both paths: min is the noise-robust
    # statistic on a shared/single-core host, and the coalesced passes
    # share one event loop the way a real deployment would
    iters = max(1, args.iters)
    seq_lat, seq_walls = [], []
    for _ in range(iters):
        lat = svc.run_sequential(imgs)[1]
        seq_lat += lat
        seq_walls.append(sum(lat))
    svc.reset_metrics()
    svc.run_requests(imgs, arrival_us=args.arrival_us, repeats=iters)
    s = svc.stats()
    seq_rate = len(imgs) / min(seq_walls)
    coal_rate = len(imgs) / min(svc.last_pass_walls)
    print("[serve-service] sequential "
          + format_latency(latency_summary(seq_lat), seq_rate))
    print("[serve-service] coalesced  "
          + format_latency(s["latency"], coal_rate))
    print(f"[serve-service] coalescing speedup "
          f"{coal_rate / seq_rate:.2f}x (best-of-{iters}), "
          f"responses exact={exact}")
    print(svc.healthz())
    assert exact, "coalesced responses must match the per-request baseline"
    return results


def list_backends():
    cols = ("name", "priority", "batched_native", "needs_strip_rows",
            "takes_m_block", "stream", "mesh_aware", "pipeline", "dtypes",
            "note")
    for row in backend_capabilities():
        print("  ".join(f"{c}={row[c]}" for c in cols))


def main(argv=None):
    # CLI surface = the registry: every backend plus "auto" (mesh-aware
    # backends additionally need --mesh-shape)
    methods = ["auto"] + list(available_backends())
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "radon", "service"],
                    default="radon")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--method", default="auto", choices=methods,
                    help="DPRT strategy for --mode radon (auto = registry "
                         "pick for shape/dtype/batch; pallas = the fused "
                         "batched kernel, one pallas_call per batch)")
    ap.add_argument("--n", type=int, default=None,
                    help="image side for --mode radon; non-prime/any size "
                         "is embedded into the next prime by the plan "
                         "layer (default: config N)")
    ap.add_argument("--strip-rows", type=int, default=None,
                    help="strip height H (strips/pallas; default: tuned)")
    ap.add_argument("--m-block", type=int, default=None,
                    help="direction block M (pallas; default: tuned)")
    ap.add_argument("--stream-rows", type=int, default=None,
                    help="stream the image through ONE pallas launch in "
                         "row strips of this height (giant-N images that "
                         "don't fit VMEM whole; stream-capable backends "
                         "only, others scan-fall-back)")
    ap.add_argument("--batch-impl", default="auto",
                    choices=["auto", "map", "vmap"],
                    help="batching for non-batched-native backends")
    ap.add_argument("--block-batch", type=int, default=None,
                    help="stream the batch through the backend in chunks "
                         "of this many images (bounded memory)")
    ap.add_argument("--mesh-shape", default=None, metavar="D[,M]",
                    help="serve through a device mesh: 'D,M' builds a "
                         "(data, model) mesh (batch shards over data, row "
                         "super-strips over model), 'D' a 1-D model mesh; "
                         "method=auto then resolves to the sharded_pallas "
                         "backend and --warmup AOT-compiles the sharded "
                         "executables")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-compile (op.lower().compile(), cached per "
                         "geometry) the forward+inverse executables before "
                         "the timing loop")
    ap.add_argument("--iters", type=int, default=5,
                    help="timing-loop samples per datapath for --mode "
                         "radon (the report is p50/p95/p99 over these)")
    ap.add_argument("--requests", type=int, default=None,
                    help="concurrent single-image requests for --mode "
                         "service (default: 64, or 2*batch with --smoke)")
    ap.add_argument("--max-wait-us", type=float, default=2000.0,
                    help="service admission window: max microseconds a "
                         "request waits for co-batching after arrival")
    ap.add_argument("--arrival-us", type=float, default=0.0,
                    help="service traffic shape: request i arrives "
                         "i*arrival_us after the first (0 = all at once)")
    ap.add_argument("--aot-dir", default=None,
                    help="persistent AOT executable cache directory for "
                         "--mode service: restarts deserialize compiled "
                         "executables instead of re-running XLA")
    ap.add_argument("--datapath", default="forward",
                    choices=["forward", "roundtrip", "conv", "solve"],
                    help="what one service request computes (conv uses a "
                         "3x3 ones kernel; solve serves least-squares "
                         "reconstruction from sinogram requests; the "
                         "service class additionally supports 'inverse' "
                         "for raw projection-domain traffic)")
    ap.add_argument("--list-backends", action="store_true",
                    help="print the backend capability table and exit")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args(argv)
    if args.list_backends:
        return list_backends()
    if args.mode == "lm":
        return serve_lm(args)
    if args.mode == "service":
        return serve_service(args)
    return serve_radon(args)


if __name__ == "__main__":
    main()
