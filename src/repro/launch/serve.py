"""Serving launcher: batched LM decode or the DPRT image service.

``--mode lm``      prefill a batch of prompts then greedy-decode N tokens.
``--mode radon``   the paper's FPGA-coprocessor pattern as a TPU service:
                   batches of images in, DPRT (or DPRT-domain
                   convolution) out, batch sharded across the mesh.
``--mode service`` the async dynamic-batching front-end
                   (:mod:`repro.launch.service`): concurrent
                   single-image requests coalesced into the fused
                   batched kernel, with an optional persistent AOT
                   executable cache (``--aot-dir``) so restarts skip
                   XLA compilation, and a ``/healthz``-style stats
                   report (latency percentiles, batch occupancy, cache
                   and trace counters).  Three sub-modes grow it into
                   the multi-tenant tier:

                   * ``--jsonl`` runs the stdin-jsonl worker over a
                     :class:`~repro.launch.router.ServiceRouter`
                     (multi-geometry routing, bounded admission,
                     deadlines, retry/degrade), prefilled from a
                     ``--manifest`` of route specs;
                   * ``--chaos`` runs the fault-injection smoke: a
                     mixed-geometry burst under injected kernel
                     errors, dispatch delays, corrupt AOT blobs and a
                     queue flood, asserting the router degrades to
                     WARN with every response bit-exact or typed;
                   * default: the single-service benchmark loop.

The radon service is built on the :mod:`repro.radon` operator API:
``--method`` resolves through the backend registry (any registered
backend plus ``auto``), arbitrary ``--n`` is accepted (non-prime sizes
are zero-embedded into the next prime and cropped back by the operator,
so the round trip stays bit-exact), and ``--warmup`` AOT-compiles the
forward/inverse executables before the timing loop (``op.compile()``,
cached per geometry), which together with the zero-leaf pytree plans
gives the zero-retrace steady state -- asserted by a retrace guard
around the timed section.  ``--strip-rows`` / ``--m-block`` /
``--stream-rows`` / ``--batch-impl`` / ``--block-batch`` plumb straight
into the operator.
``--mesh-shape D,M`` serves through a (data, model) device mesh:
``method=auto`` then resolves to the ``sharded_pallas`` backend (batch
shards over ``data``, row super-strips over ``model``; one fused kernel
call + one collective per device) and ``--warmup`` AOT-compiles the
sharded executables before the timing loop.
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import radon
from repro.configs import get_config, get_smoke_config
from repro.configs.radon_251 import config as radon_config, \
    smoke_config as radon_smoke
from repro.core.plan import available_backends, backend_capabilities, \
    get_backend
from repro.data.synthetic import TokenStream, radon_images
from repro.launch.mesh import make_local_mesh
from repro.launch.service import (DPRTService, format_latency,
                                  latency_summary)
from repro.models import Model
from repro.parallel.sharding import init_params


def serve_lm(args):
    mcfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    model = Model(mcfg)
    params = init_params(model.specs(), jax.random.key(0), jnp.float32)
    stream = TokenStream(mcfg.vocab_size, args.prompt_len, args.batch)
    prompts = jnp.asarray(stream.batch(0)["tokens"])
    batch = {"tokens": prompts}
    if mcfg.frontend == "audio_stub":
        batch["audio_embed"] = jnp.zeros(
            (args.batch, mcfg.encoder_seq, mcfg.d_model), jnp.float32)
    if mcfg.frontend == "patch_stub":
        batch["patch_embed"] = jnp.zeros(
            (args.batch, mcfg.prefix_len, mcfg.d_model), jnp.float32)

    max_len = args.prompt_len + args.gen_tokens
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    for i in range(args.gen_tokens - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    tps = args.batch * args.gen_tokens / dt
    print(f"[serve-lm] {mcfg.name}: batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen_tokens} "
          f"-> {tps:.1f} tok/s  ({dt:.2f}s)")
    print("  sample:", gen[0, :16].tolist())
    return gen


def _parse_mesh_shape(spec):
    """``--mesh-shape D,M`` -> a (data, model) mesh (or 1-D for 'D' /
    'D,1'-style shapes); validated against the visible devices."""
    if spec is None:
        return None
    try:
        dims = tuple(int(s) for s in spec.split(","))
    except ValueError:
        raise SystemExit(f"--mesh-shape must be ints like '2,4': {spec!r}")
    if not dims or any(d < 1 for d in dims) or len(dims) > 2:
        raise SystemExit(f"--mesh-shape must be 'D' or 'D,M', got {spec!r}")
    need = 1
    for d in dims:
        need *= d
    have = len(jax.devices())
    if need > have:
        raise SystemExit(
            f"--mesh-shape {spec} needs {need} devices, {have} visible "
            f"(hint: XLA_FLAGS=--xla_force_host_platform_device_count={need}"
            f" for a CPU smoke run)")
    axes = ("data", "model")[:len(dims)] if len(dims) > 1 else ("model",)
    return jax.make_mesh(dims, axes)


def serve_radon(args):
    rcfg = radon_smoke() if args.smoke else radon_config()
    n = args.n or rcfg.n                       # any size; operator embeds
    mesh = _parse_mesh_shape(args.mesh_shape)
    if (args.method != "auto" and mesh is None
            and get_backend(args.method).mesh_aware):
        raise SystemExit(f"--method {args.method} needs --mesh-shape")
    imgs = jnp.asarray(radon_images(n, args.batch or rcfg.batch,
                                    kind="phantom"))
    op = radon.DPRT(imgs.shape, imgs.dtype, args.method,
                    strip_rows=args.strip_rows, m_block=args.m_block,
                    batch_impl=args.batch_impl,
                    stream_rows=args.stream_rows,
                    block_batch=args.block_batch, mesh=mesh)
    inv = op.inverse
    if op.input_sharding is not None:
        # place traffic at the operator's mesh-natural sharding (batch
        # scattered over the data axes) so AOT executables accept it and
        # forward -> inverse chain without any resharding
        imgs = jax.device_put(imgs, op.input_sharding)
    if args.warmup:
        # AOT: build + compile both executables before any traffic; the
        # compiled calls bypass tracing entirely (cached per geometry)
        tw = time.perf_counter()
        fwd_call, inv_call = op.compile(), inv.compile()
        print(f"[serve-radon] warmup: AOT-compiled forward+inverse for "
              f"{op.shape_in} in {1e3*(time.perf_counter()-tw):.0f}ms")
    else:
        fwd_call, inv_call = op, inv
        # warm BOTH datapaths so the timed section measures steady
        # state, not the inverse's first trace+compile
        inv_call(fwd_call(imgs)).block_until_ready()
    # steady state must not retrace: one geometry, one executable.  The
    # timing loop samples each datapath --iters times so the report is a
    # latency DISTRIBUTION (p50/p95/p99, same formatter as the service
    # healthz), not a single-shot number dominated by dispatch jitter.
    iters = max(1, args.iters)
    fwd_lat, inv_lat = [], []
    with radon.retrace_guard(max_traces=0):
        for _ in range(iters):
            t0 = time.perf_counter()
            r = fwd_call(imgs)
            r.block_until_ready()
            fwd_lat.append(time.perf_counter() - t0)
            t1 = time.perf_counter()
            back = inv_call(r)
            back.block_until_ready()
            inv_lat.append(time.perf_counter() - t1)
    exact = bool((back == imgs).all())         # operator crops the embedding
    b = imgs.shape[0]
    mesh_note = "" if mesh is None else \
        f" mesh={dict(mesh.shape)}"
    print(f"[serve-radon] N={n} (prime P={op.plan.geometry.prime}) batch={b} "
          f"method={args.method}->{op.plan.method}{mesh_note}: "
          f"round-trip exact={exact}, traces={op.trace_count}")
    print("[serve-radon] forward "
          + format_latency(latency_summary(fwd_lat),
                           b * iters / sum(fwd_lat)))
    print("[serve-radon] inverse "
          + format_latency(latency_summary(inv_lat),
                           b * iters / sum(inv_lat)))
    assert exact, "DPRT round trip must be bit-exact"
    return r


def serve_service(args):
    """The dynamic-batching service: warm up (optionally through the
    persistent executable cache), run a sequential per-request baseline,
    then the same traffic coalesced, and print the healthz report."""
    rcfg = radon_smoke() if args.smoke else radon_config()
    n = args.n or rcfg.n
    mesh = _parse_mesh_shape(args.mesh_shape)
    if (args.method != "auto" and mesh is None
            and get_backend(args.method).mesh_aware):
        raise SystemExit(f"--method {args.method} needs --mesh-shape")
    max_batch = args.batch or rcfg.batch
    requests = args.requests or (2 * max_batch if args.smoke else 64)
    kernel = jnp.ones((3, 3), jnp.int32) if args.datapath == "conv" else None
    svc = DPRTService((n, n), jnp.int32, max_batch=max_batch,
                      max_wait_us=args.max_wait_us,
                      datapath=args.datapath, method=args.method,
                      conv_kernel=kernel, aot_dir=args.aot_dir,
                      strip_rows=args.strip_rows, m_block=args.m_block,
                      batch_impl=args.batch_impl,
                      stream_rows=args.stream_rows,
                      block_batch=args.block_batch, mesh=mesh)
    imgs = [np.asarray(x) for x in
            np.asarray(radon_images(n, requests, kind="phantom"))]
    if args.datapath == "solve":
        # solve requests are sinograms: forward-project the phantoms
        # into the service's (P+1, P) float contract -- BEFORE warmup,
        # so the projection's own trace doesn't read as a post-warmup
        # retrace in the healthz verdict (the counter is process-wide)
        fwd = radon.DPRT((n, n), jnp.int32)
        imgs = [np.asarray(fwd(jnp.asarray(im))).astype(
                    svc.request_dtype.name) for im in imgs]
    winfo = svc.warmup()
    cache_note = ""
    if "persistent" in winfo:
        p = winfo["persistent"]
        cache_note = (f" (persistent: {p['hits']} restored, "
                      f"{p['misses']} compiled, dir={p['directory']})")
    print(f"[serve-service] warmup: {winfo['executables']} executables "
          f"for warm_sizes={winfo['warm_sizes']} in "
          f"{1e3 * winfo['warmup_s']:.0f}ms{cache_note}")
    # warm both serving paths (thread pool, transfer paths), then
    # measure --iters full passes so single-core scheduling noise
    # averages out of the comparison
    ref, _ = svc.run_sequential(imgs)
    results = svc.run_requests(imgs, arrival_us=args.arrival_us)
    exact = all(bool((np.asarray(a) == np.asarray(b)).all())
                for a, b in zip(results, ref))
    # best-of-iters throughput on both paths: min is the noise-robust
    # statistic on a shared/single-core host, and the coalesced passes
    # share one event loop the way a real deployment would
    iters = max(1, args.iters)
    seq_lat, seq_walls = [], []
    for _ in range(iters):
        lat = svc.run_sequential(imgs)[1]
        seq_lat += lat
        seq_walls.append(sum(lat))
    svc.reset_metrics()
    svc.run_requests(imgs, arrival_us=args.arrival_us, repeats=iters)
    s = svc.stats()
    seq_rate = len(imgs) / min(seq_walls)
    coal_rate = len(imgs) / min(svc.last_pass_walls)
    print("[serve-service] sequential "
          + format_latency(latency_summary(seq_lat), seq_rate))
    print("[serve-service] coalesced  "
          + format_latency(s["latency"], coal_rate))
    print(f"[serve-service] coalescing speedup "
          f"{coal_rate / seq_rate:.2f}x (best-of-{iters}), "
          f"responses exact={exact}")
    print(svc.healthz())
    assert exact, "coalesced responses must match the per-request baseline"
    return results


def _load_manifest(spec):
    """A geometry manifest: a JSON list of route specs
    (``[{"n": 13}, {"n": 17, "datapath": "roundtrip"}, …]``) -- either
    a file path or the JSON itself (how the pool supervisor hands a
    manifest to its worker subprocesses without temp files)."""
    if spec.lstrip().startswith("["):
        data = json.loads(spec)
    else:
        with open(spec) as f:
            data = json.load(f)
    if not isinstance(data, list) or not all(isinstance(e, dict)
                                             for e in data):
        raise SystemExit(f"--manifest {spec!r} must be a JSON list of "
                         "route-spec objects")
    return data


def serve_jsonl_mode(args):
    """The transport worker: a prefilled ServiceRouter behind the
    newline-delimited-JSON protocol on stdin/stdout (healthz to stderr
    at exit -- stdout belongs to the protocol).  ``--framed`` switches
    to the supervisor's length-prefixed frames; ``--sigterm-drain``
    makes SIGTERM drain (flush in-flight, final healthz) instead of
    killing the worker mid-batch.  A ``REPRO_FAULTS`` spec in the
    environment arms deterministic chaos inside this process."""
    from repro.launch import faults
    from repro.launch.router import ServiceRouter, serve_jsonl
    inj = faults.install_from_env()
    if inj is not None:
        print(f"[serve-jsonl] faults armed from {faults.FAULTS_ENV_VAR}: "
              f"{inj.spec}", file=sys.stderr)
    router = ServiceRouter(
        max_batch=args.batch, max_wait_us=args.max_wait_us,
        max_services=args.max_services, queue_cap=args.queue_cap,
        max_inflight=args.max_inflight, aot_dir=args.aot_dir)
    if args.manifest:
        infos = router.prefill(_load_manifest(args.manifest))
        print(f"[serve-jsonl] prefilled {len(infos)} routes",
              file=sys.stderr)
    serve_jsonl(router, sys.stdin, sys.stdout, framed=args.framed,
                sigterm_drain=args.sigterm_drain)
    print(router.healthz(), file=sys.stderr)
    return router


def serve_chaos(args):
    """The fault-injection smoke: mixed-geometry traffic through a
    deliberately tight router while the :mod:`repro.launch.faults`
    harness injects kernel errors, dispatch delays, corrupt AOT blobs
    and a queue flood.  Asserts the robustness contract: no hang, no
    dropped future, every response bit-exact vs the per-operator oracle
    or a typed rejection, and a healthz that accounts for every
    degradation (verdict WARN, never FAIL)."""
    from repro.launch import faults
    from repro.launch.errors import ServiceError
    from repro.launch.router import ServiceRouter

    seed = args.chaos_seed
    ns = (13, 17)
    requests_n = 16 if args.smoke else 48
    flood_n = 3 * args.queue_cap
    manifest = ([{"n": n} for n in ns]
                + [{"n": ns[0], "datapath": "roundtrip"}])
    aot_dir = args.aot_dir or tempfile.mkdtemp(prefix="repro_chaos_aot_")

    # seed the blob store warm, then corrupt it: the chaos router's
    # prefill must degrade to counted cold compiles, not an outage
    seeder = ServiceRouter(max_batch=4, aot_dir=aot_dir)
    seeder.prefill(manifest)
    radon.aot_cache_clear()
    corrupted = faults.corrupt_blobs(aot_dir, seed=seed)
    print(f"[serve-chaos] corrupted {corrupted} AOT blobs in {aot_dir}")

    # oracles BEFORE the chaos run (process-global trace counters)
    rng = np.random.default_rng(seed)
    def oracle(n, img):
        return np.asarray(radon.DPRT((1, n, n), jnp.int32)(
            jnp.asarray(img[None])))[0]
    traffic = []      # (spec, payload, submit kwargs, expected|None)
    for i in range(requests_n):
        n = ns[i % len(ns)]
        img = rng.integers(0, 100, (n, n)).astype(np.int32)
        kw = {}
        if i % 11 == 3:
            kw["deadline_s"] = 1e-6    # unmeetable SLO: typed rejection
        if i % 5 == 0:
            kw["priority"] = 1
        want = oracle(n, img) if "deadline_s" not in kw else None
        traffic.append(({"n": n}, img, kw, want))
    rt_img = rng.integers(0, 100, (ns[0], ns[0])).astype(np.int32)
    traffic.append(({"n": ns[0], "datapath": "roundtrip"}, rt_img, {},
                    rt_img))           # roundtrip oracle = the image
    flood_img = np.zeros((ns[0], ns[0]), np.int32)
    flood_want = oracle(ns[0], flood_img)
    for _ in range(flood_n):           # queue flood: bounded admission
        traffic.append(({"n": ns[0]}, flood_img, {}, flood_want))

    router = ServiceRouter(
        max_batch=4, max_wait_us=500.0, max_services=args.max_services,
        queue_cap=args.queue_cap, max_inflight=args.max_inflight,
        max_retries=1, retry_backoff_s=1e-3, aot_dir=aot_dir)
    router.prefill(manifest)
    assert router.degraded_compiles() > 0, \
        "corrupt blobs must surface as degraded_compiles"

    with faults.FaultInjector(seed=seed, sites=("dispatch",),
                              error_count=3, error_rate=0.05,
                              delay_s=0.002, delay_rate=0.3) as inj:
        outs = router.run_requests([(s, p, kw)
                                    for s, p, kw, _ in traffic])

    # force the degrade path deterministically: every dispatch attempt
    # of ONE targeted route fails, so retries exhaust and the staged
    # fallback must produce the (bit-exact) answer
    fallbacks_before = router.fallbacks
    rt_key = f"{ns[0]}x{ns[0]}/int32/roundtrip"
    with faults.FaultInjector(seed=seed + 1, sites=("dispatch",),
                              error_count=router.max_retries + 1,
                              match=rt_key):
        forced = router.run_requests(
            [({"n": ns[0], "datapath": "roundtrip"}, rt_img)])
    assert np.array_equal(np.asarray(forced[0]), rt_img), \
        "the fallback answer must stay bit-exact"
    assert router.fallbacks > fallbacks_before, \
        "exhausted retries must degrade to the fallback path"
    print(f"[serve-chaos] forced fallback on {rt_key}: bit-exact via "
          "the staged registry path")

    exact = typed = raw = wrong = 0
    for (spec, _p, _kw, want), out in zip(traffic, outs):
        if isinstance(out, ServiceError):
            typed += 1
        elif isinstance(out, BaseException):
            raw += 1
        elif want is not None and not np.array_equal(np.asarray(out),
                                                     want):
            wrong += 1
        else:
            exact += 1
    s = router.stats()
    accounted = (s["delivered"] + s["failed"] + s["pending"]
                 + router.rejected_deadline + router.rejected_shutdown)
    print(f"[serve-chaos] injected: {inj.stats()}")
    print(f"[serve-chaos] responses: exact={exact} typed={typed} "
          f"raw={raw} wrong={wrong} "
          f"(admitted={s['admitted']} accounted={accounted})")
    print(router.healthz())
    assert wrong == 0, "a degraded response was NOT bit-exact"
    assert raw == 0, "a failure escaped untyped"
    assert s["pending"] == 0, "the router dropped a future"
    assert s["admitted"] == accounted, "future accounting does not close"
    assert typed > 0, "the flood/deadline pressure produced no rejection"
    assert router.verdict() == "WARN", \
        f"chaos must degrade to WARN, got {router.verdict()}"
    print("[serve-chaos] PASS: degraded to WARN, every response exact "
          "or typed")
    return outs


def serve_pool(args):
    """The supervised multi-process tier: spawn ``--workers`` framed
    jsonl router subprocesses over one shared ``--aot-dir``, serve a
    burst through the pool, verify bit-exactness against the local
    oracle, and print the aggregated pool healthz."""
    from repro.launch.supervisor import WorkerPool
    rcfg = radon_smoke() if args.smoke else radon_config()
    n = args.n or rcfg.n
    manifest = (_load_manifest(args.manifest) if args.manifest
                else [{"n": n}])
    requests_n = args.requests or (16 if args.smoke else 64)
    aot_dir = args.aot_dir or tempfile.mkdtemp(prefix="repro_pool_aot_")

    rng = np.random.default_rng(0)
    imgs = [rng.integers(0, 100, (n, n)).astype(np.int32)
            for _ in range(requests_n)]
    oracle_op = radon.DPRT((1, n, n), jnp.int32)
    expected = [np.asarray(oracle_op(jnp.asarray(im[None])))[0]
                for im in imgs]

    pool = WorkerPool(args.workers, aot_dir=aot_dir, manifest=manifest,
                      max_batch=args.batch, pending_cap=args.max_inflight)
    with pool:
        t_boot = time.perf_counter()
        assert pool.wait_ready(600.0), "pool workers never became ready"
        boot_s = time.perf_counter() - t_boot
        t0 = time.perf_counter()
        futs = [pool.submit({"n": n}, im) for im in imgs]
        outs = [f.result(timeout=300) for f in futs]
        dt = time.perf_counter() - t0
        report = pool.healthz(probe=True)
        print(pool.healthz_text(report))
    exact = all(np.array_equal(np.asarray(o), e)
                for o, e in zip(outs, expected))
    print(f"[serve-pool] workers={args.workers} N={n} "
          f"requests={requests_n}: {requests_n / dt:.1f} req/s "
          f"(boot {boot_s:.1f}s), exact={exact}")
    assert exact, "pool responses must match the local oracle"
    return outs


def serve_pool_chaos(args):
    """Process-level chaos: ≥2 workers over one ``aot_dir``, one
    SIGKILLed mid-burst, stale compile locks torn in (dead-PID lock
    files seeded under the restarting worker), a pool flood, and
    env-armed in-worker fault injection.  Asserts the pool invariant:
    every admitted request delivered bit-exact against the local
    oracle or rejected typed, pool accounting closes, verdict WARN
    (never FAIL), and the killed worker is back -- warm, zero
    retraces, its stolen locks cleaned up -- before the run ends."""
    import os
    import subprocess

    from repro.checkpoint.store import _blob_path, list_blobs
    from repro.launch.errors import QueueFull, ServiceError
    from repro.launch.supervisor import WorkerPool

    seed = args.chaos_seed
    ns = (13,) if args.smoke else (13, 17)
    max_batch = 4
    manifest = [{"n": n} for n in ns]
    requests_n = 24 if args.smoke else 48
    workers = max(2, args.workers)
    pending_cap = requests_n + 16
    aot_dir = args.aot_dir or tempfile.mkdtemp(prefix="repro_poolchaos_")

    # deterministic chaos INSIDE each worker, armed across the process
    # boundary via the env seam: the first dispatch in every worker
    # raises (the router's retry absorbs it), spec echoed in healthz
    fault_spec = f"sites=dispatch;error_count=1;seed={seed}"
    env = dict(os.environ, REPRO_FAULTS=fault_spec)

    rng = np.random.default_rng(seed)

    def oracle(n, img):
        return np.asarray(radon.DPRT((1, n, n), jnp.int32)(
            jnp.asarray(img[None])))[0]

    traffic = []
    for i in range(requests_n):
        n = ns[i % len(ns)]
        img = rng.integers(0, 100, (n, n)).astype(np.int32)
        traffic.append((n, img, oracle(n, img)))
    flood_img = np.zeros((ns[0], ns[0]), np.int32)
    flood_want = oracle(ns[0], flood_img)

    pool = WorkerPool(workers, aot_dir=aot_dir, manifest=manifest,
                      max_batch=max_batch, pending_cap=pending_cap,
                      probe_interval_s=0.5, restart_backoff_s=0.25,
                      env=env)
    with pool:
        assert pool.wait_ready(600.0), "pool workers never became ready"

        # -- cross-process compile coalescing: N cold workers, one
        # shared aot_dir -> exactly one compile per unique executable,
        # i.e. the pool-wide miss total equals the distinct blob count
        blobs = list_blobs(aot_dir)
        cold = pool.healthz(probe=True)
        miss_total = sum((w["persistent"] or {}).get("misses", 0)
                         for w in cold["workers"])
        hit_total = sum((w["persistent"] or {}).get("hits", 0)
                        for w in cold["workers"])
        print(f"[pool-chaos] cold start: {len(blobs)} blobs, "
              f"pool misses={miss_total} hits={hit_total}")
        assert miss_total == len(blobs), \
            (f"cross-process coalescing broken: {miss_total} compiles "
             f"for {len(blobs)} unique executables")
        for w in cold["workers"]:
            assert w["faults_env"] == fault_spec, \
                f"worker healthz must echo the fault spec, got {w}"

        # -- the burst, with worker 0 SIGKILLed while it has requests
        # in flight
        futs = [pool.submit({"n": n}, img) for n, img, _ in traffic]
        time.sleep(0.05)
        killed = pool.kill_worker(0)
        assert killed, "chaos kill found no live worker process"
        print(f"[pool-chaos] SIGKILLed worker 0 mid-burst "
              f"({pool.pending()} pending)")

        # tear stale compile locks in under the worker that is about to
        # restart: dead-PID lock files next to every blob -- its warm
        # re-prefill must steal them, not deadlock on them
        corpse = subprocess.Popen(["sleep", "0"])
        corpse.wait()
        for key in blobs:
            with open(_blob_path(aot_dir, key) + ".lock", "w") as f:
                json.dump({"pid": corpse.pid, "key": key,
                           "time": time.time() - 3600.0}, f)
        print(f"[pool-chaos] seeded {len(blobs)} stale dead-PID locks")

        # -- flood the pool past its pending budget: typed QueueFull
        # with a retry_after_s hint, never unbounded queueing
        flood_futs, flood_rejects, hints = [], 0, []
        for _ in range(pending_cap + 32):
            try:
                flood_futs.append(pool.submit({"n": ns[0]}, flood_img))
            except QueueFull as e:
                flood_rejects += 1
                hints.append(e.retry_after_s)

        exact = typed = raw = wrong = 0
        want_list = [w for _n, _i, w in traffic] + \
            [flood_want] * len(flood_futs)
        for fut, want in zip(futs + flood_futs, want_list):
            try:
                out = fut.result(timeout=300)
            except ServiceError:
                typed += 1
                continue
            except Exception:
                raw += 1
                continue
            if np.array_equal(np.asarray(out), want):
                exact += 1
            else:
                wrong += 1
        print(f"[pool-chaos] responses: exact={exact} typed={typed} "
              f"raw={raw} wrong={wrong}; flood rejected "
              f"{flood_rejects} with hints={hints[:3]}...")

        # -- the killed worker must come back and serve, warm
        deadline = time.monotonic() + 600.0
        while time.monotonic() < deadline:
            if pool.wait_ready(10.0) and \
                    all(w.alive for w in pool._workers):
                break
            time.sleep(0.25)
        final = pool.healthz(probe=True)
        w0 = final["workers"][0]
        assert w0["alive"] and w0["restarts"] >= 1, \
            f"killed worker was not restarted: {w0}"
        p0 = w0["persistent"] or {}
        assert p0.get("misses", 0) == 0 and p0.get("hits", 0) > 0, \
            f"restarted worker must come back warm from blobs: {p0}"
        assert p0.get("lock_steals", 0) >= len(blobs), \
            f"stale dead-PID locks were not stolen: {p0}"
        locks_left = [f for f in os.listdir(aot_dir)
                      if f.endswith(".lock")]
        assert not locks_left, f"stolen locks not cleaned: {locks_left}"
        # serving again, zero retraces pool-wide (every geometry warm)
        post = [pool.submit({"n": ns[0]},
                            rng.integers(0, 100, (ns[0], ns[0]))
                            .astype(np.int32))
                for _ in range(2 * workers)]
        for f in post:
            f.result(timeout=300)
        final = pool.healthz(probe=True)
        for w in final["workers"]:
            assert w["retraces_since_start"] == 0, \
                f"worker retraced in steady state: {w}"
        print(pool.healthz_text(final))

    # -- the invariant --------------------------------------------------
    assert wrong == 0, "a pool response was NOT bit-exact"
    assert raw == 0, "a worker failure escaped untyped"
    assert pool.failed == 0, "raw failures booked in the pool ledger"
    assert pool.pending() == 0, "the pool dropped a future"
    assert pool.identity_ok(), "pool accounting identity does not close"
    assert pool.workers_lost >= 1 and pool.worker_restarts >= 1, \
        "the chaos kill did not register as a worker loss + restart"
    assert pool.replays > 0, \
        "killing a loaded worker must replay its in-flight requests"
    assert flood_rejects > 0, "the flood produced no typed backpressure"
    assert all(h is not None and h > 0 for h in hints), \
        f"QueueFull must carry a positive retry_after_s hint: {hints[:5]}"
    assert pool.verdict() == "WARN", \
        f"pool chaos must degrade to WARN, got {pool.verdict()}"
    print(f"[pool-chaos] PASS: worker lost+replayed+restarted warm, "
          f"{exact} exact / {typed} typed, identity closed, verdict WARN")
    return final


def list_backends():
    cols = ("name", "priority", "batched_native", "needs_strip_rows",
            "takes_m_block", "stream", "mesh_aware", "pipeline", "dtypes",
            "note")
    for row in backend_capabilities():
        print("  ".join(f"{c}={row[c]}" for c in cols))


def main(argv=None):
    # CLI surface = the registry: every backend plus "auto" (mesh-aware
    # backends additionally need --mesh-shape)
    methods = ["auto"] + list(available_backends())
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "radon", "service", "pool"],
                    default="radon")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--method", default="auto", choices=methods,
                    help="DPRT strategy for --mode radon (auto = registry "
                         "pick for shape/dtype/batch; pallas = the fused "
                         "batched kernel, one pallas_call per batch)")
    ap.add_argument("--n", type=int, default=None,
                    help="image side for --mode radon; non-prime/any size "
                         "is embedded into the next prime by the plan "
                         "layer (default: config N)")
    ap.add_argument("--strip-rows", type=int, default=None,
                    help="strip height H (strips/pallas; default: tuned)")
    ap.add_argument("--m-block", type=int, default=None,
                    help="direction block M (pallas; default: tuned)")
    ap.add_argument("--stream-rows", type=int, default=None,
                    help="stream the image through ONE pallas launch in "
                         "row strips of this height (giant-N images that "
                         "don't fit VMEM whole; stream-capable backends "
                         "only, others scan-fall-back)")
    ap.add_argument("--batch-impl", default="auto",
                    choices=["auto", "map", "vmap"],
                    help="batching for non-batched-native backends")
    ap.add_argument("--block-batch", type=int, default=None,
                    help="stream the batch through the backend in chunks "
                         "of this many images (bounded memory)")
    ap.add_argument("--mesh-shape", default=None, metavar="D[,M]",
                    help="serve through a device mesh: 'D,M' builds a "
                         "(data, model) mesh (batch shards over data, row "
                         "super-strips over model), 'D' a 1-D model mesh; "
                         "method=auto then resolves to the sharded_pallas "
                         "backend and --warmup AOT-compiles the sharded "
                         "executables")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-compile (op.lower().compile(), cached per "
                         "geometry) the forward+inverse executables before "
                         "the timing loop")
    ap.add_argument("--iters", type=int, default=5,
                    help="timing-loop samples per datapath for --mode "
                         "radon (the report is p50/p95/p99 over these)")
    ap.add_argument("--requests", type=int, default=None,
                    help="concurrent single-image requests for --mode "
                         "service (default: 64, or 2*batch with --smoke)")
    ap.add_argument("--max-wait-us", type=float, default=2000.0,
                    help="service admission window: max microseconds a "
                         "request waits for co-batching after arrival")
    ap.add_argument("--arrival-us", type=float, default=0.0,
                    help="service traffic shape: request i arrives "
                         "i*arrival_us after the first (0 = all at once)")
    ap.add_argument("--aot-dir", default=None,
                    help="persistent AOT executable cache directory for "
                         "--mode service: restarts deserialize compiled "
                         "executables instead of re-running XLA")
    ap.add_argument("--jsonl", action="store_true",
                    help="--mode service: run the stdin-jsonl router "
                         "worker instead of the benchmark loop (submit/"
                         "healthz/shutdown ops; typed error codes)")
    ap.add_argument("--framed", action="store_true",
                    help="--jsonl: speak the supervisor's length-"
                         "prefixed frame protocol instead of bare "
                         "newline JSON (SIGKILL mid-write reads as "
                         "truncation, never as a mangled message)")
    ap.add_argument("--sigterm-drain", action="store_true",
                    help="--jsonl: install a SIGTERM handler that "
                         "drains (stop reading stdin, flush in-flight, "
                         "emit a final healthz) instead of dying "
                         "mid-batch")
    ap.add_argument("--workers", type=int, default=2,
                    help="--mode pool: number of supervised router "
                         "worker subprocesses")
    ap.add_argument("--chaos", action="store_true",
                    help="--mode service: run the fault-injection chaos "
                         "smoke (mixed geometries, injected faults, "
                         "asserts WARN-not-FAIL and exact-or-typed "
                         "responses)")
    ap.add_argument("--manifest", default=None,
                    help="geometry manifest (JSON list of route specs) "
                         "to prefill the router's warm pool from")
    ap.add_argument("--max-services", type=int, default=8,
                    help="router residency bound: LRU-evict cold routes "
                         "beyond this many (executables drop in lockstep)")
    ap.add_argument("--queue-cap", type=int, default=64,
                    help="router per-route queue cap (typed QueueFull "
                         "beyond it)")
    ap.add_argument("--max-inflight", type=int, default=256,
                    help="router global in-flight request budget")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="deterministic seed for --chaos fault injection")
    ap.add_argument("--datapath", default="forward",
                    choices=["forward", "roundtrip", "conv", "solve"],
                    help="what one service request computes (conv uses a "
                         "3x3 ones kernel; solve serves least-squares "
                         "reconstruction from sinogram requests; the "
                         "service class additionally supports 'inverse' "
                         "for raw projection-domain traffic)")
    ap.add_argument("--list-backends", action="store_true",
                    help="print the backend capability table and exit")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args(argv)
    if args.list_backends:
        return list_backends()
    if args.mode == "lm":
        return serve_lm(args)
    if args.mode == "pool":
        if args.chaos:
            return serve_pool_chaos(args)
        return serve_pool(args)
    if args.mode == "service":
        if args.chaos:
            return serve_chaos(args)
        if args.jsonl:
            return serve_jsonl_mode(args)
        return serve_service(args)
    return serve_radon(args)


if __name__ == "__main__":
    main()
