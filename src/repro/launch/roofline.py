"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch x shape x mesh), all in seconds-per-step:

    compute    = HLO_FLOPs / (chips * 197 TFLOP/s)
    memory     = HLO_bytes / (chips * 819 GB/s)
    collective = collective_bytes / (chips * 50 GB/s/link)

``cost_analysis`` on the compiled executable reports the *per-device*
(SPMD-partitioned) module, so per-device quantities are divided by the
single-chip peak; global numbers reported alongside are x chips.
Collective bytes are parsed from the partitioned HLO text: the summed
operand sizes of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute (async -start counted once, -done
skipped).
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

import jax

HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_LINE_RE = re.compile(
    r"=\s*(.*?)\s(" + "|".join(_COLL_OPS) + r")(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota form [n_groups, group_size]<=[...]
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:  # explicit {{a,b,..},{..}} form: size of the first group
        return max(len([t for t in m.group(1).split(",") if t]), 1)
    return 1


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Per-class summed *operand* bytes from (partitioned) HLO text.

    Operands are referenced by name in optimized HLO, so sizes derive from
    the result shape: all-reduce/all-to-all/collective-permute move the
    result size, an all-gather's operand is result/group_size, and a
    reduce-scatter's operand is result*group_size.
    """
    out: Dict[str, int] = {op: 0 for op in _COLL_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue  # async completion: counted at -start
        m = _LINE_RE.search(line)
        if not m:
            continue
        result_txt, op, _ = m.groups()
        rbytes = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(result_txt))
        if op == "all-gather":
            rbytes //= _group_size(line)
        elif op == "reduce-scatter":
            rbytes *= _group_size(line)
        out[op] += rbytes
        out["count"] += 1
    out["total"] = sum(out[o] for o in _COLL_OPS)
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, chips: int) -> Dict[str, float]:
    compute = flops_per_dev / HW["peak_flops"]
    memory = bytes_per_dev / HW["hbm_bw"]
    collective = coll_bytes_per_dev / HW["link_bw"]
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms.update(dominant=dom.replace("_s", ""),
                 step_s_lower_bound=bound,
                 chips=chips,
                 global_flops=flops_per_dev * chips,
                 global_bytes=bytes_per_dev * chips,
                 global_coll_bytes=coll_bytes_per_dev * chips)
    return terms


def param_counts(specs) -> Tuple[int, int]:
    """(total, active) parameters; routed-expert leaves scale by k/E."""
    from repro.parallel.sharding import ParamSpec
    import numpy as np

    total = active = 0
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))[0]
    for path, spec in flat:
        n = int(np.prod(spec.shape))
        total += n
        keys = [str(getattr(p, "key", "")) for p in path]
        routed = "moe" in keys and "experts" in (spec.logical or ())
        if not routed:
            active += n
    return total, active


def model_flops(cfg, specs, tokens: int, mode: str) -> float:
    """6*N_active*D (train) or 2*N_active*D (inference)."""
    total, nonrouted = param_counts(specs)
    routed = total - nonrouted
    if cfg.num_experts:
        active = nonrouted + routed * cfg.experts_per_token / cfg.num_experts
    else:
        active = total
    mult = 6.0 if mode == "train" else 2.0
    return mult * active * tokens, total, active
