"""Fault-tolerant multi-geometry serving router.

The paper's scalability claim is about fitting the transform to fixed
resources; a production serving tier has to make the same promise for
MANY transforms at once.  :class:`ServiceRouter` multiplexes requests
over a pool of :class:`~repro.launch.service.DPRTService` instances --
one per ``(geometry, dtype, datapath)`` route -- under explicit,
bounded resource rules:

* **Bounded admission.**  A per-route queue cap and a global in-flight
  budget; exceeding either rejects with the typed
  :class:`~repro.launch.errors.QueueFull` instead of queuing without
  bound.
* **Bounded residency.**  At most ``max_services`` routes stay live;
  creating one more retires the least-recently-used *idle* route and
  discards exactly the plans no surviving route shares
  (:func:`repro.core.plan.plan_cache_discard`), which drops their
  jitted appliers and AOT executables in lockstep -- the process
  footprint is bounded by policy, not by traffic history.
* **Deadline/priority batching.**  Requests carry an optional
  ``deadline_s`` SLO and a ``priority`` (higher dispatches first).  The
  per-route batcher flushes a group early when the oldest deadline
  minus the route's smoothed execution time is about to pass, and a
  request whose deadline already passed at dispatch is rejected with
  :class:`~repro.launch.errors.DeadlineExceeded` -- never served late,
  never left hanging.
* **Retry and degrade.**  Dispatch runs under a timeout; failures retry
  with exponential backoff, and when the primary AOT executables are
  exhausted the route degrades to its service's fallback applier (a
  fresh jit of the staged registry composition -- bit-exact, just
  slower).  Only if THAT also fails does the caller see the raw error.
  Every degradation is counted and surfaced by :meth:`healthz`:
  ``OK`` (clean), ``WARN`` (degraded but every answer exact or typed),
  ``FAIL`` (dropped/incorrectly failed work).
* **Warm-pool prefill.**  :meth:`prefill` walks a geometry manifest and
  warms each route through the persistent AOT cache before traffic.
* **Drain on shutdown.**  :meth:`shutdown` cancels the batchers, lets
  in-flight dispatches finish, and rejects anything still queued with
  :class:`~repro.launch.errors.ServiceShutdown` -- a future handed out
  by this router ALWAYS resolves.

:func:`serve_jsonl` is the transport front-end ``serve --mode service
--jsonl`` runs: newline-delimited JSON requests on stdin, responses
(with typed error codes) on stdout, ``healthz`` as an in-band op.
"""
from __future__ import annotations

import asyncio
import collections
import json
import os
import signal
import threading
import time
from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.plan import plan_cache_discard, plan_cache_info
from repro.kernels.tuning import router_warm_sizes
from repro.launch.errors import (DeadlineExceeded, QueueFull, ServiceError,
                                 ServiceShutdown)
from repro.launch.service import DPRTService, format_latency, latency_summary

__all__ = ["ServiceRouter", "serve_jsonl"]

#: slack reserved when flushing a batch against a request deadline, so
#: the dispatch-time expiry check sees the request strictly alive even
#: when the execution-time EWMA is still cold
_FLUSH_MARGIN_S = 2e-3


class _Routed:
    __slots__ = ("payload", "future", "t_enqueue", "deadline", "priority")

    def __init__(self, payload, future, t_enqueue, deadline, priority):
        self.payload = payload
        self.future = future
        self.t_enqueue = t_enqueue
        self.deadline = deadline
        self.priority = priority


class _Route:
    __slots__ = ("key", "service", "queue", "batcher", "ready", "warm_task",
                 "error", "seq", "exec_s", "inflight")

    def __init__(self, key, service):
        self.key = key
        self.service = service
        self.queue: Optional[asyncio.PriorityQueue] = None
        self.batcher: Optional[asyncio.Task] = None
        self.ready: Optional[asyncio.Event] = None
        self.warm_task: Optional[asyncio.Task] = None
        self.error: Optional[BaseException] = None
        self.seq = 0
        self.exec_s: Optional[float] = None   # EWMA of dispatch seconds
        self.inflight = 0

    @property
    def label(self) -> str:
        return self.service.fault_key

    def idle(self) -> bool:
        queued = self.queue is not None and not self.queue.empty()
        warming = self.ready is not None and not self.ready.is_set()
        return not queued and not warming and self.inflight == 0


class ServiceRouter:
    """Bounded, deadline-aware, degradable multi-geometry front-end.

    A *route spec* is ``{"n": 13}`` / ``{"shape": (13, 13)}`` plus
    optional ``dtype`` (default int32), ``datapath`` (default forward)
    and per-service knobs (``method``, ``conv_kernel``, ...); specs
    naming the same ``(shape, dtype, datapath)`` share one route.  SLO
    knobs: ``max_wait_us`` bounds coalescing latency, per-request
    ``deadline_s`` is the hard SLO, ``dispatch_timeout_s`` +
    ``max_retries``/``retry_backoff_s`` govern the retry ladder around
    one kernel dispatch.
    """

    def __init__(self, *, max_services: int = 8, queue_cap: int = 64,
                 max_inflight: int = 256, max_batch: int = 16,
                 max_wait_us: float = 2000.0,
                 dispatch_timeout_s: float = 60.0, max_retries: int = 2,
                 retry_backoff_s: float = 0.005,
                 aot_dir: Optional[str] = None, fallback: bool = True,
                 history: int = 65536):
        if max_services < 1 or queue_cap < 1 or max_inflight < 1:
            raise ValueError("max_services, queue_cap and max_inflight "
                             "must all be >= 1")
        if max_retries < 0 or retry_backoff_s < 0 or dispatch_timeout_s <= 0:
            raise ValueError("retry/timeout knobs must be non-negative "
                             "(timeout > 0)")
        self.max_services = int(max_services)
        self.queue_cap = int(queue_cap)
        self.max_inflight = int(max_inflight)
        self.max_batch = int(max_batch)
        self.max_wait_us = float(max_wait_us)
        self.dispatch_timeout_s = float(dispatch_timeout_s)
        self.max_retries = int(max_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.aot_dir = aot_dir
        self.fallback = bool(fallback)

        self._routes: "collections.OrderedDict[tuple, _Route]" = \
            collections.OrderedDict()
        self._started = False
        self._closing = False
        self._dispatch_tasks: set = set()
        self._latencies = collections.deque(maxlen=int(history))

        # -- accounting: every admitted future ends in exactly one bin --
        self.admitted = 0
        self.delivered = 0
        self.failed = 0                 # raw (non-typed) future failures
        self.rejected_deadline = 0      # admitted, then DeadlineExceeded
        self.rejected_shutdown = 0      # admitted, then ServiceShutdown
        #: typed refusals at submit time (no future was created)
        self.rejected_admission: collections.Counter = collections.Counter()
        self._inflight = 0
        self.queue_depth_max = 0
        # -- degradations -------------------------------------------------
        self.retries = 0
        self.fallbacks = 0
        self.evictions = 0
        #: counters carried over from retired services
        self._retired = collections.Counter()

    # -- route specs -------------------------------------------------------
    @staticmethod
    def _normalize(spec) -> dict:
        if isinstance(spec, (int, np.integer)):
            spec = {"n": int(spec)}
        spec = dict(spec)
        if "shape" in spec:
            shape = tuple(int(s) for s in spec.pop("shape"))
        elif "n" in spec:
            n = int(spec.pop("n"))
            shape = (n, n)
        else:
            raise ValueError(f"route spec needs 'n' or 'shape': {spec}")
        dtype = jnp.dtype(spec.pop("dtype", "int32"))
        datapath = str(spec.pop("datapath", "forward"))
        return {"shape": shape, "dtype": dtype, "datapath": datapath,
                "extra": spec}

    @classmethod
    def route_key(cls, spec) -> Tuple[tuple, str, str]:
        norm = cls._normalize(spec)
        return (norm["shape"], norm["dtype"].name, norm["datapath"])

    def _build_service(self, norm: dict) -> DPRTService:
        return DPRTService(
            norm["shape"], norm["dtype"], max_batch=self.max_batch,
            warm_sizes=router_warm_sizes(max(norm["shape"]), self.max_batch),
            max_wait_us=self.max_wait_us, datapath=norm["datapath"],
            aot_dir=self.aot_dir, fallback=self.fallback, **norm["extra"])

    def _ensure_route(self, spec) -> _Route:
        norm = self._normalize(spec)
        key = (norm["shape"], norm["dtype"].name, norm["datapath"])
        route = self._routes.get(key)
        if route is not None:
            self._routes.move_to_end(key)     # LRU touch
            return route
        self._evict_for_capacity()
        route = _Route(key, self._build_service(norm))
        self._routes[key] = route
        if self._started:
            self._open_route(route)
        return route

    # -- backpressure ------------------------------------------------------
    #: fallback execution-time estimate for a route whose EWMA is cold
    _RETRY_AFTER_COLD_S = 0.05

    def _retry_after_s(self, route: Optional[_Route] = None) -> float:
        """The hint a :class:`QueueFull` rejection carries: estimated
        seconds until the congestion that refused this request drains
        -- queue depth in batches x the route's smoothed execution
        time.  With no route (router-wide budget exhausted), the
        worst live route stands in."""
        if route is not None:
            depth = route.inflight
            if route.queue is not None:
                depth += route.queue.qsize()
            per = route.exec_s or self._RETRY_AFTER_COLD_S
        else:
            depth = self._inflight
            per = max((r.exec_s for r in self._routes.values()
                       if r.exec_s is not None),
                      default=self._RETRY_AFTER_COLD_S)
        batches = depth // max(1, self.max_batch) + 1
        return round(batches * per, 6)

    # -- bounded residency -------------------------------------------------
    def _evict_for_capacity(self) -> None:
        while len(self._routes) >= self.max_services:
            victim = next((r for r in self._routes.values() if r.idle()),
                          None)
            if victim is None:
                self.rejected_admission["queue_full"] += 1
                raise QueueFull(
                    f"router at max_services={self.max_services} with "
                    "every route busy",
                    retry_after_s=self._retry_after_s())
            self._retire(victim)

    def _retire(self, route: _Route) -> None:
        """Retire one idle route: stop its batcher, fold its counters,
        and discard exactly the plans no surviving route shares -- the
        plan-cache evict hooks then drop the jitted appliers and AOT
        executables in lockstep."""
        del self._routes[route.key]
        if route.batcher is not None:
            route.batcher.cancel()
            route.batcher = None
        route.queue = None
        svc = route.service
        self._retired["requests"] += svc._requests_done
        self._retired["failures"] += svc._failures
        self._retired["fallback_uses"] += svc._fallback_uses
        if svc.persistent is not None:
            p = svc.persistent.stats()
            for k in self._PERSISTENT_KEYS:
                self._retired[f"persistent_{k}"] += p[k]
        live: set = set()
        for other in self._routes.values():
            live |= other.service.plans()
        plan_cache_discard(svc.plans() - live)
        self.evictions += 1

    # -- warm-pool prefill -------------------------------------------------
    def prefill(self, manifest: Sequence) -> list:
        """Warm one route per manifest entry (spec dicts), through the
        persistent AOT cache when ``aot_dir`` is set -- the boot path
        that makes first traffic hit compiled executables.  Callable
        before :meth:`start` (synchronous warmup) or after (blocks the
        caller, not the loop).  Returns per-route warmup info."""
        infos = []
        for spec in manifest:
            route = self._ensure_route(spec)
            if not route.service.warmed:
                infos.append(route.service.warmup())
            if route.ready is not None and route.service.warmed:
                route.ready.set()
        return infos

    # -- loop lifecycle ----------------------------------------------------
    async def start(self) -> None:
        """Bind to the running event loop: create queues + batchers for
        every existing route (idempotent)."""
        if self._started:
            return
        self._closing = False
        for route in self._routes.values():
            self._open_route(route)
        self._started = True

    def _open_route(self, route: _Route) -> None:
        route.queue = asyncio.PriorityQueue()
        route.ready = asyncio.Event()
        if route.service.warmed:
            route.ready.set()
        else:
            route.warm_task = asyncio.create_task(self._warm(route))
        route.batcher = asyncio.create_task(self._route_batcher(route))

    async def _warm(self, route: _Route) -> None:
        try:
            await asyncio.to_thread(route.service.warmup)
        except Exception as e:        # warmup failure: the route is dead,
            route.error = e           # its requests fail typed-raw below
        finally:
            route.ready.set()

    async def shutdown(self) -> None:
        """Drain on shutdown: stop the batchers, let in-flight
        dispatches finish, reject everything still queued with the
        typed :class:`ServiceShutdown`.  The router object stays warm
        (routes and executables survive) for the next :meth:`start`."""
        if not self._started:
            return
        self._closing = True
        for route in self._routes.values():
            if route.batcher is not None:
                route.batcher.cancel()
        for route in self._routes.values():
            if route.batcher is not None:
                try:
                    await route.batcher
                except asyncio.CancelledError:
                    pass
                route.batcher = None
            if route.warm_task is not None:
                try:
                    await route.warm_task
                except asyncio.CancelledError:
                    pass
                route.warm_task = None
        if self._dispatch_tasks:
            await asyncio.gather(*list(self._dispatch_tasks),
                                 return_exceptions=True)
        for route in self._routes.values():
            self._reject_queued(route)
            route.queue = None
            route.ready = None
        self._started = False
        self._closing = False

    def _reject_requests(self, route: _Route, requests) -> None:
        for r in requests:
            if not r.future.done():
                r.future.set_exception(ServiceShutdown(
                    f"router shut down with the request for "
                    f"{route.label} still queued"))
                self.rejected_shutdown += 1

    def _reject_queued(self, route: _Route) -> None:
        if route.queue is None:
            return
        while True:
            try:
                _, _, r = route.queue.get_nowait()
            except asyncio.QueueEmpty:
                return
            self._reject_requests(route, (r,))

    # -- admission ---------------------------------------------------------
    def submit_nowait(self, spec, payload, *, deadline_s: Optional[float]
                      = None, priority: int = 0) -> asyncio.Future:
        """Admit one request (must run on the loop :meth:`start` ran
        on); returns the future of its result.  Raises the typed
        :class:`QueueFull` / :class:`DeadlineExceeded` /
        :class:`ServiceShutdown` instead of queuing work it cannot
        honor."""
        if not self._started or self._closing:
            raise ServiceShutdown("router is not running")
        route = self._ensure_route(spec)
        svc = route.service
        payload = np.asarray(payload)
        if payload.shape != svc.request_shape:
            raise ValueError(f"request shape {payload.shape} != route "
                             f"{route.label} contract {svc.request_shape}")
        if payload.dtype != np.dtype(svc.request_dtype.name):
            raise ValueError(f"request dtype {payload.dtype} != route "
                             f"{route.label} contract "
                             f"{svc.request_dtype.name}")
        if self._inflight >= self.max_inflight:
            self.rejected_admission["queue_full"] += 1
            raise QueueFull(f"global in-flight budget "
                            f"{self.max_inflight} exhausted",
                            retry_after_s=self._retry_after_s())
        if route.queue.qsize() >= self.queue_cap:
            self.rejected_admission["queue_full"] += 1
            raise QueueFull(f"queue for {route.label} at cap "
                            f"{self.queue_cap}",
                            retry_after_s=self._retry_after_s(route))
        loop = asyncio.get_running_loop()
        now = loop.time()
        deadline = None
        if deadline_s is not None:
            if deadline_s <= 0:
                self.rejected_admission["deadline_exceeded"] += 1
                raise DeadlineExceeded(
                    f"deadline_s={deadline_s} already passed at admission")
            deadline = now + float(deadline_s)
        fut = loop.create_future()
        self.admitted += 1
        self._inflight += 1
        fut.add_done_callback(self._dec_inflight)
        route.seq += 1
        route.queue.put_nowait((-int(priority), route.seq,
                                _Routed(payload, fut, now, deadline,
                                        priority)))
        self.queue_depth_max = max(self.queue_depth_max,
                                   route.queue.qsize())
        return fut

    def _dec_inflight(self, _fut) -> None:
        self._inflight -= 1

    async def submit(self, spec, payload, *, deadline_s: Optional[float]
                     = None, priority: int = 0) -> np.ndarray:
        """Admit one request and await its result."""
        await self.start()
        return await self.submit_nowait(spec, payload,
                                        deadline_s=deadline_s,
                                        priority=priority)

    # -- batching / dispatch -----------------------------------------------
    async def _route_batcher(self, route: _Route) -> None:
        await route.ready.wait()
        if route.error is not None:   # dead route: fail traffic fast
            while True:
                _, _, r = await route.queue.get()
                if not r.future.done():
                    self.failed += 1
                    r.future.set_exception(route.error)
        while True:
            _, _, first = await route.queue.get()
            # account for the forming batch immediately: requests pulled
            # off the queue must keep the route non-idle (and safe from
            # LRU eviction) while _collect awaits stragglers
            route.inflight += 1
            batch = [first]
            try:
                await self._collect(route, batch)
            except asyncio.CancelledError:
                # shutdown/retirement landed while the batch was still
                # forming: these requests left the queue, so the
                # queue-drain rejection cannot reach them -- reject
                # typed here, a future must ALWAYS resolve
                self._reject_requests(route, batch)
                route.inflight -= len(batch)
                raise
            except Exception:   # batcher bug: don't strand the batch
                self._reject_requests(route, batch)
                route.inflight -= len(batch)
                raise
            task = asyncio.create_task(self._dispatch(route, batch))
            self._dispatch_tasks.add(task)
            task.add_done_callback(self._dispatch_tasks.discard)

    async def _collect(self, route: _Route, batch: list) -> list:
        """Coalesce up to the route's max batch, bounded by
        ``max_wait_us`` AND by the tightest admitted deadline: the
        group flushes early when the oldest request's slack (deadline
        minus the route's smoothed execution time) is about to run
        out."""
        loop = asyncio.get_running_loop()
        cap = route.service.max_batch
        admission_deadline = loop.time() + self.max_wait_us * 1e-6
        while len(batch) < cap:
            try:
                batch.append(route.queue.get_nowait()[2])
                route.inflight += 1
                continue
            except asyncio.QueueEmpty:
                pass
            now = loop.time()
            wait = admission_deadline - now
            # flush with a safety margin beyond the smoothed execution
            # time: with a cold EWMA (est == 0) the group would
            # otherwise flush exactly AT the deadline and arrive at
            # dispatch already expired
            est = (route.exec_s or 0.0) + _FLUSH_MARGIN_S
            for r in batch:
                if r.deadline is not None:
                    wait = min(wait, r.deadline - est - now)
            if wait <= 0:
                break
            try:
                batch.append(
                    (await asyncio.wait_for(route.queue.get(), wait))[2])
                route.inflight += 1
            except asyncio.TimeoutError:
                break
        return batch

    async def _dispatch(self, route: _Route, batch: list) -> None:
        loop = asyncio.get_running_loop()
        try:
            now = loop.time()
            live = []
            for r in batch:
                if r.deadline is not None and now > r.deadline:
                    # reject-not-hang: serving it late helps nobody and
                    # steals batch slots from requests that can still
                    # make their SLO
                    if not r.future.done():
                        self.rejected_deadline += 1
                        r.future.set_exception(DeadlineExceeded(
                            f"request for {route.label} missed its "
                            f"deadline before dispatch"))
                else:
                    live.append(r)
            if not live:
                return
            stack = np.stack([r.payload for r in live])
            out = await self._execute(route, stack)
            now = loop.time()
            for i, r in enumerate(live):
                if not r.future.done():
                    self._latencies.append(now - r.t_enqueue)
                    self.delivered += 1
                    r.future.set_result(out[i])
        except Exception as e:
            for r in batch:
                if not r.future.done():
                    self.failed += 1
                    r.future.set_exception(e)
        finally:
            route.inflight -= len(batch)

    async def _execute(self, route: _Route, stack: np.ndarray) -> np.ndarray:
        """One admitted stack through the primary executables with
        timeout + retry/backoff; exhausted retries degrade to the
        route's bit-exact fallback applier."""
        delay = self.retry_backoff_s
        last: Optional[BaseException] = None
        for attempt in range(self.max_retries + 1):
            t0 = time.perf_counter()
            try:
                out = await asyncio.wait_for(
                    asyncio.to_thread(route.service.execute, stack),
                    self.dispatch_timeout_s)
                dt = time.perf_counter() - t0
                route.exec_s = (dt if route.exec_s is None
                                else 0.7 * route.exec_s + 0.3 * dt)
                return out
            except (Exception, asyncio.TimeoutError) as e:
                last = e
            if attempt < self.max_retries:
                self.retries += 1
                await asyncio.sleep(delay)
                delay *= 2
        self.fallbacks += 1
        try:
            return await asyncio.wait_for(
                asyncio.to_thread(route.service.execute_fallback, stack),
                self.dispatch_timeout_s)
        except (Exception, asyncio.TimeoutError) as e:
            raise e from last

    # -- synchronous driver ------------------------------------------------
    def run_requests(self, requests: Sequence, arrival_us: float = 0.0,
                     repeats: int = 1) -> list:
        """Serve ``requests`` -- ``(spec, payload)`` or ``(spec,
        payload, kwargs)`` tuples -- as concurrent routed traffic and
        return per-request results in order; a typed rejection comes
        back as the exception instance, not a raise.  ``repeats``
        replays the traffic on one loop (per-pass wall seconds land in
        ``self.last_pass_walls``)."""
        reqs = [(r if len(r) == 3 else (r[0], r[1], {})) for r in requests]

        async def driver():
            await self.start()

            async def one(i, spec, payload, kw):
                if arrival_us > 0:
                    await asyncio.sleep(i * arrival_us * 1e-6)
                try:
                    fut = self.submit_nowait(spec, payload, **kw)
                except ServiceError as e:
                    return e
                try:
                    return await fut
                except (ServiceError, Exception) as e:
                    return e

            walls, results = [], None
            try:
                for _ in range(max(1, repeats)):
                    t0 = time.perf_counter()
                    results = await asyncio.gather(
                        *(one(i, s, p, kw)
                          for i, (s, p, kw) in enumerate(reqs)))
                    walls.append(time.perf_counter() - t0)
            finally:
                await self.shutdown()
            return results, walls

        results, walls = asyncio.run(driver())
        self.last_pass_walls = walls
        return results

    # -- observability -----------------------------------------------------
    def pending(self) -> int:
        """Admitted futures not yet resolved (0 after shutdown, always:
        the drop-a-future count the chaos suite asserts on)."""
        return self._inflight

    def degraded_compiles(self) -> int:
        total = int(self._retired["persistent_degraded_compiles"])
        for route in self._routes.values():
            if route.service.persistent is not None:
                total += route.service.persistent.degraded_compiles
        return total

    _PERSISTENT_KEYS = ("hits", "misses", "errors", "degraded_compiles",
                        "lock_steals", "lock_degraded")

    def persistent_stats(self) -> Dict[str, int]:
        """Aggregated persistent-AOT-cache counters across every route
        (live and retired) -- what a pool worker reports in its healthz
        reply, and what the cross-process coalescing assertion sums:
        total ``misses`` over all workers must equal the number of
        distinct blobs on disk."""
        out = {k: int(self._retired[f"persistent_{k}"])
               for k in self._PERSISTENT_KEYS}
        for route in self._routes.values():
            p = route.service.persistent
            if p is not None:
                s = p.stats()
                for k in self._PERSISTENT_KEYS:
                    out[k] += int(s[k])
        return out

    def stats(self) -> Dict[str, object]:
        rejected = {
            "deadline_exceeded": self.rejected_deadline
            + self.rejected_admission["deadline_exceeded"],
            "queue_full": int(self.rejected_admission["queue_full"]),
            "shutdown": self.rejected_shutdown
            + self.rejected_admission["shutdown"],
        }
        fallback_uses = int(self._retired["fallback_uses"]) + sum(
            r.service._fallback_uses for r in self._routes.values())
        return {
            "verdict": self.verdict(),
            "routes": {r.label: {
                "queue": r.queue.qsize() if r.queue is not None else 0,
                "inflight": r.inflight,
                "warmed": r.service.warmed,
                "requests": r.service._requests_done,
                "exec_ms": (None if r.exec_s is None
                            else 1e3 * r.exec_s),
                "warm_sizes": r.service.sizes,
            } for r in self._routes.values()},
            "max_services": self.max_services,
            "queue_cap": self.queue_cap,
            "max_inflight": self.max_inflight,
            "admitted": self.admitted,
            "delivered": self.delivered,
            "failed": self.failed,
            "pending": self.pending(),
            "rejected": rejected,
            "retries": self.retries,
            "fallbacks": self.fallbacks,
            "fallback_uses": fallback_uses,
            "evictions": self.evictions,
            "degraded_compiles": self.degraded_compiles(),
            "queue_depth_max": self.queue_depth_max,
            "latency": latency_summary(self._latencies),
            "plan_cache": plan_cache_info()._asdict(),
        }

    def verdict(self) -> str:
        """``FAIL``: work was dropped or failed raw (wrongness).
        ``WARN``: every answer was exact or a typed rejection, but a
        degradation happened (retry, fallback, degraded compile,
        rejection, eviction).  ``OK``: clean."""
        if self.failed > 0:
            return "FAIL"
        if not self._started and self.pending() > 0:
            return "FAIL"              # a shut-down router owes nothing
        degradations = (
            self.retries + self.fallbacks + self.evictions
            + self.rejected_deadline + self.rejected_shutdown
            + sum(self.rejected_admission.values())
            + self.degraded_compiles())
        return "WARN" if degradations else "OK"

    def healthz(self) -> str:
        """The routed ``/healthz`` report: one verdict line, the
        degradation ledger, per-route lines, latency + plan-cache."""
        s = self.stats()
        rej = s["rejected"]
        lines = [
            f"[healthz] {s['verdict']} router "
            f"routes={len(s['routes'])}/{s['max_services']} "
            f"admitted={s['admitted']} delivered={s['delivered']} "
            f"failed={s['failed']} pending={s['pending']}",
            f"[healthz] rejected deadline={rej['deadline_exceeded']} "
            f"queue_full={rej['queue_full']} shutdown={rej['shutdown']} "
            f"(queue_cap={s['queue_cap']} "
            f"max_inflight={s['max_inflight']})",
            f"[healthz] degraded retries={s['retries']} "
            f"fallbacks={s['fallbacks']} "
            f"fallback_uses={s['fallback_uses']} "
            f"evictions={s['evictions']} "
            f"degraded_compiles={s['degraded_compiles']}",
        ]
        for label, r in s["routes"].items():
            exec_ms = ("-" if r["exec_ms"] is None
                       else f"{r['exec_ms']:.2f}ms")
            lines.append(
                f"[healthz] route {label} warmed={r['warmed']} "
                f"queue={r['queue']} inflight={r['inflight']} "
                f"requests={r['requests']} exec={exec_ms} "
                f"warm_sizes={tuple(r['warm_sizes'])}")
        lines.append("[healthz] " + format_latency(s["latency"]))
        lines.append(
            "[healthz] plan_cache hits={hits} misses={misses} "
            "currsize={currsize} evictions={evictions}".format(
                **s["plan_cache"]))
        return "\n".join(lines)

    def __repr__(self) -> str:
        return (f"ServiceRouter(routes={len(self._routes)}/"
                f"{self.max_services}, admitted={self.admitted}, "
                f"verdict={self.verdict()!r})")


# ---------------------------------------------------------------------------
# stdin-jsonl transport front-end
# ---------------------------------------------------------------------------
def serve_jsonl(router: ServiceRouter, infile, outfile, *,
                framed: bool = False, sigterm_drain: bool = False) -> None:
    """Newline-delimited JSON worker over ``router.submit()``.

    Requests: ``{"op": "submit", "id": …, "n"/"shape": …, ["dtype": …,]
    ["datapath": …,] "data": nested-list, ["deadline_ms": …,]
    ["priority": …]}`` -- plus ``{"op": "healthz"}`` and
    ``{"op": "shutdown"}``.  Responses carry ``"ok": true`` with
    ``"data"``, or ``"ok": false`` with the typed ``"error"`` code (and
    its ``retry_after_s`` backpressure hint when set) -- a malformed
    line is answered, never fatal.  EOF drains and shuts the router
    down (queued work rejected typed, like any shutdown).

    ``framed=True`` switches both directions to the length-prefixed
    frames of :mod:`repro.launch.pool` -- the supervisor's wire format,
    where a SIGKILL mid-write must read as truncation, not as a mangled
    message.  ``sigterm_drain=True`` installs a SIGTERM handler that
    drains instead of dying mid-batch: stop reading stdin, flush every
    in-flight request, emit one final unsolicited healthz frame
    (``"id": "__drain__"``), then return.
    """
    from repro.launch.pool import read_frame, write_frame

    def reply(obj: dict) -> None:
        if framed:
            write_frame(outfile, obj)
        else:
            outfile.write(json.dumps(obj) + "\n")
            outfile.flush()

    def error_payload(rid, e: ServiceError) -> dict:
        obj = {"id": rid, "ok": False, "error": e.code, "msg": str(e)}
        if e.retry_after_s is not None:
            obj["retry_after_s"] = e.retry_after_s
        return obj

    def healthz_payload(rid, trace_baseline: int, *,
                        final: bool = False) -> dict:
        from repro.radon import trace_count
        s = router.stats()
        obj = {"id": rid, "ok": True, "verdict": s["verdict"],
               "pid": os.getpid(),
               "stats": {"admitted": s["admitted"],
                         "delivered": s["delivered"],
                         "failed": s["failed"],
                         "rejected": sum(s["rejected"].values()),
                         "pending": s["pending"]},
               # steady-state retrace count: traces SINCE the worker
               # finished its prefill (warmup itself legitimately
               # traces) -- the pool's "warm, zero retraces" assertion
               "retraces_since_start": trace_count() - trace_baseline,
               "persistent": router.persistent_stats(),
               "faults_env": os.environ.get("REPRO_FAULTS") or None,
               "healthz": router.healthz()}
        if final:
            obj["final"] = True
        return obj

    async def answer(rid, fut) -> None:
        try:
            out = await fut
            reply({"id": rid, "ok": True, "data": np.asarray(out).tolist()})
        except ServiceError as e:
            reply(error_payload(rid, e))
        except Exception as e:                    # raw failure: surfaced
            reply({"id": rid, "ok": False, "error": "internal",
                   "msg": str(e)})

    async def main() -> None:
        from repro.radon import trace_count
        await router.start()
        trace_baseline = trace_count()
        answers: set = set()
        loop = asyncio.get_running_loop()
        inq: asyncio.Queue = asyncio.Queue()
        drained_by_sigterm = False

        def pump() -> None:
            # a daemon thread owns the blocking reads: asyncio.run
            # would join a to_thread readline forever on drain, and a
            # signal can't interrupt it -- a daemon thread it simply
            # abandons.  The sentinel None is EOF (or torn frame).
            try:
                while True:
                    if framed:
                        msg = read_frame(infile)
                        if msg is None:
                            break
                    else:
                        line = infile.readline()
                        if not line:
                            break
                        line = line.strip()
                        if not line:
                            continue
                        try:
                            msg = json.loads(line)
                        except ValueError:
                            msg = {"op": "__bad_json__"}
                    loop.call_soon_threadsafe(inq.put_nowait, msg)
            except Exception:
                pass                   # corrupt stream reads as EOF
            try:
                loop.call_soon_threadsafe(inq.put_nowait, None)
            except RuntimeError:
                pass                   # loop already gone

        def on_sigterm() -> None:
            nonlocal drained_by_sigterm
            drained_by_sigterm = True
            inq.put_nowait(None)       # stop consuming stdin, drain

        if sigterm_drain:
            loop.add_signal_handler(signal.SIGTERM, on_sigterm)
        reader = threading.Thread(target=pump, daemon=True)
        reader.start()
        try:
            while True:
                msg = await inq.get()
                if msg is None:
                    break
                rid = msg.get("id")
                op = msg.get("op", "submit")
                if op == "__bad_json__":
                    reply({"ok": False, "error": "bad_json"})
                elif op == "healthz":
                    reply(healthz_payload(rid, trace_baseline))
                elif op == "shutdown":
                    reply({"id": rid, "ok": True, "shutdown": True})
                    break
                elif op == "submit":
                    try:
                        spec = {k: msg[k] for k in
                                ("n", "shape", "dtype", "datapath")
                                if k in msg}
                        # the per-request dtype contract is the ROUTE's
                        # (inverse/solve consume accumulator-dtype
                        # projections, not images)
                        route = router._ensure_route(spec)
                        payload = np.asarray(
                            msg["data"],
                            dtype=route.service.request_dtype.name)
                        deadline_ms = msg.get("deadline_ms")
                        fut = router.submit_nowait(
                            spec, payload,
                            deadline_s=(None if deadline_ms is None
                                        else float(deadline_ms) * 1e-3),
                            priority=int(msg.get("priority", 0)))
                    except ServiceError as e:
                        reply(error_payload(rid, e))
                    except (KeyError, TypeError, ValueError) as e:
                        reply({"id": rid, "ok": False,
                               "error": "bad_request", "msg": str(e)})
                    else:
                        t = asyncio.create_task(answer(rid, fut))
                        answers.add(t)
                        t.add_done_callback(answers.discard)
                else:
                    reply({"id": rid, "ok": False, "error": "bad_request",
                           "msg": f"unknown op {op!r}"})
            if answers:
                await asyncio.gather(*answers, return_exceptions=True)
            await router.shutdown()
            if drained_by_sigterm:
                reply(healthz_payload("__drain__", trace_baseline,
                                      final=True))
        finally:
            if sigterm_drain:
                loop.remove_signal_handler(signal.SIGTERM)

    asyncio.run(main())
