"""Step builders: pjit'd train / prefill / decode with full shardings.

Each builder returns ``(jitted_fn, arg_structs)`` ready for both real
execution and ``.lower(*structs).compile()`` AOT dry-runs.  All lowering
must happen inside ``with activate_mesh(mesh):`` so that in-model
``shard_act`` constraints bind to the mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import Model, ModelConfig
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               cosine_schedule, zero1_shardings)
from repro.parallel.sharding import abstract_params, param_shardings
from . import shapes as shp

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step"]


def _opt_structs(p_struct):
    return {"mu": jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32),
                p_struct),
            "nu": jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, jnp.float32),
                p_struct),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def make_train_step(model: Model, mesh: Mesh, shape: str,
                    lr: float = 3e-4, total_steps: int = 10000,
                    param_dtype=jnp.bfloat16):
    cfg = model.cfg
    specs = model.specs()
    p_shard = param_shardings(specs, mesh)
    p_struct = abstract_params(specs, param_dtype)
    o_struct = _opt_structs(p_struct)
    o_shard = {"mu": zero1_shardings(p_shard, p_struct, mesh),
               "nu": zero1_shardings(p_shard, p_struct, mesh),
               "step": NamedSharding(mesh, P())}
    b_struct = shp.batch_structs(cfg, shape, with_labels=True)
    b_shard = shp.batch_shardings(b_struct, mesh)

    opt_cfg = AdamWConfig(lr=lr)
    sched = cosine_schedule(lr, 100, total_steps)

    def train_step(params, opt, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        params, opt, metrics = adamw_update(params, grads, opt, opt_cfg,
                                            sched)
        metrics.update(loss=loss, **parts)
        return params, opt, metrics

    fn = jax.jit(train_step,
                 in_shardings=(p_shard, o_shard, b_shard),
                 out_shardings=(p_shard, o_shard, None),
                 donate_argnums=(0, 1))
    return fn, (p_struct, o_struct, b_struct)


def make_prefill_step(model: Model, mesh: Mesh, shape: str,
                      param_dtype=jnp.bfloat16):
    cfg = model.cfg
    specs = model.specs()
    p_shard = param_shardings(specs, mesh)
    p_struct = abstract_params(specs, param_dtype)
    b_struct = shp.batch_structs(cfg, shape, with_labels=False)
    b_shard = shp.batch_shardings(b_struct, mesh)
    seq = shp.SHAPES[shape]["seq"]
    batch = shp.SHAPES[shape]["batch"]
    c_struct = shp.cache_structs(model, batch, seq)
    c_shard = shp.cache_shardings(c_struct, mesh)

    def prefill(params, b):
        logits, cache = model.prefill(params, b)
        return logits, cache

    fn = jax.jit(prefill, in_shardings=(p_shard, b_shard),
                 out_shardings=(None, c_shard))
    return fn, (p_struct, b_struct)


def make_decode_step(model: Model, mesh: Mesh, shape: str,
                     param_dtype=jnp.bfloat16):
    cfg = model.cfg
    specs = model.specs()
    p_shard = param_shardings(specs, mesh)
    p_struct = abstract_params(specs, param_dtype)
    seq = shp.SHAPES[shape]["seq"]
    batch = shp.SHAPES[shape]["batch"]
    c_struct = shp.cache_structs(model, batch, seq)
    c_shard = shp.cache_shardings(c_struct, mesh)
    tok_struct, pos_struct = shp.decode_token_structs(cfg, shape)
    bt = shp._bt(mesh)
    tok_shard = NamedSharding(
        mesh, prune_pspec_like(tok_struct.shape, bt, mesh))

    def decode(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    fn = jax.jit(decode,
                 in_shardings=(p_shard, c_shard, tok_shard,
                               NamedSharding(mesh, P())),
                 out_shardings=(None, c_shard),
                 donate_argnums=(1,))
    return fn, (p_struct, c_struct, tok_struct, pos_struct)


def prune_pspec_like(shape, bt, mesh):
    from repro.parallel.sharding import prune_pspec
    spec = P(bt, *([None] * (len(shape) - 1)))
    return prune_pspec(spec, shape, mesh)
