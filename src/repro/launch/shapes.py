"""Assigned input shapes and abstract input specs for every (arch x shape).

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for the dry-run and AOT compilation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import Model, ModelConfig
from repro.parallel.sharding import prune_pspec

__all__ = ["SHAPES", "shape_applicable", "batch_structs", "batch_shardings",
           "cache_structs", "cache_shardings", "decode_token_structs"]

SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k":    {"seq": 4096,   "batch": 256, "kind": "train"},
    "prefill_32k": {"seq": 32768,  "batch": 32,  "kind": "prefill"},
    "decode_32k":  {"seq": 32768,  "batch": 128, "kind": "decode"},
    "long_500k":   {"seq": 524288, "batch": 1,   "kind": "decode"},
}

# long_500k needs sub-quadratic sequence mixing: SSM / hybrid only.
_LONG_OK_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and cfg.family not in _LONG_OK_FAMILIES:
        return False, (f"{cfg.name} is full-attention ({cfg.family}); "
                       "524k-token decode requires sub-quadratic mixing "
                       "(skip noted in DESIGN.md)")
    return True, ""


def _bt(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def batch_structs(cfg: ModelConfig, shape: str, with_labels: bool):
    s = SHAPES[shape]
    b, q = s["batch"], s["seq"]
    out = {"tokens": jax.ShapeDtypeStruct((b, q), jnp.int32)}
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((b, q), jnp.int32)
    if cfg.frontend == "patch_stub":
        out["patch_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "audio_stub":
        out["audio_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return out


def batch_shardings(structs, mesh: Mesh):
    bt = _bt(mesh)

    def one(sds):
        spec = P(bt, *([None] * (len(sds.shape) - 1)))
        return NamedSharding(mesh, prune_pspec(spec, sds.shape, mesh))

    return jax.tree.map(one, structs)


def cache_structs(model: Model, batch: int, max_len: int):
    return model.init_cache(
        batch, max_len,
        factory=lambda sh, dt: jax.ShapeDtypeStruct(sh, dt))


_CACHE_SPEC = {
    # leaf name -> per-dim mesh-axis candidates (after the batch dim)
    "k": (None, "model", None, None),
    "v": (None, "model", None, None),
    "c_kv": (None, "model", None),
    "k_rope": (None, "model", None),
    "conv": (None, None, "model"),
    "h": (None, "model"),
    "ssm": (None, "model", None, None),
}


def cache_shardings(cache_struct, mesh: Mesh):
    bt = _bt(mesh)

    def one(path, sds):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        base = list(_CACHE_SPEC.get(name, (None,) * len(sds.shape)))
        base[0] = bt                        # batch dim
        stacked = len(sds.shape) == len(base) + 1
        spec = P(*([None] + base)) if stacked else P(*base)
        return NamedSharding(mesh, prune_pspec(spec, sds.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_struct)


def decode_token_structs(cfg: ModelConfig, shape: str):
    b = SHAPES[shape]["batch"]
    return (jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
