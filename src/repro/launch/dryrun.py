import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + os.environ.get("REPRO_DRYRUN_DEVICES", "512")

"""Multi-pod dry-run: prove every (arch x shape x mesh) lowers, partitions,
and compiles for the production meshes, and extract roofline inputs.

The two lines above run before ANY other import: jax locks the device
count at first init.  Smoke tests / benches must NOT import this module.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-medium-14b \
        --shape train_4k --mesh single --outdir experiments/dryrun
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, ALIASES, get_config      # noqa: E402
from repro.models import Model                               # noqa: E402
from repro.parallel.sharding import activate_mesh            # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.launch import shapes as shp                       # noqa: E402
from repro.launch import steps as steps_mod                  # noqa: E402
from repro.launch import roofline as rl                      # noqa: E402


def run_cell(arch: str, shape: str, multi_pod: bool, outdir: str,
             mesh=None, overrides=None, rules=None, tag="") -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    model = Model(cfg)
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    chips = mesh.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    ok, reason = shp.shape_applicable(cfg, shape)
    result = {"arch": arch + (f"+{tag}" if tag else ""), "shape": shape,
              "mesh": mesh_name, "chips": chips, "status": "skipped",
              "reason": reason, "overrides": overrides or {}}
    if not ok:
        return _emit(result, outdir)

    kind = shp.SHAPES[shape]["kind"]
    t0 = time.time()
    try:
        if kind == "train":
            fn, structs = steps_mod.make_train_step(model, mesh, shape)
        elif kind == "prefill":
            fn, structs = steps_mod.make_prefill_step(model, mesh, shape)
        else:
            fn, structs = steps_mod.make_decode_step(model, mesh, shape)

        with activate_mesh(mesh, rules):
            lowered = fn.lower(*structs)
            compiled = lowered.compile()

        # XLA counts while bodies once; the trip-count-aware walker fixes
        # scanned stacks (layers, kv chunks, SSD chunks).  Raw numbers are
        # kept alongside for reference.
        from repro.launch.hlo_cost import analyze_hlo, compiled_cost_dict
        cost = compiled_cost_dict(compiled)
        hc = analyze_hlo(compiled.as_text())
        flops = float(hc["flops"])
        nbytes = float(hc["bytes"])
        try:
            mem = compiled.memory_analysis()
            mem_info = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes",
                                      getattr(mem, "temp_size_in_bytes", 0)),
            }
        except Exception as e:  # backend-dependent
            mem_info = {"error": str(e)}

        coll = {k: v for k, v in hc.items() if k.startswith("coll_")}
        coll["total"] = float(hc["coll_bytes"])
        result["bytes_by_op_unscaled"] = hc.get("bytes_by_op_unscaled", {})
        coll["flat_module"] = rl.parse_collectives(compiled.as_text())
        terms = rl.roofline_terms(flops, nbytes, coll["total"], chips)

        tokens = shp.SHAPES[shape]["batch"] * (
            shp.SHAPES[shape]["seq"] if kind != "decode" else 1)
        mflops, n_total, n_active = rl.model_flops(
            cfg, model.specs(), tokens, "train" if kind == "train" else
            "inference")

        decode_ideal = None
        if kind == "decode":
            # decode is memory-bound by construction: the floor is reading
            # every param shard + the cache once per step
            import numpy as _np
            model_axis = dict(zip(mesh.axis_names, mesh.devices.shape)).get(
                "model", 1)
            cache_bytes = sum(
                int(_np.prod(l.shape)) * l.dtype.itemsize
                for l in jax.tree.leaves(structs[1]))
            active_bytes = n_active * 2  # bf16
            ideal_per_dev = (active_bytes / model_axis
                             + cache_bytes / chips)
            ideal_s = ideal_per_dev / rl.HW["hbm_bw"]
            decode_ideal = {
                "cache_bytes_global": cache_bytes,
                "ideal_bytes_per_dev": ideal_per_dev,
                "ideal_memory_s": ideal_s,
                "fraction_of_modeled": (ideal_s / terms["memory_s"]
                                        if terms["memory_s"] else None),
            }
        global_flops = flops * chips
        result.update(
            status="ok", kind=kind, compile_s=round(time.time() - t0, 1),
            flops_per_dev=flops, bytes_per_dev=nbytes,
            raw_cost_analysis={"flops": float(cost.get("flops", 0.0)),
                               "bytes": float(cost.get("bytes accessed",
                                                       0.0))},
            collectives=coll, memory=mem_info, roofline=terms,
            tokens=tokens, params_total=n_total, params_active=n_active,
            model_flops=mflops, decode_ideal=decode_ideal,
            useful_flops_ratio=(mflops / global_flops
                                if global_flops else None),
        )
    except Exception as e:
        result.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:],
                      compile_s=round(time.time() - t0, 1))
    return _emit(result, outdir)


def _emit(result: dict, outdir: str) -> dict:
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        fname = f"{result['arch']}_{result['shape']}_{result['mesh']}.json"
        with open(os.path.join(outdir, fname), "w") as f:
            json.dump(result, f, indent=1, default=str)
    status = result["status"]
    extra = ""
    if status == "ok":
        t = result["roofline"]
        extra = (f" dom={t['dominant']} comp={t['compute_s']:.3e}s "
                 f"mem={t['memory_s']:.3e}s coll={t['collective_s']:.3e}s "
                 f"compile={result['compile_s']}s")
    elif status == "error":
        extra = " " + result["error"][:160]
    print(f"[dryrun] {result['arch']:22s} {result['shape']:12s} "
          f"mesh={result['mesh']:10s} {status}{extra}", flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--outdir", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [
        ALIASES.get(a, a) for a in args.arch.split(",")]
    shapes = list(shp.SHAPES) if args.shape == "all" else \
        args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_err = 0
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            for shape in shapes:
                r = run_cell(arch, shape, multi, args.outdir, mesh=mesh)
                n_err += r["status"] == "error"
    if n_err:
        raise SystemExit(f"{n_err} dry-run cells failed")
    print("[dryrun] all requested cells passed")


if __name__ == "__main__":
    main()
