"""Deterministic fault injection for the serving tier.

A robustness claim that was never exercised is a guess.  This module is
the seam the chaos suite drives: a context-managed
:class:`FaultInjector` that makes the serving stack misbehave in
exactly the ways production does -- kernel dispatch raising, dispatch
stalling past SLOs, AOT blobs corrupting on disk -- while staying fully
deterministic (explicit seed, explicit error budgets), so every chaos
test failure reproduces.

The seam itself is :func:`perturb`: the service's dispatch path calls
``perturb("dispatch", key=...)`` before running a kernel, and the
fallback path calls ``perturb("fallback", key=...)``.  With no injector
active (the production default) that is a single dict-free attribute
check -- no clock reads, no rng, no lock.

Queue floods need no seam: the chaos driver oversubmits through the
router's own bounded admission.  Blob corruption is an on-disk
operation: :func:`corrupt_blobs` deterministically tears/garbles every
``*.blob`` in a directory so the persistent-cache restore path has to
take its degraded cold-compile branch.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, Optional, Sequence

import numpy as np

__all__ = ["InjectedFault", "FaultInjector", "perturb", "active_injector",
           "corrupt_blobs", "install_from_env", "FAULTS_ENV_VAR"]

#: the env var subprocess workers read at startup to arm deterministic
#: chaos; the supervisor sets it, the worker echoes it in healthz.
FAULTS_ENV_VAR = "REPRO_FAULTS"


class InjectedFault(RuntimeError):
    """The synthetic exception :class:`FaultInjector` raises at a seam
    (stands in for a kernel/dispatch failure; never escapes a correctly
    degrading service)."""


_STACK: list = []                      # innermost-active injector last
_LOCK = threading.Lock()


def active_injector() -> Optional["FaultInjector"]:
    """The innermost active injector, or None (production)."""
    with _LOCK:
        return _STACK[-1] if _STACK else None


def perturb(site: str, key: Optional[str] = None) -> None:
    """The seam: no-op unless a :class:`FaultInjector` is active, else
    delegate to it (may sleep, may raise :class:`InjectedFault`)."""
    if not _STACK:                     # fast path: nothing installed
        return
    inj = active_injector()
    if inj is not None:
        inj.perturb(site, key)


class FaultInjector:
    """Context-managed deterministic fault source.

    ``error_count`` fires an :class:`InjectedFault` on exactly the first
    N matching :func:`perturb` calls (deterministic: exercise "retry
    twice then succeed" or "exhaust retries, fall back" precisely);
    ``error_rate`` adds seeded-random failures after the budget.
    ``delay_s``/``delay_rate`` injects dispatch stalls (SLO pressure).
    ``sites`` restricts which seams fire and ``match`` (substring of the
    seam key, e.g. ``"13x13"``) targets one routed geometry in a
    mixed-traffic chaos run.

    Injectors nest (innermost wins) and are thread-safe: seams run on
    ``asyncio.to_thread`` workers.
    """

    def __init__(self, seed: int = 0, *,
                 sites: Sequence[str] = ("dispatch",),
                 match: Optional[str] = None,
                 error_count: int = 0, error_rate: float = 0.0,
                 delay_s: float = 0.0, delay_rate: float = 1.0):
        if error_count < 0 or not 0.0 <= error_rate <= 1.0:
            raise ValueError("error_count must be >= 0 and error_rate in "
                             f"[0, 1], got {error_count}/{error_rate}")
        if delay_s < 0.0 or not 0.0 <= delay_rate <= 1.0:
            raise ValueError("delay_s must be >= 0 and delay_rate in "
                             f"[0, 1], got {delay_s}/{delay_rate}")
        self.seed = int(seed)
        self.sites = tuple(sites)
        self.match = match
        self.error_count = int(error_count)
        self.error_rate = float(error_rate)
        self.delay_s = float(delay_s)
        self.delay_rate = float(delay_rate)
        self.spec: Optional[str] = None   # set when built via from_spec
        self._rng = np.random.default_rng(self.seed)
        self._lock = threading.Lock()
        self.calls = 0
        self.injected_errors = 0
        self.injected_delays = 0

    # -- env-var activation (chaos across a process boundary) -------------
    @classmethod
    def from_spec(cls, spec: str) -> "FaultInjector":
        """Build an injector from a compact ``key=value`` spec string,
        e.g. ``"sites=dispatch|fallback;error_count=2;seed=7"``.

        The spec is how deterministic chaos crosses a fork/exec
        boundary: the supervisor can't hand a live object to a
        subprocess worker, but it can put this string in the
        environment.  Pairs are ``;``-separated; ``sites`` values are
        ``|``-separated; unknown keys raise (a typo'd chaos spec that
        silently arms nothing would invalidate the whole run).
        """
        kwargs: Dict[str, object] = {}
        for pair in spec.split(";"):
            pair = pair.strip()
            if not pair:
                continue
            if "=" not in pair:
                raise ValueError(f"bad fault spec fragment {pair!r} "
                                 f"(want key=value) in {spec!r}")
            key, val = (s.strip() for s in pair.split("=", 1))
            if key == "sites":
                kwargs[key] = tuple(s for s in val.split("|") if s)
            elif key == "match":
                kwargs[key] = val
            elif key in ("seed", "error_count"):
                kwargs[key] = int(val)
            elif key in ("error_rate", "delay_s", "delay_rate"):
                kwargs[key] = float(val)
            else:
                raise ValueError(f"unknown fault spec key {key!r} "
                                 f"in {spec!r}")
        seed = int(kwargs.pop("seed", 0))
        inj = cls(seed, **kwargs)      # type: ignore[arg-type]
        inj.spec = spec
        return inj

    # -- context management ------------------------------------------------
    def __enter__(self) -> "FaultInjector":
        with _LOCK:
            _STACK.append(self)
        return self

    def __exit__(self, *exc) -> None:
        with _LOCK:
            if self in _STACK:
                _STACK.remove(self)

    # -- the seam ----------------------------------------------------------
    def perturb(self, site: str, key: Optional[str] = None) -> None:
        if site not in self.sites:
            return
        if self.match is not None and (key is None or self.match not in key):
            return
        with self._lock:
            self.calls += 1
            delay = 0.0
            if self.delay_s > 0.0 and (self.delay_rate >= 1.0
                                       or self._rng.random()
                                       < self.delay_rate):
                delay = self.delay_s
                self.injected_delays += 1
            fire = self.injected_errors < self.error_count
            if not fire and self.error_rate > 0.0:
                fire = bool(self._rng.random() < self.error_rate)
            if fire:
                self.injected_errors += 1
                n = self.injected_errors
        if delay:
            time.sleep(delay)
        if fire:
            raise InjectedFault(
                f"injected fault #{n} at site {site!r}"
                + (f" key={key!r}" if key else ""))

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {"seed": self.seed, "sites": self.sites,
                    "match": self.match, "spec": self.spec,
                    "calls": self.calls,
                    "injected_errors": self.injected_errors,
                    "injected_delays": self.injected_delays}

    def __repr__(self) -> str:
        s = self.stats()
        return (f"FaultInjector(seed={s['seed']}, sites={s['sites']}, "
                f"errors={s['injected_errors']}, "
                f"delays={s['injected_delays']})")


def install_from_env(env_var: str = FAULTS_ENV_VAR
                     ) -> Optional[FaultInjector]:
    """Arm a :class:`FaultInjector` from the environment, if set.

    Called once at worker startup (``serve --jsonl``).  The injector is
    *entered* (pushed on the active stack) and returned so the worker
    can echo its spec in healthz; it stays armed for the process
    lifetime -- chaos workers die, they don't gracefully unwind.
    Returns ``None`` when the variable is unset or empty.
    """
    spec = os.environ.get(env_var, "").strip()
    if not spec:
        return None
    inj = FaultInjector.from_spec(spec)
    inj.__enter__()
    return inj


def corrupt_blobs(directory: str, *, seed: int = 0) -> int:
    """Deterministically corrupt every ``*.blob`` in ``directory``;
    returns the number corrupted.  Corruptions alternate between the two
    on-disk failure shapes the persistent cache must survive:

    * **torn write** -- the file truncated mid-payload (header/size
      mismatch, ``load_blob`` raises ``ValueError``);
    * **payload rot** -- header intact, payload overwritten with seeded
      random bytes (loads fine, ``import_executable`` fails).

    Both must degrade to a counted fresh compile, never to an outage.
    """
    rng = np.random.default_rng(seed)
    count = 0
    if not os.path.isdir(directory):
        return 0
    for fname in sorted(os.listdir(directory)):
        if not fname.endswith(".blob"):
            continue
        path = os.path.join(directory, fname)
        with open(path, "rb") as f:
            raw = bytearray(f.read())
        if len(raw) <= 8:
            continue
        hlen = int.from_bytes(bytes(raw[:8]), "big")
        body = 8 + hlen
        if count % 2 == 0 or body >= len(raw):
            raw = raw[:max(8, len(raw) // 2)]          # torn write
        else:                                          # payload rot
            raw[body:] = rng.integers(0, 256, size=len(raw) - body,
                                      dtype=np.uint8).tobytes()
        with open(path, "wb") as f:
            f.write(bytes(raw))
        count += 1
    return count
