"""Typed service errors shared by the serving tier.

Every rejection a caller can see carries a stable machine-readable
``code`` (the jsonl front-end and the chaos harness both key on it), so
"the router refused" is always distinguishable from "the kernel is
wrong".  The contract the fault-tolerance suite enforces is exactly:
every response is either bit-exact output or one of these.

This module sits below both :mod:`repro.launch.service` and
:mod:`repro.launch.router` (the router imports the service, so the
shared vocabulary cannot live in either).
"""
from __future__ import annotations

__all__ = ["ServiceError", "DeadlineExceeded", "QueueFull",
           "ServiceShutdown"]


class ServiceError(RuntimeError):
    """Base of every typed serving rejection; ``code`` is the stable
    wire identifier."""

    code = "service_error"


class DeadlineExceeded(ServiceError):
    """The request's SLO deadline passed (or provably cannot be met)
    before its batch dispatched -- rejected instead of served late."""

    code = "deadline_exceeded"


class QueueFull(ServiceError):
    """Bounded admission refused the request: the per-key queue cap or
    the router's global in-flight budget is exhausted."""

    code = "queue_full"


class ServiceShutdown(ServiceError):
    """The service/router is (shutting) down; the request was rejected
    rather than left as a forever-pending future."""

    code = "shutdown"
