"""Typed service errors shared by the serving tier.

Every rejection a caller can see carries a stable machine-readable
``code`` (the jsonl front-end and the chaos harness both key on it), so
"the router refused" is always distinguishable from "the kernel is
wrong".  The contract the fault-tolerance suite enforces is exactly:
every response is either bit-exact output or one of these.

This module sits below both :mod:`repro.launch.service` and
:mod:`repro.launch.router` (the router imports the service, so the
shared vocabulary cannot live in either), and below the multi-process
:mod:`repro.launch.supervisor` (worker loss is a typed event too).
"""
from __future__ import annotations

from typing import Optional

__all__ = ["ServiceError", "DeadlineExceeded", "QueueFull",
           "ServiceShutdown", "WorkerLost", "error_for_code"]


class ServiceError(RuntimeError):
    """Base of every typed serving rejection; ``code`` is the stable
    wire identifier.  ``retry_after_s``, when set, is the backpressure
    hint: how long the client should wait before retrying (derived from
    queue depth x the route's execution-time EWMA -- an estimate of when
    the congestion that caused this rejection will have drained, not a
    promise of admission)."""

    code = "service_error"
    retry_after_s: Optional[float] = None

    def __init__(self, *args, retry_after_s: Optional[float] = None):
        super().__init__(*args)
        if retry_after_s is not None:
            self.retry_after_s = float(retry_after_s)


class DeadlineExceeded(ServiceError):
    """The request's SLO deadline passed (or provably cannot be met)
    before its batch dispatched -- rejected instead of served late."""

    code = "deadline_exceeded"


class QueueFull(ServiceError):
    """Bounded admission refused the request: the per-key queue cap, the
    router's global in-flight budget, or the worker pool's pending
    budget is exhausted.  Carries ``retry_after_s`` when the rejecting
    tier can estimate its own drain time."""

    code = "queue_full"


class ServiceShutdown(ServiceError):
    """The service/router is (shutting) down; the request was rejected
    rather than left as a forever-pending future."""

    code = "shutdown"


class WorkerLost(ServiceError):
    """A worker *process* died with this request in flight and the
    one-shot replay could not deliver it (no healthy worker, or the
    request already used its replay).  The typed, recoverable form of
    "the machine serving you crashed" -- never a silent drop."""

    code = "worker_lost"


#: wire code -> exception class; the supervisor rehydrates typed worker
#: rejections through this so a pool client sees the same exception
#: types an in-process router caller would.
_CODE_MAP = {cls.code: cls for cls in
             (ServiceError, DeadlineExceeded, QueueFull, ServiceShutdown,
              WorkerLost)}


def error_for_code(code: str, msg: str,
                   retry_after_s: Optional[float] = None) -> ServiceError:
    """Rebuild the typed exception a remote worker serialized as
    ``{"error": code, "msg": …}``; unknown codes come back as the base
    :class:`ServiceError` (still typed, still not a raw failure)."""
    cls = _CODE_MAP.get(code, ServiceError)
    err = cls(msg)
    if retry_after_s is not None:
        err.retry_after_s = float(retry_after_s)
    return err
