"""Async dynamic-batching DPRT service tier.

The paper's architecture exists to push throughput -- up to N^2
additions per cycle -- and the repo's fused batched kernels realize
that as a 2.5-7.5x per-image efficiency win for B=16 stacks over
single-image calls (``BENCH_dprt.json``).  A synchronous per-request
entry point forfeits that win for concurrent single-image traffic;
this module is the front-end that recovers it:

* **Admission queue.**  Concurrent single-image requests land on an
  ``asyncio`` queue; the batcher coalesces up to ``max_batch`` of them,
  waiting at most ``max_wait_us`` after the first arrival (latency
  bound), then drains whatever else is already queued for free.
* **Warm-size padding.**  A coalesced group is padded with zero images
  up to the nearest *warm batch size*
  (:func:`repro.kernels.tuning.warm_batch_sizes`), so every admitted
  group hits one of a small, pre-compiled set of AOT executables --
  no shape ever compiles at serving time.  Results are sliced back
  per request.
* **Persistent AOT cache.**  :meth:`DPRTService.warmup` compiles the
  warm-size executables through a
  :class:`repro.radon.PersistentAOTCache` when ``aot_dir`` is set:
  serialized compiled executables (via
  ``jax.experimental.serialize_executable``) stored through the
  :mod:`repro.checkpoint.store` blob machinery, so a process restart
  deserializes instead of re-running XLA (measured ~15-40x cheaper;
  ``serve/aot_*`` rows).
* **Observability.**  Per-request latency histograms (p50/p95/p99),
  batch-occupancy and queue-depth gauges, plan-cache /
  trace-counter / AOT-cache introspection -- all surfaced by
  :meth:`DPRTService.healthz`, the ``/healthz``-style report
  ``serve --mode service`` prints next to ``selfcheck``.

The latency summary/formatting helpers here are shared with the
``serve --mode radon`` timing loop and ``benchmarks/bench_serve.py``,
so every serving surface reports the same percentile statistics.
"""
from __future__ import annotations

import asyncio
import collections
import threading
import time
from typing import Dict, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import radon
from repro.core.plan import plan_cache_entries, plan_cache_info
from repro.kernels.tuning import nearest_warm_batch, warm_batch_sizes
from repro.launch.errors import ServiceShutdown
from repro.launch.faults import perturb

__all__ = ["DPRTService", "latency_summary", "format_latency",
           "percentile"]


# ---------------------------------------------------------------------------
# latency statistics (shared: service healthz, serve --mode radon, benches)
# ---------------------------------------------------------------------------
def percentile(sorted_samples: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile of an ascending-sorted sequence."""
    if not sorted_samples:
        raise ValueError("percentile of no samples")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    pos = (len(sorted_samples) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(sorted_samples) - 1)
    frac = pos - lo
    return sorted_samples[lo] * (1.0 - frac) + sorted_samples[hi] * frac


def latency_summary(samples_s: Iterable[float]) -> Dict[str, float]:
    """p50/p95/p99/mean/max (milliseconds) + count over latency samples
    in seconds.  Empty input -> ``{"n": 0}``."""
    xs = sorted(samples_s)
    if not xs:
        return {"n": 0}
    return {
        "n": len(xs),
        "mean_ms": 1e3 * sum(xs) / len(xs),
        "p50_ms": 1e3 * percentile(xs, 50),
        "p95_ms": 1e3 * percentile(xs, 95),
        "p99_ms": 1e3 * percentile(xs, 99),
        "max_ms": 1e3 * xs[-1],
    }


def format_latency(summary: Dict[str, float],
                   imgs_per_s: Optional[float] = None) -> str:
    """One-line latency report: ``p50=… p95=… p99=… ms (n=…, mean=…)``."""
    if not summary.get("n"):
        return "latency: no samples"
    line = (f"latency p50={summary['p50_ms']:.2f} "
            f"p95={summary['p95_ms']:.2f} p99={summary['p99_ms']:.2f} "
            f"max={summary['max_ms']:.2f} ms "
            f"(n={summary['n']}, mean={summary['mean_ms']:.2f} ms)")
    if imgs_per_s is not None:
        line += f", {imgs_per_s:.1f} img/s"
    return line


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------
class _Request:
    __slots__ = ("img", "future", "t_enqueue")

    def __init__(self, img, future, t_enqueue):
        self.img = img
        self.future = future
        self.t_enqueue = t_enqueue


class DPRTService:
    """Dynamic-batching front-end over the fused batched DPRT kernels.

    ``DPRTService((H, W), dtype)`` builds one operator chain per warm
    batch size (``warm_batch_sizes(max_batch)``); :meth:`warmup`
    AOT-compiles them (optionally through a persistent on-disk cache);
    :meth:`submit` is the async per-request entry point and
    :meth:`run_requests` the synchronous driver benchmarks and the CLI
    use.  ``datapath`` selects what a request computes:

    * ``"forward"``  -- image in, ``(P+1, P)`` projections out (the
      paper's coprocessor service pattern);
    * ``"inverse"``  -- projections in, reconstructed image out;
    * ``"roundtrip"`` -- image in, forward+inverse chained AOT
      executables, image out (bit-exactness observable per request);
    * ``"conv"``     -- image in, fused projection-domain convolution
      against a fixed ``conv_kernel``, image out;
    * ``"solve"``    -- (masked/weighted) projections in, least-squares
      reconstruction out via :func:`repro.radon.solve_operator`
      (``solve_mask``/``solve_weight`` fix the projection-domain
      diagonal, ``solver``/``solve_tol``/``solve_maxiter`` the solver;
      the unmasked default serves the non-iterative Sherman-Morrison
      closed form).

    Transform knobs (``method``, ``strip_rows``, ``m_block``,
    ``stream_rows``, ``mesh``, ...) pass through to the operators
    unchanged.  The object is reusable across event loops: queue and
    batcher task are created per run, metrics accumulate on the object.
    """

    def __init__(self, shape: Tuple[int, int], dtype=jnp.int32, *,
                 max_batch: int = 16, max_wait_us: float = 2000.0,
                 warm_sizes: Optional[Sequence[int]] = None,
                 datapath: str = "forward", method: Optional[str] = None,
                 conv_kernel=None, solve_mask=None, solve_weight=None,
                 solver: str = "auto", solve_tol: float = 1e-6,
                 solve_maxiter: int = 50, aot_dir: Optional[str] = None,
                 fallback: bool = False, history: int = 65536, **knobs):
        shape = tuple(int(s) for s in shape)
        if len(shape) != 2:
            raise ValueError(f"service geometry must be (H, W), got {shape}")
        if datapath not in ("forward", "inverse", "roundtrip", "conv",
                            "solve"):
            raise ValueError(f"unknown datapath {datapath!r}")
        if (conv_kernel is None) != (datapath != "conv"):
            raise ValueError("conv_kernel is required for (exactly) the "
                             "'conv' datapath")
        if max_wait_us < 0:
            raise ValueError(f"max_wait_us must be >= 0, got {max_wait_us}")
        self.shape = shape
        self.dtype = jnp.dtype(dtype)
        self.datapath = datapath
        self.max_wait_us = float(max_wait_us)
        if warm_sizes is not None:   # routed keys trim the ladder
            sizes = tuple(sorted({int(b) for b in warm_sizes}))
            if not sizes or sizes[0] < 1:
                raise ValueError(f"warm_sizes must be >= 1, got {warm_sizes}")
            self.sizes = sizes
        else:
            self.sizes = warm_batch_sizes(int(max_batch))
        self.max_batch = self.sizes[-1]
        #: stable identity at the fault seam and in typed rejections
        self.fault_key = (f"{shape[0]}x{shape[1]}/{self.dtype.name}/"
                          f"{datapath}")
        self.persistent = (radon.PersistentAOTCache(aot_dir)
                           if aot_dir else None)
        self._want_fallback = bool(fallback)

        self._ops: Dict[int, tuple] = {}
        for b in self.sizes:
            bshape = (b,) + shape
            if datapath == "conv":
                stages = (radon.Conv2D(bshape, conv_kernel, dtype,
                                       method, **knobs),)
            elif datapath == "solve":
                stages = (radon.solve_operator(
                    bshape, dtype, mask=solve_mask, weight=solve_weight,
                    solver=solver, tol=solve_tol, maxiter=solve_maxiter,
                    method=method, **knobs),)
            else:
                fwd = radon.DPRT(bshape, dtype, method, **knobs)
                stages = {"forward": (fwd,),
                          "inverse": (fwd.inverse,),
                          "roundtrip": (fwd, fwd.inverse)}[datapath]
            self._ops[b] = stages
        first = self._ops[self.sizes[0]][0]
        #: per-request input contract (leading batch dim stripped)
        self.request_shape = tuple(first.shape_in[1:])
        self.request_dtype = jnp.dtype(first.dtype_in)
        self._exes: Dict[int, tuple] = {}

        # -- degraded path -------------------------------------------------
        self._fallback = None          # jitted staged/registry applier
        self._fallback_traced = False

        # -- metrics ------------------------------------------------------
        self._metrics_lock = threading.Lock()   # execute() runs on threads
        self._latencies = collections.deque(maxlen=int(history))
        self._batch_sizes = collections.Counter()  # admitted (pre-pad) size
        self._requests_done = 0
        self._batches = 0
        self._padded_slots = 0
        self._occupancy_sum = 0.0
        self._queue_depth_max = 0
        self._failures = 0
        self._fallback_uses = 0
        self._rejected_shutdown = 0
        self._compute_s = 0.0
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._traces_after_warmup: Optional[int] = None

        # -- per-run asyncio state ----------------------------------------
        self._queue: Optional[asyncio.Queue] = None
        self._batcher: Optional[asyncio.Task] = None
        self._pending: set = set()

    # -- compilation / persistent cache ------------------------------------
    def warmup(self) -> Dict[str, object]:
        """Build every warm-size executable -- from the persistent cache
        when one is configured (restart path: deserialization, no XLA),
        compiling and persisting otherwise.  Returns timing + cache
        counters; after warmup the steady state must not trace or
        compile again (:meth:`healthz` asserts it via the trace
        counters)."""
        t0 = time.perf_counter()
        for b, stages in self._ops.items():
            if b in self._exes:
                continue
            self._exes[b] = tuple(
                (self.persistent.get_or_compile(op) if self.persistent
                 else op.compile())
                for op in stages)
        if self._want_fallback:      # degraded path traces at warmup, so
            self.prepare_fallback()  # an incident never pays its compile
        dt = time.perf_counter() - t0
        self._traces_after_warmup = radon.trace_count()
        info: Dict[str, object] = {
            "warmup_s": dt,
            "executables": sum(len(v) for v in self._exes.values()),
            "warm_sizes": self.sizes,
        }
        if self.persistent is not None:
            info["persistent"] = self.persistent.stats()
        return info

    @property
    def warmed(self) -> bool:
        """True once :meth:`warmup` has built the executables."""
        return bool(self._exes)

    def plans(self) -> set:
        """Every :class:`RadonPlan` the operator stages reference.
        Plans are SHARED across services of one geometry (forward and
        roundtrip reuse the same cached plan), so the router's targeted
        eviction discards only plans no surviving route still holds."""
        out = set()
        for stages in self._ops.values():
            for op in stages:
                plan = getattr(op, "plan", None)
                if plan is not None:
                    out.add(plan)
        return out

    # -- degraded path -----------------------------------------------------
    def prepare_fallback(self) -> None:
        """Build and trace the degraded-path applier: a fresh ``jax.jit``
        of the registry (staged, for conv) composition -- the bit-exact
        alternative :meth:`execute_fallback` serves when the primary AOT
        executables fail.  Traced at the LARGEST warm size only (one
        fallback trace per service, padding absorbs the rest).
        Idempotent; ran from :meth:`warmup` when the service was built
        with ``fallback=True``."""
        if self._fallback is None:
            self._fallback = self._build_fallback()
        if not self._fallback_traced:
            zeros = jnp.zeros((self.max_batch,) + self.request_shape,
                              self.request_dtype)
            np.asarray(self._fallback(zeros))
            self._fallback_traced = True

    def _build_fallback(self):
        ops = self._ops[self.max_batch]
        if self.datapath == "conv":
            op = ops[0]
            plan, kernel = op.plan, op.kernel
            if not plan.geometry.native:
                # non-native conv is already the staged folded
                # composition; a fresh jit of it sidesteps a broken AOT
                # executable all the same
                return jax.jit(lambda x: op(x))
            # native conv: the explicit STAGED three-launch composition
            # (forward, exact 1-D conv, inverse) -- the registry path a
            # fused-pipeline failure degrades to, replicating
            # RadonPlan.pipeline's staged branch
            from repro.core.conv import circ_conv1d_exact
            from repro.core.plan import get_plan
            p = plan.geometry.prime
            kplan = get_plan((p, p), plan.dtype_name, plan.method,
                             strip_rows=plan.strip_rows,
                             m_block=plan.m_block, mesh=plan.mesh)

            def staged(x):
                rf = plan.forward(x)
                rg = kplan.forward(kernel)
                rc = circ_conv1d_exact(rf, rg)
                return plan.inverse(rc.astype(rf.dtype))
            return jax.jit(staged)
        appliers = []
        for op in ops:
            kind = getattr(op, "kind", None)
            plan = getattr(op, "plan", None)
            if plan is not None and kind is not None \
                    and hasattr(plan, kind):
                appliers.append(getattr(plan, kind))  # raw registry path
            else:                     # solve etc.: the operator itself
                appliers.append(op)

        def chain(x):
            for fn in appliers:
                x = fn(x)
            return x
        return jax.jit(chain)

    def execute_fallback(self, stack: np.ndarray) -> np.ndarray:
        """Run one admitted stack through the degraded path -- bit-exact
        vs the primary executables, just slower (separate launches /
        fresh compile).  Counted in ``fallback_uses``; a fallback that
        was never prepared compiles here, mid-incident."""
        self.prepare_fallback()
        b = int(stack.shape[0])
        if b > self.max_batch:
            raise ValueError(f"fallback stack of {b} exceeds max_batch "
                             f"{self.max_batch}")
        if b < self.max_batch:
            pad = np.zeros((self.max_batch - b,) + tuple(stack.shape[1:]),
                           stack.dtype)
            stack = np.concatenate([stack, pad])
        perturb("fallback", key=self.fault_key)
        out = np.asarray(self._fallback(jnp.asarray(stack)))
        with self._metrics_lock:
            self._fallback_uses += 1
            self._requests_done += b
            self._t_last = time.perf_counter()
        return out[:b]

    # -- async entry points ------------------------------------------------
    async def start(self) -> None:
        """Create the queue + batcher task on the running event loop
        (idempotent; :meth:`submit` calls it on first use)."""
        if self._queue is None:
            self._queue = asyncio.Queue()
            self._batcher = asyncio.create_task(self._run())
            self._batcher.add_done_callback(self._on_batcher_done)

    def _on_batcher_done(self, task: "asyncio.Task") -> None:
        # a batcher that DIED (not: was cancelled by shutdown) can never
        # deliver the queued futures -- fail them typed instead of
        # leaving callers awaiting forever
        if task.cancelled() or task.exception() is None:
            return
        self._reject_queued(self._queue, cause=task.exception())

    def _reject_requests(self, requests,
                         cause: Optional[BaseException] = None) -> None:
        for r in requests:
            if not r.future.done():
                err = ServiceShutdown(
                    f"DPRTService({self.fault_key}) stopped with the "
                    f"request still queued")
                if cause is not None:
                    err.__cause__ = cause
                r.future.set_exception(err)
                self._rejected_shutdown += 1

    def _reject_queued(self, queue, cause: Optional[BaseException] = None) \
            -> None:
        if queue is None:
            return
        while True:
            try:
                r = queue.get_nowait()
            except asyncio.QueueEmpty:
                return
            self._reject_requests((r,), cause)

    def submit_nowait(self, img) -> asyncio.Future:
        """Enqueue one request without awaiting it; returns the future
        carrying this request's slice of the coalesced batched kernel
        output.  Must run inside the event loop :meth:`start` ran on --
        the cheap path for drivers enqueueing many requests at once
        (one asyncio task per request costs more than a small-N
        kernel)."""
        if not self._exes:
            raise RuntimeError("DPRTService.warmup() must run before "
                               "traffic is admitted")
        if self._queue is None:
            raise RuntimeError("DPRTService.start() must run on the "
                               "event loop before submit_nowait")
        if self._batcher is not None and self._batcher.done():
            raise ServiceShutdown(f"DPRTService({self.fault_key}) batcher "
                                  "is no longer running")
        img = np.asarray(img)
        if img.shape != self.request_shape:
            raise ValueError(f"request shape {img.shape} != service "
                             f"contract {self.request_shape}")
        if img.dtype != np.dtype(self.request_dtype.name):
            raise ValueError(f"request dtype {img.dtype} != service "
                             f"contract {self.request_dtype.name}")
        t = time.perf_counter()
        if self._t_first is None:
            self._t_first = t
        fut = asyncio.get_running_loop().create_future()
        self._queue.put_nowait(_Request(img, fut, t))
        self._queue_depth_max = max(self._queue_depth_max,
                                    self._queue.qsize())
        return fut

    async def submit(self, img) -> np.ndarray:
        """Enqueue one request and await its result (the per-request
        entry point; see :meth:`submit_nowait` for the contract)."""
        await self.start()
        return await self.submit_nowait(img)

    async def drain(self) -> None:
        """Wait until every queued request has been dispatched and every
        in-flight batch has completed."""
        while (self._queue is not None and not self._queue.empty()) \
                or self._pending:
            if self._pending:
                await asyncio.gather(*list(self._pending),
                                     return_exceptions=True)
            else:
                await asyncio.sleep(0)

    async def shutdown(self, drain: bool = True) -> None:
        """Stop the batcher and detach from this event loop (the service
        object stays warm for the next run).  With ``drain`` (default)
        every queued request is dispatched first; without it -- and for
        anything that raced in after the drain -- still-queued requests
        are REJECTED with the typed :class:`ServiceShutdown`, because a
        cancelled batcher can never deliver their futures."""
        if drain:
            await self.drain()
        if self._batcher is not None:
            self._batcher.cancel()
            try:
                await self._batcher
            except asyncio.CancelledError:
                pass
        queue, self._queue = self._queue, None
        self._batcher = None
        self._reject_queued(queue)
        if self._pending:   # in-flight dispatches still complete
            await asyncio.gather(*list(self._pending),
                                 return_exceptions=True)

    # -- the batcher -------------------------------------------------------
    async def _run(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            batch = [await self._queue.get()]
            try:
                deadline = loop.time() + self.max_wait_us * 1e-6
                while len(batch) < self.max_batch:
                    # drain already-queued requests synchronously first:
                    # wait_for costs a task + timer per call, which at
                    # small geometries would dwarf the kernel itself
                    try:
                        batch.append(self._queue.get_nowait())
                        continue
                    except asyncio.QueueEmpty:
                        pass
                    remaining = deadline - loop.time()
                    if remaining <= 0:
                        break
                    try:
                        batch.append(
                            await asyncio.wait_for(self._queue.get(),
                                                   remaining))
                    except asyncio.TimeoutError:
                        break
            except asyncio.CancelledError:
                # shutdown landed while this batch was still forming:
                # its requests are no longer on the queue, so reject
                # them here -- a future must ALWAYS resolve
                self._reject_requests(batch)
                raise
            except Exception as e:    # batcher bug: don't strand the batch
                self._reject_requests(batch, cause=e)
                raise
            task = asyncio.create_task(self._dispatch(batch))
            self._pending.add(task)
            task.add_done_callback(self._pending.discard)

    def _compute(self, warm: int, stack: np.ndarray) -> jnp.ndarray:
        x = jnp.asarray(stack)
        for exe in self._exes[warm]:
            x = exe(x)
        x.block_until_ready()
        return x

    def execute(self, stack: np.ndarray) -> np.ndarray:
        """Synchronous batched dispatch: pad the validated ``(b, …)``
        stack up to the nearest warm size, run the primary AOT
        executable chain, return host results sliced back to ``b``.
        This is the routed surface -- the in-process batcher and the
        :class:`~repro.launch.router.ServiceRouter` both call it on
        worker threads (batch counters are lock-guarded).  The fault
        seam (:func:`repro.launch.faults.perturb` at site
        ``"dispatch"``) fires before the kernel, so injected faults
        surface exactly like kernel failures; the sequential oracle
        (:meth:`run_sequential`) bypasses it."""
        b = int(stack.shape[0])
        warm = nearest_warm_batch(b, self.sizes)
        if warm > b:   # pad up to the nearest warm executable shape
            pad = np.zeros((warm - b,) + tuple(stack.shape[1:]),
                           stack.dtype)
            stack = np.concatenate([stack, pad])
        t0 = time.perf_counter()
        perturb("dispatch", key=self.fault_key)
        # one device-to-host transfer for the whole batch; per-request
        # responses are zero-copy views (slicing the device array would
        # dispatch one XLA gather per request instead)
        out = np.asarray(self._compute(warm, stack))
        now = time.perf_counter()
        with self._metrics_lock:
            self._compute_s += now - t0
            self._t_last = now
            self._batches += 1
            self._batch_sizes[b] += 1
            self._padded_slots += warm - b
            self._occupancy_sum += b / warm
            self._requests_done += b
        return out[:b]

    async def _dispatch(self, batch: list) -> None:
        try:
            stack = np.stack([r.img for r in batch])
            # off-loop thread: collection of the NEXT batch overlaps the
            # kernel execution of this one
            out = await asyncio.to_thread(self.execute, stack)
        except Exception as e:
            self._failures += len(batch)
            for r in batch:
                if not r.future.done():
                    r.future.set_exception(e)
            return
        now = time.perf_counter()
        for i, r in enumerate(batch):
            self._latencies.append(now - r.t_enqueue)
            if not r.future.done():
                r.future.set_result(out[i])

    # -- synchronous driver ------------------------------------------------
    def run_requests(self, imgs: Sequence, arrival_us: float = 0.0,
                     repeats: int = 1) -> list:
        """Serve every image in ``imgs`` as an independent concurrent
        request (request i arrives ``i * arrival_us`` after the first)
        and return the per-request results in order.  This is the
        benchmark/CLI driver -- real deployments call :meth:`submit`
        from their own event loop.

        ``repeats`` replays the same traffic that many times on ONE
        event loop (batcher and thread pool stay up, as in a real
        deployment); the last pass's results are returned and the
        per-pass wall seconds land in ``self.last_pass_walls``, so
        benchmarks can take the min instead of paying loop setup in
        every sample.
        """
        async def driver():
            await self.start()

            async def one(i, img):
                await asyncio.sleep(i * arrival_us * 1e-6)
                return await self.submit(img)

            walls, results = [], None
            try:
                for _ in range(max(1, repeats)):
                    t0 = time.perf_counter()
                    if arrival_us > 0:
                        results = await asyncio.gather(
                            *(one(i, img) for i, img in enumerate(imgs)))
                    else:
                        # burst arrival: enqueue everything in one task;
                        # the requests are still coalesced individually
                        results = await asyncio.gather(
                            *[self.submit_nowait(img) for img in imgs])
                    walls.append(time.perf_counter() - t0)
            finally:
                await self.shutdown()
            return results, walls

        results, walls = asyncio.run(driver())
        #: wall seconds of each pass of the most recent run_requests call
        self.last_pass_walls = walls
        return results

    def run_sequential(self, imgs: Sequence) -> Tuple[list, list]:
        """The non-coalescing baseline: every image dispatched on its
        own through the batch-1 executable, one at a time -- what a
        front-end without dynamic batching would do.  Returns
        ``(results, per-request latencies in seconds)``; the comparison
        :meth:`run_requests` is judged against (and the bit-exactness
        reference for the coalesced path)."""
        if not self._exes:
            raise RuntimeError("DPRTService.warmup() must run before "
                               "traffic is admitted")
        results, lats = [], []
        for img in imgs:
            t0 = time.perf_counter()
            out = np.asarray(self._compute(1, np.asarray(img)[None]))
            lats.append(time.perf_counter() - t0)
            results.append(out[0])
        return results, lats

    # -- observability -----------------------------------------------------
    def reset_metrics(self) -> None:
        """Zero the admission/latency counters (warmup state, compiled
        executables and the post-warmup trace baseline are kept) -- call
        between a warming pass and a measured one."""
        self._latencies.clear()
        self._batch_sizes.clear()
        self._requests_done = 0
        self._batches = 0
        self._padded_slots = 0
        self._occupancy_sum = 0.0
        self._queue_depth_max = 0
        self._failures = 0
        self._fallback_uses = 0
        self._rejected_shutdown = 0
        self._compute_s = 0.0
        self._t_first = None
        self._t_last = None

    def stats(self) -> Dict[str, object]:
        """Counters + latency summary: the machine-readable health
        report (see :meth:`healthz` for the formatted one)."""
        lat = latency_summary(self._latencies)
        wall = (self._t_last - self._t_first
                if self._t_first is not None and self._t_last is not None
                else None)
        out: Dict[str, object] = {
            "geometry": self.shape,
            "dtype": self.dtype.name,
            "datapath": self.datapath,
            "method": self._ops[self.sizes[0]][0].plan.method,
            "warm_sizes": self.sizes,
            "max_wait_us": self.max_wait_us,
            "requests": self._requests_done,
            "failures": self._failures,
            "fallback_uses": self._fallback_uses,
            "rejected_shutdown": self._rejected_shutdown,
            "batches": self._batches,
            "batch_size_counts": dict(sorted(self._batch_sizes.items())),
            "mean_batch": (self._requests_done / self._batches
                           if self._batches else None),
            "batch_occupancy": (self._occupancy_sum / self._batches
                                if self._batches else None),
            "padded_slots": self._padded_slots,
            "queue_depth_max": self._queue_depth_max,
            "latency": lat,
            "imgs_per_s": (self._requests_done / wall
                           if wall else None),
            "compute_s": self._compute_s,
            "steady_state_retraces": self.steady_state_retraces(),
            "plan_cache": plan_cache_info()._asdict(),
            "aot_cache": radon.aot_cache_info()["currsize"],
        }
        if self.persistent is not None:
            out["persistent"] = self.persistent.stats()
        return out

    def steady_state_retraces(self) -> Optional[int]:
        """Traces taken AFTER warmup -- the compile-counter check: a
        healthy steady state (and a warm restart) is exactly 0."""
        if self._traces_after_warmup is None:
            return None
        return radon.trace_count() - self._traces_after_warmup

    def healthy(self) -> bool:
        """Zero post-warmup retraces, zero request failures, zero
        persistent-cache errors."""
        retraces = self.steady_state_retraces()
        if retraces is None or retraces > 0 or self._failures > 0:
            return False
        if self.persistent is not None and self.persistent.errors > 0:
            return False
        return True

    def healthz(self) -> str:
        """The ``/healthz``-style report: one OK/FAIL verdict line, then
        admission, latency, and cache-counter lines (plan cache with its
        eviction counter, trace counts, AOT + persistent executables)."""
        s = self.stats()
        verdict = "OK" if self.healthy() else "FAIL"
        lines = [
            f"[healthz] {verdict} geometry={s['geometry']} "
            f"dtype={s['dtype']} datapath={s['datapath']} "
            f"method={s['method']} warm_sizes={s['warm_sizes']} "
            f"max_wait_us={s['max_wait_us']:.0f}",
            f"[healthz] requests={s['requests']} failures={s['failures']} "
            f"fallback_uses={s['fallback_uses']} "
            f"rejected_shutdown={s['rejected_shutdown']} "
            f"batches={s['batches']} "
            + (f"mean_batch={s['mean_batch']:.1f} "
               f"occupancy={s['batch_occupancy']:.2f} "
               if s['batches'] else "")
            + f"padded_slots={s['padded_slots']} "
            f"queue_depth_max={s['queue_depth_max']}",
            "[healthz] " + format_latency(s["latency"], s["imgs_per_s"]),
            "[healthz] plan_cache hits={hits} misses={misses} "
            "currsize={currsize} evictions={evictions}".format(
                **s["plan_cache"]),
            f"[healthz] traces total={radon.trace_count()} "
            f"steady_state_retraces={s['steady_state_retraces']} "
            f"aot_executables={s['aot_cache']}",
            f"[healthz] warm_geometries={len(plan_cache_entries())}",
        ]
        if self.persistent is not None:
            p = s["persistent"]
            lines.append(
                "[healthz] persistent_aot hits={hits} misses={misses} "
                "errors={errors} degraded_compiles={degraded_compiles} "
                "dir={directory}".format(**p))
        return "\n".join(lines)

    def __repr__(self) -> str:
        return (f"DPRTService({self.shape}, {self.dtype.name}, "
                f"datapath={self.datapath!r}, warm_sizes={self.sizes}, "
                f"max_wait_us={self.max_wait_us:.0f})")
