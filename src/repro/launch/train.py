"""Training launcher: ``python -m repro.launch.train --arch tinyllama-1.1b``.

Full-scale flags mirror the dry-run meshes; ``--smoke`` runs the reduced
config of the same family end-to-end on local devices (CPU-friendly),
exercising the identical code path: pjit step, ZeRO-1 sharding, async
checkpointing, straggler watchdog, restart-from-latest.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ALIASES, get_config, get_smoke_config
from repro.launch.mesh import make_local_mesh
from repro.runtime.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU scale)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--mesh", default="none",
                    help="'none' (single device), 'local' (DxM over host "
                         "devices), or 'AxB'")
    args = ap.parse_args(argv)

    mcfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    mesh = None
    if args.mesh == "local":
        mesh = make_local_mesh()
    elif args.mesh != "none":
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))

    tcfg = TrainerConfig(batch_size=args.batch, seq_len=args.seq,
                         steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, lr=args.lr,
                         grad_compress=args.grad_compress)
    trainer = Trainer(mcfg, tcfg, mesh=mesh)
    out = trainer.run()
    print(f"[train] {mcfg.name}: finished {args.steps} steps, "
          f"last loss {out['last_loss']:.4f}, "
          f"stragglers flagged: {len(out['stragglers'])}")
    for m in out["log"]:
        print(f"  step {m['step']:>5d} loss {m['loss']:.4f} {m['sec']:.2f}s")
    return out


if __name__ == "__main__":
    main()
