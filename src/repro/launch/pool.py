"""Wire protocol + request journal for the multi-process worker pool.

The supervisor (:mod:`repro.launch.supervisor`) talks to its
``serve --mode service --jsonl --framed`` worker subprocesses over
plain pipes.  This module is the shared vocabulary of that boundary --
deliberately free of jax imports so the supervisor side stays cheap to
load and test:

* **Length-prefixed jsonl frames.**  Each message is one compact
  ASCII-JSON object sent as ``<byte length>\\n<payload>\\n``.  Newline
  JSON alone cannot distinguish "half a message" from "a message" when
  a worker is SIGKILLed mid-write; the length prefix makes truncation
  detectable (a torn final frame reads as EOF, never as a mangled
  request), and lets the reader skip stray non-protocol lines instead
  of desyncing forever.
* **Request journal (WAL).**  Every request the supervisor dispatches
  to a worker is recorded (with a payload digest) before the frame is
  written; delivery, typed rejection, failure, replay, and
  worker-lost events append to the same journal.  On worker death the
  journal is what makes "replay exactly once, bit-exact, or reject
  typed" an auditable property instead of a hope.
"""
from __future__ import annotations

import hashlib
import json
import threading
import time
from typing import Dict, Optional

__all__ = ["write_frame", "read_frame", "payload_digest",
           "RequestJournal"]

#: largest frame the reader will accept (a corrupt length prefix must
#: not make it try to slurp gigabytes); giant-N images go through the
#: in-process router, not the pipe protocol.
MAX_FRAME_BYTES = 256 * 1024 * 1024


def write_frame(fp, obj: dict) -> None:
    """Write one length-prefixed JSON frame and flush.

    The payload is compact ASCII JSON, so its character length equals
    its UTF-8 byte length and text-mode pipes are safe on both ends.
    Callers serialize concurrent writers (frames must never interleave).
    """
    payload = json.dumps(obj, separators=(",", ":"))
    fp.write(f"{len(payload)}\n{payload}\n")
    fp.flush()


def read_frame(fp) -> Optional[dict]:
    """Read the next frame; ``None`` on EOF (including a torn final
    frame -- a crashed writer's partial output is EOF, not data).

    Non-protocol header lines (a stray print on a worker's stdout, a
    blank line) are skipped rather than treated as fatal: the length
    prefix is what lets the reader resynchronize on the next real
    frame.  A syntactically valid frame with undecodable JSON raises
    ``ValueError`` -- that is protocol corruption, not noise.
    """
    while True:
        header = fp.readline()
        if not header:
            return None
        header = header.strip()
        if not header:
            continue
        try:
            n = int(header)
        except ValueError:
            continue                   # stray line: resync on next header
        if not 0 <= n <= MAX_FRAME_BYTES:
            continue
        payload = fp.read(n)
        if payload is None or len(payload) < n:
            return None                # torn frame: writer died mid-write
        fp.readline()                  # trailing newline (may be absent at EOF)
        return json.loads(payload)


def payload_digest(payload) -> str:
    """Stable content digest of one request payload (numpy array): the
    journal records it at dispatch AND at replay, so "the replay was
    bit-exact the same request" is checkable from the WAL alone."""
    import numpy as np
    arr = np.ascontiguousarray(payload)
    h = hashlib.sha1()
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    h.update(arr.tobytes())
    return h.hexdigest()[:16]


class RequestJournal:
    """Append-only journal of per-request lifecycle events.

    With ``path`` set, every event is appended (and flushed) to a jsonl
    file -- the small write-ahead log the supervisor keeps of what it
    handed to which worker; without it the journal still keeps the
    in-memory counters the pool healthz accounting rides on.  Events:

    * ``dispatch`` -- request handed to a worker (worker idx + digest);
    * ``deliver`` / ``typed`` / ``fail`` -- terminal outcomes;
    * ``replay``  -- worker died, request re-dispatched (once) to a
      healthy worker;
    * ``lost``    -- worker died and the request could NOT be replayed
      (already replayed, or no healthy worker): rejected typed as
      ``worker_lost``.

    Thread-safe: reader threads, the probe monitor and the dispatch
    path all record through one lock.
    """

    EVENTS = ("dispatch", "deliver", "typed", "fail", "replay", "lost")

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self._fp = open(path, "a") if path else None
        self.counts: Dict[str, int] = {ev: 0 for ev in self.EVENTS}

    def record(self, event: str, rid, **fields) -> None:
        if event not in self.counts:
            raise ValueError(f"unknown journal event {event!r}")
        with self._lock:
            self.counts[event] += 1
            if self._fp is not None:
                self._fp.write(json.dumps(
                    {"t": time.time(), "ev": event, "id": rid, **fields},
                    separators=(",", ":")) + "\n")
                self._fp.flush()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.counts)

    def close(self) -> None:
        with self._lock:
            if self._fp is not None:
                self._fp.close()
                self._fp = None

    def __repr__(self) -> str:
        s = self.stats()
        return (f"RequestJournal({self.path!r}, dispatched={s['dispatch']}, "
                f"delivered={s['deliver']}, replayed={s['replay']}, "
                f"lost={s['lost']})")
