"""Supervised multi-process serving: the :class:`WorkerPool`.

PR 9's :class:`~repro.launch.router.ServiceRouter` is fault-tolerant
*inside one process*; this module is the layer that survives the
process itself dying.  A :class:`WorkerPool` spawns N ``serve --mode
service --jsonl --framed`` router subprocesses over one shared
``aot_dir`` and makes worker loss a typed, recoverable event:

* **Framed pipe protocol.**  Length-prefixed jsonl frames
  (:mod:`repro.launch.pool`) on stdin/stdout; a SIGKILL mid-write reads
  as truncation (EOF), never as a mangled request.
* **Health probes.**  A monitor thread sends an in-band ``healthz`` op
  on an interval; a worker that misses ``probe_misses`` consecutive
  probes is *suspect* and killed (crash detection for the hung-not-dead
  case), which funnels into the same death path as a real crash.
* **Crash recovery.**  A dead worker's in-flight requests are replayed
  **once** on a healthy peer -- bit-exact, the identical frame, with
  the payload digest journaled at dispatch and at replay so the
  equivalence is auditable -- or rejected typed as
  :class:`~repro.launch.errors.WorkerLost`.  Never silently dropped.
  The worker itself is restarted under exponential backoff and comes
  back *warm*: its prefill restores the shared ``aot_dir`` blobs
  (published under cross-process compile locks) instead of recompiling.
* **Request journal.**  Every dispatch/deliver/replay/loss is recorded
  through :class:`~repro.launch.pool.RequestJournal` -- the WAL that
  backs the accounting identity.
* **Bounded admission.**  A pool-wide pending budget; exceeding it
  rejects with :class:`~repro.launch.errors.QueueFull` carrying a
  ``retry_after_s`` hint (pending depth x smoothed delivery time).
* **Graceful drain.**  :meth:`drain` stops admitting, asks each worker
  to flush (shutdown op -> the worker answers everything in flight,
  typed-rejects its queue, exits), and escalates SIGTERM -> SIGKILL
  only on timeout.
* **Pool healthz.**  :meth:`healthz` aggregates per-worker reports and
  closes the same identity the router does:
  ``admitted == delivered + failed + rejected + pending``.
"""
from __future__ import annotations

import json
import queue
import signal
import subprocess
import sys
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.launch.errors import (QueueFull, ServiceShutdown, WorkerLost,
                                 error_for_code)
from repro.launch.pool import (RequestJournal, payload_digest, read_frame,
                               write_frame)

__all__ = ["WorkerPool", "default_worker_cmd"]

#: error codes the pool books as typed rejections; anything else a
#: worker reports ("internal", "bad_request") is a raw failure.
_TYPED_CODES = ("deadline_exceeded", "queue_full", "shutdown",
                "worker_lost", "service_error")


def default_worker_cmd(*, aot_dir: str, manifest: Sequence,
                       max_batch: int = 16, queue_cap: int = 64,
                       max_inflight: int = 256) -> List[str]:
    """The argv of one real router worker subprocess."""
    return [sys.executable, "-m", "repro.launch.serve",
            "--mode", "service", "--jsonl", "--framed", "--sigterm-drain",
            "--aot-dir", aot_dir, "--manifest", json.dumps(list(manifest)),
            "--batch", str(max_batch), "--queue-cap", str(queue_cap),
            "--max-inflight", str(max_inflight)]


class _PoolRequest:
    __slots__ = ("rid", "msg", "future", "digest", "replayed", "t_submit")

    def __init__(self, rid, msg, future, digest):
        self.rid = rid
        self.msg = msg
        self.future = future
        self.digest = digest
        self.replayed = False
        self.t_submit = time.monotonic()


class _Worker:
    """One subprocess plus its pipe plumbing.  The writer thread owns
    stdin (an outbox queue decouples dispatch from pipe backpressure --
    a full 64KB pipe must block the writer thread, never the pool
    lock); the reader thread owns stdout and is also the crash
    detector: EOF on a worker's stdout IS the death notification."""

    __slots__ = ("idx", "proc", "outbox", "reader", "writer", "alive",
                 "inflight", "restarts", "generation", "last_reply",
                 "booted", "probes_missed", "last_healthz", "draining")

    def __init__(self, idx: int):
        self.idx = idx
        self.proc: Optional[subprocess.Popen] = None
        self.outbox: "queue.Queue" = queue.Queue()
        self.reader: Optional[threading.Thread] = None
        self.writer: Optional[threading.Thread] = None
        self.alive = False
        self.inflight: Dict[str, _PoolRequest] = {}
        self.restarts = 0
        self.generation = 0
        self.last_reply = 0.0
        self.booted = False            # answered at least one frame
        self.probes_missed = 0
        self.last_healthz: Optional[dict] = None
        self.draining = False

    @property
    def pid(self) -> Optional[int]:
        return self.proc.pid if self.proc is not None else None


class WorkerPool:
    """Supervise N framed-jsonl router workers over one ``aot_dir``.

    ``cmd`` is the worker argv (default: :func:`default_worker_cmd`
    over ``aot_dir``/``manifest``); tests substitute a stub.  The pool
    is thread-safe; :meth:`submit` returns a
    :class:`concurrent.futures.Future` resolving to the result array
    or raising the typed error.  Use as a context manager, or call
    :meth:`start` / :meth:`drain` explicitly.
    """

    def __init__(self, n_workers: int = 2, *,
                 aot_dir: Optional[str] = None,
                 manifest: Sequence = (),
                 cmd: Optional[Sequence[str]] = None,
                 max_batch: int = 16,
                 pending_cap: int = 256,
                 probe_interval_s: float = 1.0,
                 probe_misses: int = 3,
                 restart_backoff_s: float = 0.25,
                 max_restarts: int = 5,
                 journal_path: Optional[str] = None,
                 env: Optional[dict] = None,
                 stderr=None,
                 drain_timeout_s: float = 30.0):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        if pending_cap < 1 or probe_misses < 1:
            raise ValueError("pending_cap and probe_misses must be >= 1")
        self.n_workers = int(n_workers)
        self.aot_dir = aot_dir
        self.manifest = list(manifest)
        self.max_batch = int(max_batch)
        self.pending_cap = int(pending_cap)
        self.probe_interval_s = float(probe_interval_s)
        self.probe_misses = int(probe_misses)
        self.restart_backoff_s = float(restart_backoff_s)
        self.max_restarts = int(max_restarts)
        self.drain_timeout_s = float(drain_timeout_s)
        self._cmd = list(cmd) if cmd is not None else None
        self._env = dict(env) if env is not None else None
        self._stderr = stderr
        self.journal = RequestJournal(journal_path)

        self._lock = threading.RLock()
        self._workers: List[_Worker] = [_Worker(i)
                                        for i in range(self.n_workers)]
        self._rid = 0
        self._rr = 0                      # round-robin cursor
        self._started = False
        self._draining = False
        self._monitor: Optional[threading.Thread] = None
        self._monitor_stop = threading.Event()
        self._restart_threads: List[threading.Thread] = []

        # -- accounting: every admitted future ends in exactly one bin
        self.admitted = 0
        self.delivered = 0
        self.failed = 0
        self.rejected: Dict[str, int] = {}
        #: typed refusals at submit time (no future was created, so
        #: they sit outside the admitted identity -- like the router's
        #: rejected_admission)
        self.rejected_admission: Dict[str, int] = {}
        self.replays = 0
        self.worker_restarts = 0
        self.workers_lost = 0
        self.suspect_kills = 0
        self._delivery_ewma: Optional[float] = None

    # -- lifecycle ---------------------------------------------------------
    def worker_cmd(self) -> List[str]:
        if self._cmd is not None:
            return list(self._cmd)
        if self.aot_dir is None:
            raise ValueError("WorkerPool needs aot_dir (or an explicit cmd)")
        return default_worker_cmd(aot_dir=self.aot_dir,
                                  manifest=self.manifest,
                                  max_batch=self.max_batch)

    def start(self) -> "WorkerPool":
        with self._lock:
            if self._started:
                return self
            self._started = True
            self._draining = False
            for w in self._workers:
                self._spawn(w)
            self._monitor_stop.clear()
            self._monitor = threading.Thread(target=self._monitor_loop,
                                             daemon=True,
                                             name="pool-monitor")
            self._monitor.start()
        return self

    def __enter__(self) -> "WorkerPool":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.drain()

    def _spawn(self, w: _Worker) -> None:
        """Start (or restart) one worker process and its pipe threads.
        Caller holds the lock."""
        w.proc = subprocess.Popen(
            self.worker_cmd(), stdin=subprocess.PIPE,
            stdout=subprocess.PIPE, stderr=self._stderr,
            text=True, env=self._env)
        w.alive = True
        w.draining = False
        w.generation += 1
        w.booted = False
        w.probes_missed = 0
        w.last_reply = time.monotonic()
        w.outbox = queue.Queue()
        gen = w.generation
        w.reader = threading.Thread(target=self._reader_loop, args=(w, gen),
                                    daemon=True,
                                    name=f"pool-reader-{w.idx}")
        w.writer = threading.Thread(target=self._writer_loop, args=(w, gen),
                                    daemon=True,
                                    name=f"pool-writer-{w.idx}")
        w.reader.start()
        w.writer.start()

    # -- pipe threads ------------------------------------------------------
    def _writer_loop(self, w: _Worker, gen: int) -> None:
        while True:
            item = w.outbox.get()
            if item is None or w.generation != gen:
                return
            try:
                write_frame(w.proc.stdin, item)
            except (OSError, ValueError):
                # broken pipe: the reader's EOF owns the death path;
                # this request stays in `inflight` and gets replayed
                return

    def _reader_loop(self, w: _Worker, gen: int) -> None:
        stdout = w.proc.stdout
        while True:
            try:
                msg = read_frame(stdout)
            except Exception:
                msg = None                 # protocol corruption == crash
            if msg is None:
                break
            if w.generation == gen:
                self._on_frame(w, msg)
        if w.generation == gen:
            self._on_worker_exit(w)

    def _on_frame(self, w: _Worker, msg: dict) -> None:
        rid = msg.get("id")
        w.last_reply = time.monotonic()
        w.booted = True
        w.probes_missed = 0
        if rid == "__probe__" or rid == "__drain__":
            with self._lock:
                w.last_healthz = msg
            return
        if rid is None or msg.get("shutdown"):
            return
        with self._lock:
            req = w.inflight.pop(rid, None)
        if req is None:
            return                         # late duplicate (already replayed)
        self._resolve(req, msg)

    # -- the single resolution site ----------------------------------------
    def _resolve(self, req: _PoolRequest, msg: dict) -> None:
        """Book exactly one terminal outcome for ``req`` and resolve its
        future.  Every path that finishes a request funnels through
        here, so a request can never be double-counted or double-set."""
        if req.future.done():
            return
        if msg.get("ok"):
            dt = time.monotonic() - req.t_submit
            with self._lock:
                self.delivered += 1
                self._delivery_ewma = (dt if self._delivery_ewma is None
                                       else 0.7 * self._delivery_ewma
                                       + 0.3 * dt)
            self.journal.record("deliver", req.rid,
                                replayed=req.replayed)
            req.future.set_result(np.asarray(msg.get("data")))
            return
        code = msg.get("error", "internal")
        text = msg.get("msg", "")
        if code in _TYPED_CODES:
            with self._lock:
                self.rejected[code] = self.rejected.get(code, 0) + 1
            self.journal.record("typed", req.rid, code=code)
            req.future.set_exception(
                error_for_code(code, text, msg.get("retry_after_s")))
        else:
            with self._lock:
                self.failed += 1
            self.journal.record("fail", req.rid, code=code)
            req.future.set_exception(RuntimeError(
                f"worker failure ({code}): {text}"))

    # -- crash handling ----------------------------------------------------
    def _on_worker_exit(self, w: _Worker) -> None:
        with self._lock:
            if not w.alive:
                return
            w.alive = False
            w.outbox.put(None)             # release the writer thread
            orphans = list(w.inflight.values())
            w.inflight.clear()
            clean = w.draining or self._draining
            if not clean:
                self.workers_lost += 1
        for req in orphans:
            if clean:
                # graceful exit: anything unanswered was queue-rejected
                # by the worker itself; a stray orphan is a shutdown
                with self._lock:
                    self.rejected["shutdown"] = \
                        self.rejected.get("shutdown", 0) + 1
                self.journal.record("typed", req.rid, code="shutdown")
                if not req.future.done():
                    req.future.set_exception(ServiceShutdown(
                        "pool drained with request in flight"))
                continue
            self._replay_or_reject(req, dead_idx=w.idx)
        if not clean:
            self._schedule_restart(w)

    def _replay_or_reject(self, req: _PoolRequest, *, dead_idx: int) -> None:
        """One-shot replay: a request that was in flight on a dead
        worker is re-dispatched bit-exact (the identical frame) on a
        healthy peer exactly once; a second loss -- or no healthy peer
        -- rejects it typed.  Never a silent drop, never a duplicate
        delivery race (the dead worker can no longer answer)."""
        with self._lock:
            target = self._pick_worker(exclude=dead_idx) \
                if not req.replayed else None
            if target is not None:
                req.replayed = True
                target.inflight[req.rid] = req
                self.replays += 1
        if target is not None:
            self.journal.record("replay", req.rid, worker=target.idx,
                                digest=req.digest)
            target.outbox.put(req.msg)
            return
        with self._lock:
            self.rejected["worker_lost"] = \
                self.rejected.get("worker_lost", 0) + 1
        self.journal.record("lost", req.rid, digest=req.digest)
        if not req.future.done():
            req.future.set_exception(WorkerLost(
                f"worker {dead_idx} died with request {req.rid} in "
                f"flight and no replay was possible"))

    def _schedule_restart(self, w: _Worker) -> None:
        with self._lock:
            if self._draining or w.restarts >= self.max_restarts:
                return
            w.restarts += 1
            backoff = self.restart_backoff_s * (2 ** (w.restarts - 1))
            t = threading.Thread(target=self._restart_after,
                                 args=(w, backoff), daemon=True,
                                 name=f"pool-restart-{w.idx}")
            self._restart_threads.append(t)
        t.start()

    def _restart_after(self, w: _Worker, backoff: float) -> None:
        time.sleep(backoff)
        with self._lock:
            if self._draining or w.alive:
                return
            self._spawn(w)
            self.worker_restarts += 1

    # -- probes ------------------------------------------------------------
    def _monitor_loop(self) -> None:
        while not self._monitor_stop.wait(self.probe_interval_s):
            with self._lock:
                workers = [w for w in self._workers if w.alive]
            for w in workers:
                if w.proc.poll() is not None:
                    continue               # reader's EOF handles it
                # a probe went unanswered for a full interval: the
                # worker is hung-or-wedged.  The clock only runs once
                # the worker has booted (its compile-heavy prefill
                # happens before it reads stdin) and pauses while
                # requests are in flight -- slow is not dead while
                # work completes.
                if w.booted and not w.inflight \
                        and time.monotonic() - w.last_reply \
                        > self.probe_interval_s:
                    w.probes_missed += 1
                if w.probes_missed >= self.probe_misses:
                    with self._lock:
                        self.suspect_kills += 1
                    self.kill_worker(w.idx)   # suspect -> kill -> restart
                    continue
                w.outbox.put({"op": "healthz", "id": "__probe__"})

    # -- admission / dispatch ----------------------------------------------
    def _retry_after_s(self) -> float:
        per = self._delivery_ewma or 0.05
        batches = self.pending() // max(1, self.max_batch) + 1
        return round(batches * per, 6)

    def _pick_worker(self, exclude: Optional[int] = None) \
            -> Optional[_Worker]:
        """Next healthy worker round-robin; caller holds the lock."""
        n = len(self._workers)
        for off in range(n):
            w = self._workers[(self._rr + off) % n]
            if w.alive and not w.draining and w.idx != exclude:
                self._rr = (self._rr + off + 1) % n
                return w
        return None

    def submit(self, spec, data, *, deadline_ms: Optional[float] = None,
               priority: int = 0) -> Future:
        """Admit one request into the pool; returns a Future resolving
        to the result array or raising the typed rejection."""
        arr = np.asarray(data)
        msg = dict(spec)
        msg["op"] = "submit"
        msg["data"] = arr.tolist()
        if deadline_ms is not None:
            msg["deadline_ms"] = float(deadline_ms)
        if priority:
            msg["priority"] = int(priority)
        with self._lock:
            if not self._started or self._draining:
                raise ServiceShutdown("worker pool is not running")
            if self.pending() >= self.pending_cap:
                self.rejected_admission["queue_full"] = \
                    self.rejected_admission.get("queue_full", 0) + 1
                raise QueueFull(
                    f"pool pending budget {self.pending_cap} exhausted",
                    retry_after_s=self._retry_after_s())
            w = self._pick_worker()
            if w is None:
                raise ServiceShutdown("no live worker in the pool")
            self._rid += 1
            rid = f"r{self._rid}"
            msg["id"] = rid
            req = _PoolRequest(rid, msg, Future(), payload_digest(arr))
            self.admitted += 1
            w.inflight[req.rid] = req
        self.journal.record("dispatch", rid, worker=w.idx,
                            digest=req.digest)
        w.outbox.put(msg)
        return req.future

    # -- chaos / control surface -------------------------------------------
    def kill_worker(self, idx: int, sig: int = signal.SIGKILL) -> bool:
        """Deliver ``sig`` to worker ``idx`` (the chaos harness's
        mid-burst SIGKILL); death flows through the normal crash path.
        True if a live process was signalled."""
        with self._lock:
            w = self._workers[idx]
            proc = w.proc if w.alive else None
        if proc is None or proc.poll() is not None:
            return False
        try:
            proc.send_signal(sig)
        except (ProcessLookupError, OSError):
            return False
        return True

    def wait_ready(self, timeout_s: float = 120.0) -> bool:
        """Block until every live worker answers a healthz probe --
        i.e. is past its (possibly compile-heavy) prefill.  True when
        all answered within ``timeout_s``."""
        deadline = time.monotonic() + timeout_s
        with self._lock:
            workers = [w for w in self._workers if w.alive]
        for w in workers:
            w.last_healthz = None
            w.outbox.put({"op": "healthz", "id": "__probe__"})
        while time.monotonic() < deadline:
            if all(w.last_healthz is not None or not w.alive
                   for w in workers):
                return any(w.alive for w in workers)
            time.sleep(0.02)
        return False

    def wait_pending(self, timeout_s: float = 60.0) -> bool:
        """Block until nothing is pending; True on success."""
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            if self.pending() == 0:
                return True
            time.sleep(0.01)
        return False

    # -- drain -------------------------------------------------------------
    def drain(self) -> None:
        """Graceful pool shutdown: stop admitting, ask every worker to
        flush and exit, escalate SIGTERM then SIGKILL on timeout.
        Every admitted future is resolved by the time this returns."""
        with self._lock:
            if not self._started:
                return
            self._draining = True
            workers = [w for w in self._workers if w.alive]
            for w in workers:
                w.draining = True
        self._monitor_stop.set()
        if self._monitor is not None:
            self._monitor.join(timeout=self.probe_interval_s + 1.0)
        for w in workers:
            w.outbox.put({"op": "shutdown", "id": "__drain__"})
        deadline = time.monotonic() + self.drain_timeout_s
        for w in workers:
            left = max(0.1, deadline - time.monotonic())
            try:
                w.proc.wait(timeout=left)
            except subprocess.TimeoutExpired:
                w.proc.terminate()         # SIGTERM: worker drains itself
                try:
                    w.proc.wait(timeout=5.0)
                except subprocess.TimeoutExpired:
                    w.proc.kill()
                    w.proc.wait()
        for w in workers:
            if w.reader is not None:
                w.reader.join(timeout=5.0)
        # anything STILL unresolved (worker never answered) is a typed
        # shutdown, not a hang: a future the pool handed out resolves
        leftovers = []
        with self._lock:
            for w in self._workers:
                leftovers.extend(w.inflight.values())
                w.inflight.clear()
                w.alive = False
                w.outbox.put(None)
            self._started = False
        for req in leftovers:
            with self._lock:
                self.rejected["shutdown"] = \
                    self.rejected.get("shutdown", 0) + 1
            self.journal.record("typed", req.rid, code="shutdown")
            if not req.future.done():
                req.future.set_exception(ServiceShutdown(
                    "pool drained with request unanswered"))
        self.journal.close()

    # -- observability -----------------------------------------------------
    def pending(self) -> int:
        return sum(len(w.inflight) for w in self._workers)

    def rejected_total(self) -> int:
        return sum(self.rejected.values())

    def identity_ok(self) -> bool:
        """The pool accounting identity: every admitted request is in
        exactly one terminal bin or still pending."""
        return self.admitted == (self.delivered + self.failed
                                 + self.rejected_total() + self.pending())

    def verdict(self) -> str:
        """``FAIL``: dropped/raw-failed work or broken accounting.
        ``WARN``: clean answers but degradation happened (worker lost,
        replay, restart, rejection).  ``OK``: nothing went wrong."""
        if self.failed > 0 or not self.identity_ok():
            return "FAIL"
        if not self._started and self.pending() > 0:
            return "FAIL"
        degradations = (self.workers_lost + self.replays
                        + self.worker_restarts + self.suspect_kills
                        + self.rejected_total()
                        + sum(self.rejected_admission.values()))
        return "WARN" if degradations else "OK"

    def healthz(self, probe: bool = False,
                probe_timeout_s: float = 5.0) -> dict:
        """Aggregate pool health.  ``probe=True`` refreshes each live
        worker's in-band healthz first (blocking up to the timeout)."""
        if probe:
            with self._lock:
                workers = [w for w in self._workers if w.alive]
            for w in workers:
                w.last_healthz = None
                w.outbox.put({"op": "healthz", "id": "__probe__"})
            deadline = time.monotonic() + probe_timeout_s
            while time.monotonic() < deadline:
                if all(w.last_healthz is not None or not w.alive
                       for w in workers):
                    break
                time.sleep(0.02)
        with self._lock:
            report = {
                "verdict": self.verdict(),
                "workers": [{
                    "idx": w.idx, "pid": w.pid, "alive": w.alive,
                    "restarts": w.restarts, "inflight": len(w.inflight),
                    "worker_verdict": (w.last_healthz or {}).get("verdict"),
                    "retraces_since_start":
                        (w.last_healthz or {}).get("retraces_since_start"),
                    "persistent": (w.last_healthz or {}).get("persistent"),
                    "faults_env": (w.last_healthz or {}).get("faults_env"),
                } for w in self._workers],
                "admitted": self.admitted,
                "delivered": self.delivered,
                "failed": self.failed,
                "rejected": dict(self.rejected),
                "rejected_admission": dict(self.rejected_admission),
                "pending": self.pending(),
                "replays": self.replays,
                "workers_lost": self.workers_lost,
                "worker_restarts": self.worker_restarts,
                "suspect_kills": self.suspect_kills,
                "identity_ok": self.identity_ok(),
                "journal": self.journal.stats(),
            }
        return report

    def healthz_text(self, report: Optional[dict] = None) -> str:
        s = report if report is not None else self.healthz()
        lines = [
            f"[healthz] {s['verdict']} pool workers="
            f"{sum(1 for w in s['workers'] if w['alive'])}/"
            f"{len(s['workers'])} admitted={s['admitted']} "
            f"delivered={s['delivered']} failed={s['failed']} "
            f"rejected={sum(s['rejected'].values())} "
            f"pending={s['pending']} identity_ok={s['identity_ok']}",
            f"[healthz] faults workers_lost={s['workers_lost']} "
            f"replays={s['replays']} restarts={s['worker_restarts']} "
            f"suspect_kills={s['suspect_kills']}",
        ]
        for w in s["workers"]:
            lines.append(
                f"[healthz] worker {w['idx']} pid={w['pid']} "
                f"alive={w['alive']} restarts={w['restarts']} "
                f"inflight={w['inflight']} "
                f"verdict={w['worker_verdict']} "
                f"retraces={w['retraces_since_start']}")
        return "\n".join(lines)

    def __repr__(self) -> str:
        alive = sum(1 for w in self._workers if w.alive)
        return (f"WorkerPool(workers={alive}/{len(self._workers)}, "
                f"admitted={self.admitted}, delivered={self.delivered}, "
                f"verdict={self.verdict()!r})")
