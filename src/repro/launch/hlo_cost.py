"""Trip-count-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scanned computation (stacked layers, chunked attention, SSD chunk scans)
is under-reported by its trip count.  This walker parses the optimized
HLO, multiplies while bodies by their ``known_trip_count`` backend
config, and accumulates:

* ``flops``        -- dot MACs (2*result*K) + elementwise arithmetic,
* ``bytes``        -- an HBM traffic model: operand + result bytes of
                      every top-level op (fusion *boundaries*: internals
                      of a fusion don't touch HBM),
* ``coll_bytes``   -- collective operand bytes (all-gather/-reduce/
                      reduce-scatter/all-to-all/collective-permute), with
                      the same trip multipliers.

This is a structural model (no overlap, perfect DMA) -- exactly what a
roofline wants.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "compiled_cost_dict"]


def compiled_cost_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized to a flat dict.

    jaxlib<=0.4.x returns one dict per program (``[dict]``); newer
    versions return the dict directly, or ``None`` on some backends.
    """
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^()]*(?:\([^()]*\))?[^()]*\))|(?:[a-z0-9]+"
                    r"\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\(")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n["\s:]+["]?(\d+)')
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs",
    "floor", "ceil", "sign", "cosine", "sine", "logistic", "compare",
    "select", "and", "or", "xor", "not", "remainder", "atan2",
    "exponential-minus-one", "log-plus-one", "cbrt", "round-nearest-even",
    "erf", "shift-left", "shift-right-logical", "shift-right-arithmetic",
}
_ZERO_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # control flow: carries are donated in place; bodies are accounted
    # per-iteration separately
    "while", "conditional", "call", "optimization-barrier",
}
# ops that touch only their *result*-sized window of the operand, not the
# whole buffer (counting the full operand would charge a scan's stacked
# params once per iteration):
_WINDOW_READ_OPS = {"dynamic-slice", "slice", "gather", "broadcast",
                    "reshape"}
_WINDOW_WRITE_OPS = {"dynamic-update-slice", "scatter"}
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_elems_bytes(dtype: str, dims: str) -> Tuple[int, int]:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n, n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(result_txt: str) -> Tuple[int, int]:
    elems = nbytes = 0
    for d, s in _SHAPE_RE.findall(result_txt):
        e, b = _shape_elems_bytes(d, s)
        elems += e
        nbytes += b
    return elems, nbytes


class _Instr:
    __slots__ = ("name", "op", "result_txt", "elems", "nbytes", "operands",
                 "line")

    def __init__(self, name, op, result_txt, operands, line):
        self.name, self.op, self.result_txt = name, op, result_txt
        self.elems, self.nbytes = _result_bytes(result_txt)
        self.operands = operands
        self.line = line


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    buf: List[str] = []
    for line in text.splitlines():
        if not line.startswith(" ") and "{" in line and ("(" in line):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", line.strip())
            if m:
                cur = m.group(1)
                buf = []
                comps[cur] = buf
                if "ENTRY" in line:
                    comps["__entry__"] = buf
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            buf.append(line)
    return comps


def _parse_instr(line: str) -> Optional[_Instr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    m2 = _OP_RE.match(rhs)
    if not m2:
        return None
    result_txt, op = m2.groups()
    # operand names: first (...) group after op name
    start = rhs.find(op + "(") + len(op) + 1
    depth, i = 1, start
    while i < len(rhs) and depth:
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
        i += 1
    operand_txt = rhs[start:i - 1]
    operands = re.findall(r"%([\w\.\-]+)", operand_txt)
    return _Instr(name, op, result_txt, operands, line)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len([t for t in m.group(1).split(",") if t]), 1)
    return 1


def analyze_hlo(text: str) -> Dict[str, float]:
    comps = _split_computations(text)
    parsed: Dict[str, List[_Instr]] = {}
    symtab: Dict[str, Dict[str, _Instr]] = {}
    for cname, lines in comps.items():
        if cname == "__entry__":
            continue
        instrs = [i for i in (_parse_instr(l) for l in lines) if i]
        parsed[cname] = instrs
        symtab[cname] = {i.name: i for i in instrs}

    fusion_param_bytes: Dict[str, Dict[int, float]] = {}

    def _fusion_operand_bytes(cname: str) -> Dict[int, float]:
        """Effective HBM bytes read per fusion parameter: if a parameter is
        only consumed through window reads (dynamic-slice/gather/...), the
        fusion DMAs the windows, not the whole buffer."""
        if cname in fusion_param_bytes:
            return fusion_param_bytes[cname]
        out: Dict[int, float] = {}
        instrs = parsed.get(cname, [])
        params = {}
        for ins in instrs:
            if ins.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", ins.line)
                if m:
                    params[ins.name] = (int(m.group(1)), ins.nbytes)
        for pname, (idx, full) in params.items():
            uses = [i for i in instrs if pname in i.operands]
            if uses and all(u.op in _WINDOW_READ_OPS for u in uses):
                out[idx] = float(sum(u.nbytes for u in uses))
            else:
                out[idx] = float(full)
        fusion_param_bytes[cname] = out
        return out

    memo: Dict[Tuple[str, bool], Tuple[float, float, float, Dict[str, float]]] = {}

    bytes_by_op: Dict[str, float] = {}

    def _acc_op(op: str, nbytes: float, mult: float = 1.0):
        bytes_by_op[op] = bytes_by_op.get(op, 0.0) + nbytes * mult

    def cost(cname: str, stream: bool):
        """Returns (flops, bytes, coll_bytes, coll_by_class)."""
        key = (cname, stream)
        if key in memo:
            return memo[key]
        memo[key] = (0.0, 0.0, 0.0, {})  # cycle guard
        fl = by = co = 0.0
        cls: Dict[str, float] = {}
        for ins in parsed.get(cname, []):
            op = ins.op
            if op == "dot":
                k = 1
                mC = _LHS_CONTRACT_RE.search(ins.line)
                if mC and ins.operands:
                    lhs = symtab[cname].get(ins.operands[0])
                    if lhs:
                        shapes = _SHAPE_RE.findall(lhs.result_txt)
                        if shapes:
                            dims = [int(d) for d in shapes[0][1].split(",")
                                    if d]
                            for ci in mC.group(1).split(","):
                                if ci and int(ci) < len(dims):
                                    k *= dims[int(ci)]
                fl += 2.0 * ins.elems * k
            elif op in _ELEMENTWISE:
                fl += ins.elems
            elif op == "fusion":
                mcall = _CALLS_RE.search(ins.line)
                if mcall:
                    f2, _, c2, cl2 = cost(mcall.group(1), False)
                    fl += f2
                    co += c2
                    for kk, vv in cl2.items():
                        cls[kk] = cls.get(kk, 0.0) + vv
                    if stream:
                        eff = _fusion_operand_bytes(mcall.group(1))
                        nb = ins.nbytes + sum(eff.values())
                        by += nb
                        _acc_op("fusion", nb)
                continue
            elif op == "while":
                mb = _BODY_RE.search(ins.line)
                mt = _TRIP_RE.search(ins.line)
                trip = int(mt.group(1)) if mt else 1
                if mb:
                    f2, b2, c2, cl2 = cost(mb.group(1), True)
                    fl += trip * f2
                    by += trip * b2
                    co += trip * c2
                    for kk, vv in cl2.items():
                        cls[kk] = cls.get(kk, 0.0) + trip * vv
            elif op == "conditional":
                mbr = _BRANCH_RE.search(ins.line)
                if mbr:
                    branches = re.findall(r"%?([\w\.\-]+)",
                                          mbr.group(1))
                    if branches:
                        sub = [cost(b, True) for b in branches]
                        best = max(sub, key=lambda t: t[0] + t[1])
                        fl += best[0]
                        by += best[1]
                        co += best[2]
                        for kk, vv in best[3].items():
                            cls[kk] = cls.get(kk, 0.0) + vv
            elif any(op.startswith(c) for c in _COLL_OPS):
                if op.endswith("-done"):
                    continue
                base = op.replace("-start", "")
                cbytes = ins.nbytes
                if base == "all-reduce" and op.endswith("-start"):
                    cbytes //= 2   # tuple result aliases (operand, result)
                if base == "all-gather":
                    cbytes //= _group_size(ins.line)
                elif base == "reduce-scatter":
                    cbytes *= _group_size(ins.line)
                co += cbytes
                cls[base] = cls.get(base, 0.0) + cbytes
            if stream and op not in _ZERO_BYTES_OPS:
                if op in _WINDOW_READ_OPS:
                    # reads only a result-sized window (+ tiny indices)
                    by += 2 * ins.nbytes
                    _acc_op(op, 2 * ins.nbytes)
                elif op in _WINDOW_WRITE_OPS:
                    # reads the update operand, writes a window of it
                    upd = (symtab[cname].get(ins.operands[1])
                           if len(ins.operands) > 1 else None)
                    ub = upd.nbytes if upd is not None else ins.nbytes
                    by += 2 * min(ub, ins.nbytes)
                    _acc_op(op, 2 * min(ub, ins.nbytes))
                else:
                    opb = 0
                    for oname in ins.operands:
                        o = symtab[cname].get(oname)
                        if o is not None:
                            opb += o.nbytes
                    by += ins.nbytes + opb
                    _acc_op(op, ins.nbytes + opb)
        memo[key] = (fl, by, co, cls)
        return memo[key]

    entry_name = None
    for cname in parsed:
        if ".main" in cname or cname.startswith("main"):
            entry_name = cname
    if entry_name is None and parsed:
        # fall back: the computation that no one calls
        called = set()
        for cname, instrs in parsed.items():
            for ins in instrs:
                for rx in (_CALLS_RE, _BODY_RE):
                    mm = rx.search(ins.line)
                    if mm:
                        called.add(mm.group(1))
        rest = [c for c in parsed if c not in called]
        entry_name = rest[-1] if rest else list(parsed)[-1]

    fl, by, co, cls = cost(entry_name, True)
    top = dict(sorted(bytes_by_op.items(), key=lambda kv: -kv[1])[:12])
    out = {"flops": fl, "bytes": by, "coll_bytes": co, "entry": entry_name,
           "bytes_by_op_unscaled": top}
    for k, v in cls.items():
        out[f"coll_{k}"] = v
    return out
