"""Training runtime: restartable loop with failure injection, straggler
watchdog, async checkpointing, and elastic restore.

The loop is the unit of fault tolerance: any crash (including the
injected ``SimulatedFailure``) loses at most ``ckpt_every`` steps; calling
``Trainer.run`` again resumes from the newest atomic checkpoint, possibly
on a different mesh (ZeRO/TP states are stored mesh-agnostic on host).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import AsyncCheckpointer, restore_checkpoint
from repro.data.pipeline import shard_batch
from repro.data.synthetic import TokenStream
from repro.models import Model
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               cosine_schedule, zero1_shardings)
from repro.optim.compress import compress_tree
from repro.parallel.sharding import (abstract_params, activate_mesh,
                                     init_params, param_shardings)

__all__ = ["TrainerConfig", "Trainer", "SimulatedFailure"]


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests the checkpoint/restart path)."""


@dataclasses.dataclass
class TrainerConfig:
    batch_size: int = 8
    seq_len: int = 64
    steps: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 5
    keep: int = 3
    lr: float = 1e-3
    warmup: int = 10
    seed: int = 0
    grad_compress: bool = False
    zero1: bool = True
    straggler_factor: float = 5.0   # step slower than factor x median => flag
    fail_at_step: Optional[int] = None   # failure injection
    log_every: int = 5
    param_dtype: Any = jnp.float32


class Trainer:
    def __init__(self, model_cfg, cfg: TrainerConfig, mesh=None):
        self.model = Model(model_cfg)
        self.model_cfg = model_cfg
        self.cfg = cfg
        self.mesh = mesh
        self.checkpointer = AsyncCheckpointer(cfg.ckpt_dir, cfg.keep)
        self.straggler_events = []
        self.metrics_log = []

        specs = self.model.specs()
        self._specs = specs
        if mesh is not None:
            self.p_shard = param_shardings(specs, mesh)
            self.opt_shard = {
                "mu": zero1_shardings(self.p_shard,
                                      abstract_params(specs,
                                                      cfg.param_dtype),
                                      mesh),
                "nu": zero1_shardings(self.p_shard,
                                      abstract_params(specs,
                                                      cfg.param_dtype),
                                      mesh),
                "step": None,
            }
        else:
            self.p_shard = None
            self.opt_shard = None

        opt_cfg = AdamWConfig(lr=cfg.lr)
        schedule = cosine_schedule(cfg.lr, cfg.warmup, cfg.steps)

        def train_step(params, opt, batch, key):
            loss_val, grads = jax.value_and_grad(
                lambda p: self.model.loss(p, batch)[0])(params)
            if cfg.grad_compress:
                grads = compress_tree(grads, key)
            params, opt, metrics = adamw_update(params, grads, opt, opt_cfg,
                                                schedule)
            metrics["loss"] = loss_val
            return params, opt, metrics

        self._train_step = jax.jit(train_step, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def init_state(self):
        params = init_params(self._specs, jax.random.key(self.cfg.seed),
                             self.cfg.param_dtype)
        if self.mesh is not None:
            params = jax.tree.map(jax.device_put, params, self.p_shard)
        opt = adamw_init(params)
        return params, opt

    def restore(self):
        params_like, opt_like = jax.tree.map(np.asarray, self.init_state())
        shardings = None
        if self.mesh is not None:
            shardings = {"params": self.p_shard, "opt": self.opt_shard}
        tree, step, extra = restore_checkpoint(
            self.cfg.ckpt_dir, {"params": params_like, "opt": opt_like},
            shardings=shardings)
        if tree is None:
            return None
        return tree["params"], tree["opt"], step

    # ------------------------------------------------------------------
    def run(self, resume: bool = True) -> Dict[str, Any]:
        cfg = self.cfg
        restored = self.restore() if resume else None
        if restored is not None:
            params, opt, start_step = restored
            start_step = int(start_step)
        else:
            params, opt = self.init_state()
            start_step = 0

        stream = TokenStream(self.model_cfg.vocab_size, cfg.seq_len,
                             cfg.batch_size, seed=cfg.seed)
        durations = []
        ctx = activate_mesh(self.mesh) if self.mesh is not None else None
        if ctx:
            ctx.__enter__()
        try:
            step = start_step
            for step in range(start_step, cfg.steps):
                if cfg.fail_at_step is not None and step == cfg.fail_at_step:
                    raise SimulatedFailure(f"injected failure at {step}")
                t0 = time.perf_counter()
                batch = shard_batch(stream.batch(step), self.mesh)
                params, opt, metrics = self._train_step(
                    params, opt, batch, jax.random.key(step))
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                durations.append(dt)
                med = float(np.median(durations))
                if len(durations) > 3 and dt > cfg.straggler_factor * med:
                    self.straggler_events.append(
                        {"step": step, "sec": dt, "median": med})
                if step % cfg.log_every == 0:
                    self.metrics_log.append({"step": step, "loss": loss,
                                             "sec": dt})
                if (step + 1) % cfg.ckpt_every == 0:
                    self.checkpointer.save(step + 1,
                                           {"params": params, "opt": opt},
                                           extra={"loss": loss})
            self.checkpointer.save(cfg.steps, {"params": params, "opt": opt})
            self.checkpointer.wait()
            return {"params": params, "opt": opt, "last_loss": loss,
                    "log": self.metrics_log,
                    "stragglers": self.straggler_events}
        finally:
            if ctx:
                ctx.__exit__(None, None, None)
