from .trainer import Trainer, TrainerConfig, SimulatedFailure
