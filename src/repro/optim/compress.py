"""Gradient compression: int8 stochastic-rounding quantization + a
compressed data-parallel all-reduce built on shard_map.

On a real pod the DP gradient all-reduce moves 2 bytes/param/step (bf16);
quantizing to int8 with a per-tensor scale halves the collective bytes at
~0.4% relative error (unbiased, stochastic rounding).  ``compressed_psum``
demonstrates the pattern as a shard_map: quantize -> psum(int32) ->
dequantize; the roofline collective term scales accordingly.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
try:
    from jax import shard_map  # jax >= 0.8
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

__all__ = ["quantize_int8", "dequantize_int8", "compress_tree",
           "compressed_psum_mean"]


def quantize_int8(x: jnp.ndarray, key: jax.Array) -> Tuple[jnp.ndarray,
                                                           jnp.ndarray]:
    """Unbiased int8 quantization with stochastic rounding."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    noise = jax.random.uniform(key, x.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads, key: jax.Array):
    """Quantize+dequantize every gradient leaf (simulates the wire format)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    out = []
    for g, k in zip(leaves, keys):
        q, s = quantize_int8(g, k)
        out.append(dequantize_int8(q, s, g.dtype))
    return jax.tree.unflatten(treedef, out)


def compressed_psum_mean(x: jnp.ndarray, mesh: Mesh, axis: str,
                         key: jax.Array) -> jnp.ndarray:
    """Mean over ``axis`` with int8-quantized payload (shard_map demo).

    The int8 shards are summed as int32 (exact), then rescaled -- one
    all-reduce at 1/4 the f32 bytes (1/2 of bf16).
    """
    n = mesh.shape[axis]
    keys = jax.random.split(key, n)

    # Summing int8 shards exactly requires a *shared* scale: take pmax of
    # the per-shard scales (one scalar all-reduce), quantize against it,
    # psum in int32, rescale.
    def local2(xl, kl):
        xf = xl.astype(jnp.float32)
        s_local = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
        s = jax.lax.pmax(s_local, axis)
        noise = jax.random.uniform(kl[0], xl.shape, jnp.float32) - 0.5
        q = jnp.clip(jnp.round(xf / s + noise), -127, 127).astype(jnp.int32)
        qsum = jax.lax.psum(q, axis)
        return (qsum.astype(jnp.float32) * s / n).astype(xl.dtype)

    fn2 = shard_map(local2, mesh=mesh,
                    in_specs=(P(axis), P(axis)), out_specs=P(axis))
    return fn2(x, keys)
