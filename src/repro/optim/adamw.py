"""AdamW with f32 master state, global-norm clipping, cosine schedule,
gradient accumulation, and ZeRO-1 optimizer-state sharding helpers."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "zero1_shardings", "accumulate_grads"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr_schedule: Optional[Callable] = None):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(step) if lr_schedule else cfg.lr

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a), new_mu.append(b), new_nu.append(c)
    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {"mu": jax.tree.unflatten(treedef, new_mu),
                 "nu": jax.tree.unflatten(treedef, new_nu),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def zero1_shardings(param_shardings, abstract_params, mesh: Mesh,
                    zero_axis: str = "data"):
    """ZeRO-1: shard optimizer moments over the data axis.

    For each parameter, the first dimension that is unsharded in the
    parameter's spec and divisible by the axis size gets ``zero_axis``.
    Falls back to the parameter's own sharding when nothing fits, so the
    result is always a valid NamedSharding tree for the Adam moments.
    """
    size = mesh.shape[zero_axis]

    def for_param(ns: NamedSharding, aval):
        shape = aval.shape
        spec = list(ns.spec) + [None] * (len(shape) - len(ns.spec))
        used = set()
        for s in spec:
            for a in (s if isinstance(s, tuple) else (s,)):
                if a:
                    used.add(a)
        if zero_axis in used:
            return ns
        for i, (s, dim) in enumerate(zip(spec, shape)):
            if s is None and dim % size == 0 and dim >= size:
                spec[i] = zero_axis
                return NamedSharding(mesh, P(*spec))
        return ns

    return jax.tree.map(for_param, param_shardings, abstract_params)


def accumulate_grads(loss_fn: Callable, params, batches, microbatches: int):
    """Mean loss/grads over ``microbatches`` splits of the leading axis."""

    def split(x):
        return x.reshape((microbatches, x.shape[0] // microbatches)
                         + x.shape[1:])

    mb = jax.tree.map(split, batches)

    def step(carry, b):
        acc, loss_acc = carry
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, b)
        acc = jax.tree.map(jnp.add, acc, g)
        return (acc, loss_acc + loss), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, lsum), _ = jax.lax.scan(step, (zeros, jnp.zeros((), jnp.float32)),
                                   mb)
    inv = 1.0 / microbatches
    return lsum * inv, jax.tree.map(lambda g: g * inv, gsum)
