from .adamw import (AdamWConfig, adamw_init, adamw_update, cosine_schedule,
                    global_norm, zero1_shardings, accumulate_grads)
from .compress import (quantize_int8, dequantize_int8, compress_tree,
                       compressed_psum_mean)
