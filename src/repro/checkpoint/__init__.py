from .store import (save_checkpoint, restore_checkpoint, latest_step,
                    AsyncCheckpointer, gc_checkpoints,
                    save_blob, load_blob, list_blobs, delete_blob)
