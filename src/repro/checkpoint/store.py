"""Fault-tolerant checkpointing: atomic, async, elastic.

Layout:  <dir>/step_<n>/  { manifest.json, <leaf-key>.npy ... }
written into ``step_<n>.tmp`` and atomically renamed, so a crash mid-write
never corrupts the latest checkpoint.  Restore places leaves with the
*current* mesh's shardings -- the saved mesh may be a different size
(elastic restart), since leaves are stored unsharded on host.
"""
from __future__ import annotations

import contextlib
import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer", "gc_checkpoints",
           "save_blob", "load_blob", "list_blobs", "delete_blob",
           "blob_lock", "LockTimeout"]

_SEP = "::"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(directory: str, step: int, tree, extra: Optional[Dict]
                    = None) -> str:
    """Blocking atomic save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {"step": step, "keys": {}, "extra": extra or {},
                "time": time.time()}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["keys"][key] = {"file": fname, "shape": list(arr.shape),
                                 "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like_tree, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional matching tree of NamedShardings -- this is the
    elastic path: the checkpoint is mesh-agnostic on disk and gets laid
    out for whatever mesh is active now.
    Returns (tree, step, extra) or (None, None, None) when nothing exists.
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        return None, None, None
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_like = _flatten(like_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    missing = set(flat_like) - set(manifest["keys"])
    if missing:
        raise ValueError(f"checkpoint at {path} missing keys: {sorted(missing)[:5]}")

    loaded = {}
    for key in flat_like:
        info = manifest["keys"][key]
        arr = np.load(os.path.join(path, info["file"]))
        if key in flat_shard:
            loaded[key] = jax.device_put(arr, flat_shard[key])
        else:
            loaded[key] = jax.numpy.asarray(arr)

    paths, treedef = zip(*jax.tree_util.tree_flatten_with_path(like_tree)[0]) \
        if jax.tree_util.tree_flatten_with_path(like_tree)[0] else ((), None)
    treedef = jax.tree_util.tree_structure(like_tree)
    keys_in_order = [_SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                               for p in path)
                     for path, _ in
                     jax.tree_util.tree_flatten_with_path(like_tree)[0]]
    tree = jax.tree_util.tree_unflatten(treedef,
                                        [loaded[k] for k in keys_in_order])
    return tree, manifest["step"], manifest.get("extra", {})


def gc_checkpoints(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(int(m.group(1)) for d in os.listdir(directory)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


# ---------------------------------------------------------------------------
# keyed binary blobs (the persistent AOT-executable cache rides this)
#
# One file per key: an 8-byte big-endian header length, a JSON header
# {"key", "meta", "size"}, then the payload -- written to ``.tmp`` and
# atomically renamed like the step checkpoints, so readers never see a
# torn blob and a crash mid-write leaves only an ignorable ``.tmp``.
# ---------------------------------------------------------------------------
_BLOB_SUFFIX = ".blob"


def _blob_path(directory: str, key: str) -> str:
    fname = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + _BLOB_SUFFIX
    return os.path.join(directory, fname)


def save_blob(directory: str, key: str, data: bytes,
              meta: Optional[Dict] = None) -> str:
    """Atomically persist ``data`` under ``key``; returns the file path.

    ``meta`` (JSON-serializable) travels in the header and comes back
    from :func:`load_blob` -- version/topology stamps live there so a
    stale blob can be rejected without deserializing the payload.
    """
    os.makedirs(directory, exist_ok=True)
    final = _blob_path(directory, key)
    header = json.dumps({"key": key, "meta": meta or {},
                         "size": len(data), "time": time.time()}).encode()
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        f.write(len(header).to_bytes(8, "big"))
        f.write(header)
        f.write(data)
        # fsync BEFORE the rename: os.replace is atomic in the
        # namespace but not in the page cache -- without this, a crash
        # after the rename can leave a truncated file under the FINAL
        # name, which readers would see as a corrupt (not absent) blob
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)         # atomic publish
    try:                           # persist the rename itself
        dfd = os.open(directory, os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)
    except OSError:                # platform without dir fsync: best effort
        pass
    return final


def load_blob(directory: str, key: str):
    """``(data, meta)`` for ``key``, or ``(None, None)`` when absent.

    A torn or unparsable blob raises ``ValueError`` (callers treat that
    as a cache miss and overwrite it).
    """
    path = _blob_path(directory, key)
    if not os.path.isfile(path):
        return None, None
    with open(path, "rb") as f:
        raw = f.read()
    try:
        hlen = int.from_bytes(raw[:8], "big")
        header = json.loads(raw[8:8 + hlen].decode())
        data = raw[8 + hlen:]
        if header.get("key") != key or len(data) != header.get("size"):
            raise ValueError("header/key/size mismatch")
    except (ValueError, KeyError, UnicodeDecodeError) as e:
        raise ValueError(f"corrupt blob for key {key!r} at {path}: {e}")
    return data, header.get("meta", {})


def list_blobs(directory: str) -> list:
    """Keys of every intact-looking blob in ``directory`` (by header)."""
    if not os.path.isdir(directory):
        return []
    keys = []
    for fname in sorted(os.listdir(directory)):
        if not fname.endswith(_BLOB_SUFFIX):
            continue
        path = os.path.join(directory, fname)
        try:
            with open(path, "rb") as f:
                hlen = int.from_bytes(f.read(8), "big")
                if hlen > os.path.getsize(path):   # garbage length prefix
                    continue
                header = json.loads(f.read(hlen).decode())
            keys.append(header["key"])
        except (OSError, ValueError, KeyError, UnicodeDecodeError):
            continue
    return keys


def delete_blob(directory: str, key: str) -> bool:
    """Remove ``key``'s blob; True if something was deleted."""
    path = _blob_path(directory, key)
    try:
        os.remove(path)
        return True
    except FileNotFoundError:
        return False


# ---------------------------------------------------------------------------
# cross-process per-key advisory locks
#
# N worker processes cold-starting against one blob directory must not
# each pay (and each publish) the same expensive compile.  A lock here
# is an O_CREAT|O_EXCL sidecar file -- the only primitive that is
# atomic on every local filesystem -- holding JSON {pid, time} so a
# waiter can tell "held by live work" from "left behind by a SIGKILLed
# worker" and steal the latter.
# ---------------------------------------------------------------------------
_LOCK_SUFFIX = ".lock"


class LockTimeout(TimeoutError):
    """A :func:`blob_lock` waiter gave up: the lock stayed held (by a
    live process) past ``timeout_s``."""


def _lock_path(directory: str, key: str) -> str:
    return _blob_path(directory, key) + _LOCK_SUFFIX


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:        # exists, owned by someone else
        return True
    except OSError:
        return True                # unknowable: assume alive (don't steal)
    return True


def _read_lock(path: str):
    """Raw bytes of the lock file, or None if it vanished."""
    try:
        with open(path, "rb") as f:
            return f.read()
    except OSError:
        return None


def _lock_is_stale(raw: bytes, path: str, stale_s: float) -> bool:
    """True when the lock content ``raw`` (read from ``path``) belongs
    to a dead process or has outlived ``stale_s``.  Unreadable/partial
    content only counts as stale once the file's mtime is old -- a
    peer may be mid-write."""
    try:
        info = json.loads(raw.decode())
        pid = int(info["pid"])
        born = float(info.get("time", 0.0))
    except (ValueError, KeyError, TypeError, UnicodeDecodeError):
        try:
            return time.time() - os.path.getmtime(path) > max(stale_s, 1.0)
        except OSError:
            return False           # vanished: next acquire attempt decides
    if not _pid_alive(pid):
        return True
    return time.time() - born > stale_s


@contextlib.contextmanager
def blob_lock(directory: str, key: str, *, stale_s: float = 120.0,
              poll_s: float = 0.05, timeout_s: float = 600.0):
    """Hold the cross-process advisory lock for ``key``.

    Yields a small stats dict: ``waited_s`` (how long acquisition
    blocked) and ``steals`` (stale locks reclaimed on the way in) --
    the AOT cache surfaces both.  Raises :class:`LockTimeout` if a
    *live* holder keeps the lock past ``timeout_s``.

    Stealing re-reads the lock file immediately before unlinking and
    skips the unlink if its content changed -- the window where waiter
    A decides "stale" while waiter B already stole and re-acquired is
    real, and unlinking B's fresh lock would let two processes inside.
    """
    os.makedirs(directory, exist_ok=True)
    path = _lock_path(directory, key)
    start = time.monotonic()
    steals = 0
    while True:
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            raw = _read_lock(path)
            if raw is None:
                continue                       # vanished: retry acquire
            if _lock_is_stale(raw, path, stale_s):
                if _read_lock(path) == raw:    # unchanged since judged
                    with contextlib.suppress(OSError):
                        os.unlink(path)
                    steals += 1
                continue                       # immediate retry, no sleep
            if time.monotonic() - start > timeout_s:
                raise LockTimeout(
                    f"lock for key {key!r} at {path} held past "
                    f"{timeout_s}s by a live process")
            time.sleep(poll_s)
            continue
        break
    try:
        os.write(fd, json.dumps({"pid": os.getpid(), "key": key,
                                 "time": time.time()}).encode())
    finally:
        os.close(fd)
    try:
        yield {"waited_s": time.monotonic() - start, "steals": steals}
    finally:
        with contextlib.suppress(OSError):
            os.unlink(path)


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (single in-flight write)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, extra=None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                gc_checkpoints(self.directory, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
