"""First-class linear-operator objects over cached transform plans.

``op = radon.DPRT(shape, dtype)`` builds (or fetches -- plans and trace
caches are shared) the forward DPRT operator for one input geometry.
Operators are immutable views of a ``(plan, datapath)`` pair and expose
the full linear-operator algebra:

    op(f)            # apply: (…, H, W) -> (…, P+1, P), differentiable
    op.inverse       # the exact inverse transform (crops the embedding)
    op.T             # the exact adjoint -- A^T, NOT the inverse
    op.inverse.T     # adjoint of the inverse == (A^T)^-1
    op2 @ op1        # composition (applied right-to-left)
    op.lower()       # AOT: trace+lower for the declared input aval
    op.compile()     # AOT: cached per-geometry compiled executable
    op.as_matrix()   # dense (out_size, in_size) matrix (small N; tests)

Every application routes through :mod:`repro.radon.autodiff`, so
``jax.grad``/``jax.jvp`` are exact for every registered backend and
each geometry traces exactly once no matter how many operators,
legacy-wrapper calls, or serve workers touch it.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dprt import accum_dtype_for
from repro.core.plan import RadonPlan, add_plan_evict_hook, get_plan

from . import ambient
from .autodiff import (_CACHE_LOCK, INVERSE_OF, TRANSPOSE_OF, jitted_apply,
                       trace_count)

__all__ = ["DPRT", "RadonOperator", "CompositeOperator", "operator_for",
           "aot_cache_info", "aot_cache_clear"]

#: (plan, kind, dtype) -- or a tuple of (plan, kind) pairs for
#: composites -- -> jax compiled executable; the per-geometry AOT cache
#: behind ``op.compile()`` (and ``serve --warmup``).  Entries drop in
#: lockstep with plan-cache evictions, like the jitted appliers.
_AOT_CACHE: dict = {}


def _drop_plan_executables(plan) -> None:
    def involves(key) -> bool:
        if isinstance(key[0], tuple):   # composite: ((plan, kind, dt), …)
            return any(p == plan for p, _kind, _dt in key)
        return key[0] == plan
    with _CACHE_LOCK:
        for key in [k for k in _AOT_CACHE if involves(k)]:
            del _AOT_CACHE[key]


add_plan_evict_hook(_drop_plan_executables)


def aot_cache_info() -> dict:
    with _CACHE_LOCK:
        return {"currsize": len(_AOT_CACHE),
                "keys": sorted(str(k[1]) for k in _AOT_CACHE)}


def aot_cache_clear() -> None:
    with _CACHE_LOCK:
        _AOT_CACHE.clear()


class RadonOperator:
    """One linear datapath of a :class:`~repro.core.plan.RadonPlan`.

    ``kind`` is one of ``forward`` / ``inverse`` / ``adjoint`` /
    ``inverse_adjoint``; ``dtype`` is the *image* dtype the operator was
    declared for (transform-domain inputs/outputs use its accumulator
    dtype, exactly as the transforms themselves do).
    """

    __slots__ = ("plan", "kind", "dtype")

    def __init__(self, plan: RadonPlan, kind: str, dtype):
        if kind not in TRANSPOSE_OF:
            raise ValueError(f"unknown operator kind {kind!r}")
        object.__setattr__(self, "plan", plan)
        object.__setattr__(self, "kind", kind)
        object.__setattr__(self, "dtype", jnp.dtype(dtype))

    def __setattr__(self, name, value):
        raise AttributeError("RadonOperator is immutable")

    # -- shapes / dtypes ---------------------------------------------------
    @property
    def _image_side(self) -> bool:
        """True when the INPUT lives in image space (H, W)."""
        return self.kind in ("forward", "inverse_adjoint")

    @property
    def shape_in(self) -> Tuple[int, ...]:
        g = self.plan.geometry
        return g.image_shape if self._image_side else g.transform_shape

    @property
    def shape_out(self) -> Tuple[int, ...]:
        g = self.plan.geometry
        return g.transform_shape if self._image_side else g.image_shape

    @property
    def dtype_in(self):
        # forward consumes raw images; every other datapath consumes
        # transform-domain / cotangent values, which live in the
        # accumulator dtype the transforms emit
        if self.kind == "forward":
            return self.dtype
        return jnp.dtype(accum_dtype_for(self.dtype))

    @property
    def dtype_out(self):
        return jnp.dtype(accum_dtype_for(self.dtype))

    # -- application -------------------------------------------------------
    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        return jitted_apply(self.plan, self.kind)(x)

    # -- algebra -----------------------------------------------------------
    @property
    def T(self) -> "RadonOperator":
        """The exact adjoint (transpose).  ``op.T`` satisfies
        ``<op(x), y> == <x, op.T(y)>`` -- it is NOT the inverse."""
        return RadonOperator(self.plan, TRANSPOSE_OF[self.kind], self.dtype)

    @property
    def inverse(self) -> "RadonOperator":
        """The exact inverse transform (bit-exact round trip on ints)."""
        return RadonOperator(self.plan, INVERSE_OF[self.kind], self.dtype)

    def __matmul__(self, other):
        if isinstance(other, CompositeOperator):
            return CompositeOperator((self,) + other.ops)
        if isinstance(other, RadonOperator):
            return CompositeOperator((self, other))
        return NotImplemented

    # -- AOT ---------------------------------------------------------------
    @property
    def input_sharding(self):
        """The mesh-natural sharding of this operator's input (``None``
        for non-mesh plans): batched stacks shard over the mesh's data
        axes, everything else is replicated.  Matches the output
        sharding of the paired datapath, so AOT-compiled forward/inverse
        executables chain without resharding -- ``device_put`` inputs
        here before calling a ``.compile()``d executable under a mesh."""
        mesh = self.plan.mesh
        if mesh is None:
            return None
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.core.distributed import batch_partition_spec
        if self.plan.geometry.batched:
            return NamedSharding(mesh, batch_partition_spec(mesh))
        return NamedSharding(
            mesh, PartitionSpec(*([None] * len(self.shape_in))))

    def _input_aval(self) -> jax.ShapeDtypeStruct:
        sharding = self.input_sharding
        if sharding is None:
            return jax.ShapeDtypeStruct(self.shape_in, self.dtype_in)
        return jax.ShapeDtypeStruct(self.shape_in, self.dtype_in,
                                    sharding=sharding)

    def lower(self):
        """Trace + lower this operator for its declared input aval
        (``jax.jit(...).lower``); ``.compile()`` the result for an AOT
        executable, or use :meth:`compile` for the cached one."""
        return jitted_apply(self.plan, self.kind).lower(self._input_aval())

    def compile(self):
        """The AOT-compiled executable for this geometry, built at most
        once per (plan, datapath, dtype) process-wide.  The returned
        executable is callable and never retraces -- the serve path's
        steady state."""
        key = (self.plan, self.kind, self.dtype_in.name)
        with _CACHE_LOCK:
            exe = _AOT_CACHE.get(key)
        if exe is None:
            built = self.lower().compile()
            with _CACHE_LOCK:
                exe = _AOT_CACHE.setdefault(key, built)
        return exe

    # -- introspection -----------------------------------------------------
    @property
    def trace_count(self) -> int:
        """Traces taken for this (plan, datapath) so far (all geometries
        of the plan's shape; exactly 1 after any number of same-shape
        calls)."""
        return trace_count(self.plan, self.kind)

    def as_matrix(self) -> jnp.ndarray:
        """Dense (out_size, in_size) matrix of this linear map.

        Materializes one basis vector per input element -- O(P^4) memory
        -- so this is for small primes (tests, reference checks) only.
        """
        size_in = 1
        for s in self.shape_in:
            size_in *= s
        basis = jnp.eye(size_in, dtype=self.dtype_in)
        cols = jax.vmap(lambda e: self(e.reshape(self.shape_in)).ravel())(
            basis)
        return cols.T  # vmap rows are images of basis vectors == columns

    def describe(self) -> dict:
        d = dict(self.plan.describe())
        d.update(kind=self.kind, dtype=self.dtype.name,
                 shape_in=self.shape_in, shape_out=self.shape_out)
        return d

    def __repr__(self) -> str:
        return (f"RadonOperator({self.kind}, {self.shape_in}->"
                f"{self.shape_out}, {self.dtype.name}, "
                f"method={self.plan.method!r})")

    # operators are value objects: equal views of equal plans compare ==
    def __eq__(self, other):
        return (isinstance(other, RadonOperator)
                and self.plan == other.plan and self.kind == other.kind
                and self.dtype == other.dtype)

    def __hash__(self):
        return hash((self.plan, self.kind, self.dtype))


class CompositeOperator:
    """Right-to-left composition of operators: ``(g @ f)(x) == g(f(x))``.

    Supports the same algebra (``.T`` reverses and transposes,
    ``.inverse`` reverses and inverts) plus AOT lowering of the fused
    pipeline.  Shape chaining is validated at construction.
    """

    __slots__ = ("ops",)

    def __init__(self, ops: Tuple):
        if not ops:
            raise ValueError("CompositeOperator needs at least one operator")
        for outer, inner in zip(ops[:-1], ops[1:]):
            if outer.shape_in != inner.shape_out:
                raise ValueError(
                    f"cannot compose {outer!r} after {inner!r}: "
                    f"{inner.shape_out} does not feed {outer.shape_in}")
        object.__setattr__(self, "ops", tuple(ops))

    def __setattr__(self, name, value):
        raise AttributeError("CompositeOperator is immutable")

    @property
    def shape_in(self):
        return self.ops[-1].shape_in

    @property
    def shape_out(self):
        return self.ops[0].shape_out

    @property
    def dtype_in(self):
        return self.ops[-1].dtype_in

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        for op in reversed(self.ops):
            x = op(x)
        return x

    @property
    def T(self) -> "CompositeOperator":
        return CompositeOperator(tuple(op.T for op in reversed(self.ops)))

    @property
    def inverse(self) -> "CompositeOperator":
        return CompositeOperator(
            tuple(op.inverse for op in reversed(self.ops)))

    def __matmul__(self, other):
        if isinstance(other, CompositeOperator):
            return CompositeOperator(self.ops + other.ops)
        if isinstance(other, RadonOperator):
            return CompositeOperator(self.ops + (other,))
        return NotImplemented

    def lower(self):
        spec = jax.ShapeDtypeStruct(self.shape_in, self.dtype_in)
        return jax.jit(self.__call__).lower(spec)

    def compile(self):
        # dtype is part of the key: plans are dtype-agnostic (equal
        # across dtypes of one geometry) but compiled executables are not
        key = tuple((op.plan, op.kind, op.dtype_in.name)
                    for op in self.ops)
        with _CACHE_LOCK:
            exe = _AOT_CACHE.get(key)
        if exe is None:
            built = self.lower().compile()
            with _CACHE_LOCK:
                exe = _AOT_CACHE.setdefault(key, built)
        return exe

    def as_matrix(self) -> jnp.ndarray:
        mats = [op.as_matrix() for op in self.ops]
        out = mats[-1]
        for m in reversed(mats[:-1]):
            out = m @ out
        return out

    def __repr__(self) -> str:
        return " @ ".join(repr(op) for op in self.ops)

    def __eq__(self, other):
        return (isinstance(other, CompositeOperator)
                and self.ops == other.ops)

    def __hash__(self):
        return hash(self.ops)


# operators cross jit boundaries as zero-leaf pytrees, like their plans
jax.tree_util.register_pytree_node(
    RadonOperator,
    lambda op: ((), op),
    lambda op, _: op,
)
jax.tree_util.register_pytree_node(
    CompositeOperator,
    lambda op: ((), op),
    lambda op, _: op,
)


def DPRT(shape, dtype=jnp.int32, method: Optional[str] = None, *,
         strip_rows: Optional[int] = None,
         m_block: Optional[int] = None,
         batch_impl: Optional[str] = None,
         block_rows: Optional[int] = None,
         block_batch: Optional[int] = None,
         mesh=None) -> RadonOperator:
    """The forward DPRT operator for one input geometry.

    ``shape`` is ``(H, W)`` or ``(B, H, W)`` -- any size; non-prime
    geometries are zero-embedded into the next prime and ``op.inverse``
    crops back (bit-exact round trip for integer images).  Knobs left
    unset resolve against the ambient :func:`repro.radon.config` scope,
    then fall back to ``method="auto"`` (the registry's best backend for
    the shape/dtype/mesh).

    The returned operator is a cheap immutable view: plans, traces and
    AOT executables are cached per geometry process-wide, so building
    the "same" operator twice costs a dict lookup and shares all
    compilation state.
    """
    plan = get_plan(
        tuple(int(s) for s in shape), dtype,
        ambient.resolve("method", method, "auto"),
        strip_rows=ambient.resolve("strip_rows", strip_rows),
        m_block=ambient.resolve("m_block", m_block),
        batch_impl=ambient.resolve("batch_impl", batch_impl, "auto"),
        block_rows=ambient.resolve("block_rows", block_rows),
        block_batch=ambient.resolve("block_batch", block_batch),
        mesh=ambient.resolve("mesh", mesh))
    return RadonOperator(plan, "forward", dtype)


def operator_for(shape, dtype, knobs: tuple) -> RadonOperator:
    """The cached forward operator for one geometry from an
    :func:`repro.radon.ambient.snapshot_knobs` tuple -- the shared
    builder for call sites (``core/conv``, ``core/dft``) that carry the
    full knob snapshot through their own jit static arguments."""
    return DPRT(shape, dtype, **ambient.knobs_kwargs(knobs))
