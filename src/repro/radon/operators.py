"""First-class linear-operator objects over cached transform plans.

``op = radon.DPRT(shape, dtype)`` builds (or fetches -- plans and trace
caches are shared) the forward DPRT operator for one input geometry.
Operators are immutable views of a ``(plan, datapath)`` pair and expose
the full linear-operator algebra:

    op(f)            # apply: (…, H, W) -> (…, P+1, P), differentiable
    op.inverse       # the exact inverse transform (crops the embedding)
    op.T             # the exact adjoint -- A^T, NOT the inverse
    op.inverse.T     # adjoint of the inverse == (A^T)^-1
    op2 @ op1        # composition (applied right-to-left)
    op.lower()       # AOT: trace+lower for the declared input aval
    op.compile()     # AOT: cached per-geometry compiled executable
    op.as_matrix()   # dense (out_size, in_size) matrix (small N; tests)

Every application routes through :mod:`repro.radon.autodiff`, so
``jax.grad``/``jax.jvp`` are exact for every registered backend and
each geometry traces exactly once no matter how many operators,
legacy-wrapper calls, or serve workers touch it.
"""
from __future__ import annotations

import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dprt import accum_dtype_for
from repro.core.plan import RadonPlan, add_plan_evict_hook, get_plan

from . import ambient
from .autodiff import (_CACHE_LOCK, INVERSE_OF, TRANSPOSE_OF, jitted_apply,
                       trace_count)
from .fusion import flip_image, pipeline_apply

__all__ = ["DPRT", "Conv2D", "ProjectionFilter", "RadonOperator",
           "CompositeOperator", "operator_for",
           "aot_cache_info", "aot_cache_clear",
           "PersistentAOTCache", "aot_fingerprint"]

#: (plan, kind, dtype) -- or a tuple of per-operator key entries for
#: composites (filter/conv entries are ("proj_filter"|"fused_mul"|
#: "conv2d", …, id(array)) 4-tuples) -- -> jax compiled executable; the
#: per-geometry AOT cache behind ``op.compile()`` (and
#: ``serve --warmup``).  Entries drop in lockstep with plan-cache
#: evictions, like the jitted appliers.
_AOT_CACHE: dict = {}

#: key -> arrays whose id() participates in the key.  Pinning them for
#: the life of the cache entry keeps the id from being recycled by the
#: allocator, so a dead weights array can never alias a live key.
_AOT_PINS: dict = {}

#: cache_token -> threading.Lock serializing concurrent
#: PersistentAOTCache.get_or_compile of the same executable (two
#: services/routers sharing an aot_dir must not double-compile).
#: Guarded by _CACHE_LOCK; never dropped -- a few dozen tokens of locks.
_COMPILE_LOCKS: dict = {}


def _drop_plan_executables(plan) -> None:
    def involves(key) -> bool:
        if isinstance(key[0], tuple):   # composite: one entry per operator
            return any(plan in entry for entry in key)
        return key[0] == plan
    with _CACHE_LOCK:
        for key in [k for k in _AOT_CACHE if involves(k)]:
            del _AOT_CACHE[key]
            _AOT_PINS.pop(key, None)


add_plan_evict_hook(_drop_plan_executables)


def _aot_key_label(key) -> str:
    if isinstance(key[0], tuple):   # composite: one entry per operator
        return "@".join(str(e[0] if isinstance(e[0], str) else e[1])
                        for e in key)
    return str(key[1])


def aot_cache_info() -> dict:
    with _CACHE_LOCK:
        return {"currsize": len(_AOT_CACHE),
                "keys": sorted(_aot_key_label(k) for k in _AOT_CACHE)}


def aot_cache_clear() -> None:
    with _CACHE_LOCK:
        _AOT_CACHE.clear()
        _AOT_PINS.clear()


def aot_fingerprint() -> str:
    """Environment stamp persisted next to exported executables: a blob
    compiled under a different jax version / backend / device census is
    rejected at load time instead of crashing inside the runtime."""
    devs = jax.devices()
    kinds = ",".join(sorted({d.device_kind for d in devs}))
    return f"jax={jax.__version__};backend={jax.default_backend()};" \
           f"devices={len(devs)};kinds={kinds}"


def _topology_token(mesh) -> str:
    """The device-topology component of a persistent cache token."""
    if mesh is None:
        return f"{jax.default_backend()}{len(jax.devices())}"
    return ("mesh_" + "_".join(f"{a}{s}"
                               for a, s in dict(mesh.shape).items())
            + f"_{jax.default_backend()}")


def _export_compiled(exe) -> bytes:
    """Serialize one AOT-compiled executable to restorable bytes."""
    import pickle
    from jax.experimental import serialize_executable as _se
    payload, in_tree, out_tree = _se.serialize(exe)
    return pickle.dumps((payload, in_tree, out_tree))


def _import_compiled(data: bytes):
    """Deserialize :func:`_export_compiled` bytes into a loaded
    executable -- no tracing, no XLA compilation."""
    import pickle
    from jax.experimental import serialize_executable as _se
    payload, in_tree, out_tree = pickle.loads(data)
    return _se.deserialize_and_load(payload, in_tree, out_tree)


class PersistentAOTCache:
    """Disk-backed executable cache: ``jax.export``-style serialized AOT
    executables (via ``jax.experimental.serialize_executable``) keyed by
    :meth:`RadonOperator.cache_token` and stored through the
    :mod:`repro.checkpoint.store` blob machinery (atomic rename, header
    + payload).  A warm process restart deserializes the compiled
    executable instead of re-running XLA -- measured ~15-40x cheaper
    than a cold compile on the fused pallas plans.

    ``get_or_compile(op)`` is the whole surface: in-memory AOT cache
    first, then disk (fingerprint-checked), then compile-and-persist.
    Corrupt or stale blobs count as misses (``errors`` tallies them) and
    are overwritten; serialization failures degrade to plain in-memory
    compilation, never to an outage.  ``degraded_compiles`` counts the
    restores that had a blob on disk but still had to cold-compile
    (torn/rotten/stale blob) -- the number a restarted service surfaces
    in ``healthz`` to say "I came up, but not warm".

    Concurrent ``get_or_compile`` of the same token is serialized at two
    scopes: a process-wide lock table (two services, two routers in one
    process) and a cross-process :func:`~repro.checkpoint.store.blob_lock`
    file lock (N worker *processes* cold-starting over one ``aot_dir``).
    A waiter re-reads the blob once it holds the file lock, so whichever
    process compiled first publishes and everyone else restores -- one
    compile per unique executable across the whole pool.  Lock files
    left by SIGKILLed workers carry the holder PID and are stolen once
    the PID is dead (``lock_steals`` counts these); a filesystem that
    cannot do O_EXCL degrades to unlocked operation (``lock_degraded``)
    rather than refusing to serve.
    """

    def __init__(self, directory: str, *, lock_stale_s: float = 120.0,
                 lock_timeout_s: float = 600.0):
        self.directory = str(directory)
        self.lock_stale_s = float(lock_stale_s)
        self.lock_timeout_s = float(lock_timeout_s)
        self.hits = self.misses = self.errors = 0
        self.degraded_compiles = 0
        self.lock_steals = 0
        self.lock_degraded = 0
        self.lock_wait_s = 0.0

    def _compile_lock(self, key: str):
        with _CACHE_LOCK:
            return _COMPILE_LOCKS.setdefault(key, threading.Lock())

    def get_or_compile(self, op):
        """Return the executable for any operator exposing the AOT
        surface (``RadonOperator`` and ``Conv2D`` both do)."""
        from repro.checkpoint.store import blob_lock
        with _CACHE_LOCK:
            exe = _AOT_CACHE.get(op._aot_key())
        if exe is not None:
            return exe                      # in-memory: not a disk event
        key = op.cache_token()
        with self._compile_lock(key):
            with _CACHE_LOCK:               # racer finished while we waited
                exe = _AOT_CACHE.get(op._aot_key())
            if exe is not None:
                return exe
            try:
                with blob_lock(self.directory, key,
                               stale_s=self.lock_stale_s,
                               timeout_s=self.lock_timeout_s) as lk:
                    self.lock_steals += lk["steals"]
                    self.lock_wait_s += lk["waited_s"]
                    return self._restore_or_compile(op, key)
            except OSError:                 # O_EXCL unsupported / RO dir:
                self.lock_degraded += 1     # unlocked is worse, outage is
                return self._restore_or_compile(op, key)   # worse still

    def _restore_or_compile(self, op, key: str):
        """Disk-restore-else-compile for ``key``; caller holds both the
        in-process token lock and (normally) the cross-process file
        lock, so the load here observes any blob a peer process
        published while we waited."""
        from repro.checkpoint.store import load_blob, save_blob
        data = None
        had_blob = False
        try:
            data, meta = load_blob(self.directory, key)
            had_blob = data is not None
        except ValueError:              # torn/corrupt blob: overwrite
            self.errors += 1
            had_blob = True
        if data is not None \
                and meta.get("fingerprint") == aot_fingerprint():
            try:
                exe = op.import_executable(data)
                self.hits += 1
                return exe
            except Exception:           # undeserializable: recompile
                self.errors += 1
        self.misses += 1
        if had_blob:                    # blob existed but could not
            self.degraded_compiles += 1  # restore: degraded cold start
        exe = op.compile()
        try:
            save_blob(self.directory, key, op.export_executable(),
                      meta={"fingerprint": aot_fingerprint()})
        except Exception:               # read-only disk etc.: serve
            self.errors += 1            # from memory, count it
        return exe

    def stats(self) -> dict:
        return {"directory": self.directory, "hits": self.hits,
                "misses": self.misses, "errors": self.errors,
                "degraded_compiles": self.degraded_compiles,
                "lock_steals": self.lock_steals,
                "lock_degraded": self.lock_degraded,
                "lock_wait_s": round(self.lock_wait_s, 6)}

    def __repr__(self) -> str:
        return (f"PersistentAOTCache({self.directory!r}, hits={self.hits}, "
                f"misses={self.misses}, errors={self.errors}, "
                f"degraded_compiles={self.degraded_compiles})")


class RadonOperator:
    """One linear datapath of a :class:`~repro.core.plan.RadonPlan`.

    ``kind`` is one of ``forward`` / ``inverse`` / ``adjoint`` /
    ``inverse_adjoint``; ``dtype`` is the *image* dtype the operator was
    declared for (transform-domain inputs/outputs use its accumulator
    dtype, exactly as the transforms themselves do).
    """

    __slots__ = ("plan", "kind", "dtype")

    def __init__(self, plan: RadonPlan, kind: str, dtype):
        if kind not in TRANSPOSE_OF:
            raise ValueError(f"unknown operator kind {kind!r}")
        object.__setattr__(self, "plan", plan)
        object.__setattr__(self, "kind", kind)
        object.__setattr__(self, "dtype", jnp.dtype(dtype))

    def __setattr__(self, name, value):
        raise AttributeError("RadonOperator is immutable")

    # -- shapes / dtypes ---------------------------------------------------
    @property
    def _image_side(self) -> bool:
        """True when the INPUT lives in image space (H, W)."""
        return self.kind in ("forward", "inverse_adjoint")

    @property
    def shape_in(self) -> Tuple[int, ...]:
        g = self.plan.geometry
        return g.image_shape if self._image_side else g.transform_shape

    @property
    def shape_out(self) -> Tuple[int, ...]:
        g = self.plan.geometry
        return g.transform_shape if self._image_side else g.image_shape

    @property
    def dtype_in(self):
        # forward consumes raw images; every other datapath consumes
        # transform-domain / cotangent values, which live in the
        # accumulator dtype the transforms emit
        if self.kind == "forward":
            return self.dtype
        return jnp.dtype(accum_dtype_for(self.dtype, self.plan.geometry.prime))

    @property
    def dtype_out(self):
        return jnp.dtype(accum_dtype_for(self.dtype, self.plan.geometry.prime))

    # -- application -------------------------------------------------------
    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        return jitted_apply(self.plan, self.kind)(x)

    # -- algebra -----------------------------------------------------------
    @property
    def T(self) -> "RadonOperator":
        """The exact adjoint (transpose).  ``op.T`` satisfies
        ``<op(x), y> == <x, op.T(y)>`` -- it is NOT the inverse."""
        return RadonOperator(self.plan, TRANSPOSE_OF[self.kind], self.dtype)

    @property
    def inverse(self) -> "RadonOperator":
        """The exact inverse transform (bit-exact round trip on ints)."""
        return RadonOperator(self.plan, INVERSE_OF[self.kind], self.dtype)

    def __matmul__(self, other):
        return _compose(self, other)

    def _aot_key(self):
        return (self.plan, self.kind, self.dtype_in.name)

    # -- AOT ---------------------------------------------------------------
    @property
    def input_sharding(self):
        """The mesh-natural sharding of this operator's input (``None``
        for non-mesh plans): batched stacks shard over the mesh's data
        axes, everything else is replicated.  Matches the output
        sharding of the paired datapath, so AOT-compiled forward/inverse
        executables chain without resharding -- ``device_put`` inputs
        here before calling a ``.compile()``d executable under a mesh."""
        mesh = self.plan.mesh
        if mesh is None:
            return None
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.core.distributed import batch_partition_spec
        if self.plan.geometry.batched:
            return NamedSharding(mesh, batch_partition_spec(mesh))
        return NamedSharding(
            mesh, PartitionSpec(*([None] * len(self.shape_in))))

    def _input_aval(self) -> jax.ShapeDtypeStruct:
        sharding = self.input_sharding
        if sharding is None:
            return jax.ShapeDtypeStruct(self.shape_in, self.dtype_in)
        return jax.ShapeDtypeStruct(self.shape_in, self.dtype_in,
                                    sharding=sharding)

    def lower(self):
        """Trace + lower this operator for its declared input aval
        (``jax.jit(...).lower``); ``.compile()`` the result for an AOT
        executable, or use :meth:`compile` for the cached one."""
        return jitted_apply(self.plan, self.kind).lower(self._input_aval())

    def compile(self):
        """The AOT-compiled executable for this geometry, built at most
        once per (plan, datapath, dtype) process-wide.  The returned
        executable is callable and never retraces -- the serve path's
        steady state."""
        key = (self.plan, self.kind, self.dtype_in.name)
        with _CACHE_LOCK:
            exe = _AOT_CACHE.get(key)
        if exe is None:
            built = self.lower().compile()
            with _CACHE_LOCK:
                exe = _AOT_CACHE.setdefault(key, built)
        return exe

    # -- persistent AOT (executable export/import) -------------------------
    def cache_token(self) -> str:
        """A process-independent identity string for this operator's
        compiled executable: geometry, dtype, resolved method + block
        knobs, and the device topology it was compiled for.  Used as the
        key of the persistent on-disk executable cache -- two processes
        on identical topology/geometry agree on the token, a different
        mesh or dtype never collides."""
        p = self.plan
        shape = "x".join(str(s) for s in self.shape_in)
        knobs = "h{}_m{}_sr{}_br{}_bb{}".format(
            p.strip_rows, p.m_block, p.stream_rows, p.block_rows,
            p.block_batch)
        return (f"{self.kind}_{shape}_{self.dtype_in.name}_{p.method}_"
                f"{knobs}_{_topology_token(p.mesh)}")

    def export_executable(self) -> bytes:
        """Serialize this operator's AOT-compiled executable (compiling
        first if needed) to restorable bytes: a future process calls
        :meth:`import_executable` and serves without paying XLA
        compilation (only tracing-free deserialization)."""
        return _export_compiled(self.compile())

    def import_executable(self, data: bytes):
        """Deserialize executable bytes from :meth:`export_executable`
        and install them in the in-process AOT cache under this
        operator's key -- subsequent :meth:`compile` calls return the
        imported executable without compiling anything."""
        exe = _import_compiled(data)
        with _CACHE_LOCK:
            _AOT_CACHE[self._aot_key()] = exe
        return exe

    # -- introspection -----------------------------------------------------
    @property
    def trace_count(self) -> int:
        """Traces taken for this (plan, datapath) so far (all geometries
        of the plan's shape; exactly 1 after any number of same-shape
        calls)."""
        return trace_count(self.plan, self.kind)

    def as_matrix(self) -> jnp.ndarray:
        """Dense (out_size, in_size) matrix of this linear map.

        Materializes one basis vector per input element -- O(P^4) memory
        -- so this is for small primes (tests, reference checks) only.
        """
        size_in = 1
        for s in self.shape_in:
            size_in *= s
        basis = jnp.eye(size_in, dtype=self.dtype_in)
        cols = jax.vmap(lambda e: self(e.reshape(self.shape_in)).ravel())(
            basis)
        return cols.T  # vmap rows are images of basis vectors == columns

    def describe(self) -> dict:
        d = dict(self.plan.describe())
        d.update(kind=self.kind, dtype=self.dtype.name,
                 shape_in=self.shape_in, shape_out=self.shape_out)
        return d

    def __repr__(self) -> str:
        return (f"RadonOperator({self.kind}, {self.shape_in}->"
                f"{self.shape_out}, {self.dtype.name}, "
                f"method={self.plan.method!r})")

    # operators are value objects: equal views of equal plans compare ==
    def __eq__(self, other):
        return (isinstance(other, RadonOperator)
                and self.plan == other.plan and self.kind == other.kind
                and self.dtype == other.dtype)

    def __hash__(self):
        return hash((self.plan, self.kind, self.dtype))


def _compose(left, right):
    """``left @ right``: flatten into one CompositeOperator (which then
    recognizes fusible patterns)."""
    if not (_is_operator_like(left) and _is_operator_like(right)):
        return NotImplemented
    lops = left.ops if isinstance(left, CompositeOperator) else (left,)
    rops = right.ops if isinstance(right, CompositeOperator) else (right,)
    return CompositeOperator(lops + rops)


def _is_operator_like(x) -> bool:
    return callable(x) and hasattr(x, "shape_in") and hasattr(x, "shape_out")


def _fuse_ops(ops: Tuple) -> Tuple:
    """Recognize ``inv @ pointwise @ fwd`` triples over one plan and
    replace them with the fused projection pipeline (one kernel launch on
    capable backends; staged fallback otherwise -- same dispatch rule as
    everything else)."""
    fused, i = [], 0
    while i < len(ops):
        a = ops[i]
        if (i + 2 < len(ops)
                and isinstance(a, RadonOperator) and a.kind == "inverse"
                and isinstance(ops[i + 1], ProjectionFilter)
                and isinstance(ops[i + 2], RadonOperator)
                and ops[i + 2].kind == "forward"
                and a.plan == ops[i + 2].plan
                and tuple(ops[i + 1].weights.shape[-2:])
                == tuple(a.plan.geometry.transform_shape[-2:])):
            fused.append(FusedProjectionPipeline(
                a.plan, ops[i + 1].weights, ops[i + 2].dtype))
            i += 3
        else:
            fused.append(a)
            i += 1
    return tuple(fused)


class CompositeOperator:
    """Right-to-left composition of operators: ``(g @ f)(x) == g(f(x))``.

    Supports the same algebra (``.T`` reverses and transposes,
    ``.inverse`` reverses and inverts) plus AOT lowering of the fused
    pipeline.  Shape chaining is validated at construction, and
    ``inverse @ ProjectionFilter @ forward`` triples over one plan are
    rewritten into the fused projection-domain pipeline (a single kernel
    launch on pipeline-capable backends).
    """

    __slots__ = ("ops",)

    def __init__(self, ops: Tuple):
        if not ops:
            raise ValueError("CompositeOperator needs at least one operator")
        ops = _fuse_ops(tuple(ops))
        for outer, inner in zip(ops[:-1], ops[1:]):
            if (outer.shape_in is not None and inner.shape_out is not None
                    and outer.shape_in != inner.shape_out):
                raise ValueError(
                    f"cannot compose {outer!r} after {inner!r}: "
                    f"{inner.shape_out} does not feed {outer.shape_in}")
        object.__setattr__(self, "ops", tuple(ops))

    def __setattr__(self, name, value):
        raise AttributeError("CompositeOperator is immutable")

    @property
    def shape_in(self):
        return self.ops[-1].shape_in

    @property
    def shape_out(self):
        return self.ops[0].shape_out

    @property
    def dtype_in(self):
        return self.ops[-1].dtype_in

    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        for op in reversed(self.ops):
            x = op(x)
        return x

    @property
    def T(self) -> "CompositeOperator":
        return CompositeOperator(tuple(op.T for op in reversed(self.ops)))

    @property
    def inverse(self) -> "CompositeOperator":
        return CompositeOperator(
            tuple(op.inverse for op in reversed(self.ops)))

    def __matmul__(self, other):
        return _compose(self, other)

    def lower(self):
        shape = self.shape_in
        if shape is None:
            # a shape-polymorphic input-side operator (ProjectionFilter):
            # lower for its weights' own shape, the natural unbatched aval
            inner = self.ops[-1]
            weights = getattr(inner, "weights", None)
            if weights is None:
                raise ValueError(
                    f"cannot AOT-lower a composite whose input operator "
                    f"{inner!r} has no declared input shape")
            shape = tuple(weights.shape)
        spec = jax.ShapeDtypeStruct(shape, self.dtype_in)
        return jax.jit(self.__call__).lower(spec)

    def compile(self):
        # dtype is part of the key: plans are dtype-agnostic (equal
        # across dtypes of one geometry) but compiled executables are not
        key = tuple(op._aot_key() for op in self.ops)
        pins = tuple(p for op in self.ops
                     for p in getattr(op, "_aot_pins", lambda: ())())
        with _CACHE_LOCK:
            exe = _AOT_CACHE.get(key)
        if exe is None:
            built = self.lower().compile()
            with _CACHE_LOCK:
                exe = _AOT_CACHE.setdefault(key, built)
                if pins:    # keep id()-keyed arrays alive with the entry
                    _AOT_PINS.setdefault(key, pins)
        return exe

    def as_matrix(self) -> jnp.ndarray:
        mats = [op.as_matrix() for op in self.ops]
        out = mats[-1]
        for m in reversed(mats[:-1]):
            out = m @ out
        return out

    def __repr__(self) -> str:
        return " @ ".join(repr(op) for op in self.ops)

    def __eq__(self, other):
        return (isinstance(other, CompositeOperator)
                and self.ops == other.ops)

    def __hash__(self):
        return hash(self.ops)


class ProjectionFilter:
    """Pointwise projection-domain filter: ``r -> weights * r``.

    A diagonal (self-adjoint) linear operator on ``(…, P+1, P)``
    projections.  On its own it is a plain elementwise multiply; its
    value is in *composition*: ``op.inverse @ ProjectionFilter(w) @ op``
    is recognized by :class:`CompositeOperator` and rewritten into the
    fused projection-domain pipeline, so the filtered reconstruction
    runs as ONE kernel launch on pipeline-capable backends (staged
    fallback elsewhere).  Shape-polymorphic over leading batch dims
    (``shape_in``/``shape_out`` are ``None`` wildcards for chaining).
    """

    __slots__ = ("weights",)

    def __init__(self, weights):
        weights = jnp.asarray(weights)
        if weights.ndim not in (2, 3) or \
                weights.shape[-2] != weights.shape[-1] + 1:
            raise ValueError(
                f"projection weights must be (…, P+1, P), "
                f"got {weights.shape}")
        object.__setattr__(self, "weights", weights)

    def __setattr__(self, name, value):
        raise AttributeError("ProjectionFilter is immutable")

    shape_in = None   # polymorphic: any (…, P+1, P) matching the weights
    shape_out = None

    @property
    def dtype_in(self):
        return self.weights.dtype

    def __call__(self, r: jnp.ndarray) -> jnp.ndarray:
        return r * self.weights.astype(r.dtype)

    @property
    def T(self) -> "ProjectionFilter":
        return self    # diagonal and real: self-adjoint

    @property
    def inverse(self):
        raise TypeError(
            "ProjectionFilter has no exact inverse (1/weights is not an "
            "integer-exact operation); build the reciprocal filter "
            "explicitly if that is what you mean")

    def __matmul__(self, other):
        return _compose(self, other)

    def _aot_key(self):
        return ("proj_filter", self.weights.shape,
                self.weights.dtype.name, id(self.weights))

    def _aot_pins(self):
        return (self.weights,)

    def __repr__(self) -> str:
        return f"ProjectionFilter({self.weights.shape})"


class FusedProjectionPipeline:
    """``inverse @ ProjectionFilter @ forward`` collapsed onto one plan:
    applied via the fused projection-domain pipeline (one kernel launch
    on capable backends; staged registry fallback otherwise), with exact
    autodiff through :mod:`repro.radon.fusion`."""

    __slots__ = ("plan", "weights", "dtype")

    def __init__(self, plan: RadonPlan, weights, dtype):
        object.__setattr__(self, "plan", plan)
        object.__setattr__(self, "weights", jnp.asarray(weights))
        object.__setattr__(self, "dtype", jnp.dtype(dtype))

    def __setattr__(self, name, value):
        raise AttributeError("FusedProjectionPipeline is immutable")

    @property
    def shape_in(self):
        return self.plan.geometry.image_shape

    shape_out = property(lambda self: self.plan.geometry.image_shape)

    @property
    def dtype_in(self):
        # same contract as the forward operator it swallowed: the fused
        # pipeline consumes raw images of the plan's declared dtype (the
        # fusion rewrite must not change a composite's input signature)
        return jnp.dtype(self.dtype)

    def __call__(self, f: jnp.ndarray) -> jnp.ndarray:
        return pipeline_apply(self.plan, f, "mul", self.weights)

    @property
    def T(self):
        """(B W A)^T = A^T W B^T: the exact-adjoint datapaths around the
        self-adjoint filter (not itself a fusible pattern)."""
        return CompositeOperator((
            RadonOperator(self.plan, "adjoint", self.dtype),
            ProjectionFilter(self.weights),
            RadonOperator(self.plan, "inverse_adjoint", self.dtype)))

    @property
    def inverse(self):
        raise TypeError(
            "FusedProjectionPipeline (inverse @ filter @ forward) has no "
            "exact inverse: the pointwise filter is not invertible in "
            "exact arithmetic")

    def __matmul__(self, other):
        return _compose(self, other)

    def _aot_key(self):
        return ("fused_mul", self.plan, self.dtype.name, id(self.weights))

    def _aot_pins(self):
        return (self.weights,)

    def __repr__(self) -> str:
        return (f"FusedProjectionPipeline({self.shape_in}, "
                f"method={self.plan.method!r})")


class Conv2D:
    """Exact circular 2-D convolution by a fixed kernel, as an operator.

    ``Conv2D(shape, kernel)`` convolves ``(H, W)`` images (or
    ``(B, H, W)`` stacks) with ``kernel`` on the ``(H, W)`` torus --
    the paper's Sec. VI application surfaced as operator fusion.  On
    square prime geometries the application is the fused projection-
    domain pipeline (transform, per-direction 1-D convolution, and
    inverse in ONE kernel launch on pipeline-capable backends); other
    geometries fold the exact prime-embedded linear convolution onto
    the torus.  ``jax.grad`` is exact in both the image and (via
    ``kernel=``-differentiation) the kernel, through every backend.

    ``op.T`` is the exact adjoint -- circular *correlation*, i.e.
    convolution by the flipped kernel.  ``as_matrix()`` materializes the
    dense circulant for small-N tests.
    """

    __slots__ = ("plan", "kernel", "dtype")

    def __init__(self, shape, kernel, dtype=None, method: Optional[str] = None,
                 *, strip_rows: Optional[int] = None,
                 m_block: Optional[int] = None,
                 batch_impl: Optional[str] = None,
                 block_rows: Optional[int] = None,
                 stream_rows: Optional[int] = None,
                 block_batch: Optional[int] = None,
                 mesh=None):
        kernel = jnp.asarray(kernel)
        shape = tuple(int(s) for s in shape)
        h, w = shape[-2:]
        if kernel.ndim != 2 or kernel.shape[0] > h or kernel.shape[1] > w:
            raise ValueError(
                f"kernel must be 2-D and fit the {shape[-2:]} torus, "
                f"got {kernel.shape}")
        if dtype is None:
            dtype = kernel.dtype
        # the kernel lives zero-padded on the full (H, W) torus
        kernel = jnp.pad(kernel.astype(dtype),
                         ((0, h - kernel.shape[0]), (0, w - kernel.shape[1])))
        plan = DPRT(shape, dtype, method, strip_rows=strip_rows,
                    m_block=m_block, batch_impl=batch_impl,
                    block_rows=block_rows, stream_rows=stream_rows,
                    block_batch=block_batch, mesh=mesh).plan
        object.__setattr__(self, "plan", plan)
        object.__setattr__(self, "kernel", kernel)
        object.__setattr__(self, "dtype", jnp.dtype(dtype))

    def __setattr__(self, name, value):
        raise AttributeError("Conv2D is immutable")

    @property
    def shape_in(self):
        return self.plan.geometry.image_shape

    shape_out = property(lambda self: self.plan.geometry.image_shape)

    @property
    def dtype_in(self):
        return self.dtype

    @property
    def dtype_out(self):
        return jnp.dtype(accum_dtype_for(self.dtype, self.plan.geometry.prime))

    def __call__(self, f: jnp.ndarray) -> jnp.ndarray:
        g = self.plan.geometry
        if g.native:
            return pipeline_apply(self.plan, f, "conv", self.kernel)
        # non-native: the true (H, W)-torus convolution = fold of the
        # exact linear convolution (conv.py routes its DPRT stages
        # through the same differentiable pipeline appliers).  The
        # plan's remaining knobs (mesh, batch/blocking) travel via an
        # ambient scope: conv resolves them eagerly per call.
        from repro.core.conv import circ_conv2d_dprt  # lazy: conv -> radon
        with ambient.config(mesh=self.plan.mesh,
                            batch_impl=self.plan.batch_impl,
                            block_rows=self.plan.block_rows,
                            stream_rows=self.plan.stream_rows,
                            block_batch=self.plan.block_batch):
            return circ_conv2d_dprt(f, self.kernel,
                                    method=self.plan.method,
                                    strip_rows=self.plan.strip_rows,
                                    m_block=self.plan.m_block)

    @property
    def T(self) -> "Conv2D":
        """Circular correlation: convolution by the torus-flipped kernel
        (same plan knobs, blocking/batching included)."""
        return Conv2D(self.shape_in, flip_image(self.kernel), self.dtype,
                      self.plan.method, strip_rows=self.plan.strip_rows,
                      m_block=self.plan.m_block,
                      batch_impl=self.plan.batch_impl,
                      block_rows=self.plan.block_rows,
                      stream_rows=self.plan.stream_rows,
                      block_batch=self.plan.block_batch,
                      mesh=self.plan.mesh)

    def __matmul__(self, other):
        return _compose(self, other)

    @property
    def inverse(self):
        raise TypeError(
            "Conv2D has no exact inverse (deconvolution is not an "
            "integer-exact operation)")

    def as_matrix(self) -> jnp.ndarray:
        """Dense (H*W, H*W) circulant of this convolution (small N)."""
        size = 1
        for s in self.shape_in:
            size *= s
        basis = jnp.eye(size, dtype=self.dtype)
        cols = jax.vmap(lambda e: self(e.reshape(self.shape_in)).ravel())(
            basis)
        return cols.T

    def _aot_key(self):
        return ("conv2d", self.plan, self.dtype.name, id(self.kernel))

    def _aot_pins(self):
        return (self.kernel,)

    # -- AOT / persistent executable export --------------------------------
    def lower(self):
        """Trace + lower the convolution for its declared input aval."""
        spec = jax.ShapeDtypeStruct(self.shape_in, self.dtype_in)
        return jax.jit(self.__call__).lower(spec)

    def compile(self):
        """The AOT-compiled executable for this (geometry, kernel),
        cached process-wide alongside the transform executables (the
        kernel array is pinned for the life of the entry)."""
        key = self._aot_key()
        with _CACHE_LOCK:
            exe = _AOT_CACHE.get(key)
        if exe is None:
            built = self.lower().compile()
            with _CACHE_LOCK:
                exe = _AOT_CACHE.setdefault(key, built)
                _AOT_PINS.setdefault(key, self._aot_pins())
        return exe

    def cache_token(self) -> str:
        """Persistent-cache identity: like the transform operators',
        plus a digest of the kernel taps -- the weights are baked into
        the compiled executable, so different kernels must never share
        a blob."""
        import hashlib
        import numpy as _np
        p = self.plan
        shape = "x".join(str(s) for s in self.shape_in)
        digest = hashlib.sha1(
            _np.asarray(self.kernel).tobytes()).hexdigest()[:16]
        knobs = "h{}_m{}_sr{}_br{}_bb{}".format(
            p.strip_rows, p.m_block, p.stream_rows, p.block_rows,
            p.block_batch)
        return (f"conv2d_{shape}_{self.dtype.name}_{p.method}_k{digest}_"
                f"{knobs}_{_topology_token(p.mesh)}")

    def export_executable(self) -> bytes:
        """Serialize the AOT executable (see
        :meth:`RadonOperator.export_executable`)."""
        return _export_compiled(self.compile())

    def import_executable(self, data: bytes):
        """Install executable bytes from :meth:`export_executable` in
        the in-process AOT cache under this operator's key."""
        exe = _import_compiled(data)
        key = self._aot_key()
        with _CACHE_LOCK:
            _AOT_CACHE[key] = exe
            _AOT_PINS.setdefault(key, self._aot_pins())
        return exe

    def describe(self) -> dict:
        d = dict(self.plan.describe())
        d.update(kind="conv2d", kernel_shape=tuple(self.kernel.shape),
                 pipeline=self.plan.backend.pipeline is not None)
        return d

    def __repr__(self) -> str:
        return (f"Conv2D({self.shape_in}, kernel={self.kernel.shape}, "
                f"{self.dtype.name}, method={self.plan.method!r})")


# operators cross jit boundaries as zero-leaf pytrees, like their plans
jax.tree_util.register_pytree_node(
    RadonOperator,
    lambda op: ((), op),
    lambda op, _: op,
)
jax.tree_util.register_pytree_node(
    CompositeOperator,
    lambda op: ((), op),
    lambda op, _: op,
)


def DPRT(shape, dtype=jnp.int32, method: Optional[str] = None, *,
         strip_rows: Optional[int] = None,
         m_block: Optional[int] = None,
         batch_impl: Optional[str] = None,
         block_rows: Optional[int] = None,
         stream_rows: Optional[int] = None,
         block_batch: Optional[int] = None,
         mesh=None) -> RadonOperator:
    """The forward DPRT operator for one input geometry.

    ``shape`` is ``(H, W)`` or ``(B, H, W)`` -- any size; non-prime
    geometries are zero-embedded into the next prime and ``op.inverse``
    crops back (bit-exact round trip for integer images).  Knobs left
    unset resolve against the ambient :func:`repro.radon.config` scope,
    then fall back to ``method="auto"`` (the registry's best backend for
    the shape/dtype/mesh).

    The returned operator is a cheap immutable view: plans, traces and
    AOT executables are cached per geometry process-wide, so building
    the "same" operator twice costs a dict lookup and shares all
    compilation state.
    """
    plan = get_plan(
        tuple(int(s) for s in shape), dtype,
        ambient.resolve("method", method, "auto"),
        strip_rows=ambient.resolve("strip_rows", strip_rows),
        m_block=ambient.resolve("m_block", m_block),
        batch_impl=ambient.resolve("batch_impl", batch_impl, "auto"),
        block_rows=ambient.resolve("block_rows", block_rows),
        stream_rows=ambient.resolve("stream_rows", stream_rows),
        block_batch=ambient.resolve("block_batch", block_batch),
        mesh=ambient.resolve("mesh", mesh))
    return RadonOperator(plan, "forward", dtype)


def operator_for(shape, dtype, knobs: tuple) -> RadonOperator:
    """The cached forward operator for one geometry from an
    :func:`repro.radon.ambient.snapshot_knobs` tuple -- the shared
    builder for call sites (``core/conv``, ``core/dft``) that carry the
    full knob snapshot through their own jit static arguments."""
    return DPRT(shape, dtype, **ambient.knobs_kwargs(knobs))
