"""Exact autodiff + trace accounting for the four plan datapaths.

The DPRT and its inverse are *linear* maps, so their derivatives are
known in closed form: the JVP of a linear operator is the operator
itself, and the VJP is its transpose.  This module installs those rules
once, at the plan layer, so ``jax.grad``/``jax.jvp`` through ANY
registered backend -- including the fused Pallas kernels, whose raw
``pallas_call`` JAX cannot transpose -- is exact:

* each of the four datapaths (``forward`` / ``inverse`` / ``adjoint`` /
  ``inverse_adjoint`` on :class:`repro.core.plan.RadonPlan`) is wrapped
  in a :func:`jax.custom_jvp` whose tangent is emitted through
  :func:`jax.custom_derivatives.linear_call`;
* ``linear_call`` carries the *explicit transpose* -- the mathematically
  paired datapath, built from the same backend registry skew-sum as the
  primal (see the adjoint algebra in :mod:`repro.core.plan`) -- so
  reverse-mode transposition routes through the registry instead of
  trying to differentiate kernel internals;
* forward-mode needs no transposition at all: the tangent IS the
  operator applied to the input tangent, by linearity.

The primal path is untouched (no ``linear_call`` in an undifferentiated
jaxpr), so serving traffic pays zero overhead for differentiability.

Trace accounting
----------------
Every jitted datapath bumps a per-``(plan, kind, aval)`` counter *at
trace time* (the wrapped body only executes while JAX is tracing).
:func:`trace_count` exposes the counters and :func:`retrace_guard` turns
"this geometry must compile exactly once" from a hope into an assertion
-- the serving regression the pytree-registered plans exist to prevent.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import jax.numpy as jnp
from jax.custom_derivatives import linear_call

from repro.core.plan import add_plan_evict_hook

# One lock for every per-plan cache in the radon layer (_JITTED,
# _TRACE_COUNTS here; _AOT_CACHE in operators.py): plan-cache eviction
# hooks fire outside the plan cache's own lock and may race concurrent
# serving threads inserting into these dicts.  RLock because a guard
# violation raises while the lock is held by the same thread's bump.
_CACHE_LOCK = threading.RLock()

__all__ = [
    "KINDS",
    "TRANSPOSE_OF",
    "INVERSE_OF",
    "apply_plan",
    "jitted_apply",
    "trace_count",
    "trace_counts",
    "reset_trace_counts",
    "retrace_guard",
    "RetraceError",
]

#: the four linear datapaths a plan exposes, and their algebra
KINDS = ("forward", "inverse", "adjoint", "inverse_adjoint")
TRANSPOSE_OF = {"forward": "adjoint", "adjoint": "forward",
                "inverse": "inverse_adjoint", "inverse_adjoint": "inverse"}
# (A^T)^-1 == (A^-1)^T, so inversion swaps within the transposed pair
INVERSE_OF = {"forward": "inverse", "inverse": "forward",
              "adjoint": "inverse_adjoint", "inverse_adjoint": "adjoint"}


def _primal(plan, kind: str, x: jnp.ndarray) -> jnp.ndarray:
    return getattr(plan, kind)(x)


# ---------------------------------------------------------------------------
# trace accounting
# ---------------------------------------------------------------------------
class RetraceError(RuntimeError):
    """A geometry exceeded its allowed trace count inside a guard."""


_TRACE_COUNTS: dict = {}
_GUARDS: list = []


def _note_trace(plan, kind: str, x) -> None:
    key = (plan, kind, tuple(x.shape), jnp.dtype(x.dtype).name)
    with _CACHE_LOCK:
        _TRACE_COUNTS[key] = _TRACE_COUNTS.get(key, 0) + 1
        for limit, baseline in _GUARDS:
            fresh = _TRACE_COUNTS[key] - baseline.get(key, 0)
            if fresh > limit:
                raise RetraceError(
                    f"{kind} DPRT for shape {tuple(x.shape)} "
                    f"{jnp.dtype(x.dtype).name} traced {fresh} times inside "
                    f"a retrace_guard(max_traces={limit}) -- a cached plan/"
                    f"operator should compile once per geometry")


def trace_counts() -> dict:
    """All counters: {(plan, kind, shape, dtype_name): traces}.

    Counters live exactly as long as their plan stays in the bounded
    plan cache; eviction drops them with the jitted appliers.
    """
    with _CACHE_LOCK:
        return dict(_TRACE_COUNTS)


def trace_count(plan=None, kind: Optional[str] = None) -> int:
    """Total traces, optionally filtered by plan and/or datapath kind."""
    total = 0
    for (p, k, _shape, _dt), n in trace_counts().items():
        if plan is not None and p != plan:
            continue
        if kind is not None and k != kind:
            continue
        total += n
    return total


def reset_trace_counts() -> None:
    with _CACHE_LOCK:
        _TRACE_COUNTS.clear()


@contextlib.contextmanager
def retrace_guard(max_traces: int = 1):
    """Raise :class:`RetraceError` if any (plan, kind, geometry) traces
    more than ``max_traces`` times inside the scope.

    Wrap a serving loop's steady state in ``retrace_guard()`` to assert
    the zero-retrace property instead of discovering compile storms in
    a latency dashboard.
    """
    with _CACHE_LOCK:
        frame = (int(max_traces), dict(_TRACE_COUNTS))
        _GUARDS.append(frame)
    try:
        yield
    finally:
        with _CACHE_LOCK:
            _GUARDS.remove(frame)


# ---------------------------------------------------------------------------
# the differentiable, jitted datapaths
# ---------------------------------------------------------------------------
_JITTED: dict = {}


def _drop_plan(plan) -> None:
    """Plan-cache eviction hook: release the jitted appliers (and their
    compiled executables) AND the trace counters of a plan the bounded
    cache let go, so the plan cache's bound actually bounds process
    memory (an evicted-then-rebuilt geometry restarts at one trace)."""
    with _CACHE_LOCK:
        for key in [k for k in _JITTED if k[0] == plan]:
            del _JITTED[key]
        for key in [k for k in _TRACE_COUNTS if k[0] == plan]:
            del _TRACE_COUNTS[key]


add_plan_evict_hook(_drop_plan)


def jitted_apply(plan, kind: str):
    """The jitted, differentiable callable for one (plan, datapath).

    Cached per (plan, kind), so every consumer -- operator objects, the
    legacy ``dprt``/``idprt`` wrappers, serve -- shares one trace cache
    per geometry.  Entries are dropped in lockstep with the bounded
    plan cache (see :func:`repro.core.plan.add_plan_evict_hook`), so
    this cache cannot outgrow the plan cache's bound times four.
    """
    with _CACHE_LOCK:
        cached = _JITTED.get((plan, kind))
    if cached is not None:
        return cached
    if kind not in KINDS:
        raise ValueError(f"unknown datapath kind {kind!r}; one of {KINDS}")
    tkind = TRANSPOSE_OF[kind]

    @jax.custom_jvp
    def apply(x):
        _note_trace(plan, kind, x)
        return _primal(plan, kind, x)

    @apply.defjvp
    def _apply_jvp(primals, tangents):
        (x,), (t,) = primals, tangents
        # linear operator: tangent_out = A @ tangent, staged through
        # linear_call so reverse-mode transposes to the explicit
        # registry-built adjoint instead of differentiating kernels
        tan = linear_call(lambda _res, v: _primal(plan, kind, v),
                          lambda _res, ct: _primal(plan, tkind, ct),
                          (), t)
        return apply(x), tan

    with _CACHE_LOCK:
        # a racing builder may have won; keep the first so both callers
        # share one trace cache
        return _JITTED.setdefault((plan, kind), jax.jit(apply))


def apply_plan(plan, kind: str, x: jnp.ndarray) -> jnp.ndarray:
    """Run one datapath of ``plan`` on ``x``: jitted + differentiable."""
    return jitted_apply(plan, kind)(x)
