"""Differentiable projection-pipeline appliers (the fused conv/filter path).

:meth:`repro.core.plan.RadonPlan.pipeline` runs ``forward -> per-direction
op -> inverse`` as one fused kernel launch on capable backends -- a raw
``pallas_call`` JAX cannot transpose.  This module makes the *operation*
differentiable anyway, exactly like :mod:`repro.radon.autodiff` does for
the four plan datapaths: the pipeline is **bilinear** in the image and
the operand, so its JVP is the sum of two linear terms, each staged
through :func:`jax.custom_derivatives.linear_call` with an explicit
transpose built from the same registry:

* w.r.t. the image ``f`` (operand fixed): the transpose of circular
  convolution is circular *correlation* -- the SAME fused pipeline with
  the flipped operand (``flip(g)[x] = g[<-x>]``; in the projection
  domain a lane flip, since ``R_{flip(g)}(m, d) = R_g(m, <-d>_N)``).
  The pointwise ``"mul"`` pipeline transposes to
  ``adjoint(w * inverse_adjoint(ct))`` -- the exact-adjoint plan
  datapaths around the self-adjoint diagonal weight.
* w.r.t. the operand (image fixed): commutativity (``f ** g = g ** f``)
  gives the image-operand transpose as the flipped-image pipeline; the
  projection/weight forms are per-direction correlations against
  ``forward(f)`` around ``inverse_adjoint(ct)``.  Operands shared
  across a batched plan sum their cotangent over the batch.

Primal traffic pays nothing for this (no ``linear_call`` in an
undifferentiated jaxpr), traces are counted per (plan, pipeline-op) in
the same accounting as the plan datapaths, and cached appliers drop
with plan-cache evictions (they live in the same store).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.custom_derivatives import SymbolicZero, linear_call

from .autodiff import _CACHE_LOCK, _JITTED, _note_trace, apply_plan

__all__ = ["pipeline_apply", "jitted_pipeline", "flip_image", "flip_lanes"]


def flip_image(g: jnp.ndarray) -> jnp.ndarray:
    """Torus flip: out[..., x, y] = g[..., <-x>_H, <-y>_W]."""
    return jnp.roll(jnp.flip(g, (-2, -1)), (1, 1), (-2, -1))


def flip_lanes(r: jnp.ndarray) -> jnp.ndarray:
    """Lane flip: out[..., m, d] = r[..., m, <-d>_N] -- the projection-
    domain image of :func:`flip_image` (R_{flip(g)}(m, d) = R_g(m, <-d>))."""
    return jnp.roll(jnp.flip(r, -1), 1, -1)


def _is_image_form(plan, wshape) -> bool:
    p = plan.geometry.prime
    return tuple(wshape[-2:]) == (p, p)


def _sum_to_operand(ct_w: jnp.ndarray, wshape, wdtype) -> jnp.ndarray:
    """An operand shared across a batched plan accumulates its cotangent
    over the batch; also matches the linear input's dtype for
    ``linear_call``'s transpose contract."""
    while ct_w.ndim > len(wshape):
        ct_w = ct_w.sum(axis=0)
    return ct_w.astype(wdtype)


def _transpose_f(plan, op: str, w, ct):
    """ct_f = (d pipeline / d f)^T ct, built from registry datapaths."""
    if op == "conv":
        flip = flip_image(w) if _is_image_form(plan, w.shape) \
            else flip_lanes(w)
        out = plan.pipeline(ct, "conv", flip.astype(ct.dtype))
    elif op == "mul":
        out = apply_plan(plan, "adjoint",
                         w.astype(ct.dtype)
                         * apply_plan(plan, "inverse_adjoint", ct))
    else:  # "none": (B A)^T = A^T B^T
        out = apply_plan(plan, "adjoint",
                         apply_plan(plan, "inverse_adjoint", ct))
    return out.astype(ct.dtype)


def _transpose_w(plan, op: str, f, wshape, wdtype, ct):
    """ct_w = (d pipeline / d operand)^T ct.  Only the operand's aval
    (shape/dtype) is captured, never the tangent tracer itself."""
    if op == "conv" and _is_image_form(plan, wshape):
        # commutativity: d/dg (f ** g) is h -> f ** h, whose transpose
        # is the flipped-image pipeline again (fused on capable backends)
        return _sum_to_operand(
            plan.pipeline(ct, "conv", flip_image(f).astype(ct.dtype)),
            wshape, wdtype)
    bt = apply_plan(plan, "inverse_adjoint", ct)       # B^T ct, (…, P+1, P)
    if op == "mul":
        rf = apply_plan(plan, "forward", f.astype(ct.dtype))
        return _sum_to_operand(rf * bt, wshape, wdtype)
    # conv, projection-form operand: per-direction correlation
    #   ct_rg[m, s] = sum_d (B^T ct)[m, d] * R_f[m, <d - s>]
    from repro.core.conv import circ_conv1d_exact  # lazy: conv imports radon
    rf = apply_plan(plan, "forward", f.astype(ct.dtype))
    return _sum_to_operand(circ_conv1d_exact(bt, flip_lanes(rf)),
                           wshape, wdtype)


def _is_zero_tangent(t) -> bool:
    if isinstance(t, SymbolicZero):
        return True
    return getattr(t, "dtype", None) == jax.dtypes.float0


def jitted_pipeline(plan, op: str):
    """The jitted, differentiable fused-pipeline callable for one
    (plan, op): ``fn(f)`` for ``op="none"``, else ``fn(f, operand)``.
    Cached in the same per-plan store as the datapath appliers, so
    entries drop in lockstep with plan-cache evictions."""
    key = (plan, ("pipeline", op))
    with _CACHE_LOCK:
        cached = _JITTED.get(key)
    if cached is not None:
        return cached

    if op == "none":
        @jax.custom_jvp
        def apply(f):
            _note_trace(plan, "pipeline:none", f)
            return plan.pipeline(f, "none")

        @apply.defjvp
        def _jvp(primals, tangents):
            (f,), (df,) = primals, tangents
            tan = linear_call(lambda _r, v: plan.pipeline(v, "none"),
                              lambda _r, ct: _transpose_f(plan, "none",
                                                          None, ct),
                              (), df)
            return apply(f), tan
    else:
        @jax.custom_jvp
        def apply(f, w):
            _note_trace(plan, f"pipeline:{op}", f)
            return plan.pipeline(f, op, w)

        @apply.defjvp
        def _jvp(primals, tangents):
            (f, w), (df, dw) = primals, tangents
            out = apply(f, w)
            terms = []
            # residuals are gradient-stopped: each bilinear term handles
            # exactly one argument's tangent, and an un-stopped residual
            # would make linear_call differentiate the raw kernel itself
            if not _is_zero_tangent(df):
                terms.append(linear_call(
                    lambda w_, v: plan.pipeline(v, op, w_),
                    lambda w_, ct: _transpose_f(plan, op, w_, ct),
                    jax.lax.stop_gradient(w), df))
            if not _is_zero_tangent(dw):
                wshape, wdtype = tuple(dw.shape), dw.dtype
                terms.append(linear_call(
                    lambda f_, vw: plan.pipeline(f_, op, vw),
                    lambda f_, ct: _transpose_w(plan, op, f_, wshape,
                                                wdtype, ct),
                    jax.lax.stop_gradient(f), dw))
            tan = terms[0] if terms else jnp.zeros(out.shape, out.dtype)
            for t in terms[1:]:
                tan = tan + t
            return out, tan

    with _CACHE_LOCK:
        return _JITTED.setdefault(key, jax.jit(apply))


def pipeline_apply(plan, f: jnp.ndarray, op: str = "conv",
                   operand: jnp.ndarray | None = None) -> jnp.ndarray:
    """Run the fused (or staged-fallback) projection pipeline of ``plan``
    on ``f``: jitted, trace-counted, and exactly differentiable in both
    the image and the operand."""
    if op == "none":
        return jitted_pipeline(plan, op)(f)
    return jitted_pipeline(plan, op)(f, operand)
