"""Iterative reconstruction over DPRT operators: ``radon.solve``.

The paper's motivating application is reconstruction from projections.
With exact transforms, exact adjoints, and the fused projection-domain
pipeline already in place, weighted/partial-data least squares

    min_x || d * (A x - b) ||_2^2,      d = mask * weight

closes the loop.  This module is the solver subsystem:

* **Sherman-Morrison fast path** (``method="sherman"``, the ``"auto"``
  choice when nothing is masked): the frame identity
  ``A^T A = P I + 1 1^T`` (tested at small N since PR 4) inverts in
  closed form,

      (P I + 1 1^T)^{-1} y = y/P - sum(y) / (P (P + H W)),

  so the unmasked least-squares solution is ONE adjoint plus a rank-1
  correction -- no iteration (``iterations == 0``).
* **CG on the normal equations** (``method="cg"``, the masked
  default): each application of ``M^T M`` is one fused
  ``pipeline("mul", d^2)`` launch plus a column-sum reduction
  (:meth:`repro.radon.masking.MaskedDPRT.normal_apply`), optionally
  preconditioned by the exact unmasked inverse (``precond="sherman"``,
  SPD) or a :class:`~repro.radon.ProjectionFilter` /  ``(…, P+1, P)``
  weight array riding the same fused pipeline (flexible PCG: a filter
  preconditioner is not guaranteed SPD -- convergence is then
  heuristic, the residual history is the audit trail).
* **LSQR** (Golub-Kahan bidiagonalization on ``M = D A`` itself) and
  **Landweber** (``x += tau (M^T b_w - M^T M x)``, default step
  ``tau = 1 / (max(d)^2 (P + H W))`` from the exact spectral bound
  ``||A||^2 = P + H W``) complete the classic trio.

Solver bodies are ``lax.while_loop``s under ``jit``, cached per
``(plan, method, maxiter, precond-kind)`` in the same per-plan store as
the transform appliers -- one trace per geometry
(:func:`repro.radon.retrace_guard`-clean), batched over ``(B, H, W)``
stacks, mesh-capable through the ordinary plan dispatch.  Results come
back as a :class:`SolveResult` ``(image, residual_norms, iterations,
converged)`` with a NaN-padded relative residual history.

Differentiation: at convergence the solve is the *linear* map
``b -> G^+ A^T D^2 b`` (``G = M^T M`` symmetric), so its JVP is the
solver applied to the tangent sinogram and its transpose is
``ct -> d^2 * A (G^+ ct)`` -- staged through ``linear_call`` exactly
like :mod:`repro.radon.autodiff` stages the raw transforms.  Gradients
are implicit-function-theorem exact at convergence (run tight ``tol``
when comparing against finite differences); masks, weights and
preconditioners are non-differentiable inputs and raise if perturbed.
Integer sinograms promote to :func:`repro.core.dprt.float_dtype_for`
before any plan arithmetic, so the int64-under-x64 accumulator warning
can never fire for a solve.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.custom_derivatives import linear_call

from .autodiff import _CACHE_LOCK, _JITTED, _note_trace
from .fusion import _is_zero_tangent
from .masking import MaskedDPRT
from .operators import (_AOT_CACHE, _AOT_PINS, _export_compiled,
                        _import_compiled, _topology_token, DPRT,
                        ProjectionFilter)

__all__ = ["METHODS", "SolveResult", "solve", "solve_operator",
           "ReconstructionOperator"]

#: registered solve methods; "auto" resolves to sherman (unmasked) / cg
METHODS = ("sherman", "cg", "lsqr", "landweber")


class SolveResult(NamedTuple):
    """The reconstruction and its convergence record.

    ``image``: the (…, H, W) solution.  ``residual_norms``: relative
    residual history, shape ``(maxiter + 1, *batch)`` -- entry 0 is 1.0,
    entry k the norm after k iterations scaled by the initial one,
    ``NaN`` past the final iteration (direct methods record
    ``[1.0, final]``).  ``iterations``: int32 count taken.
    ``converged``: scalar bool, every batch element within ``tol``.
    """
    image: jnp.ndarray
    residual_norms: jnp.ndarray
    iterations: jnp.ndarray
    converged: jnp.ndarray


def _bdot(u: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Per-batch-element inner product over the trailing two axes."""
    return (u * v).sum(axis=(-2, -1))


def _bnorm(v: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(_bdot(v, v))


def _bx(s: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a (*batch,) scalar field over the trailing two axes."""
    return s[..., None, None]


# ---------------------------------------------------------------------------
# the jitted, differentiable solver bodies (cached per plan, like the
# transform appliers -- entries drop with plan-cache evictions)
# ---------------------------------------------------------------------------
def _jitted_solve(plan, method: str, maxiter: int, precond_kind: str):
    key = (plan, ("solve", method, int(maxiter), precond_kind))
    with _CACHE_LOCK:
        cached = _JITTED.get(key)
    if cached is not None:
        return cached

    geom = plan.geometry
    p = geom.prime
    h, w = geom.image_shape[-2:]
    hw = h * w
    maxiter = int(maxiter)

    def atd(r):
        """A^T r = P * B r + S(r) * 1 (adjoint via the inverse epilogue
        identity; see :mod:`repro.radon.masking`)."""
        s = r[..., 0, :].sum(axis=-1)
        return p * plan.inverse(r) + _bx(s)

    def normal(x, d2, srow):
        """M^T M x: one fused pipeline launch + a column-sum term."""
        y = plan.pipeline(x, "mul", d2)
        s = (srow * x.sum(axis=-2)).sum(axis=-1)
        return p * y + _bx(s)

    def sherman_inv(y):
        """(A^T A)^{-1} y = y/P - sum(y) / (P (P + HW)), exact."""
        s = y.sum(axis=(-2, -1), keepdims=True)
        return y / p - s / (p * (p + hw))

    def make_precond(g_w):
        if precond_kind == "sherman":
            return sherman_inv
        if precond_kind == "filter":
            return lambda r: plan.pipeline(r, "mul", g_w)
        return lambda r: r

    # -- normal-equation loops (image-space rhs) ---------------------------
    def cg_loop(rhs, d2, srow, g_w, tol):
        # Masked normal matrices are SINGULAR (removed directions leave a
        # null space), so past the dtype noise floor CG's rounding noise
        # grows unboundedly along null directions.  Two defenses: return
        # the best-residual iterate ever seen (xb), and freeze a batch
        # element outright once its residual rebounds far above that
        # best (stall) or curvature is lost (pq <= 0).
        ref = _bnorm(rhs)
        safe = jnp.where(ref > 0, ref, 1).astype(rhs.dtype)
        precond = make_precond(g_w)
        hist = jnp.full((maxiter + 1,) + rhs.shape[:-2], jnp.nan,
                        rhs.dtype)
        rn0 = jnp.where(ref > 0, 1.0, 0.0).astype(rhs.dtype)
        hist = hist.at[0].set(rn0)
        x = jnp.zeros_like(rhs)
        r = rhs
        z = precond(r)
        rz = _bdot(r, z)
        conv = ref <= 0
        stall = jnp.zeros_like(conv)

        def cond(st):
            k = st[0]
            cv, sl = st[-2], st[-1]
            return (k < maxiter) & ~(cv | sl).all()

        def step(st):
            k, x, xb, r, pdir, rz, best, hist, conv, stall = st
            q = normal(pdir, d2, srow)
            pq = _bdot(pdir, q)
            # frozen batch elements take alpha = beta = 0 rather than 0/0
            ok = ~(conv | stall) & (pq > 0)
            alpha = jnp.where(ok, rz / jnp.where(pq == 0, 1, pq), 0)
            x = x + _bx(alpha) * pdir
            r = r - _bx(alpha) * q
            z = precond(r)
            rz_new = _bdot(r, z)
            beta = jnp.where(ok & (rz > 0),
                             rz_new / jnp.where(rz == 0, 1, rz), 0)
            pdir = jnp.where(_bx(ok), z + _bx(beta) * pdir, pdir)
            rn = _bnorm(r) / safe
            improved = ok & (rn < best)
            xb = jnp.where(_bx(improved), x, xb)
            best = jnp.where(improved, rn, best)
            conv = conv | (ok & (rn <= tol))
            stall = stall | (~conv & ((pq <= 0) | (rn > 100 * best)))
            hist = hist.at[k + 1].set(rn.astype(hist.dtype))
            return (k + 1, x, xb, r, pdir, rz_new, best, hist, conv,
                    stall)

        st = jax.lax.while_loop(
            cond, step, (0, x, x, r, z, rz, rn0, hist, conv, stall))
        k, xb, hist, conv = st[0], st[2], st[-3], st[-2]
        return xb, hist, k, conv.all()

    def landweber_loop(rhs, d2, srow, tol, tau):
        ref = _bnorm(rhs)
        safe = jnp.where(ref > 0, ref, 1).astype(rhs.dtype)
        # default step from the exact bound ||M||^2 <= max(d^2)(P + HW)
        dmax2 = jnp.maximum(d2.max(), jnp.asarray(1e-30, rhs.dtype))
        tau = jnp.where(jnp.isnan(tau),
                        1.0 / (dmax2 * (p + hw)), tau).astype(rhs.dtype)
        hist = jnp.full((maxiter + 1,) + rhs.shape[:-2], jnp.nan,
                        rhs.dtype)
        hist = hist.at[0].set(jnp.where(ref > 0, 1.0, 0.0))
        x = jnp.zeros_like(rhs)
        conv = ref <= 0

        def cond(st):
            k, _x, _hist, cv = st
            return (k < maxiter) & ~cv.all()

        def step(st):
            k, x, hist, conv = st
            r = rhs - normal(x, d2, srow)
            x = x + jnp.where(_bx(conv), 0, tau * r)
            rn = _bnorm(r) / safe
            conv = conv | (rn <= tol)
            hist = hist.at[k + 1].set(rn.astype(hist.dtype))
            return (k + 1, x, hist, conv)

        k, x, hist, conv = jax.lax.while_loop(
            cond, step, (0, x, hist, conv))
        return x, hist, k, conv.all()

    # -- LSQR: Golub-Kahan bidiagonalization on M = D A itself -------------
    def lsqr_loop(bw, d, tol):
        def m_apply(v):
            return d * plan.forward(v)

        def mt_apply(u):
            return atd(d * u)

        beta = _bnorm(bw)
        u = jnp.where(_bx(beta > 0), bw / _bx(jnp.where(beta > 0, beta, 1)),
                      0)
        v0 = mt_apply(u)
        alpha = _bnorm(v0)
        v = jnp.where(_bx(alpha > 0),
                      v0 / _bx(jnp.where(alpha > 0, alpha, 1)), 0)
        ref = alpha * beta            # == ||M^T b_w|| by construction
        safe = jnp.where(ref > 0, ref, 1).astype(bw.dtype)
        hist = jnp.full((maxiter + 1,) + beta.shape, jnp.nan, bw.dtype)
        hist = hist.at[0].set(jnp.where(ref > 0, 1.0, 0.0))
        x = jnp.zeros_like(v)
        conv = ref <= 0
        st0 = (0, x, u, v, v, beta, alpha, alpha, hist, conv)
        # carry: k, x, u, v, w_dir, phibar, rhobar, alpha, hist, conv

        def cond(st):
            k, *_rest, cv = st
            return (k < maxiter) & ~cv.all()

        def step(st):
            k, x, u, v, w_dir, phibar, rhobar, alpha, hist, conv = st
            un = m_apply(v) - _bx(alpha) * u
            beta = _bnorm(un)
            u = jnp.where(_bx(beta > 0),
                          un / _bx(jnp.where(beta > 0, beta, 1)), 0)
            vn = mt_apply(u) - _bx(beta) * v
            alpha = _bnorm(vn)
            v = jnp.where(_bx(alpha > 0),
                          vn / _bx(jnp.where(alpha > 0, alpha, 1)), 0)
            rho = jnp.sqrt(rhobar * rhobar + beta * beta)
            rho_s = jnp.where(rho > 0, rho, 1)
            c = rhobar / rho_s
            s = beta / rho_s
            theta = s * alpha
            rhobar = -c * alpha
            phi = c * phibar
            phibar = s * phibar
            gain = jnp.where(conv, 0, phi / rho_s)
            x = x + _bx(gain) * w_dir
            w_dir = jnp.where(_bx(conv), w_dir,
                              v - _bx(theta / rho_s) * w_dir)
            # Paige-Saunders estimate ||M^T r_k|| = phibar_k alpha_k |c_k|
            rn = phibar * alpha * jnp.abs(c) / safe
            conv = conv | (rn <= tol)
            hist = hist.at[k + 1].set(rn.astype(hist.dtype))
            return (k + 1, x, u, v, w_dir, phibar, rhobar, alpha, hist,
                    conv)

        st = jax.lax.while_loop(cond, step, st0)
        k, x = st[0], st[1]
        hist, conv = st[-2], st[-1]
        return x, hist, k, conv.all()

    # -- assembled method bodies -------------------------------------------
    def d2_parts(d):
        d2 = d * d
        return d2, d2[..., 0, :w]

    if method == "sherman":
        def body(b, d, g_w, tol, tau):
            rhs = atd(b)
            x = sherman_inv(rhs)
            # closed-form normal residual: A^T A x = P x + total(x) 1
            gx = p * x + x.sum(axis=(-2, -1), keepdims=True)
            ref = _bnorm(rhs)
            rel = _bnorm(rhs - gx) / jnp.where(ref > 0, ref, 1)
            hist = jnp.stack([jnp.ones_like(rel), rel.astype(rhs.dtype)])
            return SolveResult(x, hist, jnp.asarray(0, jnp.int32),
                               jnp.asarray(True))

        def image_of(v, d, g_w, tol, tau):
            return sherman_inv(atd(v))

        def transpose(ct, d, g_w, tol, tau):
            # L = C A^T with C = (A^T A)^{-1} symmetric => L^T = A C
            return plan.forward(sherman_inv(ct))
    else:
        def normal_solve(rhs, d, g_w, tol, tau):
            d2, srow = d2_parts(d)
            if method == "landweber":
                return landweber_loop(rhs, d2, srow, tol, tau)
            return cg_loop(rhs, d2, srow, g_w, tol)

        def body(b, d, g_w, tol, tau):
            if method == "lsqr":
                x, hist, k, conv = lsqr_loop(d * b, d, tol)
            else:
                d2, _srow = d2_parts(d)
                x, hist, k, conv = normal_solve(atd(d2 * b), d, g_w, tol,
                                                tau)
            return SolveResult(x, hist, k.astype(jnp.int32), conv)

        def image_of(v, d, g_w, tol, tau):
            # the converged linear map b -> G^+ A^T D^2 b, applied to a
            # tangent sinogram (LSQR's tangent routes through the same
            # normal-equation solve: the fixed points agree)
            d2, _srow = d2_parts(d)
            return normal_solve(atd(d2 * v), d, g_w, tol, tau)[0]

        def transpose(ct, d, g_w, tol, tau):
            # L^T = D^2 A G^+ (G symmetric): solve with ct as the rhs,
            # then push forward through the masked operator
            d2, _srow = d2_parts(d)
            x = normal_solve(ct, d, g_w, tol, tau)[0]
            return d2 * plan.forward(x)

    @jax.custom_jvp
    def run(b, d, g_w, tol, tau):
        _note_trace(plan, f"solve:{method}", b)
        return body(b, d, g_w, tol, tau)

    # symbolic_zeros: unperturbed diagonals/knobs must arrive as
    # SymbolicZero, not instantiated zero arrays -- grad w.r.t. the
    # sinogram alone is the supported (and common) case
    @partial(run.defjvp, symbolic_zeros=True)
    def _run_jvp(primals, tangents):
        b, d, g_w, tol, tau = primals
        db, dd, dg, dtol, dtau = tangents
        out = run(b, d, g_w, tol, tau)
        for name, t in (("mask/weight diagonal", dd),
                        ("preconditioner", dg), ("tol", dtol),
                        ("tau", dtau)):
            if not _is_zero_tangent(t):
                raise ValueError(
                    f"radon.solve is linear in the sinogram only; the "
                    f"{name} is not a differentiable input")
        if _is_zero_tangent(db):
            tan_img = jnp.zeros(out.image.shape, out.image.dtype)
        else:
            res = jax.lax.stop_gradient((d, g_w, tol, tau))
            tan_img = linear_call(
                lambda r, vb: image_of(vb, *r),
                lambda r, ct: transpose(ct, *r),
                res, db)
        tan = SolveResult(
            tan_img,
            jnp.zeros(out.residual_norms.shape, out.residual_norms.dtype),
            np.zeros(out.iterations.shape, jax.dtypes.float0),
            np.zeros(out.converged.shape, jax.dtypes.float0))
        return out, tan

    with _CACHE_LOCK:
        return _JITTED.setdefault(key, jax.jit(run))


# ---------------------------------------------------------------------------
# the public entry point
# ---------------------------------------------------------------------------
def _resolve_precond(precond, fdtype):
    if precond is None:
        return "none", None
    if isinstance(precond, str):
        if precond != "sherman":
            raise ValueError(
                f"unknown precond {precond!r}: 'sherman', a "
                f"ProjectionFilter, or a (…, P+1, P) weight array")
        return "sherman", None
    if isinstance(precond, ProjectionFilter):
        return "filter", precond.weights.astype(fdtype)
    g_w = jnp.asarray(precond, fdtype)
    if g_w.ndim < 2 or g_w.shape[-2] != g_w.shape[-1] + 1:
        raise ValueError(
            f"precond weights must be (…, P+1, P), got {g_w.shape}")
    return "filter", g_w


def solve(op, sinogram, method: str = "auto", *, mask=None, weight=None,
          precond=None, tol: float = 1e-6, maxiter: int = 100,
          tau: Optional[float] = None) -> SolveResult:
    """Reconstruct an image (stack) from (masked/weighted) projections.

    ``op`` is a forward :class:`~repro.radon.RadonOperator` (``mask`` /
    ``weight`` build the :class:`~repro.radon.MaskedDPRT` here) or an
    already-built ``MaskedDPRT``.  ``method``: ``"auto"`` picks the
    non-iterative Sherman-Morrison closed form when nothing is masked
    and CG on the normal equations otherwise; ``"cg"`` accepts
    ``precond`` (``"sherman"`` for the exact unmasked inverse -- SPD --
    or a ``ProjectionFilter``/weight array riding the fused pipeline).
    ``tau`` is the Landweber step (default: the exact spectral bound).

    Returns a :class:`SolveResult`; see the module docstring for the
    convergence, batching, and differentiation contracts.
    """
    if isinstance(op, MaskedDPRT):
        if mask is not None or weight is not None:
            raise ValueError(
                "pass mask/weight either to MaskedDPRT or to solve(), "
                "not both")
        if op._adjoint:
            raise ValueError("solve() expects the forward measurement "
                             "operator, got its adjoint")
        m = op
    else:
        m = MaskedDPRT(op, mask=mask, weight=weight)
    plan = m.plan
    b = jnp.asarray(sinogram)
    if b.shape != plan.geometry.transform_shape:
        raise ValueError(
            f"sinogram shape {b.shape} != operator projections "
            f"{plan.geometry.transform_shape}")
    b = b.astype(m.fdtype)

    unmasked = m.is_identity_diagonal
    if method == "auto":
        method = "sherman" if unmasked else "cg"
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; one of {METHODS}")
    if method == "sherman" and not unmasked:
        raise ValueError(
            "the Sherman-Morrison fast path is exact only for the "
            "unmasked, unweighted operator; use cg/lsqr/landweber")
    precond_kind, g_w = _resolve_precond(precond, m.fdtype)
    if precond_kind != "none" and method != "cg":
        raise ValueError(
            f"precond applies to method='cg' only (sherman is direct, "
            f"lsqr/landweber run unpreconditioned); got method={method!r}")
    if int(maxiter) < 1 and method != "sherman":
        raise ValueError(f"maxiter must be >= 1, got {maxiter}")

    fn = _jitted_solve(plan, method,
                       0 if method == "sherman" else int(maxiter),
                       precond_kind)
    g_in = g_w if g_w is not None else jnp.zeros((), m.fdtype)
    tol_in = jnp.asarray(float(tol), m.fdtype)
    tau_in = jnp.asarray(np.nan if tau is None else float(tau), m.fdtype)
    return fn(b, m.d, g_in, tol_in, tau_in)


# ---------------------------------------------------------------------------
# the servable operator surface (AOT like Conv2D: the service tier and
# the persistent executable cache consume this unchanged)
# ---------------------------------------------------------------------------
class ReconstructionOperator:
    """``sinogram -> reconstructed image`` as a compilable operator.

    Wraps one :class:`~repro.radon.MaskedDPRT` + solver configuration
    into the AOT surface the serving tier expects (``shape_in`` /
    ``dtype_in`` contract, ``lower()``/``compile()``, persistent-cache
    ``cache_token()``/``export_executable()``).  ``__call__`` returns
    the image only -- diagnostics stay on :func:`solve` -- so compiled
    executables chain like any other stage.
    """

    __slots__ = ("masked", "solver", "tol", "maxiter", "tau",
                 "precond_kind", "precond_w")

    def __init__(self, masked: MaskedDPRT, solver: str = "auto", *,
                 tol: float = 1e-6, maxiter: int = 50,
                 tau: Optional[float] = None, precond=None):
        if not isinstance(masked, MaskedDPRT) or masked._adjoint:
            raise ValueError(
                f"ReconstructionOperator wraps a forward MaskedDPRT, "
                f"got {masked!r}")
        if solver == "auto":
            solver = "sherman" if masked.is_identity_diagonal else "cg"
        if solver not in METHODS:
            raise ValueError(f"unknown solver {solver!r}; one of {METHODS}")
        kind, g_w = _resolve_precond(precond, masked.fdtype)
        object.__setattr__(self, "masked", masked)
        object.__setattr__(self, "solver", solver)
        object.__setattr__(self, "tol", float(tol))
        object.__setattr__(self, "maxiter", int(maxiter))
        object.__setattr__(self, "tau",
                           None if tau is None else float(tau))
        object.__setattr__(self, "precond_kind", kind)
        object.__setattr__(self, "precond_w", g_w)

    def __setattr__(self, name, value):
        raise AttributeError("ReconstructionOperator is immutable")

    @property
    def plan(self):
        return self.masked.plan

    @property
    def shape_in(self):
        return self.plan.geometry.transform_shape

    @property
    def shape_out(self):
        return self.plan.geometry.image_shape

    @property
    def dtype_in(self):
        return self.masked.fdtype

    dtype_out = dtype_in

    def __call__(self, sinogram: jnp.ndarray) -> jnp.ndarray:
        precond = (self.precond_w if self.precond_kind == "filter"
                   else ("sherman" if self.precond_kind == "sherman"
                         else None))
        return solve(self.masked, sinogram, self.solver, precond=precond,
                     tol=self.tol, maxiter=self.maxiter,
                     tau=self.tau).image

    def __matmul__(self, other):
        from .operators import _compose
        return _compose(self, other)

    # -- AOT / persistent executable export --------------------------------
    def _aot_key(self):
        return ("recon", self.plan, self.solver, self.maxiter, self.tol,
                self.tau, self.precond_kind, id(self.masked.d))

    def _aot_pins(self):
        pins = (self.masked.d,)
        if self.precond_w is not None:
            pins += (self.precond_w,)
        return pins

    def lower(self):
        spec = jax.ShapeDtypeStruct(self.shape_in, self.dtype_in)
        return jax.jit(self.__call__).lower(spec)

    def compile(self):
        key = self._aot_key()
        with _CACHE_LOCK:
            exe = _AOT_CACHE.get(key)
        if exe is None:
            built = self.lower().compile()
            with _CACHE_LOCK:
                exe = _AOT_CACHE.setdefault(key, built)
                _AOT_PINS.setdefault(key, self._aot_pins())
        return exe

    def cache_token(self) -> str:
        import hashlib
        pl = self.plan
        shape = "x".join(str(s) for s in self.shape_in)
        blob = np.asarray(self.masked.d).tobytes()
        if self.precond_w is not None:
            blob += np.asarray(self.precond_w).tobytes()
        digest = hashlib.sha1(blob).hexdigest()[:16]
        knobs = "h{}_m{}_sr{}_br{}_bb{}".format(
            pl.strip_rows, pl.m_block, pl.stream_rows, pl.block_rows,
            pl.block_batch)
        return (f"recon_{shape}_{self.dtype_in.name}_{pl.method}_"
                f"{self.solver}_t{self.tol:g}_i{self.maxiter}_"
                f"p{self.precond_kind}_d{digest}_{knobs}_"
                f"{_topology_token(pl.mesh)}")

    def export_executable(self) -> bytes:
        return _export_compiled(self.compile())

    def import_executable(self, data: bytes):
        exe = _import_compiled(data)
        key = self._aot_key()
        with _CACHE_LOCK:
            _AOT_CACHE[key] = exe
            _AOT_PINS.setdefault(key, self._aot_pins())
        return exe

    def describe(self) -> dict:
        d = dict(self.plan.describe())
        d.update(kind="recon", solver=self.solver, tol=self.tol,
                 maxiter=self.maxiter, precond=self.precond_kind,
                 shape_in=self.shape_in, shape_out=self.shape_out)
        return d

    def __repr__(self) -> str:
        return (f"ReconstructionOperator({self.shape_in}->"
                f"{self.shape_out}, solver={self.solver!r}, "
                f"tol={self.tol:g}, maxiter={self.maxiter}, "
                f"method={self.plan.method!r})")


def solve_operator(shape, dtype=jnp.float32, *, mask=None, weight=None,
                   solver: str = "auto", tol: float = 1e-6,
                   maxiter: int = 50, tau: Optional[float] = None,
                   precond=None, method: Optional[str] = None,
                   **knobs) -> ReconstructionOperator:
    """Build a servable reconstruction operator for one image geometry.

    ``shape`` is the image geometry ``(H, W)`` or ``(B, H, W)``;
    ``method`` / ``**knobs`` are the usual transform-plan knobs
    (backend, blocking, mesh), ``solver``/``tol``/``maxiter``/``tau``/
    ``precond`` the solver configuration, ``mask``/``weight`` the
    projection-domain diagonal.  The sinogram contract is
    ``(…, P+1, P)`` in :func:`repro.core.dprt.float_dtype_for` of
    ``dtype``.
    """
    fwd = DPRT(shape, dtype, method, **knobs)
    masked = MaskedDPRT(fwd, mask=mask, weight=weight)
    return ReconstructionOperator(masked, solver, tol=tol,
                                  maxiter=maxiter, tau=tau,
                                  precond=precond)
