"""``repro.radon`` -- the stable public operator API for the DPRT.

The paper's architecture is a geometry-fixed datapath: one adder-tree /
shift-register fabric serves every image of a given N.  This package is
the software analogue as a *public surface*: explicit operator objects
over cached, pytree-registered plans, with exact autodiff and
AOT-compiled serving.

Quickstart
----------
    from repro import radon

    op = radon.DPRT((512, 512), jnp.int32)       # any geometry; auto backend
    r = op(img)                                  # (…, P+1, P) projections
    f = op.inverse(r)                            # bit-exact reconstruction
    g = jax.grad(lambda x: loss(op(x)))(imgf)    # exact adjoint VJP
    exe = op.compile()                           # AOT executable, cached

    with radon.config(method="pallas", m_block=16):
        ...                                      # ambient knob defaults

Surface
-------
* :func:`DPRT` / :class:`RadonOperator` / :class:`CompositeOperator` --
  operator objects: ``op(f)``, ``op.inverse``, ``op.T`` (exact adjoint,
  distinct from the inverse), ``@`` composition, ``lower()``/
  ``compile()`` AOT.
* :class:`Conv2D` / :class:`ProjectionFilter` -- the projection-domain
  fusion surface: exact circular convolution and ``inv @ pointwise @
  fwd`` compositions run as ONE fused kernel launch on pipeline-capable
  backends (staged registry fallback elsewhere), with exact bilinear
  autodiff (:mod:`repro.radon.fusion`).
* :class:`config` -- ambient knob scopes (method/strip_rows/m_block/…).
* :func:`retrace_guard` / :func:`trace_count` -- the zero-retrace
  serving property as an assertion.
* :func:`solve` / :class:`MaskedDPRT` / :func:`solve_operator` -- the
  reconstruction subsystem (:mod:`repro.radon.solve`): masked/weighted
  least squares over DPRT operators via the non-iterative
  Sherman-Morrison closed form (unmasked) or CG/LSQR/Landweber with
  projection-domain preconditioning, each normal-equation application
  ONE fused pipeline launch.
* plan layer re-exports (``get_plan``, ``plan_cache_info`` with its
  eviction counter, registry introspection) for advanced callers.
* ``python -m repro.radon.selfcheck`` -- API/perf health smoke.

The PR-2-era per-call kwarg surface on :mod:`repro.core.dprt` remains
as thin deprecation shims over this package.
"""
from repro.core.plan import (Backend, RadonPlan, available_backends,
                             backend_capabilities, get_backend, get_plan,
                             plan_cache_clear, plan_cache_discard,
                             plan_cache_entries, plan_cache_info,
                             register_backend, select_backend,
                             set_plan_cache_maxsize)

from .ambient import CONFIG_KEYS, config, current_config
from .autodiff import (RetraceError, reset_trace_counts, retrace_guard,
                       trace_count, trace_counts)
from .fusion import flip_image, flip_lanes, pipeline_apply
from .masking import MaskedDPRT, direction_mask
from .operators import (DPRT, CompositeOperator, Conv2D,
                        FusedProjectionPipeline, PersistentAOTCache,
                        ProjectionFilter, RadonOperator, aot_cache_clear,
                        aot_cache_info, aot_fingerprint, operator_for)
from .solve import (METHODS, ReconstructionOperator, SolveResult, solve,
                    solve_operator)

__all__ = [
    # operators
    "DPRT", "Conv2D", "ProjectionFilter", "FusedProjectionPipeline",
    "RadonOperator", "CompositeOperator", "operator_for",
    "aot_cache_info", "aot_cache_clear",
    # persistent AOT executable cache (warm process restarts)
    "PersistentAOTCache", "aot_fingerprint",
    # projection-domain fusion
    "pipeline_apply", "flip_image", "flip_lanes",
    # reconstruction subsystem
    "solve", "SolveResult", "METHODS", "MaskedDPRT", "direction_mask",
    "ReconstructionOperator", "solve_operator",
    # ambient config
    "config", "current_config", "CONFIG_KEYS",
    # trace accounting
    "retrace_guard", "trace_count", "trace_counts", "reset_trace_counts",
    "RetraceError",
    # plan layer
    "Backend", "RadonPlan", "available_backends", "backend_capabilities",
    "get_backend", "get_plan", "plan_cache_clear", "plan_cache_discard",
    "plan_cache_entries", "plan_cache_info",
    "register_backend", "select_backend", "set_plan_cache_maxsize",
]
