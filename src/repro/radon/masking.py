"""Masked / weighted DPRT operators: the partial-data measurement model.

Rim 2019 (arXiv 1908.00887) shows a discrete Radon transform remains
invertible from *partial* projection data; the serving reality behind it
is sinograms with dead detector rows, missing directions, or per-sample
confidence weights.  This module models that as an operator:

    M = D A,   D = diag(mask * weight)  on the (P+1, P) projection grid

with ``A`` the exact forward DPRT of a cached plan.  ``MaskedDPRT`` is
the measurement operator the :mod:`repro.radon.solve` subsystem inverts:

* ``m(f)``   -- masked projections ``d * A f`` (float arithmetic; the
  mask zeroes what the detector never saw);
* ``m.T``    -- the exact adjoint ``A^T D`` (``m.T.T is m`` round trip),
  consistent with ``m.as_matrix().T`` entry-for-entry;
* ``m.normal_apply(x)`` -- ONE fused projection-pipeline launch for the
  normal-equation matrix ``M^T M`` (see below) -- the inner loop of
  every iterative solver;
* ``m.normal_rhs(b)``   -- ``M^T (d * b) = A^T (d^2 * b)``.

The launch-count trick: the exact-adjoint algebra of
:mod:`repro.core.plan` gives, entrywise,

    A^T r = P * B r + S(r) * 1,     S(r) = sum_d r(0, d),

where ``B`` is the exact inverse (adjoint epilogue = P * inverse
epilogue + S; both share one skew-sum).  Substituting ``r = d^2 * A x``
turns the normal-equation application into

    M^T M x = P * [inv . (d^2 *) . fwd](x) + S(d^2 * A x) * 1

whose bracket is exactly the PR-5 fused ``pipeline("mul")`` -- one
kernel launch on pipeline-capable backends -- and whose scalar ``S``
needs only the column sums of ``x`` (row 0 of ``A x`` is the column-sum
projection).  A ``ProjectionFilter`` preconditioner rides the same
fused pipeline, so preconditioned CG stays at two launches per
iteration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dprt import float_dtype_for
from repro.core.plan import get_plan

__all__ = ["direction_mask", "MaskedDPRT"]


def direction_mask(n: int, missing, dtype=jnp.float32) -> jnp.ndarray:
    """A ``(n+1, n)`` projection-domain mask with whole directions
    removed: row ``m`` of the sinogram is zeroed for every ``m`` in
    ``missing`` (the paper's P+1 directions index ``0..n``; ``n`` is
    the row-sum direction).  The complement stays 1, so the mask is a
    0/1 diagonal in operator form."""
    n = int(n)
    missing = jnp.atleast_1d(jnp.asarray(missing, jnp.int32))
    rows = jnp.arange(n + 1, dtype=jnp.int32)
    keep = ~jnp.isin(rows, missing)
    return (keep[:, None] * jnp.ones((1, n))).astype(dtype)


def _float_plan(plan, fdtype):
    """The float-dtype sibling of ``plan``: same geometry, same resolved
    backend and block knobs, float arithmetic.  Plans are cached, so
    this is a dict lookup after the first build."""
    return get_plan(plan.geometry.image_shape, fdtype, plan.method,
                    strip_rows=plan.strip_rows, m_block=plan.m_block,
                    batch_impl=plan.batch_impl, block_rows=plan.block_rows,
                    stream_rows=plan.stream_rows,
                    block_batch=plan.block_batch, mesh=plan.mesh)


class MaskedDPRT:
    """``M = diag(mask * weight) . A``: the masked/weighted forward DPRT.

    ``op`` is a forward :class:`repro.radon.RadonOperator` (any geometry,
    any dtype -- arithmetic promotes to :func:`float_dtype_for` of the
    image dtype, so integer sinograms solve cleanly in float32/64).
    ``mask`` and ``weight`` broadcast against the ``(…, P+1, P)``
    projection grid and are combined into one diagonal ``d``; either may
    be ``None`` (identity).  A 3-D ``d`` gives per-image masks for a
    batched plan.

    The operator surface matches :class:`RadonOperator` where it
    matters: ``shape_in``/``shape_out``/``dtype_in``, ``__call__``,
    ``.T`` (exact adjoint, an involution), ``as_matrix()`` for small-N
    tests, and ``@`` composition.
    """

    __slots__ = ("plan", "d", "fdtype", "_adjoint")

    def __init__(self, op, mask=None, weight=None, *, _plan=None,
                 _d=None, _adjoint: bool = False):
        if _plan is not None:          # internal: pre-built view
            plan, fdtype, d = _plan, jnp.dtype(_plan.dtype_name), _d
        else:
            plan = getattr(op, "plan", None)
            if plan is None or getattr(op, "kind", "forward") != "forward":
                raise ValueError(
                    "MaskedDPRT wraps a forward RadonOperator, got "
                    f"{op!r}")
            fdtype = float_dtype_for(op.dtype)
            plan = _float_plan(plan, fdtype)
            tshape = plan.geometry.transform_shape
            d = jnp.ones(tshape[-2:], fdtype)
            for part in (mask, weight):
                if part is not None:
                    part = jnp.asarray(part, fdtype)
                    try:
                        d = d * part
                    except (TypeError, ValueError) as e:
                        raise ValueError(
                            f"mask/weight must broadcast to {tshape}, "
                            f"got shape {part.shape}") from e
            if d.shape[-2:] != tshape[-2:] or d.ndim > len(tshape):
                raise ValueError(
                    f"mask/weight must broadcast to {tshape}, got "
                    f"diagonal of shape {d.shape}")
            if d.ndim == len(tshape) == 3 and d.shape[0] != tshape[0]:
                raise ValueError(
                    f"batched mask/weight {d.shape} does not match plan "
                    f"batch {tshape[0]}")
        object.__setattr__(self, "plan", plan)
        object.__setattr__(self, "d", d)
        object.__setattr__(self, "fdtype", jnp.dtype(fdtype))
        object.__setattr__(self, "_adjoint", bool(_adjoint))

    def __setattr__(self, name, value):
        raise AttributeError("MaskedDPRT is immutable")

    # -- shapes / dtypes ---------------------------------------------------
    @property
    def shape_in(self):
        g = self.plan.geometry
        return g.transform_shape if self._adjoint else g.image_shape

    @property
    def shape_out(self):
        g = self.plan.geometry
        return g.image_shape if self._adjoint else g.transform_shape

    @property
    def dtype_in(self):
        return self.fdtype

    dtype_out = dtype_in

    @property
    def is_identity_diagonal(self) -> bool:
        """True when ``d`` is exactly all-ones -- the unmasked case the
        Sherman-Morrison fast path of :mod:`repro.radon.solve` owns."""
        import numpy as np
        return bool(np.all(np.asarray(self.d) == 1))

    # -- application -------------------------------------------------------
    def __call__(self, x: jnp.ndarray) -> jnp.ndarray:
        from .autodiff import apply_plan
        x = jnp.asarray(x).astype(self.fdtype)
        if self._adjoint:
            return apply_plan(self.plan, "adjoint", self.d * x)
        return self.d * apply_plan(self.plan, "forward", x)

    # -- algebra -----------------------------------------------------------
    @property
    def T(self) -> "MaskedDPRT":
        """The exact adjoint ``(D A)^T = A^T D`` (and back: ``m.T.T``
        applies ``D A`` again)."""
        return MaskedDPRT(None, _plan=self.plan, _d=self.d,
                          _adjoint=not self._adjoint)

    def __matmul__(self, other):
        from .operators import _compose
        return _compose(self, other)

    def __rmatmul__(self, other):
        from .operators import _compose
        return _compose(other, self)

    # -- normal equations (the solver inner loop) --------------------------
    def _srow(self) -> jnp.ndarray:
        """Row 0 of ``d^2`` restricted to the W true columns: the only
        part of ``d^2 * A x`` that feeds ``S`` (row 0 of ``A x`` is the
        column-sum projection; embedded columns >= W sum zeros)."""
        w = self.plan.geometry.image_shape[-1]
        d2 = self.d * self.d
        return d2[..., 0, :w]

    def normal_apply(self, x: jnp.ndarray) -> jnp.ndarray:
        """``M^T M x`` in one fused pipeline launch + a column-sum
        reduction: ``P * pipeline(x, "mul", d^2) + S(d^2 * A x) * 1``
        (module docstring).  Raw-plan arithmetic -- solver bodies wrap
        it in their own jit/custom_jvp."""
        p = self.plan.geometry.prime
        d2 = self.d * self.d
        y = self.plan.pipeline(x, "mul", d2)
        s = (self._srow() * x.sum(axis=-2)).sum(axis=-1)
        return p * y + s[..., None, None]

    def normal_rhs(self, b: jnp.ndarray) -> jnp.ndarray:
        """``M^T (d * b) = A^T (d^2 * b)``: the normal-equation right-
        hand side, via ``A^T r = P * B r + S(r) * 1``."""
        r = (self.d * self.d) * b.astype(self.fdtype)
        p = self.plan.geometry.prime
        s = r[..., 0, :].sum(axis=-1)
        return p * self.plan.inverse(r) + s[..., None, None]

    # -- introspection -----------------------------------------------------
    def as_matrix(self) -> jnp.ndarray:
        """Dense (out_size, in_size) matrix (small N; tests only)."""
        size_in = 1
        for s in self.shape_in:
            size_in *= s
        basis = jnp.eye(size_in, dtype=self.fdtype)
        cols = jax.vmap(lambda e: self(e.reshape(self.shape_in)).ravel())(
            basis)
        return cols.T

    def __repr__(self) -> str:
        tag = "adjoint " if self._adjoint else ""
        return (f"MaskedDPRT({tag}{self.shape_in}->{self.shape_out}, "
                f"{self.fdtype.name}, method={self.plan.method!r})")

    def __eq__(self, other):
        return (isinstance(other, MaskedDPRT)
                and self.plan == other.plan
                and self._adjoint == other._adjoint
                and self.d is other.d)

    def __hash__(self):
        return hash((self.plan, self._adjoint, id(self.d)))
