"""API/perf health smoke: ``python -m repro.radon.selfcheck``.

One plan per registered (non-mesh) backend is round-tripped bit-exactly,
its gradient is checked against the explicit adjoint, a retrace guard
verifies the one-trace-per-geometry property, and one operator is
AOT-compiled.  With ``--bench`` (or via ``python -m benchmarks.run
--check``, which calls :func:`run` with the bench already handled), the
perf regression guard runs too, so API health and performance gate
together in CI.

Exit code 0 == healthy.  Keep this cheap: it is the first thing a
deploy pipeline runs.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["run", "main"]

_N = 13  # small prime: fast under CPU interpret, still exercises blocks


def _check(label: str, ok: bool, detail: str = "") -> bool:
    print(f"[selfcheck] {'ok  ' if ok else 'FAIL'} {label}"
          + (f" ({detail})" if detail else ""))
    return ok


def run(run_bench: bool = False) -> int:
    """Run the API health checks; returns a process exit code."""
    from repro.core.plan import available_backends, get_backend
    from . import DPRT, config, retrace_guard

    rng = np.random.default_rng(0)
    img_i = jnp.asarray(rng.integers(0, 256, (_N, _N)), jnp.int32)
    img_f = img_i.astype(jnp.float32)
    ok = True

    # mesh-aware backends need a multi-device mesh: round-trip one
    # sharded_pallas plan whenever this process can see one (forced-host
    # CPU runs included); single-device hosts skip with a note (the
    # distributed tests cover it under forced host devices).
    if len(jax.devices()) > 1:
        mesh = jax.make_mesh((len(jax.devices()),), ("model",))
        ops = DPRT(img_i.shape, img_i.dtype, method="auto", mesh=mesh)
        ok &= _check("sharded_pallas: auto resolves under a mesh",
                     ops.plan.method == "sharded_pallas",
                     f"plan method={ops.plan.method}")
        ok &= _check("sharded_pallas: round trip bit-exact",
                     bool((ops.inverse(ops(img_i)) == img_i).all()),
                     f"devices={len(jax.devices())}")
        gradm = jax.grad(lambda x: DPRT(img_f.shape, img_f.dtype,
                                        method="auto", mesh=mesh)(x).sum())(
                                            img_f)
        wantm = DPRT(img_f.shape, img_f.dtype, method="auto", mesh=mesh).T(
            jnp.ones((_N + 1, _N), jnp.float32))
        ok &= _check("sharded_pallas: grad == explicit adjoint",
                     bool((gradm == wantm).all()))
    else:
        print("[selfcheck] skip sharded_pallas round trip (1 device; "
              "covered by the forced-host distributed tests)")

    for name in available_backends():
        be = get_backend(name)
        if be.mesh_aware:
            continue  # needs a multi-device mesh; handled above
        op = DPRT(img_i.shape, img_i.dtype, method=name)
        back = op.inverse(op(img_i))
        ok &= _check(f"{name}: round trip bit-exact",
                     bool((back == img_i).all()),
                     f"plan method={op.plan.method}")
        if be.supports_dtype(jnp.float32):
            opf = DPRT(img_f.shape, img_f.dtype, method=name)
            grad = jax.grad(lambda x, o=opf: o(x).sum())(img_f)
            want = opf.T(jnp.ones(opf.shape_out, jnp.float32))
            ok &= _check(f"{name}: grad == explicit adjoint",
                         bool((grad == want).all()))

    # fused projection pipeline: one conv plan per capable backend --
    # fused must equal staged bit-exactly, and the delta kernel's
    # convolution pipeline must be the identity (a full fused
    # transform -> 1-D conv -> inverse round trip)
    from repro.core.conv import circ_conv2d_dprt
    kern = jnp.asarray(rng.integers(0, 16, (_N, _N)), jnp.int32)
    delta = jnp.zeros((_N, _N), jnp.int32).at[0, 0].set(1)
    for name in available_backends():
        be = get_backend(name)
        if be.pipeline is None or be.mesh_aware:
            continue
        fused = circ_conv2d_dprt(img_i, kern, method=name)
        staged = circ_conv2d_dprt(img_i, kern, method=name, fuse=False)
        ok &= _check(f"{name}: fused conv pipeline == staged (bit-exact)",
                     bool((fused == staged).all()))
        ok &= _check(f"{name}: delta-kernel conv pipeline is identity",
                     bool((circ_conv2d_dprt(img_i, delta, method=name)
                           == img_i).all()))
    if len(jax.devices()) > 1:
        mesh = jax.make_mesh((len(jax.devices()),), ("model",))
        with config(mesh=mesh):
            fused = circ_conv2d_dprt(img_i, kern, method="sharded_pallas")
            staged = circ_conv2d_dprt(img_i, kern, method="sharded_pallas",
                                      fuse=False)
        ok &= _check("sharded_pallas: fused conv pipeline == staged",
                     bool((fused == staged).all()))

    # reconstruction gate: the Sherman-Morrison fast path must match the
    # exact inverse (non-iteratively), and masked-direction CG must
    # recover the dense-oracle least-squares solution
    from . import MaskedDPRT, direction_mask, solve
    op = DPRT(img_i.shape, img_i.dtype)
    res = solve(op, op(img_i))
    ok &= _check("solve: unmasked Sherman-Morrison == exact inverse",
                 int(res.iterations) == 0
                 and np.allclose(np.asarray(res.image), np.asarray(img_i),
                                 atol=1e-3),
                 f"iterations={int(res.iterations)}")
    m = MaskedDPRT(op, mask=direction_mask(_N, [2, _N - 1]))
    b = m(img_f)
    dense = np.asarray(m.as_matrix())
    oracle, *_ = np.linalg.lstsq(dense, np.asarray(b).ravel(), rcond=None)
    rec = solve(m, b, "cg", tol=1e-7, maxiter=200)
    scale = max(1.0, float(np.abs(oracle).max()))
    err = float(np.abs(np.asarray(rec.image).ravel() - oracle).max())
    ok &= _check("solve: masked-direction CG == dense LS oracle",
                 err <= 1e-3 * scale,
                 f"max err={err:.2e}, iters={int(rec.iterations)}")

    # one trace per geometry, enforced
    op = DPRT(img_i.shape, img_i.dtype)
    op(img_i)  # first trace happens outside the guard
    try:
        with retrace_guard(max_traces=0):
            for _ in range(3):
                op(img_i + 1)
        ok &= _check("steady state: zero retraces across repeated calls",
                     True)
    except Exception as e:  # RetraceError or anything tracing raised
        ok &= _check("steady state: zero retraces across repeated calls",
                     False, repr(e))

    # AOT executable serves without tracing
    exe = op.compile()
    ok &= _check("AOT compile serves the same bits",
                 bool((exe(img_i) == op(img_i)).all()))

    # ambient config reaches plan resolution
    with config(method="gather"):
        ok &= _check("ambient config resolves method",
                     DPRT((7, 7), jnp.int32).plan.method == "gather")

    if run_bench:
        try:
            from benchmarks import check_regression
        except ImportError:
            print("[selfcheck] skip perf guard (benchmarks package not "
                  "on path; run from the repo root)")
        else:
            code = 0
            try:
                check_regression.main([])
            except SystemExit as e:
                code = int(e.code or 0)
            ok &= _check("perf regression guard", code == 0,
                         f"exit={code}")

    print(f"[selfcheck] {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench", action="store_true",
                    help="also run benchmarks.check_regression (fresh "
                         "DPRT shoot-out vs the committed baseline)")
    args = ap.parse_args(argv)
    return run(run_bench=args.bench)


if __name__ == "__main__":
    sys.exit(main())
