"""Ambient transform defaults: ``repro.radon.config(...)``.

The operator API keeps per-call knob plumbing out of user code: instead
of threading ``method=`` / ``strip_rows=`` / ``m_block=`` through every
call site (the PR-2-era "kwarg soup"), a scope sets them once

    with radon.config(method="pallas", m_block=16):
        op = radon.DPRT(img.shape, img.dtype)   # picks the ambient knobs
        r = op(img)

and every plan/operator built inside the scope -- including the legacy
:func:`repro.core.dprt.dprt` wrappers and the direct Pallas op wrappers
in :mod:`repro.kernels.ops` -- resolves unset knobs against it.  Scopes
nest (innermost wins per key) and are thread-local.  Explicit keyword
arguments always beat ambient defaults.

Resolution happens *eagerly*, before any plan-cache or trace-cache
lookup, so the ambient scope participates in every cache key: a plan
built inside a scope is never replayed outside one with different
knobs.

This module is deliberately dependency-free (stdlib only) so any layer
of the repo -- kernels, core, launch -- can consult it without import
cycles.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional

__all__ = ["config", "current_config", "resolve", "snapshot_knobs",
           "knobs_kwargs", "CONFIG_KEYS"]

#: knobs an ambient scope may set -- the same surface get_plan accepts.
#: This is also the field order of :func:`snapshot_knobs` tuples.
CONFIG_KEYS = ("method", "strip_rows", "m_block", "batch_impl",
               "block_rows", "stream_rows", "block_batch", "mesh")

_tls = threading.local()


def _stack() -> list:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    return stack


class config:
    """Context manager installing ambient transform defaults.

    Accepted keys: ``method``, ``strip_rows``, ``m_block``,
    ``batch_impl``, ``block_rows``, ``stream_rows``, ``block_batch``,
    ``mesh``.  A value
    of ``None`` is ignored (it cannot mask an outer scope's setting).
    Re-entrant use of one ``config`` object is rejected.
    """

    def __init__(self, **knobs: Any):
        unknown = sorted(set(knobs) - set(CONFIG_KEYS))
        if unknown:
            raise TypeError(
                f"radon.config got unknown knob(s) {unknown}; "
                f"valid keys: {list(CONFIG_KEYS)}")
        self._knobs = {k: v for k, v in knobs.items() if v is not None}
        self._active = False

    def __enter__(self) -> "config":
        if self._active:
            raise RuntimeError("this radon.config scope is already active")
        self._active = True
        _stack().append(self._knobs)
        return self

    def __exit__(self, *exc) -> None:
        self._active = False
        popped = _stack().pop()
        assert popped is self._knobs, "radon.config scopes exited out of order"


def current_config() -> Dict[str, Any]:
    """The merged ambient knobs for this thread (innermost scope wins)."""
    merged: Dict[str, Any] = {}
    for frame in _stack():
        merged.update(frame)
    return merged


def resolve(name: str, explicit: Optional[Any], fallback: Any = None) -> Any:
    """Explicit argument > ambient scope > ``fallback``."""
    if explicit is not None:
        return explicit
    value = current_config().get(name)
    return fallback if value is None else value


def snapshot_knobs(method: Optional[str] = None,
                   strip_rows: Optional[int] = None,
                   m_block: Optional[int] = None,
                   batch_impl: Optional[str] = None, *,
                   fallback_method: str = "horner") -> tuple:
    """One hashable tuple of ALL transform knobs, ``CONFIG_KEYS``-ordered.

    Explicit arguments beat the ambient scope; knobs with no explicit
    parameter at the call site come from the scope alone.  Callers that
    jit around plan construction (``core/conv``, ``core/dft``) pass this
    tuple as a static argument so the FULL ambient scope participates in
    their trace-cache keys -- a trace taken inside a
    ``config(block_batch=…)``/``config(mesh=…)`` scope is never replayed
    outside it with stale knobs, and vice versa.
    """
    cfg = current_config()
    return (resolve("method", method, fallback_method),
            resolve("strip_rows", strip_rows),
            resolve("m_block", m_block),
            resolve("batch_impl", batch_impl),
            cfg.get("block_rows"), cfg.get("stream_rows"),
            cfg.get("block_batch"), cfg.get("mesh"))


def knobs_kwargs(knobs: tuple) -> Dict[str, Any]:
    """A :func:`snapshot_knobs` tuple as keyword arguments for
    ``radon.DPRT`` / ``get_plan``."""
    return dict(zip(CONFIG_KEYS, knobs))
