"""Process-level cache/trace health report: ``python -m repro.radon.healthz``.

The ``/healthz``-style counterpart to :mod:`repro.radon.selfcheck`:
where selfcheck *exercises* the API, this module *inspects* a process --
plan-cache hit/miss/eviction counters, the warm plan entries themselves,
per-datapath trace counts (the zero-retrace serving property as data),
the in-memory AOT executable census, and the environment fingerprint
persistent executable blobs are keyed against.  The same counters back
:meth:`repro.launch.service.DPRTService.healthz`, which prepends its
admission-queue and latency sections.

``report()`` returns the formatted text; :func:`snapshot` the raw dict
(for tests and structured scrapes).  Exit code is always 0 -- counters
are a readout, not a judgement; the service healthz is what gates.
"""
from __future__ import annotations

__all__ = ["snapshot", "report", "main"]


def snapshot() -> dict:
    """The raw counter dict behind :func:`report`."""
    import os

    from . import (aot_cache_info, aot_fingerprint, plan_cache_entries,
                   plan_cache_info, trace_count, trace_counts)
    # distinct plans (different knobs/mesh) can share a (shape, dtype,
    # kind) label: aggregate, so the per-path counts still sum to the
    # process total
    traces: dict = {}
    for (plan, kind, shape, dtype), n in trace_counts().items():
        label = f"{shape}/{dtype}/{kind}"
        traces[label] = traces.get(label, 0) + n
    return {
        "fingerprint": aot_fingerprint(),
        "plan_cache": plan_cache_info()._asdict(),
        "plans": plan_cache_entries(),
        "traces_total": trace_count(),
        "traces": dict(sorted(traces.items())),
        "aot_cache": aot_cache_info(),
        # armed chaos spec, if any (REPRO_FAULTS): echoed so "why is
        # this worker misbehaving" is answerable from its healthz alone
        "faults_env": os.environ.get("REPRO_FAULTS") or None,
    }


def report() -> str:
    """Format :func:`snapshot` as the ``[healthz]`` text block."""
    s = snapshot()
    lines = [
        f"[healthz] {s['fingerprint']}",
        "[healthz] plan_cache hits={hits} misses={misses} "
        "currsize={currsize} maxsize={maxsize} evictions={evictions}"
        .format(**s["plan_cache"]),
    ]
    for p in s["plans"]:
        lines.append(f"[healthz]   plan {p.get('image_shape')} "
                     f"method={p.get('method')}")
    lines.append(f"[healthz] traces total={s['traces_total']}")
    for path, count in s["traces"].items():
        lines.append(f"[healthz]   trace {path} x{count}")
    aot = s["aot_cache"]
    lines.append(f"[healthz] aot_executables currsize={aot['currsize']}")
    for key in aot["keys"]:
        lines.append(f"[healthz]   aot {key}")
    if s.get("faults_env"):
        lines.append(f"[healthz] faults_env {s['faults_env']}")
    return "\n".join(lines)


def main(argv=None) -> int:
    print(report())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
