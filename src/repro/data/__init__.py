from .synthetic import TokenStream, radon_images, phantom_image
from .pipeline import Prefetcher, shard_batch
