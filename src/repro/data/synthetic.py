"""Deterministic synthetic data: learnable token streams and prime images.

The token stream has real sequential structure (an affine random walk over
the vocabulary plus noise) so training losses genuinely decrease; images
are random or phantom (disk/line) prime-sized integer rasters for the DPRT
paths.  Everything is seeded and host-shardable: shard ``i`` of ``n``
yields disjoint, reproducible batches.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["TokenStream", "radon_images", "phantom_image"]


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    batch_size: int            # per-shard batch
    seed: int = 0
    shard: int = 0
    num_shards: int = 1
    noise: float = 0.05

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * self.num_shards + self.shard)
        b, s, v = self.batch_size, self.seq_len, self.vocab_size
        # next = (prev*a + c) mod v with *stream-global* a, c: a learnable
        # deterministic next-token function, so CE genuinely decreases.
        g = np.random.default_rng(self.seed)
        a = int(g.integers(1, v)) | 1
        c = int(g.integers(0, v))
        t0 = rng.integers(0, v, size=(b, 1))
        toks = np.empty((b, s + 1), np.int64)
        toks[:, 0:1] = t0
        for i in range(1, s + 1):
            toks[:, i] = (toks[:, i - 1] * a + c) % v
        noise_mask = rng.random((b, s + 1)) < self.noise
        noise_tok = rng.integers(0, v, size=(b, s + 1))
        toks = np.where(noise_mask, noise_tok, toks).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def phantom_image(n: int, seed: int = 0, bits: int = 8) -> np.ndarray:
    """Disk + line phantom on an n x n integer raster (classic Radon test)."""
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:n, 0:n]
    img = np.zeros((n, n), np.int32)
    for _ in range(3):
        cx, cy = rng.integers(n // 4, 3 * n // 4, size=2)
        r = rng.integers(n // 10, n // 4)
        img[(yy - cy) ** 2 + (xx - cx) ** 2 <= r * r] += int(
            rng.integers(1, 2 ** bits // 4))
    k = rng.uniform(-2, 2)
    b = rng.integers(0, n)
    img[np.abs(yy - (k * xx + b)) < 1.5] += 2 ** bits // 4
    return np.clip(img, 0, 2 ** bits - 1).astype(np.int32)


def radon_images(n: int, batch: int, seed: int = 0, bits: int = 8,
                 kind: str = "random") -> np.ndarray:
    if kind == "phantom":
        return np.stack([phantom_image(n, seed + i, bits)
                         for i in range(batch)])
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2 ** bits, size=(batch, n, n)).astype(np.int32)
