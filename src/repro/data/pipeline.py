"""Input pipeline: background prefetch + device placement with sharding."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Prefetcher", "shard_batch"]


def shard_batch(batch, mesh: Optional[Mesh], batch_axes=("pod", "data")):
    """Place a host batch onto the mesh, batch dim sharded over data axes."""
    if mesh is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    axes = tuple(a for a in batch_axes if a in mesh.shape)

    def put(x):
        ndim = np.asarray(x).ndim
        bdim = axes if len(axes) > 1 else (axes[0] if axes else None)
        if ndim == 0 or not axes or x.shape[0] % _size(mesh, axes) != 0:
            s = NamedSharding(mesh, P())
        else:
            s = NamedSharding(mesh, P(bdim, *([None] * (ndim - 1))))
        return jax.device_put(x, s)

    return jax.tree.map(put, batch)


def _size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


class Prefetcher:
    """Runs an iterator in a thread, keeping ``depth`` batches ready."""

    def __init__(self, it: Iterator, depth: int = 2,
                 transform: Optional[Callable] = None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._transform = transform

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                if self._transform is not None:
                    item = self._transform(item)
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
