"""2-D DFT via the discrete Fourier-slice theorem (Gertner / Grigoryan).

With R the DPRT of f and ``Rhat(m, k) = FFT_d R(m, d)``:

    Rhat(m, k) = Fhat(<-m*k>_N, k)      0 <= m < N      (skew slices)
    Rhat(N, k) = Fhat(k, 0)                             (the v=0 column)

where ``Fhat(u, v) = sum_{i,j} f(i,j) e^{-2pi i (u*i + v*j)/N}``.  Because N
is prime, for every v != 0 the map m -> <-m*v>_N is a bijection, so the N+1
length-N 1-D FFTs cover the full 2-D spectrum exactly once (plus the shared
DC term).  This is the paper's "minimal number of 1-D FFTs" route to the
2-D DFT (Sec. I, refs [14][17]) -- all O(N^3) additions happen in exact
integer arithmetic inside the DPRT; only the final N+1 FFTs are float.

The DPRT stage routes through the transform-plan dispatch
(:mod:`repro.core.plan`): ``method`` may be any registered backend
(including ``"auto"``/``"pallas"``), and ``strip_rows``/``m_block``
are forwarded to it.  :func:`dft2_via_dprt_batched` runs a (B, N, N)
stack -- for the pallas backend the whole stack's DPRT is ONE fused
kernel call, followed by batched FFTs and one vectorized scatter.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .dprt import is_prime

__all__ = ["dft2_via_dprt", "dft2_via_dprt_batched", "dft2_reference"]


def _resolve_knobs(method, strip_rows, m_block, batch_impl=None) -> tuple:
    """Full ambient-knob snapshot (see ``ambient.snapshot_knobs``),
    taken OUTSIDE the jit boundaries below so the whole scope is part
    of each trace-cache key.  Fallback ``"auto"``: the registry's best
    backend -- the fused Pallas kernel for int/float images, so the DFT's
    whole exact-integer stage is ONE kernel launch with in-kernel
    epilogues (the projection-pipeline dispatch rule; backends without
    the fused kernels keep their staged datapaths, bit-identically)."""
    from repro.radon import ambient  # lazy: radon imports repro.core
    return ambient.snapshot_knobs(method, strip_rows, m_block, batch_impl,
                                  fallback_method="auto")


def _dprt_stage(f, knobs: tuple):
    """The integer DPRT stage through the radon operator surface."""
    from repro.radon import operator_for  # lazy: radon imports repro.core
    return operator_for(f.shape, f.dtype, knobs)(f)


def _slice_scatter(rhat: jnp.ndarray, n: int) -> jnp.ndarray:
    """Assemble the (…, N, N) spectrum from (…, N+1, N) projection FFTs."""
    k = jnp.arange(n)
    m = jnp.arange(n)[:, None]
    u = (-m * k[None, :]) % n                  # Fhat(u[m,k], k) = Rhat[m,k]

    out = jnp.zeros((*rhat.shape[:-2], n, n), rhat.dtype)
    # scatter the skew slices; k=0 column is written N times with the same
    # DC value (harmless), then overwritten exactly by the m=N projection.
    out = out.at[..., u, jnp.broadcast_to(k[None, :], (n, n))].set(
        rhat[..., :n, :])
    out = out.at[..., :, 0].set(rhat[..., n, :])  # Fhat(u, 0) = FFT(R[N])[u]
    return out


def _proj_fft(r: jnp.ndarray) -> jnp.ndarray:
    return jnp.fft.fft(r.astype(jnp.float64 if r.dtype == jnp.int64
                                else jnp.float32), axis=-1)


@functools.partial(jax.jit, static_argnames=("knobs",))
def _dft2_jit(f, knobs):
    # f is (N, N) or a (B, N, N) stack; N is always the trailing axis
    r = _dprt_stage(f, knobs)                  # (…, N+1, N) exact ints
    return _slice_scatter(_proj_fft(r), f.shape[-1])


def dft2_via_dprt(f: jnp.ndarray, method: Optional[str] = None,
                  strip_rows: Optional[int] = None,
                  m_block: Optional[int] = None) -> jnp.ndarray:
    """(N, N) real/int image -> (N, N) complex 2-D DFT, via N+1 1-D FFTs.

    The DPRT stage runs through the :mod:`repro.radon` operator surface;
    unset knobs resolve against the ambient :func:`repro.radon.config`
    scope (resolved before the trace cache, so scopes never replay
    stale knobs).
    """
    n = f.shape[0]
    if f.ndim != 2 or f.shape[1] != n or not is_prime(n):
        # the m -> <-m*v>_N bijection needs prime N; no embedding here
        # (padding would change the spectrum, unlike the DPRT round trip)
        raise ValueError(f"slice-theorem DFT needs a square prime-N image, "
                         f"got {f.shape}")
    return _dft2_jit(f, _resolve_knobs(method, strip_rows, m_block))


def dft2_via_dprt_batched(f: jnp.ndarray, method: Optional[str] = None,
                          strip_rows: Optional[int] = None,
                          m_block: Optional[int] = None,
                          batch_impl: Optional[str] = None) -> jnp.ndarray:
    """(B, N, N) stack -> (B, N, N) complex 2-D DFTs.

    The integer DPRT stage is batched through the radon operator
    surface (one fused pallas_call for ``method="pallas"``); the float
    FFT + slice scatter stages are vectorized across the batch.
    """
    if f.ndim != 3:
        raise ValueError(f"dft2_via_dprt_batched needs (B, N, N), "
                         f"got {f.shape}")
    n = f.shape[-1]
    if not is_prime(n):
        raise ValueError(f"slice-theorem DFT needs prime N, got {n}")
    return _dft2_jit(f, _resolve_knobs(method, strip_rows, m_block,
                                       batch_impl))


def dft2_reference(f: jnp.ndarray) -> jnp.ndarray:
    return jnp.fft.fft2(jnp.asarray(f, jnp.float64 if f.dtype == jnp.int64
                                    else jnp.float32))
