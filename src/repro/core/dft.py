"""2-D DFT via the discrete Fourier-slice theorem (Gertner / Grigoryan).

With R the DPRT of f and ``Rhat(m, k) = FFT_d R(m, d)``:

    Rhat(m, k) = Fhat(<-m*k>_N, k)      0 <= m < N      (skew slices)
    Rhat(N, k) = Fhat(k, 0)                             (the v=0 column)

where ``Fhat(u, v) = sum_{i,j} f(i,j) e^{-2pi i (u*i + v*j)/N}``.  Because N
is prime, for every v != 0 the map m -> <-m*v>_N is a bijection, so the N+1
length-N 1-D FFTs cover the full 2-D spectrum exactly once (plus the shared
DC term).  This is the paper's "minimal number of 1-D FFTs" route to the
2-D DFT (Sec. I, refs [14][17]) -- all O(N^3) additions happen in exact
integer arithmetic inside the DPRT; only the final N+1 FFTs are float.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .dprt import dprt, is_prime

__all__ = ["dft2_via_dprt", "dft2_reference"]


@functools.partial(jax.jit, static_argnames=("method",))
def dft2_via_dprt(f: jnp.ndarray, method: str = "horner") -> jnp.ndarray:
    """(N, N) real/int image -> (N, N) complex 2-D DFT, via N+1 1-D FFTs."""
    n = f.shape[0]
    r = dprt(f, method=method)                     # (N+1, N) exact ints
    rhat = jnp.fft.fft(r.astype(jnp.float64 if r.dtype == jnp.int64
                                else jnp.float32), axis=1)

    k = jnp.arange(n)
    m = jnp.arange(n)[:, None]
    u = (-m * k[None, :]) % n                      # Fhat(u[m,k], k) = Rhat[m,k]

    out = jnp.zeros((n, n), rhat.dtype)
    # scatter the skew slices; k=0 column is written N times with the same
    # DC value (harmless), then overwritten exactly by the m=N projection.
    out = out.at[u, jnp.broadcast_to(k[None, :], (n, n))].set(rhat[:n])
    out = out.at[:, 0].set(rhat[n])                # Fhat(u, 0) = FFT(R[N])[u]
    return out


def dft2_reference(f: jnp.ndarray) -> jnp.ndarray:
    return jnp.fft.fft2(jnp.asarray(f, jnp.float64 if f.dtype == jnp.int64
                                    else jnp.float32))
