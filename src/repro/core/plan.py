"""Transform plans: the single dispatch point for every DPRT in the repo.

Two layers live here:

1. **Backend registry.**  Each transform strategy (``gather`` /
   ``horner`` / ``strips`` / ``pallas`` / the ``sharded`` and
   ``sharded_pallas`` shard_map paths from
   :mod:`repro.core.distributed`) registers a :class:`Backend`
   object declaring its capabilities -- batched-native, needs
   ``strip_rows``, takes ``m_block``, mesh-aware, supported dtype kinds
   -- plus uniform callables for the skew-sum core and the full
   forward/inverse transforms.  Every public entry point
   (:mod:`repro.core.dprt`, :mod:`repro.core.conv`,
   :mod:`repro.core.dft`, ``launch/serve.py``) resolves methods here,
   so there is exactly one ``method`` string -> implementation mapping
   in the repo.  ``method="auto"`` picks the best registered backend
   for (shape, dtype, batch, active mesh), consulting the
   :mod:`repro.kernels.tuning` table for block shapes.

2. **RadonPlan.**  A cached, frozen plan for arbitrary ``(H, W)`` or
   ``(B, H, W)`` inputs: the image is zero-embedded into the smallest
   prime ``P >= max(H, W)`` (:mod:`repro.core.geometry` records the
   pad), transformed by the resolved backend, and the inverse crops
   back -- so ``plan.inverse(plan.forward(f)) == f`` holds bit-exactly
   for any integer image.  Zero padding is exact by linearity: padded
   rows/columns contribute 0 to every projection sum and the exact
   integer inverse reproduces them as 0, so the crop discards only
   zeros.  Plans also carry the paper's Sec. III-C resource-fitting
   knobs: ``block_rows`` streams the strip decomposition (eq. 7-8)
   through a `lax.scan` so only one strip partial is live at a time,
   and ``block_batch`` streams batched stacks through the fused Pallas
   kernel in bounded-size chunks via `lax.map`.

Plans are cached by (shape, dtype, method, knobs, mesh) in a *bounded*
LRU cache -- building one is pure Python shape math, so repeat traffic
on the same geometry (the serving scenario) hits the cache; see
:func:`plan_cache_info` (which also reports evictions) and the
``REPRO_PLAN_CACHE_MAXSIZE`` environment variable.

:class:`RadonPlan` is registered as a JAX **pytree with zero leaves**
(the whole plan is static aux data), so plans can be closed over,
passed as `jit`/`vmap`/`shard_map` arguments, and nested in argument
pytrees without ever retracing: two calls with the same plan produce
the same treedef and hit the same executable.  The differentiable /
AOT-compiled operator surface on top of plans lives in
:mod:`repro.radon`.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import os
import threading
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from . import geometry as G
from .dprt import (accum_dtype_for, align_partial, strip_partial,
                   _skew_sum_gather, _skew_sum_horner, _skew_sum_strips)
from repro.kernels.tuning import resolve_blocks

__all__ = [
    "Backend",
    "register_backend",
    "get_backend",
    "available_backends",
    "backend_capabilities",
    "select_backend",
    "RadonPlan",
    "get_plan",
    "plan_cache_info",
    "plan_cache_entries",
    "plan_cache_clear",
    "plan_cache_discard",
    "set_plan_cache_maxsize",
    "dispatch_skew_sum",
]


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Backend:
    """One transform strategy and its declared capabilities.

    All callables share the uniform keyword surface
    ``(x, *, strip_rows=None, m_block=None, mesh=None)`` (plus ``sign``
    for ``skew_sum``); adapters ignore knobs they do not use.  A ``None``
    ``forward_batched``/``inverse_batched`` means the dispatch wraps the
    single-image callable with `lax.map`/`vmap` (``batch_impl``).
    """

    name: str
    skew_sum: Callable
    forward: Callable
    inverse: Callable
    forward_batched: Optional[Callable] = None
    inverse_batched: Optional[Callable] = None
    skew_batched: Optional[Callable] = None  # (B, N, N) stacks in one call
    #: fused projection-domain pipeline (forward -> per-direction op ->
    #: inverse without materializing the projections): callable
    #: ``(fp, op, operand, operand_form, *, strip_rows, m_block, mesh)``
    #: on prime-domain inputs.  ``None`` means the dispatch runs the
    #: STAGED fallback (forward, 1-D stage, inverse as separate steps) --
    #: the rule every backend without the capability inherits.
    pipeline: Optional[Callable] = None
    batched_native: bool = False
    needs_strip_rows: bool = False
    takes_m_block: bool = False
    #: understands the ``stream_rows`` knob natively (the in-launch
    #: streamed-strip kernels).  Backends without it degrade a
    #: ``stream_rows`` request to the plan layer's scan-of-launches
    #: ``block_rows`` fallback -- same partial-sum algebra, bounded
    #: memory, just one launch per strip instead of one total.
    takes_stream_rows: bool = False
    mesh_aware: bool = False
    dtype_kinds: Optional[Tuple[str, ...]] = None  # None = any dtype
    priority: int = 0  # higher wins under method="auto"
    note: str = ""

    def supports_dtype(self, dtype) -> bool:
        if self.dtype_kinds is None:
            return True
        return jnp.dtype(dtype).kind in self.dtype_kinds


_REGISTRY: dict = {}


def register_backend(backend: Backend) -> Backend:
    """Register (or replace) a backend; returns it for chaining."""
    _REGISTRY[backend.name] = backend
    if "_cached_plan" in globals():  # cached plans may pin a stale choice
        plan_cache_clear()           # (guard: built-ins register first)
    return backend


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown method {name!r}; registered backends: "
            f"{available_backends()} (or 'auto')") from None


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def backend_capabilities() -> list:
    """Capability rows (dicts) for docs, ``serve --list-backends``, tests."""
    rows = []
    for name in available_backends():
        b = _REGISTRY[name]
        rows.append({
            "name": b.name,
            "batched_native": b.batched_native,
            "needs_strip_rows": b.needs_strip_rows,
            "takes_m_block": b.takes_m_block,
            "stream": b.takes_stream_rows,
            "mesh_aware": b.mesh_aware,
            "pipeline": b.pipeline is not None,
            "dtypes": "any" if b.dtype_kinds is None
                      else ",".join(b.dtype_kinds),
            "priority": b.priority,
            "note": b.note,
        })
    return rows


def _active_mesh():
    """The ambient `with mesh:` context's mesh, if any (best effort)."""
    try:  # pragma: no cover - exercised only under an active mesh
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m is not None and not m.empty and m.size > 1:
            return m
    except Exception:
        pass
    return None


def select_backend(n: int, dtype, batch: Optional[int] = None,
                   mesh=None) -> str:
    """``method="auto"``: best registered backend for the call site.

    An explicit mesh routes to the highest-priority mesh-aware backend
    whose dtype capability matches (with the shipped registry:
    ``sharded_pallas`` -- the per-shard fused-kernel path -- for every
    int/float image, falling back to the legacy ``sharded``); otherwise
    the highest-priority non-mesh backend wins -- the fused ``pallas``
    kernel for every int/float image, falling back to ``horner``.
    Block shapes come from :mod:`repro.kernels.tuning` at plan-build
    time.  (Ambient ``with mesh:`` contexts are resolved by the
    *callers* -- :func:`get_plan` and the public transform wrappers --
    before any cache, so a cached decision is never pinned to a stale
    context.)
    """
    best = None
    for name in available_backends():
        b = _REGISTRY[name]
        if b.mesh_aware != (mesh is not None) or not b.supports_dtype(dtype):
            continue
        if best is None or b.priority > best.priority:
            best = b
    if best is None:
        raise ValueError(f"no registered backend supports dtype {dtype}"
                         + (" under a mesh" if mesh is not None else ""))
    return best.name


# ---------------------------------------------------------------------------
# shared transform epilogues (the only copies in the repo)
# ---------------------------------------------------------------------------
def _attach_row_sum(core: jnp.ndarray, f: jnp.ndarray) -> jnp.ndarray:
    """Append the R(N, d) = sum_j f(d, j) projection row.

    Rank-polymorphic: (N, N) images or (…, N, N) stacks alike (the
    batched-native mesh backends ride the same epilogue)."""
    last = f.astype(core.dtype).sum(axis=-1)[..., None, :]
    return jnp.concatenate([core, last], axis=-2)


def _inverse_epilogue(z: jnp.ndarray, r: jnp.ndarray, n: int) -> jnp.ndarray:
    """-S + R(N, i) correction and the exact divide-by-N (paper eq. 3-4).

    Rank-polymorphic: accepts (N+1, N) or batched (…, N+1, N) stacks."""
    acc = z.dtype
    s = r[..., 0, :].astype(acc).sum(axis=-1)[..., None, None]
    num = z - s + r[..., n, :].astype(acc)[..., :, None]
    if jnp.issubdtype(acc, jnp.integer):
        return num // n
    return num / n


def _make_forward(skew: Callable) -> Callable:
    def fwd(f, *, strip_rows=None, m_block=None, mesh=None):
        core = skew(f, +1, strip_rows=strip_rows, m_block=m_block, mesh=mesh)
        return _attach_row_sum(core, f)
    return fwd


def _make_inverse(skew: Callable) -> Callable:
    def inv(r, *, strip_rows=None, m_block=None, mesh=None):
        n = r.shape[-1]
        z = skew(r[:n], -1, strip_rows=strip_rows, m_block=m_block, mesh=mesh)
        return _inverse_epilogue(z, r, n)
    return inv


# ---------------------------------------------------------------------------
# exact transposes (adjoints) of the two transforms
#
# The forward DPRT A : R^{NxN} -> R^{(N+1)xN} is linear, and so is the
# inverse B = A^{-1}.  Working out <A f, r> = <f, A^T r> entrywise:
#
#   (A^T r)[i, j]  = sum_{m<N} r(m, <j - m*i>_N) + r(N, i)
#                  = skew_sum(r[:N], -1)[i, j] + r(N, i)
#   (B^T g)        = ( [skew_sum(g, +1) ; row-sums of g] - total(g)*E00 ) / N
#                  = ( A g - total(g) * (e_0 1^T) ) / N
#
# i.e. both adjoints are built from the SAME registry skew-sum primitive
# as the transforms themselves (with the sign flipped), so an "exact
# adjoint through backend X" is exact for every registered backend,
# including the fused Pallas kernels.  These epilogues are
# rank-polymorphic: they accept (N+1, N) / (N, N) or batched stacks.
# ---------------------------------------------------------------------------
def _adjoint_epilogue(z: jnp.ndarray, r: jnp.ndarray, n: int) -> jnp.ndarray:
    """z = skew_sum(r[..., :N, :], -1); add the row-sum row's transpose."""
    return z + r[..., n, :].astype(z.dtype)[..., :, None]


def _inverse_adjoint_epilogue(core: jnp.ndarray, g: jnp.ndarray,
                              n: int) -> jnp.ndarray:
    """core = skew_sum(g, +1); build (A g - total(g) E00) / N."""
    acc = core.dtype
    rowsum = g.astype(acc).sum(axis=-1)[..., None, :]      # (…, 1, N)
    out = jnp.concatenate([core, rowsum], axis=-2)          # = A g
    total = g.astype(acc).sum(axis=(-2, -1))
    out = out.at[..., 0, :].add(-total[..., None])
    if jnp.issubdtype(acc, jnp.integer):
        # matches the inverse's floor-division convention; the true
        # adjoint of the float inverse is the float path below
        return out // n
    return out / n


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------
def _gather_skew(g, sign, *, strip_rows=None, m_block=None, mesh=None):
    return _skew_sum_gather(g, sign)


def _horner_skew(g, sign, *, strip_rows=None, m_block=None, mesh=None):
    return _skew_sum_horner(g, sign)


def _strips_skew(g, sign, *, strip_rows=None, m_block=None, mesh=None):
    if strip_rows is None:  # plan-level resolution supplies the tuned H;
        # direct callers get the same table lookup (real accum itemsize)
        itemsize = jnp.dtype(
            accum_dtype_for(g.dtype, g.shape[-1], warn=False)).itemsize
        strip_rows = resolve_blocks(g.shape[-1], itemsize)[0]
    return _skew_sum_strips(g, sign, strip_rows)


def _pallas_skew(g, sign, *, strip_rows=None, m_block=None, mesh=None,
                 stream_rows=None):
    from repro.kernels.ops import skew_sum_pallas  # lazy: no import cycle
    return skew_sum_pallas(g, sign, strip_rows=strip_rows, m_block=m_block,
                           stream_rows=stream_rows)


# the pallas skew wrapper accepts (N, N) and (B, N, N) alike, so the
# batched-native adjoint datapaths reuse the same adapter
_pallas_skew_batched = _pallas_skew


def _pallas_forward(f, *, strip_rows=None, m_block=None, mesh=None,
                    stream_rows=None):
    from repro.kernels.ops import dprt_pallas
    return dprt_pallas(f, strip_rows=strip_rows, m_block=m_block,
                       stream_rows=stream_rows)


def _pallas_inverse(r, *, strip_rows=None, m_block=None, mesh=None,
                    stream_rows=None):
    from repro.kernels.ops import idprt_pallas
    return idprt_pallas(r, strip_rows=strip_rows, m_block=m_block,
                        stream_rows=stream_rows)


def _pallas_pipeline(fp, op, operand, operand_form, *, strip_rows=None,
                     m_block=None, mesh=None, stream_rows=None):
    # m_block here is the PIPELINE direction block (its own tune table),
    # distinct from the transform kernels' m_block; plan-level callers
    # pass None and let the pipeline table decide
    from repro.kernels.ops import projection_pipeline_pallas
    return projection_pipeline_pallas(fp, op, operand,
                                      operand_form=operand_form)


def _require_mesh(mesh):
    if mesh is None:
        raise ValueError(
            "the 'sharded' backend needs mesh= (jax.sharding.Mesh); "
            "pass it explicitly or select it via an active `with mesh:`")
    return mesh


def _mesh_axis(mesh) -> str:
    """Row-sharding axis: 'model' if present, else the mesh's first axis."""
    if "model" in mesh.shape:
        return "model"
    return next(iter(mesh.shape))


def _sharded_skew(g, sign, *, strip_rows=None, m_block=None, mesh=None):
    from .distributed import _skew_sum_sharded
    mesh = _require_mesh(mesh)
    return _skew_sum_sharded(g, mesh, axis=_mesh_axis(mesh), sign=sign)


def _sharded_forward(f, *, strip_rows=None, m_block=None, mesh=None):
    from .distributed import dprt_sharded
    mesh = _require_mesh(mesh)
    return dprt_sharded(f, mesh, axis=_mesh_axis(mesh))


def _sharded_inverse(r, *, strip_rows=None, m_block=None, mesh=None):
    from .distributed import idprt_sharded
    mesh = _require_mesh(mesh)
    return idprt_sharded(r, mesh, axis=_mesh_axis(mesh))


def _sharded_forward_batched(fb, *, strip_rows=None, m_block=None, mesh=None):
    from .distributed import dprt_batch_sharded
    return dprt_batch_sharded(fb, _require_mesh(mesh))


def _sharded_inverse_batched(rb, *, strip_rows=None, m_block=None, mesh=None):
    from .distributed import idprt_batch_sharded
    return idprt_batch_sharded(rb, _require_mesh(mesh))


# the sharded_pallas entry points accept (N, N) and (B, N, N) alike, so
# one adapter each serves the single-image AND batched-native datapaths.
# The plan datapath pins reduce="psum": AOT executables chain forward ->
# inverse by exact input-sharding match, which needs the stable
# replicated projection layout (slicing the N+1 real rows off the
# direction-sharded padded layout re-lays-out anyway at operator
# geometry).  The direction-sharded default lives on the raw
# core.distributed API, where a round trip consumes the shards in place.
def _sharded_pallas_skew(g, sign, *, strip_rows=None, m_block=None,
                         mesh=None, stream_rows=None):
    from .distributed import skew_sum_sharded_pallas
    return skew_sum_sharded_pallas(g, _require_mesh(mesh), sign=sign,
                                   reduce="psum",
                                   strip_rows=strip_rows, m_block=m_block,
                                   stream_rows=stream_rows)


def _sharded_pallas_forward(f, *, strip_rows=None, m_block=None, mesh=None,
                            stream_rows=None):
    from .distributed import dprt_sharded_pallas
    return dprt_sharded_pallas(f, _require_mesh(mesh), reduce="psum",
                               strip_rows=strip_rows, m_block=m_block,
                               stream_rows=stream_rows)


def _sharded_pallas_inverse(r, *, strip_rows=None, m_block=None, mesh=None,
                            stream_rows=None):
    from .distributed import idprt_sharded_pallas
    return idprt_sharded_pallas(r, _require_mesh(mesh), reduce="psum",
                                strip_rows=strip_rows, m_block=m_block,
                                stream_rows=stream_rows)


def _sharded_pallas_pipeline(fp, op, operand, operand_form, *,
                             strip_rows=None, m_block=None, mesh=None,
                             stream_rows=None):
    from .distributed import projection_pipeline_sharded
    return projection_pipeline_sharded(fp, _require_mesh(mesh), op=op,
                                       operand=operand,
                                       strip_rows=strip_rows,
                                       m_block=m_block,
                                       stream_rows=stream_rows)


register_backend(Backend(
    name="gather",
    skew_sum=_gather_skew,
    forward=_make_forward(_gather_skew),
    inverse=_make_inverse(_gather_skew),
    priority=10,
    note="per-direction shear oracle (systolic analog)",
))
register_backend(Backend(
    name="horner",
    skew_sum=_horner_skew,
    forward=_make_forward(_horner_skew),
    inverse=_make_inverse(_horner_skew),
    priority=50,
    note="paper Sec. III-B shift-and-add dataflow",
))
register_backend(Backend(
    name="strips",
    skew_sum=_strips_skew,
    forward=_make_forward(_strips_skew),
    inverse=_make_inverse(_strips_skew),
    needs_strip_rows=True,
    priority=30,
    note="scalable SFDPRT strip decomposition (eq. 5-8)",
))
register_backend(Backend(
    name="pallas",
    skew_sum=_pallas_skew,
    forward=_pallas_forward,
    inverse=_pallas_inverse,
    forward_batched=_pallas_forward,   # same wrappers take (B, N, N)
    inverse_batched=_pallas_inverse,
    skew_batched=_pallas_skew_batched,
    pipeline=_pallas_pipeline,
    batched_native=True,
    takes_m_block=True,
    takes_stream_rows=True,
    dtype_kinds=("i", "u", "f"),
    priority=100,
    note="fused batched SFDPRT TPU kernel (one pallas_call per stack)",
))
register_backend(Backend(
    name="sharded",
    skew_sum=_sharded_skew,
    forward=_sharded_forward,
    inverse=_sharded_inverse,
    forward_batched=_sharded_forward_batched,
    inverse_batched=_sharded_inverse_batched,
    mesh_aware=True,
    priority=0,  # mesh-only; sharded_pallas outranks it under auto
    note="legacy shard_map super-strips (Horner scan) + one psum",
))
register_backend(Backend(
    name="sharded_pallas",
    skew_sum=_sharded_pallas_skew,
    forward=_sharded_pallas_forward,
    inverse=_sharded_pallas_inverse,
    forward_batched=_sharded_pallas_forward,   # same wrappers take (B, …)
    inverse_batched=_sharded_pallas_inverse,
    skew_batched=_sharded_pallas_skew,
    pipeline=_sharded_pallas_pipeline,
    batched_native=True,
    takes_m_block=True,
    takes_stream_rows=True,
    mesh_aware=True,
    dtype_kinds=("i", "u", "f"),
    priority=20,  # mesh-only: beats legacy "sharded" under method="auto"
    note="per-shard fused SFDPRT pallas kernel + one psum "
         "(mesh data x model; core/distributed.py)",
))


# ---------------------------------------------------------------------------
# blocked (resource-fitting) execution helpers
# ---------------------------------------------------------------------------
def _blocked_skew_sum(gmat: jnp.ndarray, sign: int, block_rows: int,
                      acc_dtype) -> jnp.ndarray:
    """Strip decomposition streamed through `lax.scan` (bounded memory).

    Identical algebra to ``method="strips"`` (partial Horner per strip,
    one alignment roll, accumulate -- paper eq. 7-8) but only ONE strip
    partial is live at a time instead of all ceil(N/H) of them: the
    Sec. III-C "fit the architecture to available resources" scheme.
    """
    n = gmat.shape[-1]
    h = int(block_rows)
    if h < 1:
        raise ValueError(f"block_rows must be >= 1, got {h}")
    k = math.ceil(gmat.shape[0] / h)
    gp = jnp.pad(gmat, ((0, k * h - gmat.shape[0]), (0, 0)))
    strips = gp.reshape(k, h, n)
    offsets = jnp.arange(k, dtype=jnp.int32) * h

    def step(acc, xs):
        s, off = xs
        u = strip_partial(s, n, sign=sign, acc_dtype=acc_dtype)
        return acc + align_partial(u, off, sign), None

    acc0 = jnp.zeros((n, n), acc_dtype)
    acc, _ = jax.lax.scan(step, acc0, (strips, offsets))
    return acc


def _map_chunk_pairs(fn: Callable, xb: jnp.ndarray, wb: jnp.ndarray,
                     chunk: int) -> jnp.ndarray:
    """`_map_chunks` for a paired (image stack, batched operand): both
    chunk together so e.g. a fused conv against per-image kernels keeps
    the ``block_batch`` memory bound."""
    b = xb.shape[0]
    chunk = int(chunk)
    if chunk < 1:
        raise ValueError(f"block_batch must be >= 1, got {chunk}")
    if chunk >= b:
        return fn(xb, wb)
    nb = math.ceil(b / chunk)
    pad = nb * chunk - b
    xp = jnp.pad(xb, ((0, pad),) + ((0, 0),) * (xb.ndim - 1))
    wp = jnp.pad(wb, ((0, pad),) + ((0, 0),) * (wb.ndim - 1))
    out = jax.lax.map(lambda xw: fn(*xw),
                      (xp.reshape(nb, chunk, *xb.shape[1:]),
                       wp.reshape(nb, chunk, *wb.shape[1:])))
    return out.reshape(nb * chunk, *out.shape[2:])[:b]


def _map_chunks(fn: Callable, xb: jnp.ndarray, chunk: int) -> jnp.ndarray:
    """Apply a stack-level ``fn`` over batch chunks via `lax.map`.

    Bounds live memory to one ``chunk``-sized stack (plus the output);
    zero-image padding on the last chunk is sliced back off.
    """
    b = xb.shape[0]
    chunk = int(chunk)
    if chunk < 1:
        raise ValueError(f"block_batch must be >= 1, got {chunk}")
    if chunk >= b:
        return fn(xb)
    nb = math.ceil(b / chunk)
    pad = nb * chunk - b
    xp = jnp.pad(xb, ((0, pad),) + ((0, 0),) * (xb.ndim - 1))
    out = jax.lax.map(fn, xp.reshape(nb, chunk, *xb.shape[1:]))
    return out.reshape(nb * chunk, *out.shape[2:])[:b]


# ---------------------------------------------------------------------------
# RadonPlan
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RadonPlan:
    """A resolved, cached recipe for transforming one input geometry.

    ``forward`` embeds into the prime domain and returns the
    ``(…, P+1, P)`` projections; ``inverse`` reconstructs and crops back
    to ``(…, H, W)``.  Bit-exact round trip for integer images by
    construction (zero pad in, zero pad out, crop).
    """

    geometry: G.Geometry
    method: str                      # resolved backend name (never "auto")
    requested_method: str            # what the caller asked for
    strip_rows: Optional[int] = None
    m_block: Optional[int] = None
    batch_impl: str = "auto"
    block_rows: Optional[int] = None
    #: stream H-row strips through ONE fused kernel launch (VMEM scratch
    #: accumulation / double-buffered HBM DMA) on backends declaring
    #: ``takes_stream_rows``; other backends degrade to the
    #: ``block_rows``-style scan with the same strip height.
    stream_rows: Optional[int] = None
    block_batch: Optional[int] = None
    mesh: Optional[object] = None
    # part of the plan's identity (eq/hash) so the per-plan caches
    # downstream (jitted appliers, AOT executables, trace counters) are
    # exactly as granular as the plan cache itself: evicting one
    # dtype's plan can never drop a different dtype's live state
    dtype_name: Optional[str] = None

    @property
    def backend(self) -> Backend:
        return get_backend(self.method)

    def _knobs(self) -> dict:
        knobs = {"strip_rows": self.strip_rows, "m_block": self.m_block,
                 "mesh": self.mesh}
        if self.backend.takes_stream_rows:
            knobs["stream_rows"] = self.stream_rows
        return knobs

    @property
    def _scan_rows(self) -> Optional[int]:
        """Strip height when the scan-of-launches fallback must run.

        An explicit ``block_rows`` always scans (the paper's staged
        Sec. III-C scheme); ``stream_rows`` on a backend WITHOUT the
        streamed-kernel capability degrades to the same scan -- memory
        stays bounded either way, capable backends just do it in one
        launch.  ``None`` means the resolved backend runs natively.
        """
        if self.block_rows is not None:
            return self.block_rows
        if self.stream_rows is not None \
                and not self.backend.takes_stream_rows:
            return self.stream_rows
        return None

    def _batch_impl(self) -> str:
        if self.batch_impl != "auto":
            return self.batch_impl
        # Measured (EXPERIMENTS.md §Perf): on CPU `lax.map` hits the
        # 16x-single ideal while vmap pays +60%; on TPU vmap wins.
        return "map" if jax.default_backend() == "cpu" else "vmap"

    # -- prime-domain single image ----------------------------------------
    def _forward_prime(self, fp: jnp.ndarray) -> jnp.ndarray:
        if self._scan_rows is not None:
            core = _blocked_skew_sum(fp, +1, self._scan_rows,
                                     accum_dtype_for(fp.dtype, fp.shape[-1]))
            return _attach_row_sum(core, fp)
        return self.backend.forward(fp, **self._knobs())

    def _inverse_prime(self, r: jnp.ndarray) -> jnp.ndarray:
        if self._scan_rows is not None:
            n = r.shape[-1]
            acc = accum_dtype_for(r.dtype, n)
            z = _blocked_skew_sum(r[:n], -1, self._scan_rows, acc)
            return _inverse_epilogue(z, r, n)
        return self.backend.inverse(r, **self._knobs())

    def _skew_prime(self, x: jnp.ndarray, sign: int) -> jnp.ndarray:
        if self._scan_rows is not None:
            return _blocked_skew_sum(x, sign, self._scan_rows,
                                     accum_dtype_for(x.dtype, x.shape[-1]))
        return self.backend.skew_sum(x, sign, **self._knobs())

    def _adjoint_prime(self, r: jnp.ndarray) -> jnp.ndarray:
        n = self.geometry.prime
        return _adjoint_epilogue(self._skew_prime(r[:n], -1), r, n)

    def _inverse_adjoint_prime(self, g: jnp.ndarray) -> jnp.ndarray:
        return _inverse_adjoint_epilogue(self._skew_prime(g, +1), g,
                                         self.geometry.prime)

    # -- batched stacks ----------------------------------------------------
    def _stack(self, xb: jnp.ndarray, native: Optional[Callable],
               one: Callable) -> jnp.ndarray:
        if native is not None and self._scan_rows is None:
            fn = lambda chunk: native(chunk, **self._knobs())
        elif self._batch_impl() == "map":
            fn = lambda chunk: jax.lax.map(one, chunk)
        else:
            fn = lambda chunk: jax.vmap(one)(chunk)
        if self.block_batch is not None:
            return _map_chunks(fn, xb, self.block_batch)
        return fn(xb)

    # -- public ------------------------------------------------------------
    def forward(self, f: jnp.ndarray) -> jnp.ndarray:
        """(…, H, W) image(s) -> (…, P+1, P) exact projections."""
        g = self.geometry
        if f.shape != g.image_shape:
            raise ValueError(
                f"plan built for {g.image_shape}, got image {f.shape}")
        fp = G.embed(f, g)
        if not g.batched:
            return self._forward_prime(fp)
        be = self.backend
        if be.mesh_aware and be.forward_batched is None:
            raise ValueError(f"{be.name} has no batched forward")
        native = (be.forward_batched
                  if be.batched_native or be.mesh_aware else None)
        return self._stack(fp, native, self._forward_prime)

    def inverse(self, r: jnp.ndarray) -> jnp.ndarray:
        """(…, P+1, P) projections -> (…, H, W) exact reconstruction."""
        g = self.geometry
        if r.shape != g.transform_shape:
            raise ValueError(
                f"plan expects projections {g.transform_shape}, "
                f"got {r.shape}")
        if not g.batched:
            return G.crop(self._inverse_prime(r), g)
        be = self.backend
        # mesh-aware backends with a batched-native inverse (both sharded
        # paths, via dprt/idprt_batch_sharded or the per-shard kernel) go
        # native; anything else takes the generic _stack path (map/vmap
        # of the single-image inverse).  block_batch chunking respected.
        native = (be.inverse_batched
                  if be.batched_native or be.mesh_aware else None)
        return G.crop(self._stack(r, native, self._inverse_prime), g)

    def adjoint(self, r: jnp.ndarray) -> jnp.ndarray:
        """Exact transpose of :meth:`forward`: (…, P+1, P) -> (…, H, W).

        ``adjoint`` is A^T for the *linear map* the plan's forward
        realizes (embed -> transform), so its adjoint crops back:
        crop == embed^T.  Distinct from :meth:`inverse` -- A^T A != I --
        and the VJP rule :mod:`repro.radon.autodiff` installs on every
        backend's forward.
        """
        g = self.geometry
        if r.shape != g.transform_shape:
            raise ValueError(
                f"plan adjoint expects projections {g.transform_shape}, "
                f"got {r.shape}")
        if not g.batched:
            return G.crop(self._adjoint_prime(r), g)
        be = self.backend
        native = None
        if be.skew_batched is not None and self._scan_rows is None:
            n = g.prime

            def native(rb, **knobs):
                z = be.skew_batched(rb[:, :n], -1, **knobs)
                return _adjoint_epilogue(z, rb, n)

        return G.crop(self._stack(r, native, self._adjoint_prime), g)

    def inverse_adjoint(self, f: jnp.ndarray) -> jnp.ndarray:
        """Exact transpose of :meth:`inverse`: (…, H, W) -> (…, P+1, P).

        (A^{-1})^T = (A^T)^{-1}; realized as (A g - total(g) E00) / N
        from the same backend skew-sum, so the VJP through the inverse
        stays on the selected backend too.  Integer inputs follow the
        inverse's floor-division convention; use floats for the true
        adjoint (AD always does).
        """
        g = self.geometry
        if f.shape != g.image_shape:
            raise ValueError(
                f"plan inverse_adjoint expects image {g.image_shape}, "
                f"got {f.shape}")
        fp = G.embed(f, g)                  # embed == crop^T
        if not g.batched:
            return self._inverse_adjoint_prime(fp)
        be = self.backend
        native = None
        if be.skew_batched is not None and self._scan_rows is None:
            n = g.prime

            def native(fb, **knobs):
                return _inverse_adjoint_epilogue(
                    be.skew_batched(fb, +1, **knobs), fb, n)

        return self._stack(fp, native, self._inverse_adjoint_prime)

    # -- projection-domain pipeline ----------------------------------------
    def pipeline(self, f: jnp.ndarray, op: str = "conv",
                 operand: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Fused ``inverse(per_direction_op(forward(f)))`` -- transform,
        1-D projection-domain stage and inverse as ONE dispatch.

        ``op``: ``"conv"`` (per-direction 1-D circular convolution
        against a second operand -- exact 2-D circular convolution by the
        paper's Sec. VI property), ``"mul"`` (pointwise projection-domain
        multiply: the ``inv @ pointwise @ fwd`` operator fusion), or
        ``"none"`` (the fused round trip).  ``operand`` is the conv
        operand as a prime-domain image (``(P, P)`` shared or matching
        ``f``'s batch) or as projections/weights (``(…, P+1, P)``), with
        the form inferred from its trailing shape.

        Backends declaring the ``pipeline`` capability run it as a single
        kernel launch with the projections resident in VMEM/registers;
        every other backend (and any plan streaming strips through
        ``block_rows``) takes the STAGED fallback -- forward, exact 1-D
        stage, inverse through the same registry -- so results are
        bit-exact for integers either way.  ``"conv"`` needs native prime
        geometry (zero-embedding would change the convolution's torus;
        :mod:`repro.core.conv` folds non-native geometries before
        dispatching here); ``"mul"``/``"none"`` fuse the literal
        embed -> transform -> weight -> inverse -> crop composition, so
        any geometry is accepted.
        """
        g = self.geometry
        if op not in ("none", "mul", "conv"):
            raise ValueError(f"pipeline op must be none|mul|conv: {op!r}")
        if f.shape != g.image_shape:
            raise ValueError(
                f"plan built for {g.image_shape}, got image {f.shape}")
        if op == "conv" and not g.native:
            raise ValueError(
                f"conv pipeline needs native square prime geometry, plan "
                f"is {g.image_shape} embedded in P={g.prime}")
        p = g.prime
        operand_form = None
        if op != "none":
            if operand is None:
                raise ValueError(f"pipeline op {op!r} needs an operand")
            if op == "conv" and operand.shape[-2:] == (p, p):
                operand_form = "image"
            elif operand.shape[-2:] == (p + 1, p):
                operand_form = "proj"
            else:
                raise ValueError(
                    f"pipeline operand must be (…, {p}, {p}) images or "
                    f"(…, {p + 1}, {p}) projections/weights for op={op!r}, "
                    f"got {operand.shape}")
            if operand.ndim == 3 and g.batch not in (None, operand.shape[0]) \
                    and operand.shape[0] != 1:
                raise ValueError(
                    f"batched pipeline operand {operand.shape} does not "
                    f"match plan batch {g.batch}")

        be = self.backend
        if be.pipeline is not None and self.block_rows is None \
                and self.stream_rows is None:
            fp = G.embed(f, g)
            if g.batched and self.block_batch is not None:
                if operand is None or operand.ndim == 2:
                    out = _map_chunks(
                        lambda chunk: be.pipeline(chunk, op, operand,
                                                  operand_form,
                                                  **self._knobs()),
                        fp, self.block_batch)
                else:   # batched operand: chunk image and operand together
                    out = _map_chunk_pairs(
                        lambda chunk, wch: be.pipeline(chunk, op, wch,
                                                       operand_form,
                                                       **self._knobs()),
                        fp, operand, self.block_batch)
            else:
                out = be.pipeline(fp, op, operand, operand_form,
                                  **self._knobs())
            return G.crop(out, g)

        # staged fallback: same three stages, separate launches
        rf = self.forward(f)
        if op == "conv":
            if operand_form == "image":
                if operand.shape == g.image_shape:
                    rg = self.forward(operand)
                else:  # one shared (P, P) operand for a batched plan
                    rg = get_plan((p, p), self.dtype_name, self.method,
                                  strip_rows=self.strip_rows,
                                  m_block=self.m_block,
                                  mesh=self.mesh).forward(operand)
            else:
                rg = operand
            from .conv import circ_conv1d_exact  # lazy: conv imports radon
            rc = circ_conv1d_exact(rf, rg)
        elif op == "mul":
            rc = rf * operand.astype(rf.dtype)
        else:
            rc = rf
        return self.inverse(rc.astype(rf.dtype))

    def describe(self) -> dict:
        g = self.geometry
        return {
            "image_shape": g.image_shape,
            "prime": g.prime,
            "pad": (g.pad_rows, g.pad_cols),
            "native": g.native,
            "dtype": self.dtype_name,
            "method": self.method,
            "requested_method": self.requested_method,
            "strip_rows": self.strip_rows,
            "m_block": self.m_block,
            "block_rows": self.block_rows,
            "stream_rows": self.stream_rows,
            "block_batch": self.block_batch,
            "mesh": None if self.mesh is None else repr(self.mesh),
        }


# RadonPlan is a pytree with ZERO leaves: the whole plan is static aux
# data.  Plans therefore cross jit/vmap/shard_map boundaries as
# arguments or closures without contributing tracers, and the treedef
# (== the plan, by hash/eq of the frozen dataclass) becomes part of the
# trace-cache key -- same plan, same executable, no retrace.
jax.tree_util.register_pytree_node(
    RadonPlan,
    lambda plan: ((), plan),
    lambda plan, _: plan,
)


# ---------------------------------------------------------------------------
# plan construction + cache (bounded LRU)
# ---------------------------------------------------------------------------
PlanCacheInfo = collections.namedtuple(
    "PlanCacheInfo", ["hits", "misses", "maxsize", "currsize", "evictions"])


def _env_cache_maxsize() -> Optional[int]:
    """``REPRO_PLAN_CACHE_MAXSIZE``: plans kept live (<= 0 => unbounded)."""
    raw = os.environ.get("REPRO_PLAN_CACHE_MAXSIZE", "512")
    try:
        size = int(raw)
    except ValueError:
        raise ValueError(
            f"REPRO_PLAN_CACHE_MAXSIZE must be an integer, got {raw!r}")
    return None if size <= 0 else size


class _PlanLRU:
    """A small LRU with an eviction counter (``functools.lru_cache``
    reports hits/misses but not evictions, which is the number a
    long-running serve process actually alarms on).

    Eviction hooks let the downstream per-plan caches (the jitted
    differentiable appliers and AOT executables in :mod:`repro.radon`)
    release their -- much heavier -- state in lockstep, so bounding THIS
    cache actually bounds the process."""

    def __init__(self, maxsize: Optional[int]):
        self.maxsize = maxsize
        self._data: "collections.OrderedDict" = collections.OrderedDict()
        self._lock = threading.Lock()
        self._evict_hooks: list = []
        self.hits = self.misses = self.evictions = 0

    def add_evict_hook(self, fn: Callable) -> None:
        """``fn(plan)`` is called for every plan dropped from the cache
        (eviction, resize, or clear)."""
        self._evict_hooks.append(fn)

    def _shrink_locked(self) -> list:
        dropped = []
        while self.maxsize is not None and len(self._data) > self.maxsize:
            dropped.append(self._data.popitem(last=False)[1])
            self.evictions += 1
        return dropped

    def _fire(self, dropped: list) -> None:
        for plan in dropped:        # outside the lock: hooks may be slow
            for fn in self._evict_hooks:
                fn(plan)

    def get_or_build(self, key, builder: Callable):
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return self._data[key]
        value = builder()          # build outside the lock (pure python)
        with self._lock:
            if key in self._data:  # racer built it first: keep theirs
                self._data.move_to_end(key)
                return self._data[key]
            self.misses += 1
            self._data[key] = value
            dropped = self._shrink_locked()
        self._fire(dropped)
        return value

    def info(self) -> PlanCacheInfo:
        with self._lock:
            return PlanCacheInfo(self.hits, self.misses, self.maxsize,
                                 len(self._data), self.evictions)

    def values(self) -> list:
        with self._lock:
            return list(self._data.values())

    def clear(self) -> None:
        with self._lock:
            dropped = list(self._data.values())
            self._data.clear()
        self._fire(dropped)

    def discard(self, plans) -> int:
        """Drop exactly the given plans (if cached), firing the evict
        hooks for each -- the targeted form of eviction the serving
        router uses to release one retired geometry's executables
        without disturbing its neighbours."""
        wanted = {id(p) for p in plans}
        with self._lock:
            keys = [k for k, v in self._data.items() if id(v) in wanted]
            dropped = [self._data.pop(k) for k in keys]
            self.evictions += len(dropped)
        self._fire(dropped)
        return len(dropped)

    def resize(self, maxsize: Optional[int]) -> None:
        with self._lock:
            self.maxsize = maxsize
            dropped = self._shrink_locked()
        self._fire(dropped)


_PLAN_CACHE = _PlanLRU(_env_cache_maxsize())


def add_plan_evict_hook(fn: Callable) -> None:
    """Register ``fn(plan)`` to run whenever a plan leaves the cache --
    the mechanism the radon layer uses to drop jitted appliers and AOT
    executables for geometries the bounded cache has let go."""
    _PLAN_CACHE.add_evict_hook(fn)


def set_plan_cache_maxsize(maxsize: Optional[int]) -> None:
    """Re-bound the plan cache (None or <= 0 => unbounded); evicts LRU
    entries immediately if the new bound is tighter."""
    if maxsize is not None and maxsize <= 0:
        maxsize = None
    _PLAN_CACHE.resize(maxsize)


def _cached_plan(shape: tuple, dtype_name: str, method: str,
                 strip_rows: Optional[int], m_block: Optional[int],
                 batch_impl: str, block_rows: Optional[int],
                 stream_rows: Optional[int],
                 block_batch: Optional[int], mesh) -> RadonPlan:
    key = (shape, dtype_name, method, strip_rows, m_block, batch_impl,
           block_rows, stream_rows, block_batch, mesh)
    return _PLAN_CACHE.get_or_build(key, lambda: _build_plan(*key))


def _build_plan(shape: tuple, dtype_name: str, method: str,
                strip_rows: Optional[int], m_block: Optional[int],
                batch_impl: str, block_rows: Optional[int],
                stream_rows: Optional[int],
                block_batch: Optional[int], mesh) -> RadonPlan:
    geom = G.normalize_geometry(shape)
    dtype = jnp.dtype(dtype_name)
    requested = method
    if method == "auto":
        method = select_backend(geom.prime, dtype, batch=geom.batch,
                                mesh=mesh)
    be = get_backend(method)
    if not be.supports_dtype(dtype):
        raise ValueError(
            f"backend {be.name!r} does not support dtype {dtype_name} "
            f"(kinds: {be.dtype_kinds})")
    if batch_impl not in ("auto", "map", "vmap"):
        raise ValueError(f"batch_impl must be auto|map|vmap: {batch_impl!r}")
    # warn=False: sizing only -- a plan built for block-shape metadata
    # (e.g. to hand its geometry to the float-promoting solver) must not
    # claim an integer-accumulator overflow that never runs
    itemsize = jnp.dtype(
        accum_dtype_for(dtype, geom.prime, warn=False)).itemsize
    # always resolves (even for backends without block knobs): the
    # resolver owns the block_rows/stream_rows conflict rejection
    th, tm = resolve_blocks(geom.prime, itemsize, strip_rows, m_block,
                            block_rows=block_rows, stream_rows=stream_rows)
    if be.needs_strip_rows or be.takes_m_block:
        strip_rows = th
        m_block = tm if be.takes_m_block else None
    return RadonPlan(geometry=geom, method=method, requested_method=requested,
                     strip_rows=strip_rows, m_block=m_block,
                     batch_impl=batch_impl, block_rows=block_rows,
                     stream_rows=stream_rows, block_batch=block_batch,
                     mesh=mesh, dtype_name=dtype.name)


def get_plan(shape, dtype, method: str = "auto", *,
             strip_rows: Optional[int] = None,
             m_block: Optional[int] = None,
             batch_impl: str = "auto",
             block_rows: Optional[int] = None,
             stream_rows: Optional[int] = None,
             block_batch: Optional[int] = None,
             mesh=None) -> RadonPlan:
    """Cached :class:`RadonPlan` for an input shape/dtype and knobs.

    An ambient ``with mesh:`` context is resolved HERE, before the
    lru-cache lookup, so the context participates in the effective cache
    key -- a plan built outside a mesh is never returned inside one (or
    vice versa).
    """
    if method == "auto" and mesh is None:
        mesh = _active_mesh()
    shape = tuple(int(s) for s in shape)
    return _cached_plan(shape, jnp.dtype(dtype).name, method,
                        None if strip_rows is None else int(strip_rows),
                        None if m_block is None else int(m_block),
                        batch_impl,
                        None if block_rows is None else int(block_rows),
                        None if stream_rows is None else int(stream_rows),
                        None if block_batch is None else int(block_batch),
                        mesh)


def plan_cache_info() -> PlanCacheInfo:
    """(hits, misses, maxsize, currsize, evictions) of the plan cache."""
    return _PLAN_CACHE.info()


def plan_cache_entries() -> list:
    """``describe()`` dicts for every live cached plan, LRU-oldest first
    -- the geometry census a serving process reports in its health
    endpoint (which geometries are warm, with which backend/knobs)."""
    return [plan.describe() for plan in _PLAN_CACHE.values()]


def plan_cache_clear() -> None:
    _PLAN_CACHE.clear()


def plan_cache_discard(plans) -> int:
    """Evict exactly the given plans from the cache, firing the same
    evict hooks as LRU pressure would -- so their jitted appliers and
    AOT executables are released in lockstep.  Returns how many were
    actually cached.  The serving router calls this when it retires a
    cold geometry, passing only plans no surviving route shares."""
    return _PLAN_CACHE.discard(plans)


def dispatch_skew_sum(g: jnp.ndarray, sign: int, method: str = "horner",
                      strip_rows: Optional[int] = None,
                      m_block: Optional[int] = None, mesh=None) -> jnp.ndarray:
    """Registry-routed skew-sum primitive (prime-domain (N, N) input)."""
    n = g.shape[-1]
    if method == "auto":
        method = select_backend(n, g.dtype, mesh=mesh)
    be = get_backend(method)
    if be.needs_strip_rows and strip_rows is None:
        itemsize = jnp.dtype(
            accum_dtype_for(g.dtype, n, warn=False)).itemsize
        strip_rows = resolve_blocks(n, itemsize, None, None)[0]
    return be.skew_sum(g, sign, strip_rows=strip_rows, m_block=m_block,
                       mesh=mesh)
