"""Exact fixed-point 2-D convolution via the DPRT convolution property.

The paper's headline application (Sec. I, VI): because the DPRT satisfies a
discrete Fourier-slice theorem, the DPRT of a 2-D *circular* convolution is
the per-direction 1-D circular convolution of the DPRTs:

    R_{f ** g}(m, .) = R_f(m, .) (*)_N R_g(m, .)     for all N+1 directions m

so 2-D convolution = DPRT -> (N+1) independent 1-D circular convolutions ->
inverse DPRT, entirely in integer arithmetic (no floating-point FFT).

All DPRT work routes through the transform-plan dispatch
(:mod:`repro.core.plan`), so ``method`` may be any registered backend
name (including ``"auto"`` and ``"pallas"``), and geometry handling
comes from :mod:`repro.core.geometry`:

* **Linear convolution** of arbitrary rectangular operands zero-pads
  both to the next prime P >= out_h/out_w *per axis* (the paper's
  density-of-primes argument: a power-of-two FFT must pad up to ~2x,
  the next prime is only O(log P) away on average).
* **Blocked linear convolution** (``block_size=``) realizes the
  companion paper's overlap-add scheme (arXiv 2112.13150) on the plan
  layer: the image is tiled into ``block_size``-sized square tiles,
  every tile convolves against the small kernel at the much smaller
  tile prime q = next_prime(block + k - 1) -- one batched fused-kernel
  call over the whole tile stack -- and per-tile results overlap-add
  onto the output canvas (`lax.scan`, one tile live at a time).  Exact
  in integers: tile padding is zeros, and overlap-add of exact tile
  linear convolutions is the exact full linear convolution.
* **Circular convolution** of square prime operands uses the direct
  transform-domain route above; any other (equal) geometry is convolved
  on its true (H, W) torus by folding the exact prime-embedded linear
  convolution (:func:`repro.core.geometry.fold_mod`) -- still bit-exact.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import geometry as G
from .dprt import accum_dtype_for, is_prime, next_prime

__all__ = [
    "circ_conv1d_exact",
    "circ_conv2d_dprt",
    "circ_conv2d_direct",
    "circ_conv2d_fft",
    "linear_conv2d_dprt",
    "linear_conv2d_direct",
    "prime_vs_pow2_padding",
]


def circ_conv1d_exact(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Batched exact 1-D circular convolution along the last axis.

    a, b: (..., N) with broadcastable leading dims.
    out[..., d] = sum_t a[..., t] * b[..., <d-t>_N].  O(N^2) integer
    MACs per row -- these run on the MXU as a matmul with the circulant
    of ``b``.  The circulant is only ever materialized from the
    *unbatched* operand (convolution commutes, so a batched ``b`` swaps
    with ``a``; two batched operands stream through `lax.map`), keeping
    the peak intermediate at O(rows * N^2) instead of O(B * rows * N^2).
    """
    n = a.shape[-1]
    acc = accum_dtype_for(jnp.result_type(a.dtype, b.dtype))
    out_lead = jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1])
    while a.ndim >= 3 and a.shape[0] == 1:   # size-1 batches broadcast
        a = a[0]
    while b.ndim >= 3 and b.shape[0] == 1:
        b = b[0]
    if b.ndim > a.ndim:
        a, b = b, a              # build the circulant from the smaller side
    if b.ndim >= 3 and b.ndim == a.ndim:   # both batched at the same
        if a.shape[:-2] != b.shape[:-2]:   # rank: one pair live at a time
            raise ValueError(
                f"batched circ_conv1d operands need matching leading "
                f"dims, got {a.shape} vs {b.shape}")
        out = jax.lax.map(lambda ab: circ_conv1d_exact(*ab), (a, b))
    else:  # circulant from the lower-rank side, broadcast over the rest
        d = jnp.arange(n)[:, None]
        t = jnp.arange(n)[None, :]
        bc = b.astype(acc)[..., (d - t) % n]   # bc[..., d, t] = b[<d-t>]
        out = jnp.einsum("...t,...dt->...d", a.astype(acc), bc)
    return jnp.broadcast_to(out, (*out_lead, n))


def _resolve_knobs(method, strip_rows, m_block) -> tuple:
    """Full ambient-knob snapshot (see ``ambient.snapshot_knobs``),
    taken OUTSIDE the jit boundaries below so the whole scope is part
    of each trace-cache key.  The fallback method is ``"auto"``: the
    registry's best backend for the geometry (the fused pipeline-capable
    Pallas kernel for int/float images)."""
    from repro.radon import ambient  # lazy: radon imports repro.core
    return ambient.snapshot_knobs(method, strip_rows, m_block,
                                  fallback_method="auto")


def _operator(shape, dtype, knobs: tuple):
    """The cached radon operator for one operand geometry."""
    from repro.radon import operator_for  # lazy: radon imports repro.core
    return operator_for(shape, dtype, knobs)


def _use_pipeline(plan, fuse: Optional[bool]) -> bool:
    """The staged-fallback rule: fuse when the backend declares the
    pipeline capability (and no ``block_rows`` streaming is requested);
    ``fuse=False`` forces the staged path, ``fuse=True`` asks for the
    pipeline dispatch (which itself falls back to staged stages on
    non-capable backends, bit-exactly)."""
    if fuse is not None:
        return bool(fuse)
    return plan.backend.pipeline is not None and plan.block_rows is None


def _circ_prime(f: jnp.ndarray, g: jnp.ndarray, knobs: tuple,
                fuse: Optional[bool]) -> jnp.ndarray:
    """Transform-domain circular convolution of square prime operands.

    Fused route (pipeline-capable backends): transform, per-direction
    1-D circular convolution and inverse as ONE kernel launch -- the
    projections never round-trip through HBM.  A batched stack against
    one shared kernel precomputes the kernel's projections with a single
    small forward launch and rides the batched pipeline.  Staged route:
    forward both operands, 1-D convolve (circulant built from the
    unbatched side only), inverse.
    """
    from repro.radon import pipeline_apply  # lazy: radon imports repro.core

    def fwd(x):
        return _operator(x.shape, x.dtype, knobs)(x)

    plan = _operator(f.shape, f.dtype, knobs).plan
    if _use_pipeline(plan, fuse):
        if g.ndim > f.ndim:      # convolution commutes: pipeline the stack
            return _circ_prime(g, f, knobs, fuse)
        if f.ndim == 3 and g.ndim == 2:
            # one shared operand for a whole stack: its projections are
            # computed ONCE (one small fused forward) and broadcast
            return pipeline_apply(plan, f, "conv", fwd(g))
        return pipeline_apply(plan, f, "conv", g)     # in-kernel operand
    rf, rg = fwd(f), fwd(g)
    rc = circ_conv1d_exact(rf, rg)      # all N+1 directions at once
    n = rc.shape[-1]
    shape = (n, n) if rc.ndim == 2 else (rc.shape[0], n, n)
    inv = _operator(shape, rc.dtype, knobs).inverse
    return inv(rc)


@functools.partial(jax.jit, static_argnames=("knobs", "block_size", "fuse"))
def _circ_conv2d_jit(f: jnp.ndarray, g: jnp.ndarray, knobs: tuple,
                     block_size: Optional[int],
                     fuse: Optional[bool]) -> jnp.ndarray:
    fh, fw = f.shape[-2:]
    if fh == fw and is_prime(fh) and block_size is None:
        return _circ_prime(f, g, knobs, fuse)
    lin = _linear_conv2d_jit(f, g, knobs, block_size, fuse)
    return G.fold_mod(lin, fh, fw)


def circ_conv2d_dprt(f: jnp.ndarray, g: jnp.ndarray,
                     method: Optional[str] = None,
                     strip_rows: Optional[int] = None,
                     m_block: Optional[int] = None,
                     block_size: Optional[int] = None,
                     fuse: Optional[bool] = None) -> jnp.ndarray:
    """Exact 2-D circular convolution of equal-geometry integer images.

    Square prime (N, N) operands take the paper's direct transform-
    domain route; on pipeline-capable backends (``method="auto"``
    resolves the fused Pallas kernel for int/float images) the whole
    transform -> per-direction 1-D convolution -> inverse chain runs as
    ONE kernel launch with the projections resident in VMEM/registers.
    Either operand may be a batched (B, N, N) stack.  Any other (H, W)
    geometry is convolved on its true (H, W) torus by folding the exact
    prime-embedded linear convolution -- bit-exact for integers on
    every route.  ``block_size`` streams the non-native path
    tile-by-tile (overlap-add; see :func:`linear_conv2d_dprt`).
    ``fuse=False`` forces the staged (separate-launches) path; the
    default fuses exactly when the resolved backend declares the
    pipeline capability.  All DPRT stages run through
    :mod:`repro.radon`; unset knobs resolve against the ambient
    :func:`repro.radon.config` scope, and ``jax.grad`` is exact through
    both routes.
    """
    fh, fw = f.shape[-2:]
    gh, gw = g.shape[-2:]
    if (fh, fw) != (gh, gw):
        raise ValueError(
            f"circular convolution needs equal operand geometry, got "
            f"{f.shape} vs {g.shape}")
    knobs = _resolve_knobs(method, strip_rows, m_block)
    return _circ_conv2d_jit(f, g, knobs, block_size, fuse)


def circ_conv2d_direct(f: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """O(N^4) direct oracle for circular convolution (exact)."""
    n = f.shape[0]
    acc = accum_dtype_for(jnp.result_type(f.dtype, g.dtype))
    i = jnp.arange(n)
    # out[x, y] = sum_{u,v} f[u, v] g[<x-u>, <y-v>]
    gx = g.astype(acc)[(i[:, None] - i[None, :]) % n]          # (x, u, N)
    gxy = gx[:, :, (i[:, None] - i[None, :]) % n]              # (x, u, y, v)
    return jnp.einsum("uv,xuyv->xy", f.astype(acc), gxy)


def circ_conv2d_fft(f: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Floating-point FFT path (the approach the paper's hardware avoids)."""
    out = jnp.fft.ifft2(jnp.fft.fft2(f) * jnp.fft.fft2(g)).real
    if jnp.issubdtype(f.dtype, jnp.integer):
        return jnp.round(out)
    return out


def _linear_conv_blocked(f: jnp.ndarray, g: jnp.ndarray, block: int,
                         knobs: tuple, fuse: Optional[bool]) -> jnp.ndarray:
    """Overlap-add linear convolution on prime-sized tiles.

    ``f``: (…, A_h, A_w) image(s); ``g``: one small (C_h, C_w) kernel.
    Each tile's circular convolution at q = next_prime(block + k - 1)
    IS its full linear convolution (no wraparound: q >= tile + k - 1),
    and the per-tile results overlap-add exactly to the full linear
    convolution -- the companion paper's scalable scheme.  On pipeline-
    capable backends the whole tile stack rides the batched fused
    pipeline: the kernel's projections are computed once (one small
    forward launch) and every tile's transform -> 1-D conv -> inverse
    runs as one batched kernel launch.
    """
    if g.ndim != 2:
        raise ValueError(
            f"blocked convolution needs a single 2-D kernel, got {g.shape}")
    ah, aw = f.shape[-2:]
    ch, cw = g.shape[-2:]
    block = int(block)
    q = next_prime(block + max(ch, cw) - 1)

    tiles, offsets = G.image_to_tiles(f, block)   # (…, T, block, block)
    tq = G.pad2d(tiles, q - block, q - block)
    gq = G.pad2d(g, q - ch, q - cw)
    rg = _operator(gq.shape, gq.dtype, knobs)(gq)

    t = tq.shape[-3]
    stack = tq.reshape(-1, q, q)                  # (B*T or T, q, q)
    stack_op = _operator(stack.shape, stack.dtype, knobs)
    if _use_pipeline(stack_op.plan, fuse):
        from repro.radon import pipeline_apply    # lazy: radon -> core
        outs = pipeline_apply(stack_op.plan, stack, "conv", rg)
    else:
        rt = stack_op(stack)                      # one fused forward call
        rc = circ_conv1d_exact(rt, rg)            # broadcast over the stack
        inv = _operator((rc.shape[0], q, q), rc.dtype, knobs).inverse
        outs = inv(rc)                            # (B*T or T, q, q)

    oh, ow = block + ch - 1, block + cw - 1       # useful tile output
    tile_out = outs[..., :oh, :ow]
    th, tw = -(-ah // block), -(-aw // block)
    canvas = (th * block + ch - 1, tw * block + cw - 1)

    def assemble(tiles_one):
        return G.overlap_add(tiles_one, offsets, canvas)

    if f.ndim == 3:
        lin = jax.lax.map(assemble,
                          tile_out.reshape(f.shape[0], t, oh, ow))
    else:
        lin = assemble(tile_out)
    return lin[..., : ah + ch - 1, : aw + cw - 1]


@functools.partial(jax.jit, static_argnames=("knobs", "block_size", "fuse"))
def _linear_conv2d_jit(f: jnp.ndarray, g: jnp.ndarray, knobs: tuple,
                       block_size: Optional[int],
                       fuse: Optional[bool]) -> jnp.ndarray:
    ah, aw = f.shape[-2:]
    ch, cw = g.shape[-2:]
    out_h, out_w = ah + ch - 1, aw + cw - 1
    if block_size is not None:
        return _linear_conv_blocked(f, g, block_size, knobs, fuse)
    p = next_prime(max(out_h, out_w))
    fp = G.pad2d(f, p - ah, p - aw)
    gp = G.pad2d(g, p - ch, p - cw)
    res = _circ_prime(fp, gp, knobs, fuse)
    return res[..., :out_h, :out_w]


def linear_conv2d_dprt(f: jnp.ndarray, g: jnp.ndarray,
                       method: Optional[str] = None,
                       strip_rows: Optional[int] = None,
                       m_block: Optional[int] = None,
                       block_size: Optional[int] = None,
                       fuse: Optional[bool] = None) -> jnp.ndarray:
    """Exact full linear convolution of arbitrary rectangular operands.

    Whole-image route: zero-pad both operands to the next prime that
    covers the full (out_h, out_w) support -- rows and columns padded
    independently, so rectangular inputs are handled exactly.  With
    ``block_size``, the overlap-add route tiles ``f`` into
    ``block_size``-square tiles and convolves each against the (small)
    kernel ``g`` at the tile prime instead of one giant image prime --
    the companion paper's resource-fitting scheme (bounded working set,
    batched tile stack riding the fused pipeline).  ``f`` may be a
    (B, H, W) stack in either route.  On pipeline-capable backends each
    route's transform -> 1-D conv -> inverse chain is a single kernel
    launch (``fuse=False`` forces the staged path).  Unset knobs resolve
    against the ambient :func:`repro.radon.config` scope.
    """
    knobs = _resolve_knobs(method, strip_rows, m_block)
    return _linear_conv2d_jit(f, g, knobs, block_size, fuse)


def linear_conv2d_direct(f: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """numpy oracle for full linear convolution (exact, int64)."""
    fa = np.asarray(f, dtype=np.int64)
    ga = np.asarray(g, dtype=np.int64)
    ah, aw = fa.shape
    ch, cw = ga.shape
    out = np.zeros((ah + ch - 1, aw + cw - 1), dtype=np.int64)
    for u in range(ah):
        for v in range(aw):
            out[u:u + ch, v:v + cw] += fa[u, v] * ga
    return out


def prime_vs_pow2_padding(size: int, kernel: int) -> dict:
    """Paper Sec. I: transform-size overhead of prime vs power-of-two padding."""
    need = size + kernel - 1
    p = next_prime(need)
    pow2 = 1 << max(0, (need - 1).bit_length())
    return {
        "required": need,
        "prime_pad": p,
        "pow2_pad": pow2,
        "prime_overhead": p / need,
        "pow2_overhead": pow2 / need,
    }
