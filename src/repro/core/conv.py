"""Exact fixed-point 2-D convolution via the DPRT convolution property.

The paper's headline application (Sec. I, VI): because the DPRT satisfies a
discrete Fourier-slice theorem, the DPRT of a 2-D *circular* convolution is
the per-direction 1-D circular convolution of the DPRTs:

    R_{f ** g}(m, .) = R_f(m, .) (*)_N R_g(m, .)     for all N+1 directions m

so 2-D convolution = DPRT -> (N+1) independent 1-D circular convolutions ->
inverse DPRT, entirely in integer arithmetic (no floating-point FFT).

Linear convolution is obtained by zero-padding both operands to the next
prime P >= A + C - 1.  This is the paper's density-of-primes argument: a
power-of-two FFT must pad to 2^ceil(log2(A+C-1)) (up to ~2x), while the next
prime is only O(log P) away on average.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .dprt import (accum_dtype_for, dprt, dprt_batched, idprt,
                   idprt_batched, is_prime, next_prime)

__all__ = [
    "circ_conv1d_exact",
    "circ_conv2d_dprt",
    "circ_conv2d_direct",
    "circ_conv2d_fft",
    "linear_conv2d_dprt",
    "linear_conv2d_direct",
    "prime_vs_pow2_padding",
]


def circ_conv1d_exact(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Batched exact 1-D circular convolution along the last axis.

    a, b: (..., N).  out[..., d] = sum_t a[..., t] * b[..., <d-t>_N].
    O(N^2) integer MACs per row -- these run on the MXU as a matmul with
    the circulant of ``b`` (built by gather once, reused across rows).
    """
    n = a.shape[-1]
    acc = accum_dtype_for(jnp.result_type(a.dtype, b.dtype))
    d = jnp.arange(n)[:, None]
    t = jnp.arange(n)[None, :]
    bc = b.astype(acc)[..., (d - t) % n]  # bc[..., d, t] = b[..., <d-t>_N]
    return jnp.einsum("...t,...dt->...d", a.astype(acc), bc)


@functools.partial(jax.jit, static_argnames=("method",))
def circ_conv2d_dprt(f: jnp.ndarray, g: jnp.ndarray,
                     method: str = "horner") -> jnp.ndarray:
    """Exact 2-D circular convolution of (N, N) integer images (N prime).

    All DPRT work routes through the :func:`repro.core.dprt.dprt`
    dispatch, so ``method`` may be any strategy including ``"pallas"``
    (the fused TPU kernel).  Either operand may also be a batched
    (B, N, N) stack -- batched stacks go through ``dprt_batched``/
    ``idprt_batched``, which for pallas is a single fused kernel call.
    """
    def fwd(x):
        return (dprt_batched(x, method=method) if x.ndim == 3
                else dprt(x, method=method))

    rf, rg = fwd(f), fwd(g)
    if rg.ndim > rf.ndim:
        # convolution commutes; build the circulant from the unbatched
        # operand so a batched g doesn't materialize a (B, N+1, N, N)
        # circulant (~1 GB at B=16, N=251)
        rf, rg = rg, rf
    if rf.ndim == 3 and rg.ndim == 3:
        if rf.shape[0] != rg.shape[0]:
            raise ValueError(
                f"batched operands need equal batch sizes, got "
                f"{f.shape} vs {g.shape}")
        # both batched: map over the batch so only one (N+1, N, N)
        # circulant is live at a time
        rc = jax.lax.map(lambda ab: circ_conv1d_exact(*ab), (rf, rg))
    else:
        rc = circ_conv1d_exact(rf, rg)      # all N+1 directions at once
    if rc.ndim == 3:
        return idprt_batched(rc, method=method)
    return idprt(rc, method=method)


def circ_conv2d_direct(f: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """O(N^4) direct oracle for circular convolution (exact)."""
    n = f.shape[0]
    acc = accum_dtype_for(jnp.result_type(f.dtype, g.dtype))
    i = jnp.arange(n)
    # out[x, y] = sum_{u,v} f[u, v] g[<x-u>, <y-v>]
    gx = g.astype(acc)[(i[:, None] - i[None, :]) % n]          # (x, u, N)
    gxy = gx[:, :, (i[:, None] - i[None, :]) % n]              # (x, u, y, v)
    return jnp.einsum("uv,xuyv->xy", f.astype(acc), gxy)


def circ_conv2d_fft(f: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Floating-point FFT path (the approach the paper's hardware avoids)."""
    out = jnp.fft.ifft2(jnp.fft.fft2(f) * jnp.fft.fft2(g)).real
    if jnp.issubdtype(f.dtype, jnp.integer):
        return jnp.round(out)
    return out


def _pad_to(x: jnp.ndarray, p: int) -> jnp.ndarray:
    return jnp.pad(x, ((0, p - x.shape[0]), (0, p - x.shape[1])))


def linear_conv2d_dprt(f: jnp.ndarray, g: jnp.ndarray,
                       method: str = "horner") -> jnp.ndarray:
    """Exact full linear convolution via prime zero-padding + circular conv."""
    a, c = f.shape[0], g.shape[0]
    out = a + c - 1
    p = next_prime(out)
    res = circ_conv2d_dprt(_pad_to(f, p), _pad_to(g, p), method=method)
    return res[:out, :out]


def linear_conv2d_direct(f: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """numpy oracle for full linear convolution (exact, int64)."""
    fa = np.asarray(f, dtype=np.int64)
    ga = np.asarray(g, dtype=np.int64)
    a, c = fa.shape[0], ga.shape[0]
    out = np.zeros((a + c - 1, a + c - 1), dtype=np.int64)
    for u in range(a):
        for v in range(a):
            out[u:u + c, v:v + c] += fa[u, v] * ga
    return out


def prime_vs_pow2_padding(size: int, kernel: int) -> dict:
    """Paper Sec. I: transform-size overhead of prime vs power-of-two padding."""
    need = size + kernel - 1
    p = next_prime(need)
    pow2 = 1 << max(0, (need - 1).bit_length())
    return {
        "required": need,
        "prime_pad": p,
        "pow2_pad": pow2,
        "prime_overhead": p / need,
        "pow2_overhead": pow2 / need,
    }
