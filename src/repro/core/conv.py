"""Exact fixed-point 2-D convolution via the DPRT convolution property.

The paper's headline application (Sec. I, VI): because the DPRT satisfies a
discrete Fourier-slice theorem, the DPRT of a 2-D *circular* convolution is
the per-direction 1-D circular convolution of the DPRTs:

    R_{f ** g}(m, .) = R_f(m, .) (*)_N R_g(m, .)     for all N+1 directions m

so 2-D convolution = DPRT -> (N+1) independent 1-D circular convolutions ->
inverse DPRT, entirely in integer arithmetic (no floating-point FFT).

All DPRT work routes through the transform-plan dispatch
(:mod:`repro.core.plan`), so ``method`` may be any registered backend
name (including ``"auto"`` and ``"pallas"``), and geometry handling
comes from :mod:`repro.core.geometry`:

* **Linear convolution** of arbitrary rectangular operands zero-pads
  both to the next prime P >= out_h/out_w *per axis* (the paper's
  density-of-primes argument: a power-of-two FFT must pad up to ~2x,
  the next prime is only O(log P) away on average).
* **Blocked linear convolution** (``block_size=``) realizes the
  companion paper's overlap-add scheme (arXiv 2112.13150) on the plan
  layer: the image is tiled into ``block_size``-sized square tiles,
  every tile convolves against the small kernel at the much smaller
  tile prime q = next_prime(block + k - 1) -- one batched fused-kernel
  call over the whole tile stack -- and per-tile results overlap-add
  onto the output canvas (`lax.scan`, one tile live at a time).  Exact
  in integers: tile padding is zeros, and overlap-add of exact tile
  linear convolutions is the exact full linear convolution.
* **Circular convolution** of square prime operands uses the direct
  transform-domain route above; any other (equal) geometry is convolved
  on its true (H, W) torus by folding the exact prime-embedded linear
  convolution (:func:`repro.core.geometry.fold_mod`) -- still bit-exact.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import geometry as G
from .dprt import accum_dtype_for, is_prime, next_prime

__all__ = [
    "circ_conv1d_exact",
    "circ_conv2d_dprt",
    "circ_conv2d_direct",
    "circ_conv2d_fft",
    "linear_conv2d_dprt",
    "linear_conv2d_direct",
    "prime_vs_pow2_padding",
]


def circ_conv1d_exact(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Batched exact 1-D circular convolution along the last axis.

    a, b: (..., N) with broadcastable leading dims.
    out[..., d] = sum_t a[..., t] * b[..., <d-t>_N].  O(N^2) integer
    MACs per row -- these run on the MXU as a matmul with the circulant
    of ``b`` (built by gather once, reused across rows).
    """
    n = a.shape[-1]
    acc = accum_dtype_for(jnp.result_type(a.dtype, b.dtype))
    d = jnp.arange(n)[:, None]
    t = jnp.arange(n)[None, :]
    bc = b.astype(acc)[..., (d - t) % n]  # bc[..., d, t] = b[..., <d-t>_N]
    return jnp.einsum("...t,...dt->...d", a.astype(acc), bc)


def _resolve_knobs(method, strip_rows, m_block) -> tuple:
    """Full ambient-knob snapshot (see ``ambient.snapshot_knobs``),
    taken OUTSIDE the jit boundaries below so the whole scope is part
    of each trace-cache key."""
    from repro.radon import ambient  # lazy: radon imports repro.core
    return ambient.snapshot_knobs(method, strip_rows, m_block)


def _operator(shape, dtype, knobs: tuple):
    """The cached radon operator for one operand geometry."""
    from repro.radon import operator_for  # lazy: radon imports repro.core
    return operator_for(shape, dtype, knobs)


def _circ_prime(f: jnp.ndarray, g: jnp.ndarray,
                knobs: tuple) -> jnp.ndarray:
    """Transform-domain circular convolution of square prime operands."""
    def fwd(x):
        return _operator(x.shape, x.dtype, knobs)(x)

    rf, rg = fwd(f), fwd(g)
    if rg.ndim > rf.ndim:
        # convolution commutes; build the circulant from the unbatched
        # operand so a batched g doesn't materialize a (B, N+1, N, N)
        # circulant (~1 GB at B=16, N=251)
        rf, rg = rg, rf
    if rf.ndim == 3 and rg.ndim == 3:
        if rf.shape[0] != rg.shape[0]:
            raise ValueError(
                f"batched operands need equal batch sizes, got "
                f"{f.shape} vs {g.shape}")
        # both batched: map over the batch so only one (N+1, N, N)
        # circulant is live at a time
        rc = jax.lax.map(lambda ab: circ_conv1d_exact(*ab), (rf, rg))
    else:
        rc = circ_conv1d_exact(rf, rg)      # all N+1 directions at once
    n = rc.shape[-1]
    shape = (n, n) if rc.ndim == 2 else (rc.shape[0], n, n)
    inv = _operator(shape, rc.dtype, knobs).inverse
    return inv(rc)


@functools.partial(jax.jit, static_argnames=("knobs", "block_size"))
def _circ_conv2d_jit(f: jnp.ndarray, g: jnp.ndarray, knobs: tuple,
                     block_size: Optional[int]) -> jnp.ndarray:
    fh, fw = f.shape[-2:]
    if fh == fw and is_prime(fh) and block_size is None:
        return _circ_prime(f, g, knobs)
    lin = _linear_conv2d_jit(f, g, knobs, block_size)
    return G.fold_mod(lin, fh, fw)


def circ_conv2d_dprt(f: jnp.ndarray, g: jnp.ndarray,
                     method: Optional[str] = None,
                     strip_rows: Optional[int] = None,
                     m_block: Optional[int] = None,
                     block_size: Optional[int] = None) -> jnp.ndarray:
    """Exact 2-D circular convolution of equal-geometry integer images.

    Square prime (N, N) operands take the paper's direct transform-
    domain route (either operand may be a batched (B, N, N) stack --
    for ``method="pallas"`` one fused kernel call per stack).  Any
    other (H, W) geometry is convolved on its true (H, W) torus by
    folding the exact prime-embedded linear convolution -- bit-exact
    for integers either way.  ``block_size`` streams the non-native
    path tile-by-tile (overlap-add; see :func:`linear_conv2d_dprt`).
    All DPRT stages run through :mod:`repro.radon` operators; unset
    knobs resolve against the ambient :func:`repro.radon.config` scope.
    """
    fh, fw = f.shape[-2:]
    gh, gw = g.shape[-2:]
    if (fh, fw) != (gh, gw):
        raise ValueError(
            f"circular convolution needs equal operand geometry, got "
            f"{f.shape} vs {g.shape}")
    knobs = _resolve_knobs(method, strip_rows, m_block)
    return _circ_conv2d_jit(f, g, knobs, block_size)


def circ_conv2d_direct(f: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """O(N^4) direct oracle for circular convolution (exact)."""
    n = f.shape[0]
    acc = accum_dtype_for(jnp.result_type(f.dtype, g.dtype))
    i = jnp.arange(n)
    # out[x, y] = sum_{u,v} f[u, v] g[<x-u>, <y-v>]
    gx = g.astype(acc)[(i[:, None] - i[None, :]) % n]          # (x, u, N)
    gxy = gx[:, :, (i[:, None] - i[None, :]) % n]              # (x, u, y, v)
    return jnp.einsum("uv,xuyv->xy", f.astype(acc), gxy)


def circ_conv2d_fft(f: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Floating-point FFT path (the approach the paper's hardware avoids)."""
    out = jnp.fft.ifft2(jnp.fft.fft2(f) * jnp.fft.fft2(g)).real
    if jnp.issubdtype(f.dtype, jnp.integer):
        return jnp.round(out)
    return out


def _linear_conv_blocked(f: jnp.ndarray, g: jnp.ndarray, block: int,
                         knobs: tuple) -> jnp.ndarray:
    """Overlap-add linear convolution on prime-sized tiles.

    ``f``: (…, A_h, A_w) image(s); ``g``: one small (C_h, C_w) kernel.
    Each tile's circular convolution at q = next_prime(block + k - 1)
    IS its full linear convolution (no wraparound: q >= tile + k - 1),
    and the per-tile results overlap-add exactly to the full linear
    convolution -- the companion paper's scalable scheme.
    """
    if g.ndim != 2:
        raise ValueError(
            f"blocked convolution needs a single 2-D kernel, got {g.shape}")
    ah, aw = f.shape[-2:]
    ch, cw = g.shape[-2:]
    block = int(block)
    q = next_prime(block + max(ch, cw) - 1)

    tiles, offsets = G.image_to_tiles(f, block)   # (…, T, block, block)
    tq = G.pad2d(tiles, q - block, q - block)
    gq = G.pad2d(g, q - ch, q - cw)
    rg = _operator(gq.shape, gq.dtype, knobs)(gq)

    t = tq.shape[-3]
    stack = tq.reshape(-1, q, q)                  # (B*T or T, q, q)
    rt = _operator(stack.shape, stack.dtype, knobs)(stack)  # one fused call
    rc = circ_conv1d_exact(rt, rg)                # broadcast over the stack
    inv = _operator((rc.shape[0], q, q), rc.dtype, knobs).inverse
    outs = inv(rc)                                # (B*T or T, q, q)

    oh, ow = block + ch - 1, block + cw - 1       # useful tile output
    tile_out = outs[..., :oh, :ow]
    th, tw = -(-ah // block), -(-aw // block)
    canvas = (th * block + ch - 1, tw * block + cw - 1)

    def assemble(tiles_one):
        return G.overlap_add(tiles_one, offsets, canvas)

    if f.ndim == 3:
        lin = jax.lax.map(assemble,
                          tile_out.reshape(f.shape[0], t, oh, ow))
    else:
        lin = assemble(tile_out)
    return lin[..., : ah + ch - 1, : aw + cw - 1]


@functools.partial(jax.jit, static_argnames=("knobs", "block_size"))
def _linear_conv2d_jit(f: jnp.ndarray, g: jnp.ndarray, knobs: tuple,
                       block_size: Optional[int]) -> jnp.ndarray:
    ah, aw = f.shape[-2:]
    ch, cw = g.shape[-2:]
    out_h, out_w = ah + ch - 1, aw + cw - 1
    if block_size is not None:
        return _linear_conv_blocked(f, g, block_size, knobs)
    p = next_prime(max(out_h, out_w))
    fp = G.pad2d(f, p - ah, p - aw)
    gp = G.pad2d(g, p - ch, p - cw)
    res = _circ_prime(fp, gp, knobs)
    return res[..., :out_h, :out_w]


def linear_conv2d_dprt(f: jnp.ndarray, g: jnp.ndarray,
                       method: Optional[str] = None,
                       strip_rows: Optional[int] = None,
                       m_block: Optional[int] = None,
                       block_size: Optional[int] = None) -> jnp.ndarray:
    """Exact full linear convolution of arbitrary rectangular operands.

    Whole-image route: zero-pad both operands to the next prime that
    covers the full (out_h, out_w) support -- rows and columns padded
    independently, so rectangular inputs are handled exactly.  With
    ``block_size``, the overlap-add route tiles ``f`` into
    ``block_size``-square tiles and convolves each against the (small)
    kernel ``g`` at the tile prime instead of one giant image prime --
    the companion paper's resource-fitting scheme (bounded working set,
    batched tile stack through the plan dispatch).  ``f`` may be a
    (B, H, W) stack in either route.  Unset knobs resolve against the
    ambient :func:`repro.radon.config` scope.
    """
    knobs = _resolve_knobs(method, strip_rows, m_block)
    return _linear_conv2d_jit(f, g, knobs, block_size)


def linear_conv2d_direct(f: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """numpy oracle for full linear convolution (exact, int64)."""
    fa = np.asarray(f, dtype=np.int64)
    ga = np.asarray(g, dtype=np.int64)
    ah, aw = fa.shape
    ch, cw = ga.shape
    out = np.zeros((ah + ch - 1, aw + cw - 1), dtype=np.int64)
    for u in range(ah):
        for v in range(aw):
            out[u:u + ch, v:v + cw] += fa[u, v] * ga
    return out


def prime_vs_pow2_padding(size: int, kernel: int) -> dict:
    """Paper Sec. I: transform-size overhead of prime vs power-of-two padding."""
    need = size + kernel - 1
    p = next_prime(need)
    pow2 = 1 << max(0, (need - 1).bit_length())
    return {
        "required": need,
        "prime_pad": p,
        "pow2_pad": pow2,
        "prime_overhead": p / need,
        "pow2_overhead": pow2 / need,
    }
