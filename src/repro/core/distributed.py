"""Distributed DPRT: the paper's strip decomposition lifted onto a mesh.

The SFDPRT computes per-strip *partial* DPRTs and accumulates them in
MEM_OUT (eq. 8).  Across a TPU pod the same algebra shards: each device
owns a contiguous block of image rows (a "super-strip"), computes its
partial skew-sum locally (Horner shift-and-add, zero inter-device
traffic), applies its alignment roll, and the partial results are
combined with one collective:

* ``psum``          -> every device holds the full (N+1, N) transform
                       (MEM_OUT replicated), or
* ``psum_scatter``  -> each device keeps only its slice of directions
                       (MEM_OUT sharded; 1/devices the collective bytes,
                       the beyond-paper option used by the perf pass).

Image *batches* shard trivially over the data axes on top of this.

This module is registered as the ``"sharded"`` backend in the transform
plan registry (:mod:`repro.core.plan`) -- declared mesh-aware, so
``method="auto"`` routes here whenever a mesh is passed (or an ambient
``with mesh:`` context is active) and every public entry point accepts
``method="sharded", mesh=...`` without importing this module directly.
"""
from __future__ import annotations

import functools
import math
from typing import Literal

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
try:
    from jax import shard_map  # jax >= 0.8
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

from .dprt import (accum_dtype_for, align_partial, is_prime, strip_partial)

__all__ = ["dprt_sharded", "idprt_sharded", "dprt_batch_sharded"]

Reduce = Literal["psum", "psum_scatter"]


def _skew_sum_local(g_local: jnp.ndarray, n: int, sign: int, axis: str,
                    rows_per_dev: int) -> jnp.ndarray:
    """Partial skew-sum of this device's row block, aligned to global rows."""
    r = jax.lax.axis_index(axis)
    u = strip_partial(g_local, n, sign=sign,
                      acc_dtype=accum_dtype_for(g_local.dtype))
    return align_partial(u, r * rows_per_dev, sign=sign)


@functools.partial(jax.jit,
                   static_argnames=("mesh", "axis", "reduce", "sign"))
def _skew_sum_sharded(g: jnp.ndarray, mesh: Mesh, axis: str = "model",
                      reduce: Reduce = "psum", sign: int = 1) -> jnp.ndarray:
    n = g.shape[1]
    devs = mesh.shape[axis]
    rows_per_dev = math.ceil(g.shape[0] / devs)
    gp = jnp.pad(g, ((0, devs * rows_per_dev - g.shape[0]), (0, 0)))

    n_out_pad = math.ceil(n / devs) * devs

    def local(gl):
        part = _skew_sum_local(gl, n, sign, axis, rows_per_dev)
        if reduce == "psum":
            return jax.lax.psum(part, axis)
        part = jnp.pad(part, ((0, n_out_pad - n), (0, 0)))
        return jax.lax.psum_scatter(part, axis, scatter_dimension=0,
                                    tiled=True)

    out_spec = P(None, None) if reduce == "psum" else P(axis, None)
    fn = shard_map(local, mesh=mesh, in_specs=P(axis, None),
                   out_specs=out_spec)
    out = fn(gp)
    return out[:n]


def dprt_sharded(f: jnp.ndarray, mesh: Mesh, axis: str = "model",
                 reduce: Reduce = "psum") -> jnp.ndarray:
    """Forward DPRT of one (N, N) image with rows sharded over ``axis``.

    Returns the (N+1, N) transform; direction rows are sharded over
    ``axis`` when ``reduce='psum_scatter'``, else replicated.
    """
    n = f.shape[0]
    if not is_prime(n):
        raise ValueError(f"DPRT needs prime N, got {n}")
    core = _skew_sum_sharded(f, mesh, axis, reduce, sign=1)
    last = f.astype(accum_dtype_for(f.dtype)).sum(axis=1)
    return jnp.concatenate([core, last[None, :]], axis=0)


def idprt_sharded(r: jnp.ndarray, mesh: Mesh, axis: str = "model",
                  reduce: Reduce = "psum") -> jnp.ndarray:
    """Inverse DPRT with the projection rows sharded over ``axis``."""
    n = r.shape[1]
    if r.shape[0] != n + 1 or not is_prime(n):
        raise ValueError(f"iDPRT input must be (N+1, N), N prime: {r.shape}")
    acc = accum_dtype_for(r.dtype)
    z = _skew_sum_sharded(r[:n], mesh, axis, reduce, sign=-1)
    s = r[0].astype(acc).sum()
    num = z - s + r[n].astype(acc)[:, None]
    if jnp.issubdtype(acc, jnp.integer):
        return num // n
    return num / n


def dprt_batch_sharded(fb: jnp.ndarray, mesh: Mesh,
                       batch_axes=("pod", "data"),
                       method: str = "horner") -> jnp.ndarray:
    """DPRT of a batch of images, batch sharded over the data axes.

    This is the FPGA-coprocessor service pattern of Sec. V-B scaled out:
    every device transforms its own images; no collectives at all.
    """
    from .dprt import dprt_batched  # local import to avoid cycle

    axes = tuple(a for a in batch_axes if a in mesh.shape)
    if not axes:
        # mesh has no data axis to shard the batch over (e.g. a pure
        # "model" mesh): every device computes the full batch locally
        return dprt_batched(fb, method=method)
    sharding = NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0],
                                     None, None))
    fb = jax.lax.with_sharding_constraint(fb, sharding)
    out = dprt_batched(fb, method=method)
    return jax.lax.with_sharding_constraint(
        out, NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0],
                                   None, None)))
