"""Distributed DPRT: the paper's strip decomposition lifted onto a mesh.

The SFDPRT computes per-strip *partial* DPRTs and accumulates them in
MEM_OUT (eq. 8).  Across a TPU pod the same algebra shards: each device
owns a contiguous block of image rows (a "super-strip"), computes its
partial skew-sum locally with zero inter-device traffic, applies its
alignment roll, and the partial results are combined with one
collective:

* ``psum``          -> every device holds the full (N+1, N) transform
                       (MEM_OUT replicated),
* ``psum_scatter``  -> each device keeps only its slice of directions
                       (MEM_OUT sharded; 1/devices the collective bytes,
                       the beyond-paper option used by the perf pass), or
* ``ring``          -> the same direction-sharded result built from an
                       explicit ``ppermute`` (collective_permute) ring:
                       devices exchange one direction chunk per step and
                       accumulate in place, so per-step wire volume is
                       O(N^2 / devices) and never the full transform.

The ``sharded_pallas`` forward now *defaults* to the direction-sharded
layout (``psum_scatter``), and the inverse consumes that layout in
place: its row super-strips are the forward's direction shards
(``ceil((N+1)/devices)`` rows per device, global rows >= N masked
in-shard), so a forward -> inverse round trip re-shards nothing.

Image *batches* shard over the data axes on top of this (2-D
``data x model`` meshes: batch shards over ``data``, row super-strips
over ``model``).

Two shard-local datapaths are registered in the transform plan registry
(:mod:`repro.core.plan`):

* ``"sharded"``         -- the legacy path: per-device Horner
  shift-and-add scan (:func:`repro.core.dprt.strip_partial`) plus an
  explicit alignment gather.
* ``"sharded_pallas"``  -- each device runs the fused SFDPRT Pallas
  kernel (:func:`repro.kernels.skew_sum_pallas_strip`) over its local
  row strip or batch shard: the hoisted roll-select-ladder datapath of
  PR 1 with the device's first global row folded into the alignment
  ladder (one ``pallas_call`` per shard, batched stacks native).  All
  four plan datapaths (forward / inverse / adjoint / inverse_adjoint)
  ride this skew-sum, so ``jax.grad`` and ``op.T`` stay exact through
  the distributed path.  Declared mesh-aware with higher priority than
  ``"sharded"``, so ``method="auto"`` under a mesh resolves here.
"""
from __future__ import annotations

import functools
import math
from typing import Literal, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
try:
    from jax import shard_map  # jax >= 0.8
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

from .dprt import (accum_dtype_for, align_partial, is_prime, strip_partial)

__all__ = [
    "dprt_sharded",
    "idprt_sharded",
    "dprt_batch_sharded",
    "idprt_batch_sharded",
    "skew_sum_sharded_pallas",
    "dprt_sharded_pallas",
    "idprt_sharded_pallas",
    "projection_pipeline_sharded",
    "batch_partition_spec",
]

Reduce = Literal["psum", "psum_scatter", "ring"]

#: axes a batch may shard over (leading mesh axes of the standard
#: production meshes); the row super-strips take the remaining axis.
BATCH_AXES = ("pod", "data")


def _shard_map(fn, mesh, in_specs, out_specs):
    """shard_map without the replication checker: ``pallas_call`` has no
    replication rule (jax asks for ``check_rep=False``), and the psum'd
    outputs below are replicated by construction."""
    try:
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover - newer jax renamed the flag
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def _row_axis(mesh: Mesh) -> str:
    """Row-sharding axis: 'model' if present, else the mesh's last axis
    (leading axes are batch/data axes by convention)."""
    if "model" in mesh.shape:
        return "model"
    return tuple(mesh.shape)[-1]


def _batch_axes(mesh: Mesh, row_axis: str) -> tuple:
    """Data axes a batched stack shards over (never the row axis)."""
    return tuple(a for a in BATCH_AXES
                 if a in mesh.shape and a != row_axis)


def _bspec(baxes: tuple):
    """PartitionSpec entry for a batch dim sharded over ``baxes``."""
    return (baxes if len(baxes) > 1 else baxes[0]) if baxes else None


def batch_partition_spec(mesh: Mesh) -> P:
    """The mesh-natural PartitionSpec of a (B, rows, N) stack: batch over
    the mesh's data axes, rows/lanes unsharded.  The single convention
    point shared by the shard_map in/out specs here and the operator
    layer's AOT input shardings (``RadonOperator.input_sharding``)."""
    return P(_bspec(_batch_axes(mesh, _row_axis(mesh))), None, None)


# ---------------------------------------------------------------------------
# legacy "sharded" backend: per-device Horner scan + alignment gather
# ---------------------------------------------------------------------------
def _skew_sum_local(g_local: jnp.ndarray, n: int, sign: int, axis: str,
                    rows_per_dev: int) -> jnp.ndarray:
    """Partial skew-sum of this device's row block, aligned to global rows."""
    r = jax.lax.axis_index(axis)
    u = strip_partial(g_local, n, sign=sign,
                      acc_dtype=accum_dtype_for(g_local.dtype, n))
    return align_partial(u, r * rows_per_dev, sign=sign)


@functools.partial(jax.jit,
                   static_argnames=("mesh", "axis", "reduce", "sign"))
def _skew_sum_sharded(g: jnp.ndarray, mesh: Mesh, axis: str = "model",
                      reduce: Reduce = "psum", sign: int = 1) -> jnp.ndarray:
    n = g.shape[1]
    devs = mesh.shape[axis]
    rows_per_dev = math.ceil(g.shape[0] / devs)
    gp = jnp.pad(g, ((0, devs * rows_per_dev - g.shape[0]), (0, 0)))

    n_out_pad = math.ceil(n / devs) * devs

    def local(gl):
        part = _skew_sum_local(gl, n, sign, axis, rows_per_dev)
        if reduce == "psum":
            return jax.lax.psum(part, axis)
        part = jnp.pad(part, ((0, n_out_pad - n), (0, 0)))
        return jax.lax.psum_scatter(part, axis, scatter_dimension=0,
                                    tiled=True)

    out_spec = P(None, None) if reduce == "psum" else P(axis, None)
    fn = shard_map(local, mesh=mesh, in_specs=P(axis, None),
                   out_specs=out_spec)
    out = fn(gp)
    return out[:n]


def dprt_sharded(f: jnp.ndarray, mesh: Mesh, axis: str = "model",
                 reduce: Reduce = "psum") -> jnp.ndarray:
    """Forward DPRT of one (N, N) image with rows sharded over ``axis``.

    Returns the (N+1, N) transform; direction rows are sharded over
    ``axis`` when ``reduce='psum_scatter'``, else replicated.
    """
    n = f.shape[0]
    if not is_prime(n):
        raise ValueError(f"DPRT needs prime N, got {n}")
    core = _skew_sum_sharded(f, mesh, axis, reduce, sign=1)
    last = f.astype(accum_dtype_for(f.dtype, n)).sum(axis=1)
    return jnp.concatenate([core, last[None, :]], axis=0)


def idprt_sharded(r: jnp.ndarray, mesh: Mesh, axis: str = "model",
                  reduce: Reduce = "psum") -> jnp.ndarray:
    """Inverse DPRT with the projection rows sharded over ``axis``."""
    n = r.shape[1]
    if r.shape[0] != n + 1 or not is_prime(n):
        raise ValueError(f"iDPRT input must be (N+1, N), N prime: {r.shape}")
    acc = accum_dtype_for(r.dtype, n)
    z = _skew_sum_sharded(r[:n], mesh, axis, reduce, sign=-1)
    s = r[0].astype(acc).sum()
    num = z - s + r[n].astype(acc)[:, None]
    if jnp.issubdtype(acc, jnp.integer):
        return num // n
    return num / n


def _batch_shard(xb: jnp.ndarray, mesh: Mesh, batch_axes) -> tuple:
    """Constrain a stack's leading axis onto the mesh's data axes."""
    axes = tuple(a for a in batch_axes if a in mesh.shape)
    if not axes:
        return xb, None
    spec = P(axes if len(axes) > 1 else axes[0], None, None)
    return jax.lax.with_sharding_constraint(
        xb, NamedSharding(mesh, spec)), spec


def dprt_batch_sharded(fb: jnp.ndarray, mesh: Mesh,
                       batch_axes=BATCH_AXES,
                       method: str = "horner") -> jnp.ndarray:
    """DPRT of a batch of images, batch sharded over the data axes.

    This is the FPGA-coprocessor service pattern of Sec. V-B scaled out:
    every device transforms its own images; no collectives at all.
    """
    from .plan import get_plan  # local import to avoid cycle

    fb, spec = _batch_shard(fb, mesh, batch_axes)
    out = get_plan(fb.shape, fb.dtype, method).forward(fb)
    if spec is None:
        # mesh has no data axis to shard the batch over (e.g. a pure
        # "model" mesh): every device computes the full batch locally
        return out
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, spec))


def idprt_batch_sharded(rb: jnp.ndarray, mesh: Mesh,
                        batch_axes=BATCH_AXES,
                        method: str = "horner") -> jnp.ndarray:
    """Inverse DPRT of a (B, N+1, N) stack, batch sharded over the data
    axes -- the missing mirror of :func:`dprt_batch_sharded`: every
    device reconstructs its own images, no collectives at all."""
    from .plan import get_plan  # local import to avoid cycle

    n = rb.shape[-1]
    if rb.ndim != 3 or rb.shape[-2] != n + 1 or not is_prime(n):
        raise ValueError(
            f"idprt_batch_sharded needs (B, N+1, N), N prime: {rb.shape}")
    rb, spec = _batch_shard(rb, mesh, batch_axes)
    out = get_plan((rb.shape[0], n, n), rb.dtype, method).inverse(rb)
    if spec is None:
        return out
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# "sharded_pallas" backend: per-shard fused SFDPRT kernel + one collective
# ---------------------------------------------------------------------------
def _ring_reduce_scatter(part: jnp.ndarray, axis: str,
                         devs: int) -> jnp.ndarray:
    """Reduce-scatter ``part`` over its row dim (-2) with an explicit
    ``ppermute`` ring instead of ``psum_scatter``.

    Device r ends holding the fully reduced chunk r (identical layout to
    ``psum_scatter(..., tiled=True)``).  Each of the devs-1 steps moves
    ONE chunk (rows/devs of the partial) to the right neighbour and
    accumulates the local contribution for the chunk's eventual owner --
    per-step wire volume is O(N^2 / devs), never the whole transform,
    which is the layout the giant-N streamed kernels need to keep
    per-host memory flat.  Rows of ``part`` must be a devs multiple.
    """
    if devs == 1:
        return part
    rows = part.shape[-2] // devs
    r = jax.lax.axis_index(axis)

    def chunk(i):
        return jax.lax.dynamic_slice_in_dim(part, i * rows, rows, axis=-2)

    perm = [(d, (d + 1) % devs) for d in range(devs)]
    buf = chunk((r - 1) % devs)
    for t in range(devs - 1):
        buf = jax.lax.ppermute(buf, axis, perm)
        buf = buf + chunk((r - t - 2) % devs)
    return buf


def _reduce_partial(part: jnp.ndarray, axis: str, devs: int,
                    out_rows: int, out_pad: int,
                    reduce: str) -> jnp.ndarray:
    """Apply the configured collective to a per-device partial."""
    if reduce == "psum":
        return jax.lax.psum(part, axis)
    ppad = [(0, 0)] * part.ndim
    ppad[-2] = (0, out_pad - out_rows)
    part = jnp.pad(part, ppad)
    if reduce == "ring":
        return _ring_reduce_scatter(part, axis, devs)
    return jax.lax.psum_scatter(part, axis,
                                scatter_dimension=part.ndim - 2,
                                tiled=True)



def _shard_layout(g: jnp.ndarray, mesh: Mesh, axis: Optional[str],
                  batch_axes: Optional[tuple]) -> tuple:
    """The single convention point for laying a (…, rows, N) input onto
    a mesh: resolves the row axis and batch axes, pads rows to a
    devs-multiple and the batch to a data-devices multiple, and returns
    ``(gp, axis, baxes, devs, rows_per_dev, b)`` -- shared by every
    per-shard kernel datapath so the padding rules cannot diverge."""
    batched = g.ndim == 3
    if axis is None:
        axis = _row_axis(mesh)
    baxes = () if not batched else (
        _batch_axes(mesh, axis) if batch_axes is None
        else tuple(a for a in batch_axes if a in mesh.shape and a != axis))
    devs = mesh.shape[axis]
    rows_per_dev = math.ceil(g.shape[-2] / devs)
    pad = [(0, 0)] * g.ndim
    pad[-2] = (0, devs * rows_per_dev - g.shape[-2])
    b = g.shape[0] if batched else None
    if baxes:
        bdevs = math.prod(mesh.shape[a] for a in baxes)
        pad[0] = (0, math.ceil(b / bdevs) * bdevs - b)
    return jnp.pad(g, pad), axis, baxes, devs, rows_per_dev, b
@functools.partial(jax.jit,
                   static_argnames=("mesh", "mode", "sign", "axis",
                                    "batch_axes", "reduce", "strip_rows",
                                    "m_block", "stream_rows",
                                    "mask_rows_from"))
def _sharded_pallas_partials(g: jnp.ndarray, mesh: Mesh, mode: str = "core",
                             sign: int = 1,
                             axis: Optional[str] = None,
                             batch_axes: Optional[tuple] = None,
                             reduce: Reduce = "psum",
                             strip_rows: Optional[int] = None,
                             m_block: Optional[int] = None,
                             stream_rows: Optional[int] = None,
                             mask_rows_from: Optional[int] = None
                             ) -> jnp.ndarray:
    """Shared mesh datapath: per-device fused kernel + one collective.

    Rows of ``g`` (…, rows, N) shard over the mesh's row axis, a batch
    dim over its data axes.  Inside ``shard_map`` every device runs ONE
    fused Pallas kernel call over its local (B_local, rows_per_dev, N)
    block: the hoisted binary roll-select-ladder datapath with the
    device's first global row (``axis_index * rows_per_dev``, a traced
    value) folded into the alignment ladder.  ``mode="core"`` computes
    the bare skew-sum partial; ``mode="forward"`` additionally fuses
    the R(N, d) row-sum epilogue in-kernel at global lane positions, so
    the full forward transform is exactly one kernel + one collective.
    One ``psum`` (replicated MEM_OUT), ``psum_scatter`` (output rows
    stay sharded over the row axis) or ``ring`` (same sharded layout via
    an explicit ppermute ring) assembles eq. 8.

    ``stream_rows`` engages the in-launch streamed strip kernel on each
    shard (still one pallas_call per device; the shard's rows stream
    HBM -> VMEM inside it).  ``mask_rows_from`` zeroes global input rows
    >= the bound in-shard BEFORE the kernel -- how the inverse consumes
    a direction-sharded (dirs-padded) forward layout in place without a
    global slice-and-reshard.
    """
    from repro.kernels.ops import (dprt_pallas_strip,  # no import cycle
                                   skew_sum_pallas_strip)

    n = g.shape[-1]
    out_rows = n + 1 if mode == "forward" else n
    batched = g.ndim == 3
    gp, axis, baxes, devs, rows_per_dev, b = _shard_layout(
        g, mesh, axis, batch_axes)

    out_pad = math.ceil(out_rows / devs) * devs

    def local(gl):
        r = jax.lax.axis_index(axis)
        off = r * rows_per_dev
        if mask_rows_from is not None:
            keep = (off + jnp.arange(gl.shape[-2]) < mask_rows_from)
            gl = jnp.where(keep[:, None], gl, jnp.zeros((), gl.dtype))
        if mode == "forward":
            part = dprt_pallas_strip(gl, row_offset=off,
                                     strip_rows=strip_rows, m_block=m_block,
                                     stream_rows=stream_rows)
        else:
            part = skew_sum_pallas_strip(gl, sign, row_offset=off,
                                         strip_rows=strip_rows,
                                         m_block=m_block,
                                         stream_rows=stream_rows)
        return _reduce_partial(part, axis, devs, out_rows, out_pad, reduce)

    bspec = (_bspec(baxes),) if batched else ()
    row_spec = None if reduce == "psum" else axis
    fn = _shard_map(local, mesh,
                    in_specs=P(*bspec, axis, None),
                    out_specs=P(*bspec, row_spec, None))
    out = fn(gp)[..., :out_rows, :]
    return out[:b] if batched and baxes else out


def skew_sum_sharded_pallas(g: jnp.ndarray, mesh: Mesh, sign: int = 1,
                            axis: Optional[str] = None,
                            batch_axes: Optional[tuple] = None,
                            reduce: Reduce = "psum",
                            strip_rows: Optional[int] = None,
                            m_block: Optional[int] = None,
                            stream_rows: Optional[int] = None) -> jnp.ndarray:
    """skew_sum of (rows, N) -- or a (B, rows, N) stack -- with rows
    sharded over the mesh's row axis and the batch over its data axes;
    one fused Pallas kernel call per device, one collective."""
    return _sharded_pallas_partials(g, mesh, mode="core", sign=sign,
                                    axis=axis, batch_axes=batch_axes,
                                    reduce=reduce, strip_rows=strip_rows,
                                    m_block=m_block, stream_rows=stream_rows)


def dprt_sharded_pallas(f: jnp.ndarray, mesh: Mesh,
                        reduce: Reduce = "psum_scatter",
                        strip_rows: Optional[int] = None,
                        m_block: Optional[int] = None,
                        stream_rows: Optional[int] = None) -> jnp.ndarray:
    """Forward DPRT of (N, N) -- or a (B, N, N) stack -- via the
    per-shard fused kernel: the R(N, d) row-sum epilogue runs in-kernel
    at global lane positions, so the whole distributed forward is one
    pallas_call per device plus one collective.  Default layout is
    direction-sharded (``psum_scatter``): each device keeps only its
    output direction shard, 1/devices the collective bytes of the old
    all-directions ``psum`` assembly (still available as
    ``reduce="psum"``; ``reduce="ring"`` builds the same sharded layout
    from explicit ppermute steps)."""
    n = f.shape[-1]
    if f.shape[-2] != n or not is_prime(n):
        raise ValueError(f"DPRT needs prime (…, N, N), got {f.shape}")
    return _sharded_pallas_partials(f, mesh, mode="forward", reduce=reduce,
                                    strip_rows=strip_rows, m_block=m_block,
                                    stream_rows=stream_rows)


def idprt_sharded_pallas(r: jnp.ndarray, mesh: Mesh,
                         reduce: Reduce = "psum_scatter",
                         strip_rows: Optional[int] = None,
                         m_block: Optional[int] = None,
                         stream_rows: Optional[int] = None) -> jnp.ndarray:
    """Inverse DPRT of (N+1, N) -- or a (B, N+1, N) stack -- via the
    per-shard Pallas path.

    Consumes the forward's direction-sharded layout IN PLACE: the full
    (N+1)-row input (not a [:N] slice) shards over the row axis in the
    same ``ceil((N+1)/devices)``-row chunks ``psum_scatter`` produced,
    and global rows >= N (the R(N, d) row plus dirs padding) are zeroed
    in-shard before the kernel -- algebraically identical to slicing,
    with no cross-device re-shard between a forward and its inverse.
    The -S + R(N, i) and exact divide-by-N epilogue needs the *global*
    sums, so it runs post-collective -- O(N^2) elementwise."""
    n = r.shape[-1]
    if r.shape[-2] != n + 1 or not is_prime(n):
        raise ValueError(
            f"iDPRT input must be (…, N+1, N), N prime: {r.shape}")
    from .plan import _inverse_epilogue  # lazy: no cycle
    z = _sharded_pallas_partials(r, mesh, mode="core", sign=-1,
                                 reduce=reduce, strip_rows=strip_rows,
                                 m_block=m_block, stream_rows=stream_rows,
                                 mask_rows_from=n)
    return _inverse_epilogue(z, r, n)


# ---------------------------------------------------------------------------
# mesh-composed projection-domain pipeline (fused conv / filter)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("mesh", "op", "axis", "batch_axes",
                                    "strip_rows", "m_block", "stream_rows"))
def projection_pipeline_sharded(f: jnp.ndarray, mesh: Mesh, op: str = "conv",
                                operand: Optional[jnp.ndarray] = None,
                                axis: Optional[str] = None,
                                batch_axes: Optional[tuple] = None,
                                strip_rows: Optional[int] = None,
                                m_block: Optional[int] = None,
                                stream_rows: Optional[int] = None
                                ) -> jnp.ndarray:
    """The fused projection pipeline on a mesh: per shard, TWO kernel
    launches with a SINGLE collective between forward and inverse.

    Every O(N^3) stage shards: device r forward-transforms its local row
    super-strip (one fused kernel, eq. 7 alignment at its global row
    offset), a ``psum_scatter`` re-shards the summed projections over
    *directions* -- the one collective between forward and inverse --
    and the per-shard tail kernel applies the per-direction epilogue
    (1-D circular convolution / pointwise multiply) and the inverse
    ladder for its direction shard only.  A final ``psum_scatter`` over
    *image rows* (each device keeps its output row shard -- 1/devices
    the closing-collective bytes of the old full ``psum``) plus the tiny
    -S + R'(N, i) / N correction (whose aux sums ARE psum'd -- 2 rows)
    assembles the reconstruction.

    ``operand``: conv operand as a replicated (N, N) image (its full
    projections are computed once via :func:`dprt_sharded_pallas`) or
    projections/weights (…, N+1, N); a batched operand shards over the
    data axes with the image batch.  Exact for integers, like every
    other datapath here.
    """
    from repro.kernels.ops import (dprt_pallas_strip,   # no import cycle
                                   pipeline_tail_pallas)

    n = f.shape[-1]
    if f.shape[-2] != n or not is_prime(n):
        raise ValueError(f"pipeline needs prime (…, N, N), got {f.shape}")
    acc = accum_dtype_for(f.dtype, n)
    batched = f.ndim == 3
    gp, axis, baxes, devs, rows_per_dev, b = _shard_layout(
        f, mesh, axis, batch_axes)
    dirs_pad = math.ceil((n + 1) / devs) * devs
    dirs_loc = dirs_pad // devs

    wp = None
    w_batched = False
    if op != "none":
        if operand is None:
            raise ValueError(f"pipeline op {op!r} needs an operand")
        if op == "conv" and operand.shape[-2:] == (n, n):
            # one sharded forward (kernel + psum) turns the image operand
            # into its replicated projections
            operand = dprt_sharded_pallas(operand, mesh, reduce="psum",
                                          strip_rows=strip_rows,
                                          m_block=m_block,
                                          stream_rows=stream_rows)
        wp = operand.astype(acc)
        w_batched = wp.ndim == 3 and batched and wp.shape[0] == f.shape[0]
        if w_batched and baxes:
            bdevs = math.prod(mesh.shape[a] for a in baxes)
            wpad = [(0, math.ceil(b / bdevs) * bdevs - b), (0, 0), (0, 0)]
            wp = jnp.pad(wp, wpad)
        elif wp.ndim == 3 and not w_batched:
            if wp.shape[0] != 1:    # same contract as the unsharded path
                raise ValueError(
                    f"batched pipeline operand must match the stack batch "
                    f"({f.shape[0] if batched else 'unbatched'}), got "
                    f"{operand.shape}")
            wp = wp[0]

    bspec = (_bspec(baxes),) if batched else ()
    img_pad = math.ceil(n / devs) * devs

    def local(gl, wl):
        r = jax.lax.axis_index(axis)
        part = dprt_pallas_strip(gl, row_offset=r * rows_per_dev,
                                 strip_rows=strip_rows, m_block=m_block,
                                 stream_rows=stream_rows)
        ppad = [(0, 0)] * part.ndim
        ppad[-2] = (0, dirs_pad - (n + 1))
        part = jnp.pad(part, ppad)
        # collective ONE of two: re-shard the summed projections over
        # directions (1/devs the bytes of a full psum)
        rc_loc = jax.lax.psum_scatter(part, axis,
                                      scatter_dimension=part.ndim - 2,
                                      tiled=True)
        z, aux = pipeline_tail_pallas(rc_loc, op, wl,
                                      row_offset=r * dirs_loc, n=n,
                                      m_block=None)
        # collective TWO: scatter the reconstruction over image rows --
        # each device keeps only its output row shard (the aux rows the
        # deferred correction needs really are global sums, but they are
        # 2 rows: psum them)
        zpad = [(0, 0)] * z.ndim
        zpad[-2] = (0, img_pad - n)
        z_loc = jax.lax.psum_scatter(jnp.pad(z, zpad), axis,
                                     scatter_dimension=z.ndim - 2,
                                     tiled=True)
        return z_loc, jax.lax.psum(aux, axis)

    if op == "none":
        def local1(gl):
            return local(gl, None)
        fn = _shard_map(local1, mesh,
                        in_specs=P(*bspec, axis, None),
                        out_specs=(P(*bspec, axis, None),
                                   P(*bspec, None, None)))
        z, aux = fn(gp)
    else:
        wspec = P(_bspec(baxes), None, None) if w_batched else P(None, None)
        fn = _shard_map(local, mesh,
                        in_specs=(P(*bspec, axis, None), wspec),
                        out_specs=(P(*bspec, axis, None),
                                   P(*bspec, None, None)))
        z, aux = fn(gp, wp)

    if batched and baxes:
        z, aux = z[:b], aux[:b]
    # deferred correction: needs the globally summed Z / aux rows
    s = aux[..., 0, :n].sum(axis=-1)[..., None, None]
    cn = aux[..., 1, :n][..., :, None]
    num = z[..., :n, :n] - s + cn
    if jnp.issubdtype(acc, jnp.integer):
        return num // n
    return num / n
