"""Discrete Periodic Radon Transform (DPRT) and its exact inverse.

Implements the transforms of Carranza/Llamocca/Pattichis in three
strategies that mirror the paper's architecture space:

* ``gather``  -- per-direction shear via ``take_along_axis`` (the "memory
  indexing" formulation the paper's hardware *avoids*; kept as oracle and
  as the systolic-architecture analog).
* ``horner``  -- the paper's shift-and-add dataflow: a Horner recurrence
  over image rows where each step circularly shifts the accumulator and
  adds one row (CLS registers + adder trees, Sec. III-B).
* ``strips``  -- the scalable SFDPRT (Sec. III-A/B): the image is split
  into K = ceil(N/H) strips of H rows, each strip produces a *partial*
  DPRT via the Horner recurrence, and partial results are aligned
  (one circular roll) and accumulated -- eq. (7)-(8) of the paper.
* ``pallas``  -- the fused, batched Pallas TPU kernel family
  (:mod:`repro.kernels`): the strip decomposition mapped onto a
  (batch, m-block, strip) grid with hoisted binary roll-select ladders
  and the forward/inverse epilogues fused in-kernel; block shapes come
  from the ``repro.kernels.tuning`` table unless given explicitly.

All integer inputs are transformed with exact fixed-point arithmetic
(the paper's motivation vs. floating-point FFTs); the inverse divides by
N exactly and ``idprt(dprt(f)) == f`` holds bit-for-bit.

Definitions (N prime):

    R(m,d) = sum_i f(i, <d + m*i>_N)    0 <= m < N
    R(N,d) = sum_j f(d, j)

    f(i,j) = (1/N) [ sum_m R(m, <j - m*i>_N) - S + R(N,i) ]
"""
from __future__ import annotations

import functools
import math
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

Method = Literal["gather", "horner", "strips", "pallas"]

__all__ = [
    "is_prime",
    "next_prime",
    "dprt",
    "idprt",
    "dprt_batched",
    "idprt_batched",
    "skew_sum",
    "strip_partial",
    "align_partial",
    "accum_dtype_for",
]


# ---------------------------------------------------------------------------
# primes
# ---------------------------------------------------------------------------
def is_prime(n: int) -> bool:
    if n < 2:
        return False
    if n < 4:
        return True
    if n % 2 == 0:
        return False
    f = 3
    while f * f <= n:
        if n % f == 0:
            return False
        f += 2
    return True


def next_prime(n: int) -> int:
    """Smallest prime >= n."""
    while not is_prime(n):
        n += 1
    return n


def _check_square_prime(shape) -> int:
    if len(shape) != 2 or shape[0] != shape[1]:
        raise ValueError(f"DPRT needs a square image, got {shape}")
    n = shape[0]
    if not is_prime(n):
        raise ValueError(f"DPRT needs prime N, got N={n}")
    return n


def accum_dtype_for(dtype) -> jnp.dtype:
    """Accumulator dtype with enough headroom for exact sums.

    Forward growth is +ceil(log2 N) bits; inverse adds another
    ceil(log2 N) (paper Sec. IV-B).  For 8-bit pixels the inverse
    intermediates scale as 255*N^2, so int32 stays exact up to prime
    N <= 2897 (every tuned/benchmarked size, table max N=1021); for
    larger N pass int64 inputs under x64 (int64 inputs stay int64).
    """
    dtype = jnp.dtype(dtype)
    if dtype in (jnp.int64, jnp.uint64):
        return jnp.dtype(jnp.int64)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.dtype(jnp.int32)
    if dtype == jnp.float64:
        return jnp.dtype(jnp.float64)
    return jnp.dtype(jnp.float32)


# ---------------------------------------------------------------------------
# the skew-sum primitive
#
#   skew_sum(g, sign)[m, d] = sum_i g(i, <d + sign*m*i>_N)
#
# Forward DPRT core is sign=+1 applied to the image; the inverse core
# (sum over m of R(m, <j - i*m>)) is sign=-1 applied to R[:N].
# ---------------------------------------------------------------------------
def _step_indices(n: int, sign: int) -> jnp.ndarray:
    """idx[m, d] = <d + sign*m>_N : one Horner step's shift per direction."""
    m = jnp.arange(n, dtype=jnp.int32)[:, None]
    d = jnp.arange(n, dtype=jnp.int32)[None, :]
    return (d + sign * m) % n


def _skew_sum_gather(g: jnp.ndarray, sign: int, block_m: int = 32) -> jnp.ndarray:
    """Oracle/systolic analog: one shear (gather) per direction, then sum."""
    n = g.shape[0]
    acc_dtype = accum_dtype_for(g.dtype)
    gacc = g.astype(acc_dtype)
    i = jnp.arange(n, dtype=jnp.int32)[:, None]
    d = jnp.arange(n, dtype=jnp.int32)[None, :]

    def one_direction(m):
        idx = (d + sign * m * i) % n
        return jnp.take_along_axis(gacc, idx, axis=1).sum(axis=0)

    ms = jnp.arange(n, dtype=jnp.int32)
    return jax.lax.map(one_direction, ms, batch_size=min(block_m, n))


def _horner_scan(strip: jnp.ndarray, n: int, sign: int,
                 acc_dtype) -> jnp.ndarray:
    """Horner recurrence over the rows of ``strip`` (shape (H, N)).

    Returns U[m, d] = sum_{i<H} strip(i, <d + sign*m*i>_N), for all N
    directions m.  Each scan step is the paper's single clock cycle:
    circularly shift the (direction x d) accumulator by one step of m
    and add the next row.
    """
    idx = _step_indices(n, sign)

    def step(t, row):
        t = jnp.take_along_axis(t, idx, axis=1) + row[None, :]
        return t, None

    rows = strip[::-1].astype(acc_dtype)  # process bottom row first (T_H = 0)
    # zeros derived from the data so the carry inherits any shard_map
    # varying-axis annotations (required for scan under shard_map).
    t0 = jnp.zeros((n, n), acc_dtype) + (rows[0] * 0)[None, :]
    t, _ = jax.lax.scan(step, t0, rows)
    return t


def _skew_sum_horner(g: jnp.ndarray, sign: int) -> jnp.ndarray:
    n = g.shape[0]
    return _horner_scan(g, n, sign, accum_dtype_for(g.dtype))


def strip_partial(strip: jnp.ndarray, n: int, sign: int = 1,
                  acc_dtype=None) -> jnp.ndarray:
    """Partial skew-sum of one strip (paper eq. (7), before alignment)."""
    if acc_dtype is None:
        acc_dtype = accum_dtype_for(strip.dtype)
    return _horner_scan(strip, n, sign, acc_dtype)


def align_partial(u: jnp.ndarray, row_offset, sign: int = 1) -> jnp.ndarray:
    """Align a strip's partial result: R'(r,m,d) = U_r(<d + sign*m*rH>_N).

    ``row_offset`` is the strip's first global row (r*H); it may be a
    traced scalar (used by the shard_map distributed path).
    """
    n = u.shape[1]
    m = jnp.arange(n, dtype=jnp.int32)[:, None]
    d = jnp.arange(n, dtype=jnp.int32)[None, :]
    idx = (d + sign * m * jnp.asarray(row_offset, jnp.int32)) % n
    return jnp.take_along_axis(u, idx, axis=1)


def _skew_sum_strips(g: jnp.ndarray, sign: int, strip_rows: int) -> jnp.ndarray:
    """The scalable strip decomposition (paper eq. (5)-(8))."""
    n = g.shape[0]
    h = int(strip_rows)
    if not (1 <= h <= n):
        raise ValueError(f"strip_rows must be in [1, {n}], got {h}")
    k = math.ceil(n / h)
    acc_dtype = accum_dtype_for(g.dtype)
    pad = k * h - n
    gp = jnp.pad(g, ((0, pad), (0, 0)))  # zero rows contribute nothing
    strips = gp.reshape(k, h, n)

    partial = jax.vmap(lambda s: _horner_scan(s, n, sign, acc_dtype))(strips)
    offsets = jnp.arange(k, dtype=jnp.int32) * h
    aligned = jax.vmap(lambda u, off: align_partial(u, off, sign))(partial,
                                                                   offsets)
    return aligned.sum(axis=0)  # MEM_OUT accumulation, eq. (8)


def skew_sum(g: jnp.ndarray, sign: int, method: Method = "horner",
             strip_rows: Optional[int] = None,
             m_block: Optional[int] = None) -> jnp.ndarray:
    """skew_sum(g, sign)[m, d] = sum_i g(i, <d + sign*m*i>_N)."""
    if method == "gather":
        return _skew_sum_gather(g, sign)
    if method == "horner":
        return _skew_sum_horner(g, sign)
    if method == "strips":
        if strip_rows is None:
            raise ValueError("strips method requires strip_rows (H)")
        return _skew_sum_strips(g, sign, strip_rows)
    if method == "pallas":
        from repro.kernels.ops import skew_sum_pallas  # lazy: no cycle
        return skew_sum_pallas(g, sign, strip_rows=strip_rows,
                               m_block=m_block)
    raise ValueError(f"unknown method {method!r}")


# ---------------------------------------------------------------------------
# public transforms
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("method", "strip_rows", "m_block"))
def dprt(f: jnp.ndarray, method: Method = "horner",
         strip_rows: Optional[int] = None,
         m_block: Optional[int] = None) -> jnp.ndarray:
    """Forward DPRT: (N, N) image -> (N+1, N) projections. Exact for ints.

    ``method="pallas"`` runs the fused TPU kernel (R(N, d) row produced
    in-kernel, not as a separate pass); ``m_block`` is pallas-only.
    """
    n = _check_square_prime(f.shape)
    if method == "pallas":
        from repro.kernels.ops import dprt_pallas  # lazy: no import cycle
        return dprt_pallas(f, strip_rows=strip_rows, m_block=m_block)
    acc_dtype = accum_dtype_for(f.dtype)
    core = skew_sum(f, +1, method=method, strip_rows=strip_rows)
    last = f.astype(acc_dtype).sum(axis=1)  # R(N, d) = sum_j f(d, j)
    return jnp.concatenate([core, last[None, :]], axis=0)


@functools.partial(jax.jit,
                   static_argnames=("method", "strip_rows", "m_block"))
def idprt(r: jnp.ndarray, method: Method = "horner",
          strip_rows: Optional[int] = None,
          m_block: Optional[int] = None) -> jnp.ndarray:
    """Inverse DPRT: (N+1, N) projections -> (N, N) image.

    Exact integer reconstruction: the bracketed sum is always divisible
    by N (property-tested), so integer inputs round-trip bit-for-bit.
    ``method="pallas"`` fuses the -S + R(N, i) correction and the exact
    divide into the kernel's final strip; ``m_block`` is pallas-only.
    """
    if r.ndim != 2 or r.shape[0] != r.shape[1] + 1:
        raise ValueError(f"iDPRT input must be (N+1, N), got {r.shape}")
    n = r.shape[1]
    if not is_prime(n):
        raise ValueError(f"iDPRT needs prime N, got N={n}")
    if method == "pallas":
        from repro.kernels.ops import idprt_pallas  # lazy: no import cycle
        return idprt_pallas(r, strip_rows=strip_rows, m_block=m_block)
    acc_dtype = accum_dtype_for(r.dtype)
    z = skew_sum(r[:n], -1, method=method, strip_rows=strip_rows)
    s = r[0].astype(acc_dtype).sum()            # S = total pixel sum (eq. 4)
    num = z - s + r[n].astype(acc_dtype)[:, None]  # + R(N, i) on row i
    if jnp.issubdtype(acc_dtype, jnp.integer):
        return num // n
    return num / n


def dprt_batched(f: jnp.ndarray, method: Method = "horner",
                 strip_rows: Optional[int] = None,
                 batch_impl: str = "auto",
                 m_block: Optional[int] = None) -> jnp.ndarray:
    """Batched :func:`dprt` over a leading axis.

    ``method="pallas"`` transforms the whole (B, N, N) stack in ONE
    fused pallas_call (leading batch grid dimension -- the paper's
    Sec. V-B coprocessor throughput scenario); ``batch_impl`` is ignored
    there.  Otherwise ``batch_impl``: 'vmap' | 'map' | 'auto'.  Measured
    (EXPERIMENTS.md §Perf): on CPU, ``lax.map`` hits the 16x-single ideal
    while vmap pays +60% (the vmapped scan broadcasts its gather indices
    and blows the L2 working set); on TPU vmap vectorizes across the
    batch and wins.
    """
    if method == "pallas":
        if f.ndim != 3:  # other methods raise via dprt(); match them
            raise ValueError(f"dprt_batched needs (B, N, N), got {f.shape}")
        from repro.kernels.ops import dprt_pallas  # lazy: no import cycle
        return dprt_pallas(f, strip_rows=strip_rows, m_block=m_block)
    fn = lambda x: dprt(x, method=method, strip_rows=strip_rows)
    if batch_impl == "auto":
        batch_impl = "map" if jax.default_backend() == "cpu" else "vmap"
    if batch_impl == "map":
        return jax.lax.map(fn, f)
    return jax.vmap(fn)(f)


def idprt_batched(r: jnp.ndarray, method: Method = "horner",
                  strip_rows: Optional[int] = None,
                  batch_impl: str = "auto",
                  m_block: Optional[int] = None) -> jnp.ndarray:
    if method == "pallas":
        if r.ndim != 3:  # other methods raise via idprt(); match them
            raise ValueError(
                f"idprt_batched needs (B, N+1, N), got {r.shape}")
        from repro.kernels.ops import idprt_pallas  # lazy: no import cycle
        return idprt_pallas(r, strip_rows=strip_rows, m_block=m_block)
    fn = lambda x: idprt(x, method=method, strip_rows=strip_rows)
    if batch_impl == "auto":
        batch_impl = "map" if jax.default_backend() == "cpu" else "vmap"
    if batch_impl == "map":
        return jax.lax.map(fn, r)
    return jax.vmap(fn)(r)


# ---------------------------------------------------------------------------
# numpy oracle (used by tests; deliberately independent of the jax paths)
# ---------------------------------------------------------------------------
def dprt_oracle_np(f: np.ndarray) -> np.ndarray:
    n = f.shape[0]
    assert f.shape == (n, n) and is_prime(n)
    out = np.zeros((n + 1, n), dtype=np.int64)
    cols = np.arange(n)
    for m in range(n):
        for i in range(n):
            out[m] += f[i, (cols + m * i) % n].astype(np.int64)
    out[n] = f.sum(axis=1)
    return out


def idprt_oracle_np(r: np.ndarray) -> np.ndarray:
    n = r.shape[1]
    assert r.shape == (n + 1, n) and is_prime(n)
    s = int(r[0].sum())
    f = np.zeros((n, n), dtype=np.int64)
    cols = np.arange(n)
    for i in range(n):
        z = np.zeros(n, dtype=np.int64)
        for m in range(n):
            z += r[m, (cols - m * i) % n].astype(np.int64)
        f[i] = (z - s + int(r[n, i]))
    assert (f % n == 0).all(), "inverse DPRT numerator must be divisible by N"
    return f // n
