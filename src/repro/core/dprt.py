"""Discrete Periodic Radon Transform (DPRT) and its exact inverse.

Implements the transforms of Carranza/Llamocca/Pattichis in three
strategies that mirror the paper's architecture space:

* ``gather``  -- per-direction shear via ``take_along_axis`` (the "memory
  indexing" formulation the paper's hardware *avoids*; kept as oracle and
  as the systolic-architecture analog).
* ``horner``  -- the paper's shift-and-add dataflow: a Horner recurrence
  over image rows where each step circularly shifts the accumulator and
  adds one row (CLS registers + adder trees, Sec. III-B).
* ``strips``  -- the scalable SFDPRT (Sec. III-A/B): the image is split
  into K = ceil(N/H) strips of H rows, each strip produces a *partial*
  DPRT via the Horner recurrence, and partial results are aligned
  (one circular roll) and accumulated -- eq. (7)-(8) of the paper.
* ``pallas``  -- the fused, batched Pallas TPU kernel family
  (:mod:`repro.kernels`): the strip decomposition mapped onto a
  (batch, m-block, strip) grid with hoisted binary roll-select ladders
  and the forward/inverse epilogues fused in-kernel; block shapes come
  from the ``repro.kernels.tuning`` table unless given explicitly.
* ``sharded`` / ``sharded_pallas`` -- the shard_map super-strip paths
  (:mod:`repro.core.distributed`); need ``mesh=``.  ``sharded_pallas``
  runs the fused Pallas kernel per device shard (one kernel call + one
  collective) and is the ``method="auto"`` pick under a mesh.

Method dispatch lives in :mod:`repro.core.plan` (the backend registry);
this module owns the transform *primitives* (Horner scans, strip
partials, alignment rolls) that the registered backends are built from,
plus the thin public entry points.  ``method="auto"`` picks the best
registered backend for the call site.

Inputs may be any ``(H, W)`` or ``(B, H, W)`` geometry: non-square or
non-prime images are zero-embedded into the smallest prime
``P >= max(H, W)`` (see :mod:`repro.core.geometry`), so :func:`dprt`
returns ``(P+1, P)`` projections.  The pad metadata is recorded on the
cached plan -- ``plan.inverse(plan.forward(f)) == f`` bit-exactly for
any integer image (:func:`repro.core.plan.get_plan`).

All integer inputs are transformed with exact fixed-point arithmetic
(the paper's motivation vs. floating-point FFTs); the inverse divides by
N exactly and ``idprt(dprt(f)) == f`` holds bit-for-bit.

Definitions (N prime):

    R(m,d) = sum_i f(i, <d + m*i>_N)    0 <= m < N
    R(N,d) = sum_j f(d, j)

    f(i,j) = (1/N) [ sum_m R(m, <j - m*i>_N) - S + R(N,i) ]
"""
from __future__ import annotations

import math
from typing import Literal, Optional

import jax
import jax.numpy as jnp
import numpy as np

Method = Literal["auto", "gather", "horner", "strips", "pallas", "sharded",
                 "sharded_pallas"]

__all__ = [
    "is_prime",
    "next_prime",
    "dprt",
    "idprt",
    "dprt_batched",
    "idprt_batched",
    "skew_sum",
    "strip_partial",
    "align_partial",
    "accum_dtype_for",
    "float_dtype_for",
    "int32_accum_exact",
]


# ---------------------------------------------------------------------------
# primes
# ---------------------------------------------------------------------------
def is_prime(n: int) -> bool:
    if n < 2:
        return False
    if n < 4:
        return True
    if n % 2 == 0:
        return False
    f = 3
    while f * f <= n:
        if n % f == 0:
            return False
        f += 2
    return True


def next_prime(n: int) -> int:
    """Smallest prime >= n."""
    while not is_prime(n):
        n += 1
    return n


#: worst-case growth of the exact inverse intermediates: for pixels of
#: magnitude <= v the CRS core Z is <= v*N^2 (N direction rows, each an
#: N-term sum of <= v*N projections... bounded by v*N per row) and the
#: -S + R(N, i) correction adds up to v*N more, so |Z - S + R(N, i)| <=
#: v*N*(N+1).  Forward-only growth is just v*N (one N-term sum).
_INT32_MAX = 2**31 - 1
_X64_WARNED = False


def int32_accum_exact(n: int, dtype) -> bool:
    """True when an int32 accumulator provably cannot overflow the
    inverse's ``v*N*(N+1)`` worst case for full-range pixels of this
    integer dtype at transform size N (prime).

    ``v`` is the dtype's max magnitude: for uint8 (v=255) the bound
    gives N*(N+1) <= (2^31-1)/255, i.e. int32 stays exact up to prime
    N <= 2897 -- and FAILS at the next prime 2903 (255*2903*2904 >
    2^31).  For int16 (v=32767) the cliff is already at N=257.
    """
    dtype = jnp.dtype(dtype)
    if not jnp.issubdtype(dtype, jnp.integer):
        raise TypeError(f"int32_accum_exact is an integer bound: {dtype}")
    info = jnp.iinfo(dtype)
    v = max(int(info.max), -int(info.min))
    return v * n * (n + 1) <= _INT32_MAX


def accum_dtype_for(dtype, n: Optional[int] = None, *,
                    warn: bool = True) -> jnp.dtype:
    """Accumulator dtype with enough headroom for exact sums.

    Forward growth is +ceil(log2 N) bits; inverse adds another
    ceil(log2 N) plus the -S + R(N, i) correction (paper Sec. IV-B),
    so the worst intermediate for pixels of magnitude <= v is
    ``v*N*(N+1)`` (:func:`int32_accum_exact`).  For 8-bit pixels int32
    therefore stays exact up to prime N <= 2897; int16 pixels already
    need promotion at N >= 257.

    When the transform size ``n`` is given, *narrow* integer inputs
    (int8/uint8/int16/uint16 -- dtypes whose full range is a true pixel
    bound) are promoted to int64 whenever the int32 bound fails, so the
    giant-N geometries (N >= 2903 for 8-bit data) stay exact under x64.
    int32/uint32 inputs keep the int32 accumulator regardless (their
    dtype max is not a pixel bound; pass int64 inputs under x64 for a
    guarantee, as before).  Without ``n`` the legacy dtype-only rule
    applies unchanged.

    ``warn=False`` suppresses the no-x64 warning: call sites that only
    need the accumulator's *itemsize* for block sizing (plan build,
    kernel tuning) or its name for metadata must not claim an overflow
    that no integer accumulation will ever hit -- e.g. a solver that
    promotes the same geometry to float residual arithmetic
    (:func:`float_dtype_for`) before any sum runs.
    """
    dtype = jnp.dtype(dtype)
    if dtype in (jnp.int64, jnp.uint64):
        return jnp.dtype(jnp.int64)
    if jnp.issubdtype(dtype, jnp.integer):
        if (n is not None and dtype.itemsize < 4
                and not int32_accum_exact(int(n), dtype)):
            if jax.config.jax_enable_x64:
                return jnp.dtype(jnp.int64)
            global _X64_WARNED
            if warn and not _X64_WARNED:  # pragma: no cover - x64 flag
                _X64_WARNED = True
                import warnings
                warnings.warn(
                    f"{dtype.name} pixels at N={n} exceed the int32 "
                    f"accumulator bound v*N*(N+1) <= 2^31-1 but x64 is "
                    f"disabled; enable jax_enable_x64 for an exact int64 "
                    f"accumulator (falling back to int32, sums may "
                    f"overflow)", stacklevel=2)
        return jnp.dtype(jnp.int32)
    if dtype == jnp.float64:
        return jnp.dtype(jnp.float64)
    return jnp.dtype(jnp.float32)


def float_dtype_for(dtype) -> jnp.dtype:
    """Float dtype for residual/solver arithmetic over ``dtype`` data.

    Iterative reconstruction (:mod:`repro.radon.solve`) runs CG/LSQR/
    Landweber residual updates in floating point regardless of the
    sinogram's storage dtype: float64 stays float64; 64-bit integers
    promote to float64 when x64 is enabled (their magnitudes exceed a
    float32 mantissa); everything else -- float32/16 and all the pixel
    integer dtypes -- solves in float32.  Integer inputs never route
    through the integer-accumulator rules, so the int64-under-x64
    warning of :func:`accum_dtype_for` cannot fire for a solve.
    """
    dtype = jnp.dtype(dtype)
    if dtype == jnp.float64:
        return jnp.dtype(jnp.float64)
    if (jnp.issubdtype(dtype, jnp.integer) and dtype.itemsize >= 8
            and jax.config.jax_enable_x64):
        return jnp.dtype(jnp.float64)
    return jnp.dtype(jnp.float32)


# ---------------------------------------------------------------------------
# the skew-sum primitive
#
#   skew_sum(g, sign)[m, d] = sum_i g(i, <d + sign*m*i>_N)
#
# Forward DPRT core is sign=+1 applied to the image; the inverse core
# (sum over m of R(m, <j - i*m>)) is sign=-1 applied to R[:N].
# ---------------------------------------------------------------------------
def _step_indices(n: int, sign: int) -> jnp.ndarray:
    """idx[m, d] = <d + sign*m>_N : one Horner step's shift per direction."""
    m = jnp.arange(n, dtype=jnp.int32)[:, None]
    d = jnp.arange(n, dtype=jnp.int32)[None, :]
    return (d + sign * m) % n


def _skew_sum_gather(g: jnp.ndarray, sign: int, block_m: int = 32) -> jnp.ndarray:
    """Oracle/systolic analog: one shear (gather) per direction, then sum."""
    n = g.shape[0]
    acc_dtype = accum_dtype_for(g.dtype, n)
    gacc = g.astype(acc_dtype)
    i = jnp.arange(n, dtype=jnp.int32)[:, None]
    d = jnp.arange(n, dtype=jnp.int32)[None, :]

    def one_direction(m):
        idx = (d + sign * m * i) % n
        return jnp.take_along_axis(gacc, idx, axis=1).sum(axis=0)

    ms = jnp.arange(n, dtype=jnp.int32)
    return jax.lax.map(one_direction, ms, batch_size=min(block_m, n))


def _horner_scan(strip: jnp.ndarray, n: int, sign: int,
                 acc_dtype) -> jnp.ndarray:
    """Horner recurrence over the rows of ``strip`` (shape (H, N)).

    Returns U[m, d] = sum_{i<H} strip(i, <d + sign*m*i>_N), for all N
    directions m.  Each scan step is the paper's single clock cycle:
    circularly shift the (direction x d) accumulator by one step of m
    and add the next row.
    """
    idx = _step_indices(n, sign)

    def step(t, row):
        t = jnp.take_along_axis(t, idx, axis=1) + row[None, :]
        return t, None

    rows = strip[::-1].astype(acc_dtype)  # process bottom row first (T_H = 0)
    # zeros derived from the data so the carry inherits any shard_map
    # varying-axis annotations (required for scan under shard_map).
    t0 = jnp.zeros((n, n), acc_dtype) + (rows[0] * 0)[None, :]
    t, _ = jax.lax.scan(step, t0, rows)
    return t


def _skew_sum_horner(g: jnp.ndarray, sign: int) -> jnp.ndarray:
    n = g.shape[0]
    return _horner_scan(g, n, sign, accum_dtype_for(g.dtype, n))


def strip_partial(strip: jnp.ndarray, n: int, sign: int = 1,
                  acc_dtype=None) -> jnp.ndarray:
    """Partial skew-sum of one strip (paper eq. (7), before alignment)."""
    if acc_dtype is None:
        acc_dtype = accum_dtype_for(strip.dtype, n)
    return _horner_scan(strip, n, sign, acc_dtype)


def align_partial(u: jnp.ndarray, row_offset, sign: int = 1) -> jnp.ndarray:
    """Align a strip's partial result: R'(r,m,d) = U_r(<d + sign*m*rH>_N).

    ``row_offset`` is the strip's first global row (r*H); it may be a
    traced scalar (used by the shard_map distributed path).
    """
    n = u.shape[1]
    m = jnp.arange(n, dtype=jnp.int32)[:, None]
    d = jnp.arange(n, dtype=jnp.int32)[None, :]
    idx = (d + sign * m * jnp.asarray(row_offset, jnp.int32)) % n
    return jnp.take_along_axis(u, idx, axis=1)


def _skew_sum_strips(g: jnp.ndarray, sign: int, strip_rows: int) -> jnp.ndarray:
    """The scalable strip decomposition (paper eq. (5)-(8))."""
    n = g.shape[0]
    h = int(strip_rows)
    if not (1 <= h <= n):
        raise ValueError(f"strip_rows must be in [1, {n}], got {h}")
    k = math.ceil(n / h)
    acc_dtype = accum_dtype_for(g.dtype, n)
    pad = k * h - n
    gp = jnp.pad(g, ((0, pad), (0, 0)))  # zero rows contribute nothing
    strips = gp.reshape(k, h, n)

    partial = jax.vmap(lambda s: _horner_scan(s, n, sign, acc_dtype))(strips)
    offsets = jnp.arange(k, dtype=jnp.int32) * h
    aligned = jax.vmap(lambda u, off: align_partial(u, off, sign))(partial,
                                                                   offsets)
    return aligned.sum(axis=0)  # MEM_OUT accumulation, eq. (8)


def skew_sum(g: jnp.ndarray, sign: int, method: Method = "horner",
             strip_rows: Optional[int] = None,
             m_block: Optional[int] = None, mesh=None) -> jnp.ndarray:
    """skew_sum(g, sign)[m, d] = sum_i g(i, <d + sign*m*i>_N).

    Routed through the backend registry (:mod:`repro.core.plan`); any
    registered method name (or ``"auto"``) is accepted.
    """
    from .plan import dispatch_skew_sum  # lazy: plan imports this module
    return dispatch_skew_sum(g, sign, method=method, strip_rows=strip_rows,
                             m_block=m_block, mesh=mesh)


# ---------------------------------------------------------------------------
# public transforms: thin deprecation shims over repro.radon operators
#
# The per-call kwarg surface below predates the operator API; it now
# resolves its knobs (explicit > ambient radon.config scope > legacy
# default) and routes through the SAME cached, differentiable,
# trace-counted appliers as `radon.DPRT(...)`.  New code should build
# operators instead -- these wrappers warn once per process when the
# legacy knob plumbing is used.
# ---------------------------------------------------------------------------
_LEGACY_KNOB_WARNED = False


def _warn_legacy_knobs() -> None:
    global _LEGACY_KNOB_WARNED
    if _LEGACY_KNOB_WARNED:
        return
    _LEGACY_KNOB_WARNED = True
    import sys
    import warnings
    # point the warning at the caller's code, not at this module's
    # internals: skip however many shim frames (dprt -> dprt_batched
    # etc.) sit between here and the first out-of-module frame
    stacklevel, frame = 1, sys._getframe()
    while frame is not None and frame.f_code.co_filename == __file__:
        frame = frame.f_back
        stacklevel += 1
    warnings.warn(
        "passing method=/strip_rows=/m_block=/... per call to "
        "repro.core.dprt functions is deprecated: build an operator once "
        "with repro.radon.DPRT(shape, dtype, method=..., ...) or set an "
        "ambient scope with repro.radon.config(...). The kwargs keep "
        "working (this warns once per process).",
        DeprecationWarning, stacklevel=stacklevel)


def _legacy_operator(shape, dtype, method, strip_rows, m_block, batch_impl,
                     block_rows, block_batch, mesh, stream_rows=None):
    """Resolve legacy per-call knobs into a cached radon operator."""
    if any(k is not None for k in (method, strip_rows, m_block, block_rows,
                                   stream_rows, block_batch, mesh)
           ) or batch_impl not in (None, "auto"):
        _warn_legacy_knobs()
    from repro.radon import DPRT, ambient  # lazy: radon imports this module
    # legacy default was method="horner" -- EXCEPT under a mesh (explicit
    # or ambient), where "auto" routes to the mesh-aware registry pick
    # (sharded_pallas / sharded); ambient scopes override either default
    mesh = ambient.resolve("mesh", mesh)
    fallback = "horner" if mesh is None else "auto"
    return DPRT(shape, dtype,
                method=ambient.resolve("method", method, fallback),
                strip_rows=strip_rows, m_block=m_block,
                batch_impl=batch_impl, block_rows=block_rows,
                stream_rows=stream_rows, block_batch=block_batch, mesh=mesh)


def dprt(f: jnp.ndarray, method: Optional[Method] = None,
         strip_rows: Optional[int] = None,
         m_block: Optional[int] = None,
         batch_impl: Optional[str] = None,
         block_rows: Optional[int] = None,
         block_batch: Optional[int] = None,
         mesh=None, stream_rows: Optional[int] = None) -> jnp.ndarray:
    """Forward DPRT: (H, W) image -> (P+1, P) projections. Exact for ints.

    Deprecation shim over ``repro.radon.DPRT(f.shape, f.dtype, ...)``;
    same numerics, same caches, and now differentiable (`jax.grad` /
    `jax.jvp` hit the exact adjoint rules).  Any geometry is accepted:
    square prime-N images transform natively (P = N); everything else is
    zero-embedded into the smallest prime P >= max(H, W).  A
    ``(B, H, W)`` stack transforms batched (for ``method="pallas"``: ONE
    fused pallas_call).  Unset knobs resolve against the ambient
    :func:`repro.radon.config` scope, then the legacy default
    (``horner``); use the operator's ``.inverse`` when you need the
    crop-back inverse of a padded geometry.
    """
    op = _legacy_operator(f.shape, f.dtype, method, strip_rows, m_block,
                          batch_impl, block_rows, block_batch, mesh,
                          stream_rows=stream_rows)
    return op(f)


def idprt(r: jnp.ndarray, method: Optional[Method] = None,
          strip_rows: Optional[int] = None,
          m_block: Optional[int] = None,
          batch_impl: Optional[str] = None,
          block_rows: Optional[int] = None,
          block_batch: Optional[int] = None,
          mesh=None, stream_rows: Optional[int] = None) -> jnp.ndarray:
    """Inverse DPRT: (N+1, N) projections -> (N, N) image.

    Deprecation shim over ``repro.radon.DPRT((N, N), ...).inverse``.
    Exact integer reconstruction: the bracketed sum is always divisible
    by N (property-tested), so integer inputs round-trip bit-for-bit.
    Batched ``(B, N+1, N)`` stacks are accepted.  Projections always
    live in the prime domain; to recover the original (H, W) of an
    embedded image, call ``.inverse`` on the operator/plan that produced
    the projections (it crops the recorded padding).
    """
    if r.ndim not in (2, 3) or r.shape[-2] != r.shape[-1] + 1:
        raise ValueError(
            f"iDPRT input must be (N+1, N) or (B, N+1, N), got {r.shape}")
    n = r.shape[-1]
    if not is_prime(n):
        raise ValueError(f"iDPRT needs prime N, got N={n}")
    shape = (n, n) if r.ndim == 2 else (r.shape[0], n, n)
    op = _legacy_operator(shape, r.dtype, method, strip_rows, m_block,
                          batch_impl, block_rows, block_batch, mesh,
                          stream_rows=stream_rows)
    return op.inverse(r)


def dprt_batched(f: jnp.ndarray, method: Optional[Method] = None,
                 strip_rows: Optional[int] = None,
                 batch_impl: Optional[str] = None,
                 m_block: Optional[int] = None,
                 block_batch: Optional[int] = None,
                 mesh=None) -> jnp.ndarray:
    """Batched :func:`dprt` over a leading axis (requires (B, H, W)).

    ``method="pallas"`` transforms the whole stack in ONE fused
    pallas_call (the paper's Sec. V-B coprocessor throughput scenario).
    Other backends batch via ``batch_impl``: 'vmap' | 'map' | 'auto'
    (auto: `lax.map` on CPU, vmap on TPU -- measured EXPERIMENTS.md
    §Perf).  ``block_batch`` streams the stack through the backend in
    bounded-size chunks.
    """
    if f.ndim != 3:
        raise ValueError(f"dprt_batched needs (B, H, W), got {f.shape}")
    return dprt(f, method=method, strip_rows=strip_rows, m_block=m_block,
                batch_impl=batch_impl, block_batch=block_batch, mesh=mesh)


def idprt_batched(r: jnp.ndarray, method: Optional[Method] = None,
                  strip_rows: Optional[int] = None,
                  batch_impl: Optional[str] = None,
                  m_block: Optional[int] = None,
                  block_batch: Optional[int] = None,
                  mesh=None) -> jnp.ndarray:
    """Batched :func:`idprt` over a leading axis (requires (B, N+1, N))."""
    if r.ndim != 3:
        raise ValueError(f"idprt_batched needs (B, N+1, N), got {r.shape}")
    return idprt(r, method=method, strip_rows=strip_rows, m_block=m_block,
                 batch_impl=batch_impl, block_batch=block_batch, mesh=mesh)


# ---------------------------------------------------------------------------
# numpy oracle (used by tests; deliberately independent of the jax paths)
# ---------------------------------------------------------------------------
def dprt_oracle_np(f: np.ndarray) -> np.ndarray:
    n = f.shape[0]
    assert f.shape == (n, n) and is_prime(n)
    out = np.zeros((n + 1, n), dtype=np.int64)
    cols = np.arange(n)
    for m in range(n):
        for i in range(n):
            out[m] += f[i, (cols + m * i) % n].astype(np.int64)
    out[n] = f.sum(axis=1)
    return out


def idprt_oracle_np(r: np.ndarray) -> np.ndarray:
    n = r.shape[1]
    assert r.shape == (n + 1, n) and is_prime(n)
    s = int(r[0].sum())
    f = np.zeros((n, n), dtype=np.int64)
    cols = np.arange(n)
    for i in range(n):
        z = np.zeros(n, dtype=np.int64)
        for m in range(n):
            z += r[m, (cols - m * i) % n].astype(np.int64)
        f[i] = (z - s + int(r[n, i]))
    assert (f % n == 0).all(), "inverse DPRT numerator must be divisible by N"
    return f // n
