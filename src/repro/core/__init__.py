"""Core DPRT library: the paper's contribution as composable JAX modules."""
from .dprt import (dprt, idprt, dprt_batched, idprt_batched, skew_sum,
                   strip_partial, align_partial, is_prime, next_prime,
                   accum_dtype_for, float_dtype_for, dprt_oracle_np,
                   idprt_oracle_np)
from .geometry import Geometry, normalize_geometry
from .plan import (Backend, RadonPlan, available_backends,
                   backend_capabilities, get_backend, get_plan,
                   plan_cache_clear, plan_cache_entries,
                   plan_cache_info, register_backend,
                   select_backend, set_plan_cache_maxsize)
from .conv import (circ_conv2d_dprt, circ_conv2d_direct, circ_conv2d_fft,
                   linear_conv2d_dprt, linear_conv2d_direct,
                   circ_conv1d_exact, prime_vs_pow2_padding)
from .dft import dft2_via_dprt, dft2_via_dprt_batched, dft2_reference
from . import pareto

__all__ = [
    "dprt", "idprt", "dprt_batched", "idprt_batched", "skew_sum",
    "strip_partial", "align_partial", "is_prime", "next_prime",
    "accum_dtype_for", "float_dtype_for", "dprt_oracle_np",
    "idprt_oracle_np",
    "Geometry", "normalize_geometry",
    "Backend", "RadonPlan", "available_backends", "backend_capabilities",
    "get_backend", "get_plan", "plan_cache_clear", "plan_cache_entries",
    "plan_cache_info",
    "register_backend", "select_backend", "set_plan_cache_maxsize",
    "circ_conv2d_dprt", "circ_conv2d_direct", "circ_conv2d_fft",
    "linear_conv2d_dprt", "linear_conv2d_direct", "circ_conv1d_exact",
    "prime_vs_pow2_padding", "dft2_via_dprt", "dft2_via_dprt_batched",
    "dft2_reference", "pareto",
]
