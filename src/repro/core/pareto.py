"""The paper's analytical cost/resource models and Pareto front.

Implements, as executable code:

* Table I   -- forward-DPRT cycle counts (serial / systolic / SFDPRT / FDPRT)
* Table II  -- inverse-DPRT cycle counts
* Table III -- resource usage (register bits, adder-tree flip-flops,
               1-bit additions, MUXes, RAM bits)
* Fig. 22   -- ``tree_resources`` (adder-tree resource recurrence)
* eq. (11)  -- the Pareto-front membership test over strip heights H
* the TPU-analog cost model used by the §Roofline/§Perf analysis: VMEM
  working-set bytes and VPU op counts per (strip H, direction block M).

The unit tests pin these against the concrete numbers quoted in the paper
(N=251, B=8: FDPRT = 511 cycles; systolic = 63,253 cycles and 516,096
flip-flops; H=84 runs 36x faster than systolic with ~25% fewer FFs).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List

__all__ = [
    "tree_resources",
    "cycles_serial", "cycles_systolic", "cycles_sfdprt", "cycles_fdprt",
    "cycles_isfdprt", "cycles_ifdprt",
    "flipflops_sfdprt", "flipflops_systolic", "flipflops_serial",
    "flipflops_fdprt",
    "adders_sfdprt", "adders_systolic", "adders_serial", "adders_fdprt",
    "pareto_front", "pareto_points",
    "TPUStripCost", "tpu_strip_cost",
]


def _n(x: int) -> int:
    return math.ceil(math.log2(x))


# ---------------------------------------------------------------------------
# Fig. 22: adder-tree resources for X operands of B bits
# ---------------------------------------------------------------------------
def tree_resources(x: int, b: int) -> Dict[str, int]:
    """Returns {'fa': 1-bit additions, 'ff': flip-flops, 'mux': 2-to-1 muxes}."""
    h = _n(x) if x > 1 else 0
    a_ff = a_fa = a_mux = 0
    a = x
    for z in range(1, h + 1):
        r = a % 2
        a = a // 2
        a_fa += a * (b + z - 1)
        a_mux += a * b
        a = a + r
        a_ff += a * (b + z)
    return {"fa": a_fa, "ff": a_ff, "mux": a_mux}


# ---------------------------------------------------------------------------
# Table I: forward cycle counts
# ---------------------------------------------------------------------------
def cycles_serial(n: int) -> int:
    return n ** 3 + 2 * n ** 2 + n


def cycles_systolic(n: int) -> int:
    return n ** 2 + n + 1


def cycles_sfdprt(n: int, h: int) -> int:
    k = math.ceil(n / h)
    return k * (n + 3 * h + 3) + n + _n(h) + 1


def cycles_fdprt(n: int) -> int:
    return 2 * n + _n(n) + 1


# ---------------------------------------------------------------------------
# Table II: inverse cycle counts
# ---------------------------------------------------------------------------
def cycles_isfdprt(n: int, h: int, b: int) -> int:
    k = math.ceil(n / h)
    return k * (n + h) + 2 * _n(n) + _n(h) + b + 3


def cycles_ifdprt(n: int, b: int) -> int:
    return 2 * n + 3 * _n(n) + b + 2


# ---------------------------------------------------------------------------
# Table III: resources (flip-flops = register-array bits + adder-tree FFs,
# matching how Fig. 19 counts them)
# ---------------------------------------------------------------------------
def flipflops_serial(n: int, b: int) -> int:
    return n * (b + _n(n)) + (3 * b + 2 * _n(n))


def flipflops_systolic(n: int, b: int) -> int:
    return n * (n + 1) * _n(n) + (n + 1) * (3 * b + 2 * _n(n))


def flipflops_sfdprt(n: int, h: int, b: int) -> int:
    return n * h * b + n * tree_resources(h, b)["ff"]


def flipflops_fdprt(n: int, b: int) -> int:
    return n * n * b + n * tree_resources(n, b)["ff"]


def adders_serial(n: int, b: int) -> int:
    return b + _n(n)


def adders_systolic(n: int, b: int) -> int:
    return (n + 1) * (b + _n(n))


def adders_sfdprt(n: int, h: int, b: int) -> int:
    return n * tree_resources(h, b)["fa"] + n * (b + _n(n))


def adders_fdprt(n: int, b: int) -> int:
    return n * tree_resources(n, b)["fa"]


# ---------------------------------------------------------------------------
# eq. (11): Pareto front over H
# ---------------------------------------------------------------------------
def pareto_front(n: int) -> List[int]:
    """H in {2..(N-1)/2} with ceil(N/H) < ceil(N/(H-1))."""
    return [h for h in range(2, (n - 1) // 2 + 1)
            if math.ceil(n / h) < math.ceil(n / (h - 1))]


def pareto_points(n: int, b: int) -> List[Dict[str, int]]:
    """(H, cycles, flip-flops, 1-bit adders) along the front, plus H=N."""
    pts = [{"h": h,
            "cycles": cycles_sfdprt(n, h),
            "ff": flipflops_sfdprt(n, h, b),
            "fa": adders_sfdprt(n, h, b)} for h in pareto_front(n)]
    pts.append({"h": n, "cycles": cycles_fdprt(n),
                "ff": flipflops_fdprt(n, b), "fa": adders_fdprt(n, b)})
    return pts


# ---------------------------------------------------------------------------
# TPU-analog cost model for the strip kernel (used by §Perf block sweeps).
#
# A (H-row strip) x (M-direction block) tile keeps in VMEM:
#   strip rows        H  x Npad  x in_bytes
#   accumulator       M  x Npad  x 4            (int32)
#   per-step work: the binary roll-select ladder issues ceil(log2 N)
#   roll+select pairs on the (M, Npad) accumulator plus one add.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TPUStripCost:
    h: int
    m_block: int
    n: int
    n_pad: int
    vmem_bytes: int
    vpu_ops: int            # scalar-equivalent VPU lane-ops for the full DPRT
    hbm_bytes: int          # image reads + output writes (one pass)
    ai: float               # arithmetic intensity (ops/HBM byte)


def tpu_strip_cost(n: int, h: int, m_block: int, in_bytes: int = 4,
                   lanes: int = 128, sublanes: int = 8) -> TPUStripCost:
    n_pad = math.ceil(n / lanes) * lanes
    k = math.ceil(n / h)
    mb = math.ceil((n + 1) / m_block)
    ladder = max(1, _n(n))
    vmem = h * n_pad * in_bytes + m_block * n_pad * 4 * 2  # strip + acc (dbl buf)
    # per (strip, m-block): H steps x (ladder rolls + ladder selects + 1 add)
    per_tile = h * (2 * ladder + 1) * m_block * n_pad
    align = (2 * ladder) * m_block * n_pad                 # alignment roll
    vpu = k * mb * (per_tile + align)
    hbm = k * mb * h * n_pad * in_bytes + (n + 1) * n_pad * 4
    return TPUStripCost(h=h, m_block=m_block, n=n, n_pad=n_pad,
                        vmem_bytes=vmem, vpu_ops=vpu, hbm_bytes=hbm,
                        ai=vpu / max(hbm, 1))
