"""Geometry normalization for arbitrary-shape DPRT inputs.

The transforms themselves are only defined on square prime-N images
(the paper's Sec. II setting).  This module is the bridge from
arbitrary ``(H, W)`` / ``(B, H, W)`` inputs to that prime domain and
back, with every pad/crop recorded so the round trip is bit-exact:

* **Embedding** -- an ``(H, W)`` image is zero-padded into the smallest
  prime ``P >= max(H, W)`` (density of primes: ``P - max(H, W)`` is
  ``O(log P)`` on average, the paper's Sec. I argument vs power-of-two
  FFT padding).  Zero rows/columns contribute nothing to any projection
  sum, and the exact inverse reproduces the zero padding exactly, so
  cropping back to ``(H, W)`` loses nothing: for any integer image
  ``crop(idprt(dprt(embed(f)))) == f`` bit-for-bit.
* **Tiling** -- helpers for the block-based resource-fitting scheme
  (paper Sec. III-C / the companion overlap-add convolution paper,
  arXiv 2112.13150): split a large image into fixed-size square tiles
  plus their placement offsets, and overlap-add per-tile results back
  onto a canvas.
* **Folding** -- wrap a full linear-convolution result onto an
  ``(H, W)`` torus (index arithmetic mod H / mod W), which turns the
  prime-embedded *linear* convolution into the true ``(H, W)``-periodic
  *circular* convolution for geometries the DPRT cannot represent
  directly.

Everything here is shape metadata plus cheap `jnp.pad`/slice/scatter
ops; no transform math.  :mod:`repro.core.plan` builds on these to make
cached :class:`~repro.core.plan.RadonPlan` objects.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dprt import next_prime

__all__ = [
    "Geometry",
    "normalize_geometry",
    "embed",
    "crop",
    "pad2d",
    "image_to_tiles",
    "overlap_add",
    "fold_mod",
]


@dataclasses.dataclass(frozen=True)
class Geometry:
    """Pad/crop metadata tying a logical image shape to its prime domain.

    ``prime`` is the transform size P; ``height``/``width`` the logical
    image; ``batch`` is ``None`` for single images.  ``native`` means the
    input already lives in the prime domain (square, prime side) and
    embed/crop are identities -- the fast path every existing caller of
    square prime-N transforms stays on.
    """

    height: int
    width: int
    prime: int
    batch: Optional[int] = None

    @property
    def batched(self) -> bool:
        return self.batch is not None

    @property
    def native(self) -> bool:
        return self.height == self.width == self.prime

    @property
    def pad_rows(self) -> int:
        return self.prime - self.height

    @property
    def pad_cols(self) -> int:
        return self.prime - self.width

    @property
    def image_shape(self) -> tuple:
        hw = (self.height, self.width)
        return (self.batch, *hw) if self.batched else hw

    @property
    def transform_shape(self) -> tuple:
        pr = (self.prime + 1, self.prime)
        return (self.batch, *pr) if self.batched else pr


def normalize_geometry(shape: Sequence[int]) -> Geometry:
    """(H, W) or (B, H, W) -> :class:`Geometry` with P = next_prime(max)."""
    shape = tuple(int(s) for s in shape)
    if len(shape) == 2:
        batch, (h, w) = None, shape
    elif len(shape) == 3:
        batch, (h, w) = shape[0], shape[1:]
    else:
        raise ValueError(
            f"DPRT input must be (H, W) or (B, H, W), got {shape}")
    if h < 1 or w < 1 or (batch is not None and batch < 1):
        raise ValueError(f"DPRT input dims must be positive, got {shape}")
    return Geometry(height=h, width=w, prime=next_prime(max(h, w, 2)),
                    batch=batch)


def pad2d(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    """Zero-pad the trailing two axes by (rows, cols) at the high end."""
    if rows == 0 and cols == 0:
        return x
    cfg = [(0, 0)] * (x.ndim - 2) + [(0, rows), (0, cols)]
    return jnp.pad(x, cfg)


def embed(f: jnp.ndarray, geom: Geometry) -> jnp.ndarray:
    """Zero-embed the image(s) into the (…, P, P) prime domain."""
    if f.shape[-2:] != (geom.height, geom.width):
        raise ValueError(
            f"image trailing shape {f.shape[-2:]} does not match plan "
            f"geometry ({geom.height}, {geom.width})")
    return pad2d(f, geom.pad_rows, geom.pad_cols)


def crop(x: jnp.ndarray, geom: Geometry) -> jnp.ndarray:
    """Crop a (…, P, P) prime-domain image back to (…, H, W)."""
    return x[..., : geom.height, : geom.width]


# ---------------------------------------------------------------------------
# tiling (paper Sec. III-C / companion-paper overlap-add blocks)
# ---------------------------------------------------------------------------
def image_to_tiles(f: jnp.ndarray, block: int
                   ) -> Tuple[jnp.ndarray, np.ndarray]:
    """Split (…, H, W) into (…, T, block, block) tiles + (T, 2) offsets.

    The image is zero-padded up to a multiple of ``block`` per axis; the
    returned offsets are each tile's top-left corner in the *original*
    image, row-major.  Zero padding in edge tiles contributes nothing to
    any downstream convolution, so overlap-add of per-tile results stays
    exact.
    """
    h, w = f.shape[-2:]
    if block < 1:
        raise ValueError(f"block must be >= 1, got {block}")
    th, tw = math.ceil(h / block), math.ceil(w / block)
    fp = pad2d(f, th * block - h, tw * block - w)
    lead = fp.shape[:-2]
    tiles = fp.reshape(*lead, th, block, tw, block)
    tiles = jnp.swapaxes(tiles, -3, -2).reshape(
        *lead, th * tw, block, block)
    offsets = np.array([(i * block, j * block)
                        for i in range(th) for j in range(tw)],
                       dtype=np.int32)
    return tiles, offsets


def overlap_add(tile_outs: jnp.ndarray, offsets: np.ndarray,
                canvas_shape: Tuple[int, int]) -> jnp.ndarray:
    """Accumulate (T, oh, ow) tiles onto a canvas at (T, 2) offsets.

    A `lax.scan` keeps exactly one tile live at a time (bounded memory:
    the canvas plus a single tile), which is the streaming half of the
    resource-fitting scheme.
    """
    t, oh, ow = tile_outs.shape
    canvas = jnp.zeros(canvas_shape, tile_outs.dtype)

    def step(c, xs):
        tile, off = xs
        cur = jax.lax.dynamic_slice(c, (off[0], off[1]), (oh, ow))
        return jax.lax.dynamic_update_slice(c, cur + tile,
                                            (off[0], off[1])), None

    canvas, _ = jax.lax.scan(step, canvas,
                             (tile_outs, jnp.asarray(offsets)))
    return canvas


def fold_mod(lin: jnp.ndarray, h: int, w: int) -> jnp.ndarray:
    """Wrap a (…, LH, LW) linear-conv result onto the (h, w) torus.

    out[..., x, y] = sum of lin[..., u, v] over u ≡ x (mod h),
    v ≡ y (mod w).  Exact in integers (scatter-add), turning prime-
    embedded linear convolution into true (h, w)-circular convolution.
    """
    lh, lw = lin.shape[-2:]
    u = jnp.arange(lh) % h
    v = jnp.arange(lw) % w
    out = jnp.zeros((*lin.shape[:-2], h, w), lin.dtype)
    return out.at[..., u[:, None], v[None, :]].add(lin)
