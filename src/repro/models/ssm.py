"""Mamba-2 SSD (state-space duality) blocks: chunked scan + O(1) decode."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ParamSpec, shard_act
from .layers import (apply_causal_conv1d, causal_conv1d_specs, dense,
                     dense_spec, rmsnorm)

__all__ = ["mamba_specs", "apply_mamba", "mamba_cache_shapes"]


def mamba_specs(cfg):
    d = cfg.d_model
    din = cfg.d_inner
    h = cfg.ssm_heads
    g, n = cfg.ssm_groups, cfg.ssm_state
    conv_ch = din + 2 * g * n
    s = {"in_proj": dense_spec(d, 2 * din + 2 * g * n + h, "embed", "inner"),
         "A_log": ParamSpec((h,), (None,), init="zeros"),
         "D_skip": ParamSpec((h,), (None,), init="ones"),
         "dt_bias": ParamSpec((h,), (None,), init="zeros"),
         "norm": ParamSpec((din,), ("inner",), init="ones"),
         "out_proj": dense_spec(din, d, "inner", "embed")}
    s.update(causal_conv1d_specs(conv_ch, cfg.conv_width))
    return s


def mamba_cache_shapes(cfg, batch: int):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {"conv": (batch, cfg.conv_width - 1, conv_ch),
            "ssm": (batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim)}


def _ssd_chunked(xs, dt, a, bm, cm, chunk: int):
    """Chunked SSD scan.

    xs: (B,S,H,P) values; dt: (B,S,H) softplus'd steps; a: (H,) negative;
    bm, cm: (B,S,H,N) input/output projections (already head-broadcast).
    Returns (y (B,S,H,P), final_state (B,H,N,P)).
    """
    b, s, h, p = xs.shape
    n = bm.shape[-1]
    q = min(chunk, s)
    nc = math.ceil(s / q)
    pad = nc * q - s
    if pad:  # padded steps get dt=0 => exp(0) decay, zero input: no-ops
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    xc = xs.reshape(b, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)
    bc = bm.reshape(b, nc, q, h, n).astype(jnp.float32)
    cc = cm.reshape(b, nc, q, h, n).astype(jnp.float32)

    da = dtc * a.astype(jnp.float32)                     # (B,nc,Q,H) <= 0
    da_cum = jnp.cumsum(da, axis=2)                      # inclusive
    da_tot = da_cum[:, :, -1, :]                         # (B,nc,H)

    # intra-chunk: L[i,j] = exp(da_cum_i - da_cum_j) for i >= j
    li = da_cum[:, :, :, None, :]                        # i
    lj = da_cum[:, :, None, :, :]                        # j
    mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])
    l = jnp.where(mask[None, None, :, :, None], jnp.exp(li - lj), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", cc, bc) * l
    y_diag = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", scores, dtc, xc)

    # per-chunk input states: sum_j exp(da_tot - da_cum_j) dt_j B_j x_j
    decay_out = jnp.exp(da_tot[:, :, None, :] - da_cum)  # (B,nc,Q,H)
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", bc, decay_out * dtc, xc)

    # inter-chunk recurrence (sequential scan over chunks)
    def step(st, inp):
        st_dec, new = inp
        st_next = st * st_dec[:, :, None, None] + new
        return st_next, st

    st0 = jnp.zeros((b, h, n, p), jnp.float32)
    decays = jnp.exp(da_tot).transpose(1, 0, 2)          # (nc,B,H)
    st_in = states.transpose(1, 0, 2, 3, 4)              # (nc,B,H,N,P)
    final, prev = jax.lax.scan(step, st0, (decays, st_in))
    prev = prev.transpose(1, 0, 2, 3, 4)                 # state before chunk c

    y_off = jnp.einsum("bcihn,bchnp,bcih->bcihp", cc, prev,
                       jnp.exp(da_cum))
    y = (y_diag + y_off).reshape(b, nc * q, h, p)[:, :s]
    return y.astype(xs.dtype), final


def apply_mamba(params, cfg, x, cache=None, decode: bool = False):
    """x: (B,S,D). Returns (out, new_cache)."""
    b, s, d = x.shape
    din, h, p = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    rep = h // g

    zxbcdt = dense(x, params["in_proj"])
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:2 * din + 2 * g * n]
    dt_raw = zxbcdt[..., -h:]

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = apply_causal_conv1d(
        {"conv_w": params["conv_w"], "conv_b": params["conv_b"]}, xbc,
        conv_state if decode or cache is not None else None)
    xbc = jax.nn.silu(xbc)

    xs = xbc[..., :din].reshape(b, s, h, p)
    bm = xbc[..., din:din + g * n].reshape(b, s, g, n)
    cm = xbc[..., din + g * n:].reshape(b, s, g, n)
    bm = jnp.repeat(bm, rep, axis=2)
    cm = jnp.repeat(cm, rep, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["A_log"].astype(jnp.float32))

    if decode:
        st = cache["ssm"].astype(jnp.float32)            # (B,H,N,P)
        da = jnp.exp(dt[:, 0] * a)                       # (B,H)
        upd = jnp.einsum("bh,bhn,bhp->bhnp", dt[:, 0],
                         bm[:, 0].astype(jnp.float32),
                         xs[:, 0].astype(jnp.float32))
        st = st * da[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", cm[:, 0].astype(jnp.float32), st)
        y = y[:, None].astype(x.dtype)                   # (B,1,H,P)
        new_ssm = st
    else:
        y, new_ssm = _ssd_chunked(xs, dt, a, bm, cm, cfg.ssm_chunk)

    y = y + params["D_skip"].astype(x.dtype)[None, None, :, None] \
        * xs.astype(x.dtype)
    y = y.reshape(b, s, din)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                params["norm"], cfg.norm_eps)
    y = shard_act(y, "batch", "seq", "inner")
    out = dense(y, params["out_proj"])
    return out, {"conv": new_conv, "ssm": new_ssm.astype(jnp.float32)}
