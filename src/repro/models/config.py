"""Unified model configuration covering all assigned architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads

    # block pattern, cycled over layers: attn|local_attn|recurrent|mamba
    block_pattern: Tuple[str, ...] = ("attn",)
    window: int = 2048                # local attention window
    rope_theta: float = 10000.0
    qk_norm: bool = False
    tie_embeddings: bool = False
    mlp_act: str = "silu"             # silu (SwiGLU) | gelu (plain MLP)
    learned_pos: int = 0              # >0: learned positions (disables RoPE)
    pad_vocab_multiple: int = 128     # pad embedding table for clean TP

    # mixture of experts
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    shared_experts: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "grouped"   # grouped (optimized, §Perf B) | global (baseline)

    # multi-head latent attention (DeepSeek-V2)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # state-space (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    conv_width: int = 4

    # RG-LRU (RecurrentGemma / Griffin)
    lru_width: int = 0

    # encoder-decoder (Whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500
    cross_attention: bool = False

    # modality frontend stub
    frontend: str = "none"            # none|audio_stub|patch_stub
    prefix_len: int = 0               # precomputed patch/frame prefix length

    norm_eps: float = 1e-6
    remat: str = "full"               # none|full|dots
    scan_layers: bool = True
    chunk_q: int = 512
    chunk_kv: int = 4096
    causal_skip: bool = True          # skip fully-masked kv chunks (perf)
    attn_impl: str = "segmented" # segmented (optimized, §Perf C) | chunked (baseline) | qchunked
    attn_segments: int = 8       # triangle segments for attn_impl=segmented

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_multiple
        if m <= 1:
            return self.vocab_size
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kinds(self):
        """Per-layer (block_kind, mlp_kind) resolved from the pattern."""
        kinds = []
        for i in range(self.num_layers):
            blk = self.block_pattern[i % len(self.block_pattern)]
            if blk == "mamba":
                mlp = "none"
            elif self.num_experts > 0 and i >= self.first_dense_layers:
                mlp = "moe"
            else:
                mlp = "dense"
            kinds.append((blk, mlp))
        return kinds

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
