"""Mixture-of-Experts with sort-based capacity dispatch (expert parallel).

Token routing uses top-k + argsort position assignment (no (T, E) one-hot
cumsum, O(T*k) memory), scatter into a (E, C, D) expert buffer sharded over
the ``model`` axis (EP), per-expert SwiGLU einsums, and weighted combine.
Capacity drops overflow tokens (standard Switch-style); the residual path
keeps dropped tokens intact.  Emits the load-balance auxiliary loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ParamSpec, shard_act
from .layers import dense, dense_spec, mlp_specs, apply_mlp

__all__ = ["moe_specs", "apply_moe"]


def moe_specs(cfg):
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    s = {"router": ParamSpec((d, e), ("embed", None), scale=0.02),
         "w_gate": ParamSpec((e, d, f), ("experts", "embed", None)),
         "w_up": ParamSpec((e, d, f), ("experts", "embed", None)),
         "w_down": ParamSpec((e, f, d), ("experts", None, "embed"))}
    if cfg.shared_experts:
        s["shared"] = mlp_specs(d, cfg.shared_experts * f, "silu")
    return s


def _dp_size() -> int:
    from repro.parallel.sharding import active_mesh
    mesh = active_mesh()
    if mesh is None:
        return 1
    n = 1
    for ax in ("pod", "data"):
        n *= mesh.shape.get(ax, 1)
    return n


def apply_moe(params, cfg, x):
    """x: (B, S, D) -> (out, aux_loss).

    ``cfg.moe_dispatch``:
      * 'global'  -- one global capacity pool (baseline; XLA realizes the
        scatter as a full-buffer all-reduce across DP -- §Perf cell B).
      * 'grouped' -- per-DP-shard capacity pools: the dispatch scatter and
        combine gather stay shard-local, experts stay model-sharded, and
        the giant DP all-reduce disappears.
    """
    b, s, d = x.shape
    t = b * s
    groups = _dp_size() if cfg.moe_dispatch == "grouped" else 1
    if groups > 1 and t % groups == 0 and t // groups >= 8:
        xg = x.reshape(groups, t // groups, d)
        xg = shard_act(xg, "expert_group", None, "embed")
        outs, auxs = jax.vmap(
            lambda g: _moe_tokens(params, cfg, g))(xg)
        out = outs.reshape(b, s, d)
        aux = auxs.mean()
    else:
        out, aux = _moe_tokens(params, cfg, x.reshape(t, d))
        out = out.reshape(b, s, d)

    if cfg.shared_experts:
        out = out + apply_mlp(params["shared"], x, "silu")
    return out.astype(x.dtype), aux


def _moe_tokens(params, cfg, xf):
    """Route/dispatch/compute/combine for a flat (T, D) token block."""
    t, d = xf.shape
    k = cfg.experts_per_token
    e = cfg.num_experts
    # floor the capacity so tiny decode batches never drop tokens
    cap = max(int(math.ceil(t * k / e * cfg.capacity_factor)), min(t, 32))
    logits = dense(xf, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate, eidx = jax.lax.top_k(probs, k)                      # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert, via one sort
    fe = eidx.reshape(-1)                                     # (T*k,)
    perm = jnp.argsort(fe)
    se = fe[perm]
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype))
    pos_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    pos = jnp.zeros((t * k,), jnp.int32).at[perm].set(pos_sorted)
    keep = pos < cap
    slot = jnp.where(keep, fe.astype(jnp.int32) * cap + pos, e * cap)

    tok = jnp.arange(t * k, dtype=jnp.int32) // k
    dispatched = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].add(xf[tok])
    hidden = dispatched[:e * cap].reshape(e, cap, d)
    hidden = shard_act(hidden, "experts", "expert_cap", "embed")

    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", hidden, params["w_gate"]))
         * jnp.einsum("ecd,edf->ecf", hidden, params["w_up"]))
    ho = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    ho = shard_act(ho, "experts", "expert_cap", "embed")

    flat = jnp.concatenate([ho.reshape(e * cap, d),
                            jnp.zeros((1, d), ho.dtype)], axis=0)
    weights = (gate.reshape(-1) * keep.astype(gate.dtype))
    out_k = flat[slot] * weights[:, None].astype(flat.dtype)
    out = out_k.reshape(t, k, d).sum(axis=1)

    # Switch-style load-balance loss
    counts = jnp.zeros((e,), jnp.float32).at[fe].add(1.0) / (t * k)
    aux = e * jnp.sum(counts * probs.mean(axis=0))
    return out, aux
