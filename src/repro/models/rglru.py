"""RG-LRU recurrent block (RecurrentGemma / Griffin) with associative scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ParamSpec, shard_act
from .layers import (apply_causal_conv1d, causal_conv1d_specs, dense,
                     dense_spec)

__all__ = ["rglru_specs", "apply_rglru", "rglru_cache_shapes"]

_C = 8.0  # Griffin's fixed gate sharpness


def rglru_specs(cfg):
    d = cfg.d_model
    w = cfg.lru_width or d
    h = max(cfg.num_heads, 1)
    bw = w // h                       # Griffin: block-diagonal gate matrices
    s = {"w_x": dense_spec(d, w, "embed", "inner"),
         "w_y": dense_spec(d, w, "embed", "inner"),
         "w_rg": ParamSpec((h, bw, bw), ("inner", None, None)),
         "b_rg": ParamSpec((w,), ("inner",), init="zeros"),
         "w_ig": ParamSpec((h, bw, bw), ("inner", None, None)),
         "b_ig": ParamSpec((w,), ("inner",), init="zeros"),
         "a_param": ParamSpec((w,), ("inner",), init="ones"),
         "w_out": dense_spec(w, d, "inner", "embed")}
    s.update(causal_conv1d_specs(w, cfg.conv_width))
    return s


def _block_gate(x, w_block, b):
    """x: (B,S,W) through a block-diagonal (h, W/h, W/h) matrix + bias."""
    bsz, s, wdim = x.shape
    h, bw, _ = w_block.shape
    xh = x.reshape(bsz, s, h, bw)
    y = jnp.einsum("bshi,hij->bshj", xh, w_block).reshape(bsz, s, wdim)
    return y + b


def rglru_cache_shapes(cfg, batch: int):
    w = cfg.lru_width or cfg.d_model
    return {"conv": (batch, cfg.conv_width - 1, w), "h": (batch, w)}


def apply_rglru(params, cfg, x, cache=None, decode: bool = False):
    """x: (B,S,D) -> (out, new_cache={conv, h})."""
    b, s, d = x.shape
    xb = dense(x, params["w_x"])
    yb = jax.nn.gelu(dense(x, params["w_y"]))

    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = apply_causal_conv1d(
        {"conv_w": params["conv_w"], "conv_b": params["conv_b"]}, xb,
        conv_state)

    r = jax.nn.sigmoid(_block_gate(xc, params["w_rg"], params["b_rg"]))
    i = jax.nn.sigmoid(_block_gate(xc, params["w_ig"], params["b_ig"]))
    log_a = (-_C * jax.nn.softplus(params["a_param"].astype(jnp.float32))
             * r.astype(jnp.float32))                     # (B,S,W) <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = beta * (i.astype(jnp.float32) * xc.astype(jnp.float32))

    if decode:
        h_prev = cache["h"].astype(jnp.float32)           # (B,W)
        h = a[:, 0] * h_prev + gated[:, 0]
        hs = h[:, None]
    else:
        h0 = cache["h"].astype(jnp.float32) if cache is not None else None

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        if h0 is not None:  # inject carried state into the first step
            gated = gated.at[:, 0].add(a[:, 0] * h0)
        aa, hs = jax.lax.associative_scan(combine, (a, gated), axis=1)
        h = hs[:, -1]

    hs = hs.astype(x.dtype)
    hs = shard_act(hs, "batch", "seq", "inner")
    out = dense(hs * yb, params["w_out"])
    return out, {"conv": new_conv, "h": h.astype(jnp.float32)}
