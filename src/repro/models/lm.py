"""Model assembly: embeddings + (prefix | scanned body | tail) stacks.

Layers whose kinds repeat periodically are stacked and run under
``lax.scan`` with per-group rematerialization, keeping HLO size O(1) in
depth (a 94-layer MoE lowers in seconds).  Irregular layers (e.g.
DeepSeek-V2's first dense layer, pattern remainders) run unscanned.

Three entry points:
  * ``forward``     -- training/eval logits over a full batch,
  * ``prefill``     -- forward + KV/state cache construction,
  * ``decode_step`` -- one-token step against the cache.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ParamSpec, stack_specs, shard_act
from .blocks import apply_block, block_cache_shapes, block_specs
from .config import ModelConfig
from .layers import (cross_entropy_loss, embed_specs, embed_tokens, rmsnorm,
                     rmsnorm_spec, unembed)

__all__ = ["Model"]


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save nothing


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.kinds = cfg.layer_kinds()
        pl = len(cfg.block_pattern)
        start = cfg.first_dense_layers
        if cfg.scan_layers and cfg.num_layers - start >= pl:
            self.n_groups = (cfg.num_layers - start) // pl
        else:
            self.n_groups = 0
        self.body_start = start
        self.tail_start = start + self.n_groups * pl
        self.pattern_kinds = [
            self.kinds[start + p] if self.n_groups else None
            for p in range(pl)] if self.n_groups else []
        self.prefix_ids = list(range(0, self.body_start))
        self.tail_ids = list(range(self.tail_start, cfg.num_layers))
        self.use_rope = cfg.learned_pos == 0
        self.cross = cfg.cross_attention

    # ------------------------------------------------------------------
    # parameter specs
    # ------------------------------------------------------------------
    def specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        s: Dict[str, Any] = {
            "embed": embed_specs(cfg.padded_vocab, cfg.d_model,
                                 cfg.tie_embeddings, max_pos=cfg.learned_pos),
            "final_norm": rmsnorm_spec(cfg.d_model),
        }
        if self.prefix_ids:
            s["prefix"] = {str(i): block_specs(cfg, *self.kinds[i],
                                               cross=self.cross)
                           for i in self.prefix_ids}
        if self.n_groups:
            s["body"] = {str(p): stack_specs(
                block_specs(cfg, *self.pattern_kinds[p], cross=self.cross),
                self.n_groups) for p in range(len(self.pattern_kinds))}
        if self.tail_ids:
            s["tail"] = {str(i): block_specs(cfg, *self.kinds[i],
                                             cross=self.cross)
                         for i in self.tail_ids}
        if cfg.encoder_layers:
            s["encoder"] = {
                "body": stack_specs(block_specs(cfg, "attn", "dense"),
                                    cfg.encoder_layers),
                "final_norm": rmsnorm_spec(cfg.d_model),
            }
        return s

    # ------------------------------------------------------------------
    # encoder (whisper-style; stub embeddings in, contextual states out)
    # ------------------------------------------------------------------
    def _encode(self, params, audio_embed):
        cfg = self.cfg
        positions = jnp.arange(audio_embed.shape[1], dtype=jnp.int32)

        def fn(carry, pg):
            x, aux = carry
            x, _, a = apply_block(pg, cfg, x, "attn", "dense",
                                  positions=positions, causal=False,
                                  use_rope=False)
            return (x, aux + a), None

        (x, _), _ = jax.lax.scan(_remat(fn, cfg.remat),
                                 (audio_embed, jnp.zeros((), jnp.float32)),
                                 params["encoder"]["body"])
        return rmsnorm(x, params["encoder"]["final_norm"], cfg.norm_eps)

    # ------------------------------------------------------------------
    # embedding / input munging
    # ------------------------------------------------------------------
    def _embed(self, params, batch, positions):
        cfg = self.cfg
        tokens = batch["tokens"]
        pos2d = positions if positions.ndim == 2 else positions[None, :]
        x = embed_tokens(params["embed"], tokens,
                         positions=positions if cfg.learned_pos else None)
        if cfg.frontend == "patch_stub" and "patch_embed" in batch:
            p = batch["patch_embed"].astype(x.dtype)   # (B, P, D)
            x = jnp.concatenate([p, x[:, p.shape[1]:]], axis=1)
        enc_out = None
        if cfg.encoder_layers and "audio_embed" in batch:
            enc_out = self._encode(params, batch["audio_embed"])
        return shard_act(x, "batch", "seq", "embed"), enc_out

    # ------------------------------------------------------------------
    # layer stacks
    # ------------------------------------------------------------------
    def _run_stack(self, params, x, positions, *, causal=True, cache=None,
                   decode_pos=None, enc_out=None, collect_cache=False):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        new_cache: Dict[str, Any] = {}
        decode = cache is not None and decode_pos is not None

        def run_one(pblock, x, kinds, c):
            return apply_block(pblock, cfg, x, *kinds, positions=positions,
                               causal=causal, cache=c,
                               decode_pos=decode_pos if decode else None,
                               enc_out=enc_out, use_rope=self.use_rope)

        if self.prefix_ids:
            new_cache["prefix"] = {}
            for i in self.prefix_ids:
                c = cache["prefix"][str(i)] if decode else None
                x, nc, a = run_one(params["prefix"][str(i)], x,
                                   self.kinds[i], c)
                aux = aux + a
                new_cache["prefix"][str(i)] = nc

        if self.n_groups:
            pat = self.pattern_kinds

            def fn(carry, xs):
                x, aux = carry
                if decode:
                    pg, cg = xs
                else:
                    pg, cg = xs, None
                ncg = {}
                for p, kinds in enumerate(pat):
                    ci = cg[str(p)] if cg is not None else None
                    x, nc, a = run_one(pg[str(p)], x, kinds, ci)
                    aux = aux + a
                    ncg[str(p)] = nc
                ys = ncg if (decode or collect_cache) else None
                return (x, aux), ys

            xs = (params["body"], cache["body"]) if decode else params["body"]
            (x, aux), body_cache = jax.lax.scan(_remat(fn, cfg.remat),
                                                (x, aux), xs)
            if decode or collect_cache:
                new_cache["body"] = body_cache

        if self.tail_ids:
            new_cache["tail"] = {}
            for i in self.tail_ids:
                c = cache["tail"][str(i)] if decode else None
                x, nc, a = run_one(params["tail"][str(i)], x,
                                   self.kinds[i], c)
                aux = aux + a
                new_cache["tail"][str(i)] = nc

        return x, new_cache, aux

    # ------------------------------------------------------------------
    # public entry points
    # ------------------------------------------------------------------
    def forward(self, params, batch):
        """-> (logits (B,S,V), aux).  Training / teacher-forced eval."""
        cfg = self.cfg
        s = batch["tokens"].shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        x, enc_out = self._embed(params, batch, positions)
        x, _, aux = self._run_stack(params, x, positions, enc_out=enc_out)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x)[..., :cfg.vocab_size]
        return logits, aux

    def loss(self, params, batch, aux_weight: float = 0.01):
        logits, aux = self.forward(params, batch)
        ce = cross_entropy_loss(logits, batch["labels"],
                                batch.get("loss_mask"))
        return ce + aux_weight * aux, {"ce": ce, "aux": aux}

    def prefill(self, params, batch, max_len: Optional[int] = None):
        """-> (last-position logits (B,1,V), cache sized for ``max_len``)."""
        cfg = self.cfg
        b, s = batch["tokens"].shape
        positions = jnp.arange(s, dtype=jnp.int32)
        x, enc_out = self._embed(params, batch, positions)
        x, cache, _ = self._run_stack(params, x, positions, enc_out=enc_out,
                                      collect_cache=True)
        x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x)[..., :cfg.vocab_size]
        if max_len is not None and max_len > s:
            cache_dtype = jax.tree.leaves(params)[0].dtype
            full = self.init_cache(b, max_len,
                                   enc_len=(enc_out.shape[1]
                                            if enc_out is not None else 0),
                                   dtype=cache_dtype)
            cache = _merge_cache(full, cache)
        return logits, cache

    def decode_step(self, params, cache, tokens, pos):
        """tokens (B,1), pos scalar int32 -> (logits (B,1,V), new cache)."""
        cfg = self.cfg
        positions = jnp.reshape(pos, (1,)).astype(jnp.int32)
        x, _ = self._embed(params, {"tokens": tokens}, positions)
        x, new_cache, _ = self._run_stack(params, x, positions, cache=cache,
                                          decode_pos=pos)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params["embed"], x)[..., :cfg.vocab_size]
        return logits, new_cache

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def cache_shapes(self, batch: int, max_len: int, enc_len: int = 0):
        cfg = self.cfg
        enc_len = enc_len or (cfg.encoder_seq if cfg.encoder_layers else 0)

        def one(i):
            return block_cache_shapes(cfg, self.kinds[i][0], self.cross,
                                      batch, max_len, enc_len)

        tree: Dict[str, Any] = {}
        if self.prefix_ids:
            tree["prefix"] = {str(i): one(i) for i in self.prefix_ids}
        if self.n_groups:
            body = {}
            for p in range(len(self.pattern_kinds)):
                shapes = block_cache_shapes(cfg, self.pattern_kinds[p][0],
                                            self.cross, batch, max_len,
                                            enc_len)
                body[str(p)] = jax.tree.map(
                    lambda sh: (self.n_groups,) + sh, shapes,
                    is_leaf=lambda v: isinstance(v, tuple)
                    and all(isinstance(t, int) for t in v))
            tree["body"] = body
        if self.tail_ids:
            tree["tail"] = {str(i): one(i) for i in self.tail_ids}
        return tree

    def init_cache(self, batch: int, max_len: int, enc_len: int = 0,
                   dtype=jnp.bfloat16, factory=None):
        shapes = self.cache_shapes(batch, max_len, enc_len)
        factory = factory or (lambda sh, dt: jnp.zeros(sh, dt))

        def make(path, sh):
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            dt = jnp.float32 if name in ("h", "ssm") else dtype
            return factory(sh, dt)

        return jax.tree_util.tree_map_with_path(
            make, shapes,
            is_leaf=lambda v: isinstance(v, tuple)
            and all(isinstance(t, int) for t in v))


def _merge_cache(full, prefill):
    """Write a prefill cache into a zero-initialized ``max_len`` cache."""

    def merge(f, p):
        if f.shape == p.shape:
            return p.astype(f.dtype)
        idx = (0,) * f.ndim
        return jax.lax.dynamic_update_slice(f, p.astype(f.dtype), idx)

    return jax.tree.map(merge, full, prefill)
