from .config import ModelConfig
from .lm import Model

__all__ = ["ModelConfig", "Model"]
