"""Transformer-block assembly: pre-norm residual blocks of every family."""
from __future__ import annotations

import jax.numpy as jnp

from .attention import apply_attention, apply_mla, attn_specs, mla_specs
from .layers import apply_mlp, mlp_specs, rmsnorm, rmsnorm_spec
from .moe import apply_moe, moe_specs
from .rglru import apply_rglru, rglru_cache_shapes, rglru_specs
from .ssm import apply_mamba, mamba_cache_shapes, mamba_specs

__all__ = ["block_specs", "apply_block", "block_cache_shapes"]


def block_specs(cfg, blk: str, mlp: str, cross: bool = False):
    d = cfg.d_model
    s = {"ln1": rmsnorm_spec(d)}
    if blk in ("attn", "local_attn"):
        s["attn"] = mla_specs(cfg) if cfg.is_mla else attn_specs(cfg)
    elif blk == "recurrent":
        s["rec"] = rglru_specs(cfg)
    elif blk == "mamba":
        s["mamba"] = mamba_specs(cfg)
    else:
        raise ValueError(f"unknown block kind {blk!r}")
    if cross:
        s["ln_x"] = rmsnorm_spec(d)
        s["cross"] = attn_specs(cfg)
    if mlp == "dense":
        s["ln2"] = rmsnorm_spec(d)
        s["mlp"] = mlp_specs(d, cfg.d_ff, cfg.mlp_act)
    elif mlp == "moe":
        s["ln2"] = rmsnorm_spec(d)
        s["moe"] = moe_specs(cfg)
    return s


def block_cache_shapes(cfg, blk: str, cross: bool, batch: int, kv_len: int,
                       enc_len: int = 0):
    """Shape dict mirroring the cache pytree of one block."""
    hkv, hd = cfg.num_kv_heads, cfg.hd
    if blk == "attn":
        if cfg.is_mla:
            c = {"c_kv": (batch, kv_len, cfg.kv_lora_rank),
                 "k_rope": (batch, kv_len, cfg.rope_head_dim)}
        else:
            c = {"k": (batch, kv_len, hkv, hd),
                 "v": (batch, kv_len, hkv, hd)}
    elif blk == "local_attn":
        w = min(cfg.window, kv_len)
        c = {"k": (batch, w, hkv, hd), "v": (batch, w, hkv, hd)}
    elif blk == "recurrent":
        c = rglru_cache_shapes(cfg, batch)
    elif blk == "mamba":
        c = mamba_cache_shapes(cfg, batch)
    else:
        raise ValueError(blk)
    out = {"self": c}
    if cross:
        out["cross"] = {"k": (batch, enc_len, hkv, hd),
                        "v": (batch, enc_len, hkv, hd)}
    return out


def apply_block(params, cfg, x, blk: str, mlp: str, *, positions,
                causal: bool = True, cache=None, decode_pos=None,
                enc_out=None, use_rope: bool = True):
    """Returns (x, new_cache, aux).  ``cache``/``decode_pos`` given => decode;
    cache None => train/prefill (new_cache still returned for prefill)."""
    aux = jnp.zeros((), jnp.float32)
    decode = cache is not None and decode_pos is not None
    self_cache = cache["self"] if decode else None

    h = rmsnorm(x, params["ln1"], cfg.norm_eps)
    if blk in ("attn", "local_attn"):
        if cfg.is_mla:
            sub, new_self = apply_mla(params["attn"], cfg, h,
                                      positions=positions, cache=self_cache,
                                      decode_pos=decode_pos)
        else:
            sub, new_self = apply_attention(
                params["attn"], cfg, h, positions=positions, causal=causal,
                local=(blk == "local_attn"), cache=self_cache,
                decode_pos=decode_pos, use_rope=use_rope)
    elif blk == "recurrent":
        sub, new_self = apply_rglru(params["rec"], cfg, h, cache=self_cache,
                                    decode=decode)
    elif blk == "mamba":
        sub, new_self = apply_mamba(params["mamba"], cfg, h,
                                    cache=self_cache, decode=decode)
    else:
        raise ValueError(blk)
    x = x + sub
    new_cache = {"self": new_self}

    if "cross" in params:
        hx = rmsnorm(x, params["ln_x"], cfg.norm_eps)
        sub, new_cross = apply_attention(
            params["cross"], cfg, hx, positions=positions, cross=True,
            cache=cache["cross"] if decode else None,
            decode_pos=decode_pos if decode else None,
            kv_x=None if decode else enc_out, use_rope=False)
        x = x + sub
        new_cache["cross"] = new_cross

    if mlp == "dense":
        x = x + apply_mlp(params["mlp"],
                          rmsnorm(x, params["ln2"], cfg.norm_eps),
                          cfg.mlp_act)
    elif mlp == "moe":
        sub, aux = apply_moe(params["moe"],
                             cfg, rmsnorm(x, params["ln2"], cfg.norm_eps))
        x = x + sub
    return x, new_cache, aux
