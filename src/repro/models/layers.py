"""Shared neural layers: norms, RoPE, embeddings, MLPs, 1-D convs."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ParamSpec, shard_act

__all__ = [
    "rmsnorm", "rmsnorm_spec", "rope", "dense", "dense_spec",
    "mlp_specs", "apply_mlp", "embed_specs", "embed_tokens", "unembed",
    "causal_conv1d_specs", "apply_causal_conv1d", "cross_entropy_loss",
]


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm_spec(dim: int, logical: str = "embed") -> ParamSpec:
    return ParamSpec((dim,), (logical,), init="ones")


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding (half-split convention)
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense projections
# ---------------------------------------------------------------------------
def dense_spec(d_in: int, d_out: int, lin: str = "embed",
               lout: str = "ffn", scale: Optional[float] = None) -> ParamSpec:
    return ParamSpec((d_in, d_out), (lin, lout), scale=scale)


def dense(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,df->...f", x, w)


# ---------------------------------------------------------------------------
# MLP (SwiGLU or GELU)
# ---------------------------------------------------------------------------
def mlp_specs(d_model: int, d_ff: int, act: str = "silu"):
    if act == "silu":
        return {"w_gate": dense_spec(d_model, d_ff),
                "w_up": dense_spec(d_model, d_ff),
                "w_down": dense_spec(d_ff, d_model, "ffn", "embed")}
    return {"w_in": dense_spec(d_model, d_ff),
            "w_out": dense_spec(d_ff, d_model, "ffn", "embed")}


def apply_mlp(params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    if act == "silu":
        h = jax.nn.silu(dense(x, params["w_gate"])) * dense(x, params["w_up"])
        h = shard_act(h, "batch", "seq", "ffn")
        return dense(h, params["w_down"])
    h = jax.nn.gelu(dense(x, params["w_in"]))
    h = shard_act(h, "batch", "seq", "ffn")
    return dense(h, params["w_out"])


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------
def embed_specs(vocab: int, d_model: int, tie: bool, max_pos: int = 0):
    s = {"embedding": ParamSpec((vocab, d_model), ("vocab", "embed"),
                                scale=0.02)}
    if not tie:
        s["unembed"] = dense_spec(d_model, vocab, "embed", "vocab")
    if max_pos:
        s["pos_embedding"] = ParamSpec((max_pos, d_model), ("seq", "embed"),
                                       scale=0.02)
    return s


def embed_tokens(params, tokens: jnp.ndarray, positions=None) -> jnp.ndarray:
    x = jnp.take(params["embedding"], tokens, axis=0)
    if positions is not None and "pos_embedding" in params:
        x = x + jnp.take(params["pos_embedding"], positions, axis=0)
    return shard_act(x, "batch", "seq", "embed")


def unembed(params, x: jnp.ndarray) -> jnp.ndarray:
    if "unembed" in params:
        logits = dense(x, params["unembed"])
    else:
        logits = jnp.einsum("...d,vd->...v", x, params["embedding"])
    return shard_act(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# causal depthwise conv1d (Mamba / RecurrentGemma frontends)
# ---------------------------------------------------------------------------
def causal_conv1d_specs(channels: int, width: int):
    return {"conv_w": ParamSpec((width, channels), ("conv", "inner"),
                                scale=0.5),
            "conv_b": ParamSpec((channels,), ("inner",), init="zeros")}


def apply_causal_conv1d(params, x: jnp.ndarray, state=None):
    """x: (B, S, C) depthwise causal conv; ``state``: (B, W-1, C) for decode.

    Returns (y, new_state).
    """
    w = params["conv_w"].astype(x.dtype)           # (W, C)
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+W-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    y = y + params["conv_b"].astype(x.dtype)
    new_state = xp[:, -(width - 1):, :]
    return y, new_state


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                       mask: Optional[jnp.ndarray] = None):
    """Mean next-token cross entropy in f32; labels (B, S) int32."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
