"""Attention: chunked-online-softmax GQA, sliding-window, cross, and MLA.

Implementation notes
--------------------
* Global causal attention streams KV chunks through an online-softmax
  scan (flash-style) so the (Sq, Skv) score matrix is never materialized
  in HBM -- required for the 32k prefill shapes.  With ``causal_skip``
  the scan carries a per-chunk validity mask so fully-masked KV chunks
  contribute a cheap select instead of a masked matmul where possible.
* Sliding-window attention uses the chunk-pair scheme (each W-sized
  query chunk attends to its own + previous chunk), FLOP-tight for
  window == chunk.
* Decode uses the same chunked path with a KV cache; sliding-window
  decode uses a ring buffer so the cache is O(window), which is what
  makes ``long_500k`` feasible for the hybrid archs.
* MLA (DeepSeek-V2) trains/prefills in expanded form and decodes in the
  *absorbed* form over the compressed `c_kv` cache -- the whole point of
  MLA; expanding 32k keys per step would be O(H * d) larger.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ParamSpec, shard_act
from .layers import dense, dense_spec, rmsnorm, rmsnorm_spec, rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------
def attn_specs(cfg):
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    s = {"wq": dense_spec(d, h * hd),
         "wk": dense_spec(d, hkv * hd),
         "wv": dense_spec(d, hkv * hd),
         "wo": dense_spec(h * hd, d, "ffn", "embed")}
    if cfg.qk_norm:
        s["q_norm"] = rmsnorm_spec(hd, "head_dim")
        s["k_norm"] = rmsnorm_spec(hd, "head_dim")
    return s


def mla_specs(cfg):
    d, h = cfg.d_model, cfg.num_heads
    qk = cfg.nope_head_dim + cfg.rope_head_dim
    s = {"kv_down": dense_spec(d, cfg.kv_lora_rank + cfg.rope_head_dim,
                               "embed", "lora"),
         "kv_norm": rmsnorm_spec(cfg.kv_lora_rank, "lora"),
         "k_up": dense_spec(cfg.kv_lora_rank, h * cfg.nope_head_dim,
                            "lora", "ffn"),
         "v_up": dense_spec(cfg.kv_lora_rank, h * cfg.v_head_dim,
                            "lora", "ffn"),
         "wo": dense_spec(h * cfg.v_head_dim, d, "ffn", "embed")}
    if cfg.q_lora_rank:
        s["q_down"] = dense_spec(d, cfg.q_lora_rank, "embed", "lora")
        s["q_norm"] = rmsnorm_spec(cfg.q_lora_rank, "lora")
        s["q_up"] = dense_spec(cfg.q_lora_rank, h * qk, "lora", "ffn")
    else:
        s["wq"] = dense_spec(d, h * qk)
    return s


# ---------------------------------------------------------------------------
# chunked online-softmax scaled dot product (GQA grouped, no KV repeat)
# ---------------------------------------------------------------------------
def _sdpa_chunked(q, k, v, *, q_positions, causal: bool,
                  window: int = 0, kv_valid: Optional[jnp.ndarray] = None,
                  chunk_kv: int = 1024):
    """q: (B,Sq,H,hd); k,v: (B,Skv,Hkv,hd). Returns (B,Sq,H,hd).

    ``q_positions``: (Sq,) absolute positions of queries.
    ``kv_valid``: scalar count of valid cache entries (decode), else None.
    """
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = hd ** -0.5

    ckv = min(chunk_kv, skv)
    nkv = math.ceil(skv / ckv)
    pad = nkv * ckv - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = q.reshape(b, sq, hkv, g, hd).astype(jnp.float32)
    kc = k.reshape(b, nkv, ckv, hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nkv, ckv, hkv, hd).transpose(1, 0, 2, 3, 4)

    qpos = q_positions.astype(jnp.int32)                     # (Sq,)
    limit = jnp.asarray(skv if kv_valid is None else kv_valid, jnp.int32)

    def step(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        kvpos = j * ckv + jnp.arange(ckv, dtype=jnp.int32)    # (ckv,)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                       kj.astype(jnp.float32)) * scale
        valid = (kvpos[None, :] < limit)
        if causal:
            valid = valid & (kvpos[None, :] <= qpos[:, None])
        if window:
            valid = valid & (qpos[:, None] - kvpos[None, :] < window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vj.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    js = jnp.arange(nkv, dtype=jnp.int32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (js, kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def _sdpa_qchunked(q, k, v, *, q_positions, causal: bool,
                   window: int = 0, kv_valid=None,
                   chunk_q: int = 512, chunk_kv: int = 1024):
    """Two-level flash attention: outer map over query chunks, inner
    online-softmax scan over KV chunks.

    vs. ``_sdpa_chunked``: the (B,H,Sq,hd) softmax accumulator no longer
    round-trips HBM once per KV chunk -- only a (B,H,cq,hd) tile does.
    The trade is re-reading K/V once per query chunk.  For Sq=Skv=32k
    this cuts modeled HBM bytes ~5x (EXPERIMENTS.md §Perf, cell C).
    """
    b, sq, h, hd = q.shape
    cq = min(chunk_q, sq)
    nq = math.ceil(sq / cq)
    pad = nq * cq - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded queries get position -1: fully masked, cropped after
        q_positions = jnp.pad(q_positions, (0, pad), constant_values=-1)
    qc = q.reshape(b, nq, cq, h, hd).transpose(1, 0, 2, 3, 4)
    posc = q_positions.reshape(nq, cq)

    def one(args):
        qi, pi = args
        return _sdpa_chunked(qi, k, v, q_positions=pi, causal=causal,
                             window=window, kv_valid=kv_valid,
                             chunk_kv=chunk_kv)

    out = jax.lax.map(one, (qc, posc))          # (nq, B, cq, H, hd)
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nq * cq, h, hd)
    return out[:, :sq]


def _sdpa_segmented(q, k, v, *, q_positions, causal: bool,
                    segments: int = 4, chunk_kv: int = 1024):
    """Causal triangular segmentation (self-attention, Sq == Skv).

    Query segment s attends only kv[: (s+1)*Sq/segments] -- the fully
    masked upper-triangle KV chunks are never *computed*, cutting both
    score-tensor HBM traffic and matmul FLOPs by ~(1 - (n+1)/2n) at the
    cost of `segments`x HLO size (static python loop).
    """
    b, sq, h, hd = q.shape
    seg = math.ceil(sq / segments)
    outs = []
    for s in range(segments):
        lo, hi = s * seg, min((s + 1) * seg, sq)
        if lo >= hi:
            break
        kv_end = min(hi, k.shape[1])
        outs.append(_sdpa_chunked(
            q[:, lo:hi], k[:, :kv_end], v[:, :kv_end],
            q_positions=q_positions[lo:hi], causal=causal,
            chunk_kv=chunk_kv))
    return jnp.concatenate(outs, axis=1)


def _sdpa(q, k, v, *, cfg, q_positions, causal, window=0, kv_valid=None):
    """Dispatch on cfg.attn_impl: chunked (baseline) | qchunked | segmented."""
    long_self = (q.shape[1] > cfg.chunk_q and kv_valid is None
                 and window == 0)
    if cfg.attn_impl == "qchunked" and long_self:
        return _sdpa_qchunked(q, k, v, q_positions=q_positions,
                              causal=causal, window=window,
                              kv_valid=kv_valid, chunk_q=cfg.chunk_q,
                              chunk_kv=cfg.chunk_kv)
    if (cfg.attn_impl == "segmented" and long_self and causal
            and q.shape[1] == k.shape[1]):
        return _sdpa_segmented(q, k, v, q_positions=q_positions,
                               causal=causal, segments=cfg.attn_segments,
                               chunk_kv=cfg.chunk_kv)
    return _sdpa_chunked(q, k, v, q_positions=q_positions, causal=causal,
                         window=window, kv_valid=kv_valid,
                         chunk_kv=cfg.chunk_kv)


def _local_attention(q, k, v, window: int):
    """FLOP-tight sliding-window causal attention (train/prefill).

    Chunk size == window; each query chunk attends to [prev | own].
    q,k,v: (B,S,H|Hkv,hd) with S % window == 0 after padding.
    """
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    w = window
    nc = math.ceil(s / w)
    pad = nc * w - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    scale = hd ** -0.5
    qc = q.reshape(b, nc, w, hkv, g, hd).astype(jnp.float32)
    kc = k.reshape(b, nc, w, hkv, hd)
    vc = v.reshape(b, nc, w, hkv, hd)
    prev_k = jnp.pad(kc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    prev_v = jnp.pad(vc, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    kk = jnp.concatenate([prev_k, kc], axis=2)               # (B,nc,2W,Hkv,hd)
    vv = jnp.concatenate([prev_v, vc], axis=2)

    scores = jnp.einsum("bcqkgd,bcskd->bckgqs", qc,
                        kk.astype(jnp.float32)) * scale
    qi = jnp.arange(w)[:, None]                # in-chunk query index
    kj = jnp.arange(2 * w)[None, :] - w        # kv offset relative to chunk
    delta = qi - kj                            # q_pos - kv_pos
    valid = (delta >= 0) & (delta < w)         # causal, within window
    not_first = jnp.arange(nc)[:, None, None] > 0
    valid = valid[None] & (not_first | (kj >= 0)[None])   # no prev for c=0
    scores = jnp.where(valid[None, :, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bckgqs,bcskd->bcqkgd", p, vv.astype(jnp.float32))
    out = out.reshape(b, nc * w, h, hd)[:, :s]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (self / cross / cached decode)
# ---------------------------------------------------------------------------
def apply_attention(params, cfg, x, *, positions, causal=True,
                    local: bool = False, cross: bool = False,
                    cache=None, decode_pos=None, kv_x=None, use_rope=True):
    """Returns (out, new_cache).

    * train/prefill: ``cache=None`` -> new_cache holds this segment's K/V
      (ring-buffered to ``window`` when ``local``).
    * decode: ``cache`` given, ``x`` is (B,1,D), ``decode_pos`` scalar.
    * cross: ``kv_x`` is the encoder output at prefill (cache stores the
      projected K/V); at decode the cross cache is static.
    """
    b, sq, d = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    window = cfg.window if local else 0

    q = dense(x, params["wq"]).reshape(b, sq, h, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)

    is_decode = cache is not None and decode_pos is not None

    if cross:
        if kv_x is not None:       # prefill: project encoder output once
            k = dense(kv_x, params["wk"]).reshape(b, -1, hkv, hd)
            v = dense(kv_x, params["wv"]).reshape(b, -1, hkv, hd)
            new_cache = {"k": k, "v": v}
        else:                      # decode: static projected cache
            new_cache = cache
            k, v = cache["k"], cache["v"]
        out = _sdpa(q, k, v, cfg=cfg, q_positions=positions, causal=False)
        return dense(out.reshape(b, sq, h * hd), params["wo"]), new_cache

    k = dense(x, params["wk"]).reshape(b, sq, hkv, hd)
    v = dense(x, params["wv"]).reshape(b, sq, hkv, hd)
    if cfg.qk_norm:
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if use_rope:
        pos2d = jnp.broadcast_to(positions[None, :], (b, sq))
        q = rope(q, pos2d, cfg.rope_theta)
        k = rope(k, pos2d, cfg.rope_theta)

    if is_decode:
        cap = cache["k"].shape[1]
        slot = decode_pos % cap if window else decode_pos
        z = jnp.zeros((), jnp.int32)
        idx = (z, jnp.asarray(slot, jnp.int32), z, z)
        kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          idx)
        vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          idx)
        new_cache = {"k": kc, "v": vc}
        if window:
            valid = jnp.minimum(decode_pos + 1, cap)
            out = _sdpa_chunked(q, kc, vc, q_positions=positions,
                                causal=False, kv_valid=valid,
                                chunk_kv=cfg.chunk_kv)
        else:
            out = _sdpa_chunked(q, kc, vc, q_positions=positions,
                                causal=True, kv_valid=decode_pos + 1,
                                chunk_kv=cfg.chunk_kv)
        return dense(out.reshape(b, sq, h * hd), params["wo"]), new_cache

    # train / prefill
    if local:
        out = _local_attention(q, k, v, window)
        # ring-buffer invariant: absolute position p lives at slot p % window
        if sq >= window:
            ring_k = jnp.roll(k[:, -window:], sq % window, axis=1)
            ring_v = jnp.roll(v[:, -window:], sq % window, axis=1)
        else:
            ring_k = jnp.pad(k, ((0, 0), (0, window - sq), (0, 0), (0, 0)))
            ring_v = jnp.pad(v, ((0, 0), (0, window - sq), (0, 0), (0, 0)))
        new_cache = {"k": ring_k, "v": ring_v}
    else:
        out = _sdpa(q, k, v, cfg=cfg, q_positions=positions, causal=causal)
        new_cache = {"k": k, "v": v}
    out = shard_act(out.reshape(b, sq, h * hd), "batch", "seq", "ffn")
    return dense(out, params["wo"]), new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------
def _mla_q(params, cfg, x):
    b, sq, _ = x.shape
    h = cfg.num_heads
    qk = cfg.nope_head_dim + cfg.rope_head_dim
    if cfg.q_lora_rank:
        cq = rmsnorm(dense(x, params["q_down"]), params["q_norm"],
                     cfg.norm_eps)
        q = dense(cq, params["q_up"])
    else:
        q = dense(x, params["wq"])
    q = q.reshape(b, sq, h, qk)
    return q[..., :cfg.nope_head_dim], q[..., cfg.nope_head_dim:]


def apply_mla(params, cfg, x, *, positions, cache=None, decode_pos=None):
    """Returns (out, new_cache); cache = {c_kv (B,S,r), k_rope (B,S,rd)}."""
    b, sq, d = x.shape
    h = cfg.num_heads
    nope, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    scale = (nope + rd) ** -0.5
    pos2d = jnp.broadcast_to(positions[None, :], (b, sq))

    q_nope, q_rope = _mla_q(params, cfg, x)
    q_rope = rope(q_rope, pos2d, cfg.rope_theta)

    ckv_full = dense(x, params["kv_down"])
    c_kv = rmsnorm(ckv_full[..., :cfg.kv_lora_rank], params["kv_norm"],
                   cfg.norm_eps)
    k_rope = rope(ckv_full[..., cfg.kv_lora_rank:][:, :, None, :],
                  pos2d, cfg.rope_theta)[:, :, 0, :]

    if cache is not None and decode_pos is not None:
        z = jnp.zeros((), jnp.int32)
        idx = (z, jnp.asarray(decode_pos, jnp.int32), z)
        ckv_c = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), idx)
        kr_c = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), idx)
        new_cache = {"c_kv": ckv_c, "k_rope": kr_c}
        # absorbed decode
        w_kup = params["k_up"].reshape(cfg.kv_lora_rank, h, nope)
        w_vup = params["v_up"].reshape(cfg.kv_lora_rank, h, vd)
        q_abs = jnp.einsum("bqhn,lhn->bqhl", q_nope.astype(jnp.float32),
                           w_kup.astype(jnp.float32))
        s = (jnp.einsum("bqhl,bsl->bhqs", q_abs,
                        ckv_c.astype(jnp.float32))
             + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                          kr_c.astype(jnp.float32))) * scale
        kvpos = jnp.arange(ckv_c.shape[1])
        s = jnp.where(kvpos[None, None, None, :] <= decode_pos, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhqs,bsl->bqhl", p, ckv_c.astype(jnp.float32))
        out = jnp.einsum("bqhl,lhv->bqhv", ctx, w_vup.astype(jnp.float32))
        out = out.astype(x.dtype).reshape(b, sq, h * vd)
        return dense(out, params["wo"]), new_cache

    # train / prefill: expanded attention
    k_nope = dense(c_kv, params["k_up"]).reshape(b, sq, h, nope)
    v = dense(c_kv, params["v_up"]).reshape(b, sq, h, vd)
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (b, sq, h, rd))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    # pad v to qk dim for the shared kernel, crop after
    vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, nope + rd - vd)))
    out = _sdpa(q, k, vpad, cfg=cfg, q_positions=positions,
                causal=True)[..., :vd]
    out = out.reshape(b, sq, h * vd)
    new_cache = {"c_kv": c_kv, "k_rope": k_rope}
    return dense(out, params["wo"]), new_cache
