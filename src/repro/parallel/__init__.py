from .sharding import (ParamSpec, LOGICAL_RULES, logical_to_pspec,
                       param_pspecs, param_shardings, init_params,
                       abstract_params, stack_specs, shard_act,
                       activate_mesh, active_mesh, count_params)
