"""Logical-axis sharding: one source of truth for params and activations.

Every parameter is declared as a :class:`ParamSpec` carrying its *logical*
axis names; a rule table maps logical names onto mesh axes (DP over
``pod``/``data``, TP/EP over ``model``).  The same tree of specs yields

* initialized parameters (deterministic per-path PRNG folding),
* ``PartitionSpec``s / ``NamedSharding``s for pjit in_shardings,
* activation sharding constraints via :func:`shard_act`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ParamSpec", "LOGICAL_RULES", "logical_to_pspec", "param_pspecs",
    "param_shardings", "init_params", "abstract_params", "stack_specs",
    "shard_act", "activate_mesh", "active_mesh", "count_params",
]

# logical axis -> mesh axis (None = replicated).  DP batch over pod+data,
# TP over model for heads / ffn / vocab, EP: experts over model.
LOGICAL_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,
    "embed": None,
    "q_heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "expert_group": ("pod", "data"),
    "inner": "model",       # mamba d_inner / rg-lru width
    "state": None,
    "conv": None,
    "lora": None,           # MLA compressed dims stay replicated
    "layers": None,         # stacked-scan leading axis
    "zero": "data",         # ZeRO-1 optimizer-state sharding axis
}


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    scale: Optional[float] = None  # stddev; default 1/sqrt(fan_in)

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(f"shape {self.shape} vs logical {self.logical}")


def logical_to_pspec(logical, rules=None, mesh: Optional[Mesh] = None) -> P:
    rules = rules or LOGICAL_RULES
    mesh_axes = set(mesh.shape.keys()) if mesh is not None else None
    axes = []
    used = set()
    for name in logical:
        ax = rules.get(name) if name is not None else None
        if isinstance(ax, tuple):
            ax = tuple(a for a in ax
                       if a not in used
                       and (mesh_axes is None or a in mesh_axes))
            ax = ax if ax else None
        elif ax is not None and mesh_axes is not None and ax not in mesh_axes:
            ax = None
        if ax is None:
            axes.append(None)
        else:
            flat = ax if isinstance(ax, tuple) else (ax,)
            if any(a in used for a in flat):
                axes.append(None)      # a mesh axis may appear only once
                continue
            used.update(flat)
            axes.append(ax)
    return P(*axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def param_pspecs(specs, rules=None, mesh: Optional[Mesh] = None):
    return jax.tree.map(lambda s: logical_to_pspec(s.logical, rules, mesh),
                        specs, is_leaf=_is_spec)


def prune_pspec(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes from dims they don't divide (uneven shardings are
    legal in GSPMD but pad; we prefer replication for those dims)."""
    out = []
    for i, s in enumerate(spec):
        if s is None or i >= len(shape):
            out.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        out.append(s if (n > 0 and shape[i] % n == 0) else None)
    return P(*out)


def param_shardings(specs, mesh: Mesh, rules=None):
    def one(s: ParamSpec):
        spec = prune_pspec(logical_to_pspec(s.logical, rules, mesh), s.shape,
                           mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, specs, is_leaf=_is_spec)


def _path_seed(path) -> int:
    s = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
    return int.from_bytes(hashlib.sha256(s.encode()).digest()[:4], "little")


def init_params(specs, key: jax.Array, dtype=jnp.bfloat16):
    """Materialize a spec tree; per-leaf keys are path-derived (stable)."""

    def one(path, spec: ParamSpec):
        k = jax.random.fold_in(key, _path_seed(path))
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale if spec.scale is not None else fan_in ** -0.5
        return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype)

    return jax.tree_util.tree_map_with_path(one, specs, is_leaf=_is_spec)


def abstract_params(specs, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for the spec tree (used by the dry-run)."""
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
                        is_leaf=_is_spec)


def stack_specs(specs, n_layers: int):
    """Prepend a stacked-layers axis to every leaf (for lax.scan blocks)."""
    return jax.tree.map(
        lambda s: ParamSpec((n_layers,) + s.shape, ("layers",) + s.logical,
                            init=s.init, scale=s.scale),
        specs, is_leaf=_is_spec)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


# ---------------------------------------------------------------------------
# active mesh context (read at trace time by shard_act)
# ---------------------------------------------------------------------------
_STATE = threading.local()


class activate_mesh:
    """``with activate_mesh(mesh):`` makes shard_act constraints concrete."""

    def __init__(self, mesh: Optional[Mesh], rules=None):
        self.mesh, self.rules = mesh, rules

    def __enter__(self):
        self.prev = getattr(_STATE, "mesh", None), getattr(_STATE, "rules", None)
        _STATE.mesh, _STATE.rules = self.mesh, self.rules
        return self

    def __exit__(self, *exc):
        _STATE.mesh, _STATE.rules = self.prev
        return False


def active_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def shard_act(x: jnp.ndarray, *logical: Optional[str]) -> jnp.ndarray:
    """Constrain an activation's sharding by logical axis names (no-op when
    no mesh is active, e.g. single-device tests)."""
    mesh = active_mesh()
    if mesh is None:
        return x
    rules = getattr(_STATE, "rules", None)
    if len(logical) != getattr(x, "ndim", len(logical)):
        return x  # vmap-inserted batch dims: skip the constraint
    spec = prune_pspec(logical_to_pspec(logical, rules, mesh), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
