"""(N -> strip_rows H, m_block M) tuning for the fused SFDPRT kernels.

The kernel's cost surface (paper Fig. 19/20 Pareto front, transplanted to
TPU blocks): grid steps per image = ceil(N/H) * ceil((N+1)/M); VMEM per
step = (H + 2M) * N_pad * itemsize; the hoisted-ladder setup
(<= ceil(log2 N) mask derivations + alignment rotate+selects) is paid
once per (m-block, strip), so *larger* blocks amortize setup while
*smaller* blocks cut VMEM and wasted rows in the final m-block.

``PALLAS_TUNE`` pins measured-good choices for the primes the repo's
tests and benchmarks exercise (CPU interpret measurements; Mosaic-aligned
sublane counts for the TPU path).  :func:`pallas_block_spec` is the
dispatch-time lookup with a heuristic fallback for unlisted N.
"""
from __future__ import annotations

import math
import warnings

__all__ = ["PALLAS_TUNE", "pallas_block_spec", "resolve_blocks",
           "PIPELINE_TUNE", "pipeline_block_spec", "resolve_pipeline_blocks",
           "wasted_direction_rows",
           "SERVE_WARM_BATCHES", "warm_batch_sizes", "nearest_warm_batch"]

# N values we already warned about (once per process per N): a giant-N
# heuristic fallback should be loud exactly once, not per dispatch.
_FALLBACK_WARNED: set = set()
_PIPELINE_FALLBACK_WARNED: set = set()


def _warn_off_table(n: int, table: dict, warned: set, kind: str) -> None:
    """Warn ONCE when N falls off the top of a measured table: the
    heuristic extrapolates block shapes that nobody has timed at this
    size, which is exactly when silent mis-tuning hurts most."""
    top = max(table)
    if n > top and n not in warned:
        warned.add(n)
        warnings.warn(
            f"N={n} is beyond the largest measured {kind} tuning row "
            f"(N={top}); using the heuristic block-shape fallback. "
            f"Pass strip_rows/m_block (or stream_rows) explicitly, or "
            f"add a measured entry, if performance matters at this size.",
            stacklevel=3)

# N: (strip_rows H, m_block M).  M multiples of 8 keep int32 sublane
# tiling aligned off the interpret path.  CPU-interpret measurements
# (N=251, int32): H=N (single strip, no alignment pass) with moderate M
# wins -- {(251,32): 13.7ms, (251,64): 14.5ms, (64,64): 21.9ms,
# (32,32): 16.9ms} vs horner 25.7ms; on real TPUs H instead bounds the
# VMEM-resident strip (H*N_pad*4B), which every pinned H below respects
# by a wide margin against the ~16 MB/core budget.
PALLAS_TUNE = {
    2: (2, 8),
    3: (3, 8),
    5: (5, 8),
    7: (7, 8),
    11: (11, 8),
    13: (13, 8),
    17: (17, 8),
    31: (31, 8),
    61: (61, 16),
    127: (127, 16),
    251: (251, 32),
    509: (256, 32),
    1021: (256, 64),
    # giant-N rows (the streamed-strip kernels): H=256 keeps one strip +
    # double buffer at (2*256 + 2*64) * N_pad * 4B < 6 MB VMEM even at
    # N=4099; M=64 amortizes the hoisted ladder over a full sublane tile
    2053: (256, 64),
    4099: (256, 64),
}


def pallas_block_spec(n: int, itemsize: int = 4) -> tuple[int, int]:
    """Tuned (strip_rows, m_block) for prime N; heuristic off-table.

    ``itemsize`` is the *accumulator* element size in bytes (8 for int64
    under x64).  The heuristic keeps one strip + accumulators within a
    ~2 MB VMEM budget and rounds the direction block to a sublane
    multiple (8/16/64), so the final m-block can carry up to m_block-1
    masked rows; :func:`wasted_direction_rows` reports the exact count
    per (N, m_block) and the benchmarks surface it as useful_row_frac.
    """
    if n in PALLAS_TUNE:
        return PALLAS_TUNE[n]
    _warn_off_table(n, PALLAS_TUNE, _FALLBACK_WARNED, "pallas")
    if n <= 32:
        return n, 8
    h = min(n, 128)
    m_block = 64 if n >= 128 else 16
    # shrink until (H + 2M) * N_pad * itemsize fits the budget: H first
    # (strip residency), then the direction block, flooring both at the
    # 8-row sublane tile
    n_pad = ((n + 127) // 128) * 128
    budget = 2 * 1024 * 1024
    while (h + 2 * m_block) * n_pad * itemsize > budget:
        if h > 8:
            h //= 2
        elif m_block > 8:
            m_block //= 2
        else:
            break
    return max(h, 1), m_block


def resolve_blocks(n: int, itemsize: int = 4,
                   strip_rows=None, m_block=None, block_rows=None,
                   stream_rows=None) -> tuple[int, int]:
    """Fill missing (strip_rows, m_block) from the table, validate given.

    The single knob-resolution used by both the Pallas op wrappers and
    the transform-plan layer (``repro.core.plan``), so ``method="auto"``
    and explicit ``method="pallas"`` land on identical block shapes.

    ``block_rows`` (the scan-of-launches staged fallback) and
    ``stream_rows`` (the in-launch streamed kernel) both partition the
    image into row strips; asking for BOTH is ambiguous and rejected
    here rather than silently preferring one.
    """
    if block_rows is not None and stream_rows is not None:
        raise ValueError(
            f"block_rows={block_rows} and stream_rows={stream_rows} are "
            "mutually exclusive: block_rows scans separate kernel "
            "launches over row strips (the staged fallback), stream_rows "
            "streams strips through ONE fused launch. Pick one.")
    if stream_rows is not None and int(stream_rows) < 1:
        raise ValueError(f"stream_rows must be >= 1, got {stream_rows}")
    th, tm = pallas_block_spec(n, itemsize)
    h = th if strip_rows is None else int(strip_rows)
    mb = tm if m_block is None else int(m_block)
    if h < 1 or mb < 1:
        raise ValueError(f"strip_rows/m_block must be >= 1, got {h}/{mb}")
    return h, mb


def wasted_direction_rows(n: int, m_block: int, forward: bool = True) -> int:
    """Masked (non-useful) rows in the final m-block -- reported by the
    benchmarks so padded work is never counted as useful throughput."""
    rows = n + 1 if forward else n
    return math.ceil(rows / m_block) * m_block - rows


# ---------------------------------------------------------------------------
# projection-domain pipeline (fused fwd -> per-direction op -> inverse)
# ---------------------------------------------------------------------------
# N: (m_block M, conv tap group K).  The pipeline kernel always runs the
# whole image as ONE strip (H = N: the conv epilogue needs each
# direction's complete projection before it can run), so its only block
# knobs are the direction block M and the Horner conv tap group K.
# CPU-interpret measurements at N=251 (min-of-many, 2-core host):
# M=64/K=4 31.2 ms vs M=32/K=8 31.9, M=128+ worse (alignment tile and
# iota setup outgrow L2); small primes are a single m-block.  On real
# TPUs M bounds the accumulator sublanes ((M + N_pad_rows) * N_pad *
# itemsize VMEM per step) -- re-measure on Mosaic before trusting these.
PIPELINE_TUNE = {
    61: (62, 4),
    127: (64, 4),
    251: (64, 4),
    509: (64, 4),
    1021: (64, 4),
    2053: (64, 4),
    4099: (64, 4),
}


def pipeline_block_spec(n: int, itemsize: int = 4) -> tuple[int, int]:
    """Tuned (m_block, conv tap group) for the fused pipeline kernel."""
    if n in PIPELINE_TUNE:
        return PIPELINE_TUNE[n]
    _warn_off_table(n, PIPELINE_TUNE, _PIPELINE_FALLBACK_WARNED, "pipeline")
    if n <= 61:
        return n + 1, 4         # one m-block covers every direction row
    return 64, 4


# ---------------------------------------------------------------------------
# serving tier: warm batch sizes
# ---------------------------------------------------------------------------
# The dynamic batcher pads coalesced request groups up to one of these
# batch sizes, so the service only ever needs |SERVE_WARM_BATCHES| AOT
# executables per (geometry, dtype, datapath) -- every admitted group
# hits a pre-compiled stack shape instead of compiling its exact count.
# Powers of two bound padding waste at < 2x and match the measured
# fused-kernel batched sweet spot (B=16 rows in BENCH_dprt.json: the
# one-call pallas stack is 2.4-7.5x per-image efficiency over
# single-image calls on CPU interpret and the 8-device mesh alike).
SERVE_WARM_BATCHES = (1, 2, 4, 8, 16)


def warm_batch_sizes(max_batch: int) -> tuple:
    """The warm sizes a service with admission limit ``max_batch`` keeps
    compiled: table entries up to ``max_batch``, plus ``max_batch``
    itself (an off-table limit still gets an exact-fit executable)."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    sizes = [b for b in SERVE_WARM_BATCHES if b <= max_batch]
    if not sizes or sizes[-1] != max_batch:
        sizes.append(max_batch)
    return tuple(sizes)


#: routed keys at/above this geometry get a trimmed warm ladder -- a
#: giant-N executable is hundreds of MB of compiled program, and a
#: multi-tenant router keeping the full (1, 2, 4, 8, 16) ladder for
#: every resident geometry would blow the executable budget the LRU
#: eviction exists to bound.
ROUTER_TRIM_N = 509


def router_warm_sizes(n: int, max_batch: int) -> tuple:
    """Warm batch sizes for one routed ``(geometry, dtype, datapath)``
    key: the full :func:`warm_batch_sizes` ladder for small geometries,
    trimmed to ``(1, max_batch)`` once ``n >= ROUTER_TRIM_N`` (padding
    waste is bounded by the batcher's coalescing at large N, executable
    residency is not)."""
    if n >= ROUTER_TRIM_N and max_batch > 1:
        return (1, int(max_batch))
    return warm_batch_sizes(max_batch)


def nearest_warm_batch(count: int, sizes) -> int:
    """Smallest warm size >= ``count`` (the padding target for one
    coalesced batch).  ``count`` above every size is a caller bug: the
    admission loop never collects more than the largest warm size."""
    for b in sizes:
        if b >= count:
            return int(b)
    raise ValueError(f"batch of {count} exceeds warm sizes {tuple(sizes)}")


def resolve_pipeline_blocks(n: int, itemsize: int = 4,
                            m_block=None, group=None) -> tuple[int, int]:
    """Fill missing pipeline (m_block, group) from the table, validate."""
    tm, tg = pipeline_block_spec(n, itemsize)
    mb = tm if m_block is None else int(m_block)
    k = tg if group is None else int(group)
    if mb < 1 or k < 1:
        raise ValueError(f"m_block/group must be >= 1, got {mb}/{k}")
    return mb, k
