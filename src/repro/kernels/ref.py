"""Pure-jnp oracles for the Pallas DPRT kernels (no Pallas imports)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def skew_sum_ref(g: jnp.ndarray, sign: int = 1) -> jnp.ndarray:
    """out[m, d] = sum_i g(i, <d + sign*m*i>_N), exact int32."""
    n = g.shape[0]
    gi = g.astype(jnp.int32)
    i = jnp.arange(n, dtype=jnp.int32)[:, None]
    d = jnp.arange(n, dtype=jnp.int32)[None, :]

    def one_direction(m):
        idx = (d + sign * m * i) % n
        return jnp.take_along_axis(gi, idx, axis=1).sum(axis=0)

    return jax.lax.map(one_direction, jnp.arange(n, dtype=jnp.int32),
                       batch_size=32)


def dprt_ref(f: jnp.ndarray) -> jnp.ndarray:
    """(N, N) -> (N+1, N) forward DPRT oracle."""
    core = skew_sum_ref(f, 1)
    return jnp.concatenate([core, f.astype(jnp.int32).sum(1)[None, :]], 0)


def idprt_ref(r: jnp.ndarray) -> jnp.ndarray:
    """(N+1, N) -> (N, N) inverse DPRT oracle (exact integer divide)."""
    n = r.shape[1]
    z = skew_sum_ref(r[:n], -1)
    s = r[0].astype(jnp.int32).sum()
    return (z - s + r[n].astype(jnp.int32)[:, None]) // n
