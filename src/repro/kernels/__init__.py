"""Pallas TPU kernels for the DPRT hot-spot (validated in interpret mode)."""
from .ops import dprt_pallas, idprt_pallas, skew_sum_pallas
from .ref import dprt_ref, idprt_ref, skew_sum_ref

__all__ = ["dprt_pallas", "idprt_pallas", "skew_sum_pallas",
           "dprt_ref", "idprt_ref", "skew_sum_ref"]
