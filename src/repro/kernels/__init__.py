"""Pallas TPU kernels for the DPRT hot-spot (validated in interpret mode).

The fused, batched SFDPRT kernel family lives in :mod:`.sfdprt`
(including the inverse CRS core ``isfdprt_core``, folded in from the
former ``kernels/isfdprt.py``); :mod:`.ops` wraps it with auto block
tuning (:mod:`.tuning`) and is what ``repro.core.dprt`` dispatches to
for ``method="pallas"``.  :func:`skew_sum_pallas_strip` is the
shard-local entry point the mesh-distributed ``sharded_pallas`` backend
(:mod:`repro.core.distributed`) runs per device.
"""
from .ops import (dprt_pallas, idprt_pallas, pipeline_tail_pallas,
                  projection_pipeline_pallas, skew_sum_pallas,
                  skew_sum_pallas_strip)
from .ref import dprt_ref, idprt_ref, skew_sum_ref
from .tuning import PALLAS_TUNE, PIPELINE_TUNE, pallas_block_spec, \
    pipeline_block_spec
from .sfdprt import isfdprt_core, roll_rows_ladder_spec

__all__ = ["dprt_pallas", "idprt_pallas", "skew_sum_pallas",
           "skew_sum_pallas_strip", "isfdprt_core",
           "projection_pipeline_pallas", "pipeline_tail_pallas",
           "dprt_ref", "idprt_ref", "skew_sum_ref",
           "PALLAS_TUNE", "pallas_block_spec", "roll_rows_ladder_spec",
           "PIPELINE_TUNE", "pipeline_block_spec"]
