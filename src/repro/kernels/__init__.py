"""Pallas TPU kernels for the DPRT hot-spot (validated in interpret mode).

The fused, batched SFDPRT kernel family lives in :mod:`.sfdprt`;
:mod:`.ops` wraps it with auto block tuning (:mod:`.tuning`) and is what
``repro.core.dprt`` dispatches to for ``method="pallas"``.
"""
from .ops import dprt_pallas, idprt_pallas, skew_sum_pallas
from .ref import dprt_ref, idprt_ref, skew_sum_ref
from .tuning import PALLAS_TUNE, pallas_block_spec
from .sfdprt import roll_rows_ladder_spec

__all__ = ["dprt_pallas", "idprt_pallas", "skew_sum_pallas",
           "dprt_ref", "idprt_ref", "skew_sum_ref",
           "PALLAS_TUNE", "pallas_block_spec", "roll_rows_ladder_spec"]
