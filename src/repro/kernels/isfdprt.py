"""Inverse SFDPRT Pallas kernel (iSFDPRT_core, paper Sec. III-C / Fig. 16).

The inverse core is the forward skew-sum with circular *right* shifts
(CRS replaces CLS): Z(i,j) = sum_m R(m, <j - i*m>_N) = skew_sum(R[:N], -1).
It shares the fused kernel family in :mod:`.sfdprt` with ``sign=-1``.

Since the fused-epilogue refactor the -S / +R(N,i) correction and the
exact divide-by-N (the paper's pipelined array divider) no longer live in
:mod:`repro.kernels.ops` as post-kernel passes -- they run *inside* the
kernel on the final strip (``mode="inverse"``); the full fused transform
is :func:`repro.kernels.sfdprt.idprt_pallas_raw`.  ``isfdprt_core`` below
remains the bare CRS core for callers that want the un-corrected Z.
"""
from __future__ import annotations

import functools

from .sfdprt import idprt_pallas_raw, skew_sum_pallas_raw

__all__ = ["isfdprt_core", "idprt_pallas_raw"]

isfdprt_core = functools.partial(skew_sum_pallas_raw, sign=-1)
