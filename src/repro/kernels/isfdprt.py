"""Inverse SFDPRT Pallas kernel (iSFDPRT_core, paper Sec. III-C / Fig. 16).

The inverse core is the forward skew-sum with circular *right* shifts
(CRS replaces CLS): Z(i,j) = sum_m R(m, <j - i*m>_N) = skew_sum(R[:N], -1).
It therefore shares the machinery in :mod:`.sfdprt` with ``sign=-1``; the
-S / +R(N,i) correction and the exact divide-by-N (the paper's pipelined
array divider) live in :func:`repro.kernels.ops.idprt_pallas`.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from .sfdprt import skew_sum_pallas_raw

__all__ = ["isfdprt_core"]

isfdprt_core = functools.partial(skew_sum_pallas_raw, sign=-1)
