"""Fused, batched Pallas TPU kernels for the scalable DPRT (SFDPRT core).

Maps the paper's SFDPRT_core / iSFDPRT_core (Fig. 2/8/16) onto a TPU as
one kernel family with three modes:

* ``core``    -- the bare skew-sum (used by :func:`skew_sum_pallas_raw`),
* ``forward`` -- skew-sum plus the fused R(N, d) row-sum epilogue: the
  extra projection is accumulated *while each strip is VMEM-resident*,
  eliminating the separate post-kernel pass over the image in HBM,
* ``inverse`` -- skew-sum with CRS (sign=-1) plus the fused
  ``(Z - S + R(N, i)) / N`` correction and exact divide (the paper's
  pipelined array divider, Sec. IV-B) applied on the final strip, so the
  reconstruction never round-trips through HBM before the epilogue.

A fourth family, the **projection-domain pipeline**
(:func:`pipeline_pallas_raw`, bottom of this module), chains
forward -> per-direction epilogue (1-D circular convolution / pointwise
multiply) -> inverse in ONE launch -- the Sec. I/VI convolution
application with the projections never leaving VMEM/registers.

Dataflow (per grid step):

* a strip of H image rows is the VMEM-resident register array
  (``BlockSpec((1, H, N))``); a leading *batch* grid dimension transforms
  a (B, N, N) stack in a single ``pallas_call`` (the FPGA-coprocessor
  throughput scenario of Sec. V-B),
* a block of M directions lives in the sublane axis of the accumulator,
* each Horner step ``T <- row_i + roll(T, m)`` is the paper's single
  clock cycle: circular-shift registers + adder tree,
* the per-direction roll amount m varies across sublanes, which TPUs
  cannot shift natively; it is synthesized with a ceil(log2 N)-step
  **binary roll-select ladder**: for each bit b of m, rotate the whole
  tile by the *static* amount 2^b (two lane slices + concat -- no
  gather, no index arithmetic) and select per sublane on bit b.

**Hoisted ladder setup.**  The per-step roll amount is constant per
direction across all H Horner steps, so all roll machinery -- for both
the step roll and the alignment roll R'(r,m,d) = U_r(<d + m*rH>) of
eq. (7) -- is precomputed ONCE per (m-block, strip) and closed over by
the ``fori_loop`` body.  On the TPU ``"ladder"`` datapath that setup is
the per-bit select masks (``(amt >> b) & 1``, :func:`ladder_select_masks`,
<= ceil(log2 N) mask derivations + alignment rotate+select pairs per
m-block); on the interpret/CPU ``"permute"`` lowering the permutations
are materialized directly in index space and the alignment is ONE
gather.  Nothing is re-derived on a Horner cycle; the loop body itself
is the paper's pure shift-add datapath.

**Shard-local partials.**  Every mode accepts ``rows < N`` inputs plus
a (possibly traced) ``row_offset`` scalar operand: the mesh-distributed
backend (:mod:`repro.core.distributed`) runs this kernel per device
over its local row super-strip, with the device's first global row
folded into the alignment roll amount at zero extra datapath cost.

**Lane padding.**  Off the interpret path the lane axis is padded to a
multiple of 128 so Mosaic tiling is aligned; every ladder rotate slices
at the *logical* N (``[s:n] ++ [:s] ++ [n:]``) so the circular wraparound
stays exact and the zero tail is preserved.

**Masked final m-block.**  Direction rows beyond N-1 in the last m-block
(the ``% N`` wrapped duplicates the seed kernel silently computed and
discarded) are masked to zero; in ``forward`` mode the first wasted slot
(global row N) is recycled to hold the fused R(N, d) row-sum.

Accumulators use :func:`repro.core.dprt.accum_dtype_for` (int32/int64/
float) rather than a hardcoded int32, so batched large-N integer inputs
cannot silently overflow.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dprt import accum_dtype_for

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None


def _tpu_compiler_params(dimension_semantics):
    """Compiler params across jax versions (CompilerParams vs
    TPUCompilerParams spelling), None when unavailable."""
    if pltpu is None:  # pragma: no cover
        return None
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:  # pragma: no cover
        return None
    try:
        return cls(dimension_semantics=dimension_semantics)
    except Exception:  # pragma: no cover
        return None


_COMPILER_PARAMS = _tpu_compiler_params(("parallel", "parallel", "arbitrary"))

__all__ = [
    "skew_sum_pallas_raw",
    "dprt_pallas_raw",
    "idprt_pallas_raw",
    "isfdprt_core",
    "roll_rows_ladder_spec",
    "ladder_select_masks",
    "apply_roll_ladder",
    "pipeline_pallas_raw",
    "PIPELINE_OPS",
]

LANE = 128  # TPU lane width; Mosaic tiles want the last axis % 128 == 0


def _num_bits(n: int) -> int:
    return max(1, math.ceil(math.log2(n)))


def _ladder_rungs(n: int):
    """Static rotate amounts 2^b < n used by the roll-select ladder."""
    return [1 << b for b in range(_num_bits(n)) if (1 << b) < n]


def roll_rows_ladder_spec(n: int) -> int:
    """Rotate+select pairs per variable roll (and per-block mask setups):
    the ladder issues ceil(log2 N) of each."""
    return _num_bits(n)


def ladder_select_masks(amt: jnp.ndarray, n: int):
    """Hoisted ladder setup: per-bit select masks for a (M, 1) roll amount.

    Computed once per m-block and closed over by the Horner loop body --
    this is the "setup" the paper amortizes across all H cycles of a
    strip (<= ceil(log2 N) shift+compare ops total, not per cycle).
    """
    return [((amt >> b) & 1) == 1 for b in range(len(_ladder_rungs(n)))]


def apply_roll_ladder(acc: jnp.ndarray, masks, n: int) -> jnp.ndarray:
    """out[j, d] = acc[j, <d + amt[j]>_n] for d < n, given hoisted masks.

    ``acc`` is (M, n_pad) with n_pad >= n; lanes >= n are a zero tail that
    is carried through unrotated (wraparound happens at the logical N).
    Every rotate is a static lane-slice pair, every select a per-sublane
    mask -- no gathers, no index arithmetic.
    """
    for b, sel in enumerate(masks):
        s = 1 << b
        rolled = jnp.concatenate([acc[:, s:n], acc[:, :s], acc[:, n:]],
                                 axis=1)
        acc = jnp.where(sel, rolled, acc)
    return acc


def _strip_block_partial(read_row, *, h: int, n: int, n_pad: int,
                         m_block: int, m_vec, valid, offset, sign: int,
                         step_impl: str, acc_dtype):
    """Aligned, masked partial skew-sum of ONE H-row strip for one m-block.

    This is the shared per-strip datapath of the fused (`_sfdprt_kernel`)
    and streamed (`_stream_grid_kernel` / `_stream_dma_kernel`) kernels:
    hoisted roll setup (per strip, not per cycle), H Horner cycles over
    ``read_row(j)`` (j = 0 is the strip's top row), the eq. (7)
    alignment roll for the strip's first global row ``offset`` (static
    or traced), and the wrapped-duplicate row mask.  ``step_impl`` picks
    the per-cycle roll realization (see :func:`_sfdprt_kernel`).
    """
    zero = jnp.zeros((), acc_dtype)
    step_amt = m_vec if sign > 0 else (n - m_vec) % n
    # reduce the offset mod N before the multiply: streamed/sharded
    # offsets can exceed N (row padding), so m_vec * offset alone could
    # overflow int32 near the top-end N; with the reduction
    # m_vec * (offset % N) <= (N-1)^2 < 2^31 for every supported N
    align_amt = jnp.mod(sign * m_vec * (offset % n), n)

    if step_impl == "permute":
        lane_iota = jax.lax.broadcasted_iota(jnp.int32, (m_block, n_pad), 1)
        in_tail = lane_iota >= n
        perm = jnp.where(in_tail, lane_iota, (lane_iota + step_amt) % n)
        align_perm = jnp.where(in_tail, lane_iota,
                               (lane_iota + align_amt) % n)
    else:
        step_sel = ladder_select_masks(step_amt, n)
        align_sel = ladder_select_masks(align_amt, n)

    def body(i, acc):
        # T_i = f(i, .) + roll(T_{i+1}, sign*m): one "clock cycle" -- the
        # roll consumes the precomputed masks/permutation.
        if step_impl == "permute":
            acc = jnp.take_along_axis(acc, perm, axis=1)
        else:
            acc = apply_roll_ladder(acc, step_sel, n)
        row = read_row(h - 1 - i)
        return acc + row[None, :].astype(acc.dtype)

    acc = jax.lax.fori_loop(0, h, body,
                            jnp.zeros((m_block, n_pad), acc_dtype))

    # alignment roll: R'(r, m, d) = U_r(<d + sign*m*rH>_n)   (eq. 7)
    if step_impl == "permute":
        acc = jnp.take_along_axis(acc, align_perm, axis=1)
    else:
        acc = apply_roll_ladder(acc, align_sel, n)
    return jnp.where(valid, acc, zero)


def _sfdprt_kernel(f_ref, *rest, n: int, n_pad: int, h: int, m_block: int,
                   sign: int, k_steps: int, mode: str, acc_dtype,
                   step_impl: str, with_offset: bool = False):
    """One (batch, m-block, strip) grid step of the fused SFDPRT.

    Grid is (B, MB, K) with K innermost ("arbitrary"): for a fixed
    (batch, m-block) the output block stays resident while strips
    accumulate into it -- the paper's MEM_OUT (eq. 8).

    ``step_impl`` picks how each Horner cycle realizes the hoisted roll:

    * ``"ladder"``  -- re-apply the rotate+select ladder with the
      precomputed masks every cycle (the TPU datapath: static lane
      slices + per-sublane selects, no gathers -- Mosaic-friendly),
    * ``"permute"`` -- materialize the step AND alignment permutations
      directly in index space ONCE per m-block (setup only), then apply
      one ``take_along_axis`` per cycle plus ONE for the eq. (7)
      alignment (the interpret/CPU lowering, where a gather is cheap and
      per-cycle -- or per-short-strip -- ladder passes are not).

    ``with_offset`` threads a (1, 1) scalar operand holding the strip's
    first *global* image row (the mesh-sharded path: each device's local
    row block starts at ``axis_index * rows_per_dev``, a traced value).
    The offset merely shifts the alignment ladder's roll amount
    (eq. 7 with rH -> row_offset + rH) -- zero extra datapath work.
    """
    rest = list(rest)
    off_ref = rest.pop(0) if with_offset else None
    if mode == "inverse":
        corr_ref, out_ref = rest
    else:
        (out_ref,) = rest
    mb = pl.program_id(1)
    k = pl.program_id(2)

    zero = jnp.zeros((), acc_dtype)
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (m_block, 1), 0)
    grow = mb * m_block + row_iota            # global output row
    valid = grow < n                          # mask wrapped-duplicate rows
    m_vec = jnp.where(valid, grow, 0)

    # ---- hoisted ladder setup + H Horner cycles + eq. (7) alignment ------
    # (the shared per-strip datapath; the "permute" lowering hoists the
    # step AND alignment permutations into index space ONCE per m-block)
    offset = k * h                            # strip's first global row rH
    if with_offset:                           # shard-local: + the block's
        offset = offset + off_ref[0, 0]       # first global image row
    acc = _strip_block_partial(
        lambda j: f_ref[0, j, :], h=h, n=n, n_pad=n_pad, m_block=m_block,
        m_vec=m_vec, valid=valid, offset=offset, sign=sign,
        step_impl=step_impl, acc_dtype=acc_dtype)

    @pl.when(k == 0)
    def _init():
        out_ref[0] = acc

    @pl.when(k > 0)
    def _accum():
        out_ref[0] = out_ref[0] + acc

    if mode == "forward":
        # Fused epilogue: R(N, d) = sum_j f(d, j).  Each strip owns the
        # disjoint lane range [rH, rH+H); its row-sums are placed there and
        # dropped into the recycled slot row N while the strip is in VMEM.
        # Only the (static) m-block that holds global row N pays for it.
        @pl.when(mb == n // m_block)
        def _rowsum():
            rsum = jnp.sum(f_ref[0].astype(acc_dtype), axis=1, keepdims=True)
            lane = jax.lax.broadcasted_iota(jnp.int32, (h, n_pad), 1)
            srow = jax.lax.broadcasted_iota(jnp.int32, (h, n_pad), 0)
            placed = jnp.sum(jnp.where(lane == offset + srow, rsum, zero),
                             axis=0)
            out_ref[0] = out_ref[0] + jnp.where(grow == n, placed[None, :],
                                                zero)

    if mode == "inverse":
        # Fused epilogue on the last strip: f = (Z - S + R(N, i)) / N with
        # corr[i] = R(N, i) - S precomputed per row; exact integer divide
        # (the paper's pipelined array divider, Sec. IV-B).
        @pl.when(k == k_steps - 1)
        def _epilogue():
            total = out_ref[0] + corr_ref[0].astype(acc_dtype)
            if jnp.issubdtype(jnp.dtype(acc_dtype), jnp.integer):
                res = total // n
            else:
                res = total / n
            out_ref[0] = jnp.where(valid, res, zero)


def _pallas_skew_call(g: jnp.ndarray, *, sign: int, mode: str,
                      strip_rows: int, m_block: int, interpret: bool,
                      corr: jnp.ndarray | None = None,
                      lane_pad: bool | None = None,
                      step_impl: str | None = None,
                      row_offset: jnp.ndarray | int | None = None
                      ) -> jnp.ndarray:
    """Shared fused pallas_call: g is (B, rows, N) already in the
    accumulator dtype (rows == N for whole images; rows < N for a
    shard-local row strip); returns (B, R, n_pad) with
    R = ceil(out_rows/m_block)*m_block -- callers slice to the logical
    output.

    ``lane_pad`` (default: pad iff compiled) rounds the lane axis up to a
    128-multiple for Mosaic tile alignment; it is overridable so the
    wraparound-at-logical-N path is testable in interpret mode.
    ``step_impl`` (default: "permute" in interpret mode, "ladder"
    compiled) picks the per-cycle roll realization -- see
    :func:`_sfdprt_kernel`.  ``row_offset`` (static or traced scalar)
    is the first *global* image row of ``g``'s row block -- the
    shard-local partial of the mesh path; it feeds the alignment ladder
    only (core mode).
    """
    b, rows, n = g.shape
    acc_dtype = g.dtype
    h = max(1, min(int(strip_rows), rows))
    k_steps = math.ceil(rows / h)
    if lane_pad is None:
        lane_pad = not interpret
    if step_impl is None:
        step_impl = "permute" if interpret else "ladder"
    n_pad = ((n + LANE - 1) // LANE) * LANE if lane_pad else n
    out_rows = n + 1 if mode == "forward" else n
    r_blocks = math.ceil(out_rows / m_block)

    gp = jnp.pad(g, ((0, 0), (0, k_steps * h - rows), (0, n_pad - n)))
    in_specs = [pl.BlockSpec((1, h, n_pad), lambda bb, i, j: (bb, j, 0))]
    operands = [gp]
    with_offset = row_offset is not None
    if with_offset:
        off = jnp.asarray(row_offset, jnp.int32).reshape(1, 1)
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, i, j: (0, 0)))
        operands.append(off)
    if mode == "inverse":
        corr_p = jnp.pad(corr.astype(acc_dtype),
                         ((0, 0), (0, r_blocks * m_block - n)))[..., None]
        in_specs.append(pl.BlockSpec((1, m_block, 1),
                                     lambda bb, i, j: (bb, i, 0)))
        operands.append(corr_p)

    return pl.pallas_call(
        functools.partial(_sfdprt_kernel, n=n, n_pad=n_pad, h=h,
                          m_block=m_block, sign=sign, k_steps=k_steps,
                          mode=mode, acc_dtype=acc_dtype,
                          step_impl=step_impl, with_offset=with_offset),
        grid=(b, r_blocks, k_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, m_block, n_pad),
                               lambda bb, i, j: (bb, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r_blocks * m_block, n_pad),
                                       acc_dtype),
        compiler_params=None if interpret else _COMPILER_PARAMS,
        interpret=interpret,
    )(*operands)


# ===========================================================================
# In-launch strip streaming (the giant-N path).
#
# The fused kernel above holds one (1, H, N) strip in VMEM per grid step
# and revisits the output block across the innermost strip dimension --
# fine while ceil(N/H) output revisits are free (they stay VMEM-resident)
# but it leans on the BlockSpec pipeline for every strip fetch and keeps
# the whole (B, N, N) operand eligible for pipelining.  For images that
# do NOT fit whole-image-in-VMEM (N >= 2048) the streamed variants below
# process the image as ONE ``pallas_call`` with an explicit strip loop
# and a VMEM scratch accumulator:
#
# * ``stream_impl="grid"`` -- the strip loop stays a grid dimension, but
#   partial skew-sums accumulate into a VMEM scratch tile; ``out_ref`` is
#   written exactly once, on the final strip (the interpret/CPU
#   emulation of the DMA path: block-indexed strip fetches, identical
#   numerics and revisit structure),
# * ``stream_impl="dma"`` -- the operand stays in HBM
#   (``memory_space=ANY``); the kernel drives its own strip loop with
#   double-buffered ``pltpu.make_async_copy`` HBM->VMEM copies (2 strip
#   slots + 2 DMA semaphores): strip k+1's copy is launched before strip
#   k is consumed, so the Horner datapath hides the HBM fetch latency
#   (the Mosaic path).  Exactly ONE strip buffer pair is live regardless
#   of ceil(N/H) -- memory is O(H*N), not O(N^2).
#
# Both variants replace the plan layer's scan-of-launches ``block_rows``
# fallback on pallas-capable backends: one launch, one jaxpr, partial
# sums never round-tripping through HBM between strips.
# ===========================================================================


def _stream_grid_kernel(f_ref, *rest, n: int, n_pad: int, h: int,
                        m_block: int, sign: int, k_steps: int, mode: str,
                        acc_dtype, step_impl: str, with_offset: bool):
    """One (batch, m-block, strip) step of the streamed kernel, strip loop
    on the grid: partial skew-sums accumulate in a VMEM scratch tile and
    ``out_ref`` is written once, on the final strip."""
    rest = list(rest)
    off_ref = rest.pop(0) if with_offset else None
    corr_ref = rest.pop(0) if mode == "inverse" else None
    out_ref, acc_ref = rest
    mb = pl.program_id(1)
    k = pl.program_id(2)

    zero = jnp.zeros((), acc_dtype)
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (m_block, 1), 0)
    grow = mb * m_block + row_iota
    valid = grow < n
    m_vec = jnp.where(valid, grow, 0)
    offset = k * h
    if with_offset:
        offset = offset + off_ref[0, 0]

    acc = _strip_block_partial(
        lambda j: f_ref[0, j, :], h=h, n=n, n_pad=n_pad, m_block=m_block,
        m_vec=m_vec, valid=valid, offset=offset, sign=sign,
        step_impl=step_impl, acc_dtype=acc_dtype)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = acc

    @pl.when(k > 0)
    def _accum():
        acc_ref[...] = acc_ref[...] + acc

    if mode == "forward":
        # fused R(N, d) epilogue: this strip owns lanes [offset, offset+H)
        @pl.when(mb == n // m_block)
        def _rowsum():
            rsum = jnp.sum(f_ref[0].astype(acc_dtype), axis=1, keepdims=True)
            lane = jax.lax.broadcasted_iota(jnp.int32, (h, n_pad), 1)
            srow = jax.lax.broadcasted_iota(jnp.int32, (h, n_pad), 0)
            placed = jnp.sum(jnp.where(lane == offset + srow, rsum, zero),
                             axis=0)
            acc_ref[...] = acc_ref[...] + jnp.where(
                grow == n, placed[None, :], zero)

    @pl.when(k == k_steps - 1)
    def _flush():
        total = acc_ref[...]
        if mode == "inverse":
            total = total + corr_ref[0].astype(acc_dtype)
            if jnp.issubdtype(jnp.dtype(acc_dtype), jnp.integer):
                res = total // n
            else:
                res = total / n
            out_ref[0] = jnp.where(valid, res, zero)
        else:
            out_ref[0] = total


def _stream_dma_kernel(f_ref, *rest, n: int, n_pad: int, h: int,
                       m_block: int, sign: int, k_steps: int, mode: str,
                       acc_dtype, step_impl: str, with_offset: bool):
    """One (batch, m-block) step of the streamed kernel, strip loop in
    the kernel: the operand stays in HBM (``memory_space=ANY``) and the
    ``fori_loop`` below double-buffers H-row strips into a 2-slot VMEM
    scratch with ``make_async_copy`` -- strip k+1's DMA is started before
    strip k's partial skew-sum runs, so compute hides the fetch."""
    rest = list(rest)
    off_ref = rest.pop(0) if with_offset else None
    corr_ref = rest.pop(0) if mode == "inverse" else None
    out_ref, buf_ref, sem_ref = rest
    bb = pl.program_id(0)
    mb = pl.program_id(1)

    zero = jnp.zeros((), acc_dtype)
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (m_block, 1), 0)
    grow = mb * m_block + row_iota
    valid = grow < n
    m_vec = jnp.where(valid, grow, 0)
    off0 = off_ref[0, 0] if with_offset else 0

    def copy_in(slot, k):
        return pltpu.make_async_copy(
            f_ref.at[bb, pl.ds(k * h, h), :],
            buf_ref.at[slot],
            sem_ref.at[slot])

    copy_in(0, 0).start()

    def body(k, acc):
        slot = jax.lax.rem(k, 2)

        @pl.when(k + 1 < k_steps)
        def _prefetch():                       # overlap: next strip's DMA
            copy_in(jax.lax.rem(k + 1, 2), k + 1).start()

        copy_in(slot, k).wait()
        offset = k * h + off0
        acc = acc + _strip_block_partial(
            lambda j: buf_ref[slot, j, :], h=h, n=n, n_pad=n_pad,
            m_block=m_block, m_vec=m_vec, valid=valid, offset=offset,
            sign=sign, step_impl=step_impl, acc_dtype=acc_dtype)
        if mode == "forward":
            # fused R(N, d) epilogue while the strip is VMEM-resident;
            # mb is traced here (loop-carried value, not a ref), so the
            # owning-block condition folds into the placement mask
            rsum = jnp.sum(buf_ref[slot].astype(acc_dtype), axis=1,
                           keepdims=True)
            lane = jax.lax.broadcasted_iota(jnp.int32, (h, n_pad), 1)
            srow = jax.lax.broadcasted_iota(jnp.int32, (h, n_pad), 0)
            placed = jnp.sum(jnp.where(lane == offset + srow, rsum, zero),
                             axis=0)
            owns = jnp.logical_and(mb == n // m_block, grow == n)
            acc = acc + jnp.where(owns, placed[None, :], zero)
        return acc

    acc = jax.lax.fori_loop(0, k_steps, body,
                            jnp.zeros((m_block, n_pad), acc_dtype))

    if mode == "inverse":
        total = acc + corr_ref[0].astype(acc_dtype)
        if jnp.issubdtype(jnp.dtype(acc_dtype), jnp.integer):
            res = total // n
        else:
            res = total / n
        out_ref[0] = jnp.where(valid, res, zero)
    else:
        out_ref[0] = acc


def _pallas_stream_call(g: jnp.ndarray, *, sign: int, mode: str,
                        stream_rows: int, m_block: int, interpret: bool,
                        corr: jnp.ndarray | None = None,
                        lane_pad: bool | None = None,
                        step_impl: str | None = None,
                        stream_impl: str | None = None,
                        row_offset: jnp.ndarray | int | None = None
                        ) -> jnp.ndarray:
    """Streamed fused pallas_call: like :func:`_pallas_skew_call` but the
    strip loop accumulates into a VMEM scratch (``stream_impl="grid"``)
    or is driven in-kernel with double-buffered HBM->VMEM DMA copies
    (``stream_impl="dma"``, default off-interpret).  ``stream_rows`` is
    the streamed strip height H; VMEM footprint is O(m_block*N + H*N)
    per grid step regardless of ceil(N/H)."""
    if pltpu is None:  # pragma: no cover - pltpu import failed
        raise RuntimeError("streamed SFDPRT kernels need pallas TPU "
                           "support (jax.experimental.pallas.tpu)")
    b, rows, n = g.shape
    acc_dtype = g.dtype
    h = max(1, min(int(stream_rows), rows))
    k_steps = math.ceil(rows / h)
    if lane_pad is None:
        lane_pad = not interpret
    if step_impl is None:
        step_impl = "permute" if interpret else "ladder"
    if stream_impl is None:
        stream_impl = "grid" if interpret else "dma"
    if stream_impl not in ("grid", "dma"):
        raise ValueError(f"stream_impl must be 'grid' or 'dma': "
                         f"{stream_impl!r}")
    n_pad = ((n + LANE - 1) // LANE) * LANE if lane_pad else n
    out_rows = n + 1 if mode == "forward" else n
    r_blocks = math.ceil(out_rows / m_block)
    grid_rank = 3 if stream_impl == "grid" else 2

    gp = jnp.pad(g, ((0, 0), (0, k_steps * h - rows), (0, n_pad - n)))
    if stream_impl == "grid":
        in_specs = [pl.BlockSpec((1, h, n_pad), lambda bb, i, j: (bb, j, 0))]
    else:
        # the operand never enters the BlockSpec pipeline: it stays in
        # HBM and the kernel DMAs strips on its own schedule
        in_specs = [pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)]
    operands = [gp]
    with_offset = row_offset is not None
    if with_offset:
        off = jnp.asarray(row_offset, jnp.int32).reshape(1, 1)
        in_specs.append(pl.BlockSpec(
            (1, 1), (lambda bb, i, j: (0, 0)) if grid_rank == 3
            else (lambda bb, i: (0, 0))))
        operands.append(off)
    if mode == "inverse":
        corr_p = jnp.pad(corr.astype(acc_dtype),
                         ((0, 0), (0, r_blocks * m_block - n)))[..., None]
        in_specs.append(pl.BlockSpec(
            (1, m_block, 1), (lambda bb, i, j: (bb, i, 0)) if grid_rank == 3
            else (lambda bb, i: (bb, i, 0))))
        operands.append(corr_p)

    kw = dict(n=n, n_pad=n_pad, h=h, m_block=m_block, sign=sign,
              k_steps=k_steps, mode=mode, acc_dtype=acc_dtype,
              step_impl=step_impl, with_offset=with_offset)
    if stream_impl == "grid":
        kernel = functools.partial(_stream_grid_kernel, **kw)
        grid = (b, r_blocks, k_steps)
        out_spec = pl.BlockSpec((1, m_block, n_pad),
                                lambda bb, i, j: (bb, i, 0))
        scratch = [pltpu.VMEM((m_block, n_pad), acc_dtype)]
        cparams = None if interpret else _COMPILER_PARAMS
    else:
        kernel = functools.partial(_stream_dma_kernel, **kw)
        grid = (b, r_blocks)
        out_spec = pl.BlockSpec((1, m_block, n_pad), lambda bb, i: (bb, i, 0))
        # exactly ONE double-buffer pair, however many strips stream
        scratch = [pltpu.VMEM((2, h, n_pad), acc_dtype),
                   pltpu.SemaphoreType.DMA((2,))]
        cparams = None if interpret else _tpu_compiler_params(
            ("parallel", "arbitrary"))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, r_blocks * m_block, n_pad),
                                       acc_dtype),
        scratch_shapes=scratch,
        compiler_params=cparams,
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit,
                   static_argnames=("sign", "strip_rows", "m_block",
                                    "interpret", "step_impl",
                                    "stream_rows", "stream_impl"))
def skew_sum_pallas_raw(g: jnp.ndarray, sign: int = 1, strip_rows: int = 16,
                        m_block: int = 8, interpret: bool = True,
                        step_impl: str | None = None,
                        row_offset=None, stream_rows: int | None = None,
                        stream_impl: str | None = None) -> jnp.ndarray:
    """Bare skew_sum via the strip kernel (core mode, no fused epilogue).

    g: (rows, N) or a batched (B, rows, N) stack, N prime.  Returns the
    same rank (N direction rows) in the accumulator dtype with
    out[..., m, d] = sum_i g(..., i, <d + sign*m*(row_offset+i)>_N); a
    stack runs in ONE pallas_call via the kernel's leading batch grid
    dimension (this is the datapath the exact-adjoint/VJP rules ride).
    Wrapped-duplicate direction rows in the final m-block are masked
    (never computed as "useful" output) and sliced away.

    ``rows < N`` with a (possibly traced) ``row_offset`` computes the
    *partial* skew-sum of a row strip aligned to global rows -- the
    shard-local entry of the mesh-distributed path (eq. 7 with the
    device's first global row folded into the alignment ladder).
    """
    single = g.ndim == 2
    gb = g[None] if single else g
    n = gb.shape[-1]
    ga = gb.astype(accum_dtype_for(g.dtype, n))
    if stream_rows is not None:
        out = _pallas_stream_call(ga, sign=sign, mode="core",
                                  stream_rows=stream_rows, m_block=m_block,
                                  interpret=interpret, step_impl=step_impl,
                                  stream_impl=stream_impl,
                                  row_offset=row_offset)
    else:
        out = _pallas_skew_call(ga, sign=sign, mode="core",
                                strip_rows=strip_rows, m_block=m_block,
                                interpret=interpret, step_impl=step_impl,
                                row_offset=row_offset)
    out = out[:, :n, :n]
    return out[0] if single else out


@functools.partial(jax.jit,
                   static_argnames=("strip_rows", "m_block", "interpret",
                                    "step_impl", "stream_rows",
                                    "stream_impl"))
def dprt_pallas_raw(f: jnp.ndarray, strip_rows: int = 16, m_block: int = 8,
                    interpret: bool = True,
                    step_impl: str | None = None,
                    row_offset=None, stream_rows: int | None = None,
                    stream_impl: str | None = None) -> jnp.ndarray:
    """Fused batched forward DPRT: (B, N, N) -> (B, N+1, N) in ONE
    pallas_call; the R(N, d) row-sum row is produced by the in-kernel
    epilogue rather than a second pass over the image.

    With ``rows < N`` and a ``row_offset`` this is the *partial* forward
    of a shard-local row strip: both the skew-sum directions AND the
    fused row-sum row carry the device's global row placement, so one
    cross-device ``psum`` of the partials is the exact full transform.
    """
    _, _, n = f.shape
    fa = f.astype(accum_dtype_for(f.dtype, n))
    if stream_rows is not None:
        out = _pallas_stream_call(fa, sign=1, mode="forward",
                                  stream_rows=stream_rows, m_block=m_block,
                                  interpret=interpret, step_impl=step_impl,
                                  stream_impl=stream_impl,
                                  row_offset=row_offset)
    else:
        out = _pallas_skew_call(fa, sign=1, mode="forward",
                                strip_rows=strip_rows, m_block=m_block,
                                interpret=interpret, step_impl=step_impl,
                                row_offset=row_offset)
    return out[:, :n + 1, :n]


@functools.partial(jax.jit,
                   static_argnames=("strip_rows", "m_block", "interpret",
                                    "step_impl", "stream_rows",
                                    "stream_impl"))
def idprt_pallas_raw(r: jnp.ndarray, strip_rows: int = 16, m_block: int = 8,
                     interpret: bool = True,
                     step_impl: str | None = None,
                     stream_rows: int | None = None,
                     stream_impl: str | None = None) -> jnp.ndarray:
    """Fused batched inverse DPRT: (B, N+1, N) -> (B, N, N) in ONE
    pallas_call; the -S + R(N, i) correction and exact divide-by-N run
    in-kernel on the final strip (no post-kernel pass)."""
    _, _, n = r.shape
    acc = accum_dtype_for(r.dtype, n)
    ra = r.astype(acc)
    corr = ra[:, n, :] - ra[:, 0, :].sum(axis=1, keepdims=True)
    if stream_rows is not None:
        out = _pallas_stream_call(ra[:, :n, :], sign=-1, mode="inverse",
                                  stream_rows=stream_rows, m_block=m_block,
                                  interpret=interpret, corr=corr,
                                  step_impl=step_impl,
                                  stream_impl=stream_impl)
    else:
        out = _pallas_skew_call(ra[:, :n, :], sign=-1, mode="inverse",
                                strip_rows=strip_rows, m_block=m_block,
                                interpret=interpret, corr=corr,
                                step_impl=step_impl)
    return out[:, :n, :n]


# The inverse core (iSFDPRT_core, paper Sec. III-C / Fig. 16) is the
# forward skew-sum with circular *right* shifts: CRS == sign=-1.  The
# -S / +R(N,i) correction and exact divide-by-N run in-kernel in
# :func:`idprt_pallas_raw` (``mode="inverse"``); this alias is the bare
# un-corrected Z for callers that want it (formerly kernels/isfdprt.py).
isfdprt_core = functools.partial(skew_sum_pallas_raw, sign=-1)


# ===========================================================================
# Projection-domain pipeline: forward -> per-direction op -> inverse in ONE
# kernel launch (the conv/DFT fusion of the paper's Sec. I/VI application).
#
# Grid is (lane-group, m-block): each step forward-skew-sums the whole image
# for one block of directions (optionally the second conv operand too),
# applies the per-direction epilogue IN REGISTERS -- a Horner-style 1-D
# circular convolution against the operand's projections ("conv"), or a
# pointwise projection-domain multiply ("mul") -- and immediately feeds the
# block's direction rows through the inverse skew-sum ladder onto the full
# output image.  The (N+1, N) projections never exist outside VMEM/registers;
# MEM_OUT is only ever the final (N, N) image.
#
# The -S + R'(N, i) correction and exact /N divide need two *global* rows of
# the convolved projections (row 0 for S, row N for the correction column);
# they are accumulated into a tiny ``aux`` output block as their owning
# m-blocks pass through, and the final m-block applies the whole correction
# in-kernel -- or leaves it to the caller (``defer=True``, the mesh-sharded
# path, where the division must wait for the cross-device ``psum``).
#
# **Batch-in-lanes.**  A batched stack packs ``lane_batch`` images side by
# side along the lane axis (segment s owns lanes [s*n_pad, (s+1)*n_pad));
# every roll/gather/select then acts per segment, so transforming LB images
# costs the same op count as one image with LB-times-wider tiles -- the
# layout that keeps the CPU-interpret path from paying per-image dispatch
# overhead.  On TPU, ``lane_batch=1`` recovers the per-image grid.
#
# **Tail mode** (``source="proj"``).  The input rows are already-assembled
# projection rows (a shard of directions, first global direction
# ``row_offset``); the kernel applies the epilogue and the inverse ladder
# for those directions only.  This is the second (per-shard) launch of the
# mesh-distributed pipeline: forward partials are psum_scatter'd over
# directions between the two launches -- the single collective between
# forward and inverse.
# ===========================================================================

PIPELINE_OPS = ("none", "mul", "conv")


def _seg_perm(amt, n: int, n_pad: int, lb: int, rows_out: int) -> jnp.ndarray:
    """Per-segment rotation gather index for a wide (rows_out, lb*n_pad)
    tile: idx[r, s*n_pad + d] = s*n_pad + <d + amt[r]>_n for d < n,
    identity on each segment's zero tail.  ``amt`` is (rows_out, 1) in
    [0, n)."""
    d = jax.lax.broadcasted_iota(jnp.int32, (1, 1, n_pad), 2)
    base = jax.lax.broadcasted_iota(jnp.int32, (1, lb, 1), 1) * n_pad
    rot = d + amt[:, :, None]                 # (rows_out, 1, n_pad)
    rot = jnp.where(rot >= n, rot - n, rot)
    rot = jnp.where(d >= n, d, rot)
    return jnp.broadcast_to(rot + base, (rows_out, lb, n_pad)).reshape(
        rows_out, lb * n_pad)


def _seg_roller(amt, n: int, n_pad: int, lb: int, rows_out: int,
                step_impl: str):
    """The hoisted per-step roll for a wide tile: a closure applying
    out[r, s, d] = acc[r, s, <d + amt[r]>_n].  ``"permute"`` materializes
    ONE gather index (interpret/CPU); ``"ladder"`` uses the binary
    rotate+select ladder per segment (static lane slices -- Mosaic)."""
    if step_impl == "permute":
        idx = _seg_perm(amt, n, n_pad, lb, rows_out)

        def roll(acc):
            return jnp.take_along_axis(acc, idx, axis=1)
    else:
        masks = [m[:, :, None] for m in ladder_select_masks(amt, n)]

        def roll(acc):
            a3 = acc.reshape(rows_out, lb, n_pad)
            for b, sel in enumerate(masks):
                s = 1 << b
                rolled = jnp.concatenate(
                    [a3[:, :, s:n], a3[:, :, :s], a3[:, :, n:]], axis=2)
                a3 = jnp.where(sel, rolled, a3)
            return a3.reshape(rows_out, lb * n_pad)
    return roll


def _seg_roll_static(acc3: jnp.ndarray, k: int, n: int) -> jnp.ndarray:
    """Rotate every segment of a (rows, lb, n_pad) tile right by the
    *static* amount k at logical width n (zero tails carried through)."""
    if k == 0:
        return acc3
    return jnp.concatenate(
        [acc3[:, :, n - k:n], acc3[:, :, :n - k], acc3[:, :, n:]], axis=2)


def _conv_epilogue(rf: jnp.ndarray, rg3: jnp.ndarray, n: int, n_pad: int,
                   lb: int, group: int, acc_dtype) -> jnp.ndarray:
    """In-register per-direction 1-D circular convolution (Horner form):

        rc[m, s, d] = sum_t rf[m, s, t] * rg[m, s|0, <d - t>_n]

    K taps are consumed per cycle against K statically pre-rotated copies
    of the operand rows, so the loop body is K multiply-adds plus ONE
    static rotate-by-K of the accumulator -- no gathers, no index math.
    """
    m_block = rf.shape[0]
    k = max(1, min(group, n - 1))
    rf3 = rf.reshape(m_block, lb, n_pad)
    rgs = [rg3]
    for _ in range(1, k):
        rgs.append(_seg_roll_static(rgs[-1], 1, n))
    nk = math.ceil(n / k)
    if nk * k > n_pad:        # taps beyond the lane pad: zero (rf tail is 0)
        rf3 = jnp.pad(rf3, ((0, 0), (0, 0), (0, nk * k - n_pad)))

    def body(j, acc):
        t0 = (nk - 1 - j) * k
        acc = _seg_roll_static(acc, k, n)
        fts = jax.lax.dynamic_slice(rf3, (0, 0, t0), (m_block, lb, k))
        for u in range(k):
            acc = acc + fts[:, :, u:u + 1] * rgs[u]
        return acc

    acc = jnp.zeros((m_block, lb, n_pad), acc_dtype)
    return jax.lax.fori_loop(0, nk, body, acc).reshape(m_block, lb * n_pad)


def _pipeline_kernel(*refs, n: int, n_pad: int, rows: int, m_block: int,
                     nr_pad: int, mb_total: int, lb: int, op: str,
                     source: str, operand_form: str, w_wide: bool,
                     defer: bool, acc_dtype, group: int, step_impl: str,
                     with_offset: bool):
    """One (lane-group, m-block) grid step of the fused pipeline."""
    refs = list(refs)
    off_ref = refs.pop(0) if with_offset else None
    f_ref = refs.pop(0)
    g_ref = refs.pop(0) if (op == "conv" and operand_form == "image") else None
    w_ref = refs.pop(0) if (op == "mul" or (op == "conv"
                                            and operand_form == "proj")) \
        else None
    out_ref, aux_ref = refs

    mb = pl.program_id(1)
    zero = jnp.zeros((), acc_dtype)
    wide = lb * n_pad

    row_iota = jax.lax.broadcasted_iota(jnp.int32, (m_block, 1), 0)
    dir0 = mb * m_block
    if with_offset:
        dir0 = dir0 + off_ref[0, 0]
    grow = dir0 + row_iota                    # global direction row
    valid_fwd = grow < n
    m_vec = jnp.where(valid_fwd, grow, 0)
    last = mb == mb_total - 1

    # ---- forward stage: whole-rows Horner per direction block ------------
    def fwd_of(x_ref):
        roll = _seg_roller(m_vec, n, n_pad, lb, m_block, step_impl)

        def body(i, acc):
            row = x_ref[0, rows - 1 - i, :]
            return roll(acc) + row[None, :].astype(acc_dtype)

        acc = jax.lax.fori_loop(0, rows, body,
                                jnp.zeros((m_block, wide), acc_dtype))
        return jnp.where(valid_fwd, acc, zero)

    def rowsum_of(x_ref):
        # R(N, d): each image row's sum placed at its own lane -- per
        # segment -- and dropped into the grow == n direction slot.
        x3 = x_ref[0].reshape(rows, lb, n_pad)
        lane = jax.lax.broadcasted_iota(jnp.int32, (rows, 1, n_pad), 2)
        rsum = jnp.sum(jnp.where(lane < n, x3.astype(acc_dtype), zero),
                       axis=2)[:, :, None]               # (rows, lb, 1)
        srow = jax.lax.broadcasted_iota(jnp.int32, (rows, 1, n_pad), 0)
        placed = jnp.sum(jnp.where(lane == srow, rsum, zero),
                         axis=0).reshape(1, wide)        # (1, lb*n_pad)
        return jnp.where(grow == n, placed, zero)

    # the row-sum row lives in exactly one m-block; pay for its placement
    # there alone (a traced condition in tail mode, static otherwise)
    def with_rowsum(r, x_ref):
        owns = jnp.logical_and(dir0 <= n, n < dir0 + m_block)
        return jax.lax.cond(owns, lambda v: v + rowsum_of(x_ref),
                            lambda v: v, r)

    if source == "proj":
        rf = jnp.where(grow <= n, f_ref[0].astype(acc_dtype), zero)
    else:
        rf = with_rowsum(fwd_of(f_ref), f_ref)

    # ---- per-direction epilogue ------------------------------------------
    def w_block3():
        """This block's operand rows as (m_block, lb|1, n_pad).

        In tail mode the operand block holds ALL direction rows (the
        shard's window is traced), so slice at the global dir0; clamped
        overreads only feed rows that are zero-masked through ``rf``.
        """
        width = wide if w_wide else n_pad
        if source == "proj":
            rows_w = jax.lax.dynamic_slice(w_ref[0], (dir0, 0),
                                           (m_block, width))
        else:           # blockspec already selected this m-block's rows
            rows_w = w_ref[0]
        rows_w = rows_w.astype(acc_dtype)
        if w_wide:
            return rows_w.reshape(m_block, lb, n_pad)
        return rows_w[:, None, :]

    if op == "conv":
        if operand_form == "image":
            rg3 = with_rowsum(fwd_of(g_ref), g_ref).reshape(
                m_block, lb, n_pad)
        else:
            rg3 = w_block3()
        rc = _conv_epilogue(rf, rg3, n, n_pad, lb, group, acc_dtype)
    elif op == "mul":
        rc = (rf.reshape(m_block, lb, n_pad) * w_block3()).reshape(
            m_block, wide)
    else:
        rc = rf

    # ---- stash the correction rows (row 0 -> S, row N -> column) ---------
    aux = jnp.stack([
        jnp.sum(jnp.where(grow == 0, rc, zero), axis=0),
        jnp.sum(jnp.where(grow == n, rc, zero), axis=0),
    ])

    @pl.when(mb == 0)
    def _aux_init():
        aux_ref[0, :2] = aux

    @pl.when(mb > 0)
    def _aux_accum():
        aux_ref[0, :2] = aux_ref[0, :2] + aux

    # ---- inverse stage: this block's directions onto ALL image rows ------
    # The output rows are processed in cache-sized sub-blocks (the same
    # tile height the dedicated inverse kernel tunes to): one (IB, wide)
    # accumulator + its gather index stay resident per sub-block instead
    # of a single (nr_pad, wide) mega-tile thrashing L2.
    rcm = jnp.where(valid_fwd, rc, zero)
    ib_rows = min(64, nr_pad)
    zs = []
    for i0 in range(0, nr_pad, ib_rows):
        rows_ib = min(ib_rows, nr_pad - i0)
        i_iota = i0 + jax.lax.broadcasted_iota(jnp.int32, (rows_ib, 1), 0)
        i_valid = i_iota < n
        i_vec = jnp.where(i_valid, i_iota, 0)
        neg_i = jnp.where(i_vec == 0, 0, n - i_vec)
        roll_inv = _seg_roller(neg_i, n, n_pad, lb, rows_ib, step_impl)

        def ibody(t, acc):
            return roll_inv(acc) + rcm[m_block - 1 - t, :][None, :]

        z = jax.lax.fori_loop(0, m_block, ibody,
                              jnp.zeros((rows_ib, wide), acc_dtype))
        # alignment: the Horner above assumed the block's first direction
        # is 0; roll each output row i by <-i * dir0>_n (eq. 7, m -> i)
        align_amt = jnp.mod(-i_vec * (dir0 % n), n)
        z = _seg_roller(align_amt, n, n_pad, lb, rows_ib, step_impl)(z)
        zs.append(jnp.where(i_valid, z, zero))
    z = jnp.concatenate(zs, axis=0) if len(zs) > 1 else zs[0]

    @pl.when(mb == 0)
    def _init():
        out_ref[0] = z

    @pl.when(mb > 0)
    def _accum():
        out_ref[0] = out_ref[0] + z

    if not defer:
        @pl.when(last)
        def _final():
            # f = (Z - S + R'(N, i)) / N per segment, exact for integers
            aux3 = aux_ref[0, :2].reshape(2, lb, n_pad)
            lane = jax.lax.broadcasted_iota(jnp.int32, (nr_pad, 1, n_pad), 2)
            srow = jax.lax.broadcasted_iota(jnp.int32, (nr_pad, 1, n_pad), 0)
            s = jnp.sum(jnp.where(lane[0] < n, aux3[0], zero),
                        axis=1)[None, :, None]            # (1, lb, 1)
            cn = jnp.sum(jnp.where(lane == srow, aux3[1][None], zero),
                         axis=2, keepdims=True)           # (nr_pad, lb, 1)
            num = out_ref[0].reshape(nr_pad, lb, n_pad) - s + cn
            if jnp.issubdtype(jnp.dtype(acc_dtype), jnp.integer):
                res = num // n
            else:
                res = num / n
            keep = (srow < n) & (lane < n)
            out_ref[0] = jnp.where(keep, res, zero).reshape(nr_pad, wide)


def _pack_lanes(x: jnp.ndarray, lb: int, n_pad: int) -> jnp.ndarray:
    """(B, rows, N) -> (ceil(B/lb), rows, lb*n_pad) batch-in-lanes layout
    (zero images pad the last group; zero lane tails pad each segment)."""
    b, rows, n = x.shape
    bg = math.ceil(b / lb)
    x = jnp.pad(x, ((0, bg * lb - b), (0, 0), (0, n_pad - n)))
    return jnp.transpose(x.reshape(bg, lb, rows, n_pad),
                         (0, 2, 1, 3)).reshape(bg, rows, lb * n_pad)


def _unpack_lanes(y: jnp.ndarray, b: int, lb: int, n_pad: int) -> jnp.ndarray:
    """(BG, rows, lb*n_pad) -> (B, rows, n_pad): inverse of _pack_lanes."""
    bg, rows, _ = y.shape
    y = jnp.transpose(y.reshape(bg, rows, lb, n_pad), (0, 2, 1, 3))
    return y.reshape(bg * lb, rows, n_pad)[:b]


@functools.partial(
    jax.jit, static_argnames=("op", "operand_form", "source", "m_block",
                              "group", "lane_batch", "defer", "interpret",
                              "step_impl", "n_rows"))
def pipeline_pallas_raw(f: jnp.ndarray, operand: jnp.ndarray | None = None,
                        op: str = "none", operand_form: str = "proj",
                        source: str = "image", m_block: int = 32,
                        group: int = 4, lane_batch: int = 1,
                        defer: bool = False, interpret: bool = True,
                        step_impl: str | None = None,
                        row_offset=None, n_rows: int | None = None
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The fused projection-domain pipeline in ONE ``pallas_call``.

    ``f``: (B, N, N) image stack (``source="image"``) or a (B, rows, N)
    shard of already-assembled projection rows (``source="proj"``, first
    global direction ``row_offset`` -- the mesh tail).  ``operand``:
    the second conv operand as images (B|1, N, N), its projections
    (B|1, N+1, N), or pointwise projection-domain weights (B|1, N+1, N),
    depending on (op, operand_form); batched operands must match ``f``'s
    batch.  Returns ``(out, aux)`` where out is (B, nr_pad, n_pad) --
    the reconstruction (or, with ``defer=True``, the raw inverse-ladder
    partial Z) -- and aux is (B, 2, n_pad) holding the convolved rows 0
    and N for the deferred -S + R'(N, i) correction.  Callers slice to
    (…, N, N).  ``n_rows`` is the transform size N when ``source="proj"``
    rows don't imply it.
    """
    if op not in PIPELINE_OPS:
        raise ValueError(f"pipeline op must be one of {PIPELINE_OPS}: {op!r}")
    b, rows, n = f.shape
    if source == "proj":
        n = f.shape[-1] if n_rows is None else n_rows
    acc_dtype = f.dtype
    lb = max(1, min(int(lane_batch), b))
    if step_impl is None:
        step_impl = "permute" if interpret else "ladder"
    lane_pad = not interpret
    n_pad = ((n + LANE - 1) // LANE) * LANE if lane_pad else n
    nr_pad = ((n + 7) // 8) * 8
    bg = math.ceil(b / lb)
    wide = lb * n_pad

    if source == "proj":
        mb_total = math.ceil(rows / m_block)
        rows_pad = mb_total * m_block
        fp4 = jnp.pad(f, ((0, bg * lb - b), (0, rows_pad - rows),
                          (0, n_pad - n)))
        fp = jnp.transpose(fp4.reshape(bg, lb, rows_pad, n_pad),
                           (0, 2, 1, 3)).reshape(bg, rows_pad, wide)
        in_specs = [pl.BlockSpec((1, m_block, wide),
                                 lambda bb, i: (bb, i, 0))]
        defer = True                      # correction needs the global psum
    else:
        mb_total = math.ceil((n + 1) / m_block)
        fp = _pack_lanes(f, lb, n_pad)
        in_specs = [pl.BlockSpec((1, rows, wide), lambda bb, i: (bb, 0, 0))]

    operands = [fp]
    with_offset = row_offset is not None
    if with_offset:
        off = jnp.asarray(row_offset, jnp.int32).reshape(1, 1)
        in_specs.insert(0, pl.BlockSpec((1, 1), lambda bb, i: (0, 0)))
        operands.insert(0, off)

    w_wide = False
    if op == "conv" and operand_form == "image":
        gb = operand
        if gb.shape[0] == b:
            gp = _pack_lanes(gb, lb, n_pad)
            in_specs.append(pl.BlockSpec((1, rows, wide),
                                         lambda bb, i: (bb, 0, 0)))
        else:   # one shared operand image, tiled across segments
            gp = _pack_lanes(jnp.broadcast_to(gb, (lb, *gb.shape[1:])),
                             lb, n_pad)
            in_specs.append(pl.BlockSpec((1, rows, wide),
                                         lambda bb, i: (0, 0, 0)))
        operands.append(gp.astype(acc_dtype))
    elif op == "mul" or (op == "conv" and operand_form == "proj"):
        wb = operand if operand.ndim == 3 else operand[None]
        # pad the direction rows with m_block slack so the (traced) tail
        # window slice stays in bounds; clamped overreads feed rows that
        # are zero-masked through rf either way
        w_rows = math.ceil((wb.shape[1] + m_block) / m_block) * m_block
        if wb.shape[0] == b and b > 1:
            w_wide = True
            wp = jnp.pad(wb, ((0, bg * lb - b), (0, w_rows - wb.shape[1]),
                              (0, n_pad - n)))
            wp = jnp.transpose(wp.reshape(bg, lb, w_rows, n_pad),
                               (0, 2, 1, 3)).reshape(bg, w_rows, wide)
            if source == "proj":
                in_specs.append(pl.BlockSpec((1, w_rows, wide),
                                             lambda bb, i: (bb, 0, 0)))
            else:
                in_specs.append(pl.BlockSpec((1, m_block, wide),
                                             lambda bb, i: (bb, i, 0)))
        else:
            wp = jnp.pad(wb[0], ((0, w_rows - wb.shape[1]),
                                 (0, n_pad - n)))[None]
            if source == "proj":
                in_specs.append(pl.BlockSpec((1, w_rows, n_pad),
                                             lambda bb, i: (0, 0, 0)))
            else:
                in_specs.append(pl.BlockSpec((1, m_block, n_pad),
                                             lambda bb, i: (0, i, 0)))
        operands.append(wp.astype(acc_dtype))

    cparams = None if interpret else _tpu_compiler_params(
        ("parallel", "arbitrary"))

    out, aux = pl.pallas_call(
        functools.partial(
            _pipeline_kernel, n=n, n_pad=n_pad, rows=rows,
            m_block=m_block, nr_pad=nr_pad, mb_total=mb_total, lb=lb,
            op=op, source=source, operand_form=operand_form, w_wide=w_wide,
            defer=defer, acc_dtype=acc_dtype, group=group,
            step_impl=step_impl, with_offset=with_offset),
        grid=(bg, mb_total),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((1, nr_pad, wide), lambda bb, i: (bb, 0, 0)),
                   pl.BlockSpec((1, 8, wide), lambda bb, i: (bb, 0, 0))),
        out_shape=(jax.ShapeDtypeStruct((bg, nr_pad, wide), acc_dtype),
                   jax.ShapeDtypeStruct((bg, 8, wide), acc_dtype)),
        compiler_params=cparams,
        interpret=interpret,
    )(*operands)
    return (_unpack_lanes(out, b, lb, n_pad),
            _unpack_lanes(aux, b, lb, n_pad)[:, :2])
