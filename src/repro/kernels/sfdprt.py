"""Fused, batched Pallas TPU kernels for the scalable DPRT (SFDPRT core).

Maps the paper's SFDPRT_core / iSFDPRT_core (Fig. 2/8/16) onto a TPU as
one kernel family with three modes:

* ``core``    -- the bare skew-sum (used by :func:`skew_sum_pallas_raw`),
* ``forward`` -- skew-sum plus the fused R(N, d) row-sum epilogue: the
  extra projection is accumulated *while each strip is VMEM-resident*,
  eliminating the separate post-kernel pass over the image in HBM,
* ``inverse`` -- skew-sum with CRS (sign=-1) plus the fused
  ``(Z - S + R(N, i)) / N`` correction and exact divide (the paper's
  pipelined array divider, Sec. IV-B) applied on the final strip, so the
  reconstruction never round-trips through HBM before the epilogue.

Dataflow (per grid step):

* a strip of H image rows is the VMEM-resident register array
  (``BlockSpec((1, H, N))``); a leading *batch* grid dimension transforms
  a (B, N, N) stack in a single ``pallas_call`` (the FPGA-coprocessor
  throughput scenario of Sec. V-B),
* a block of M directions lives in the sublane axis of the accumulator,
* each Horner step ``T <- row_i + roll(T, m)`` is the paper's single
  clock cycle: circular-shift registers + adder tree,
* the per-direction roll amount m varies across sublanes, which TPUs
  cannot shift natively; it is synthesized with a ceil(log2 N)-step
  **binary roll-select ladder**: for each bit b of m, rotate the whole
  tile by the *static* amount 2^b (two lane slices + concat -- no
  gather, no index arithmetic) and select per sublane on bit b.

**Hoisted ladder setup.**  The per-step roll amount is constant per
direction across all H Horner steps, so all roll machinery -- for both
the step roll and the alignment roll R'(r,m,d) = U_r(<d + m*rH>) of
eq. (7) -- is precomputed ONCE per (m-block, strip) and closed over by
the ``fori_loop`` body.  On the TPU ``"ladder"`` datapath that setup is
the per-bit select masks (``(amt >> b) & 1``, :func:`ladder_select_masks`,
<= ceil(log2 N) mask derivations + alignment rotate+select pairs per
m-block); on the interpret/CPU ``"permute"`` lowering the permutations
are materialized directly in index space and the alignment is ONE
gather.  Nothing is re-derived on a Horner cycle; the loop body itself
is the paper's pure shift-add datapath.

**Shard-local partials.**  Every mode accepts ``rows < N`` inputs plus
a (possibly traced) ``row_offset`` scalar operand: the mesh-distributed
backend (:mod:`repro.core.distributed`) runs this kernel per device
over its local row super-strip, with the device's first global row
folded into the alignment roll amount at zero extra datapath cost.

**Lane padding.**  Off the interpret path the lane axis is padded to a
multiple of 128 so Mosaic tiling is aligned; every ladder rotate slices
at the *logical* N (``[s:n] ++ [:s] ++ [n:]``) so the circular wraparound
stays exact and the zero tail is preserved.

**Masked final m-block.**  Direction rows beyond N-1 in the last m-block
(the ``% N`` wrapped duplicates the seed kernel silently computed and
discarded) are masked to zero; in ``forward`` mode the first wasted slot
(global row N) is recycled to hold the fused R(N, d) row-sum.

Accumulators use :func:`repro.core.dprt.accum_dtype_for` (int32/int64/
float) rather than a hardcoded int32, so batched large-N integer inputs
cannot silently overflow.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dprt import accum_dtype_for

try:  # compiler params spelling differs across jax versions
    from jax.experimental.pallas import tpu as pltpu
    _COMPILER_PARAMS = pltpu.CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary"))
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None

__all__ = [
    "skew_sum_pallas_raw",
    "dprt_pallas_raw",
    "idprt_pallas_raw",
    "isfdprt_core",
    "roll_rows_ladder_spec",
    "ladder_select_masks",
    "apply_roll_ladder",
]

LANE = 128  # TPU lane width; Mosaic tiles want the last axis % 128 == 0


def _num_bits(n: int) -> int:
    return max(1, math.ceil(math.log2(n)))


def _ladder_rungs(n: int):
    """Static rotate amounts 2^b < n used by the roll-select ladder."""
    return [1 << b for b in range(_num_bits(n)) if (1 << b) < n]


def roll_rows_ladder_spec(n: int) -> int:
    """Rotate+select pairs per variable roll (and per-block mask setups):
    the ladder issues ceil(log2 N) of each."""
    return _num_bits(n)


def ladder_select_masks(amt: jnp.ndarray, n: int):
    """Hoisted ladder setup: per-bit select masks for a (M, 1) roll amount.

    Computed once per m-block and closed over by the Horner loop body --
    this is the "setup" the paper amortizes across all H cycles of a
    strip (<= ceil(log2 N) shift+compare ops total, not per cycle).
    """
    return [((amt >> b) & 1) == 1 for b in range(len(_ladder_rungs(n)))]


def apply_roll_ladder(acc: jnp.ndarray, masks, n: int) -> jnp.ndarray:
    """out[j, d] = acc[j, <d + amt[j]>_n] for d < n, given hoisted masks.

    ``acc`` is (M, n_pad) with n_pad >= n; lanes >= n are a zero tail that
    is carried through unrotated (wraparound happens at the logical N).
    Every rotate is a static lane-slice pair, every select a per-sublane
    mask -- no gathers, no index arithmetic.
    """
    for b, sel in enumerate(masks):
        s = 1 << b
        rolled = jnp.concatenate([acc[:, s:n], acc[:, :s], acc[:, n:]],
                                 axis=1)
        acc = jnp.where(sel, rolled, acc)
    return acc


def _sfdprt_kernel(f_ref, *rest, n: int, n_pad: int, h: int, m_block: int,
                   sign: int, k_steps: int, mode: str, acc_dtype,
                   step_impl: str, with_offset: bool = False):
    """One (batch, m-block, strip) grid step of the fused SFDPRT.

    Grid is (B, MB, K) with K innermost ("arbitrary"): for a fixed
    (batch, m-block) the output block stays resident while strips
    accumulate into it -- the paper's MEM_OUT (eq. 8).

    ``step_impl`` picks how each Horner cycle realizes the hoisted roll:

    * ``"ladder"``  -- re-apply the rotate+select ladder with the
      precomputed masks every cycle (the TPU datapath: static lane
      slices + per-sublane selects, no gathers -- Mosaic-friendly),
    * ``"permute"`` -- materialize the step AND alignment permutations
      directly in index space ONCE per m-block (setup only), then apply
      one ``take_along_axis`` per cycle plus ONE for the eq. (7)
      alignment (the interpret/CPU lowering, where a gather is cheap and
      per-cycle -- or per-short-strip -- ladder passes are not).

    ``with_offset`` threads a (1, 1) scalar operand holding the strip's
    first *global* image row (the mesh-sharded path: each device's local
    row block starts at ``axis_index * rows_per_dev``, a traced value).
    The offset merely shifts the alignment ladder's roll amount
    (eq. 7 with rH -> row_offset + rH) -- zero extra datapath work.
    """
    rest = list(rest)
    off_ref = rest.pop(0) if with_offset else None
    if mode == "inverse":
        corr_ref, out_ref = rest
    else:
        (out_ref,) = rest
    mb = pl.program_id(1)
    k = pl.program_id(2)

    zero = jnp.zeros((), acc_dtype)
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (m_block, 1), 0)
    grow = mb * m_block + row_iota            # global output row
    valid = grow < n                          # mask wrapped-duplicate rows
    m_vec = jnp.where(valid, grow, 0)

    # ---- hoisted ladder setup: ONCE per (m-block, strip) -----------------
    step_amt = m_vec if sign > 0 else (n - m_vec) % n
    offset = k * h                            # strip's first global row rH
    if with_offset:                           # shard-local: + the block's
        offset = offset + off_ref[0, 0]       # first global image row
    # reduce the offset mod N before the multiply: the sharded offset can
    # exceed N (row padding on the last device), so m_vec * offset alone
    # could overflow int32 near the top-end N; with the reduction
    # m_vec * (offset % N) <= (N-1)^2 < 2^31 for every supported N
    align_amt = jnp.mod(sign * m_vec * (offset % n), n)

    if step_impl == "permute":
        # Hoisted setup, interpret/CPU lowering: the step AND alignment
        # permutations are materialized directly in index space --
        # perm[j, d] = <d + amt_j>_n for d < n, identity on the zero
        # tail -- so the Horner cycles below do zero rotate+select work
        # and the eq. (7) alignment is ONE gather of the accumulator
        # (short shard-local strips cannot amortize ladder passes over
        # the accumulator; index setup is O(log N)-free here because a
        # gather is cheap on this path).
        lane_iota = jax.lax.broadcasted_iota(jnp.int32, (m_block, n_pad), 1)
        in_tail = lane_iota >= n
        perm = jnp.where(in_tail, lane_iota, (lane_iota + step_amt) % n)
        align_perm = jnp.where(in_tail, lane_iota,
                               (lane_iota + align_amt) % n)
    else:
        step_sel = ladder_select_masks(step_amt, n)
        align_sel = ladder_select_masks(align_amt, n)

    def body(i, acc):
        # T_i = f(i, .) + roll(T_{i+1}, sign*m): one "clock cycle" -- the
        # roll consumes the precomputed masks/permutation, no
        # (amt >> b) & 1 here.
        if step_impl == "permute":
            acc = jnp.take_along_axis(acc, perm, axis=1)
        else:
            acc = apply_roll_ladder(acc, step_sel, n)
        row = f_ref[0, h - 1 - i, :]
        return acc + row[None, :].astype(acc.dtype)

    acc = jnp.zeros((m_block, n_pad), acc_dtype)
    acc = jax.lax.fori_loop(0, h, body, acc)

    # alignment roll: R'(r, m, d) = U_r(<d + sign*m*rH>_n)   (eq. 7)
    if step_impl == "permute":
        acc = jnp.take_along_axis(acc, align_perm, axis=1)
    else:
        acc = apply_roll_ladder(acc, align_sel, n)
    acc = jnp.where(valid, acc, zero)

    @pl.when(k == 0)
    def _init():
        out_ref[0] = acc

    @pl.when(k > 0)
    def _accum():
        out_ref[0] = out_ref[0] + acc

    if mode == "forward":
        # Fused epilogue: R(N, d) = sum_j f(d, j).  Each strip owns the
        # disjoint lane range [rH, rH+H); its row-sums are placed there and
        # dropped into the recycled slot row N while the strip is in VMEM.
        # Only the (static) m-block that holds global row N pays for it.
        @pl.when(mb == n // m_block)
        def _rowsum():
            rsum = jnp.sum(f_ref[0].astype(acc_dtype), axis=1, keepdims=True)
            lane = jax.lax.broadcasted_iota(jnp.int32, (h, n_pad), 1)
            srow = jax.lax.broadcasted_iota(jnp.int32, (h, n_pad), 0)
            placed = jnp.sum(jnp.where(lane == offset + srow, rsum, zero),
                             axis=0)
            out_ref[0] = out_ref[0] + jnp.where(grow == n, placed[None, :],
                                                zero)

    if mode == "inverse":
        # Fused epilogue on the last strip: f = (Z - S + R(N, i)) / N with
        # corr[i] = R(N, i) - S precomputed per row; exact integer divide
        # (the paper's pipelined array divider, Sec. IV-B).
        @pl.when(k == k_steps - 1)
        def _epilogue():
            total = out_ref[0] + corr_ref[0].astype(acc_dtype)
            if jnp.issubdtype(jnp.dtype(acc_dtype), jnp.integer):
                res = total // n
            else:
                res = total / n
            out_ref[0] = jnp.where(valid, res, zero)


def _pallas_skew_call(g: jnp.ndarray, *, sign: int, mode: str,
                      strip_rows: int, m_block: int, interpret: bool,
                      corr: jnp.ndarray | None = None,
                      lane_pad: bool | None = None,
                      step_impl: str | None = None,
                      row_offset: jnp.ndarray | int | None = None
                      ) -> jnp.ndarray:
    """Shared fused pallas_call: g is (B, rows, N) already in the
    accumulator dtype (rows == N for whole images; rows < N for a
    shard-local row strip); returns (B, R, n_pad) with
    R = ceil(out_rows/m_block)*m_block -- callers slice to the logical
    output.

    ``lane_pad`` (default: pad iff compiled) rounds the lane axis up to a
    128-multiple for Mosaic tile alignment; it is overridable so the
    wraparound-at-logical-N path is testable in interpret mode.
    ``step_impl`` (default: "permute" in interpret mode, "ladder"
    compiled) picks the per-cycle roll realization -- see
    :func:`_sfdprt_kernel`.  ``row_offset`` (static or traced scalar)
    is the first *global* image row of ``g``'s row block -- the
    shard-local partial of the mesh path; it feeds the alignment ladder
    only (core mode).
    """
    b, rows, n = g.shape
    acc_dtype = g.dtype
    h = max(1, min(int(strip_rows), rows))
    k_steps = math.ceil(rows / h)
    if lane_pad is None:
        lane_pad = not interpret
    if step_impl is None:
        step_impl = "permute" if interpret else "ladder"
    n_pad = ((n + LANE - 1) // LANE) * LANE if lane_pad else n
    out_rows = n + 1 if mode == "forward" else n
    r_blocks = math.ceil(out_rows / m_block)

    gp = jnp.pad(g, ((0, 0), (0, k_steps * h - rows), (0, n_pad - n)))
    in_specs = [pl.BlockSpec((1, h, n_pad), lambda bb, i, j: (bb, j, 0))]
    operands = [gp]
    with_offset = row_offset is not None
    if with_offset:
        off = jnp.asarray(row_offset, jnp.int32).reshape(1, 1)
        in_specs.append(pl.BlockSpec((1, 1), lambda bb, i, j: (0, 0)))
        operands.append(off)
    if mode == "inverse":
        corr_p = jnp.pad(corr.astype(acc_dtype),
                         ((0, 0), (0, r_blocks * m_block - n)))[..., None]
        in_specs.append(pl.BlockSpec((1, m_block, 1),
                                     lambda bb, i, j: (bb, i, 0)))
        operands.append(corr_p)

    return pl.pallas_call(
        functools.partial(_sfdprt_kernel, n=n, n_pad=n_pad, h=h,
                          m_block=m_block, sign=sign, k_steps=k_steps,
                          mode=mode, acc_dtype=acc_dtype,
                          step_impl=step_impl, with_offset=with_offset),
        grid=(b, r_blocks, k_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, m_block, n_pad),
                               lambda bb, i, j: (bb, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r_blocks * m_block, n_pad),
                                       acc_dtype),
        compiler_params=None if interpret else _COMPILER_PARAMS,
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit,
                   static_argnames=("sign", "strip_rows", "m_block",
                                    "interpret", "step_impl"))
def skew_sum_pallas_raw(g: jnp.ndarray, sign: int = 1, strip_rows: int = 16,
                        m_block: int = 8, interpret: bool = True,
                        step_impl: str | None = None,
                        row_offset=None) -> jnp.ndarray:
    """Bare skew_sum via the strip kernel (core mode, no fused epilogue).

    g: (rows, N) or a batched (B, rows, N) stack, N prime.  Returns the
    same rank (N direction rows) in the accumulator dtype with
    out[..., m, d] = sum_i g(..., i, <d + sign*m*(row_offset+i)>_N); a
    stack runs in ONE pallas_call via the kernel's leading batch grid
    dimension (this is the datapath the exact-adjoint/VJP rules ride).
    Wrapped-duplicate direction rows in the final m-block are masked
    (never computed as "useful" output) and sliced away.

    ``rows < N`` with a (possibly traced) ``row_offset`` computes the
    *partial* skew-sum of a row strip aligned to global rows -- the
    shard-local entry of the mesh-distributed path (eq. 7 with the
    device's first global row folded into the alignment ladder).
    """
    single = g.ndim == 2
    gb = g[None] if single else g
    n = gb.shape[-1]
    out = _pallas_skew_call(gb.astype(accum_dtype_for(g.dtype)), sign=sign,
                            mode="core", strip_rows=strip_rows,
                            m_block=m_block, interpret=interpret,
                            step_impl=step_impl, row_offset=row_offset)
    out = out[:, :n, :n]
    return out[0] if single else out


@functools.partial(jax.jit,
                   static_argnames=("strip_rows", "m_block", "interpret",
                                    "step_impl"))
def dprt_pallas_raw(f: jnp.ndarray, strip_rows: int = 16, m_block: int = 8,
                    interpret: bool = True,
                    step_impl: str | None = None,
                    row_offset=None) -> jnp.ndarray:
    """Fused batched forward DPRT: (B, N, N) -> (B, N+1, N) in ONE
    pallas_call; the R(N, d) row-sum row is produced by the in-kernel
    epilogue rather than a second pass over the image.

    With ``rows < N`` and a ``row_offset`` this is the *partial* forward
    of a shard-local row strip: both the skew-sum directions AND the
    fused row-sum row carry the device's global row placement, so one
    cross-device ``psum`` of the partials is the exact full transform.
    """
    _, _, n = f.shape
    out = _pallas_skew_call(f.astype(accum_dtype_for(f.dtype)), sign=1,
                            mode="forward", strip_rows=strip_rows,
                            m_block=m_block, interpret=interpret,
                            step_impl=step_impl, row_offset=row_offset)
    return out[:, :n + 1, :n]


@functools.partial(jax.jit,
                   static_argnames=("strip_rows", "m_block", "interpret",
                                    "step_impl"))
def idprt_pallas_raw(r: jnp.ndarray, strip_rows: int = 16, m_block: int = 8,
                     interpret: bool = True,
                     step_impl: str | None = None) -> jnp.ndarray:
    """Fused batched inverse DPRT: (B, N+1, N) -> (B, N, N) in ONE
    pallas_call; the -S + R(N, i) correction and exact divide-by-N run
    in-kernel on the final strip (no post-kernel pass)."""
    _, _, n = r.shape
    acc = accum_dtype_for(r.dtype)
    ra = r.astype(acc)
    corr = ra[:, n, :] - ra[:, 0, :].sum(axis=1, keepdims=True)
    out = _pallas_skew_call(ra[:, :n, :], sign=-1, mode="inverse",
                            strip_rows=strip_rows, m_block=m_block,
                            interpret=interpret, corr=corr,
                            step_impl=step_impl)
    return out[:, :n, :n]


# The inverse core (iSFDPRT_core, paper Sec. III-C / Fig. 16) is the
# forward skew-sum with circular *right* shifts: CRS == sign=-1.  The
# -S / +R(N,i) correction and exact divide-by-N run in-kernel in
# :func:`idprt_pallas_raw` (``mode="inverse"``); this alias is the bare
# un-corrected Z for callers that want it (formerly kernels/isfdprt.py).
isfdprt_core = functools.partial(skew_sum_pallas_raw, sign=-1)
