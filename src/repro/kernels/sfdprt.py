"""Pallas TPU kernel for the scalable DPRT skew-sum (SFDPRT core).

Maps the paper's SFDPRT_core (Fig. 2/8) onto a TPU:

* a strip of H image rows is the VMEM-resident register array
  (``BlockSpec((H, N))``),
* a block of M directions lives in the sublane axis of the accumulator,
* each Horner step ``T <- row_i + roll(T, m)`` is the paper's single
  clock cycle: circular-shift registers + adder tree,
* the per-direction roll amount m varies across sublanes, which TPUs
  cannot shift natively; it is synthesized with a ceil(log2 N)-step
  **binary roll-select ladder**: for each bit b of m, rotate the whole
  tile by the *static* amount 2^b (two lane slices + concat -- no
  gather, no index arithmetic) and select per sublane on bit b.
* strips are grid steps that revisit and accumulate into the output
  block -- the paper's MEM_OUT accumulator (eq. 8); the alignment roll
  R'(r,m,d) = U_r(<d + m*rH>) uses the same ladder.

The same kernel computes the inverse core with ``sign=-1`` (CLS -> CRS,
Sec. III-C).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # compiler params spelling differs across jax versions
    from jax.experimental.pallas import tpu as pltpu
    _COMPILER_PARAMS = pltpu.CompilerParams(
        dimension_semantics=("parallel", "arbitrary"))
except Exception:  # pragma: no cover
    _COMPILER_PARAMS = None

__all__ = ["skew_sum_pallas_raw", "roll_rows_ladder_spec"]


def _num_bits(n: int) -> int:
    return max(1, math.ceil(math.log2(n)))


def roll_rows_ladder_spec(n: int) -> int:
    """Ops per variable roll: the ladder issues ceil(log2 N) rot+sel pairs."""
    return _num_bits(n)


def _roll_rows(acc: jnp.ndarray, amt: jnp.ndarray, n: int) -> jnp.ndarray:
    """out[j, d] = acc[j, <d + amt[j]>_n] via static-shift rotate + select.

    ``acc`` is (M, n); ``amt`` is (M, 1) int32 in [0, n).  Every rotate is a
    static lane slice pair, every select a per-sublane mask -- no gathers.
    """
    for b in range(_num_bits(n)):
        s = 1 << b
        if s >= n:
            break
        rolled = jnp.concatenate([acc[:, s:], acc[:, :s]], axis=1)
        bit = (amt >> b) & 1
        acc = jnp.where(bit == 1, rolled, acc)
    return acc


def _sfdprt_kernel(f_ref, out_ref, *, n: int, h: int, m_block: int,
                   sign: int):
    mb = pl.program_id(0)
    k = pl.program_id(1)

    iota = jax.lax.broadcasted_iota(jnp.int32, (m_block, 1), 0)
    m_vec = (mb * m_block + iota) % n          # directions of this block
    step_amt = m_vec if sign > 0 else (n - m_vec) % n

    def body(i, acc):
        # T_i = f(i, .) + roll(T_{i+1}, sign*m):  one "clock cycle".
        acc = _roll_rows(acc, step_amt, n)
        row = f_ref[h - 1 - i, :]
        return acc + row[None, :].astype(acc.dtype)

    acc = jnp.zeros((m_block, n), jnp.int32)
    acc = jax.lax.fori_loop(0, h, body, acc)

    # alignment roll: R'(r, m, d) = U_r(<d + sign*m*rH>_n)   (eq. 7)
    offset = k * h
    align_amt = jnp.mod(sign * m_vec * offset, n)
    acc = _roll_rows(acc, align_amt, n)

    # MEM_OUT accumulation across strips (eq. 8)
    @pl.when(k == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(k > 0)
    def _accum():
        out_ref[...] += acc


@functools.partial(jax.jit,
                   static_argnames=("sign", "strip_rows", "m_block",
                                    "interpret"))
def skew_sum_pallas_raw(g: jnp.ndarray, sign: int = 1, strip_rows: int = 16,
                        m_block: int = 8,
                        interpret: bool = True) -> jnp.ndarray:
    """skew_sum via the Pallas strip kernel.

    g: (N, N) int array, N prime.  Returns (N, N) int32 with
    out[m, d] = sum_i g(i, <d + sign*m*i>_N).
    """
    n = g.shape[0]
    h = min(int(strip_rows), n)
    k = math.ceil(n / h)
    mb = math.ceil(n / m_block)

    gp = jnp.pad(g.astype(jnp.int32), ((0, k * h - n), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_sfdprt_kernel, n=n, h=h, m_block=m_block,
                          sign=sign),
        grid=(mb, k),
        in_specs=[pl.BlockSpec((h, n), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((m_block, n), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mb * m_block, n), jnp.int32),
        compiler_params=None if interpret else _COMPILER_PARAMS,
        interpret=interpret,
    )(gp)
    return out[:n]
