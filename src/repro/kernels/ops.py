"""Public jit'd wrappers around the fused Pallas DPRT kernels.

This is the layer ``repro.core.dprt`` dispatches to for
``method="pallas"``: each wrapper accepts a single (N, N) image or a
batched (B, N, N) stack (transformed in ONE ``pallas_call`` via the
kernel's leading batch grid dimension), resolves block shapes through
the :mod:`.tuning` table when not given explicitly, and uses
:func:`repro.core.dprt.accum_dtype_for` for overflow-safe accumulators
(int64 inputs stay int64, never silently truncated to int32).

The forward/inverse epilogues (R(N, d) row-sum; -S + R(N, i) correction
plus exact divide-by-N) are fused *inside* the kernel -- see
:mod:`.sfdprt` -- so there are no post-kernel passes here, only slicing.

``interpret`` defaults to auto: Pallas interpret mode off-TPU (this
container is CPU-only), compiled Mosaic on real TPUs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dprt import accum_dtype_for, is_prime
from .sfdprt import (dprt_pallas_raw, idprt_pallas_raw, skew_sum_pallas_raw)
from .tuning import resolve_blocks

__all__ = ["dprt_pallas", "idprt_pallas", "skew_sum_pallas",
           "skew_sum_pallas_strip", "dprt_pallas_strip"]


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _resolve_blocks(n: int, strip_rows: Optional[int],
                    m_block: Optional[int], dtype) -> tuple[int, int]:
    # delegate to the shared resolver so the plan layer ("auto") and
    # direct pallas calls agree on block shapes.  Deliberately does NOT
    # consult the ambient radon.config scope: these wrappers may run
    # inside a caller's jit trace, where a scope read would be baked
    # into the cached executable and replayed after the scope exits.
    # Ambient knobs apply at (eager) plan/operator construction instead.
    return resolve_blocks(n, jnp.dtype(accum_dtype_for(dtype)).itemsize,
                          strip_rows, m_block)


def skew_sum_pallas(g: jnp.ndarray, sign: int = 1,
                    strip_rows: Optional[int] = None,
                    m_block: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Bare skew-sum: (N, N), or a (B, N, N) stack in ONE pallas_call.

    The batched form serves the plan layer's batched-native adjoint
    datapath (exact VJPs through ``method="pallas"``) as well as the
    core-mode tests.
    """
    h, mb = _resolve_blocks(g.shape[-1], strip_rows, m_block, g.dtype)
    return skew_sum_pallas_raw(g, sign=sign, strip_rows=h, m_block=mb,
                               interpret=_auto_interpret(interpret))


def skew_sum_pallas_strip(g: jnp.ndarray, sign: int = 1, *,
                          row_offset=0,
                          strip_rows: Optional[int] = None,
                          m_block: Optional[int] = None,
                          interpret: Optional[bool] = None) -> jnp.ndarray:
    """Shard-local partial skew-sum: a (rows, N) or (B, rows, N) row
    strip whose first *global* image row is ``row_offset`` (static int or
    traced scalar, e.g. ``axis_index * rows_per_dev`` inside shard_map).

    Returns the (…, N, N) partial aligned to global rows -- the fused
    kernel's alignment roll-select ladder absorbs the offset at zero
    extra datapath cost (eq. 7 with rH -> row_offset + rH), replacing
    the distributed path's per-ray Horner roll loop.  Summing these
    partials over devices (``psum``/``psum_scatter``) yields the full
    skew-sum; block shapes default to the :mod:`.tuning` table for N.
    """
    n = g.shape[-1]
    h, mb = _resolve_blocks(n, strip_rows, m_block, g.dtype)
    return skew_sum_pallas_raw(g, sign=sign, strip_rows=h, m_block=mb,
                               interpret=_auto_interpret(interpret),
                               row_offset=row_offset)


def dprt_pallas_strip(g: jnp.ndarray, *, row_offset=0,
                      strip_rows: Optional[int] = None,
                      m_block: Optional[int] = None,
                      interpret: Optional[bool] = None) -> jnp.ndarray:
    """Shard-local partial *forward* DPRT: a (rows, N) or (B, rows, N)
    row strip starting at global image row ``row_offset`` -> the
    (…, N+1, N) partial transform, R(N, d) row-sum epilogue fused
    in-kernel at the strip's global lane positions.  Summing the
    partials over devices (one ``psum``) yields the exact full forward
    -- the whole distributed datapath is one fused kernel call plus one
    collective per device."""
    n = g.shape[-1]
    single = g.ndim == 2
    gb = g[None] if single else g
    h, mb = _resolve_blocks(n, strip_rows, m_block, g.dtype)
    out = dprt_pallas_raw(gb, strip_rows=h, m_block=mb,
                          interpret=_auto_interpret(interpret),
                          row_offset=row_offset)
    return out[0] if single else out


def dprt_pallas(f: jnp.ndarray, strip_rows: Optional[int] = None,
                m_block: Optional[int] = None,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Forward DPRT via the fused SFDPRT kernel.

    (N, N) -> (N+1, N), or batched (B, N, N) -> (B, N+1, N) in a single
    pallas_call.  Block shapes default to the :mod:`.tuning` table.
    """
    single = f.ndim == 2
    fb = f[None] if single else f
    if fb.ndim != 3 or fb.shape[-1] != fb.shape[-2]:
        raise ValueError(f"DPRT needs (B, N, N) or (N, N), got {f.shape}")
    n = fb.shape[-1]
    if not is_prime(n):
        raise ValueError(f"DPRT needs prime N, got {n}")
    h, mb = _resolve_blocks(n, strip_rows, m_block, fb.dtype)
    out = dprt_pallas_raw(fb, strip_rows=h, m_block=mb,
                          interpret=_auto_interpret(interpret))
    return out[0] if single else out


def idprt_pallas(r: jnp.ndarray, strip_rows: Optional[int] = None,
                 m_block: Optional[int] = None,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """Inverse DPRT via the fused kernel (CRS core + in-kernel epilogue).

    (N+1, N) -> (N, N), or batched (B, N+1, N) -> (B, N, N) in a single
    pallas_call; exact for integer inputs (accumulator from
    ``accum_dtype_for``, so int64 survives).
    """
    single = r.ndim == 2
    rb = r[None] if single else r
    n = rb.shape[-1]
    if rb.ndim != 3 or rb.shape[-2] != n + 1 or not is_prime(n):
        raise ValueError(
            f"iDPRT input must be (B, N+1, N) or (N+1, N) with N prime: "
            f"{r.shape}")
    h, mb = _resolve_blocks(n, strip_rows, m_block, rb.dtype)
    out = idprt_pallas_raw(rb, strip_rows=h, m_block=mb,
                           interpret=_auto_interpret(interpret))
    return out[0] if single else out
