"""Public jit'd wrappers around the fused Pallas DPRT kernels.

This is the layer ``repro.core.dprt`` dispatches to for
``method="pallas"``: each wrapper accepts a single (N, N) image or a
batched (B, N, N) stack (transformed in ONE ``pallas_call`` via the
kernel's leading batch grid dimension), resolves block shapes through
the :mod:`.tuning` table when not given explicitly, and uses
:func:`repro.core.dprt.accum_dtype_for` for overflow-safe accumulators
(int64 inputs stay int64, never silently truncated to int32).

The forward/inverse epilogues (R(N, d) row-sum; -S + R(N, i) correction
plus exact divide-by-N) are fused *inside* the kernel -- see
:mod:`.sfdprt` -- so there are no post-kernel passes here, only slicing.

``interpret`` defaults to auto: Pallas interpret mode off-TPU (this
container is CPU-only), compiled Mosaic on real TPUs.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dprt import accum_dtype_for, is_prime
from .sfdprt import (PIPELINE_OPS, dprt_pallas_raw, idprt_pallas_raw,
                     pipeline_pallas_raw, skew_sum_pallas_raw)
from .tuning import resolve_blocks, resolve_pipeline_blocks

__all__ = ["dprt_pallas", "idprt_pallas", "skew_sum_pallas",
           "skew_sum_pallas_strip", "dprt_pallas_strip",
           "projection_pipeline_pallas", "pipeline_tail_pallas"]


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _resolve_blocks(n: int, strip_rows: Optional[int],
                    m_block: Optional[int], dtype,
                    stream_rows: Optional[int] = None) -> tuple[int, int]:
    # delegate to the shared resolver so the plan layer ("auto") and
    # direct pallas calls agree on block shapes.  Deliberately does NOT
    # consult the ambient radon.config scope: these wrappers may run
    # inside a caller's jit trace, where a scope read would be baked
    # into the cached executable and replayed after the scope exits.
    # Ambient knobs apply at (eager) plan/operator construction instead.
    return resolve_blocks(n,
                          jnp.dtype(accum_dtype_for(dtype, n,
                                                    warn=False)).itemsize,
                          strip_rows, m_block, stream_rows=stream_rows)


def _stream_int(stream_rows: Optional[int]) -> Optional[int]:
    return None if stream_rows is None else int(stream_rows)


def skew_sum_pallas(g: jnp.ndarray, sign: int = 1,
                    strip_rows: Optional[int] = None,
                    m_block: Optional[int] = None,
                    interpret: Optional[bool] = None,
                    stream_rows: Optional[int] = None) -> jnp.ndarray:
    """Bare skew-sum: (N, N), or a (B, N, N) stack in ONE pallas_call.

    The batched form serves the plan layer's batched-native adjoint
    datapath (exact VJPs through ``method="pallas"``) as well as the
    core-mode tests.  ``stream_rows`` switches to the streamed-strip
    kernel (VMEM scratch accumulation / double-buffered DMA; giant N).
    """
    h, mb = _resolve_blocks(g.shape[-1], strip_rows, m_block, g.dtype,
                            stream_rows)
    return skew_sum_pallas_raw(g, sign=sign, strip_rows=h, m_block=mb,
                               interpret=_auto_interpret(interpret),
                               stream_rows=_stream_int(stream_rows))


def skew_sum_pallas_strip(g: jnp.ndarray, sign: int = 1, *,
                          row_offset=0,
                          strip_rows: Optional[int] = None,
                          m_block: Optional[int] = None,
                          interpret: Optional[bool] = None,
                          stream_rows: Optional[int] = None) -> jnp.ndarray:
    """Shard-local partial skew-sum: a (rows, N) or (B, rows, N) row
    strip whose first *global* image row is ``row_offset`` (static int or
    traced scalar, e.g. ``axis_index * rows_per_dev`` inside shard_map).

    Returns the (…, N, N) partial aligned to global rows -- the fused
    kernel's alignment roll-select ladder absorbs the offset at zero
    extra datapath cost (eq. 7 with rH -> row_offset + rH), replacing
    the distributed path's per-ray Horner roll loop.  Summing these
    partials over devices (``psum``/``psum_scatter``) yields the full
    skew-sum; block shapes default to the :mod:`.tuning` table for N.
    """
    n = g.shape[-1]
    h, mb = _resolve_blocks(n, strip_rows, m_block, g.dtype, stream_rows)
    return skew_sum_pallas_raw(g, sign=sign, strip_rows=h, m_block=mb,
                               interpret=_auto_interpret(interpret),
                               row_offset=row_offset,
                               stream_rows=_stream_int(stream_rows))


def dprt_pallas_strip(g: jnp.ndarray, *, row_offset=0,
                      strip_rows: Optional[int] = None,
                      m_block: Optional[int] = None,
                      interpret: Optional[bool] = None,
                      stream_rows: Optional[int] = None) -> jnp.ndarray:
    """Shard-local partial *forward* DPRT: a (rows, N) or (B, rows, N)
    row strip starting at global image row ``row_offset`` -> the
    (…, N+1, N) partial transform, R(N, d) row-sum epilogue fused
    in-kernel at the strip's global lane positions.  Summing the
    partials over devices (one ``psum``) yields the exact full forward
    -- the whole distributed datapath is one fused kernel call plus one
    collective per device."""
    n = g.shape[-1]
    single = g.ndim == 2
    gb = g[None] if single else g
    h, mb = _resolve_blocks(n, strip_rows, m_block, g.dtype, stream_rows)
    out = dprt_pallas_raw(gb, strip_rows=h, m_block=mb,
                          interpret=_auto_interpret(interpret),
                          row_offset=row_offset,
                          stream_rows=_stream_int(stream_rows))
    return out[0] if single else out


def dprt_pallas(f: jnp.ndarray, strip_rows: Optional[int] = None,
                m_block: Optional[int] = None,
                interpret: Optional[bool] = None,
                stream_rows: Optional[int] = None) -> jnp.ndarray:
    """Forward DPRT via the fused SFDPRT kernel.

    (N, N) -> (N+1, N), or batched (B, N, N) -> (B, N+1, N) in a single
    pallas_call.  Block shapes default to the :mod:`.tuning` table.
    ``stream_rows`` streams H-row strips through ONE launch (VMEM
    scratch accumulation; double-buffered HBM DMA off-interpret) for
    images too large to sit whole in VMEM.
    """
    single = f.ndim == 2
    fb = f[None] if single else f
    if fb.ndim != 3 or fb.shape[-1] != fb.shape[-2]:
        raise ValueError(f"DPRT needs (B, N, N) or (N, N), got {f.shape}")
    n = fb.shape[-1]
    if not is_prime(n):
        raise ValueError(f"DPRT needs prime N, got {n}")
    h, mb = _resolve_blocks(n, strip_rows, m_block, fb.dtype, stream_rows)
    out = dprt_pallas_raw(fb, strip_rows=h, m_block=mb,
                          interpret=_auto_interpret(interpret),
                          stream_rows=_stream_int(stream_rows))
    return out[0] if single else out


def idprt_pallas(r: jnp.ndarray, strip_rows: Optional[int] = None,
                 m_block: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 stream_rows: Optional[int] = None) -> jnp.ndarray:
    """Inverse DPRT via the fused kernel (CRS core + in-kernel epilogue).

    (N+1, N) -> (N, N), or batched (B, N+1, N) -> (B, N, N) in a single
    pallas_call; exact for integer inputs (accumulator from
    ``accum_dtype_for``, so int64 survives).
    """
    single = r.ndim == 2
    rb = r[None] if single else r
    n = rb.shape[-1]
    if rb.ndim != 3 or rb.shape[-2] != n + 1 or not is_prime(n):
        raise ValueError(
            f"iDPRT input must be (B, N+1, N) or (N+1, N) with N prime: "
            f"{r.shape}")
    h, mb = _resolve_blocks(n, strip_rows, m_block, rb.dtype, stream_rows)
    out = idprt_pallas_raw(rb, strip_rows=h, m_block=mb,
                           interpret=_auto_interpret(interpret),
                           stream_rows=_stream_int(stream_rows))
    return out[0] if single else out


def _lane_batch_for(lane_batch=None) -> int:
    """Batch-in-lanes width.  Packing LB images side by side along the
    lane axis trades op count for tile width; measured on the 2-core
    CPU-interpret host the per-image grid (LB=1) wins once the inverse
    stage is output-row-blocked (wide tiles thrash L2), so LB=1 is the
    default everywhere -- the knob stays for wider hosts / re-tuning."""
    if lane_batch is not None:
        return max(1, int(lane_batch))
    return 1


def projection_pipeline_pallas(f, op: str = "conv", operand=None,
                               operand_form: Optional[str] = None,
                               m_block: Optional[int] = None,
                               group: Optional[int] = None,
                               lane_batch: Optional[int] = None,
                               interpret: Optional[bool] = None):
    """Fused projection-domain pipeline: inverse(op(forward(f))) in ONE
    ``pallas_call`` -- the projections never round-trip through HBM.

    ``f``: (N, N) or a (B, N, N) stack, N prime.  ``op``:

    * ``"conv"`` -- per-direction 1-D circular convolution against the
      second operand (the paper's Sec. VI convolution property), i.e.
      exact 2-D circular convolution.  ``operand`` is the other image
      ((N, N) shared or (B, N, N) matched; its forward runs in-kernel)
      or its precomputed projections ((N+1, N) / (B, N+1, N)) with
      ``operand_form="proj"`` -- the form batched callers use so one
      small forward launch is shared by the whole stack.
    * ``"mul"``  -- pointwise projection-domain multiply by an
      (N+1, N) / (B, N+1, N) weight array (``inv @ pointwise @ fwd``
      operator fusion).
    * ``"none"`` -- inverse(forward(f)): the fused round trip.

    Returns the (…, N, N) result in the accumulator dtype; bit-exact for
    integer inputs (both stages and the epilogue run the same exact
    integer datapath as the staged kernels).
    """
    if op not in PIPELINE_OPS:
        raise ValueError(f"pipeline op must be one of {PIPELINE_OPS}: {op!r}")
    single = f.ndim == 2
    fb = f[None] if single else f
    if fb.ndim != 3 or fb.shape[-1] != fb.shape[-2]:
        raise ValueError(f"pipeline needs (B, N, N) or (N, N), got {f.shape}")
    n = fb.shape[-1]
    if not is_prime(n):
        raise ValueError(f"pipeline needs prime N, got {n}")
    acc = accum_dtype_for(fb.dtype, n)
    wb = None
    if op != "none":
        if operand is None:
            raise ValueError(f"pipeline op {op!r} needs an operand")
        wb = operand[None] if operand.ndim == 2 else operand
        if operand_form is None:
            operand_form = "image" if (op == "conv"
                                       and wb.shape[-2] == n) else "proj"
        want = (n, n) if (op == "conv" and operand_form == "image") \
            else (n + 1, n)
        if wb.shape[-2:] != want:
            raise ValueError(
                f"pipeline operand for op={op!r}/{operand_form} must be "
                f"(…, {want[0]}, {want[1]}), got {operand.shape}")
        if wb.shape[0] not in (1, fb.shape[0]):
            raise ValueError(
                f"batched pipeline operand must match the stack batch "
                f"({fb.shape[0]}), got {operand.shape}")
        wb = wb.astype(acc)
    interp = _auto_interpret(interpret)
    mb, grp = resolve_pipeline_blocks(n, jnp.dtype(acc).itemsize,
                                      m_block, group)
    lb = _lane_batch_for(lane_batch)
    out, _aux = pipeline_pallas_raw(fb.astype(acc), wb, op=op,
                                    operand_form=operand_form or "proj",
                                    m_block=mb, group=grp, lane_batch=lb,
                                    interpret=interp)
    out = out[:, :n, :n]
    return out[0] if single else out


def pipeline_tail_pallas(rows, op: str = "conv", operand=None, *,
                         row_offset=0, n: Optional[int] = None,
                         m_block: Optional[int] = None,
                         group: Optional[int] = None,
                         lane_batch: Optional[int] = None,
                         interpret: Optional[bool] = None):
    """Shard-local pipeline tail: already-assembled projection rows in,
    per-direction epilogue + inverse ladder out (correction deferred).

    ``rows``: (dirs_local, N) or (B, dirs_local, N) -- this device's
    shard of direction rows, first global direction ``row_offset``
    (static or traced).  ``operand``: the full (N+1, N) projections /
    weights (replicated; the kernel slices this shard's window).
    Returns ``(z, aux)`` partials -- one cross-device ``psum`` of both
    plus the shared -S + R'(N, i) / N epilogue reconstructs exactly;
    see :func:`repro.core.distributed.projection_pipeline_sharded`.
    """
    single = rows.ndim == 2
    rb = rows[None] if single else rows
    if n is None:
        n = rb.shape[-1]
    acc = accum_dtype_for(rb.dtype, n)
    interp = _auto_interpret(interpret)
    mb, grp = resolve_pipeline_blocks(n, jnp.dtype(acc).itemsize,
                                      m_block, group)
    mb = min(mb, math.ceil(rb.shape[-2] / 8) * 8)
    lb = _lane_batch_for(lane_batch)
    wb = None
    if op != "none":
        wb = operand[None] if operand.ndim == 2 else operand
        wb = wb.astype(acc)
    z, aux = pipeline_pallas_raw(rb.astype(acc), wb, op=op,
                                 operand_form="proj", source="proj",
                                 m_block=mb, group=grp, lane_batch=lb,
                                 interpret=interp, row_offset=row_offset,
                                 n_rows=n)
    z = z[:, :n, :n]
    if single:
        return z[0], aux[0]
    return z, aux
