"""Public jit'd wrappers around the Pallas DPRT kernels.

``interpret`` defaults to auto: Pallas interpret mode off-TPU (this
container is CPU-only), compiled Mosaic on real TPUs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dprt import is_prime
from .sfdprt import skew_sum_pallas_raw

__all__ = ["dprt_pallas", "idprt_pallas", "skew_sum_pallas"]


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def skew_sum_pallas(g: jnp.ndarray, sign: int = 1, strip_rows: int = 16,
                    m_block: int = 8,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    return skew_sum_pallas_raw(g, sign=sign, strip_rows=strip_rows,
                               m_block=m_block,
                               interpret=_auto_interpret(interpret))


def dprt_pallas(f: jnp.ndarray, strip_rows: int = 16, m_block: int = 8,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Forward DPRT (N,N)->(N+1,N) via the SFDPRT Pallas kernel."""
    n = f.shape[0]
    if not is_prime(n):
        raise ValueError(f"DPRT needs prime N, got {n}")
    core = skew_sum_pallas(f, 1, strip_rows, m_block, interpret)
    last = f.astype(jnp.int32).sum(axis=1)
    return jnp.concatenate([core, last[None, :]], axis=0)


def idprt_pallas(r: jnp.ndarray, strip_rows: int = 16, m_block: int = 8,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """Inverse DPRT (N+1,N)->(N,N) via the kernel with CRS (sign=-1)."""
    n = r.shape[1]
    if r.shape[0] != n + 1 or not is_prime(n):
        raise ValueError(f"iDPRT input must be (N+1, N) with N prime: {r.shape}")
    z = skew_sum_pallas(r[:n], -1, strip_rows, m_block, interpret)
    s = r[0].astype(jnp.int32).sum()
    return (z - s + r[n].astype(jnp.int32)[:, None]) // n
