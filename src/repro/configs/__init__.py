from .registry import ARCH_IDS, ALIASES, get_config, get_smoke_config
