"""internvl2-26b [arXiv:2404.16821]: InternViT (stub) + InternLM2 backbone.

The vision frontend is a STUB per assignment: ``input_specs`` provides
precomputed patch embeddings as a prefix; the backbone below is the
InternLM2-20B-class decoder given in the assignment.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="vlm",
        num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=16384, vocab_size=92553, rope_theta=1000000.0,
        frontend="patch_stub", prefix_len=256)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, prefix_len=4, chunk_kv=32, chunk_q=32)
