"""Architecture registry: --arch <id> resolves here.

Every module defines ``config()`` (the exact assigned configuration) and
``smoke_config()`` (a reduced same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

ARCH_IDS: List[str] = [
    "phi3_medium_14b",
    "tinyllama_1_1b",
    "minitron_8b",
    "qwen3_0_6b",
    "internvl2_26b",
    "qwen3_moe_235b_a22b",
    "deepseek_v2_236b",
    "whisper_large_v3",
    "recurrentgemma_2b",
    "mamba2_2_7b",
]

# canonical dashed ids from the assignment
ALIASES: Dict[str, str] = {
    "phi3-medium-14b": "phi3_medium_14b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "minitron-8b": "minitron_8b",
    "qwen3-0.6b": "qwen3_0_6b",
    "internvl2-26b": "internvl2_26b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "whisper-large-v3": "whisper_large_v3",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mamba2-2.7b": "mamba2_2_7b",
}


def _module(arch: str):
    arch = ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str):
    return _module(arch).config()


def get_smoke_config(arch: str):
    return _module(arch).smoke_config()
