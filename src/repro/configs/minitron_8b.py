"""minitron-8b [arXiv:2407.14679]: width-pruned Nemotron dense GQA."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=16384, vocab_size=256000, rope_theta=10000.0)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, chunk_kv=32, chunk_q=32)
