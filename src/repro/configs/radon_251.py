"""The paper's own workload: 251x251 8-bit images (Sec. V)."""
from dataclasses import dataclass


@dataclass(frozen=True)
class RadonConfig:
    n: int = 251          # prime image size
    bits: int = 8         # B, bits per pixel
    strip_rows: int = 16  # H, the paper's scalability knob
    m_block: int = 8      # direction block (TPU sublane tiling)
    batch: int = 256      # images per service batch


def config() -> RadonConfig:
    return RadonConfig()


def smoke_config() -> RadonConfig:
    return RadonConfig(n=31, batch=8, strip_rows=4, m_block=8)
