"""whisper-large-v3 [arXiv:2212.04356]: enc-dec; conv frontend is a STUB
(``input_specs`` provides precomputed 1500-frame embeddings)."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio",
        num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
        head_dim=64, d_ff=5120, vocab_size=51866, mlp_act="gelu",
        encoder_layers=32, encoder_seq=1500, cross_attention=True,
        frontend="audio_stub", learned_pos=32768)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, encoder_layers=2, encoder_seq=16,
        learned_pos=128, chunk_kv=32, chunk_q=32)
