"""recurrentgemma-2b [arXiv:2402.19427]: RG-LRU + local attn, 1:2 pattern."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
        head_dim=256, d_ff=7680, vocab_size=256000,
        block_pattern=("recurrent", "recurrent", "local_attn"),
        window=2048, lru_width=2560, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=256, window=16, lru_width=64,
        chunk_kv=32, chunk_q=32)
