"""mamba2-2.7b [arXiv:2405.21060]: SSD (state-space duality), attn-free."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        num_layers=64, d_model=2560, num_heads=1, num_kv_heads=1,
        head_dim=64, d_ff=0, vocab_size=50280,
        block_pattern=("mamba",), ssm_state=128, ssm_expand=2,
        ssm_head_dim=64, ssm_groups=1, ssm_chunk=256, conv_width=4)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, vocab_size=256, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=32, chunk_kv=32, chunk_q=32)
