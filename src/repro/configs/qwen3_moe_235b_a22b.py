"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B]: 128 experts, top-8."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
        head_dim=128, d_ff=1536, vocab_size=151936, rope_theta=1000000.0,
        qk_norm=True,
        num_experts=128, experts_per_token=8, moe_d_ff=1536,
        capacity_factor=1.25)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, num_experts=8, experts_per_token=2,
        moe_d_ff=64, chunk_kv=32, chunk_q=32)
