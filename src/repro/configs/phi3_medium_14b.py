"""phi3-medium-14b [arXiv:2404.14219]: dense, RoPE + SwiGLU + GQA."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
        head_dim=128, d_ff=17920, vocab_size=100352, rope_theta=10000.0)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, chunk_kv=32, chunk_q=32)
