"""qwen3-0.6b [hf:Qwen/Qwen3-8B family]: dense GQA with qk-norm."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="dense",
        num_layers=28, d_model=1024, num_heads=16, num_kv_heads=8,
        head_dim=128, d_ff=3072, vocab_size=151936, rope_theta=1000000.0,
        qk_norm=True, tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, chunk_kv=32, chunk_q=32)
