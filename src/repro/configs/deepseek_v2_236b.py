"""deepseek-v2-236b [arXiv:2405.04434]: MLA (kv_lora=512) + 2 shared +
160 routed experts top-6; first layer dense."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
        head_dim=128, d_ff=12288, vocab_size=102400, rope_theta=10000.0,
        num_experts=160, experts_per_token=6, moe_d_ff=1536,
        shared_experts=2, first_dense_layers=1, capacity_factor=1.25,
        q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
        nope_head_dim=128, v_head_dim=128)


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, num_experts=8, experts_per_token=2,
        moe_d_ff=64, shared_experts=1, q_lora_rank=32, kv_lora_rank=16,
        rope_head_dim=8, nope_head_dim=16, v_head_dim=16,
        chunk_kv=32, chunk_q=32)
