"""repro: fast & scalable DPRT (Carranza et al.) as a JAX/TPU framework."""
__version__ = "1.0.0"
