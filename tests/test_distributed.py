"""Multi-device behaviour (subprocess with fake host devices): sharded
DPRT, compressed collectives, mesh training, elastic restore."""
import pytest


def test_sharded_dprt_exact(subproc):
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import dprt_sharded, idprt_sharded, dprt_batch_sharded
from repro.core.dprt import dprt_oracle_np
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(3)
f = jnp.asarray(rng.integers(0, 256, (31, 31)), jnp.int32)
ref = dprt_oracle_np(np.asarray(f))
for reduce in ["psum", "psum_scatter"]:
    r = np.asarray(dprt_sharded(f, mesh, reduce=reduce))
    assert (r == ref).all(), reduce
    back = np.asarray(idprt_sharded(jnp.asarray(r), mesh, reduce=reduce))
    assert (back == np.asarray(f)).all(), ("inv", reduce)
fb = jnp.asarray(rng.integers(0, 256, (8, 13, 13)), jnp.int32)
rb = np.asarray(dprt_batch_sharded(fb, mesh, batch_axes=("data",)))
for b in range(8):
    assert (rb[b] == dprt_oracle_np(np.asarray(fb[b]))).all()
print("OK")
""")


def test_compressed_psum_accuracy(subproc):
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.optim.compress import compressed_psum_mean
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
got = np.asarray(compressed_psum_mean(x, mesh, "data", jax.random.key(0)))
want = np.broadcast_to(np.asarray(x).mean(0, keepdims=True), (8, 256))
err = np.abs(got - want).max() / np.abs(want).max()
assert err < 0.05, err
print("OK", err)
""")


def test_mesh_training_and_elastic_restore(subproc, tmp_path):
    """Train on a (2,4) mesh, checkpoint, restore onto (4,2) -- elastic."""
    subproc(f"""
import jax, numpy as np
from repro.configs import get_smoke_config
from repro.runtime import Trainer, TrainerConfig
d = r"{tmp_path}/ck"
mcfg = get_smoke_config("tinyllama_1_1b")
mesh_a = jax.make_mesh((2, 4), ("data", "model"))
cfg = TrainerConfig(steps=6, ckpt_every=3, ckpt_dir=d, batch_size=4,
                    seq_len=32, log_every=2)
out_a = Trainer(mcfg, cfg, mesh=mesh_a).run()
# elastic: restore the same checkpoint onto a transposed mesh
mesh_b = jax.make_mesh((4, 2), ("data", "model"))
cfg_b = TrainerConfig(steps=9, ckpt_every=3, ckpt_dir=d, batch_size=4,
                      seq_len=32, log_every=1)
tr_b = Trainer(mcfg, cfg_b, mesh=mesh_b)
out_b = tr_b.run()
assert out_b["log"][0]["step"] == 6
assert out_b["last_loss"] < out_a["log"][0]["loss"]
print("OK elastic", out_a["last_loss"], "->", out_b["last_loss"])
""", devices=8, timeout=900)


def test_sharded_train_matches_single_device(subproc):
    """The pjit train step computes the same loss as single-device."""
    subproc("""
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models import Model
from repro.parallel.sharding import (activate_mesh, init_params,
                                     param_shardings)
from repro.data.pipeline import shard_batch
from repro.data.synthetic import TokenStream
mcfg = get_smoke_config("qwen3_0_6b")
model = Model(mcfg)
params = init_params(model.specs(), jax.random.key(0), jnp.float32)
batch_np = TokenStream(mcfg.vocab_size, 32, 8, seed=0).batch(0)
loss_1 = float(jax.jit(lambda p, b: model.loss(p, b)[0])(
    params, jax.tree.map(jnp.asarray, batch_np)))
mesh = jax.make_mesh((2, 4), ("data", "model"))
ps = param_shardings(model.specs(), mesh)
params_s = jax.tree.map(jax.device_put, params, ps)
batch_s = shard_batch(batch_np, mesh, batch_axes=("data",))
with activate_mesh(mesh):
    loss_8 = float(jax.jit(lambda p, b: model.loss(p, b)[0])(
        params_s, batch_s))
assert abs(loss_1 - loss_8) < 5e-3 * abs(loss_1), (loss_1, loss_8)
print("OK", loss_1, loss_8)
""")


def test_zero1_shards_optimizer_state(subproc):
    subproc("""
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models import Model
from repro.parallel.sharding import abstract_params, param_shardings
from repro.optim.adamw import zero1_shardings
mesh = jax.make_mesh((2, 4), ("data", "model"))
model = Model(get_smoke_config("tinyllama_1_1b"))
specs = model.specs()
ps = param_shardings(specs, mesh)
zs = zero1_shardings(ps, abstract_params(specs, jnp.float32), mesh)
n_data_sharded = 0
for s in jax.tree.leaves(zs):
    axes = [a for dim in (s.spec or []) for a in
            ((dim,) if isinstance(dim, str) else (dim or ()))]
    n_data_sharded += "data" in axes
assert n_data_sharded > 0, "ZeRO-1 sharded nothing"
print("OK", n_data_sharded, "leaves data-sharded")
""")
