"""Multi-device behaviour (subprocess with fake host devices): sharded
DPRT (legacy Horner and per-shard fused-Pallas paths), compressed
collectives, mesh training, elastic restore."""
import pytest

# every test here spawns a forced-host multi-device
# subprocess; `-m "not slow"` is the quick tier
pytestmark = pytest.mark.slow


def test_sharded_dprt_exact(subproc):
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import (dprt_sharded, idprt_sharded,
                                    dprt_batch_sharded, idprt_batch_sharded)
from repro.core.dprt import dprt_oracle_np
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(3)
f = jnp.asarray(rng.integers(0, 256, (31, 31)), jnp.int32)
ref = dprt_oracle_np(np.asarray(f))
for reduce in ["psum", "psum_scatter"]:
    r = np.asarray(dprt_sharded(f, mesh, reduce=reduce))
    assert (r == ref).all(), reduce
    back = np.asarray(idprt_sharded(jnp.asarray(r), mesh, reduce=reduce))
    assert (back == np.asarray(f)).all(), ("inv", reduce)
fb = jnp.asarray(rng.integers(0, 256, (8, 13, 13)), jnp.int32)
rb = np.asarray(dprt_batch_sharded(fb, mesh, batch_axes=("data",)))
for b in range(8):
    assert (rb[b] == dprt_oracle_np(np.asarray(fb[b]))).all()
# the batched sharded inverse (parity with the forward's batch sharding)
bb = np.asarray(idprt_batch_sharded(jnp.asarray(rb.astype(np.int32)), mesh,
                                    batch_axes=("data",)))
assert (bb == np.asarray(fb)).all()
print("OK")
""")


def test_sharded_pallas_roundtrips_and_layouts(subproc):
    """Forward/inverse/adjoint round-trips through the per-shard fused
    kernel path, psum vs psum_scatter layouts, on 1-D and 2-D meshes."""
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import (dprt_sharded_pallas, idprt_sharded_pallas,
                                    skew_sum_sharded_pallas)
from repro.core.dprt import dprt_oracle_np
from repro.kernels import skew_sum_ref
rng = np.random.default_rng(7)
f = jnp.asarray(rng.integers(0, 256, (31, 31)), jnp.int32)
ref = dprt_oracle_np(np.asarray(f))
for mesh in [jax.make_mesh((8,), ("model",)),
             jax.make_mesh((2, 4), ("data", "model"))]:
    for reduce in ["psum", "psum_scatter"]:
        r = np.asarray(dprt_sharded_pallas(f, mesh, reduce=reduce))
        assert (r == ref).all(), (mesh.shape, reduce)
        back = np.asarray(idprt_sharded_pallas(jnp.asarray(r.astype(np.int32)),
                                               mesh, reduce=reduce))
        assert (back == np.asarray(f)).all(), ("inv", mesh.shape, reduce)
# bare skew-sum (the adjoint datapaths' primitive), both signs
mesh = jax.make_mesh((8,), ("model",))
for sign in (1, -1):
    got = np.asarray(skew_sum_sharded_pallas(f, mesh, sign=sign))
    want = np.asarray(skew_sum_ref(f, sign))
    assert (got == want).all(), sign
print("OK")
""")


def test_sharded_pallas_2d_mesh_batched(subproc):
    """2-D mesh: batch shards over data, row strips over model, one
    fused kernel call per device shard -- including a batch that does
    not divide the data axis."""
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.plan import get_plan, select_backend
from repro.core.dprt import dprt_oracle_np
mesh = jax.make_mesh((2, 4), ("data", "model"))
assert select_backend(13, jnp.int32, mesh=mesh) == "sharded_pallas"
rng = np.random.default_rng(5)
for b in (6, 5):   # divisible and non-divisible batches over data=2
    fb = jnp.asarray(rng.integers(0, 256, (b, 13, 13)), jnp.int32)
    plan = get_plan(fb.shape, fb.dtype, "auto", mesh=mesh)
    assert plan.method == "sharded_pallas", plan.method
    rb = plan.forward(fb)
    for i in range(b):
        assert (np.asarray(rb[i]) == dprt_oracle_np(np.asarray(fb[i]))).all()
    assert (np.asarray(plan.inverse(rb)) == np.asarray(fb)).all()
    # batched adjoint datapaths ride the same per-shard kernel; values
    # must match the single-device pallas backend bit-for-bit
    ref = get_plan(fb.shape, fb.dtype, "pallas")
    ab = np.asarray(plan.adjoint(rb.astype(jnp.int32)))
    iab = np.asarray(plan.inverse_adjoint(fb))
    assert (ab == np.asarray(ref.adjoint(rb.astype(jnp.int32)))).all()
    assert (iab == np.asarray(ref.inverse_adjoint(fb))).all()
print("OK")
""")


def test_sharded_pallas_grad_equals_adjoint(subproc):
    """jax.grad through the distributed path == the explicit adjoint,
    for all four datapaths (vs the single-device pallas dense forms)."""
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro import radon
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(11)
imgf = jnp.asarray(rng.normal(size=(7, 7)), jnp.float32)
opm = radon.DPRT(imgf.shape, imgf.dtype, mesh=mesh)
assert opm.plan.method == "sharded_pallas", opm.plan.method
ref = radon.DPRT(imgf.shape, imgf.dtype, method="pallas")
for a, b in [(opm, ref), (opm.T, ref.T),
             (opm.inverse, ref.inverse), (opm.inverse.T, ref.inverse.T)]:
    np.testing.assert_allclose(np.asarray(a.as_matrix()),
                               np.asarray(b.as_matrix()),
                               rtol=1e-5, atol=1e-5)
grad = jax.grad(lambda x: opm(x).sum())(imgf)
want = opm.T(jnp.ones(opm.shape_out, jnp.float32))
np.testing.assert_array_equal(np.asarray(grad), np.asarray(want))
gi = jax.grad(lambda x: opm.inverse(x).sum())(opm(imgf))
wi = opm.inverse.T(jnp.ones(opm.inverse.shape_out, jnp.float32))
np.testing.assert_allclose(np.asarray(gi), np.asarray(wi), rtol=1e-5)
print("OK")
""")


def test_sharded_pallas_auto_and_aot_serving(subproc):
    """method='auto' under a mesh resolves to sharded_pallas; the AOT
    executables chain forward -> inverse without resharding and the
    legacy mesh= shim routes through the mesh-aware registry pick."""
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro import radon
from repro.core.dprt import dprt_batched, idprt_batched, dprt_oracle_np
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(13)
fb = jnp.asarray(rng.integers(0, 256, (8, 13, 13)), jnp.int32)
op = radon.DPRT(fb.shape, fb.dtype, mesh=mesh)
assert op.plan.method == "sharded_pallas"
fwd, inv = op.compile(), op.inverse.compile()
x = jax.device_put(fb, op.input_sharding)
with radon.retrace_guard(max_traces=0):
    r = fwd(x)
    back = inv(r)
assert (np.asarray(back) == np.asarray(fb)).all()
# legacy wrappers: mesh= routes through the mesh-aware auto pick
rb = dprt_batched(fb, mesh=mesh)
for i in range(8):
    assert (np.asarray(rb[i]) == dprt_oracle_np(np.asarray(fb[i]))).all()
bb = idprt_batched(rb.astype(jnp.int32), mesh=mesh)
assert (np.asarray(bb) == np.asarray(fb)).all()
print("OK")
""")


def test_compressed_psum_accuracy(subproc):
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.optim.compress import compressed_psum_mean
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
got = np.asarray(compressed_psum_mean(x, mesh, "data", jax.random.key(0)))
want = np.broadcast_to(np.asarray(x).mean(0, keepdims=True), (8, 256))
err = np.abs(got - want).max() / np.abs(want).max()
assert err < 0.05, err
print("OK", err)
""")


def test_mesh_training_and_elastic_restore(subproc, tmp_path):
    """Train on a (2,4) mesh, checkpoint, restore onto (4,2) -- elastic."""
    subproc(f"""
import jax, numpy as np
from repro.configs import get_smoke_config
from repro.runtime import Trainer, TrainerConfig
d = r"{tmp_path}/ck"
mcfg = get_smoke_config("tinyllama_1_1b")
mesh_a = jax.make_mesh((2, 4), ("data", "model"))
cfg = TrainerConfig(steps=6, ckpt_every=3, ckpt_dir=d, batch_size=4,
                    seq_len=32, log_every=2)
out_a = Trainer(mcfg, cfg, mesh=mesh_a).run()
# elastic: restore the same checkpoint onto a transposed mesh
mesh_b = jax.make_mesh((4, 2), ("data", "model"))
cfg_b = TrainerConfig(steps=9, ckpt_every=3, ckpt_dir=d, batch_size=4,
                      seq_len=32, log_every=1)
tr_b = Trainer(mcfg, cfg_b, mesh=mesh_b)
out_b = tr_b.run()
assert out_b["log"][0]["step"] == 6
assert out_b["last_loss"] < out_a["log"][0]["loss"]
print("OK elastic", out_a["last_loss"], "->", out_b["last_loss"])
""", devices=8, timeout=900)


def test_sharded_train_matches_single_device(subproc):
    """The pjit train step computes the same loss as single-device."""
    subproc("""
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models import Model
from repro.parallel.sharding import (activate_mesh, init_params,
                                     param_shardings)
from repro.data.pipeline import shard_batch
from repro.data.synthetic import TokenStream
mcfg = get_smoke_config("qwen3_0_6b")
model = Model(mcfg)
params = init_params(model.specs(), jax.random.key(0), jnp.float32)
batch_np = TokenStream(mcfg.vocab_size, 32, 8, seed=0).batch(0)
loss_1 = float(jax.jit(lambda p, b: model.loss(p, b)[0])(
    params, jax.tree.map(jnp.asarray, batch_np)))
mesh = jax.make_mesh((2, 4), ("data", "model"))
ps = param_shardings(model.specs(), mesh)
params_s = jax.tree.map(jax.device_put, params, ps)
batch_s = shard_batch(batch_np, mesh, batch_axes=("data",))
with activate_mesh(mesh):
    loss_8 = float(jax.jit(lambda p, b: model.loss(p, b)[0])(
        params_s, batch_s))
assert abs(loss_1 - loss_8) < 5e-3 * abs(loss_1), (loss_1, loss_8)
print("OK", loss_1, loss_8)
""")


def test_zero1_shards_optimizer_state(subproc):
    subproc("""
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models import Model
from repro.parallel.sharding import abstract_params, param_shardings
from repro.optim.adamw import zero1_shardings
mesh = jax.make_mesh((2, 4), ("data", "model"))
model = Model(get_smoke_config("tinyllama_1_1b"))
specs = model.specs()
ps = param_shardings(specs, mesh)
zs = zero1_shardings(ps, abstract_params(specs, jnp.float32), mesh)
n_data_sharded = 0
for s in jax.tree.leaves(zs):
    axes = [a for dim in (s.spec or []) for a in
            ((dim,) if isinstance(dim, str) else (dim or ()))]
    n_data_sharded += "data" in axes
assert n_data_sharded > 0, "ZeRO-1 sharded nothing"
print("OK", n_data_sharded, "leaves data-sharded")
""")


def test_sharded_projection_pipeline_conv(subproc):
    """Fused conv pipeline on a mesh: per-shard forward kernel, ONE
    psum_scatter between forward and inverse, per-shard tail kernel,
    final psum -- bit-exact vs the staged path and the dense oracle,
    on 1-D and 2-D meshes, via the registry."""
    subproc("""
import numpy as np
import jax, jax.numpy as jnp
from repro.core.distributed import projection_pipeline_sharded
from repro.core.conv import circ_conv2d_dprt, circ_conv2d_direct
from repro import radon

rng = np.random.default_rng(0)
n = 13
f = jnp.asarray(rng.integers(0, 30, (n, n)), jnp.int32)
g = jnp.asarray(rng.integers(0, 9, (n, n)), jnp.int32)
want = np.asarray(circ_conv2d_direct(f, g))

mesh = jax.make_mesh((8,), ("model",))
out = projection_pipeline_sharded(f, mesh, "conv", g)
np.testing.assert_array_equal(np.asarray(out, np.int64), want)

# 2-D mesh, non-divisible batch, shared AND per-image operands
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
fb = jnp.asarray(rng.integers(0, 30, (5, n, n)), jnp.int32)
outb = projection_pipeline_sharded(fb, mesh2, "conv", g)
gb = jnp.asarray(rng.integers(0, 9, (5, n, n)), jnp.int32)
outbb = projection_pipeline_sharded(fb, mesh2, "conv", gb)
for i in range(5):
    np.testing.assert_array_equal(
        np.asarray(outb[i], np.int64),
        np.asarray(circ_conv2d_direct(fb[i], g)))
    np.testing.assert_array_equal(
        np.asarray(outbb[i], np.int64),
        np.asarray(circ_conv2d_direct(fb[i], gb[i])))

# registry route under an ambient mesh: fused == staged bit-exactly
with radon.config(mesh=mesh):
    fused = circ_conv2d_dprt(f, g)            # auto -> sharded_pallas
    staged = circ_conv2d_dprt(f, g, fuse=False)
np.testing.assert_array_equal(np.asarray(fused), np.asarray(staged))
np.testing.assert_array_equal(np.asarray(fused, np.int64), want)

# pointwise pipeline under the mesh (all-ones == round trip)
w = jnp.ones((n + 1, n), jnp.int32)
np.testing.assert_array_equal(
    np.asarray(projection_pipeline_sharded(f, mesh, "mul", w)),
    np.asarray(f))
print("SHARDED_PIPELINE_OK")
""")
