"""Substrate: data determinism, optimizer, compression, checkpointing,
failure injection + restart."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (AsyncCheckpointer, gc_checkpoints, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.configs import get_smoke_config
from repro.data import Prefetcher, TokenStream
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_tree, cosine_schedule, dequantize_int8,
                         quantize_int8)
from repro.runtime import SimulatedFailure, Trainer, TrainerConfig


def test_token_stream_deterministic_and_sharded():
    a = TokenStream(100, 16, 4, seed=1).batch(3)
    b = TokenStream(100, 16, 4, seed=1).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    s0 = TokenStream(100, 16, 4, seed=1, shard=0, num_shards=2).batch(3)
    s1 = TokenStream(100, 16, 4, seed=1, shard=1, num_shards=2).batch(3)
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # labels are next tokens
    assert a["labels"].shape == a["tokens"].shape


def test_prefetcher_delivers_in_order():
    it = iter([{"x": np.full((2,), i)} for i in range(5)])
    pf = Prefetcher(it, depth=2)
    got = [next(pf)["x"][0] for _ in range(5)]
    assert got == list(range(5))
    pf.close()


def test_adamw_optimizes_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, m = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert int(opt["step"]) == 200


def test_cosine_schedule_shape():
    s = cosine_schedule(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) < 1e-6
    assert float(s(55)) < float(s(11))


def test_int8_quantization_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
    q, s = quantize_int8(x, jax.random.key(0))
    back = dequantize_int8(q, s)
    # max error is one quantization step (scale), mean error near zero
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 1.01
    assert abs(float(jnp.mean(back - x))) < float(s) * 0.2


def test_compress_tree_keeps_structure():
    g = {"a": jnp.ones((4, 4)), "b": {"c": jnp.full((3,), -2.0)}}
    out = compress_tree(g, jax.random.key(1))
    assert jax.tree.structure(out) == jax.tree.structure(g)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]), -2.0, rtol=0.02)


def test_checkpoint_atomic_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "opt": {"step": jnp.int32(7)}}
    save_checkpoint(d, 7, tree, extra={"loss": 1.5})
    assert latest_step(d) == 7
    like = jax.tree.map(np.asarray, tree)
    got, step, extra = restore_checkpoint(d, like)
    assert step == 7 and extra["loss"] == 1.5
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    # no .tmp leftovers
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_checkpoint_gc_and_async(tmp_path):
    d = str(tmp_path / "ck")
    ck = AsyncCheckpointer(d, keep=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, {"x": jnp.full((2,), s)})
    ck.wait()
    assert latest_step(d) == 4
    steps = sorted(int(p.split("_")[1]) for p in os.listdir(d))
    assert len(steps) == 2 and steps[-1] == 4


def test_restore_missing_returns_none(tmp_path):
    got, step, extra = restore_checkpoint(str(tmp_path / "none"), {"x": 1})
    assert got is None and step is None


def test_failure_injection_and_restart(tmp_path):
    d = str(tmp_path / "ck")
    mcfg = get_smoke_config("tinyllama_1_1b")
    cfg = TrainerConfig(steps=10, ckpt_every=3, ckpt_dir=d, fail_at_step=7,
                        batch_size=2, seq_len=24, log_every=2)
    tr0 = Trainer(mcfg, cfg)
    with pytest.raises(SimulatedFailure):
        tr0.run()
    # an async save may be in flight when the "node" dies: atomicity means
    # the newest *published* checkpoint is 3 or 6, never corrupt
    tr0.checkpointer.wait()
    survived = latest_step(d)
    assert survived in (3, 6)
    cfg2 = TrainerConfig(steps=10, ckpt_every=3, ckpt_dir=d, batch_size=2,
                         seq_len=24, log_every=2)
    tr = Trainer(mcfg, cfg2)
    out = tr.run()
    assert out["log"][0]["step"] == survived   # resumed where it left off
    assert latest_step(d) == 10


def test_training_reduces_loss(tmp_path):
    mcfg = get_smoke_config("qwen3_0_6b")
    cfg = TrainerConfig(steps=30, ckpt_every=100, log_every=1,
                        ckpt_dir=str(tmp_path / "ck"), batch_size=4,
                        seq_len=32, lr=3e-3)
    out = Trainer(mcfg, cfg).run()
    losses = [m["loss"] for m in out["log"]]
    assert losses[-1] < losses[0] - 0.3, losses


def test_grad_compression_trains(tmp_path):
    mcfg = get_smoke_config("tinyllama_1_1b")
    cfg = TrainerConfig(steps=15, ckpt_every=100, log_every=1,
                        ckpt_dir=str(tmp_path / "ck"), batch_size=4,
                        seq_len=32, lr=3e-3, grad_compress=True)
    out = Trainer(mcfg, cfg).run()
    losses = [m["loss"] for m in out["log"]]
    assert losses[-1] < losses[0]


def test_grad_accumulation_matches_full_batch():
    """accumulate_grads over microbatches == one full-batch grad."""
    import jax
    from repro.optim import accumulate_grads
    from repro.configs import get_smoke_config
    from repro.models import Model
    from repro.parallel.sharding import init_params
    from repro.data import TokenStream

    mcfg = get_smoke_config("qwen3_0_6b")
    model = Model(mcfg)
    params = init_params(model.specs(), jax.random.key(0), jnp.float32)
    batch = jax.tree.map(jnp.asarray,
                         TokenStream(mcfg.vocab_size, 24, 8, seed=3).batch(0))

    def loss_fn(p, b):
        return model.loss(p, b)

    (full_loss, _), full_g = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params, batch)
    acc_loss, acc_g = jax.jit(
        lambda p, b: accumulate_grads(loss_fn, p, b, 4))(params, batch)
    # microbatch mean-of-means == full mean here (equal-sized splits)
    assert abs(float(acc_loss) - float(full_loss)) < 5e-3
    rel = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))
                           / (jnp.max(jnp.abs(b)) + 1e-9)), acc_g, full_g)
    worst = max(jax.tree.leaves(rel))
    assert worst < 5e-2, worst
