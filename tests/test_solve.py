"""Tests for the reconstruction subsystem (``repro.radon.solve``).

Covers: MaskedDPRT adjoint exactness (``m.T.as_matrix()`` vs the dense
transpose) across backends, the fused normal-equation identity against
the dense ``(DA)^T (DA)``, every solver against the dense least-squares
oracle on masked-direction problems, the non-iterative Sherman-Morrison
fast path against the exact inverse, preconditioning, gradients via the
implicit-function theorem vs finite differences, zero-retrace solver
loops, batched-vs-per-image consistency, the servable operator surface,
and the integer-promotion no-warning regression at the N=257
accumulator cliff.
"""
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import radon
from repro.core.dprt import float_dtype_for

PRIMES = [5, 7, 13]
BACKENDS = ["gather", "horner", "pallas"]


def rand_img(n, seed=0, batch=None):
    shape = (n, n) if batch is None else (batch, n, n)
    return np.random.default_rng(seed).integers(0, 9, shape)


def masked_op(n, missing, method="pallas", dtype=jnp.int32, batch=None):
    shape = (n, n) if batch is None else (batch, n, n)
    op = radon.DPRT(shape, dtype, method=method)
    return radon.MaskedDPRT(op, mask=radon.direction_mask(n, missing))


def ls_oracle(m, b):
    """Min-norm dense least-squares solution (what CG/LSQR from x0=0
    converge to on a singular masked system)."""
    A = np.asarray(m.as_matrix()).astype(np.float64)
    x, *_ = np.linalg.lstsq(A, np.asarray(b).ravel().astype(np.float64),
                            rcond=None)
    return x


# ---------------------------------------------------------------------------
# MaskedDPRT: exact adjoint + the fused normal-equation identity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", BACKENDS)
@pytest.mark.parametrize("n", PRIMES)
def test_masked_adjoint_matrix_exact(n, method):
    m = masked_op(n, [1, n - 1], method=method)
    A = np.asarray(m.as_matrix())
    AT = np.asarray(m.T.as_matrix())
    # 0/1 mask on integer-valued float arithmetic: exact equality
    assert np.array_equal(AT, A.T)
    assert m.T.T.shape_out == m.shape_out  # involution


def test_masked_weighting_and_validation():
    n = 7
    op = radon.DPRT((n, n), jnp.int32)
    w = np.random.default_rng(3).uniform(0.5, 2.0, (n + 1, n))
    m = radon.MaskedDPRT(op, mask=radon.direction_mask(n, [0]),
                         weight=jnp.asarray(w))
    x = jnp.asarray(rand_img(n), jnp.float32)
    want = np.array(radon.MaskedDPRT(op)(x)) * w
    want[0] = 0
    np.testing.assert_allclose(np.asarray(m(x)), want, rtol=1e-6)
    with pytest.raises(ValueError):
        radon.MaskedDPRT(op, mask=jnp.ones((3, 3)))
    with pytest.raises(ValueError):
        radon.MaskedDPRT(op.inverse)


@pytest.mark.parametrize("n", PRIMES)
def test_normal_apply_matches_dense(n):
    m = masked_op(n, [2], method="pallas")
    G = np.asarray(m.as_matrix()).astype(np.float64)
    G = G.T @ G
    x = jnp.asarray(np.random.default_rng(1).standard_normal((n, n)),
                    jnp.float32)
    fused = np.asarray(m.normal_apply(x))
    dense = (G @ np.asarray(x).ravel().astype(np.float64)).reshape(n, n)
    np.testing.assert_allclose(fused, dense, rtol=1e-4, atol=1e-4)
    rhs = np.asarray(m.normal_rhs(m(x)))
    dense_rhs = (np.asarray(m.as_matrix()).T.astype(np.float64)
                 @ np.asarray(m(x)).ravel()).reshape(n, n)
    np.testing.assert_allclose(rhs, dense_rhs, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# solvers vs the dense oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", BACKENDS)
@pytest.mark.parametrize("n", PRIMES)
def test_masked_cg_matches_dense_ls(n, method):
    m = masked_op(n, [2, n - 1], method=method)
    b = m(jnp.asarray(rand_img(n, seed=n), jnp.float32))
    want = ls_oracle(m, b)
    res = radon.solve(m, b, "cg", tol=1e-7, maxiter=300)
    got = np.asarray(res.image).ravel()
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-5 * max(1.0, np.abs(want).max()))
    hist = np.asarray(res.residual_norms)
    assert hist.shape == (301,)
    assert hist[0] == 1.0


@pytest.mark.parametrize("solver", ["lsqr", "landweber"])
def test_other_solvers_match_dense_ls(solver):
    n = 7
    m = masked_op(n, [3])
    b = m(jnp.asarray(rand_img(n, seed=2), jnp.float32))
    want = ls_oracle(m, b)
    kw = (dict(tol=1e-10, maxiter=200) if solver == "lsqr"
          else dict(tol=1e-7, maxiter=4000))
    got = np.asarray(radon.solve(m, b, solver, **kw).image).ravel()
    tol = 1e-5 if solver == "lsqr" else 1e-3
    np.testing.assert_allclose(got, want, rtol=tol,
                               atol=tol * max(1.0, np.abs(want).max()))


@pytest.mark.parametrize("precond", ["sherman", "filter"])
def test_preconditioned_cg(precond):
    n = 13
    m = masked_op(n, [5])
    b = m(jnp.asarray(rand_img(n, seed=4), jnp.float32))
    want = ls_oracle(m, b)
    pc = ("sherman" if precond == "sherman"
          else radon.ProjectionFilter(jnp.full((n + 1, n), 1.0 / (n + 1),
                                               jnp.float32)))
    res = radon.solve(m, b, "cg", precond=pc, tol=1e-7, maxiter=300)
    got = np.asarray(res.image).ravel()
    np.testing.assert_allclose(got, want, rtol=1e-4,
                               atol=1e-4 * max(1.0, np.abs(want).max()))


@pytest.mark.parametrize("n", PRIMES)
def test_sherman_fast_path_noniterative_matches_inverse(n):
    op = radon.DPRT((n, n), jnp.int32)
    x = rand_img(n, seed=n)
    res = radon.solve(op, op(jnp.asarray(x, jnp.int32)))
    assert int(res.iterations) == 0          # closed form, no loop
    assert bool(res.converged)
    want = np.asarray(op.inverse(op(jnp.asarray(x, jnp.int32))))
    np.testing.assert_allclose(np.asarray(res.image), want,
                               rtol=1e-5, atol=1e-4)
    # and it IS the least-squares solution of the full system
    m = radon.MaskedDPRT(op)
    np.testing.assert_allclose(
        np.asarray(res.image).ravel(),
        ls_oracle(m, m(jnp.asarray(x, jnp.float32))), rtol=1e-4,
        atol=1e-3)


def test_method_resolution_and_validation():
    n = 7
    op = radon.DPRT((n, n), jnp.int32)
    m = masked_op(n, [1])
    b = jnp.zeros((n + 1, n), jnp.float32)
    with pytest.raises(ValueError):
        radon.solve(m, b, "sherman")           # masked: no closed form
    with pytest.raises(ValueError):
        radon.solve(op, b, "nope")
    with pytest.raises(ValueError):
        radon.solve(m, b, "lsqr", precond="sherman")
    with pytest.raises(ValueError):
        radon.solve(m, b, mask=radon.direction_mask(n, [0]))  # twice
    with pytest.raises(ValueError):
        radon.solve(op, jnp.zeros((n, n), jnp.float32))  # bad shape
    # auto: unmasked -> sherman, masked -> cg
    assert int(radon.solve(op, b).iterations) == 0
    res = radon.solve(m, b)                    # zero rhs converges at 0
    assert bool(res.converged) and int(res.iterations) == 0


# ---------------------------------------------------------------------------
# differentiation: implicit-function-theorem gradients vs FD
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("solver", ["cg", "lsqr"])
def test_grad_through_solve_matches_fd(solver):
    n = 7
    m = masked_op(n, [2])
    b = jnp.asarray(np.asarray(m(jnp.asarray(rand_img(n, seed=5),
                                             jnp.float32))))

    def loss(bb):
        return (radon.solve(m, bb, solver, tol=1e-9,
                            maxiter=300).image ** 2).sum()

    g = np.asarray(jax.grad(loss)(b))
    # f32 central differences on an O(1e4) loss carry ~1% cancellation
    # noise; the tight check against the dense-oracle gradient follows
    eps = 1e-2
    for (i, j) in [(0, 0), (3, 4), (n, n - 1)]:
        e = jnp.zeros_like(b).at[i, j].set(eps)
        fd = (loss(b + e) - loss(b - e)) / (2 * eps)
        assert abs(g[i, j] - float(fd)) <= 5e-2 * max(1.0, abs(float(fd)))
    # tight: x(b) = pinv(DA) D b is linear, so grad ||x||^2 = 2 P^T P b
    M = np.asarray(m.as_matrix()).astype(np.float64)
    P = np.linalg.pinv(M) @ np.diag(np.asarray(m.d).ravel().astype(
        np.float64))
    want = (2 * P.T @ (P @ np.asarray(b).ravel().astype(np.float64)))
    np.testing.assert_allclose(g.ravel(), want, rtol=1e-3,
                               atol=1e-3 * max(1.0, np.abs(want).max()))


def test_grad_through_sherman_is_exact():
    n = 5
    op = radon.DPRT((n, n), jnp.int32)
    b = jnp.asarray(np.asarray(op(jnp.asarray(rand_img(n, seed=6),
                                              jnp.int32))), jnp.float32)

    def loss(bb):
        return (radon.solve(op, bb).image ** 2).sum()

    g = np.asarray(jax.grad(loss)(b))
    m = radon.MaskedDPRT(op)
    P = np.linalg.pinv(np.asarray(m.as_matrix()).astype(np.float64))
    want = 2 * P.T @ (P @ np.asarray(b).ravel().astype(np.float64))
    np.testing.assert_allclose(g.ravel(), want, rtol=1e-3,
                               atol=1e-3 * max(1.0, np.abs(want).max()))


def test_solve_jittable_and_composable():
    n = 7
    m = masked_op(n, [1])
    b = jnp.asarray(np.asarray(m(jnp.asarray(rand_img(n, seed=7),
                                             jnp.float32))))
    direct = radon.solve(m, b, "cg", tol=1e-6, maxiter=100).image
    jitted = jax.jit(lambda bb: radon.solve(m, bb, "cg", tol=1e-6,
                                            maxiter=100).image)(b)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(jitted),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# serving properties: zero retrace, batching, the operator surface
# ---------------------------------------------------------------------------
def test_solver_loops_are_retrace_free():
    n = 7
    op = radon.DPRT((n, n), jnp.int32)
    m = masked_op(n, [2])
    rng = np.random.default_rng(8)
    b1 = jnp.asarray(rng.standard_normal((n + 1, n)), jnp.float32)
    b2 = jnp.asarray(rng.standard_normal((n + 1, n)), jnp.float32)
    with radon.retrace_guard(max_traces=1):
        for meth in ("cg", "lsqr", "landweber"):
            radon.solve(m, b1, meth, tol=1e-6, maxiter=40)
            radon.solve(m, b2, meth, tol=1e-6, maxiter=40)
        radon.solve(op, b1)
        radon.solve(op, b2)


def test_batched_solve_matches_per_image():
    n, nb = 7, 3
    mb = masked_op(n, [1], batch=nb)
    m1 = masked_op(n, [1])
    xs = rand_img(n, seed=9, batch=nb)
    bb = mb(jnp.asarray(xs, jnp.float32))
    res = radon.solve(mb, bb, "cg", tol=1e-6, maxiter=150)
    assert res.residual_norms.shape == (151, nb)
    for i in range(nb):
        one = radon.solve(m1, bb[i], "cg", tol=1e-6, maxiter=150)
        np.testing.assert_allclose(np.asarray(res.image[i]),
                                   np.asarray(one.image),
                                   rtol=1e-4, atol=1e-4)


def test_solve_operator_surface():
    n = 7
    mask = radon.direction_mask(n, [2])
    ro = radon.solve_operator((n, n), jnp.int32, mask=mask, tol=1e-7,
                              maxiter=150)
    assert ro.solver == "cg"
    assert ro.shape_in == (n + 1, n)
    assert ro.shape_out == (n, n)
    assert ro.dtype_in == float_dtype_for(jnp.int32)
    m = radon.MaskedDPRT(radon.DPRT((n, n), jnp.int32), mask=mask)
    b = jnp.asarray(np.asarray(m(jnp.asarray(rand_img(n, seed=10),
                                             jnp.float32))))
    exe = ro.compile()
    np.testing.assert_allclose(np.asarray(exe(b)), np.asarray(ro(b)),
                               rtol=1e-6, atol=1e-6)
    tok = ro.cache_token()
    assert tok.startswith("recon_") and "cg" in tok
    # unmasked defaults to the direct solver
    assert radon.solve_operator((n, n), jnp.int32).solver == "sherman"


# ---------------------------------------------------------------------------
# regression: integer sinograms promote to float without the x64 warning
# ---------------------------------------------------------------------------
def test_int_solve_no_accum_warning_at_cliff():
    import importlib
    dprt_mod = importlib.import_module("repro.core.dprt")
    n = 257   # the int32->int64 accumulator cliff geometry
    op = radon.DPRT((n, n), jnp.int16)
    b = jnp.zeros((n + 1, n), jnp.int16)
    old = dprt_mod._X64_WARNED
    dprt_mod._X64_WARNED = False
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            res = radon.solve(op, b)
    finally:
        dprt_mod._X64_WARNED = old
    assert res.image.dtype == float_dtype_for(jnp.int16)
