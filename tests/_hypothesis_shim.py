"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The container this repo targets ships without hypothesis and nothing may
be pip-installed, so ``conftest.py`` registers this module as
``sys.modules["hypothesis"]`` when the real package is missing.  It
implements exactly the surface the test-suite uses:

    from hypothesis import given, settings, strategies as st
    st.integers(lo, hi), st.sampled_from(seq)
    @settings(max_examples=K, deadline=None)
    @given(n=..., seed=...)

``given`` expands each test into ``max_examples`` deterministic examples
drawn from a PRNG seeded by the test name, so failures reproduce
run-to-run (no shrinking, no database -- just seeded sampling).
"""
from __future__ import annotations

import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(seq):
    items = list(seq)
    return _Strategy(lambda rng: items[int(rng.integers(0, len(items)))])


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
strategies.sampled_from = sampled_from


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        def runner():
            n = getattr(runner, "_hyp_max_examples",
                        getattr(fn, "_hyp_max_examples",
                                DEFAULT_MAX_EXAMPLES))
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                fn(**{k: s.example(rng) for k, s in strats.items()})
        # NOTE: no functools.wraps -- pytest must see a zero-arg function,
        # not the original signature (it would treat params as fixtures).
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco
