"""Pallas SFDPRT kernels vs the pure-jnp oracle (interpret mode on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dprt import dprt_oracle_np, idprt_oracle_np
from repro.kernels import (dprt_pallas, idprt_pallas, skew_sum_pallas,
                           dprt_ref, idprt_ref, skew_sum_ref)

PRIMES = [3, 5, 7, 13, 31]


@pytest.mark.parametrize("n", PRIMES)
@pytest.mark.parametrize("h,mb", [(2, 4), (3, 8), (4, 16), (999, 8)])
def test_forward_kernel_vs_oracle(n, h, mb):
    f = np.random.default_rng(n * h + mb).integers(0, 256, (n, n))
    f = f.astype(np.int32)
    out = np.asarray(dprt_pallas(jnp.asarray(f), strip_rows=h, m_block=mb))
    np.testing.assert_array_equal(out, dprt_oracle_np(f))


@pytest.mark.parametrize("n", PRIMES)
def test_inverse_kernel_roundtrip(n):
    f = np.random.default_rng(n).integers(0, 256, (n, n)).astype(np.int32)
    r = dprt_pallas(jnp.asarray(f), strip_rows=4, m_block=8)
    back = np.asarray(idprt_pallas(r, strip_rows=4, m_block=8))
    np.testing.assert_array_equal(back, f)
    np.testing.assert_array_equal(idprt_oracle_np(np.asarray(r)), f)


@pytest.mark.parametrize("dtype", [np.uint8, np.int16, np.int32])
def test_kernel_dtypes(dtype):
    n = 13
    hi = min(np.iinfo(dtype).max, 255)
    f = np.random.default_rng(7).integers(0, hi, (n, n)).astype(dtype)
    out = np.asarray(dprt_pallas(jnp.asarray(f)))
    np.testing.assert_array_equal(out, dprt_oracle_np(f.astype(np.int32)))


@pytest.mark.parametrize("sign", [1, -1])
def test_skew_sum_sign_matches_ref(sign):
    n = 11
    g = np.random.default_rng(0).integers(0, 99, (n, n)).astype(np.int32)
    a = np.asarray(skew_sum_pallas(jnp.asarray(g), sign=sign, strip_rows=3))
    b = np.asarray(skew_sum_ref(jnp.asarray(g), sign=sign))
    np.testing.assert_array_equal(a, b)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([5, 7, 11]),
       h=st.integers(1, 12),
       mb=st.sampled_from([1, 2, 4, 8, 16]),
       seed=st.integers(0, 10 ** 6))
def test_kernel_block_shape_sweep(n, h, mb, seed):
    """The kernel is exact for every (strip H x direction block M) tiling --
    the paper's whole Pareto family on one assert."""
    f = np.random.default_rng(seed).integers(0, 256, (n, n)).astype(np.int32)
    out = np.asarray(dprt_pallas(jnp.asarray(f), strip_rows=min(h, n),
                                 m_block=mb))
    np.testing.assert_array_equal(out, dprt_oracle_np(f))


def test_ref_matches_numpy_oracle():
    f = np.random.default_rng(1).integers(0, 256, (13, 13)).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(dprt_ref(jnp.asarray(f))),
                                  dprt_oracle_np(f))
    r = dprt_oracle_np(f)
    np.testing.assert_array_equal(np.asarray(idprt_ref(jnp.asarray(r))), f)
