"""Multi-process serving: frame protocol, journal, cross-process locks,
and the WorkerPool supervisor.

The quick tier drives the pool against the jax-free stub worker in
``tests/_pool_stub.py`` (the supervisor never interprets payloads, so
an echo worker exercises dispatch/replay/probe/crash/drain without a
~10s jax import per subprocess); the ``slow`` tests spawn real
``serve --jsonl`` router workers for the SIGTERM-drain regression and
true cross-process compile coalescing.
"""
import io
import json
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.checkpoint.store import (LockTimeout, _blob_path, blob_lock,
                                    list_blobs)
from repro.launch.errors import (QueueFull, ServiceError, WorkerLost,
                                 error_for_code)
from repro.launch.faults import FaultInjector, active_injector, \
    install_from_env
from repro.launch.pool import RequestJournal, payload_digest, read_frame, \
    write_frame
from repro.launch.supervisor import WorkerPool

HERE = os.path.dirname(os.path.abspath(__file__))
STUB = os.path.join(HERE, "_pool_stub.py")


def stub_pool(n_workers=2, *, stub_env=None, **kw):
    env = dict(os.environ)
    env.update(stub_env or {})
    kw.setdefault("probe_interval_s", 0.1)
    return WorkerPool(n_workers, cmd=[sys.executable, STUB], env=env, **kw)


def wait_for(cond, timeout_s=15.0, msg="condition"):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if cond():
            return
        time.sleep(0.02)
    raise AssertionError(f"timed out waiting for {msg}")


# ---------------------------------------------------------------------------
# frame protocol
# ---------------------------------------------------------------------------
def test_frame_roundtrip():
    buf = io.StringIO()
    msgs = [{"op": "submit", "id": "r1", "data": [[1, 2], [3, 4]]},
            {"ok": True, "nested": {"a": [1.5, None, "x"]}}]
    for m in msgs:
        write_frame(buf, m)
    buf.seek(0)
    assert read_frame(buf) == msgs[0]
    assert read_frame(buf) == msgs[1]
    assert read_frame(buf) is None          # EOF


def test_frame_reader_skips_noise_and_resyncs():
    buf = io.StringIO()
    buf.write("some stray log line\n\n")
    write_frame(buf, {"id": 1})
    buf.write("[warning] another stray\n")
    write_frame(buf, {"id": 2})
    buf.seek(0)
    assert read_frame(buf) == {"id": 1}
    assert read_frame(buf) == {"id": 2}


def test_frame_torn_write_reads_as_eof():
    buf = io.StringIO()
    write_frame(buf, {"id": 1, "data": [0] * 50})
    whole = buf.getvalue()
    torn = io.StringIO(whole[:len(whole) - 20])   # killed mid-payload
    assert read_frame(io.StringIO(whole)) == {"id": 1, "data": [0] * 50}
    assert read_frame(torn) is None


# ---------------------------------------------------------------------------
# journal + typed-error wire codes
# ---------------------------------------------------------------------------
def test_journal_counts_and_wal(tmp_path):
    path = str(tmp_path / "wal.jsonl")
    j = RequestJournal(path)
    j.record("dispatch", "r1", worker=0, digest="abc")
    j.record("replay", "r1", worker=1, digest="abc")
    j.record("deliver", "r1", replayed=True)
    j.record("lost", "r2", digest="def")
    assert j.stats() == {"dispatch": 1, "deliver": 1, "typed": 0,
                         "fail": 0, "replay": 1, "lost": 1}
    with pytest.raises(ValueError):
        j.record("nonsense", "r3")
    j.close()
    events = [json.loads(line) for line in open(path)]
    assert [e["ev"] for e in events] == ["dispatch", "replay", "deliver",
                                         "lost"]
    # the WAL is what makes "replayed bit-exact" auditable: the digest
    # at dispatch equals the digest at replay
    assert events[0]["digest"] == events[1]["digest"]


def test_payload_digest_is_content_addressed():
    a = np.arange(12, dtype=np.int32).reshape(3, 4)
    assert payload_digest(a) == payload_digest(a.copy())
    assert payload_digest(a) != payload_digest(a.T.copy())
    assert payload_digest(a) != payload_digest(a.astype(np.int64))


def test_error_for_code_rehydrates_typed_errors():
    e = error_for_code("queue_full", "busy", 1.25)
    assert isinstance(e, QueueFull) and e.retry_after_s == 1.25
    assert isinstance(error_for_code("worker_lost", "gone"), WorkerLost)
    unknown = error_for_code("no_such_code", "x")
    assert isinstance(unknown, ServiceError)
    assert not isinstance(unknown, QueueFull)


# ---------------------------------------------------------------------------
# fault-injector env activation
# ---------------------------------------------------------------------------
def test_fault_injector_from_spec():
    inj = FaultInjector.from_spec(
        "sites=dispatch|fallback;error_count=2;seed=7;match=13x13;"
        "delay_s=0.001;delay_rate=0.5;error_rate=0.25")
    assert inj.sites == ("dispatch", "fallback")
    assert inj.error_count == 2 and inj.seed == 7
    assert inj.match == "13x13" and inj.error_rate == 0.25
    assert inj.delay_s == 0.001 and inj.delay_rate == 0.5
    assert inj.spec and "error_count=2" in inj.spec
    assert inj.stats()["spec"] == inj.spec
    with pytest.raises(ValueError):
        FaultInjector.from_spec("unknown_knob=1")
    with pytest.raises(ValueError):
        FaultInjector.from_spec("error_count")


def test_install_from_env(monkeypatch):
    monkeypatch.delenv("REPRO_FAULTS", raising=False)
    assert install_from_env() is None
    monkeypatch.setenv("REPRO_FAULTS", "sites=dispatch;error_count=1")
    inj = install_from_env()
    try:
        assert inj is not None and active_injector() is inj
        with pytest.raises(Exception):
            inj.perturb("dispatch", "any")       # the armed budget fires
    finally:
        inj.__exit__(None, None, None)
    assert active_injector() is not inj


# ---------------------------------------------------------------------------
# cross-process blob locks
# ---------------------------------------------------------------------------
def test_blob_lock_acquire_release(tmp_path):
    d = str(tmp_path)
    with blob_lock(d, "tok") as lk:
        lock_file = _blob_path(d, "tok") + ".lock"
        assert os.path.exists(lock_file)
        info = json.load(open(lock_file))
        assert info["pid"] == os.getpid()
        assert lk["steals"] == 0
    assert not os.path.exists(lock_file)


def test_blob_lock_contention_waits(tmp_path):
    d = str(tmp_path)
    order = []

    def holder():
        with blob_lock(d, "tok"):
            order.append("a-in")
            time.sleep(0.3)
            order.append("a-out")

    t = threading.Thread(target=holder)
    t.start()
    wait_for(lambda: order == ["a-in"], msg="holder inside")
    with blob_lock(d, "tok", poll_s=0.01) as lk:
        order.append("b-in")
    t.join()
    assert order == ["a-in", "a-out", "b-in"]
    assert lk["waited_s"] > 0.1 and lk["steals"] == 0


def test_blob_lock_steals_dead_pid(tmp_path):
    d = str(tmp_path)
    corpse = subprocess.Popen(["sleep", "0"])
    corpse.wait()
    lock_file = _blob_path(d, "tok") + ".lock"
    with open(lock_file, "w") as f:
        json.dump({"pid": corpse.pid, "key": "tok",
                   "time": time.time()}, f)
    with blob_lock(d, "tok", poll_s=0.01) as lk:
        assert lk["steals"] >= 1            # dead holder reclaimed
    assert not os.path.exists(lock_file)


def test_blob_lock_respects_live_holder_then_times_out(tmp_path):
    d = str(tmp_path)
    lock_file = _blob_path(d, "tok") + ".lock"
    with open(lock_file, "w") as f:         # held by THIS live process
        json.dump({"pid": os.getpid(), "key": "tok",
                   "time": time.time()}, f)
    with pytest.raises(LockTimeout):
        with blob_lock(d, "tok", poll_s=0.02, timeout_s=0.2,
                       stale_s=100.0):
            pass
    assert os.path.exists(lock_file)        # never stolen from the living
    os.unlink(lock_file)


def test_blob_lock_steals_aged_lock(tmp_path):
    d = str(tmp_path)
    lock_file = _blob_path(d, "tok") + ".lock"
    with open(lock_file, "w") as f:         # live PID but ancient
        json.dump({"pid": os.getpid(), "key": "tok",
                   "time": time.time() - 3600.0}, f)
    with blob_lock(d, "tok", stale_s=1.0, poll_s=0.01) as lk:
        assert lk["steals"] >= 1


# ---------------------------------------------------------------------------
# WorkerPool against the stub worker
# ---------------------------------------------------------------------------
def test_pool_roundtrip_and_identity():
    with stub_pool(2) as pool:
        assert pool.wait_ready(20.0)
        imgs = [np.full((2, 2), i, np.int64) for i in range(8)]
        futs = [pool.submit({"n": 2}, im) for im in imgs]
        outs = [f.result(timeout=20) for f in futs]
        for i, out in enumerate(outs):
            assert np.array_equal(out, 2 * imgs[i])
        report = pool.healthz(probe=True)
    assert report["identity_ok"]
    assert report["admitted"] == report["delivered"] == 8
    assert pool.verdict() == "OK"
    assert pool.journal.stats()["dispatch"] == 8
    assert pool.journal.stats()["deliver"] == 8
    # both workers actually served (round-robin)
    assert all(w["pid"] for w in report["workers"])


def test_pool_sigkill_replays_then_restarts():
    with stub_pool(2, stub_env={"STUB_DELAY_S": "0.25"},
                   restart_backoff_s=0.1) as pool:
        assert pool.wait_ready(20.0)
        imgs = [np.full((2, 2), i, np.int64) for i in range(6)]
        futs = [pool.submit({"n": 2}, im) for im in imgs]
        time.sleep(0.05)                    # let dispatch begin
        assert pool.kill_worker(0)
        outs = [f.result(timeout=30) for f in futs]
        for i, out in enumerate(outs):      # replays are bit-exact
            assert np.array_equal(out, 2 * imgs[i])
        assert pool.replays > 0, "no in-flight request was replayed"
        assert pool.workers_lost == 1
        # the killed worker comes back and serves again
        wait_for(lambda: pool._workers[0].alive, 20.0, "worker restart")
        assert pool.wait_ready(20.0)
        out = pool.submit({"n": 2}, imgs[0]).result(timeout=20)
        assert np.array_equal(out, 2 * imgs[0])
        assert pool.worker_restarts >= 1
    assert pool.identity_ok()
    assert pool.failed == 0
    assert pool.verdict() == "WARN"         # loss+replay degrade, not FAIL
    j = pool.journal.stats()
    assert j["replay"] > 0 and j["lost"] == 0


def test_pool_single_worker_loss_is_typed_worker_lost():
    with stub_pool(1, stub_env={"STUB_DELAY_S": "0.4"},
                   max_restarts=0) as pool:
        assert pool.wait_ready(20.0)
        futs = [pool.submit({"n": 2}, np.ones((2, 2), np.int64))
                for _ in range(3)]
        time.sleep(0.05)
        assert pool.kill_worker(0)
        with pytest.raises(WorkerLost):
            futs[0].result(timeout=20)
        for f in futs[1:]:                  # every future resolves typed
            with pytest.raises(WorkerLost):
                f.result(timeout=20)
    assert pool.rejected.get("worker_lost") == 3
    assert pool.identity_ok() and pool.pending() == 0
    assert pool.journal.stats()["lost"] == 3
    assert pool.verdict() == "WARN"


def test_pool_crash_exit_detected_without_external_kill():
    # the stub hard-exits itself mid-service: reader EOF is the crash
    # detector, no signal involved
    with stub_pool(2, stub_env={"STUB_EXIT_AFTER": "2",
                                "STUB_DELAY_S": "0.05"},
                   restart_backoff_s=0.1) as pool:
        assert pool.wait_ready(20.0)
        futs = [pool.submit({"n": 2}, np.ones((2, 2), np.int64))
                for _ in range(10)]
        done = 0
        for f in futs:
            try:
                f.result(timeout=30)
                done += 1
            except ServiceError:
                pass
        assert done > 0
        assert pool.workers_lost >= 1
    assert pool.identity_ok() and pool.failed == 0


def test_pool_pending_budget_rejects_with_retry_hint():
    with stub_pool(1, stub_env={"STUB_DELAY_S": "0.3"},
                   pending_cap=3) as pool:
        assert pool.wait_ready(20.0)
        futs, hints = [], []
        for _ in range(8):
            try:
                futs.append(pool.submit({"n": 2},
                                        np.ones((2, 2), np.int64)))
            except QueueFull as e:
                hints.append(e.retry_after_s)
        assert len(futs) == 3 and len(hints) == 5
        assert all(h is not None and h > 0 for h in hints)
        for f in futs:
            f.result(timeout=20)
    assert pool.rejected_admission.get("queue_full") == 5
    assert pool.identity_ok()
    assert pool.verdict() == "WARN"


def test_pool_typed_error_passthrough_with_hint():
    with stub_pool(1) as pool:
        assert pool.wait_ready(20.0)
        fut = pool.submit({"n": 2, "stub_error": "queue_full",
                           "retry_after_s": 1.5},
                          np.ones((2, 2), np.int64))
        with pytest.raises(QueueFull) as ei:
            fut.result(timeout=20)
        assert ei.value.retry_after_s == 1.5
    assert pool.rejected.get("queue_full") == 1
    assert pool.identity_ok()


def test_pool_probe_suspect_kill_of_hung_worker():
    # worker answers its first frame then goes mute (hung, not dead):
    # the probe monitor must suspect it and kill it
    with stub_pool(1, stub_env={"STUB_MUTE_AFTER": "1"},
                   probe_interval_s=0.05, probe_misses=2,
                   max_restarts=0) as pool:
        pool.wait_ready(5.0)                # first (only) reply
        wait_for(lambda: pool.suspect_kills >= 1, 15.0,
                 "suspect kill of the mute worker")
    assert pool.workers_lost >= 1
    assert pool.verdict() == "WARN"


def test_pool_drain_flushes_in_flight():
    pool = stub_pool(2, stub_env={"STUB_DELAY_S": "0.15"})
    pool.start()
    assert pool.wait_ready(20.0)
    imgs = [np.full((2, 2), i, np.int64) for i in range(4)]
    futs = [pool.submit({"n": 2}, im) for im in imgs]
    pool.drain()                            # graceful: flush, then exit
    for i, f in enumerate(futs):
        assert f.done(), "drain left a future unresolved"
        try:
            assert np.array_equal(f.result(), 2 * imgs[i])
        except ServiceError:
            pass                            # typed shutdown is legal too
    assert pool.identity_ok() and pool.pending() == 0
    assert pool.failed == 0
    with pytest.raises(ServiceError):
        pool.submit({"n": 2}, imgs[0])      # drained pool admits nothing


# ---------------------------------------------------------------------------
# real router workers (slow tier: each spawn pays the jax import)
# ---------------------------------------------------------------------------
REPO = os.path.dirname(HERE)
SRC = os.path.join(REPO, "src")


def worker_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


@pytest.mark.slow
def test_jsonl_sigterm_drains_and_emits_final_healthz(tmp_path):
    cmd = [sys.executable, "-m", "repro.launch.serve", "--mode", "service",
           "--jsonl", "--sigterm-drain", "--batch", "2",
           "--manifest", '[{"n": 5}]', "--aot-dir", str(tmp_path)]
    proc = subprocess.Popen(cmd, stdin=subprocess.PIPE,
                            stdout=subprocess.PIPE,
                            stderr=subprocess.DEVNULL, text=True,
                            env=worker_env())
    try:
        img = np.ones((5, 5), np.int32)
        proc.stdin.write(json.dumps(
            {"op": "submit", "id": "r1", "n": 5,
             "data": img.tolist()}) + "\n")
        proc.stdin.flush()
        first = json.loads(proc.stdout.readline())
        assert first["id"] == "r1" and first["ok"]
        proc.send_signal(signal.SIGTERM)
        rest = [json.loads(line) for line in proc.stdout
                if line.strip()]
        rc = proc.wait(timeout=60)
    finally:
        proc.kill()
    assert rc == 0, "SIGTERM must drain, not kill the worker"
    finals = [m for m in rest if m.get("id") == "__drain__"]
    assert finals and finals[-1].get("final") is True
    assert finals[-1]["verdict"] in ("OK", "WARN")
    assert finals[-1]["stats"]["pending"] == 0


@pytest.mark.slow
def test_cross_process_compile_coalescing_and_stale_lock(tmp_path):
    """Two fresh worker processes cold-start one aot_dir concurrently:
    exactly one compile per unique cache token (the file locks coalesce
    them); a third worker then recovers past stale dead-PID locks."""
    aot = str(tmp_path / "aot")
    cmd = [sys.executable, "-m", "repro.launch.serve", "--mode", "service",
           "--jsonl", "--framed", "--batch", "2",
           "--manifest", '[{"n": 5}]', "--aot-dir", aot]

    def spawn():
        return subprocess.Popen(cmd, stdin=subprocess.PIPE,
                                stdout=subprocess.PIPE,
                                stderr=subprocess.DEVNULL, text=True,
                                env=worker_env())

    def healthz(proc):
        write_frame(proc.stdin, {"op": "healthz", "id": "h"})
        while True:
            msg = read_frame(proc.stdout)
            assert msg is not None, "worker died before healthz reply"
            if msg.get("id") == "h":
                return msg

    def shutdown(proc):
        write_frame(proc.stdin, {"op": "shutdown", "id": "bye"})
        assert proc.wait(timeout=60) == 0

    p1, p2 = spawn(), spawn()               # genuinely concurrent boot
    try:
        h1, h2 = healthz(p1), healthz(p2)
        shutdown(p1)
        shutdown(p2)
    finally:
        p1.kill()
        p2.kill()
    blobs = list_blobs(aot)
    assert blobs, "cold start published no executables"
    misses = h1["persistent"]["misses"] + h2["persistent"]["misses"]
    hits = h1["persistent"]["hits"] + h2["persistent"]["hits"]
    assert misses == len(blobs), \
        (f"coalescing broken: {misses} compiles for {len(blobs)} "
         f"unique executables ({h1['persistent']} / {h2['persistent']})")
    assert hits == len(blobs), "the non-compiling worker must restore"
    assert not [f for f in os.listdir(aot) if f.endswith(".lock")]

    # stale dead-PID locks on every blob: a fresh worker must steal
    # them and come up warm, not deadlock or recompile
    corpse = subprocess.Popen(["sleep", "0"])
    corpse.wait()
    for key in blobs:
        with open(_blob_path(aot, key) + ".lock", "w") as f:
            json.dump({"pid": corpse.pid, "key": key,
                       "time": time.time() - 3600.0}, f)
    p3 = spawn()
    try:
        h3 = healthz(p3)
        shutdown(p3)
    finally:
        p3.kill()
    assert h3["persistent"]["misses"] == 0
    assert h3["persistent"]["hits"] == len(blobs)
    assert h3["persistent"]["lock_steals"] >= len(blobs)
    assert not [f for f in os.listdir(aot) if f.endswith(".lock")]


@pytest.mark.slow
def test_pool_of_real_workers_end_to_end(tmp_path):
    """A small WorkerPool over two real router workers: bit-exact
    against the in-process oracle, pool healthz aggregates worker
    reports (faults spec echoed), identity closes."""
    import jax.numpy as jnp

    from repro import radon

    aot = str(tmp_path / "aot")
    n = 5
    spec = "sites=dispatch;error_count=1;seed=3"
    env = worker_env()
    env["REPRO_FAULTS"] = spec
    rng = np.random.default_rng(0)
    imgs = [rng.integers(0, 50, (n, n)).astype(np.int32)
            for _ in range(8)]
    fwd = radon.DPRT((1, n, n), jnp.int32)
    expected = [np.asarray(fwd(jnp.asarray(im[None])))[0] for im in imgs]

    pool = WorkerPool(2, aot_dir=aot, manifest=[{"n": n}], max_batch=2,
                      env=env, probe_interval_s=1.0)
    with pool:
        assert pool.wait_ready(600.0), "real workers never became ready"
        futs = [pool.submit({"n": n}, im) for im in imgs]
        outs = [f.result(timeout=300) for f in futs]
        report = pool.healthz(probe=True)
    for out, want in zip(outs, expected):
        assert np.array_equal(np.asarray(out), want)
    assert report["identity_ok"]
    assert report["delivered"] == len(imgs)
    for w in report["workers"]:
        assert w["faults_env"] == spec      # env seam reached the worker
        assert w["retraces_since_start"] == 0
    misses = sum(w["persistent"]["misses"] for w in report["workers"])
    assert misses == len(list_blobs(aot))   # coalesced cold start
