"""DPRT applications: exact convolution (the paper's motivation) and the
discrete Fourier-slice 2-D DFT."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import conv as C
from repro.core import dft as F
from repro.core.dprt import next_prime


@pytest.mark.parametrize("n", [5, 7, 11, 13])
def test_circular_conv_exact(n):
    rng = np.random.default_rng(n)
    f = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 16, (n, n)), jnp.int32)
    got = np.asarray(C.circ_conv2d_dprt(f, g))
    want = np.asarray(C.circ_conv2d_direct(f, g))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(a=st.integers(3, 9), c=st.integers(2, 5), seed=st.integers(0, 10 ** 6))
def test_linear_conv_exact_vs_numpy(a, c, seed):
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.integers(0, 256, (a, a)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 16, (c, c)), jnp.int32)
    got = np.asarray(C.linear_conv2d_dprt(f, g))
    np.testing.assert_array_equal(got, C.linear_conv2d_direct(f, g))


@settings(max_examples=10, deadline=None)
@given(ah=st.integers(2, 9), aw=st.integers(2, 9), ch=st.integers(1, 4),
       cw=st.integers(1, 4), seed=st.integers(0, 10 ** 6))
def test_linear_conv_rectangular_exact(ah, aw, ch, cw, seed):
    """Regression: the old square-only prime padding mis-padded
    rectangular operands; the geometry layer pads each axis."""
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.integers(0, 256, (ah, aw)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 16, (ch, cw)), jnp.int32)
    got = np.asarray(C.linear_conv2d_dprt(f, g))
    assert got.shape == (ah + ch - 1, aw + cw - 1)
    np.testing.assert_array_equal(got, C.linear_conv2d_direct(f, g))


@settings(max_examples=6, deadline=None)
@given(block=st.integers(2, 9), seed=st.integers(0, 10 ** 6))
def test_linear_conv_blocked_overlap_add_equals_whole(block, seed):
    """Companion-paper overlap-add: tile-by-tile at the tile prime must
    reproduce the whole-image result exactly."""
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.integers(0, 256, (13, 17)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 16, (3, 4)), jnp.int32)
    whole = np.asarray(C.linear_conv2d_dprt(f, g))
    blocked = np.asarray(C.linear_conv2d_dprt(f, g, block_size=block))
    np.testing.assert_array_equal(blocked, whole)
    np.testing.assert_array_equal(whole, C.linear_conv2d_direct(f, g))


def test_linear_conv_blocked_batched_stack():
    rng = np.random.default_rng(7)
    fb = jnp.asarray(rng.integers(0, 256, (3, 10, 8)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 16, (3, 3)), jnp.int32)
    got = np.asarray(C.linear_conv2d_dprt(fb, g, method="pallas",
                                          block_size=4))
    for i in range(3):
        np.testing.assert_array_equal(
            got[i], C.linear_conv2d_direct(fb[i], g))


def test_circular_conv_arbitrary_geometry_torus():
    """Non-prime geometry circular conv = true (H, W)-torus convolution
    (fold of the exact linear convolution)."""
    rng = np.random.default_rng(4)
    h, w = 6, 8
    f = rng.integers(0, 50, (h, w)).astype(np.int64)
    g = rng.integers(0, 10, (h, w)).astype(np.int64)
    got = np.asarray(C.circ_conv2d_dprt(jnp.asarray(f, jnp.int32),
                                        jnp.asarray(g, jnp.int32)))
    want = np.zeros((h, w), np.int64)
    for x in range(h):
        for y in range(w):
            want[x, y] = sum(f[u, v] * g[(x - u) % h, (y - v) % w]
                             for u in range(h) for v in range(w))
    np.testing.assert_array_equal(got, want)


def test_circular_conv_rejects_mismatched_geometry():
    with pytest.raises(ValueError):
        C.circ_conv2d_dprt(jnp.zeros((5, 5), jnp.int32),
                           jnp.zeros((7, 7), jnp.int32))


def test_dft_batched_matches_reference():
    rng = np.random.default_rng(9)
    fb = jnp.asarray(rng.integers(0, 256, (4, 13, 13)), jnp.int32)
    got = np.asarray(F.dft2_via_dprt_batched(fb))
    for i in range(4):
        want = np.asarray(F.dft2_reference(fb[i]))
        assert np.max(np.abs(got[i] - want)) / np.max(np.abs(want)) < 1e-5


def test_dft_kwargs_forward_to_dispatch():
    rng = np.random.default_rng(10)
    f = jnp.asarray(rng.integers(0, 256, (13, 13)), jnp.int32)
    base = np.asarray(F.dft2_via_dprt(f))
    for kw in [dict(method="strips", strip_rows=4),
               dict(method="pallas", strip_rows=5, m_block=3)]:
        np.testing.assert_array_equal(np.asarray(F.dft2_via_dprt(f, **kw)),
                                      base)
    with pytest.raises(ValueError):
        F.dft2_via_dprt(jnp.zeros((6, 6), jnp.int32))  # non-prime: no DFT


def test_fft_path_agrees_but_is_float():
    """The FFT route (what the paper's hardware avoids) only matches after
    rounding -- the DPRT route is exact by construction."""
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.integers(0, 256, (11, 11)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 16, (11, 11)), jnp.int32)
    exact = np.asarray(C.circ_conv2d_dprt(f, g))
    fft = np.asarray(C.circ_conv2d_fft(f, g))
    np.testing.assert_allclose(fft, exact, rtol=0, atol=0.5)
    assert not np.issubdtype(np.asarray(
        jnp.fft.fft2(f)).dtype, np.integer)


def test_prime_padding_beats_pow2():
    """Sec. I density-of-primes argument, quantified."""
    r = C.prime_vs_pow2_padding(251, 16)
    assert r["prime_pad"] == next_prime(266) == 269
    assert r["pow2_pad"] == 512
    assert r["prime_overhead"] < 1.05 < 1.5 < r["pow2_overhead"]
    # and generally: prime overhead is small across a sweep
    for size in [100, 251, 500, 1000]:
        rr = C.prime_vs_pow2_padding(size, 32)
        assert rr["prime_overhead"] <= rr["pow2_overhead"]


@pytest.mark.parametrize("n", [7, 13, 31])
def test_dft_slice_theorem(n):
    rng = np.random.default_rng(n)
    f = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)
    got = np.asarray(F.dft2_via_dprt(f))
    want = np.asarray(F.dft2_reference(f))
    scale = np.max(np.abs(want))
    assert np.max(np.abs(got - want)) / scale < 1e-5


def test_conv1d_exact_batched():
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.integers(-50, 50, (4, 11)), jnp.int32)
    b = jnp.asarray(rng.integers(-10, 10, (4, 11)), jnp.int32)
    got = np.asarray(C.circ_conv1d_exact(a, b))
    for i in range(4):
        want = np.array([sum(int(a[i, t]) * int(b[i, (d - t) % 11])
                             for t in range(11)) for d in range(11)])
        np.testing.assert_array_equal(got[i], want)
