"""DPRT applications: exact convolution (the paper's motivation) and the
discrete Fourier-slice 2-D DFT."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import conv as C
from repro.core import dft as F
from repro.core.dprt import next_prime


@pytest.mark.parametrize("n", [5, 7, 11, 13])
def test_circular_conv_exact(n):
    rng = np.random.default_rng(n)
    f = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 16, (n, n)), jnp.int32)
    got = np.asarray(C.circ_conv2d_dprt(f, g))
    want = np.asarray(C.circ_conv2d_direct(f, g))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(a=st.integers(3, 9), c=st.integers(2, 5), seed=st.integers(0, 10 ** 6))
def test_linear_conv_exact_vs_numpy(a, c, seed):
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.integers(0, 256, (a, a)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 16, (c, c)), jnp.int32)
    got = np.asarray(C.linear_conv2d_dprt(f, g))
    np.testing.assert_array_equal(got, C.linear_conv2d_direct(f, g))


def test_fft_path_agrees_but_is_float():
    """The FFT route (what the paper's hardware avoids) only matches after
    rounding -- the DPRT route is exact by construction."""
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.integers(0, 256, (11, 11)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 16, (11, 11)), jnp.int32)
    exact = np.asarray(C.circ_conv2d_dprt(f, g))
    fft = np.asarray(C.circ_conv2d_fft(f, g))
    np.testing.assert_allclose(fft, exact, rtol=0, atol=0.5)
    assert not np.issubdtype(np.asarray(
        jnp.fft.fft2(f)).dtype, np.integer)


def test_prime_padding_beats_pow2():
    """Sec. I density-of-primes argument, quantified."""
    r = C.prime_vs_pow2_padding(251, 16)
    assert r["prime_pad"] == next_prime(266) == 269
    assert r["pow2_pad"] == 512
    assert r["prime_overhead"] < 1.05 < 1.5 < r["pow2_overhead"]
    # and generally: prime overhead is small across a sweep
    for size in [100, 251, 500, 1000]:
        rr = C.prime_vs_pow2_padding(size, 32)
        assert rr["prime_overhead"] <= rr["pow2_overhead"]


@pytest.mark.parametrize("n", [7, 13, 31])
def test_dft_slice_theorem(n):
    rng = np.random.default_rng(n)
    f = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)
    got = np.asarray(F.dft2_via_dprt(f))
    want = np.asarray(F.dft2_reference(f))
    scale = np.max(np.abs(want))
    assert np.max(np.abs(got - want)) / scale < 1e-5


def test_conv1d_exact_batched():
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.integers(-50, 50, (4, 11)), jnp.int32)
    b = jnp.asarray(rng.integers(-10, 10, (4, 11)), jnp.int32)
    got = np.asarray(C.circ_conv1d_exact(a, b))
    for i in range(4):
        want = np.array([sum(int(a[i, t]) * int(b[i, (d - t) % 11])
                             for t in range(11)) for d in range(11)])
        np.testing.assert_array_equal(got[i], want)
