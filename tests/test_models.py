"""Per-arch smoke tests (reduced same-family configs) + decode consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import Model
from repro.parallel.sharding import init_params, count_params

B, S = 2, 40


def _batch(cfg, rng, seq, with_labels=True):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, seq)))}
    if with_labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, seq)))
    if cfg.frontend == "patch_stub":
        batch["patch_embed"] = jnp.asarray(
            rng.normal(size=(B, cfg.prefix_len, cfg.d_model)), jnp.float32)
    if cfg.frontend == "audio_stub":
        batch["audio_embed"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one grad step, shapes + finiteness."""
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = init_params(model.specs(), jax.random.key(0), jnp.float32)
    rng = np.random.default_rng(42)
    batch = _batch(cfg, rng, S)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf logits"
    (loss, _), grads = jax.jit(
        jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    finite = jax.tree.reduce(
        lambda acc, g: acc and bool(jnp.isfinite(g).all()), grads, True)
    assert finite, f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forcing(arch):
    """prefill(S) + decode_step(S) must equal forward(S+1) at position S."""
    cfg = get_smoke_config(arch)
    if cfg.num_experts:  # disable capacity drops for the equivalence check
        cfg = cfg.replace(capacity_factor=float(cfg.num_experts))
    model = Model(cfg)
    params = init_params(model.specs(), jax.random.key(1), jnp.float32)
    rng = np.random.default_rng(7)
    batch = _batch(cfg, rng, S + 1, with_labels=False)
    ref, _ = jax.jit(model.forward)(params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S]
    last, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=S + 8))(params, pre)
    dec, _ = jax.jit(model.decode_step)(
        params, cache, batch["tokens"][:, S:S + 1], jnp.int32(S))
    scale = float(np.max(np.abs(np.asarray(ref[:, S - 1])))) + 1e-9
    err_pre = float(np.max(np.abs(
        np.asarray(ref[:, S - 1]) - np.asarray(last[:, 0])))) / scale
    err_dec = float(np.max(np.abs(
        np.asarray(ref[:, S]) - np.asarray(dec[:, 0])))) / scale
    assert err_pre < 1e-4, f"{arch}: prefill mismatch {err_pre}"
    assert err_dec < 2e-3, f"{arch}: decode mismatch {err_dec}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_budget(arch):
    """The full (assigned) configs build specs with the right scale."""
    expected = {
        "phi3_medium_14b": (12e9, 16e9),
        "tinyllama_1_1b": (0.9e9, 1.3e9),
        "minitron_8b": (7e9, 10.5e9),
        "qwen3_0_6b": (0.4e9, 0.9e9),
        "internvl2_26b": (17e9, 26e9),     # LM backbone (ViT is a stub)
        "qwen3_moe_235b_a22b": (200e9, 260e9),
        "deepseek_v2_236b": (200e9, 260e9),
        "whisper_large_v3": (1.2e9, 2.2e9),
        "recurrentgemma_2b": (2.0e9, 3.5e9),
        "mamba2_2_7b": (2.2e9, 3.2e9),
    }[arch]
    cfg = get_config(arch)
    n = count_params(Model(cfg).specs())
    assert expected[0] <= n <= expected[1], f"{arch}: {n:,} params"


def test_long_context_states_are_o1():
    """SSM/hybrid decode state must not scale with context length --
    this is what makes long_500k runnable for them."""
    for arch in ["mamba2_2_7b", "recurrentgemma_2b"]:
        cfg = get_smoke_config(arch)
        model = Model(cfg)
        s1 = model.cache_shapes(1, 1024)
        s2 = model.cache_shapes(1, 1024 * 512)
        n1 = sum(np.prod(s) for s in jax.tree.leaves(
            s1, is_leaf=lambda v: isinstance(v, tuple)))
        n2 = sum(np.prod(s) for s in jax.tree.leaves(
            s2, is_leaf=lambda v: isinstance(v, tuple)))
        assert n2 == n1, f"{arch}: cache grows with context"


def test_full_attention_cache_grows():
    cfg = get_smoke_config("phi3_medium_14b")
    model = Model(cfg)
    n1 = sum(np.prod(s) for s in jax.tree.leaves(
        model.cache_shapes(1, 128),
        is_leaf=lambda v: isinstance(v, tuple)))
    n2 = sum(np.prod(s) for s in jax.tree.leaves(
        model.cache_shapes(1, 256),
        is_leaf=lambda v: isinstance(v, tuple)))
    assert n2 > n1
