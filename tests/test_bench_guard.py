"""The perf regression guard must stay runnable everywhere: baseline
rows whose backend cannot run in the current process (mesh rows needing
forced host devices, unregistered backends) are SKIPPED with a warning,
never failed."""
import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:  # make the benchmarks package importable
    sys.path.insert(0, REPO)

from benchmarks import check_regression as CR  # noqa: E402


def _baseline(rows):
    return {"backend": "cpu", "rows": {r["name"]: r for r in rows}}


def test_unavailable_mesh_row_skips_not_fails():
    baseline = _baseline([
        {"name": "dprt_impl/sharded_pallas8/N251", "us_per_call": 6000.0,
         "method": "sharded_pallas", "devices": 8},
        {"name": "dprt_impl/horner/N251", "us_per_call": 100.0,
         "method": "horner"},
    ])
    fresh = [{"name": "dprt_impl/horner/N251", "us_per_call": 101.0}]
    lines, regressions = CR.compare(baseline, fresh, tol=1.5)
    assert not regressions
    skipped = [ln for ln in lines if ln.startswith("SKIPPED")]
    assert len(skipped) == 1 and "sharded_pallas8" in skipped[0], lines


def test_unregistered_backend_row_skips():
    baseline = _baseline([
        {"name": "dprt_impl/exotic/N251", "us_per_call": 1.0,
         "method": "no_such_backend"},
    ])
    lines, regressions = CR.compare(baseline, [], tol=1.5)
    assert not regressions
    assert any(ln.startswith("SKIPPED") and "not registered" in ln
               for ln in lines), lines


def test_measurable_missing_row_still_reported_missing():
    baseline = _baseline([
        {"name": "dprt_impl/horner/N251", "us_per_call": 100.0,
         "method": "horner"},
    ])
    lines, _ = CR.compare(baseline, [], tol=1.5)
    assert any(ln.startswith("MISSING") for ln in lines), lines


def test_regression_still_fails():
    baseline = _baseline([
        {"name": "dprt_impl/horner/N251", "us_per_call": 100.0,
         "method": "horner"},
    ])
    fresh = [{"name": "dprt_impl/horner/N251", "us_per_call": 250.0}]
    lines, regressions = CR.compare(baseline, fresh, tol=1.5)
    assert regressions and regressions[0][0] == "dprt_impl/horner/N251"
