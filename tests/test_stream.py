"""In-launch streamed-strip SFDPRT kernels (``stream_rows``) and the
direction-sharded collective layout.

The streamed kernels process an N x N image that does not fit
whole-image-in-VMEM as ONE ``pallas_call``: the grid (or an in-kernel
DMA double-buffer loop) walks row strips and accumulates partial
skew-sums in a VMEM scratch accumulator.  Everything here must stay
bit-exact against the whole-image kernel and the numpy oracle --
including awkward primes where the strip count does not divide N (the
final strip is masked padding).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.dprt import dprt_oracle_np
from repro.core.plan import available_backends, get_backend, get_plan
from repro.kernels.sfdprt import (dprt_pallas_raw, idprt_pallas_raw,
                                  skew_sum_pallas_raw)
from repro.kernels.tuning import resolve_blocks
from repro import radon


def _img(n, b=None, seed=0, lo=0, hi=250):
    rng = np.random.default_rng(seed)
    shape = (n, n) if b is None else (b, n, n)
    return rng.integers(lo, hi, shape, dtype=np.int32)


# ---------------------------------------------------------------------------
# streamed vs whole-image: bit-exact, both stream impls, partial strips
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("stream_impl", ["grid", "dma"])
@pytest.mark.parametrize("n,sr", [(13, 5), (61, 7)])
def test_streamed_raw_kernels_bitexact(n, sr, stream_impl):
    """Raw streamed kernels == whole-image kernels == oracle, at strip
    heights that do NOT divide N (final strip is a masked partial)."""
    assert n % sr != 0, "test wants a masked final strip"
    fb = jnp.asarray(_img(n, b=3, seed=n))
    whole = dprt_pallas_raw(fb, strip_rows=n, m_block=8)
    got = dprt_pallas_raw(fb, stream_rows=sr, m_block=8,
                          stream_impl=stream_impl)
    assert (np.asarray(got) == np.asarray(whole)).all()
    for b in range(3):
        assert (np.asarray(got[b]) == dprt_oracle_np(np.asarray(fb[b]))).all()
    back = idprt_pallas_raw(got, stream_rows=sr, m_block=8,
                            stream_impl=stream_impl)
    assert (np.asarray(back) == np.asarray(fb)).all()
    # bare skew-sum, both signs (adjoint datapaths ride this)
    for sign in (1, -1):
        want = skew_sum_pallas_raw(fb, sign, strip_rows=n, m_block=8)
        got = skew_sum_pallas_raw(fb, sign, stream_rows=sr, m_block=8,
                                  stream_impl=stream_impl)
        assert (np.asarray(got) == np.asarray(want)).all(), sign


@pytest.mark.parametrize("stream_impl", ["grid", "dma"])
def test_streamed_row_offset_partials(stream_impl):
    """A streamed partial over a shard-local strip (row_offset) matches
    the fused strip kernel -- the contract the sharded backend uses."""
    n, rows, off = 13, 6, 7
    g = jnp.asarray(_img(n, seed=5)[:rows])
    want = skew_sum_pallas_raw(g, 1, strip_rows=rows, m_block=8,
                               row_offset=off)
    got = skew_sum_pallas_raw(g, 1, stream_rows=4, m_block=8,
                              row_offset=off, stream_impl=stream_impl)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_streamed_plan_roundtrip_and_adjoint():
    """Plan-level: stream_rows on the pallas backend stays bit-exact
    through forward / inverse / adjoint."""
    n, sr = 61, 7
    f = jnp.asarray(_img(n, seed=2))
    oracle = dprt_oracle_np(np.asarray(f))
    whole = get_plan(f.shape, f.dtype, "pallas")
    p = get_plan(f.shape, f.dtype, "pallas", stream_rows=sr)
    assert p.stream_rows == sr
    assert p.describe()["stream_rows"] == sr
    r = p.forward(f)
    assert (np.asarray(r) == oracle).all()
    assert (np.asarray(r) == np.asarray(whole.forward(f))).all()
    assert (np.asarray(p.inverse(r)) == np.asarray(f)).all()
    ra = jnp.asarray(oracle.astype(np.int32))
    assert (np.asarray(p.adjoint(ra))
            == np.asarray(whole.adjoint(ra))).all()


def test_streamed_noncapable_backends_fall_back_to_scan():
    """stream_rows on a backend without streamed kernels takes the
    staged scan fallback at the same strip height -- still exact."""
    n, sr = 13, 5
    f = jnp.asarray(_img(n, seed=3))
    oracle = dprt_oracle_np(np.asarray(f))
    for method in available_backends():
        be = get_backend(method)
        if be.mesh_aware or be.takes_stream_rows:
            continue
        p = get_plan(f.shape, f.dtype, method, stream_rows=sr)
        assert p._scan_rows == sr, method
        assert (np.asarray(p.forward(f)) == oracle).all(), method
        assert (np.asarray(p.inverse(jnp.asarray(oracle.astype(np.int32))))
                == np.asarray(f)).all(), method
    # capable backends must NOT take the scan fallback
    assert get_plan(f.shape, f.dtype, "pallas",
                    stream_rows=sr)._scan_rows is None


def test_streamed_ambient_config_carries_through():
    """radon.config(stream_rows=...) resolves eagerly into the plan."""
    n = 61
    f = jnp.asarray(_img(n, seed=4))
    with radon.config(method="pallas", stream_rows=9):
        op = radon.DPRT(f.shape, f.dtype)
    assert op.plan.stream_rows == 9
    assert op.plan.method == "pallas"
    assert (np.asarray(op(f)) == dprt_oracle_np(np.asarray(f))).all()
    assert (np.asarray(op.inverse(op(f))) == np.asarray(f)).all()


# ---------------------------------------------------------------------------
# knob conflict rejection
# ---------------------------------------------------------------------------
def test_block_rows_stream_rows_conflict_rejected():
    with pytest.raises(ValueError, match="mutually exclusive"):
        resolve_blocks(61, 4, block_rows=8, stream_rows=7)
    with pytest.raises(ValueError, match="mutually exclusive"):
        get_plan((61, 61), jnp.int32, "pallas", block_rows=8, stream_rows=7)
    # conflict fires for every backend, not just block-taking ones
    with pytest.raises(ValueError, match="mutually exclusive"):
        get_plan((61, 61), jnp.int32, "horner", block_rows=8, stream_rows=7)
    with pytest.raises(ValueError, match="stream_rows"):
        get_plan((61, 61), jnp.int32, "pallas", stream_rows=0)


# ---------------------------------------------------------------------------
# single-launch structure: one pallas_call, no scan-of-launches, and the
# jaxpr does not grow with the strip count (one live buffer pair)
# ---------------------------------------------------------------------------
def _walk_eqns(jaxpr, inside_loop, pallas_found, counter):
    for eqn in jaxpr.eqns:
        counter[0] += 1
        name = eqn.primitive.name
        if name == "pallas_call":
            pallas_found.append(inside_loop)
            continue        # kernel body size is checked via the total
        nested_loop = inside_loop or name in ("scan", "while")
        for val in eqn.params.values():
            for sub in _subjaxprs(val):
                _walk_eqns(sub, nested_loop, pallas_found, counter)


def _subjaxprs(val):
    if hasattr(val, "eqns"):
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _subjaxprs(v)


def _jaxpr_stats(fn, x):
    jaxpr = jax.make_jaxpr(fn)(x)
    pallas_found, counter = [], [0]
    _walk_eqns(jaxpr.jaxpr, False, pallas_found, counter)
    return pallas_found, counter[0]


@pytest.mark.parametrize("stream_impl", ["grid", "dma"])
def test_streamed_is_one_launch_constant_size(stream_impl):
    n = 61
    fb = jnp.asarray(_img(n, b=1, seed=6))

    def fwd(sr):
        return lambda x: dprt_pallas_raw(x, stream_rows=sr, m_block=8,
                                         stream_impl=stream_impl)

    found, size_a = _jaxpr_stats(fwd(4), fb)
    assert len(found) == 1, "streamed forward must be ONE pallas_call"
    assert not found[0], "pallas_call must not sit under a scan/while"
    # doubling the strip count must not grow the program: only one strip
    # buffer (pair) is ever live, the rest is grid/loop bounds
    found_b, size_b = _jaxpr_stats(fwd(8), fb)
    assert len(found_b) == 1 and not found_b[0]
    assert size_a == size_b, (size_a, size_b)


def test_streamed_plan_forward_is_one_launch():
    """Through the plan layer too: no scan-of-launches on the
    stream-capable backend (the scan survives only as the
    block_rows/non-capable fallback)."""
    n = 61
    f = jnp.asarray(_img(n, seed=7))
    p = get_plan(f.shape, f.dtype, "pallas", stream_rows=7)
    found, _ = _jaxpr_stats(p.forward, f)
    assert len(found) == 1 and not found[0]
    # while the block_rows staged fallback leaves the fused kernel
    # entirely (a scanned Horner datapath: zero pallas_calls)
    pb = get_plan(f.shape, f.dtype, "pallas", block_rows=16)
    found_b, _ = _jaxpr_stats(pb.forward, f)
    assert len(found_b) == 0, "block_rows fallback must not be fused"


# ---------------------------------------------------------------------------
# giant-N and the direction-sharded collectives (forced-host subprocesses)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_giant_n_2053_streamed_roundtrip():
    """N=2053 forward + inverse, bit-exact for integer images, through
    the streamed kernel as ONE pallas_call (the acceptance geometry)."""
    n = 2053
    rng = np.random.default_rng(11)
    f = jnp.asarray(rng.integers(0, 256, (n, n), dtype=np.int32))
    p = get_plan(f.shape, f.dtype, "pallas", stream_rows=256)
    found, _ = _jaxpr_stats(p.forward, f)
    assert len(found) == 1 and not found[0]
    r = p.forward(f)
    cols = np.arange(n)
    fnp = np.asarray(f, dtype=np.int64)
    for m in (0, 1, n - 1):      # oracle spot-check: full O(N^3) is slow
        want = np.zeros(n, dtype=np.int64)
        for i in range(n):
            want += fnp[i, (cols + m * i) % n]
        assert (np.asarray(r[m]) == want).all(), m
    assert (np.asarray(r[n]) == fnp.sum(axis=1)).all()
    assert (np.asarray(p.inverse(r)) == np.asarray(f)).all()


@pytest.mark.slow
def test_sharded_direction_layout_and_ring(subproc):
    """8-device direction-sharded forward/inverse (the new default) ==
    oracle; the explicit ppermute ring == psum_scatter; streamed
    per-shard kernels compose with both."""
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.distributed import dprt_sharded_pallas, idprt_sharded_pallas
from repro.core.dprt import dprt_oracle_np
rng = np.random.default_rng(13)
n = 61
f = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)
ref = dprt_oracle_np(np.asarray(f))
mesh = jax.make_mesh((8,), ("model",))
# the direction-sharded default round-trips exactly
r = dprt_sharded_pallas(f, mesh)
assert (np.asarray(r) == ref).all()
assert (np.asarray(idprt_sharded_pallas(r, mesh)) == np.asarray(f)).all()
# ring == psum_scatter == psum, forward and inverse
for reduce in ("psum", "psum_scatter", "ring"):
    r = dprt_sharded_pallas(f, mesh, reduce=reduce)
    assert (np.asarray(r) == ref).all(), reduce
    back = idprt_sharded_pallas(r, mesh, reduce=reduce)
    assert (np.asarray(back) == np.asarray(f)).all(), reduce
# streamed per-shard kernel under the sharded layouts
for reduce in ("psum_scatter", "ring"):
    r = dprt_sharded_pallas(f, mesh, reduce=reduce, stream_rows=3)
    assert (np.asarray(r) == ref).all(), ("stream", reduce)
    back = idprt_sharded_pallas(r, mesh, reduce=reduce, stream_rows=3)
    assert (np.asarray(back) == np.asarray(f)).all(), ("stream-inv", reduce)
# batched 2-D mesh with a non-dividing batch
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
fb = jnp.asarray(rng.integers(0, 256, (5, n, n)), jnp.int32)
rb = dprt_sharded_pallas(fb, mesh2)
for b in range(5):
    assert (np.asarray(rb[b]) == dprt_oracle_np(np.asarray(fb[b]))).all()
bb = idprt_sharded_pallas(rb, mesh2)
assert (np.asarray(bb) == np.asarray(fb)).all()
print("OK")
""")


@pytest.mark.slow
def test_sharded_plan_stream_rows(subproc):
    """stream_rows reaches the sharded_pallas backend through the plan
    layer (mesh auto-routing) and the pipeline stays exact."""
    subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core.plan import get_plan, select_backend
from repro.core.dprt import dprt_oracle_np
from repro.core.distributed import projection_pipeline_sharded
mesh = jax.make_mesh((8,), ("model",))
assert select_backend(61, jnp.int32, mesh=mesh) == "sharded_pallas"
rng = np.random.default_rng(17)
f = jnp.asarray(rng.integers(0, 256, (61, 61)), jnp.int32)
p = get_plan(f.shape, f.dtype, "auto", mesh=mesh, stream_rows=3)
assert p.method == "sharded_pallas" and p.stream_rows == 3
r = p.forward(f)
assert (np.asarray(r) == dprt_oracle_np(np.asarray(f))).all()
assert (np.asarray(p.inverse(r)) == np.asarray(f)).all()
# twice-scattered pipeline (psum_scatter fwd collective + image-row
# scatter on the close) reconstructs exactly
out = projection_pipeline_sharded(f, mesh, op="none")
assert (np.asarray(out) == np.asarray(f)).all()
print("OK")
""")
