"""Fault-tolerant multi-geometry router: admission, deadlines,
retry/degrade, eviction, fault injection, and the jsonl front-end.

The contract under test (the chaos acceptance criteria): the router
never deadlocks or drops a future; every response is bit-exact vs the
sequential per-operator oracle or a typed rejection; and healthz
accounts for every degradation.
"""
import asyncio
import io
import json
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro import radon
from repro.checkpoint.store import list_blobs
from repro.kernels.tuning import (ROUTER_TRIM_N, router_warm_sizes,
                                  warm_batch_sizes)
from repro.launch import faults
from repro.launch.errors import (DeadlineExceeded, QueueFull, ServiceError,
                                 ServiceShutdown)
from repro.launch.router import ServiceRouter, serve_jsonl
from repro.launch.service import DPRTService

N1, N2 = 13, 17


def _imgs(n, count, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 100, (n, n)).astype(np.int32)
            for _ in range(count)]


def _oracle(n, img):
    return np.asarray(radon.DPRT((1, n, n), jnp.int32)(
        jnp.asarray(np.asarray(img)[None])))[0]


# ---------------------------------------------------------------------------
# routing and exactness
# ---------------------------------------------------------------------------
def test_mixed_geometry_routing_bit_exact():
    a, b = _imgs(N1, 4, 1), _imgs(N2, 4, 2)
    want = [_oracle(N1, x) for x in a] + [_oracle(N2, x) for x in b]
    router = ServiceRouter(max_batch=2, max_wait_us=500.0)
    router.prefill([{"n": N1}, {"n": N2}])
    reqs = [({"n": N1}, x) for x in a] + [({"n": N2}, x) for x in b]
    outs = router.run_requests(reqs)
    for out, ref in zip(outs, want):
        np.testing.assert_array_equal(np.asarray(out), ref)
    assert router.verdict() == "OK"
    assert router.pending() == 0
    assert router.delivered == len(reqs) == router.admitted
    assert len(router.stats()["routes"]) == 2


def test_specs_normalize_to_shared_route():
    router = ServiceRouter(max_batch=2)
    k1 = ServiceRouter.route_key({"n": N1})
    k2 = ServiceRouter.route_key({"shape": (N1, N1), "dtype": "int32",
                                  "datapath": "forward"})
    assert k1 == k2 == ((N1, N1), "int32", "forward")
    assert ServiceRouter.route_key({"n": N1, "datapath": "roundtrip"}) != k1
    with pytest.raises(ValueError):
        ServiceRouter._normalize({"dtype": "int32"})   # no geometry


def test_router_warm_sizes_trim():
    assert router_warm_sizes(N1, 16) == warm_batch_sizes(16)
    assert router_warm_sizes(ROUTER_TRIM_N, 16) == (1, 16)
    assert router_warm_sizes(ROUTER_TRIM_N + 2, 8) == (1, 8)
    assert router_warm_sizes(ROUTER_TRIM_N, 1) == (1,)


# ---------------------------------------------------------------------------
# bounded admission: typed rejections
# ---------------------------------------------------------------------------
def test_queue_cap_rejects_typed():
    imgs = _imgs(N1, 12, 3)
    router = ServiceRouter(max_batch=2, queue_cap=4, max_wait_us=200.0)
    router.prefill([{"n": N1}])
    # burst admission: every submit lands before the batcher runs, so
    # the 5th..12th hit the cap deterministically
    outs = router.run_requests([({"n": N1}, x) for x in imgs])
    full = [o for o in outs if isinstance(o, QueueFull)]
    served = [o for o in outs if not isinstance(o, Exception)]
    assert len(full) == len(imgs) - 4 and len(served) == 4
    assert router.rejected_admission["queue_full"] == len(full)
    assert router.verdict() == "WARN"      # rejections are a degradation
    for i, o in enumerate(outs):
        if not isinstance(o, Exception):
            np.testing.assert_array_equal(np.asarray(o),
                                          _oracle(N1, imgs[i]))


def test_global_inflight_budget():
    imgs = _imgs(N1, 6, 4)
    router = ServiceRouter(max_batch=2, max_inflight=3, queue_cap=64)
    router.prefill([{"n": N1}])
    outs = router.run_requests([({"n": N1}, x) for x in imgs])
    assert sum(isinstance(o, QueueFull) for o in outs) == 3
    assert router.admitted == 3


def test_deadline_rejections_typed():
    imgs = _imgs(N1, 3, 5)
    router = ServiceRouter(max_batch=2, max_wait_us=200.0)
    router.prefill([{"n": N1}])
    outs = router.run_requests([
        ({"n": N1}, imgs[0], {}),
        ({"n": N1}, imgs[1], {"deadline_s": -1.0}),  # dead at admission
        ({"n": N1}, imgs[2], {"deadline_s": 1e-9}),  # expires in queue
    ])
    np.testing.assert_array_equal(np.asarray(outs[0]),
                                  _oracle(N1, imgs[0]))
    assert isinstance(outs[1], DeadlineExceeded)
    assert isinstance(outs[2], DeadlineExceeded)
    s = router.stats()
    assert s["rejected"]["deadline_exceeded"] == 2
    assert router.verdict() == "WARN"


def test_deadline_flushes_batch_early():
    # admission window is huge (30 s); the deadline must flush the
    # group long before it.  On a loaded host the loop wakeup can slip
    # past the flush margin, in which case the router's contract is a
    # typed rejection at dispatch -- either way the deadline, not
    # max_wait_us, bounded the wait.
    img = _imgs(N1, 1, 6)[0]
    router = ServiceRouter(max_batch=16, max_wait_us=30_000_000.0)
    router.prefill([{"n": N1}])
    import time as _t
    t0 = _t.perf_counter()
    outs = router.run_requests([({"n": N1}, img, {"deadline_s": 0.25})])
    wall = _t.perf_counter() - t0
    assert wall < 10.0, f"deadline did not flush the batch early ({wall=})"
    if isinstance(outs[0], Exception):
        assert isinstance(outs[0], DeadlineExceeded)
        assert router.rejected_deadline == 1
    else:
        np.testing.assert_array_equal(np.asarray(outs[0]),
                                      _oracle(N1, img))


def test_priority_orders_the_queue():
    imgs = _imgs(N1, 4, 7)
    router = ServiceRouter(max_batch=2)
    router.prefill([{"n": N1}])

    async def run():
        await router.start()
        route = router._ensure_route({"n": N1})
        futs = [router.submit_nowait({"n": N1}, img, priority=p)
                for img, p in zip(imgs, (0, 5, 1, 5))]
        # peek: dequeue order is priority-major, FIFO within a priority
        items = []
        while not route.queue.empty():
            items.append(route.queue.get_nowait())
        assert [it[2].priority for it in items] == [5, 5, 1, 0]
        for it in items:          # put back and let them serve
            route.queue.put_nowait(it)
        outs = await asyncio.gather(*futs)
        await router.shutdown()
        return outs

    outs = asyncio.run(run())
    for img, out in zip(imgs, outs):
        np.testing.assert_array_equal(np.asarray(out), _oracle(N1, img))


# ---------------------------------------------------------------------------
# retry / degrade
# ---------------------------------------------------------------------------
def test_injected_fault_retries_then_succeeds():
    imgs = _imgs(N1, 2, 8)
    router = ServiceRouter(max_batch=2, max_retries=2,
                           retry_backoff_s=1e-3)
    router.prefill([{"n": N1}])
    with faults.FaultInjector(seed=0, error_count=1,
                              sites=("dispatch",)) as inj:
        outs = router.run_requests([({"n": N1}, x) for x in imgs])
    for img, out in zip(imgs, outs):
        np.testing.assert_array_equal(np.asarray(out), _oracle(N1, img))
    assert inj.injected_errors == 1
    assert router.retries == 1 and router.fallbacks == 0
    assert router.verdict() == "WARN"


def test_exhausted_retries_degrade_to_fallback_bit_exact():
    imgs = _imgs(N1, 2, 9)
    router = ServiceRouter(max_batch=2, max_retries=1,
                           retry_backoff_s=1e-3)
    router.prefill([{"n": N1}])
    # every primary attempt of the single batch fails: 1 + retries
    with faults.FaultInjector(seed=0, error_count=2, sites=("dispatch",)):
        outs = router.run_requests([({"n": N1}, x) for x in imgs])
    for img, out in zip(imgs, outs):
        np.testing.assert_array_equal(np.asarray(out), _oracle(N1, img))
    assert router.fallbacks == 1 and router.retries == 1
    assert router.verdict() == "WARN"
    assert router.stats()["fallback_uses"] == 1


def test_fallback_failure_is_raw_and_verdict_fail():
    img = _imgs(N1, 1, 10)[0]
    router = ServiceRouter(max_batch=1, max_retries=0,
                           retry_backoff_s=1e-3)
    router.prefill([{"n": N1}])
    with faults.FaultInjector(seed=0, error_count=10,
                              sites=("dispatch", "fallback")):
        outs = router.run_requests([({"n": N1}, img)])
    assert isinstance(outs[0], faults.InjectedFault)
    assert not isinstance(outs[0], ServiceError)
    assert router.failed == 1 and router.pending() == 0
    assert router.verdict() == "FAIL"


def test_fault_injector_deterministic_and_scoped():
    with faults.FaultInjector(seed=3, error_count=2, sites=("dispatch",),
                              match="17x17") as inj:
        faults.perturb("dispatch", key="13x13/int32/forward")  # no match
        faults.perturb("fallback", key="17x17/int32/forward")  # wrong site
        with pytest.raises(faults.InjectedFault):
            faults.perturb("dispatch", key="17x17/int32/forward")
        with pytest.raises(faults.InjectedFault):
            faults.perturb("dispatch", key="17x17/int32/forward")
        faults.perturb("dispatch", key="17x17/int32/forward")  # budget spent
    assert inj.injected_errors == 2
    faults.perturb("dispatch", key="17x17/int32/forward")  # exited: no-op
    assert faults.active_injector() is None


def test_service_warm_sizes_override():
    svc = DPRTService((N1, N1), jnp.int32, max_batch=4,
                      warm_sizes=(4, 2, 2))
    assert svc.sizes == (2, 4)          # sorted, deduped
    svc.warmup()
    img = _imgs(N1, 1, 24)[0]
    out = svc.execute(img[None])        # b=1 pads up to warm size 2
    np.testing.assert_array_equal(out[0], _oracle(N1, img))
    assert svc.stats()["padded_slots"] == 1


def test_conv_fallback_matches_fused_pipeline():
    kernel = np.ones((3, 3), np.int32)
    svc = DPRTService((N1, N1), jnp.int32, max_batch=2, datapath="conv",
                      conv_kernel=jnp.asarray(kernel), fallback=True)
    svc.warmup()
    imgs = np.stack(_imgs(N1, 2, 11))
    primary = svc.execute(imgs.copy())
    degraded = svc.execute_fallback(imgs.copy())
    np.testing.assert_array_equal(primary, degraded)
    assert svc.stats()["fallback_uses"] == 1


# ---------------------------------------------------------------------------
# bounded residency: LRU eviction in lockstep with the plan cache
# ---------------------------------------------------------------------------
def test_lru_eviction_discards_only_unshared_plans():
    router = ServiceRouter(max_batch=2, max_services=2)
    router.prefill([{"n": N1}, {"n": N1, "datapath": "roundtrip"}])
    aot_before = radon.aot_cache_info()["currsize"]
    evict_before = radon.plan_cache_info().evictions
    # a third route forces the LRU ({"n": N1} forward) out
    router.prefill([{"n": N2}])
    assert router.evictions == 1
    assert len(router.stats()["routes"]) == 2
    labels = set(router.stats()["routes"])
    assert f"{N1}x{N1}/int32/forward" not in labels
    # the forward route's plans are SHARED with the surviving roundtrip
    # route (same geometry) -- nothing may be discarded for them, so
    # the plan cache saw no eviction and the roundtrip executables
    # survived
    assert radon.plan_cache_info().evictions == evict_before
    assert radon.aot_cache_info()["currsize"] == aot_before
    # retiring the remaining routes too (max_services drops to 1, so
    # BOTH live routes go) drops the now-unshared plans and their
    # executables in lockstep
    router.max_services = 1
    router.prefill([{"n": N2, "datapath": "roundtrip"}])
    assert router.evictions == 3
    assert radon.plan_cache_info().evictions > evict_before
    assert radon.aot_cache_info()["currsize"] < aot_before
    # the surviving route still serves, bit-exact
    img = _imgs(N2, 1, 12)[0]
    outs = router.run_requests([({"n": N2, "datapath": "roundtrip"}, img)])
    np.testing.assert_array_equal(np.asarray(outs[0]), img)


def test_eviction_refuses_when_every_route_busy():
    router = ServiceRouter(max_batch=2, max_services=1)
    router.prefill([{"n": N1}])

    async def run():
        await router.start()
        # hold the single route busy with a queued request, then ask
        # for a second route: bounded residency must refuse, typed
        fut = router.submit_nowait({"n": N1}, _imgs(N1, 1, 13)[0])
        with pytest.raises(QueueFull):
            router._ensure_route({"n": N2})
        out = await fut
        await router.shutdown()
        return out

    out = asyncio.run(run())
    np.testing.assert_array_equal(np.asarray(out),
                                  _oracle(N1, _imgs(N1, 1, 13)[0]))


# ---------------------------------------------------------------------------
# warmup concurrency and shared blob stores
# ---------------------------------------------------------------------------
def test_concurrent_submit_during_warmup():
    # no prefill: the route warms on the loop while traffic queues
    imgs = _imgs(N1, 6, 14)
    router = ServiceRouter(max_batch=2, max_wait_us=500.0)
    outs = router.run_requests([({"n": N1}, x) for x in imgs])
    for img, out in zip(imgs, outs):
        np.testing.assert_array_equal(np.asarray(out), _oracle(N1, img))
    assert router.verdict() == "OK"


def test_two_routers_share_aot_dir_without_storms(tmp_path):
    radon.aot_cache_clear()
    routers = [ServiceRouter(max_batch=2, aot_dir=str(tmp_path))
               for _ in range(2)]
    errs = []

    def boot(r):
        try:
            r.prefill([{"n": N1}, {"n": N2}])
        except Exception as e:      # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=boot, args=(r,)) for r in routers]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    stats = [r.stats() for r in routers]
    persistent = []
    for r in routers:
        for route in r._routes.values():
            persistent.append(route.service.persistent)
    total_misses = sum(p.misses for p in persistent)
    total_errors = sum(p.errors for p in persistent)
    # 2 routes x len(warm sizes) executables compiled ONCE across both
    # routers (the per-token compile locks coalesce the storm); every
    # blob intact on disk
    executables = sum(len(route.service._exes)
                      for route in routers[0]._routes.values())
    per_route_exes = {route.key: sum(
        len(stages) for stages in route.service._ops.values())
        for route in routers[0]._routes.values()}
    want_unique = sum(per_route_exes.values())
    assert total_misses == want_unique
    assert total_errors == 0
    assert len(list_blobs(str(tmp_path))) == want_unique
    # both routers serve, exact
    img = _imgs(N1, 1, 15)[0]
    for r in routers:
        out = r.run_requests([({"n": N1}, img)])[0]
        np.testing.assert_array_equal(np.asarray(out), _oracle(N1, img))


# ---------------------------------------------------------------------------
# shutdown semantics: a future ALWAYS resolves
# ---------------------------------------------------------------------------
def test_router_shutdown_rejects_queued_typed():
    router = ServiceRouter(max_batch=2)   # cold route: requests queue
    imgs = _imgs(N1, 3, 16)

    async def run():
        await router.start()
        futs = [router.submit_nowait({"n": N1}, x) for x in imgs]
        await router.shutdown()
        return await asyncio.gather(*futs, return_exceptions=True)

    outs = asyncio.wait_for(run(), timeout=120)
    outs = asyncio.run(outs)
    assert all(isinstance(o, ServiceShutdown) for o in outs)
    assert router.rejected_shutdown == len(imgs)
    assert router.pending() == 0
    assert router.verdict() == "WARN"


def test_service_shutdown_rejects_queued_regression():
    # the PR-8 hang: shutdown(drain=False) used to cancel the batcher
    # and leave queued futures pending forever
    svc = DPRTService((N1, N1), jnp.int32, max_batch=2,
                      max_wait_us=5_000_000.0)   # batcher waits ~forever
    svc.warmup()
    imgs = _imgs(N1, 3, 17)

    async def run():
        await svc.start()
        futs = [svc.submit_nowait(x) for x in imgs]
        # the batcher holds the first request in its forming batch; the
        # rest sit queued.  A no-drain shutdown must reject the queued
        # ones typed -- and resolve EVERY future within the timeout.
        await asyncio.sleep(0.05)
        await svc.shutdown(drain=False)
        return await asyncio.wait_for(
            asyncio.gather(*futs, return_exceptions=True), timeout=60)

    outs = asyncio.run(run())
    rejected = [o for o in outs if isinstance(o, ServiceShutdown)]
    assert rejected, "no queued request was typed-rejected"
    for o in outs:        # every future resolved: result or typed error
        assert isinstance(o, (np.ndarray, ServiceShutdown))
    assert svc.stats()["rejected_shutdown"] == len(rejected)


def test_service_batcher_death_fails_fast_not_forever():
    svc = DPRTService((N1, N1), jnp.int32, max_batch=2)
    svc.warmup()

    async def doomed(self):
        raise RuntimeError("batcher bug")

    svc._run = doomed.__get__(svc)

    async def run():
        await svc.start()
        f1 = svc.submit_nowait(_imgs(N1, 1, 18)[0])
        f2 = svc.submit_nowait(_imgs(N1, 1, 19)[0])
        outs = await asyncio.wait_for(
            asyncio.gather(f1, f2, return_exceptions=True), timeout=60)
        # a dead batcher also refuses NEW work, typed
        with pytest.raises(ServiceShutdown):
            svc.submit_nowait(_imgs(N1, 1, 20)[0])
        return outs

    outs = asyncio.run(run())
    # the done-callback flushed the queue: every future rejected typed,
    # carrying the batcher's own error as the cause
    assert all(isinstance(o, ServiceShutdown) for o in outs)
    assert all(isinstance(o.__cause__, RuntimeError) for o in outs)
    assert svc.stats()["rejected_shutdown"] == 2


def test_service_batcher_exception_rejects_forming_batch():
    # the in-hand batch (already off the queue) must be rejected typed
    # when the product batcher loop itself raises
    svc = DPRTService((N1, N1), jnp.int32, max_batch=4,
                      max_wait_us=5_000_000.0)
    svc.warmup()

    async def run():
        await svc.start()
        f1 = svc.submit_nowait(_imgs(N1, 1, 22)[0])
        await asyncio.sleep(0.05)     # batcher takes f1, awaits more
        # poison the collect loop, one shot: the next straggler append
        # explodes (later drains see the real queue again)
        real, armed = svc._queue.get_nowait, [True]

        def poisoned():
            if armed:
                armed.clear()
                raise RuntimeError("collect bug")
            return real()

        svc._queue.get_nowait = poisoned
        f2 = svc.submit_nowait(_imgs(N1, 1, 23)[0])
        return await asyncio.wait_for(
            asyncio.gather(f1, f2, return_exceptions=True), timeout=60)

    outs = asyncio.run(run())
    assert all(isinstance(o, ServiceShutdown) for o in outs)


# ---------------------------------------------------------------------------
# the jsonl transport front-end
# ---------------------------------------------------------------------------
def test_serve_jsonl_roundtrip_and_typed_errors():
    img = _imgs(N1, 1, 21)[0]
    want = _oracle(N1, img)
    lines = [
        {"op": "submit", "id": "a", "n": N1, "data": img.tolist()},
        {"op": "submit", "id": "b", "n": N1,
         "data": [[1, 2], [3, 4]]},                   # bad shape
        {"op": "submit", "id": "c", "n": N1, "data": img.tolist(),
         "deadline_ms": -5.0},                        # typed rejection
        {"op": "healthz", "id": "h"},
        {"op": "nope", "id": "x"},
        {"op": "shutdown", "id": "z"},
    ]
    infile = io.StringIO("\n".join(json.dumps(m) for m in lines)
                         + "\nnot json\n")
    outfile = io.StringIO()
    router = ServiceRouter(max_batch=2, max_wait_us=200.0)
    router.prefill([{"n": N1}])
    serve_jsonl(router, infile, outfile)
    replies = {m.get("id"): m for m in
               (json.loads(s) for s in
                outfile.getvalue().strip().splitlines())}
    np.testing.assert_array_equal(np.asarray(replies["a"]["data"],
                                             np.int64), want)
    assert replies["a"]["ok"] is True
    assert replies["b"]["ok"] is False
    assert replies["b"]["error"] == "bad_request"
    assert replies["c"]["error"] == DeadlineExceeded.code
    assert replies["h"]["verdict"] in ("OK", "WARN")
    assert "[healthz]" in replies["h"]["healthz"]
    assert replies["x"]["error"] == "bad_request"
    assert replies["z"]["shutdown"] is True
    assert router.pending() == 0


# ---------------------------------------------------------------------------
# chaos invariants (the in-process version of serve --chaos)
# ---------------------------------------------------------------------------
def test_chaos_burst_never_wrong_never_hangs(tmp_path):
    radon.aot_cache_clear()
    seeder = ServiceRouter(max_batch=2, aot_dir=str(tmp_path))
    seeder.prefill([{"n": N1}, {"n": N2}])
    radon.aot_cache_clear()
    assert faults.corrupt_blobs(str(tmp_path), seed=0) > 0

    router = ServiceRouter(max_batch=2, max_wait_us=300.0, queue_cap=6,
                           max_retries=1, retry_backoff_s=1e-3,
                           aot_dir=str(tmp_path))
    router.prefill([{"n": N1}, {"n": N2}])
    assert router.degraded_compiles() > 0

    rng = np.random.default_rng(1)
    traffic, oracles = [], []
    for i in range(20):
        n = (N1, N2)[i % 2]
        img = rng.integers(0, 50, (n, n)).astype(np.int32)
        kw = {"deadline_s": 1e-9} if i % 9 == 4 else {}
        traffic.append(({"n": n}, img, kw))
        oracles.append(None if kw else _oracle(n, img))
    with faults.FaultInjector(seed=2, error_count=2, error_rate=0.1,
                              delay_s=0.001, delay_rate=0.25,
                              sites=("dispatch",)):
        outs = router.run_requests(traffic)

    for out, want in zip(outs, oracles):
        if isinstance(out, BaseException):
            assert isinstance(out, ServiceError), f"untyped: {out!r}"
        elif want is not None:
            np.testing.assert_array_equal(np.asarray(out), want)
    assert router.pending() == 0
    assert router.failed == 0
    s = router.stats()
    accounted = (s["delivered"] + s["failed"] + s["pending"]
                 + router.rejected_deadline + router.rejected_shutdown)
    assert s["admitted"] == accounted
    assert router.verdict() == "WARN"
    assert "degraded" in router.healthz()


# ---------------------------------------------------------------------------
# backpressure hints and the framed transport (PR 10)
# ---------------------------------------------------------------------------
def test_queue_full_carries_retry_after_hint():
    imgs = _imgs(N1, 12, 23)
    router = ServiceRouter(max_batch=2, queue_cap=4, max_wait_us=200.0)
    router.prefill([{"n": N1}])
    outs = router.run_requests([({"n": N1}, x) for x in imgs])
    full = [o for o in outs if isinstance(o, QueueFull)]
    assert full
    for e in full:       # every rejection tells the client when to retry
        assert e.retry_after_s is not None and e.retry_after_s > 0
    # the hint scales with queue depth: a full queue quotes at least
    # one batch's worth of service time
    assert max(e.retry_after_s for e in full) >= min(
        e.retry_after_s for e in full)


def test_serve_jsonl_framed_mode_and_healthz_payload():
    from repro.launch.pool import read_frame, write_frame

    img = _imgs(N1, 1, 24)[0]
    want = _oracle(N1, img)
    infile = io.StringIO()
    for m in [{"op": "submit", "id": "a", "n": N1, "data": img.tolist()},
              {"op": "submit", "id": "c", "n": N1, "data": img.tolist(),
               "deadline_ms": -5.0},
              {"op": "healthz", "id": "h"},
              {"op": "shutdown", "id": "z"}]:
        write_frame(infile, m)
    infile.seek(0)
    outfile = io.StringIO()
    router = ServiceRouter(max_batch=2, max_wait_us=200.0)
    router.prefill([{"n": N1}])
    serve_jsonl(router, infile, outfile, framed=True)
    outfile.seek(0)
    replies = {}
    while True:
        msg = read_frame(outfile)
        if msg is None:
            break
        replies[msg.get("id")] = msg
    np.testing.assert_array_equal(np.asarray(replies["a"]["data"],
                                             np.int64), want)
    assert replies["c"]["error"] == DeadlineExceeded.code
    h = replies["h"]
    # the supervisor-facing healthz: a machine-readable stats block.
    # It answers inline, while the submits may still be in flight, so
    # only admission-time counters are deterministic here.
    assert h["pid"] > 0
    assert h["stats"]["admitted"] >= 1
    assert h["stats"]["failed"] == 0
    assert h["retraces_since_start"] == 0
    assert set(h["persistent"]) >= {"hits", "misses", "lock_steals",
                                    "lock_degraded"}
    assert h["faults_env"] is None
    assert replies["z"]["shutdown"] is True
