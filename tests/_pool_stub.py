"""A jax-free framed-jsonl worker stub for WorkerPool tests.

The supervisor never interprets request payloads, so an echo worker is
enough to exercise every pool behavior (dispatch, replay, probes,
crash, drain) without paying a ~10s jax import per subprocess on the
single-core CI host.  The framing here is implemented independently of
:mod:`repro.launch.pool` on purpose: the protocol has two ends, and a
stub that imported the library would only ever test it against itself.

Semantics:
* ``submit`` replies with every payload element doubled (so tests can
  check the answer actually went through the worker);
* ``healthz`` replies with a healthz-shaped frame;
* ``shutdown`` acks and exits 0.

Chaos knobs (env vars):
* ``STUB_DELAY_S``      -- sleep before answering each submit (keeps
                           requests in flight for kill/replay tests);
* ``STUB_EXIT_AFTER``   -- hard-exit (simulated crash) after N submit
                           replies;
* ``STUB_MUTE_AFTER``   -- after N replies of any kind, keep reading
                           but stop answering (a hung worker, for the
                           probe suspect-kill path).

A submit whose message carries ``stub_error`` replies that typed error
code instead of data (plus ``retry_after_s`` if present) -- the
passthrough seam for typed-rejection tests.
"""
import json
import os
import sys
import time


def write_frame(fp, obj):
    payload = json.dumps(obj, separators=(",", ":"))
    fp.write(f"{len(payload)}\n{payload}\n")
    fp.flush()


def read_frame(fp):
    while True:
        header = fp.readline()
        if not header:
            return None
        header = header.strip()
        if not header:
            continue
        try:
            n = int(header)
        except ValueError:
            continue
        payload = fp.read(n)
        if payload is None or len(payload) < n:
            return None
        fp.readline()
        return json.loads(payload)


def main():
    delay_s = float(os.environ.get("STUB_DELAY_S", "0"))
    exit_after = int(os.environ.get("STUB_EXIT_AFTER", "0"))
    mute_after = int(os.environ.get("STUB_MUTE_AFTER", "0"))
    replies = submits = 0
    muted = False

    def reply(obj):
        nonlocal replies, muted
        if mute_after and replies >= mute_after:
            muted = True
            return
        write_frame(sys.stdout, obj)
        replies += 1

    while True:
        msg = read_frame(sys.stdin)
        if msg is None:
            return 0
        rid = msg.get("id")
        op = msg.get("op", "submit")
        if op == "healthz":
            reply({"id": rid, "ok": True, "verdict": "OK",
                   "pid": os.getpid(),
                   "stats": {"admitted": submits, "delivered": submits,
                             "failed": 0, "rejected": 0, "pending": 0},
                   "retraces_since_start": 0,
                   "persistent": {"hits": 0, "misses": 0, "errors": 0,
                                  "degraded_compiles": 0,
                                  "lock_steals": 0, "lock_degraded": 0},
                   "faults_env": os.environ.get("REPRO_FAULTS") or None})
        elif op == "shutdown":
            reply({"id": rid, "ok": True, "shutdown": True})
            return 0
        elif op == "submit":
            if delay_s:
                time.sleep(delay_s)
            if "stub_error" in msg:
                err = {"id": rid, "ok": False,
                       "error": msg["stub_error"],
                       "msg": "stub-injected typed error"}
                if "retry_after_s" in msg:
                    err["retry_after_s"] = msg["retry_after_s"]
                reply(err)
            else:
                data = msg.get("data", [])
                doubled = [[2 * x for x in row] for row in data]
                reply({"id": rid, "ok": True, "data": doubled})
                submits += 1
                if exit_after and submits >= exit_after:
                    os._exit(17)       # simulated crash: no drain, no ack
        else:
            reply({"id": rid, "ok": False, "error": "bad_request",
                   "msg": f"unknown op {op!r}"})


if __name__ == "__main__":
    sys.exit(main())
