"""The async dynamic-batching serve tier (``repro.launch.service``).

Covers: the latency-statistics helpers shared across serving surfaces,
warm-batch-size resolution, admission/padding/occupancy accounting,
coalesced-vs-direct bit-exactness on every datapath, the persistent AOT
executable cache (warm restart restores with ZERO traces; corrupt and
stale blobs degrade to recompiles), the Conv2D AOT surface, and the
``/healthz`` reports (service + module level).
"""
import asyncio

import numpy as np
import jax.numpy as jnp
import pytest

from repro import radon
from repro.checkpoint.store import save_blob
from repro.kernels.tuning import nearest_warm_batch, warm_batch_sizes
from repro.launch import serve
from repro.launch.service import (DPRTService, format_latency,
                                  latency_summary, percentile)
from repro.radon import healthz

N = 13


def _imgs(count, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 100, (N, N), dtype=np.int32)
            for _ in range(count)]


# ---------------------------------------------------------------------------
# latency statistics helpers (shared: service, serve --mode radon, benches)
# ---------------------------------------------------------------------------
def test_percentile_math():
    xs = [1.0, 2.0, 3.0, 4.0, 5.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 50) == 3.0
    assert percentile(xs, 100) == 5.0
    assert percentile([0.0, 10.0], 75) == pytest.approx(7.5)
    with pytest.raises(ValueError):
        percentile([], 50)
    with pytest.raises(ValueError):
        percentile(xs, 101)


def test_latency_summary_and_format():
    s = latency_summary([0.004, 0.001, 0.003, 0.002])  # order-insensitive
    assert s["n"] == 4
    assert s["p50_ms"] == pytest.approx(2.5)
    assert s["max_ms"] == pytest.approx(4.0)
    assert s["mean_ms"] == pytest.approx(2.5)
    line = format_latency(s, imgs_per_s=123.4)
    assert "p50=2.50" in line and "p99=" in line
    assert line.endswith("123.4 img/s")
    assert latency_summary([]) == {"n": 0}
    assert format_latency({"n": 0}) == "latency: no samples"


def test_warm_batch_size_resolution():
    assert warm_batch_sizes(16) == (1, 2, 4, 8, 16)
    assert warm_batch_sizes(5) == (1, 2, 4, 5)   # off-table limit kept warm
    assert warm_batch_sizes(1) == (1,)
    with pytest.raises(ValueError):
        warm_batch_sizes(0)
    assert nearest_warm_batch(3, (1, 2, 4)) == 4
    assert nearest_warm_batch(4, (1, 2, 4)) == 4
    with pytest.raises(ValueError):
        nearest_warm_batch(5, (1, 2, 4))


# ---------------------------------------------------------------------------
# admission contract
# ---------------------------------------------------------------------------
def test_constructor_validation():
    with pytest.raises(ValueError, match="geometry"):
        DPRTService((N,), jnp.int32)
    with pytest.raises(ValueError, match="datapath"):
        DPRTService((N, N), jnp.int32, datapath="sideways")
    with pytest.raises(ValueError, match="conv_kernel"):
        DPRTService((N, N), jnp.int32, datapath="conv")   # kernel missing
    with pytest.raises(ValueError, match="conv_kernel"):
        DPRTService((N, N), jnp.int32,
                    conv_kernel=jnp.ones((3, 3), jnp.int32))
    with pytest.raises(ValueError, match="max_wait_us"):
        DPRTService((N, N), jnp.int32, max_wait_us=-1.0)


def test_traffic_rejected_before_warmup_or_loop():
    svc = DPRTService((N, N), jnp.int32, max_batch=2)
    with pytest.raises(RuntimeError, match="warmup"):
        svc.run_sequential(_imgs(1))
    with pytest.raises(RuntimeError, match="warmup"):
        svc.submit_nowait(np.zeros((N, N), np.int32))
    svc.warmup()
    with pytest.raises(RuntimeError, match="start"):
        svc.submit_nowait(np.zeros((N, N), np.int32))     # no event loop


def test_request_shape_dtype_validation():
    svc = DPRTService((N, N), jnp.int32, max_batch=2, max_wait_us=100.0)
    svc.warmup()

    async def go():
        await svc.start()
        with pytest.raises(ValueError, match="shape"):
            svc.submit_nowait(np.zeros((N, N + 1), np.int32))
        with pytest.raises(ValueError, match="dtype"):
            svc.submit_nowait(np.zeros((N, N), np.float32))
        out = await svc.submit(np.zeros((N, N), np.int32))
        await svc.shutdown()
        return out

    out = asyncio.run(go())
    assert out.shape == (N + 1, N)        # (P+1, P) projections per request


# ---------------------------------------------------------------------------
# coalescing: correctness + padding/occupancy accounting
# ---------------------------------------------------------------------------
def test_coalesced_matches_direct_and_pads():
    imgs = _imgs(7)
    # ground truth from the plain operator, computed BEFORE warmup so
    # its traces don't count against the service's steady state
    op = radon.DPRT((N, N), jnp.int32)
    ref = [np.asarray(op(img)) for img in imgs]

    svc = DPRTService((N, N), jnp.int32, max_batch=8, max_wait_us=100.0)
    svc.warmup()
    got = svc.run_requests(imgs)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(g), r)

    s = svc.stats()
    assert s["requests"] == 7 and s["failures"] == 0
    assert s["batches"] == 1              # burst of 7 coalesces into one
    assert s["batch_size_counts"] == {7: 1}
    assert s["padded_slots"] == 1         # 7 padded up to warm size 8
    assert s["batch_occupancy"] == pytest.approx(7 / 8)
    assert s["steady_state_retraces"] == 0
    assert svc.healthy()


def test_batcher_splits_at_max_batch():
    imgs = _imgs(6)
    svc = DPRTService((N, N), jnp.int32, max_batch=4, max_wait_us=100.0)
    svc.warmup()
    ref, _ = svc.run_sequential(imgs)
    got = svc.run_requests(imgs)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
    s = svc.stats()
    assert s["requests"] == 6
    assert s["batch_size_counts"] == {4: 1, 2: 1}   # full batch + remainder
    assert s["padded_slots"] == 0                   # 2 is itself a warm size
    assert s["queue_depth_max"] >= 1


def test_spaced_arrivals_and_repeats():
    imgs = _imgs(4, seed=3)
    svc = DPRTService((N, N), jnp.int32, max_batch=4, max_wait_us=500.0)
    svc.warmup()
    ref, _ = svc.run_sequential(imgs)
    got = svc.run_requests(imgs, arrival_us=200.0, repeats=2)
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
    assert len(svc.last_pass_walls) == 2            # one wall per pass
    assert svc.stats()["requests"] == 2 * len(imgs)


def test_roundtrip_and_conv_datapaths():
    imgs = _imgs(3, seed=5)
    kernel = jnp.asarray(np.arange(9, dtype=np.int32).reshape(3, 3))
    conv_ref = np.asarray(
        radon.Conv2D((1, N, N), kernel, jnp.int32)(imgs[0][None]))[0]

    rt = DPRTService((N, N), jnp.int32, datapath="roundtrip", max_batch=2,
                     max_wait_us=100.0)
    rt.warmup()
    for g, img in zip(rt.run_requests(imgs), imgs):
        np.testing.assert_array_equal(np.asarray(g), img)   # bit-exact

    cv = DPRTService((N, N), jnp.int32, datapath="conv", max_batch=2,
                     conv_kernel=kernel, max_wait_us=100.0)
    cv.warmup()
    np.testing.assert_array_equal(
        np.asarray(cv.run_requests(imgs[:1])[0]), conv_ref)


def test_solve_datapath_serves_reconstructions():
    # requests are sinograms; responses are least-squares reconstructions
    imgs = _imgs(3, seed=6)
    fwd = radon.DPRT((N, N), jnp.int32)
    sinos = [np.asarray(fwd(jnp.asarray(x))).astype(np.float32)
             for x in imgs]

    svc = DPRTService((N, N), jnp.int32, datapath="solve", max_batch=2,
                      max_wait_us=100.0)
    assert svc.request_shape == (N + 1, N)
    assert svc.request_dtype == jnp.float32
    svc.warmup()
    for got, img in zip(svc.run_requests(sinos), imgs):
        # unmasked -> the Sherman-Morrison closed form == exact inverse
        np.testing.assert_allclose(np.asarray(got), img, atol=1e-3)
    assert svc.healthy()
    assert svc.stats()["datapath"] == "solve"

    # masked-direction CG datapath: the service must agree with a direct
    # radon.solve of the same masked operator
    mask = radon.direction_mask(N, [2])
    m = radon.MaskedDPRT(fwd, mask=mask)
    msinos = [np.asarray(m(jnp.asarray(x, jnp.float32))) for x in imgs]
    # reference solves trace BEFORE warmup: the retrace counter is
    # process-global and healthy() asserts zero post-warmup traces
    want = [np.asarray(radon.solve(m, jnp.asarray(s), "cg", tol=1e-6,
                                   maxiter=100).image) for s in msinos]
    svc2 = DPRTService((N, N), jnp.int32, datapath="solve", max_batch=2,
                       max_wait_us=100.0, solve_mask=mask, solver="cg",
                       solve_tol=1e-6, solve_maxiter=100)
    svc2.warmup()
    for got, ref in zip(svc2.run_requests(msinos), want):
        np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-4,
                                   atol=1e-4)
    assert svc2.healthy()


def test_reset_metrics_keeps_executables():
    imgs = _imgs(2)
    svc = DPRTService((N, N), jnp.int32, max_batch=2, max_wait_us=100.0)
    svc.warmup()
    svc.run_requests(imgs)
    svc.reset_metrics()
    s = svc.stats()
    assert s["requests"] == 0 and s["batches"] == 0
    assert s["latency"] == {"n": 0}
    assert s["steady_state_retraces"] == 0          # warmup baseline kept
    assert svc.run_requests(imgs)                   # still serves, no warmup


# ---------------------------------------------------------------------------
# persistent AOT executable cache
# ---------------------------------------------------------------------------
def test_persistent_cache_warm_restart_zero_traces(tmp_path):
    radon.aot_cache_clear()       # fresh in-memory cache: disk must decide
    svc1 = DPRTService((N, N), jnp.int32, max_batch=2,
                       aot_dir=str(tmp_path), max_wait_us=100.0)
    info1 = svc1.warmup()
    p1 = info1["persistent"]
    assert p1["misses"] == info1["executables"] and p1["hits"] == 0

    # simulated restart: in-memory executables gone, blobs remain
    radon.aot_cache_clear()
    t0 = radon.trace_count()
    svc2 = DPRTService((N, N), jnp.int32, max_batch=2,
                       aot_dir=str(tmp_path), max_wait_us=100.0)
    info2 = svc2.warmup()
    p2 = info2["persistent"]
    assert p2["hits"] == info2["executables"]
    assert p2["misses"] == 0 and p2["errors"] == 0
    assert radon.trace_count() == t0      # restore took ZERO traces/compiles

    out = svc2.run_requests([np.ones((N, N), np.int32)])
    assert np.asarray(out[0]).shape == (N + 1, N)
    assert svc2.healthy()
    assert "persistent_aot hits=" in svc2.healthz()


def test_persistent_cache_corrupt_and_stale_blobs(tmp_path):
    radon.aot_cache_clear()
    op = radon.DPRT((2, N, N), jnp.int32)
    first = radon.PersistentAOTCache(str(tmp_path))
    first.get_or_compile(op)
    s = first.stats()
    assert s["directory"] == str(tmp_path)
    assert (s["hits"], s["misses"], s["errors"]) == (0, 1, 0)
    assert s["degraded_compiles"] == 0
    # uncontended cold compile: the cross-process lock engaged cleanly
    assert s["lock_steals"] == 0 and s["lock_degraded"] == 0

    # torn blob on disk: counted as an error, recompiled, re-persisted
    # -- and surfaced as a DEGRADED compile (a blob existed, the
    # restart still had to pay XLA)
    blob = next(tmp_path.glob("*.blob"))
    blob.write_bytes(b"\xff" * 32)
    radon.aot_cache_clear()
    torn = radon.PersistentAOTCache(str(tmp_path))
    torn.get_or_compile(op)
    assert torn.errors == 1 and torn.misses == 1 and torn.hits == 0
    assert torn.degraded_compiles == 1

    # the recompile healed the blob: a clean restart now hits
    radon.aot_cache_clear()
    healed = radon.PersistentAOTCache(str(tmp_path))
    healed.get_or_compile(op)
    assert healed.hits == 1 and healed.misses == 0 and healed.errors == 0

    # stale environment fingerprint: a silent miss (recompile), not an
    # error -- the blob is valid, just compiled for another world
    save_blob(str(tmp_path), op.cache_token(), b"\x00",
              meta={"fingerprint": "jax=0.0.0;backend=nowhere"})
    radon.aot_cache_clear()
    stale = radon.PersistentAOTCache(str(tmp_path))
    stale.get_or_compile(op)
    assert stale.misses == 1 and stale.errors == 0 and stale.hits == 0
    assert stale.degraded_compiles == 1   # blob present, restore cold


def test_conv2d_aot_export_import_roundtrip():
    kernel = jnp.ones((3, 3), jnp.int32)
    op = radon.Conv2D((1, N, N), kernel, jnp.int32)
    x = np.arange(N * N, dtype=np.int32).reshape(1, N, N)
    want = np.asarray(op(x))
    op.compile()
    token = op.cache_token()
    assert token.startswith("conv2d_") and f"{N}x{N}" in token
    data = op.export_executable()
    radon.aot_cache_clear()
    exe = op.import_executable(data)
    np.testing.assert_array_equal(np.asarray(exe(x)), want)
    assert radon.aot_cache_info()["currsize"] == 1  # import installs + pins


# ---------------------------------------------------------------------------
# healthz surfaces
# ---------------------------------------------------------------------------
def test_service_healthz_report():
    svc = DPRTService((N, N), jnp.int32, max_batch=2, max_wait_us=100.0)
    svc.warmup()
    svc.run_requests(_imgs(3))
    text = svc.healthz()
    assert text.startswith("[healthz] OK ")
    assert "plan_cache hits=" in text and "evictions=" in text
    assert "latency p50=" in text
    assert "steady_state_retraces=0" in text
    s = svc.stats()
    assert isinstance(s["method"], str) and s["imgs_per_s"] > 0


def test_healthz_module_snapshot_and_report():
    radon.DPRT((N, N), jnp.int32)(np.ones((N, N), np.int32))  # warm a plan
    snap = healthz.snapshot()
    for key in ("fingerprint", "plan_cache", "plans", "traces_total",
                "traces", "aot_cache"):
        assert key in snap, key
    assert snap["traces_total"] == sum(snap["traces"].values())
    text = healthz.report()
    assert "[healthz]" in text and "plan_cache" in text
    assert healthz.main() == 0


def test_serve_cli_service_smoke(capsys):
    serve.main(["--mode", "service", "--smoke", "--batch", "2",
                "--iters", "1", "--max-wait-us", "200"])
    out = capsys.readouterr().out
    assert "[serve-service] warmup:" in out
    assert "coalescing speedup" in out
    assert "[healthz] OK " in out
