"""Projection-domain pipeline: fused conv/DFT dispatch, bit-exactness
against the staged path on every registered backend, exact autodiff
through the fused operators, and the circulant memory-regression guard."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conv as C
from repro.core import dft as F
from repro.core.dprt import dprt_oracle_np
from repro.core.plan import available_backends, backend_capabilities, \
    get_backend, get_plan
from repro.kernels.ops import (pipeline_tail_pallas,
                               projection_pipeline_pallas)
from repro import radon


def _nonmesh_backends():
    return [n for n in available_backends()
            if not get_backend(n).mesh_aware]


def _capable_backends():
    return [n for n in _nonmesh_backends()
            if get_backend(n).pipeline is not None]


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,m_block,group,lane_batch",
                         [(5, 4, 1, 1), (7, 8, 3, 2), (13, 4, 8, 3),
                          (13, 16, 4, 1)])
def test_pipeline_kernel_conv_matches_oracle(n, m_block, group, lane_batch):
    rng = np.random.default_rng(n)
    fb = jnp.asarray(rng.integers(0, 30, (3, n, n)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 9, (n, n)), jnp.int32)
    out = projection_pipeline_pallas(fb, "conv", g, m_block=m_block,
                                     group=group, lane_batch=lane_batch)
    for i in range(3):
        np.testing.assert_array_equal(
            np.asarray(out[i], np.int64),
            np.asarray(C.circ_conv2d_direct(fb[i], g)))
    # round trip (op="none") and all-ones pointwise weights == identity
    np.testing.assert_array_equal(
        np.asarray(projection_pipeline_pallas(
            fb, "none", m_block=m_block, group=group,
            lane_batch=lane_batch)), np.asarray(fb))
    np.testing.assert_array_equal(
        np.asarray(projection_pipeline_pallas(
            fb, "mul", jnp.ones((n + 1, n), jnp.int32), m_block=m_block,
            group=group, lane_batch=lane_batch)), np.asarray(fb))


def test_pipeline_kernel_operand_forms_agree():
    rng = np.random.default_rng(0)
    n = 13
    fb = jnp.asarray(rng.integers(0, 30, (4, n, n)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 9, (n, n)), jnp.int32)
    gb = jnp.asarray(rng.integers(0, 9, (4, n, n)), jnp.int32)
    rg = jnp.asarray(dprt_oracle_np(np.asarray(g)), jnp.int32)
    img = projection_pipeline_pallas(fb, "conv", g)
    proj = projection_pipeline_pallas(fb, "conv", rg, operand_form="proj")
    np.testing.assert_array_equal(np.asarray(img), np.asarray(proj))
    # per-image batched operand
    outb = projection_pipeline_pallas(fb, "conv", gb)
    for i in range(4):
        np.testing.assert_array_equal(
            np.asarray(outb[i], np.int64),
            np.asarray(C.circ_conv2d_direct(fb[i], gb[i])))


def test_pipeline_kernel_float_roundtrip():
    rng = np.random.default_rng(1)
    ff = jnp.asarray(rng.random((2, 7, 7)), jnp.float32)
    out = projection_pipeline_pallas(ff, "none")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ff),
                               rtol=1e-5, atol=1e-5)


def test_pipeline_tail_partials_sum_to_full_inverse():
    """Tail mode (the mesh phase 2): direction shards with offsets must
    psum to the exact staged convolution."""
    rng = np.random.default_rng(2)
    n = 13
    f = jnp.asarray(rng.integers(0, 30, (n, n)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 9, (n, n)), jnp.int32)
    rfull = jnp.asarray(dprt_oracle_np(np.asarray(f)), jnp.int32)
    rg = jnp.asarray(dprt_oracle_np(np.asarray(g)), jnp.int32)
    want = np.asarray(C.circ_conv2d_direct(f, g))

    half = (n + 2) // 2
    zs, auxs = [], []
    for r in range(2):
        rows = rfull[r * half:(r + 1) * half]
        if rows.shape[0] < half:
            rows = jnp.pad(rows, ((0, half - rows.shape[0]), (0, 0)))
        z, aux = pipeline_tail_pallas(rows, "conv", rg,
                                      row_offset=r * half, n=n)
        zs.append(z)
        auxs.append(aux)
    z, aux = zs[0] + zs[1], auxs[0] + auxs[1]
    s = aux[0, :n].sum()
    cn = aux[1, :n][:, None]
    np.testing.assert_array_equal(
        np.asarray((z[:n, :n] - s + cn) // n, np.int64), want)


def test_pipeline_kernel_rejects_bad_operands():
    f = jnp.zeros((5, 5), jnp.int32)
    with pytest.raises(ValueError):
        projection_pipeline_pallas(f, "conv")          # missing operand
    with pytest.raises(ValueError):
        projection_pipeline_pallas(f, "warp", f)       # unknown op
    with pytest.raises(ValueError):
        projection_pipeline_pallas(f, "mul", jnp.zeros((4, 5), jnp.int32))
    with pytest.raises(ValueError):                    # batch mismatch
        projection_pipeline_pallas(jnp.zeros((3, 5, 5), jnp.int32), "conv",
                                   jnp.zeros((2, 5, 5), jnp.int32))
    with pytest.raises(ValueError):                    # non-prime
        projection_pipeline_pallas(jnp.zeros((6, 6), jnp.int32), "none")


# ---------------------------------------------------------------------------
# plan-level dispatch: fused == staged on every registered backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", _nonmesh_backends())
def test_plan_pipeline_bit_exact_vs_staged(method):
    rng = np.random.default_rng(3)
    n = 13
    f = jnp.asarray(rng.integers(0, 30, (n, n)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 9, (n, n)), jnp.int32)
    plan = get_plan((n, n), jnp.int32, method)
    want = np.asarray(C.circ_conv2d_direct(f, g))
    np.testing.assert_array_equal(
        np.asarray(plan.pipeline(f, "conv", g), np.int64), want)
    rg = jnp.asarray(dprt_oracle_np(np.asarray(g)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(plan.pipeline(f, "conv", rg), np.int64), want)
    np.testing.assert_array_equal(np.asarray(plan.pipeline(f, "none")),
                                  np.asarray(f))


def test_plan_pipeline_validations():
    plan = get_plan((6, 8), jnp.int32, "pallas")   # embedded geometry
    f = jnp.zeros((6, 8), jnp.int32)
    with pytest.raises(ValueError):                # conv needs native
        plan.pipeline(f, "conv", f)
    with pytest.raises(ValueError):
        plan.pipeline(f, "mul")                    # operand missing
    # mul on an embedded geometry is the literal fused composition
    w = jnp.ones(plan.geometry.transform_shape, jnp.int32)
    np.testing.assert_array_equal(np.asarray(plan.pipeline(f + 3, "mul", w)),
                                  np.asarray(f + 3))


def test_capability_table_has_pipeline_column():
    rows = {r["name"]: r for r in backend_capabilities()}
    assert rows["pallas"]["pipeline"] is True
    assert rows["sharded_pallas"]["pipeline"] is True
    assert rows["horner"]["pipeline"] is False
    assert rows["gather"]["pipeline"] is False


# ---------------------------------------------------------------------------
# conv/dft entry points: fused vs staged
# ---------------------------------------------------------------------------
def test_circ_conv_fused_equals_staged_batched():
    rng = np.random.default_rng(4)
    n = 13
    fb = jnp.asarray(rng.integers(0, 200, (5, n, n)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 16, (n, n)), jnp.int32)
    fused = C.circ_conv2d_dprt(fb, g)
    staged = C.circ_conv2d_dprt(fb, g, fuse=False)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(staged))
    # both operands batched
    gb = jnp.asarray(rng.integers(0, 16, (5, n, n)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(C.circ_conv2d_dprt(fb, gb)),
        np.asarray(C.circ_conv2d_dprt(fb, gb, fuse=False)))
    # batched g against single f (commuted pipeline)
    np.testing.assert_array_equal(
        np.asarray(C.circ_conv2d_dprt(fb[0], g)),
        np.asarray(C.circ_conv2d_dprt(fb[0], g, fuse=False)))


def test_linear_conv_fused_equals_staged_rectangular():
    rng = np.random.default_rng(5)
    f = jnp.asarray(rng.integers(0, 200, (9, 6)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 16, (3, 4)), jnp.int32)
    fused = np.asarray(C.linear_conv2d_dprt(f, g))
    staged = np.asarray(C.linear_conv2d_dprt(f, g, fuse=False))
    np.testing.assert_array_equal(fused, staged)
    np.testing.assert_array_equal(fused, C.linear_conv2d_direct(f, g))


def test_linear_conv_blocked_fused_equals_staged():
    """Overlap-add tiles ride the batched pipeline; result must match
    the staged tile path and the whole-image result bit-for-bit."""
    rng = np.random.default_rng(6)
    f = jnp.asarray(rng.integers(0, 200, (13, 17)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 16, (3, 3)), jnp.int32)
    fused = np.asarray(C.linear_conv2d_dprt(f, g, block_size=5))
    staged = np.asarray(C.linear_conv2d_dprt(f, g, block_size=5,
                                             fuse=False))
    np.testing.assert_array_equal(fused, staged)
    np.testing.assert_array_equal(fused, C.linear_conv2d_direct(f, g))
    # batched stack through the blocked route
    fb = jnp.asarray(rng.integers(0, 200, (2, 10, 8)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(C.linear_conv2d_dprt(fb, g, block_size=4)),
        np.asarray(C.linear_conv2d_dprt(fb, g, block_size=4, fuse=False)))


def test_circ_conv_torus_fused_equals_staged():
    rng = np.random.default_rng(7)
    f = jnp.asarray(rng.integers(0, 50, (6, 8)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 10, (6, 8)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(C.circ_conv2d_dprt(f, g)),
        np.asarray(C.circ_conv2d_dprt(f, g, fuse=False)))


@pytest.mark.parametrize("method", _nonmesh_backends())
def test_dft2_bit_exact_across_backends(method):
    """The DFT's integer stage must be bit-identical on every backend,
    so the float spectra match exactly (same FFT on the same ints)."""
    rng = np.random.default_rng(8)
    n = 13
    f = jnp.asarray(rng.integers(0, 256, (n, n)), jnp.int32)
    base = np.asarray(F.dft2_via_dprt(f))
    np.testing.assert_array_equal(np.asarray(F.dft2_via_dprt(
        f, method=method)), base)
    fb = jnp.asarray(rng.integers(0, 256, (3, n, n)), jnp.int32)
    baseb = np.asarray(F.dft2_via_dprt_batched(fb))
    np.testing.assert_array_equal(np.asarray(F.dft2_via_dprt_batched(
        fb, method=method)), baseb)


# ---------------------------------------------------------------------------
# memory regression: circ_conv1d_exact must not materialize per-batch
# circulants
# ---------------------------------------------------------------------------
def _max_intermediate_size(fn, *avals) -> int:
    jaxpr = jax.make_jaxpr(fn)(*avals)

    def walk(jpr):
        worst = 0
        for eqn in jpr.eqns:
            for v in eqn.outvars:
                if hasattr(v.aval, "shape"):
                    size = int(np.prod(v.aval.shape)) if v.aval.shape else 1
                    worst = max(worst, size)
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    worst = max(worst, walk(sub.jaxpr))
        return worst
    return walk(jaxpr.jaxpr)


def test_circ_conv1d_batched_peak_size_bounded():
    b, rows, n = 8, 14, 13
    a = jax.ShapeDtypeStruct((b, rows, n), jnp.int32)
    bb = jax.ShapeDtypeStruct((b, rows, n), jnp.int32)
    peak = _max_intermediate_size(C.circ_conv1d_exact, a, bb)
    # one (rows, N, N) circulant at a time -- never the O(B * rows * N^2)
    # blow-up the un-streamed gather produced
    assert peak < b * rows * n * n, peak
    assert peak >= rows * n * n
    # and a batched b against unbatched a commutes to the small circulant
    a1 = jax.ShapeDtypeStruct((rows, n), jnp.int32)
    peak2 = _max_intermediate_size(C.circ_conv1d_exact, a1, bb)
    assert peak2 < b * rows * n * n, peak2


def test_circ_conv1d_batched_correctness():
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.integers(-50, 50, (3, 4, 11)), jnp.int32)
    b = jnp.asarray(rng.integers(-10, 10, (3, 4, 11)), jnp.int32)
    got = np.asarray(C.circ_conv1d_exact(a, b))
    for i in range(3):
        for j in range(4):
            want = [sum(int(a[i, j, t]) * int(b[i, j, (d - t) % 11])
                        for t in range(11)) for d in range(11)]
            np.testing.assert_array_equal(got[i, j], want)
    # unbatched-vs-batched swap path
    got2 = np.asarray(C.circ_conv1d_exact(a[0], b))
    for i in range(3):
        want = np.asarray(C.circ_conv1d_exact(a[0], b[i]))
        np.testing.assert_array_equal(got2[i], want)
    with pytest.raises(ValueError):
        C.circ_conv1d_exact(a, b[:2])


# ---------------------------------------------------------------------------
# operators: Conv2D / ProjectionFilter / composite fusion + exact grads
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [5, 7, 13])
@pytest.mark.parametrize("method", _capable_backends() + ["horner"])
def test_conv2d_grad_matches_dense_oracle(n, method):
    rng = np.random.default_rng(n)
    f = jnp.asarray(rng.random((n, n)), jnp.float32)
    kern = jnp.asarray(rng.random((3, 3)), jnp.float32)
    u = jnp.asarray(rng.random((n, n)), jnp.float32)
    op = radon.Conv2D((n, n), kern, jnp.float32, method)
    dense = np.asarray(op.as_matrix(), np.float64)
    # grad of <C f, u> w.r.t. f is C^T u
    grad = jax.grad(lambda x: (op(x) * u).sum())(f)
    np.testing.assert_allclose(np.asarray(grad).ravel(),
                               dense.T @ np.asarray(u).ravel(),
                               rtol=3e-4, atol=3e-4)
    # op.T applies the same matrix transpose
    np.testing.assert_allclose(np.asarray(op.T(u)).ravel(),
                               dense.T @ np.asarray(u).ravel(),
                               rtol=3e-4, atol=3e-4)


def test_conv2d_grad_wrt_kernel():
    rng = np.random.default_rng(11)
    n = 7
    f = jnp.asarray(rng.random((n, n)), jnp.float32)
    u = jnp.asarray(rng.random((n, n)), jnp.float32)
    plan = get_plan((n, n), jnp.float32, "pallas")
    kern = jnp.asarray(rng.random((n, n)), jnp.float32)
    gk = jax.grad(lambda y: (radon.pipeline_apply(plan, f, "conv", y)
                             * u).sum())(kern)
    dense_g = np.zeros((n * n, n * n))
    for j in range(n * n):
        e = np.zeros((n, n), np.float32)
        e.flat[j] = 1
        dense_g[:, j] = np.asarray(
            C.circ_conv2d_direct(f, jnp.asarray(e))).ravel()
    np.testing.assert_allclose(np.asarray(gk).ravel(),
                               dense_g.T @ np.asarray(u).ravel(),
                               rtol=3e-4, atol=3e-4)


def test_conv2d_exact_int_and_torus():
    rng = np.random.default_rng(12)
    f = jnp.asarray(rng.integers(0, 100, (13, 13)), jnp.int32)
    kern = jnp.asarray(rng.integers(0, 9, (4, 4)), jnp.int32)
    op = radon.Conv2D((13, 13), kern)
    want = C.circ_conv2d_direct(
        f, jnp.pad(kern, ((0, 9), (0, 9))))
    np.testing.assert_array_equal(np.asarray(op(f), np.int64),
                                  np.asarray(want))
    # non-prime torus geometry
    f2 = jnp.asarray(rng.integers(0, 50, (6, 8)), jnp.int32)
    op2 = radon.Conv2D((6, 8), kern)
    want2 = C.circ_conv2d_dprt(f2, jnp.pad(kern, ((0, 2), (0, 4))))
    np.testing.assert_array_equal(np.asarray(op2(f2)), np.asarray(want2))


def test_composite_recognizes_inv_pointwise_fwd():
    rng = np.random.default_rng(13)
    n = 13
    f = jnp.asarray(rng.random((n, n)), jnp.float32)
    w = jnp.asarray(rng.random((n + 1, n)), jnp.float32)
    dp = radon.DPRT((n, n), jnp.float32, "pallas")
    comp = dp.inverse @ radon.ProjectionFilter(w) @ dp
    assert len(comp.ops) == 1
    assert isinstance(comp.ops[0], radon.FusedProjectionPipeline)
    got = comp(f)
    want = dp.inverse(w * dp(f))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # grads agree with the unfused composition
    gc = jax.grad(lambda x: (comp(x) ** 2).sum())(f)
    gs = jax.grad(lambda x: ((dp.inverse(w * dp(x))) ** 2).sum())(f)
    np.testing.assert_allclose(np.asarray(gc), np.asarray(gs),
                               rtol=3e-4, atol=3e-4)
    # .T round-trips through the adjoint datapaths
    u = jnp.asarray(rng.random((n, n)), jnp.float32)
    lhs = float((comp(f) * u).sum())
    rhs = float((f * comp.ops[0].T(u)).sum())
    assert abs(lhs - rhs) < 1e-2 * max(1.0, abs(lhs))


def test_composite_fusion_requires_matching_plan():
    n = 13
    dp = radon.DPRT((n, n), jnp.float32, "pallas")
    other = radon.DPRT((n, n), jnp.float32, "horner")
    w = jnp.ones((n + 1, n), jnp.float32)
    comp = dp.inverse @ radon.ProjectionFilter(w) @ other
    # plans differ -> NOT fused, still correct
    assert len(comp.ops) == 3
    f = jnp.ones((n, n), jnp.float32)
    np.testing.assert_allclose(np.asarray(comp(f)), np.asarray(f),
                               rtol=2e-5, atol=2e-5)


def test_pipeline_trace_counting_and_retrace_guard():
    n = 13
    rng = np.random.default_rng(14)
    f = jnp.asarray(rng.integers(0, 30, (n, n)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 9, (n, n)), jnp.int32)
    C.circ_conv2d_dprt(f, g)   # first call traces
    with radon.retrace_guard(max_traces=0):
        for _ in range(3):     # steady state: zero retraces
            C.circ_conv2d_dprt(f + 1, g)


def test_pipeline_ladder_step_impl_matches_permute():
    """The rotate+select ladder datapath (the Mosaic/TPU lowering) must
    produce the same bits as the interpret-default permute lowering."""
    from repro.kernels.sfdprt import pipeline_pallas_raw
    rng = np.random.default_rng(15)
    n = 13
    fb = jnp.asarray(rng.integers(0, 30, (2, n, n)), jnp.int32)
    g = jnp.asarray(rng.integers(0, 9, (n, n)), jnp.int32)
    for op, operand, form in [("conv", g[None], "image"), ("none", None,
                                                          "proj")]:
        a, _ = pipeline_pallas_raw(fb, operand, op=op, operand_form=form,
                                   m_block=4, group=3, step_impl="permute")
        b, _ = pipeline_pallas_raw(fb, operand, op=op, operand_form=form,
                                   m_block=4, group=3, step_impl="ladder")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_composite_aot_survives_plan_cache_clear():
    """Regression: evicting plans used to crash on composite AOT keys
    containing filter/fused 4-tuple entries (and never actually dropped
    them)."""
    n = 13
    dp = radon.DPRT((n, n), jnp.float32, "pallas")
    w = jnp.ones((n + 1, n), jnp.float32)
    comp = dp.inverse @ radon.ProjectionFilter(w) @ dp
    exe = comp.compile()
    f = jnp.ones((n, n), jnp.float32)
    np.testing.assert_allclose(np.asarray(exe(f)), np.asarray(f),
                               rtol=1e-5, atol=1e-5)
    before = radon.aot_cache_info()["currsize"]
    assert before >= 1
    radon.plan_cache_clear()       # must not raise, must drop the entry
    assert radon.aot_cache_info()["currsize"] < before


def test_fused_composite_keeps_forward_input_dtype():
    """The fusion rewrite must not change a composite's input signature:
    dtype_in stays the forward operator's image dtype."""
    n = 13
    dp = radon.DPRT((n, n), jnp.uint8, "pallas")
    w = jnp.ones((n + 1, n), jnp.int32)
    comp = dp.inverse @ radon.ProjectionFilter(w) @ dp
    assert isinstance(comp.ops[0], radon.FusedProjectionPipeline)
    assert comp.dtype_in == jnp.dtype(jnp.uint8)
    img = jnp.arange(n * n, dtype=jnp.uint8).reshape(n, n)
    exe = comp.compile()           # AOT signature accepts uint8 images
    np.testing.assert_array_equal(np.asarray(exe(img)),
                                  np.asarray(img.astype(jnp.int32)))


def test_operator_inverse_errors_are_informative():
    n = 13
    w = jnp.ones((n + 1, n), jnp.float32)
    with pytest.raises(TypeError, match="no exact inverse"):
        radon.ProjectionFilter(w).inverse
    with pytest.raises(TypeError, match="no exact inverse"):
        radon.Conv2D((n, n), w[:2, :2]).inverse
    dp = radon.DPRT((n, n), jnp.float32, "pallas")
    comp = dp.inverse @ radon.ProjectionFilter(w) @ dp
    with pytest.raises(TypeError, match="no exact inverse"):
        comp.inverse


def test_sharded_pipeline_rejects_mismatched_operand_batch():
    from repro.core.distributed import projection_pipeline_sharded
    mesh = jax.make_mesh((1,), ("model",))
    fb = jnp.zeros((5, 13, 13), jnp.int32)
    bad = jnp.zeros((3, 14, 13), jnp.int32)
    with pytest.raises(ValueError, match="must match the stack batch"):
        projection_pipeline_sharded(fb, mesh, "conv", bad)


def test_circ_conv1d_mixed_rank_broadcast():
    """Regression: a higher-rank `a` against a lower-rank batched `b`
    broadcasts (the circulant still comes from the lower-rank side)."""
    rng = np.random.default_rng(16)
    a = jnp.asarray(rng.integers(-9, 9, (2, 3, 4, 11)), jnp.int32)
    b = jnp.asarray(rng.integers(-9, 9, (3, 4, 11)), jnp.int32)
    got = np.asarray(C.circ_conv1d_exact(a, b))
    assert got.shape == (2, 3, 4, 11)
    for i in range(2):
        np.testing.assert_array_equal(
            got[i], np.asarray(C.circ_conv1d_exact(a[i], b)))


def test_filter_composite_lowers_for_weights_shape():
    """inverse @ ProjectionFilter (projection-domain input) AOT-lowers
    using the weights' own shape instead of crashing on the wildcard."""
    n = 13
    dp = radon.DPRT((n, n), jnp.float32, "pallas")
    w = jnp.ones((n + 1, n), jnp.float32)
    comp = dp.inverse @ radon.ProjectionFilter(w)
    exe = comp.compile()
    r = dp(jnp.ones((n, n), jnp.float32))
    np.testing.assert_allclose(np.asarray(exe(r)),
                               np.asarray(dp.inverse(w * r)),
                               rtol=1e-5, atol=1e-5)


def test_conv2d_transpose_keeps_plan_knobs():
    op = radon.Conv2D((4, 13, 13), jnp.ones((3, 3), jnp.int32),
                      block_batch=2)
    assert op.T.plan.block_batch == 2
    assert op.T.plan.batch_impl == op.plan.batch_impl


def test_pipeline_block_batch_with_batched_operand():
    """block_batch must bound the fused pipeline even when the conv
    operand is per-image batched (image and operand chunk together)."""
    rng = np.random.default_rng(17)
    n = 13
    fb = jnp.asarray(rng.integers(0, 50, (5, n, n)), jnp.int32)
    gb = jnp.asarray(rng.integers(0, 9, (5, n, n)), jnp.int32)
    whole = get_plan((5, n, n), jnp.int32, "pallas")
    chunked = get_plan((5, n, n), jnp.int32, "pallas", block_batch=2)
    np.testing.assert_array_equal(
        np.asarray(chunked.pipeline(fb, "conv", gb)),
        np.asarray(whole.pipeline(fb, "conv", gb)))
    # shared operand keeps chunking too
    np.testing.assert_array_equal(
        np.asarray(chunked.pipeline(fb, "conv", gb[0])),
        np.asarray(whole.pipeline(fb, "conv", gb[0])))
