"""Core DPRT: exactness, invariants (property-based), paper-pinned models."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

import importlib
D = importlib.import_module("repro.core.dprt")
P = importlib.import_module("repro.core.pareto")

PRIMES = [2, 3, 5, 7, 11, 13, 17, 31]
METHODS = [("gather", {}), ("horner", {}), ("strips", {"strip_rows": 2}),
           ("strips", {"strip_rows": 5})]


def rand_img(n, seed=0, lo=0, hi=256):
    return np.random.default_rng(seed).integers(lo, hi, (n, n)).astype(np.int32)


@pytest.mark.parametrize("n", PRIMES)
@pytest.mark.parametrize("method,kw", METHODS)
def test_forward_matches_oracle(n, method, kw):
    if kw.get("strip_rows", 1) > n:
        pytest.skip("strip taller than image")
    f = rand_img(n, seed=n)
    ref = D.dprt_oracle_np(f)
    out = np.asarray(D.dprt(jnp.asarray(f), method=method, **kw))
    np.testing.assert_array_equal(out, ref)


@pytest.mark.parametrize("n", PRIMES)
@pytest.mark.parametrize("method,kw", METHODS)
def test_roundtrip_bit_exact(n, method, kw):
    if kw.get("strip_rows", 1) > n:
        pytest.skip("strip taller than image")
    f = rand_img(n, seed=n + 100)
    r = D.dprt(jnp.asarray(f), method=method, **kw)
    back = np.asarray(D.idprt(r, method=method, **kw))
    np.testing.assert_array_equal(back, f)


def test_all_strip_heights_n13():
    f = rand_img(13, seed=3)
    ref = D.dprt_oracle_np(f)
    for h in range(1, 14):
        out = np.asarray(D.dprt(jnp.asarray(f), method="strips",
                                strip_rows=h))
        np.testing.assert_array_equal(out, ref, err_msg=f"H={h}")


def test_arbitrary_geometry_embeds_to_next_prime():
    """Non-prime / non-square inputs are zero-embedded (plan layer), not
    rejected; projections come back in the (P+1, P) prime domain."""
    assert D.dprt(jnp.zeros((4, 4), jnp.int32)).shape == (6, 5)
    assert D.dprt(jnp.zeros((3, 5), jnp.int32)).shape == (6, 5)
    f = rand_img(6, seed=9)[:4]                   # (4, 6) rectangle
    r = D.dprt(jnp.asarray(f))
    assert r.shape == (8, 7)                      # next_prime(6) = 7
    fp = np.zeros((7, 7), f.dtype)
    fp[:4, :6] = f
    np.testing.assert_array_equal(np.asarray(r), D.dprt_oracle_np(fp))


def test_rejects_malformed_inputs():
    with pytest.raises(ValueError):               # not a projection shape
        D.idprt(jnp.zeros((5, 5), jnp.int32))
    with pytest.raises(ValueError):               # (N+1, N) but N not prime
        D.idprt(jnp.zeros((10, 9), jnp.int32))
    with pytest.raises(ValueError):               # 4-D is not a geometry
        D.dprt(jnp.zeros((2, 2, 4, 4), jnp.int32))
    with pytest.raises(ValueError):
        D.dprt_batched(jnp.zeros((5, 5), jnp.int32))


# ---------------------------------------------------------------------------
# property-based invariants
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([5, 7, 11]), seed=st.integers(0, 10 ** 6))
def test_projection_sums_equal_total(n, seed):
    """Every projection of the DPRT sums to the total pixel sum (eq. 4)."""
    f = rand_img(n, seed)
    r = D.dprt_oracle_np(f)
    s = f.sum()
    assert (r.sum(axis=1) == s).all()


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([5, 7, 11]), seed=st.integers(0, 10 ** 6))
def test_inverse_numerator_divisible_by_n(n, seed):
    """The iDPRT bracket is always divisible by N (exact reconstruction)."""
    f = rand_img(n, seed)
    r = D.dprt_oracle_np(f)
    z = np.asarray(D.skew_sum(jnp.asarray(r[:n]), -1, method="horner"))
    num = z - f.sum() + r[n][:, None]
    assert (num % n == 0).all()


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([5, 7]), seed=st.integers(0, 10 ** 6))
def test_linearity(n, seed):
    a = rand_img(n, seed)
    b = rand_img(n, seed + 1)
    ra = np.asarray(D.dprt(jnp.asarray(a)))
    rb = np.asarray(D.dprt(jnp.asarray(b)))
    rab = np.asarray(D.dprt(jnp.asarray(a + b)))
    np.testing.assert_array_equal(rab, ra + rb)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([5, 7, 11]), s=st.integers(1, 10),
       seed=st.integers(0, 10 ** 6))
def test_column_shift_property(n, s, seed):
    """f(i, <j-s>) has DPRT R(m, <d-s>) for m<N (shift covariance)."""
    f = rand_img(n, seed)
    fs = np.roll(f, s % n, axis=1)
    r = D.dprt_oracle_np(f)
    rs = D.dprt_oracle_np(fs)
    np.testing.assert_array_equal(rs[:n], np.roll(r[:n], s % n, axis=1))


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([5, 7]), seed=st.integers(0, 10 ** 6),
       h=st.integers(1, 7))
def test_strip_decomposition_property(n, seed, h):
    """Partial DPRTs accumulate to the full DPRT for any H (eq. 8)."""
    if h > n:
        h = n
    f = rand_img(n, seed)
    out = np.asarray(D.dprt(jnp.asarray(f), method="strips", strip_rows=h))
    np.testing.assert_array_equal(out, D.dprt_oracle_np(f))


def test_dtypes_and_batching():
    f = rand_img(7, 5, hi=255).astype(np.uint8)
    r8 = np.asarray(D.dprt(jnp.asarray(f)))
    np.testing.assert_array_equal(r8, D.dprt_oracle_np(f.astype(np.int32)))
    fb = np.stack([rand_img(7, i) for i in range(4)])
    rb = np.asarray(D.dprt_batched(jnp.asarray(fb)))
    for i in range(4):
        np.testing.assert_array_equal(rb[i], D.dprt_oracle_np(fb[i]))


# ---------------------------------------------------------------------------
# the paper's analytical models, pinned to quoted numbers (Sec. V)
# ---------------------------------------------------------------------------
def test_paper_cycle_pins():
    assert P.cycles_fdprt(251) == 511            # "requires only 511 cycles"
    assert P.cycles_systolic(251) == 63253       # "63,253 clock cycles"
    assert P.cycles_serial(251) == 251 ** 3 + 2 * 251 ** 2 + 251
    assert P.cycles_sfdprt(251, 2) == \
        (251 // 2 + 1) * (251 + 9) + 251 + 2     # H=2 lowest-resource row


def test_paper_resource_pins():
    assert P.flipflops_systolic(251, 8) == 516096  # square dot in Fig. 19
    # "with 25% less resources for H=84 ... 36 times faster"
    speedup = P.cycles_systolic(251) / P.cycles_sfdprt(251, 84)
    assert 34 <= speedup <= 38
    ratio = P.flipflops_sfdprt(251, 84, 8) / P.flipflops_systolic(251, 8)
    assert 0.70 <= ratio <= 0.80


def test_pareto_front_monotone():
    front = P.pareto_front(251)
    assert front and front[0] == 2
    pts = P.pareto_points(251, 8)
    cycles = [p["cycles"] for p in pts]
    ffs = [p["ff"] for p in pts]
    assert cycles == sorted(cycles, reverse=True)   # more H -> fewer cycles
    assert ffs == sorted(ffs)                       # more H -> more FFs


def test_tree_resources_matches_structure():
    r = P.tree_resources(2, 8)
    assert r["fa"] == 8 and r["ff"] == 9            # one 8-bit adder stage
    assert P.tree_resources(1, 8) == {"fa": 0, "ff": 0, "mux": 0}


# ---------------------------------------------------------------------------
# accumulator promotion bound: v*N*(N+1) <= 2^31-1 (inverse worst case)
# ---------------------------------------------------------------------------
def test_int32_accum_bound_cliffs():
    """The documented cliffs of the exact-int32 bound: uint8 pixels hold
    to prime N=2897 and fail at 2903; int16 already fails at 257."""
    assert D.int32_accum_exact(2897, jnp.uint8)
    assert not D.int32_accum_exact(2903, jnp.uint8)
    assert D.int32_accum_exact(251, jnp.int16)
    assert not D.int32_accum_exact(257, jnp.int16)
    # the giant-N streamed geometries stay exact for 8-bit pixels
    assert D.int32_accum_exact(2053, jnp.uint8)
    assert not D.int32_accum_exact(4099, jnp.uint8)
    with pytest.raises(TypeError):
        D.int32_accum_exact(251, jnp.float32)


def test_accum_dtype_promotion_rules():
    # below the cliff: int32 accumulator, with or without N
    assert D.accum_dtype_for(jnp.uint8, 2897) == jnp.int32
    assert D.accum_dtype_for(jnp.int16, 251) == jnp.int32
    # int32/uint32 inputs never promote (their max is not a pixel bound)
    assert D.accum_dtype_for(jnp.int32, 4099) == jnp.int32
    assert D.accum_dtype_for(jnp.uint32, 4099) == jnp.int32
    # legacy dtype-only rule is unchanged
    assert D.accum_dtype_for(jnp.uint8) == jnp.int32
    assert D.accum_dtype_for(jnp.int64) == jnp.int64


def test_accum_overflow_regression_at_bound(subproc):
    """Full-range int16 pixels at N=257 (just past the int32 cliff):
    with x64 the accumulator promotes to int64 and the round trip is
    bit-exact; the same data WOULD overflow an int32 accumulator."""
    subproc("""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
import importlib
D = importlib.import_module("repro.core.dprt")
n = 257
assert not D.int32_accum_exact(n, jnp.int16)
assert D.accum_dtype_for(jnp.int16, n) == jnp.int64
rng = np.random.default_rng(19)
# near-full-range negative pixels: the inverse's per-pixel sum over all
# N directions (Z = sum_m R(m, <j - m i>)) reaches ~N^2 * 32768, past
# the int32 edge at N=257
f = (-32768 + rng.integers(0, 64, (n, n))).astype(np.int16)
r = D.dprt(jnp.asarray(f))
assert r.dtype == jnp.int64
rnp = np.asarray(r, dtype=np.int64)
cols = np.arange(n)
z = np.zeros((n, n), dtype=np.int64)
for i in range(n):
    z[i] = rnp[np.arange(n), (cols[None, :] - np.arange(n)[:, None] * i) % n
               ].sum(axis=0)
assert np.abs(z).max() > 2**31 - 1, "data must overflow an int32 accum"
back = D.idprt(r)
assert (np.asarray(back) == f.astype(np.int64)).all()
print("OK")
""", devices=1)


test_accum_overflow_regression_at_bound = pytest.mark.slow(
    test_accum_overflow_regression_at_bound)
