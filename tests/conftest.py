import importlib.util
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

# The target container has no hypothesis and pip installs are forbidden;
# fall back to the deterministic shim so property tests still run.
try:
    import hypothesis  # noqa: F401
except ImportError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", os.path.join(os.path.dirname(__file__),
                                   "_hypothesis_shim.py"))
    _shim = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies


def pytest_configure(config):
    # Quick tier: `pytest -m "not slow"` skips the forced-host subprocess
    # tests (each spawns a fresh 8-device python, ~10-60 s apiece).
    config.addinivalue_line(
        "markers",
        "slow: forced-host subprocess tests (sharded meshes, int64-x64); "
        "deselect with -m 'not slow' for the quick tier")


def run_subprocess(code: str, devices: int = 8, timeout: int = 600,
                   extra_env=None):
    """Run python code in a fresh process with N fake host devices.

    Needed because the main pytest process must keep the default single
    CPU device (smoke tests and benches see 1 device per the assignment).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.update(extra_env or {})
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=REPO, timeout=timeout, env=env)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={r.returncode})\n--- stdout ---\n"
            f"{r.stdout[-4000:]}\n--- stderr ---\n{r.stderr[-4000:]}")
    return r.stdout


@pytest.fixture
def subproc():
    return run_subprocess
